"""End-to-end behaviour tests for the DISC system on paper-like workloads.

These mirror the paper's evaluation setting: inference graphs with varying
sequence lengths, executed through the full DISC pipeline (bridge →
constraints → fusion → bucketed compile → generated dispatch) and checked
against direct JAX execution.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ArgSpec, BucketPolicy, compile as disc_compile


def transformer_ffn(x, w1, b1, w2, b2):
    h = jax.nn.gelu(x @ w1 + b1)
    return h @ w2 + b2


def layer_norm(x, g, b, eps=1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def attention(q, k, v):
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q, k) / np.sqrt(d)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v)


D = 32


def encoder_layer(x, wq, wk, wv, wo, w1, b1, w2, b2, g1, bb1, g2, bb2):
    """One pre-LN transformer encoder layer (the paper's main workload)."""
    h = layer_norm(x, g1, bb1)
    q, k, v = h @ wq, h @ wk, h @ wv
    x = x + attention(q, k, v) @ wo
    h = layer_norm(x, g2, bb2)
    return x + transformer_ffn(h, w1, b1, w2, b2)


def _layer_params(rng, d=D, f=4 * D):
    ws = [rng.randn(d, d).astype(np.float32) * 0.1 for _ in range(4)]
    w1 = rng.randn(d, f).astype(np.float32) * 0.1
    b1 = np.zeros(f, np.float32)
    w2 = rng.randn(f, d).astype(np.float32) * 0.1
    b2 = np.zeros(d, np.float32)
    g1 = np.ones(d, np.float32)
    bb1 = np.zeros(d, np.float32)
    g2 = np.ones(d, np.float32)
    bb2 = np.zeros(d, np.float32)
    return (*ws, w1, b1, w2, b2, g1, bb1, g2, bb2)


def _specs():
    return [ArgSpec(("B", "S", D))] + [
        ArgSpec((D, D)), ArgSpec((D, D)), ArgSpec((D, D)), ArgSpec((D, D)),
        ArgSpec((D, 4 * D)), ArgSpec((4 * D,)), ArgSpec((4 * D, D)),
        ArgSpec((D,)), ArgSpec((D,)), ArgSpec((D,)), ArgSpec((D,)),
        ArgSpec((D,)),
    ]


class TestTransformerLayerEndToEnd:
    def test_encoder_layer_dynamic_batch_and_seq(self):
        rng = np.random.RandomState(0)
        params = _layer_params(rng)
        eng = disc_compile(encoder_layer, _specs(), name="encoder_layer")
        for b, s in [(1, 7), (2, 19), (4, 64), (3, 33)]:
            x = rng.randn(b, s, D).astype(np.float32)
            got = eng(x, *params)
            want = encoder_layer(jnp.asarray(x), *params)
            np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)

    def test_seq2seq_style_varying_lengths_compile_bound(self):
        """The paper's Seq2seq scenario: ~uniform random lengths; compile
        count stays at #buckets while correctness holds per request."""
        rng = np.random.RandomState(1)
        params = _layer_params(rng)
        eng = disc_compile(encoder_layer, _specs(), name="seq2seq",
                         policy=BucketPolicy(kind="pow2", granule=16))
        lengths = rng.randint(1, 128, size=24)
        for s in lengths:
            x = rng.randn(2, int(s), D).astype(np.float32)
            got = eng(x, *params)
            want = encoder_layer(jnp.asarray(x), *params)
            np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)
        n_buckets = len({eng.policy.bucket("S", int(s)) for s in lengths})
        n_b_buckets = 1  # B is always 2
        assert eng.n_compiles == n_buckets * n_b_buckets
        assert eng.n_compiles <= 4  # 16/32/64/128

    def test_fusion_collapses_memory_ops(self):
        eng = disc_compile(encoder_layer, _specs(), name="fusion_stats")
        st = eng.plan.stats()
        # the paper's Table-3 effect: far fewer kernels than memory ops
        assert st["kernels_after_fusion"] < st["memory_ops"] / 2


class TestModelControlFlowSmoke:
    """whisper_tiny / rwkv6_3b greedy decode as ONE compiled artifact: the
    whole autoregressive loop is a traced ``lax.while_loop`` region, so
    the compile count is O(#entry-shape buckets) — one per batch bucket —
    and valid rows match eager greedy decode exactly."""

    MAXN = 4

    def _artifact(self, arch):
        import jax
        from repro.api import CompileOptions, Dim, TreeSpec
        from repro.configs import get_config
        from repro.models.registry import get_model

        cfg = get_config(arch).reduced()
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        dim_b = Dim("B", max=8)
        specs = [None, TreeSpec({1: "B"}),
                 ArgSpec((dim_b, 1), jnp.int32, name="tokens"),
                 ArgSpec((dim_b,), jnp.int32, name="lens")]
        kw = {}
        if arch == "whisper_tiny":
            import repro.models.whisper as whisper_mod
            specs.append(ArgSpec((dim_b, cfg.encoder_len, cfg.d_model),
                                 jnp.float32, name="enc_out"))

            def step(params, cache, toks, lens, enc_out):
                return model.greedy_decode(params, cache, toks, lens,
                                           enc_out=enc_out,
                                           max_new=self.MAXN, eos_id=-1)

            def enc(b):
                frames = jnp.zeros((b, cfg.encoder_len, cfg.d_model),
                                   jnp.float32)
                return whisper_mod.encode(cfg, params, frames)

            kw["enc"] = enc
        else:
            def step(params, cache, toks, lens):
                return model.greedy_decode(params, cache, toks, lens,
                                           max_new=self.MAXN, eos_id=-1)

        cf = disc_compile(
            step, specs=specs,
            options=CompileOptions(
                pipeline="jit", name=f"{arch}_greedy",
                policy=BucketPolicy(kind="multiple", granule=2)))
        return cfg, model, params, cf, kw

    def _run(self, arch):
        import numpy as _np

        cfg, model, params, cf, kw = self._artifact(arch)
        rng = _np.random.RandomState(3)
        seen_buckets = set()
        for b in (3, 4, 2):            # buckets: 4, 4, 2 -> 2 compiles
            cache = model.init_cache(b, 32)
            toks = rng.randint(1, cfg.vocab, size=(b, 1)).astype(_np.int32)
            lens = _np.ones((b,), _np.int32)
            extra = (kw["enc"](b),) if "enc" in kw else ()
            buf, n, _ = cf(params, cache, toks, lens, *extra)
            ekw = {"enc_out": extra[0]} if extra else {}
            want, wn, _ = model.greedy_decode(params, cache, toks, lens,
                                              max_new=self.MAXN, eos_id=-1,
                                              **ekw)
            # jit pipeline: batch rows beyond b are bucket padding
            _np.testing.assert_array_equal(_np.asarray(buf)[:b],
                                           _np.asarray(want))
            seen_buckets.add(-(-b // 2) * 2)
        assert cf.n_compiles == len(seen_buckets) == 2

    def test_rwkv6_single_artifact_decode(self):
        self._run("rwkv6_3b")

    def test_whisper_single_artifact_decode(self):
        self._run("whisper_tiny")

    def test_rwkv6_early_exit_matches_eager(self):
        """Exact-batch call (no padded rows): the traced while_loop's
        early-EOS exit runs the same number of steps as eager."""
        import jax

        from repro.configs import get_config
        from repro.models.registry import get_model

        cfg = get_config("rwkv6_3b").reduced()
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(1))
        b = 2
        cache = model.init_cache(b, 32)
        toks = np.array([[5], [9]], np.int32)
        lens = np.ones((b,), np.int32)
        # pick the token row 0 emits at step 0 as EOS: row 0 finishes
        # immediately, the loop keeps going only for row 1
        probe, _, _ = model.greedy_decode(params, cache, toks, lens,
                                          max_new=1, eos_id=-1)
        eos = int(np.asarray(probe)[0, 0])
        buf, n, _ = model.greedy_decode(params, cache, toks, lens,
                                        max_new=6, eos_id=eos)
        buf, n = np.asarray(buf), int(n)
        assert buf[0, 0] == eos
        assert (buf[0, 1:] == eos).all()   # frozen after EOS
        if (buf[1] == eos).any():          # row 1 hit EOS too -> early exit
            assert n == int(np.argmax(buf[1] == eos)) + 1
        else:
            assert n == 6
