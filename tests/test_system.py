"""End-to-end behaviour tests for the DISC system on paper-like workloads.

These mirror the paper's evaluation setting: inference graphs with varying
sequence lengths, executed through the full DISC pipeline (bridge →
constraints → fusion → bucketed compile → generated dispatch) and checked
against direct JAX execution.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ArgSpec, BucketPolicy, compile as disc_compile


def transformer_ffn(x, w1, b1, w2, b2):
    h = jax.nn.gelu(x @ w1 + b1)
    return h @ w2 + b2


def layer_norm(x, g, b, eps=1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def attention(q, k, v):
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q, k) / np.sqrt(d)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v)


D = 32


def encoder_layer(x, wq, wk, wv, wo, w1, b1, w2, b2, g1, bb1, g2, bb2):
    """One pre-LN transformer encoder layer (the paper's main workload)."""
    h = layer_norm(x, g1, bb1)
    q, k, v = h @ wq, h @ wk, h @ wv
    x = x + attention(q, k, v) @ wo
    h = layer_norm(x, g2, bb2)
    return x + transformer_ffn(h, w1, b1, w2, b2)


def _layer_params(rng, d=D, f=4 * D):
    ws = [rng.randn(d, d).astype(np.float32) * 0.1 for _ in range(4)]
    w1 = rng.randn(d, f).astype(np.float32) * 0.1
    b1 = np.zeros(f, np.float32)
    w2 = rng.randn(f, d).astype(np.float32) * 0.1
    b2 = np.zeros(d, np.float32)
    g1 = np.ones(d, np.float32)
    bb1 = np.zeros(d, np.float32)
    g2 = np.ones(d, np.float32)
    bb2 = np.zeros(d, np.float32)
    return (*ws, w1, b1, w2, b2, g1, bb1, g2, bb2)


def _specs():
    return [ArgSpec(("B", "S", D))] + [
        ArgSpec((D, D)), ArgSpec((D, D)), ArgSpec((D, D)), ArgSpec((D, D)),
        ArgSpec((D, 4 * D)), ArgSpec((4 * D,)), ArgSpec((4 * D, D)),
        ArgSpec((D,)), ArgSpec((D,)), ArgSpec((D,)), ArgSpec((D,)),
        ArgSpec((D,)),
    ]


class TestTransformerLayerEndToEnd:
    def test_encoder_layer_dynamic_batch_and_seq(self):
        rng = np.random.RandomState(0)
        params = _layer_params(rng)
        eng = disc_compile(encoder_layer, _specs(), name="encoder_layer")
        for b, s in [(1, 7), (2, 19), (4, 64), (3, 33)]:
            x = rng.randn(b, s, D).astype(np.float32)
            got = eng(x, *params)
            want = encoder_layer(jnp.asarray(x), *params)
            np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)

    def test_seq2seq_style_varying_lengths_compile_bound(self):
        """The paper's Seq2seq scenario: ~uniform random lengths; compile
        count stays at #buckets while correctness holds per request."""
        rng = np.random.RandomState(1)
        params = _layer_params(rng)
        eng = disc_compile(encoder_layer, _specs(), name="seq2seq",
                         policy=BucketPolicy(kind="pow2", granule=16))
        lengths = rng.randint(1, 128, size=24)
        for s in lengths:
            x = rng.randn(2, int(s), D).astype(np.float32)
            got = eng(x, *params)
            want = encoder_layer(jnp.asarray(x), *params)
            np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)
        n_buckets = len({eng.policy.bucket("S", int(s)) for s in lengths})
        n_b_buckets = 1  # B is always 2
        assert eng.n_compiles == n_buckets * n_b_buckets
        assert eng.n_compiles <= 4  # 16/32/64/128

    def test_fusion_collapses_memory_ops(self):
        eng = disc_compile(encoder_layer, _specs(), name="fusion_stats")
        st = eng.plan.stats()
        # the paper's Table-3 effect: far fewer kernels than memory ops
        assert st["kernels_after_fusion"] < st["memory_ops"] / 2
