"""Unit tests: symbolic shapes, constraint store, DHLO IR, jaxpr bridging."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.constraints import ConstraintViolation, ShapeConstraintStore
from repro.core.dhlo import DGraph
from repro.core.propagation import CostClass, PropClass, op_info
from repro.core.symshape import SizeExpr, fresh_symdim, size_of_shape
from repro.frontends import ArgSpec, bridge
from repro.frontends.jaxpr_frontend import eval_dim


class TestConstraints:
    def test_dim_equality_transitive(self):
        s = ShapeConstraintStore()
        a, b, c = fresh_symdim("a"), fresh_symdim("b"), fresh_symdim("c")
        s.assert_dim_eq(a, b)
        s.assert_dim_eq(b, c)
        assert s.dims_equal(a, c)

    def test_dim_refined_to_const(self):
        s = ShapeConstraintStore()
        a, b = fresh_symdim("a"), fresh_symdim("b")
        s.assert_dim_eq(a, b)
        s.assert_dim_eq(b, 128)
        assert s.canon_dim(a) == 128

    def test_dim_conflict_raises(self):
        s = ShapeConstraintStore()
        a = fresh_symdim("a")
        s.assert_dim_eq(a, 128)
        with pytest.raises(ConstraintViolation):
            s.assert_dim_eq(a, 64)

    def test_tensor_size_equality_structural(self):
        s = ShapeConstraintStore()
        b_, s_ = fresh_symdim("B"), fresh_symdim("S")
        s.note_value_size(1, (b_, s_, 64))
        s.note_value_size(2, (s_, b_, 8, 8))  # transpose+reshape: same count
        assert s.sizes_equal(1, 2)

    def test_tensor_size_equality_declared(self):
        s = ShapeConstraintStore()
        s.note_value_size(1, (fresh_symdim("B"), 4))
        s.note_value_size(2, (fresh_symdim("N"),))
        assert not s.sizes_equal(1, 2)
        s.assert_size_eq(1, 2)
        assert s.sizes_equal(1, 2)

    def test_size_equality_uses_dim_equality(self):
        s = ShapeConstraintStore()
        m, n = fresh_symdim("M"), fresh_symdim("N")
        s.note_value_size(1, (m, 16))
        s.note_value_size(2, (n, 16))
        assert not s.sizes_equal(1, 2)
        s.assert_dim_eq(m, n)
        assert s.sizes_equal(1, 2)

    def test_divisibility(self):
        s = ShapeConstraintStore()
        d = fresh_symdim("S")
        s.assert_divisible(d, 128)
        assert s.is_divisible(d, 128)
        assert s.is_divisible(d, 8)  # 128 % 8 == 0 implies d % 8 == 0
        assert not s.is_divisible(d, 3)


class TestSizeExpr:
    def test_canonical_product(self):
        b, s = fresh_symdim("B"), fresh_symdim("S")
        e1 = size_of_shape((b, s, 64))
        e2 = size_of_shape((s, 8, b, 8))
        assert e1 == e2

    def test_static(self):
        assert size_of_shape((4, 8)).coeff == 32
        assert size_of_shape((4, 8)).is_static()


class TestBridge:
    def test_elementwise_chain(self):
        def f(x, y):
            return jnp.tanh(x) * y + 1.0

        g, _ = bridge(f, [ArgSpec(("B", "D")), ArgSpec(("B", "D"))])
        codes = [op.opcode for op in g.ops]
        assert "tanh" in codes and "mul" in codes and "add" in codes
        # all elementwise ops share the (B, D) shape class
        keys = {g.store.shape_class_key(op.outputs[0].shape)
                for op in g.ops if op.opcode in ("tanh", "mul", "add")}
        assert len(keys) == 1

    def test_symbolic_dims_propagate_through_reshape(self):
        def f(x):  # (B, S, 64) -> (B, S, 8, 8) -> sum
            y = x.reshape(x.shape[0], x.shape[1], 8, 8)
            return y.sum(axis=-1)

        g, _ = bridge(f, [ArgSpec(("B", "S", 64))])
        out = g.outputs[0]
        names = [getattr(d, "name", d) for d in out.shape]
        assert names[0] == "B" and names[1] == "S" and out.shape[2] == 8

    def test_reshape_merge_derived_dim(self):
        def f(x):  # (B, S, D) -> (B*S, D)
            return x.reshape(-1, x.shape[-1])

        g, _ = bridge(f, [ArgSpec(("B", "S", 32))])
        out = g.outputs[0]
        merged = out.shape[0]
        assert hasattr(merged, "uid")
        bindings = {d.uid: v for d, v in zip(g.params[0].shape[:2], (4, 6))
                    if hasattr(d, "uid")}
        assert eval_dim(g, merged, bindings) == 24

    def test_dynamic_slice_is_dhlo_dslice(self):
        def f(x, i):
            return jax.lax.dynamic_slice(x, (i, 0), (2, 4))

        g, _ = bridge(f, [ArgSpec(("N", 4)), ArgSpec((), jnp.int32)])
        dslices = [op for op in g.ops if op.opcode == "dslice"]
        assert len(dslices) == 1
        # Fig. 2: start indices are tensor operands, not constant attrs
        assert len(dslices[0].shape_operands) == 2

    def test_dot_general_contract_constraint(self):
        def f(x, w):
            return x @ w

        # shared symbol "K" declares the contraction compatibility up front;
        # the semantic pass re-asserts it from dot_general's dnums
        g, _ = bridge(f, [ArgSpec(("B", "K")), ArgSpec(("K", 16))])
        k = g.params[0].shape[1]
        k2 = g.params[1].shape[0]
        assert g.store.dims_equal(k, k2)
        assert g.store.stats()["dim_constraints"] > 0
        dots = [op for op in g.ops if op.opcode == "dot_general"]
        assert len(dots) == 1
        out = dots[0].outputs[0]
        assert getattr(out.shape[0], "name", None) == "B"
        assert out.shape[1] == 16

    def test_split_hint_injected(self):
        def f(x):
            a, b, c = jnp.split(x, 3, axis=1)
            return a * b + c

        g, _ = bridge(f, [ArgSpec(("B", 12))])
        slices = [op for op in g.ops if op.opcode == "slice"]
        assert len(slices) == 3
        k0 = g.store.shape_class_key(slices[0].outputs[0].shape)
        assert all(g.store.shape_class_key(s.outputs[0].shape) == k0
                   for s in slices)

    def test_fingerprint_is_shape_free(self):
        def f(x):
            return jnp.exp(x) + 1.0

        g1, _ = bridge(f, [ArgSpec(("B", 64))])
        g2, _ = bridge(f, [ArgSpec(("N", 128))])
        assert g1.fingerprint() == g2.fingerprint()

        def h(x):
            return jnp.exp(x) * 2.0

        g3, _ = bridge(h, [ArgSpec(("B", 64))])
        assert g3.fingerprint() != g1.fingerprint()

    def test_concat_derived_sum_dim(self):
        def f(x, y):
            return jnp.concatenate([x, y], axis=0)

        g, _ = bridge(f, [ArgSpec(("M", 8)), ArgSpec(("N", 8))])
        out = g.outputs[0]
        m = g.params[0].shape[0]
        n = g.params[1].shape[0]
        assert eval_dim(g, out.shape[0], {m.uid: 5, n.uid: 9}) == 14


def in_dim_exprs(g: DGraph):
    return getattr(g, "dim_exprs", {})


class TestOpTable:
    def test_add_sub_share_prop_class(self):
        assert op_info("add").prop is op_info("sub").prop is PropClass.ELEMENTWISE

    def test_cost_classes(self):
        assert op_info("dot_general").cost is CostClass.COMPUTE
        assert op_info("add").cost is CostClass.MEMORY

    def test_pad_identities(self):
        assert op_info("reduce_sum").pad_identity == 0.0
        assert op_info("reduce_max").pad_identity == -float("inf")


class TestNestedCallInlining:
    def test_relu_nested_jit_is_inlined(self):
        """jax.nn.relu = custom_jvp_call wrapping an inner `jit` primitive;
        both levels must inline so no rep-traced call survives (regression:
        the opaque fallback bound a 37-shaped jaxpr at other buckets)."""
        def f(x):
            return jax.nn.relu(x) * 2.0

        g, _ = bridge(f, [ArgSpec(("B", 4))])
        assert all(op.opcode not in ("jit", "pjit", "custom_jvp_call")
                   for op in g.ops), [op.opcode for op in g.ops]
        codes = [op.opcode for op in g.ops]
        assert "max" in codes  # relu inlined down to lax.max

    def test_relu_engine_dynamic_shapes(self):
        from repro.api import compile as disc_compile

        def f(x):
            return jax.nn.relu(x - 0.5).sum(axis=1)

        eng = disc_compile(f, [ArgSpec(("B", 8))])
        for b in (3, 37, 50):  # 37 = a representative prime (the regression)
            x = np.random.randn(b, 8).astype(np.float32)
            np.testing.assert_allclose(eng(x), f(jnp.asarray(x)),
                                       rtol=1e-5, atol=1e-6)
