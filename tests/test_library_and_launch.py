"""§4.5 library interface + launcher smoke coverage."""
import jax.numpy as jnp
import numpy as np

from repro.core.library import pick


class TestLibrary:
    def test_vendor_fallback_for_odd_shapes(self):
        choice = pick(100, 100, 100)
        assert choice.name == "vendor:xla_dot"
        a = jnp.ones((100, 100))
        b = jnp.ones((100, 100))
        np.testing.assert_allclose(choice(a, b), a @ b)

    def test_tuned_kernel_for_aligned_shapes(self):
        choice = pick(128, 128, 128)
        assert choice.name.startswith("library:")
        rng = np.random.RandomState(0)
        a = jnp.asarray(rng.randn(128, 128), jnp.float32)
        b = jnp.asarray(rng.randn(128, 128), jnp.float32)
        np.testing.assert_allclose(choice(a, b), a @ b, rtol=1e-4, atol=1e-4)

    def test_decode_shape_routes_to_skinny(self):
        assert pick(8, 128, 128).name == "library:skinny_m"
