"""§4.3 Pallas codegen backend: eligible fusion clusters execute through
the fused kernels (interpret mode) and must match the XLA path exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ArgSpec, bridge, compile as disc_compile
from repro.core.codegen import (_pallas_input_eligible,
                                _pallas_loop_eligible)
from repro.core.fusion import plan_fusion


def _ew_chain(x, y):
    return jnp.tanh(x) * y + jnp.exp(x * 0.5) - y


def _reduce_chain(x):
    return (jnp.exp(x) * 0.5 + 1.0).sum(axis=-1)


class TestEligibility:
    def test_elementwise_chain_is_loop_eligible(self):
        g, _ = bridge(_ew_chain, [ArgSpec(("B", "D")), ArgSpec(("B", "D"))])
        plan = plan_fusion(g)
        assert any(_pallas_loop_eligible(g, c) for c in plan.clusters)

    def test_reduce_chain_is_input_eligible(self):
        g, _ = bridge(_reduce_chain, [ArgSpec(("B", "S"))])
        plan = plan_fusion(g)
        assert any(_pallas_input_eligible(g, c) for c in plan.clusters)

    def test_matmul_cluster_not_eligible(self):
        def f(x, w):
            return jnp.tanh(x @ w)

        g, _ = bridge(f, [ArgSpec(("B", 8)), ArgSpec((8, 8))])
        plan = plan_fusion(g)
        for c in plan.clusters:
            if any(op.opcode == "dot_general" for op in c.ops):
                assert not _pallas_loop_eligible(g, c)


class TestPallasBackendCorrectness:
    @pytest.mark.parametrize("shape", [(4, 16), (7, 33), (16, 64)])
    def test_elementwise_matches_xla(self, shape):
        eng = disc_compile(_ew_chain,
                         [ArgSpec(("B", "D")), ArgSpec(("B", "D"))],
                         backend="pallas")
        assert eng.report()["pallas_eligible_clusters"] >= 1
        rng = np.random.RandomState(0)
        x = rng.randn(*shape).astype(np.float32)
        y = rng.randn(*shape).astype(np.float32)
        np.testing.assert_allclose(np.asarray(eng(x, y)),
                                   np.asarray(_ew_chain(jnp.asarray(x),
                                                        jnp.asarray(y))),
                                   rtol=1e-5, atol=1e-6)

    @pytest.mark.parametrize("shape", [(8, 32), (3, 17)])
    def test_reduce_matches_xla(self, shape):
        eng = disc_compile(_reduce_chain, [ArgSpec(("B", "S"))],
                         backend="pallas")
        rng = np.random.RandomState(1)
        x = rng.randn(*shape).astype(np.float32)
        np.testing.assert_allclose(np.asarray(eng(x)),
                                   np.asarray(_reduce_chain(jnp.asarray(x))),
                                   rtol=1e-5, atol=1e-5)

    def test_mixed_graph_with_matmul(self):
        def f(x, w):
            h = jnp.tanh(x) * 2.0 + jnp.abs(x)      # pallas cluster
            z = h @ w                                # xla (library)
            return jax.nn.sigmoid(z) * z             # pallas cluster

        eng = disc_compile(f, [ArgSpec(("B", 16)), ArgSpec((16, 8))],
                         backend="pallas")
        rng = np.random.RandomState(2)
        x = rng.randn(5, 16).astype(np.float32)
        w = rng.randn(16, 8).astype(np.float32)
        np.testing.assert_allclose(
            np.asarray(eng(x, w)),
            np.asarray(f(jnp.asarray(x), jnp.asarray(w))),
            rtol=1e-4, atol=1e-5)

    def test_dynamic_shapes_masked(self):
        # tainted padded region (exp) feeding a reduce: the Pallas kInput
        # kernel must mask with the actual column count
        eng = disc_compile(_reduce_chain, [ArgSpec(("B", "S"))],
                        backend="pallas")
        for b, s in [(3, 5), (6, 21), (2, 40)]:
            rng = np.random.RandomState(s)
            x = rng.randn(b, s).astype(np.float32)
            np.testing.assert_allclose(
                np.asarray(eng(x)),
                np.asarray(_reduce_chain(jnp.asarray(x))),
                rtol=1e-5, atol=1e-5)
