"""§4.3 Pallas codegen backend: clusters whose fusion-plan template is
registered by the backend execute through the fused kernels (interpret
mode) and must match the XLA path exactly.

Fused execution is *proved*, not assumed: the backend's
:class:`~repro.core.codegen.ClusterKernel` objects count traces
(``runs``) and silent per-op fallbacks (``fallbacks``), so a parity test
that accidentally exercises the XLA fallback fails loudly instead of
passing vacuously.

``TestDocsCoverageTable`` keeps ``docs/backends.md`` honest: every row of
its coverage table is recomputed from a real fusion plan.
"""
import pathlib
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ArgSpec, bridge, compile as disc_compile, get_backend
from repro.core.fusion import plan_fusion


def _ew_chain(x, y):
    return jnp.tanh(x) * y + jnp.exp(x * 0.5) - y


def _ew_multi(x, y):
    h = jnp.tanh(x) * y + 1.0
    return h * 2.0, jnp.exp(h) - y


def _reduce_chain(x):
    return (jnp.exp(x) * 0.5 + 1.0).sum(axis=-1)


def _reduce_axis0(x):
    return (jnp.exp(x) * 0.5 + 1.0).sum(axis=0)


def _reduce_mid(x):
    return (jnp.tanh(x) * 2.0).sum(axis=1)


def _dot_bias_gelu(x, w, b):
    return jax.nn.gelu(x @ w + b)


def _dot_residual_multi(x, w, r):
    h = x @ w
    a = jnp.tanh(h + r)
    return a, a * h


def _pallas_kernels():
    return get_backend("pallas").cluster_kernels


def _counters():
    return {t: (k.runs, k.fallbacks) for t, k in _pallas_kernels().items()}


def _assert_ran_fused(before, template):
    """The given template traced at least once since ``before``, with no
    new fallbacks anywhere."""
    after = _counters()
    assert after[template][0] > before[template][0], \
        f"{template} never executed through the fused kernel"
    for t in after:
        assert after[t][1] == before[t][1], \
            f"{t} silently fell back to per-op XLA"


class TestEligibility:
    def test_elementwise_chain_is_loop_template(self):
        g, _ = bridge(_ew_chain, [ArgSpec(("B", "D")), ArgSpec(("B", "D"))])
        assert "kLoop" in plan_fusion(g).template_counts()

    def test_multi_output_chain_is_loop_template(self):
        g, _ = bridge(_ew_multi, [ArgSpec(("B", "D")), ArgSpec(("B", "D"))])
        plan = plan_fusion(g)
        (cl,) = [c for c in plan.clusters if c.template == "kLoop"]
        assert len(cl.ops) >= 4  # the multi-consumer cluster did not split

    def test_reduce_chain_is_input_template(self):
        g, _ = bridge(_reduce_chain, [ArgSpec(("B", "S"))])
        assert "kInput" in plan_fusion(g).template_counts()

    @pytest.mark.parametrize("fn,spec", [
        (_reduce_axis0, ("B", "S")),
        (_reduce_mid, ("B", "S", 4)),
    ])
    def test_non_last_axis_reduce_is_input_template(self, fn, spec):
        g, _ = bridge(fn, [ArgSpec(spec)])
        assert "kInput" in plan_fusion(g).template_counts()

    def test_dot_epilogue_is_dot_template(self):
        g, _ = bridge(_dot_bias_gelu,
                      [ArgSpec(("B", 16)), ArgSpec((16, 8)), ArgSpec((8,))])
        assert "kDot" in plan_fusion(g).template_counts()

    def test_batched_dot_cluster_not_templated(self):
        def f(x, w):
            return jnp.tanh(jnp.einsum("bmk,bkn->bmn", x, w))

        g, _ = bridge(f, [ArgSpec(("B", 4, 8)), ArgSpec(("B", 8, 4))])
        plan = plan_fusion(g)
        for c in plan.clusters:
            if any(op.opcode == "dot_general" for op in c.ops):
                assert c.template is None  # falls back to per-op execution

    def test_backend_registers_all_three_templates(self):
        assert set(_pallas_kernels()) == {"kLoop", "kInput", "kDot"}


class TestPallasBackendCorrectness:
    @pytest.mark.parametrize("shape", [(4, 16), (7, 33), (16, 64)])
    def test_elementwise_matches_xla(self, shape):
        eng = disc_compile(_ew_chain,
                           [ArgSpec(("B", "D")), ArgSpec(("B", "D"))],
                           backend="pallas")
        assert eng.report()["pallas_eligible_clusters"] >= 1
        before = _counters()
        rng = np.random.RandomState(0)
        x = rng.randn(*shape).astype(np.float32)
        y = rng.randn(*shape).astype(np.float32)
        np.testing.assert_allclose(np.asarray(eng(x, y)),
                                   np.asarray(_ew_chain(jnp.asarray(x),
                                                        jnp.asarray(y))),
                                   rtol=1e-5, atol=1e-6)
        _assert_ran_fused(before, "kLoop")

    @pytest.mark.parametrize("shape", [(4, 16), (6, 40)])
    def test_multi_output_loop_matches_xla(self, shape):
        # two live-outs from one cluster: a single flattened kernel writes
        # both refs instead of splitting the cluster
        eng = disc_compile(_ew_multi,
                           [ArgSpec(("B", "D")), ArgSpec(("B", "D"))],
                           backend="pallas")
        before = _counters()
        rng = np.random.RandomState(1)
        x = rng.randn(*shape).astype(np.float32)
        y = rng.randn(*shape).astype(np.float32)
        got = eng(x, y)
        want = _ew_multi(jnp.asarray(x), jnp.asarray(y))
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=1e-5, atol=1e-6)
        _assert_ran_fused(before, "kLoop")

    @pytest.mark.parametrize("shape", [(8, 32), (3, 17)])
    def test_reduce_matches_xla(self, shape):
        eng = disc_compile(_reduce_chain, [ArgSpec(("B", "S"))],
                           backend="pallas")
        before = _counters()
        rng = np.random.RandomState(1)
        x = rng.randn(*shape).astype(np.float32)
        np.testing.assert_allclose(np.asarray(eng(x)),
                                   np.asarray(_reduce_chain(jnp.asarray(x))),
                                   rtol=1e-5, atol=1e-5)
        _assert_ran_fused(before, "kInput")

    @pytest.mark.parametrize("shape", [(5, 9), (12, 40)])
    def test_axis0_reduce_matches_xla(self, shape):
        # exp taints the padded region of BOTH axes; reducing axis 0 must
        # mask with the actual row count after the transpose normalization
        eng = disc_compile(_reduce_axis0, [ArgSpec(("B", "S"))],
                           backend="pallas")
        before = _counters()
        rng = np.random.RandomState(2)
        x = rng.randn(*shape).astype(np.float32)
        np.testing.assert_allclose(np.asarray(eng(x)),
                                   np.asarray(_reduce_axis0(jnp.asarray(x))),
                                   rtol=1e-5, atol=1e-5)
        _assert_ran_fused(before, "kInput")

    @pytest.mark.parametrize("shape", [(3, 11, 4), (6, 23, 4)])
    def test_middle_axis_reduce_matches_xla(self, shape):
        eng = disc_compile(_reduce_mid, [ArgSpec(("B", "S", 4))],
                           backend="pallas")
        before = _counters()
        rng = np.random.RandomState(3)
        x = rng.randn(*shape).astype(np.float32)
        np.testing.assert_allclose(np.asarray(eng(x)),
                                   np.asarray(_reduce_mid(jnp.asarray(x))),
                                   rtol=1e-5, atol=1e-5)
        _assert_ran_fused(before, "kInput")

    @pytest.mark.parametrize("b", [5, 21])
    def test_dot_bias_gelu_matches_xla(self, b):
        # bias broadcast is hoisted to the prologue; gelu's elementwise
        # expansion runs on the accumulator tiles at the final K step
        eng = disc_compile(_dot_bias_gelu,
                           [ArgSpec(("B", 16)), ArgSpec((16, 8)),
                            ArgSpec((8,))],
                           backend="pallas")
        before = _counters()
        rng = np.random.RandomState(4)
        x = rng.randn(b, 16).astype(np.float32)
        w = rng.randn(16, 8).astype(np.float32)
        bias = rng.randn(8).astype(np.float32)
        np.testing.assert_allclose(
            np.asarray(eng(x, w, bias)),
            np.asarray(_dot_bias_gelu(jnp.asarray(x), jnp.asarray(w),
                                      jnp.asarray(bias))),
            rtol=1e-4, atol=1e-5)
        _assert_ran_fused(before, "kDot")

    @pytest.mark.parametrize("b", [6, 13])
    def test_dot_residual_multi_output_matches_xla(self, b):
        # residual extra streamed as (M, N) tiles + TWO kernel outputs
        eng = disc_compile(_dot_residual_multi,
                           [ArgSpec(("B", 16)), ArgSpec((16, 8)),
                            ArgSpec(("B", 8))],
                           backend="pallas")
        before = _counters()
        rng = np.random.RandomState(5)
        x = rng.randn(b, 16).astype(np.float32)
        w = rng.randn(16, 8).astype(np.float32)
        r = rng.randn(b, 8).astype(np.float32)
        got = eng(x, w, r)
        want = _dot_residual_multi(jnp.asarray(x), jnp.asarray(w),
                                   jnp.asarray(r))
        for g, w_ in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w_),
                                       rtol=1e-4, atol=1e-5)
        _assert_ran_fused(before, "kDot")

    def test_dynamic_k_is_masked(self):
        # dynamic contraction dim: padded-K garbage from an upstream
        # cluster must not leak into the accumulator
        def f(x, w):
            return jnp.tanh(jnp.exp(x) @ w) * 2.0

        eng = disc_compile(f, [ArgSpec(("B", "K")), ArgSpec(("K", 8))],
                           backend="pallas")
        for b, k in [(3, 5), (6, 21)]:
            rng = np.random.RandomState(k)
            x = rng.randn(b, k).astype(np.float32)
            w = rng.randn(k, 8).astype(np.float32)
            np.testing.assert_allclose(
                np.asarray(eng(x, w)),
                np.asarray(f(jnp.asarray(x), jnp.asarray(w))),
                rtol=1e-4, atol=1e-5)

    def test_mixed_graph_with_matmul(self):
        def f(x, w):
            h = jnp.tanh(x) * 2.0 + jnp.abs(x)      # kLoop cluster
            z = h @ w                                # kDot root
            return jax.nn.sigmoid(z) * z             # ... with epilogue

        eng = disc_compile(f, [ArgSpec(("B", 16)), ArgSpec((16, 8))],
                           backend="pallas")
        rng = np.random.RandomState(2)
        x = rng.randn(5, 16).astype(np.float32)
        w = rng.randn(16, 8).astype(np.float32)
        np.testing.assert_allclose(
            np.asarray(eng(x, w)),
            np.asarray(f(jnp.asarray(x), jnp.asarray(w))),
            rtol=1e-4, atol=1e-5)

    def test_interleaved_cluster_order(self):
        # the elementwise cluster here consumes the reduce cluster's
        # output although its first op (tanh) traces earlier — clusters
        # must execute in cluster-DAG topological order, not first-op
        # order (regression: KeyError "undefined value" at lowering)
        def f(x):
            return jnp.tanh(x) * (x * x).sum(axis=-1)[:, None] + jnp.tanh(x)

        eng = disc_compile(f, [ArgSpec(("B", 8))], backend="pallas")
        rng = np.random.RandomState(7)
        x = rng.randn(5, 8).astype(np.float32)
        np.testing.assert_allclose(np.asarray(eng(x)),
                                   np.asarray(f(jnp.asarray(x))),
                                   rtol=1e-5, atol=1e-5)

    def test_dynamic_shapes_masked(self):
        # tainted padded region (exp) feeding a reduce: the Pallas kInput
        # kernel must mask with the actual column count
        eng = disc_compile(_reduce_chain, [ArgSpec(("B", "S"))],
                           backend="pallas")
        for b, s in [(3, 5), (6, 21), (2, 40)]:
            rng = np.random.RandomState(s)
            x = rng.randn(b, s).astype(np.float32)
            np.testing.assert_allclose(
                np.asarray(eng(x)),
                np.asarray(_reduce_chain(jnp.asarray(x))),
                rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------- docs --

_DOCS = pathlib.Path(__file__).resolve().parent.parent / "docs" / "backends.md"

# one example per coverage-table row: case key -> (fn, specs)
_COVERAGE_EXAMPLES = {
    "elementwise chain, one output":
        (_ew_chain, [ArgSpec(("B", "D")), ArgSpec(("B", "D"))]),
    "elementwise chain, multiple outputs":
        (_ew_multi, [ArgSpec(("B", "D")), ArgSpec(("B", "D"))]),
    "elementwise chain with broadcast bias":
        (lambda x, b: jnp.tanh(x + b) * 2.0,
         [ArgSpec(("B", 8)), ArgSpec((8,))]),
    "last-axis reduce with elementwise producers":
        (_reduce_chain, [ArgSpec(("B", "S"))]),
    "non-last single-axis reduce":
        (_reduce_axis0, [ArgSpec(("B", "S"))]),
    "multi-axis reduce":
        (lambda x: jnp.exp(x).sum(), [ArgSpec(("B", "S"))]),
    "2-D dot_general with elementwise epilogue":
        (_dot_bias_gelu, [ArgSpec(("B", 16)), ArgSpec((16, 8)),
                          ArgSpec((8,))]),
    "batched dot_general with epilogue":
        (lambda x, w: jnp.tanh(jnp.einsum("bmk,bkn->bmn", x, w)),
         [ArgSpec(("B", 4, 8)), ArgSpec(("B", 8, 4))]),
    "sort / gather clusters":
        (lambda x: jnp.sort(x, axis=-1) * 2.0, [ArgSpec(("B", 8))]),
    "single-op clusters":
        (lambda x: jnp.tanh(x), [ArgSpec(("B", 8))]),
}


def _parse_coverage_table(text):
    """Rows between the coverage markers: case -> (template, fused)."""
    m = re.search(r"<!-- coverage:begin -->(.*?)<!-- coverage:end -->",
                  text, re.S)
    assert m, "docs/backends.md lost its coverage markers"
    rows = {}
    for line in m.group(1).splitlines():
        cells = [c.strip() for c in line.strip().strip("|").split("|")]
        if len(cells) != 3 or cells[0] in ("case", "") or \
                set(cells[1]) <= {"-"}:
            continue
        rows[cells[0]] = (cells[1], cells[2])
    return rows


class TestDocsCoverageTable:
    def test_table_matches_fusion_plan(self):
        doc_rows = _parse_coverage_table(_DOCS.read_text())
        assert set(doc_rows) == set(_COVERAGE_EXAMPLES), (
            "docs/backends.md coverage table rows and the test registry "
            "diverged")
        registered = set(_pallas_kernels())
        for case, (fn, specs) in _COVERAGE_EXAMPLES.items():
            g, _ = bridge(fn, specs)
            counts = plan_fusion(g).template_counts()
            actual_template = next(iter(counts), "—")
            actual_fused = "yes" if (counts and
                                     set(counts) <= registered) else "no"
            doc_template, doc_fused = doc_rows[case]
            assert (doc_template, doc_fused) == \
                (actual_template, actual_fused), (
                f"docs/backends.md row {case!r} says "
                f"({doc_template}, {doc_fused}) but the fusion plan says "
                f"({actual_template}, {actual_fused}) — update the docs")
