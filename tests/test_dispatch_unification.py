"""Tests for the unified dispatch emitter (one lens-parameterized
generator for both pipelines), §4.4 escalation on the jit pipeline, and
promote-on-change spec refinement.

The contract under test: ``core/dispatcher.generate_dispatch`` is the
*only* host-flow generator — ``pipeline="dhlo"`` and ``pipeline="jit"``
differ solely in the :class:`~repro.core.dispatcher.DispatchLens` they
hand it, so bucket-key computation, pad plans, escalation, and tie guards
behave identically under either.
"""
import numpy as np
import jax.numpy as jnp
import pytest

import disc
from repro.api import ArgSpec
from repro.core.bucketing import BucketPolicy, pow2_bucket
from repro.core.cache import CompileCache
from repro.core.dispatcher import (ArgPlan, DispatchLens, DynAxis,
                                   generate_dispatch, jit_lens)


def _lines(src, needle):
    return [ln for ln in src.splitlines() if needle in ln]


class TestEmitterParity:
    def test_same_key_and_pad_logic_across_pipelines(self):
        """For an equivalent function/spec, both pipelines must emit the
        *identical* extraction, bucket-key, and pad-plan source."""
        specs = [ArgSpec(("S", 4), jnp.float32)]
        d = disc.compile(lambda x: jnp.tanh(x), specs)
        j = disc.compile(lambda x: jnp.tanh(x), specs=specs,
                         options=disc.CompileOptions(pipeline="jit"))
        j(np.zeros((3, 4), np.float32))  # jit lowers lazily on first call

        d_src, j_src = d.dispatch_source, j.dispatch_source
        # extraction site
        assert _lines(d_src, "s_0 = arrays[0].shape[0]") == \
            _lines(j_src, "s_0 = arrays[0].shape[0]")
        # bucket-key line (inlined pow2 math) is byte-identical
        assert _lines(d_src, "key = ") == _lines(j_src, "key = ")
        assert _lines(d_src, "key = ")[0].strip().startswith("key = ((16 if")
        # pad plan is byte-identical (zero-fill to the bucket)
        for needle in ("x0 = arrays[0]", "if tuple(x0.shape) != (key[0], 4):",
                       "_buf = _np.zeros((key[0], 4), _dt0)",
                       "_buf[:x0.shape[0], :]"):
            assert _lines(d_src, needle) == _lines(j_src, needle) != []
        # both lenses free their staging buffers right after the entry call
        assert _lines(d_src, "x0 = None  # plan: free staging") == \
            _lines(j_src, "x0 = None  # plan: free staging") != []
        # the two pipelines differ only in lens threading + output recovery
        assert "lens = " in d_src and "lens = " not in j_src
        assert "outs[0][" in d_src and "outs[0][" not in j_src

    def test_bucket_expr_matches_policy_everywhere(self):
        """The inlined integer bucket math must agree with
        ``BucketPolicy.bucket`` (the float-free form is what the emitter
        compiles into the host flow)."""
        for kind, granules in (("pow2", (1, 3, 16, 64)),
                               ("multiple", (1, 7, 32)),
                               ("exact", (1,))):
            for g in granules:
                pol = BucketPolicy(kind=kind, granule=g)
                fn = eval(f"lambda v: {pol.emit_bucket_expr('S', 'v')}")
                for v in list(range(1, 3000)) + [2**20, 2**20 + 1, 10**9]:
                    assert fn(v) == pol.bucket("S", v), (kind, g, v)

    def test_jit_lens_direct(self):
        """The lens builder exposes the pipeline differences explicitly:
        jit lenses carry no output plans and no lens vector."""
        lens = jit_lens([None, ArgSpec(("S", 4), jnp.float32)], ["S"],
                        name="t")
        assert lens.outputs is None and lens.pass_lens is False
        assert lens.args[0] == ArgPlan()            # pytree passthrough
        assert lens.args[1].shape == (DynAxis(0), 4)
        assert lens.sym_sites == (((1, 0),),)


class TestJitEscalation:
    def test_hot_exact_shape_escalates_unpadded(self):
        calls = []

        def f(x):
            calls.append(x.shape)  # traced shapes only
            return x * 2.0

        cf = disc.compile(
            f, specs=[ArgSpec(("S", 4))],
            options=disc.CompileOptions(pipeline="jit",
                                        escalation_threshold=3))
        x = np.arange(20, dtype=np.float32).reshape(5, 4)
        outs = [cf(x) for _ in range(5)]

        st = cf.cache_stats()
        assert st["escalations"] == 1
        assert cf.compile_counts()["exact"] == 1
        assert cf.compile_counts()["bucket"] == 1
        # pre-escalation calls are bucket-padded (pow2/16), the escalated
        # path is the unpadded §4.4 specialization
        assert (16, 4) in calls and (5, 4) in calls
        assert np.asarray(outs[-1]).shape == (5, 4)
        np.testing.assert_allclose(outs[-1], x * 2.0, rtol=1e-6)
        # valid region identical across both paths
        np.testing.assert_allclose(np.asarray(outs[0])[:5], outs[-1],
                                   rtol=1e-6)

    def test_escalated_entries_are_independent(self):
        """Each escalated signature gets its own entry object, so LRU
        eviction (or a promotion purge) actually frees its executable —
        a single shared jax.jit wrapper would retain every trace."""
        cf = disc.compile(
            lambda x: x * 2.0, specs=[ArgSpec(("S", 2))],
            options=disc.CompileOptions(pipeline="jit",
                                        escalation_threshold=2))
        a, b = np.ones((3, 2), np.float32), np.ones((5, 2), np.float32)
        for _ in range(3):
            cf(a)
            cf(b)
        exact = [v for k, v in cf.cache._entries.items() if k[0] == "exact"]
        assert len(exact) == 2 and exact[0] is not exact[1]
        assert cf.compile_counts()["exact"] == 2

    def test_escalation_disabled_by_default_in_jit(self):
        cf = disc.compile(lambda x: x + 1.0, specs=[ArgSpec(("S", 2))],
                          options=disc.CompileOptions(pipeline="jit"))
        x = np.zeros((3, 2), np.float32)
        for _ in range(10):
            cf(x)
        assert cf.cache_stats()["escalations"] == 0
        assert "should_escalate" not in cf.dispatch_source

    def test_dhlo_and_jit_escalate_identically(self):
        """Same function, same threshold: both pipelines cross §4.4 at the
        same call and agree numerically on the escalated result."""
        def f(x):
            return jnp.exp(x) + 1.0

        opts = dict(escalation_threshold=3)
        d = disc.compile(f, [ArgSpec(("S", 4))], **opts)
        j = disc.compile(f, specs=[ArgSpec(("S", 4))],
                         options=disc.CompileOptions(pipeline="jit", **opts))
        x = np.random.randn(5, 4).astype(np.float32)
        for _ in range(4):
            d_out, j_out = d(x), j(x)
        assert d.cache_stats()["escalations"] == 1
        assert j.cache_stats()["escalations"] == 1
        np.testing.assert_allclose(d_out, np.asarray(j_out)[:5], rtol=1e-6)


class TestPromoteOnChange:
    def test_tie_broken_relowers_instead_of_erroring(self):
        def f(x, y):
            return jnp.tanh(x).sum(axis=0), jnp.exp(y).sum(axis=0)

        cf = disc.compile(f)  # no specs: first call infers + ties
        x = np.random.randn(4, 3).astype(np.float32)
        y = np.random.randn(4, 5).astype(np.float32)
        cf(x, y)
        assert cf.lower().specs[0].shape == ("d4", "d3")
        assert cf.lower().specs[1].shape == ("d4", "d5")  # axis 0 tied
        old_keys = set(cf.cache._entries)
        assert old_keys  # the first call compiled under the tied profile

        y2 = np.random.randn(6, 5).astype(np.float32)  # breaks the tie
        a, b = cf(x, y2)
        np.testing.assert_allclose(a, np.tanh(x).sum(0), rtol=1e-5)
        np.testing.assert_allclose(b, np.exp(y2).sum(0), rtol=1e-4)
        assert cf.cache_stats()["promotions"] == 1
        # the superseded artifact's entries were purged from the carried
        # cache (unreachable: refined keys carry strictly more symbols)
        assert old_keys.isdisjoint(cf.cache._entries)
        # profile refined: the coincidental tie became independent dims
        s0, s1 = cf.lower().specs
        assert s0.shape == ("d4", "d3")
        assert s1.shape[0] not in ("d4",) and s1.shape[1] == "d5"

        # both equality structures keep working, with no further promotion
        cf(x, y)
        cf(x, y2)
        cf(np.random.randn(9, 3).astype(np.float32),
           np.random.randn(2, 5).astype(np.float32))
        assert cf.cache_stats()["promotions"] == 1

    def test_promotion_preserves_surviving_ties(self):
        """(4,4,4) infers one symbol over three args; a (4,6,6) call must
        split only the broken site-group — the 6==6 coincidence observed
        mid-promotion must NOT merge into the existing d6-style group."""
        def f(x, y, z):
            return x.sum(), y.sum(), z.sum()

        cf = disc.compile(f)
        mk = lambda n: np.random.randn(n, 2).astype(np.float32)
        cf(mk(4), mk(4), mk(4))
        assert [s.shape[0] for s in cf.lower().specs] == ["d4"] * 3

        cf(mk(4), mk(6), mk(6))
        names = [s.shape[0] for s in cf.lower().specs]
        assert names[0] == "d4"
        assert names[1] == names[2] != "d4"  # still tied to each other
        assert cf.cache_stats()["promotions"] == 1

        # ...and THAT tie can break later, promoting once more
        cf(mk(4), mk(6), mk(8))
        names = [s.shape[0] for s in cf.lower().specs]
        assert len(set(names)) == 3
        assert cf.cache_stats()["promotions"] == 2
        # all three dims now independent: any size mix works
        r = cf(mk(1), mk(2), mk(3))
        assert len(r) == 3

    def test_stale_handle_does_not_repromote(self):
        """A kept reference to a superseded artifact must not trigger a
        spurious second promotion (which would purge the live artifact's
        entries): its guard redirects to the live dispatch instead."""
        def f(x, y):
            return jnp.tanh(x).sum(axis=0), jnp.exp(y).sum(axis=0)

        cf = disc.compile(f)
        x = np.random.randn(4, 3).astype(np.float32)
        cf(x, np.random.randn(4, 5).astype(np.float32))
        stale = cf._compiled  # pre-promotion artifact handle
        y2 = np.random.randn(6, 5).astype(np.float32)
        cf(x, y2)  # promotes
        assert cf._compiled is not stale
        live_keys = set(cf.cache._entries)

        a, b = stale(x, y2)  # stale guard fires -> live dispatch serves it
        np.testing.assert_allclose(b, np.exp(y2).sum(0), rtol=1e-4)
        assert cf.cache_stats()["promotions"] == 1  # no double count
        assert live_keys <= set(cf.cache._entries)  # nothing purged

    def test_declared_tie_violation_raises_contract_error(self):
        """Ties declared via a shared symbol are a contract, not a
        coincidence: breaking one raises instead of promoting."""
        cf = disc.compile(lambda u, v: (u.sum(), v.sum()),
                          [("N", 2), ("N", 2)])
        ok = np.zeros((3, 2), np.float32)
        cf(ok, ok)
        with pytest.raises(ValueError, match="tied across arguments"):
            cf(ok, np.zeros((5, 2), np.float32))

    def test_promote_disabled_raises(self):
        cf = disc.compile(lambda x, y: (x.sum(), y.sum()),
                          options=disc.CompileOptions(
                              promote_on_change=False))
        cf(np.zeros((4, 2), np.float32), np.zeros((4, 2), np.float32))
        with pytest.raises(ValueError, match="tied across arguments"):
            cf(np.zeros((4, 2), np.float32), np.zeros((6, 2), np.float32))

    def test_promote_failure_explains_required_equality(self):
        """If the function semantically requires the tied sizes (x + y),
        promotion re-lowering fails with a pointed error, not a cryptic
        trace-time shape mismatch."""
        cf = disc.compile(lambda x, y: x + y)
        ok = np.arange(4, dtype=np.float32)
        np.testing.assert_allclose(cf(ok, ok), ok + ok)
        with pytest.raises(ValueError, match="promote-on-change"):
            cf(ok, np.zeros((6,), np.float32))
        # failed promotion rolls back: the original tied profile (and its
        # compiled artifact) keep serving valid calls, and the failed
        # attempt is not counted as a promotion
        np.testing.assert_allclose(cf(ok, ok), ok + ok)
        assert cf.cache_stats()["promotions"] == 0


class TestGenerateDispatchDirect:
    """The emitter as pure mechanism: drive it with a hand-built lens."""

    def test_custom_lens_round_trip(self):
        lens = DispatchLens(
            name="hand", sym_names=("S",), sym_sites=(((0, 0),),),
            args=(ArgPlan((DynAxis(0), 2), np.float32),),
            outputs=None, pass_lens=False)
        cache = CompileCache("hand")
        compiled_keys = []

        def compile_bucket(key):
            compiled_keys.append(key)
            return lambda x: x.sum()

        dispatch, src = generate_dispatch(
            lens, BucketPolicy(kind="multiple", granule=4), cache,
            compile_bucket)
        out = dispatch([np.ones((3, 2), np.float32)])
        assert out == pytest.approx(6.0)  # zero-padded to (4, 2), sum==6
        assert compiled_keys == [(4,)]
        dispatch([np.ones((4, 2), np.float32)])
        assert cache.stats.hits == 1
        assert "(-(-s_0 // 4) * 4)" in src  # inlined 'multiple' rule

    def test_tie_break_handler_is_pipeline_agnostic(self):
        """Tie guards + on_tie_break work for a jit lens too — the
        mechanism is shared, not a dhlo special case."""
        specs = [ArgSpec(("S", 1), np.float32), ArgSpec(("S", 1), np.float32)]
        lens = jit_lens(specs, ["S"])
        seen = []
        dispatch, src = generate_dispatch(
            lens, BucketPolicy(kind="exact"), CompileCache("t"),
            lambda key: (lambda *a: sum(x.sum() for x in a)),
            on_tie_break=lambda arrays: seen.append(
                tuple(a.shape for a in arrays)) or "promoted")
        a = np.ones((2, 1), np.float32)
        assert dispatch([a, a]) == pytest.approx(4.0)
        assert dispatch([a, np.ones((3, 1), np.float32)]) == "promoted"
        assert seen == [((2, 1), (3, 1))]
        assert "_tie_break(arrays)" in src

    def test_cap_enforced_inline(self):
        lens = jit_lens([ArgSpec(("S", 1), np.float32)], ["S"])
        pol = BucketPolicy(kind="pow2", granule=4, caps=(("S", 8),))
        dispatch, src = generate_dispatch(
            lens, pol, CompileCache("cap"), lambda key: (lambda x: x))
        assert dispatch([np.ones((5, 1), np.float32)]).shape == (8, 1)
        with pytest.raises(ValueError, match="max"):
            dispatch([np.ones((9, 1), np.float32)])
        assert "min(" in src  # cap compiled into the key expression


class TestServeEscalation:
    def test_prefill_escalates_on_hot_prompt_length(self):
        import jax
        from repro.configs import get_config
        from repro.data.pipeline import Request
        from repro.models.registry import get_model
        from repro.serve.engine import ServeConfig, ServeEngine

        cfg = get_config("tinyllama_11b").reduced()
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        eng = ServeEngine(model, params,
                          ServeConfig(max_batch=2, max_seq=64,
                                      escalation_threshold=2))
        # same prompt length 5, repeatedly: crosses the §4.4 threshold
        for rid in range(3):
            eng.submit([Request(rid=rid, tokens=[2, 3, 4, 5, 6],
                                max_new_tokens=1)])
            eng.run_until_done()
        assert eng.stats["prefill_escalations"] >= 1
        assert len(eng.done) == 3
