"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles.

Sweeps shapes and dtypes per kernel; every kernel must match its ref.py
oracle within per-dtype tolerances.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fused_elementwise.ops import fused_elementwise
from repro.kernels.fused_elementwise.ref import fused_elementwise_ref
from repro.kernels.fused_reduce.ops import fused_reduce
from repro.kernels.fused_reduce.ref import fused_reduce_ref
from repro.kernels.softmax.ops import masked_softmax
from repro.kernels.softmax.ref import masked_softmax_ref
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.layernorm.ops import layernorm
from repro.kernels.layernorm.ref import layernorm_ref
from repro.kernels.flash_attention.ops import flash_attention, flash_decode
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.matmul.ops import matmul, select_gemm_version
from repro.kernels.matmul.ref import matmul_ref
from repro.kernels.rwkv6.ops import rwkv6_scan
from repro.kernels.rwkv6.ref import rwkv6_ref
from repro.kernels.mamba2.ops import mamba2_scan
from repro.kernels.mamba2.ref import mamba2_ref

TOL = {jnp.float32: dict(rtol=1e-5, atol=1e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.randn(*shape), dtype=dtype)


class TestFusedElementwise:
    @pytest.mark.parametrize("shape", [(1024,), (4096,), (8, 256), (3, 7, 64)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_expr_cluster(self, shape, dtype):
        rng = np.random.RandomState(0)
        x = _rand(rng, shape, dtype)
        y = _rand(rng, shape, dtype)

        def expr(a, b):
            return jnp.tanh(a) * b + a

        total = int(np.prod(shape))
        n_valid = total - 7 if total > 7 else total
        got = fused_elementwise(expr, [x, y], n_valid, [dtype])[0]
        want = fused_elementwise_ref(expr, [x.ravel(), y.ravel()], n_valid,
                                     [dtype])[0].reshape(shape)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), **TOL[dtype])

    def test_multi_output(self):
        rng = np.random.RandomState(1)
        x = _rand(rng, (2048,), jnp.float32)

        def expr(a):
            return jnp.exp(a), a * 2.0

        got = fused_elementwise(expr, [x], 2000, [jnp.float32, jnp.float32])
        want = fused_elementwise_ref(expr, [x], 2000,
                                     [jnp.float32, jnp.float32])
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-6)


class TestFusedReduce:
    @pytest.mark.parametrize("kind", ["sum", "max", "min", "prod"])
    @pytest.mark.parametrize("shape", [(16, 128), (64, 33), (8, 1024)])
    def test_reduce_kinds(self, kind, shape):
        rng = np.random.RandomState(2)
        x = _rand(rng, shape, jnp.float32)
        n_valid = shape[1] - 3 if shape[1] > 3 else shape[1]

        def expr(a):
            return a * 0.5 + 1.0

        got = fused_reduce(expr, [x], n_valid, kind)
        want = fused_reduce_ref(expr, [x], n_valid, kind)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_dynamic_cols_sweep(self):
        rng = np.random.RandomState(3)
        x = _rand(rng, (8, 64), jnp.float32)
        for n in (1, 13, 37, 64):
            got = fused_reduce(lambda a: jnp.exp(a), [x], n, "sum")
            want = fused_reduce_ref(lambda a: jnp.exp(a), [x], n, "sum")
            np.testing.assert_allclose(got, want, rtol=1e-5)


class TestMaskedSoftmax:
    @pytest.mark.parametrize("shape", [(8, 64), (2, 4, 128), (16, 100)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, shape, dtype):
        rng = np.random.RandomState(4)
        x = _rand(rng, shape, dtype)
        n = shape[-1] // 2 + 1
        got = masked_softmax(x, n)
        want = masked_softmax_ref(x.reshape(-1, shape[-1]).astype(jnp.float32),
                                  n).reshape(shape)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), **TOL[dtype])

    def test_padded_cols_zero(self):
        x = jnp.ones((8, 32))
        out = masked_softmax(x, 10)
        assert np.all(np.asarray(out)[:, 10:] == 0.0)
        np.testing.assert_allclose(np.asarray(out).sum(-1), 1.0, rtol=1e-6)


class TestNorms:
    @pytest.mark.parametrize("shape", [(8, 64), (4, 16, 128), (256, 512)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_rmsnorm(self, shape, dtype):
        rng = np.random.RandomState(5)
        x = _rand(rng, shape, dtype)
        w = _rand(rng, shape[-1:], dtype)
        np.testing.assert_allclose(
            np.asarray(rmsnorm(x, w), np.float32),
            np.asarray(rmsnorm_ref(x, w), np.float32), **TOL[dtype])

    @pytest.mark.parametrize("shape", [(8, 64), (3, 5, 32)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_layernorm(self, shape, dtype):
        rng = np.random.RandomState(6)
        x = _rand(rng, shape, dtype)
        g = _rand(rng, shape[-1:], dtype)
        b = _rand(rng, shape[-1:], dtype)
        np.testing.assert_allclose(
            np.asarray(layernorm(x, g, b), np.float32),
            np.asarray(layernorm_ref(x, g, b), np.float32), **TOL[dtype])


class TestFlashAttention:
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("hkv", [4, 1])  # MHA-group / MQA
    def test_varlen_matches_ref(self, causal, hkv):
        rng = np.random.RandomState(7)
        b, h, s, d = 2, 4, 32, 16
        q = _rand(rng, (b, h, s, d), jnp.float32)
        k = _rand(rng, (b, hkv, s, d), jnp.float32)
        v = _rand(rng, (b, hkv, s, d), jnp.float32)
        lens = jnp.array([s, s // 2 + 1], jnp.int32)
        got = flash_attention(q, k, v, lens, causal=causal,
                              block_q=8, block_k=8)
        want = attention_ref(q, k, v, lens, causal=causal)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)

    def test_length_sweep(self):
        rng = np.random.RandomState(8)
        b, h, s, d = 1, 2, 64, 8
        q = _rand(rng, (b, h, s, d), jnp.float32)
        k = _rand(rng, (b, h, s, d), jnp.float32)
        v = _rand(rng, (b, h, s, d), jnp.float32)
        for n in (1, 9, 33, 64):
            lens = jnp.array([n], jnp.int32)
            got = flash_attention(q, k, v, lens, causal=True,
                                  block_q=8, block_k=8)
            want = attention_ref(q, k, v, lens, causal=True)
            np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)

    def test_bf16(self):
        rng = np.random.RandomState(9)
        b, h, s, d = 1, 2, 16, 8
        q = _rand(rng, (b, h, s, d), jnp.bfloat16)
        k = _rand(rng, (b, h, s, d), jnp.bfloat16)
        v = _rand(rng, (b, h, s, d), jnp.bfloat16)
        lens = jnp.array([11], jnp.int32)
        got = flash_attention(q, k, v, lens, causal=True, block_q=8, block_k=8)
        want = attention_ref(q, k, v, lens, causal=True)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=3e-2, atol=3e-2)

    def test_decode(self):
        rng = np.random.RandomState(10)
        b, h, smax, d = 2, 4, 64, 16
        q = _rand(rng, (b, h, 1, d), jnp.float32)
        kc = _rand(rng, (b, h, smax, d), jnp.float32)
        vc = _rand(rng, (b, h, smax, d), jnp.float32)
        lens = jnp.array([37, 5], jnp.int32)
        got = flash_decode(q, kc, vc, lens)
        want = attention_ref(q, kc, vc, lens, causal=False)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


class TestMatmulLibrary:
    @pytest.mark.parametrize("mkn", [(128, 128, 128), (256, 128, 384),
                                     (8, 128, 128), (128, 512, 128)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_library_kernels(self, mkn, dtype):
        m, k, n = mkn
        rng = np.random.RandomState(11)
        a = _rand(rng, (m, k), dtype)
        b = _rand(rng, (k, n), dtype)
        got = matmul(a, b)
        want = matmul_ref(a, b)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
                                   atol=3e-1 if dtype == jnp.bfloat16 else 1e-3)

    def test_selection_interface(self):
        assert select_gemm_version(2048, 1024, 2048) == "square_big"
        assert select_gemm_version(8, 128, 128) == "skinny_m"
        assert select_gemm_version(128, 1024, 128) == "deep_k"
        assert select_gemm_version(128, 128, 128) == "balanced"
        assert select_gemm_version(100, 100, 100) is None  # vendor fallback


class TestRWKV6:
    @pytest.mark.parametrize("t", [16, 48, 100])
    def test_matches_sequential_ref(self, t):
        rng = np.random.RandomState(12)
        b, h, dk, dv = 2, 2, 8, 8
        r = _rand(rng, (b, h, t, dk), jnp.float32) * 0.5
        k = _rand(rng, (b, h, t, dk), jnp.float32) * 0.5
        v = _rand(rng, (b, h, t, dv), jnp.float32) * 0.5
        w = jax.nn.sigmoid(_rand(rng, (b, h, t, dk), jnp.float32))
        u = _rand(rng, (h, dk), jnp.float32) * 0.1
        got = rwkv6_scan(r, k, v, w, u)
        want = rwkv6_ref(r, k, v, w, u)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


class TestMamba2:
    @pytest.mark.parametrize("t", [16, 64, 70])
    def test_matches_sequential_ref(self, t):
        rng = np.random.RandomState(13)
        b, h, n, p = 2, 2, 8, 8
        x = _rand(rng, (b, h, t, p), jnp.float32) * 0.5
        a = jax.nn.sigmoid(_rand(rng, (b, h, t, 1), jnp.float32))
        bb = _rand(rng, (b, h, t, n), jnp.float32) * 0.5
        c = _rand(rng, (b, h, t, n), jnp.float32) * 0.5
        got = mamba2_scan(x, a, bb, c)
        want = mamba2_ref(x, a, bb, c)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
