"""H1 correctness: chunk-parallel WKV must match the sequential oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import _wkv_chunked, _wkv_scan


@pytest.mark.parametrize("t", [16, 64, 128])
@pytest.mark.parametrize("decay_scale", [0.1, 3.0])  # mild and harsh decays
@pytest.mark.parametrize("fast_dtype,rtol,atol", [
    (jnp.float32, 2e-4, 2e-5),   # exact-math equivalence
    (jnp.bfloat16, 3e-2, 3e-2),  # production traffic-halving path (H1 iter2)
])
def test_chunked_matches_scan(t, decay_scale, fast_dtype, rtol, atol):
    rng = np.random.RandomState(0)
    b, h, dk, dv = 2, 3, 8, 8
    r = jnp.asarray(rng.randn(b, h, t, dk), jnp.float32) * 0.5
    k = jnp.asarray(rng.randn(b, h, t, dk), jnp.float32) * 0.5
    v = jnp.asarray(rng.randn(b, h, t, dv), jnp.float32) * 0.5
    # data-dependent decay in (0,1), including near-zero (harsh) decays
    w = jnp.exp(-jnp.exp(
        jnp.asarray(rng.randn(b, h, t, dk), jnp.float32) * decay_scale))
    u = jnp.asarray(rng.randn(h, dk), jnp.float32) * 0.1
    seq, _ = _wkv_scan(r, k, v, w, u)
    par = _wkv_chunked(r, k, v, w, u, chunk=16, fast_dtype=fast_dtype)
    np.testing.assert_allclose(np.asarray(par), np.asarray(seq),
                               rtol=rtol, atol=atol)


def test_grads_flow():
    rng = np.random.RandomState(1)
    b, h, t, d = 1, 2, 32, 4
    args = [jnp.asarray(rng.randn(b, h, t, d), jnp.float32) * 0.3
            for _ in range(3)]
    w = jax.nn.sigmoid(jnp.asarray(rng.randn(b, h, t, d), jnp.float32))
    u = jnp.asarray(rng.randn(h, d), jnp.float32) * 0.1

    def loss(r, k, v, w, u):
        return _wkv_chunked(r, k, v, w, u, chunk=16).sum()

    grads = jax.grad(loss, argnums=(0, 1, 2, 3, 4))(*args, w, u)
    for g in grads:
        assert np.isfinite(np.asarray(g)).all()
