"""Per-architecture smoke tests: reduced same-family configs, one forward
and one train step on CPU; output shapes + finiteness asserted.  Also
asserts params/specs tree congruence (the sharding contract).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.registry import get_model
from repro.train.step import TrainConfig, make_train_step, train_state_init

B, S = 2, 32


def _batch(cfg, rng):
    tokens = rng.randint(0, cfg.vocab, size=(B, S)).astype(np.int32)
    labels = rng.randint(0, cfg.vocab, size=(B, S)).astype(np.int32)
    batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels),
             "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.randn(B, cfg.encoder_len, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.randn(B, cfg.max_image_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch_id):
        cfg = get_config(arch_id).reduced()
        model = get_model(cfg)
        rng = np.random.RandomState(42)
        params = model.init(jax.random.PRNGKey(0))
        batch = _batch(cfg, rng)
        logits = model.forward(params, batch)
        s_out = S + (cfg.max_image_tokens if cfg.family == "vlm" else 0)
        assert logits.shape == (B, s_out, cfg.vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()

    def test_train_step_no_nans(self, arch_id):
        cfg = get_config(arch_id).reduced()
        model = get_model(cfg)
        rng = np.random.RandomState(7)
        tcfg = TrainConfig(peak_lr=1e-3, warmup=1, total_steps=10)
        state = train_state_init(model, jax.random.PRNGKey(1), tcfg)
        step = jax.jit(make_train_step(model, tcfg))
        state, metrics = step(state, _batch(cfg, rng))
        loss = float(metrics["loss"])
        assert np.isfinite(loss), f"{arch_id}: loss={loss}"
        # params actually changed
        leaf0 = jax.tree.leaves(state.params)[0]
        assert np.isfinite(np.asarray(leaf0, np.float32)).all()

    def test_specs_tree_congruent(self, arch_id):
        cfg = get_config(arch_id).reduced()
        model = get_model(cfg)
        params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        specs = model.specs()
        pt = jax.tree.structure(params)
        st = jax.tree.structure(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        assert pt == st, f"{arch_id}: params/specs trees diverge"
        # every spec's rank must not exceed the param's rank
        for p, s in zip(
                jax.tree.leaves(params),
                jax.tree.leaves(specs, is_leaf=lambda x: isinstance(
                    x, jax.sharding.PartitionSpec))):
            assert len(s) <= len(p.shape), f"{arch_id}: spec {s} vs {p.shape}"


@pytest.mark.parametrize("arch_id", ["tinyllama_11b", "rwkv6_3b",
                                     "zamba2_7b", "whisper_tiny",
                                     "deepseek_v2_236b"])
class TestDecodeSmoke:
    def test_decode_step(self, arch_id):
        cfg = get_config(arch_id).reduced()
        model = get_model(cfg)
        rng = np.random.RandomState(3)
        params = model.init(jax.random.PRNGKey(0))
        max_len = 64
        cache = model.init_cache(B, max_len)
        tokens = jnp.asarray(rng.randint(0, cfg.vocab, (B, 1)), jnp.int32)
        lens = jnp.array([3, 10], jnp.int32)
        kw = {}
        if cfg.family == "encdec":
            from repro.models import whisper
            frames = jnp.asarray(rng.randn(B, cfg.encoder_len, cfg.d_model),
                                 jnp.float32)
            kw["enc_out"] = whisper.encode(cfg, params, frames)
        logits, new_cache = model.decode_step(params, cache, tokens, lens,
                                              **kw)
        assert logits.shape == (B, 1, cfg.vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        # cache structure preserved
        assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


class TestDecodeMatchesPrefill:
    def test_tinyllama_decode_consistency(self):
        """Prefill logits at position t == decode-step logits after caching
        t tokens — the KV-cache correctness invariant."""
        cfg = get_config("tinyllama_11b").reduced()
        model = get_model(cfg)
        rng = np.random.RandomState(5)
        params = model.init(jax.random.PRNGKey(0))
        toks = jnp.asarray(rng.randint(0, cfg.vocab, (1, 8)), jnp.int32)
        full = model.forward(params, {"tokens": toks})
        cache = model.init_cache(1, 16)
        lens = jnp.zeros((1,), jnp.int32)
        outs = []
        for t in range(8):
            logits, cache = model.decode_step(params, cache, toks[:, t:t + 1],
                                              lens)
            lens = lens + 1
            outs.append(logits[:, 0])
        dec = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(dec, np.float32),
                                   np.asarray(full, np.float32),
                                   rtol=2e-3, atol=2e-3)
