"""Tests for the public ``disc`` / ``repro.api`` surface.

Covers the staged pipeline (lower → compile), spec inference from the
first call, ``CompileOptions`` consolidation, the backend registry, the
``Dim`` bucketing contracts, cache sharing between artifacts, and the
``DiscEngine`` deprecation shim's parity with ``disc.compile``.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import disc
from repro.api import backends as backends_mod


def _f(x, w):
    return jax.nn.softmax(jnp.tanh(x) @ w, axis=-1)


W = np.random.RandomState(3).randn(16, 8).astype(np.float32)


class TestStagedPipeline:
    def test_lower_then_compile_round_trip(self):
        cf = disc.compile(_f, [("B", 16), (16, 8)])
        lowered = cf.lower()
        # stage 1 artifacts are inspectable before any device compile
        assert lowered.graph is not None
        assert lowered.plan is not None
        assert lowered.sym_names == ("B",)
        assert "dynamic symbols" in lowered.as_text()
        compiled = lowered.compile()
        assert compiled.compile_counts()["total"] == 0  # nothing ran yet
        x = np.random.randn(5, 16).astype(np.float32)
        np.testing.assert_allclose(compiled(x, W),
                                   _f(jnp.asarray(x), jnp.asarray(W)),
                                   rtol=1e-4, atol=1e-6)
        assert compiled.compile_counts() == {"bucket": 1, "exact": 0,
                                             "total": 1}
        assert "def _dispatch" in compiled.dispatch_source

    def test_callable_immediately_with_specs(self):
        cf = disc.compile(_f, [("B", 16), (16, 8)])
        x = np.random.randn(3, 16).astype(np.float32)
        np.testing.assert_allclose(cf(x, W),
                                   _f(jnp.asarray(x), jnp.asarray(W)),
                                   rtol=1e-4, atol=1e-6)

    def test_decorator_form(self):
        @disc.compile
        def g(x):
            return jnp.exp(x).sum(axis=1)

        x = np.random.randn(4, 9).astype(np.float32)
        np.testing.assert_allclose(g(x), np.exp(x).sum(1), rtol=1e-5)

    def test_decorator_with_arguments(self):
        @disc.compile(specs=[("B", 8)], backend="xla")
        def g(x):
            return jnp.tanh(x) * 2.0

        x = np.random.randn(6, 8).astype(np.float32)
        np.testing.assert_allclose(g(x), np.tanh(x) * 2.0, rtol=1e-5)

    def test_lower_requires_specs_or_call(self):
        cf = disc.compile(_f)
        with pytest.raises(ValueError, match="no specs"):
            cf.lower()


class TestSpecInference:
    def test_inferred_from_first_call(self):
        cf = disc.compile(_f)
        sizes = [(5,), (9,), (17,), (30,)]
        for (b,) in sizes:
            x = np.random.randn(b, 16).astype(np.float32)
            np.testing.assert_allclose(cf(x, W),
                                       _f(jnp.asarray(x), jnp.asarray(W)),
                                       rtol=1e-4, atol=1e-6)
        # all >1 axes become symbols; equal sizes share a symbol
        specs = cf.lower().specs
        assert specs[0].shape == ("d5", "d16")
        assert specs[1].shape == ("d16", "d8")
        # O(#buckets): 4 distinct batch sizes but ≤ 3 bucket compiles
        assert cf.compile_counts()["bucket"] <= 3

    def test_inference_keeps_size1_static(self):
        spec, = disc.infer_specs([np.zeros((1, 7), np.float32)])
        assert spec.shape == (1, "d7")

    def test_inference_ties_equal_sizes(self):
        a, b = disc.infer_specs([np.zeros((4, 4), np.float32),
                                 np.zeros((4,), np.int32)])
        assert a.shape == ("d4", "d4") and b.shape == ("d4",)
        assert a.dtype == np.float32 and b.dtype == np.int32


class TestCompileOptions:
    def test_defaults(self):
        o = disc.CompileOptions()
        assert o.policy is disc.POW2
        assert o.backend == "xla"
        assert o.escalation_threshold is None
        assert o.max_cache_entries == 256
        assert o.donate is False
        assert o.pipeline == "dhlo"
        assert o.cache is None

    def test_replace_and_validation(self):
        o = disc.CompileOptions().replace(backend="pallas")
        assert o.backend == "pallas"
        with pytest.raises(ValueError, match="pipeline"):
            disc.CompileOptions(pipeline="interpreted")

    def test_kwargs_forwarded_from_compile(self):
        cf = disc.compile(_f, [("B", 16), (16, 8)],
                          policy=disc.BucketPolicy(kind="exact"),
                          escalation_threshold=7)
        assert cf.options.policy.kind == "exact"
        assert cf.options.escalation_threshold == 7


class TestDim:
    def test_max_is_a_contract(self):
        cf = disc.compile(lambda x: jnp.tanh(x),
                          [(disc.Dim("S", max=32), 4)])
        cf(np.zeros((30, 4), np.float32))  # bucket clamped to 32
        with pytest.raises(ValueError, match="max"):
            cf(np.zeros((40, 4), np.float32))

    def test_multiple_of_controls_buckets(self):
        cf = disc.compile(lambda x: jnp.tanh(x),
                          [(disc.Dim("S", multiple_of=8, bucket="multiple"),
                            4)])
        for s in (3, 9, 10, 17):
            cf(np.zeros((s, 4), np.float32))
        # buckets: 8, 16, 16, 24 -> 3 compiles
        assert cf.compile_counts()["bucket"] == 3

    def test_conflicting_redeclaration_rejected(self):
        with pytest.raises(ValueError, match="twice"):
            disc.compile(_f, [(disc.Dim("S", max=8), 16),
                              (disc.Dim("S", max=16), 8)])

    def test_string_reference_to_dim_is_order_independent(self):
        # a bare "S" refers to the Dim contract wherever it was declared
        from repro.api.options import normalize_specs
        for spec_order in ([("S",), (disc.Dim("S", max=100),)],
                           [(disc.Dim("S", max=100),), ("S",)]):
            specs, dims = normalize_specs(spec_order)
            assert [d for d in dims if d.name == "S"][0].max == 100


class TestBackendRegistry:
    def test_builtins_registered(self):
        names = disc.list_backends()
        assert {"xla", "pallas", "nimble_vm"} <= set(names)

    def test_unknown_backend_error(self):
        with pytest.raises(disc.UnknownBackendError, match="tvm"):
            disc.compile(_f, [("B", 16), (16, 8)], backend="tvm")

    def test_pallas_selected_through_registry(self):
        def ew(x, y):
            return jnp.tanh(x) * y + jnp.exp(x * 0.5)

        cf = disc.compile(ew, [("B", "D"), ("B", "D")], backend="pallas")
        assert cf.report()["backend"] == "pallas"
        assert cf.report()["pallas_eligible_clusters"] >= 1
        sizes = [(4, 16), (7, 33), (4, 16), (9, 60)]
        for b, d in sizes:
            x = np.random.randn(b, d).astype(np.float32)
            y = np.random.randn(b, d).astype(np.float32)
            np.testing.assert_allclose(cf(x, y), np.tanh(x) * y + np.exp(x * 0.5),
                                       rtol=1e-5, atol=1e-5)
        # compile count stays O(#buckets) through the pallas path
        assert cf.compile_counts()["bucket"] <= 3

    def test_nimble_vm_backend_matches(self):
        cf = disc.compile(_f, [("B", 16), (16, 8)], backend="nimble_vm")
        x = np.random.randn(5, 16).astype(np.float32)
        np.testing.assert_allclose(cf(x, W),
                                   _f(jnp.asarray(x), jnp.asarray(W)),
                                   rtol=1e-4, atol=1e-6)

    def test_register_custom_backend(self):
        calls = {"bucket": 0}
        xla = disc.get_backend("xla")

        def build_bucket(graph, plan, syms, padded, donate):
            calls["bucket"] += 1
            return xla.build_bucket(graph, plan, syms, padded, donate)

        be = disc.Backend(name="traced", build_bucket=build_bucket,
                          build_exact=xla.build_exact)
        disc.register_backend("traced", be, overwrite=True)
        try:
            with pytest.raises(ValueError, match="already registered"):
                disc.register_backend("traced", be)
            cf = disc.compile(lambda x: x * 2.0, [("B", 4)],
                              backend="traced")
            cf(np.zeros((3, 4), np.float32))
            assert calls["bucket"] == 1
        finally:
            backends_mod._REGISTRY.pop("traced", None)


class TestSharedCache:
    def test_jit_artifacts_never_collide(self):
        # regression: two different functions, same name/specs, one cache —
        # the fingerprint must include function identity
        shared = disc.CompileCache("shared-jit", max_entries=16)
        opts = disc.CompileOptions(pipeline="jit", cache=shared)
        f1 = disc.compile(lambda x: x + 1.0, options=opts)
        f2 = disc.compile(lambda x: x * 100.0, options=opts)
        x = np.ones((2, 2), np.float32)
        np.testing.assert_allclose(f1(x), x + 1.0)
        np.testing.assert_allclose(f2(x), x * 100.0)
        assert len(shared) == 2

    def test_hot_entry_stays_resident_under_eviction(self):
        # regression: fast-path hits must refresh LRU recency
        cf = disc.compile(lambda x: jnp.tanh(x), [("S", 2)],
                          policy=disc.BucketPolicy(kind="exact"),
                          max_cache_entries=2)
        hot = np.zeros((1, 2), np.float32)
        cf(hot)
        for s in (2, 3):            # fill the LRU, hitting `hot` in between
            cf(hot)
            cf(np.zeros((s, 2), np.float32))
        before = cf.compile_counts()["bucket"]
        cf(hot)                     # must still be resident
        assert cf.compile_counts()["bucket"] == before

    def test_dhlo_artifacts_differing_only_in_constants(self):
        # regression: DGraph.fingerprint() is constant-free; the shared
        # cache key must still distinguish x*2 from x*100
        shared = disc.CompileCache("shared-dhlo", max_entries=16)
        a = disc.compile(lambda x: x * 2.0, [("B", 2)],
                         options=disc.CompileOptions(cache=shared))
        b = disc.compile(lambda x: x * 100.0, [("B", 2)],
                         options=disc.CompileOptions(cache=shared))
        x = np.ones((2, 2), np.float32)
        np.testing.assert_allclose(a(x), x * 2.0)
        np.testing.assert_allclose(b(x), x * 100.0)
        assert len(shared) == 2

    def test_jit_bound_methods_of_distinct_instances(self):
        # regression: bound methods carry instance state; two instances of
        # one class sharing a cache must not serve each other's closures
        class Eng:
            def __init__(self, scale):
                self.scale = scale

            def step(self, x):
                return x * self.scale

        shared = disc.CompileCache("shared-bound", max_entries=16)
        opts = disc.CompileOptions(pipeline="jit", cache=shared)
        a = disc.compile(Eng(2.0).step, options=opts)
        b = disc.compile(Eng(100.0).step, options=opts)
        x = np.ones((2,), np.float32)
        np.testing.assert_allclose(a(x), x * 2.0)
        np.testing.assert_allclose(b(x), x * 100.0)
        assert len(shared) == 2

    def test_two_artifacts_share_one_cache(self):
        shared = disc.CompileCache("shared", max_entries=16)
        a = disc.compile(lambda x: jnp.tanh(x), [("B", 4)],
                         options=disc.CompileOptions(cache=shared, name="a"))
        b = disc.compile(lambda x: jnp.exp(x), [("B", 4)],
                         options=disc.CompileOptions(cache=shared, name="b"))
        x = np.zeros((3, 4), np.float32)
        a(x), b(x), a(x), b(x)
        # same bucket key, different fingerprints: no collision
        assert a.compile_counts()["bucket"] == 1
        assert b.compile_counts()["bucket"] == 1
        assert len(shared) == 2
        np.testing.assert_allclose(b(x), np.exp(x), rtol=1e-6)


class TestJitPipeline:
    def test_pytree_passthrough_with_bucketed_arg(self):
        def fn(params, tokens, lens):
            emb = params["w"][tokens]            # (1, S, D)
            total = emb.sum(axis=1)
            return total * lens[0]

        cf = disc.compile(
            fn,
            specs=[None, disc.ArgSpec((1, "S"), jnp.int32), None],
            options=disc.CompileOptions(pipeline="jit", name="jp"))
        params = {"w": jnp.asarray(np.random.randn(11, 4).astype(np.float32))}
        for s in (3, 7, 9, 21):
            toks = np.random.randint(0, 11, size=(1, s)).astype(np.int32)
            out = cf(params, toks, np.array([s], np.int32))
            # fn is lens-aware only through masking-free ops here; padded
            # tokens index row 0, so compare against padded reference
            assert out.shape == (1, 4)
        # 3,7 -> bucket 16; 9 -> 16; 21 -> 32 (pow2/16): 2 compiles
        assert cf.compile_counts()["bucket"] == 2
        assert "def _dispatch" in cf.dispatch_source


class TestDiscEngineShim:
    def test_shim_warns_and_matches(self):
        from repro.core.runtime import DiscEngine

        specs = [disc.ArgSpec(("B", 16)), disc.ArgSpec((16, 8))]
        with warnings.catch_warnings(record=True) as ws:
            warnings.simplefilter("always")
            eng = DiscEngine(_f, specs)
        assert any(issubclass(w.category, DeprecationWarning) for w in ws)

        new = disc.compile(_f, specs)
        for b in (3, 17, 30):
            x = np.random.randn(b, 16).astype(np.float32)
            np.testing.assert_allclose(eng(x, W), new(x, W),
                                       rtol=1e-6, atol=1e-7)
        # old attribute surface still present
        assert eng.n_compiles == new.compile_counts()["total"]
        # sources are identical up to fresh symbol uids
        import re
        _norm = lambda s: re.sub(r"s_\d+", "s_N", s)
        assert _norm(eng.dispatch_source) == _norm(new.dispatch_source)
        assert eng.report()["cache"]["compiles"] == \
            new.report()["cache"]["compiles"]
        assert eng.plan.stats() == new.plan.stats()
