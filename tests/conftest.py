import os

# Force a multi-device host platform for the whole suite so the SPMD
# tests (tests/test_dist_spmd.py) exercise real >1-axis meshes.  Must be
# set before jax initializes; conftest imports before any test module.
# An explicit XLA_FLAGS in the environment wins (the tests then skip
# whatever the device count cannot support).
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import numpy as np
import pytest


@pytest.fixture(autouse=True)
def _seed_rng():
    np.random.seed(20210426)  # EuroMLSys '21
    yield
