import numpy as np
import pytest


@pytest.fixture(autouse=True)
def _seed_rng():
    np.random.seed(20210426)  # EuroMLSys '21
    yield
