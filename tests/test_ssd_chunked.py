"""H4 correctness: the chunk-parallel SSD path (zamba2's mixer) must match
the sequential Mamba-2 oracle, including the bf16 stacked-state variant."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.mamba2.ref import mamba2_ref
from repro.models.layers import _ssd_chunked


@pytest.mark.parametrize("t,chunk", [(32, 16), (128, 64), (64, 8)])
def test_chunked_matches_sequential(t, chunk):
    rng = np.random.RandomState(0)
    b, h, n, p = 2, 3, 8, 8
    x = jnp.asarray(rng.randn(b, h, t, p), jnp.float32) * 0.5
    a = jax.nn.sigmoid(jnp.asarray(rng.randn(b, h, t, 1), jnp.float32))
    bb = jnp.asarray(rng.randn(b, h, t, n), jnp.float32) * 0.5
    c = jnp.asarray(rng.randn(b, h, t, n), jnp.float32) * 0.5
    got = _ssd_chunked(x, a, bb, c, chunk)
    want = mamba2_ref(x, a, bb, c)
    # bf16 stacked inter-chunk states (H4) dominate the tolerance
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


def test_grads_finite():
    rng = np.random.RandomState(1)
    b, h, t, n, p = 1, 2, 32, 4, 4
    x = jnp.asarray(rng.randn(b, h, t, p), jnp.float32) * 0.3
    a = jax.nn.sigmoid(jnp.asarray(rng.randn(b, h, t, 1), jnp.float32))
    bb = jnp.asarray(rng.randn(b, h, t, n), jnp.float32) * 0.3
    c = jnp.asarray(rng.randn(b, h, t, n), jnp.float32) * 0.3

    def loss(*args):
        return _ssd_chunked(*args, 16).sum()

    grads = jax.grad(loss, argnums=(0, 1, 2, 3))(x, a, bb, c)
    for g in grads:
        assert np.isfinite(np.asarray(g)).all()
