"""Differential fault-injection suite for the robustness plane.

For every named injection site (:data:`repro.ft.faults.SITES`) the serve
engine must degrade, not die: non-faulted requests finish with tokens
bit-identical to a fault-free run, ``run_until_done`` never raises, and
the taxonomy counters (``failed_requests``, ``retries``,
``deadline_expirations``, ``replica_drains``, ``kernel_demotions``)
tick.  The compile-fault ladder is exercised on both pipelines
(``dhlo`` + ``jit``) through ``disc.compile``, and the engine-level
differential runs both without and with a mesh.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ArgSpec, compile as disc_compile
from repro.api.backends import _make_aot_backend, register_backend
from repro.configs import get_config
from repro.core import codegen
from repro.data.pipeline import Request
from repro.errors import (CONTROL_EXCEPTIONS, CompileError, DeadlineExceeded,
                          DiscError, LaunchError, PoolExhausted, RetryPolicy,
                          classify_transient, retry_call, wrap_compile_error,
                          wrap_launch_error)
from repro.ft import faults
from repro.ft.faults import FaultInjector, FaultSpec
from repro.launch.mesh import make_mesh
from repro.models.registry import get_model
from repro.serve.engine import STATS_KEYS, ServeConfig, ServeEngine

N_DEV = len(jax.devices())
needs2 = pytest.mark.skipif(N_DEV < 2, reason="needs >=2 devices")


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tinyllama_11b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(autouse=True)
def _no_injector_leak():
    """A test that leaves an injector installed would fault every test
    after it; fail loudly and clean up."""
    yield
    leaked = faults.ACTIVE is not None
    faults.clear()
    assert not leaked, "test left a FaultInjector installed"


def _requests(vocab, lens, max_new=5, rid0=0):
    rng = np.random.RandomState(11)
    return [Request(rid=rid0 + i,
                    tokens=rng.randint(0, vocab, size=ln).astype(np.int32),
                    max_new_tokens=max_new)
            for i, ln in enumerate(lens)]


def _engine(model, params, **kw):
    kw.setdefault("max_batch", 3)
    kw.setdefault("max_seq", 64)
    return ServeEngine(model, params, ServeConfig(**kw))


def _run(model, params, reqs, **kw):
    eng = _engine(model, params, **kw)
    eng.submit(reqs)
    done = eng.run_until_done(max_steps=400)
    return eng, done


LENS = [5, 9, 12]


# ------------------------------------------------------------- taxonomy --

class TestTaxonomy:
    def test_hierarchy_preserves_builtin_types(self):
        # multiple inheritance keeps pre-taxonomy except/raises contracts
        assert issubclass(CompileError, ValueError)
        assert issubclass(LaunchError, RuntimeError)
        assert issubclass(PoolExhausted, RuntimeError)
        assert issubclass(DeadlineExceeded, TimeoutError)
        for k in (CompileError, LaunchError, PoolExhausted,
                  DeadlineExceeded):
            assert issubclass(k, DiscError)

    def test_classify_transient(self):
        from repro.core.constraints import ConstraintViolation
        from repro.frontends.jaxpr_frontend import UnsupportedPrimitiveError
        assert not classify_transient(ConstraintViolation("8 % 3"))
        assert not classify_transient(UnsupportedPrimitiveError("nope"))
        assert not classify_transient(TypeError("bad arg"))
        assert classify_transient(RuntimeError("RESOURCE_EXHAUSTED: hbm"))
        assert classify_transient(MemoryError("out of memory"))
        # an already-classified error speaks for itself
        assert classify_transient(LaunchError("x", transient=True))
        assert not classify_transient(CompileError("x", transient=False))

    def test_wrappers_chain_and_classify(self):
        src = RuntimeError("RESOURCE_EXHAUSTED while allocating")
        ce = wrap_compile_error(src, "bucket (8,)")
        assert ce.transient and ce.__cause__ is src
        assert "bucket (8,)" in str(ce)
        le = wrap_launch_error(ValueError("shape"), "decode")
        assert not le.transient and isinstance(le, LaunchError)
        # wrapping an already-wrapped error is the identity
        assert wrap_compile_error(ce, "again") is ce
        assert wrap_launch_error(le, "again") is le

    def test_retry_call_retries_transient_only(self):
        calls = {"n": 0}

        def flaky():
            calls["n"] += 1
            if calls["n"] < 3:
                raise LaunchError("flap", transient=True)
            return "ok"

        pol = RetryPolicy(max_retries=3, backoff_s=0.0)
        assert retry_call(flaky, policy=pol, sleep=lambda s: None) == "ok"
        assert calls["n"] == 3

        def perm():
            raise LaunchError("dead", transient=False)

        with pytest.raises(LaunchError, match="dead"):
            retry_call(perm, policy=pol, sleep=lambda s: None)

    def test_control_exceptions_never_swallowed(self):
        def boom():
            raise KeyboardInterrupt
        with pytest.raises(KeyboardInterrupt):
            retry_call(boom, policy=RetryPolicy(max_retries=5,
                                                backoff_s=0.0),
                       sleep=lambda s: None)
        assert KeyboardInterrupt in CONTROL_EXCEPTIONS

    def test_backoff_is_capped_exponential(self):
        pol = RetryPolicy(max_retries=9, backoff_s=0.01, multiplier=2.0,
                          cap_s=0.04)
        assert pol.delay(0) == pytest.approx(0.01)
        assert pol.delay(1) == pytest.approx(0.02)
        assert pol.delay(5) == pytest.approx(0.04)   # capped


# ------------------------------------------------------------- injector --

class TestInjector:
    def test_unknown_site_rejected(self):
        with pytest.raises(ValueError, match="unknown fault site"):
            FaultSpec("compile.bukcet")

    def test_disabled_by_default(self):
        assert faults.ACTIVE is None

    def test_at_indexes_matching_calls(self):
        # `at` counts calls the spec MATCHES, so match="decode", at=[0]
        # fires on the first decode no matter how many prefills preceded
        inj = FaultInjector([FaultSpec("serve.launch", match="decode",
                                       at=[0])])
        inj.suppress("serve.launch", key="prefill")
        inj.suppress("serve.launch", key="prefill")
        assert inj.suppress("serve.launch", key="decode")
        assert not inj.suppress("serve.launch", key="decode")
        assert inj.calls["serve.launch"] == 4
        assert inj.fired["serve.launch"] == 1

    def test_times_bounds_firing(self):
        inj = FaultInjector([FaultSpec("pool.alloc", times=2)])
        hits = [inj.suppress("pool.alloc") for _ in range(5)]
        assert hits == [True, True, False, False, False]

    def test_seeded_probability_is_deterministic(self):
        def schedule(seed):
            inj = FaultInjector([FaultSpec("pool.alloc", p=0.3)], seed=seed)
            return [inj.suppress("pool.alloc") for _ in range(64)]
        assert schedule(7) == schedule(7)
        assert schedule(7) != schedule(8)
        assert any(schedule(7)) and not all(schedule(7))

    def test_check_raises_classified_default_errors(self):
        with faults.inject(FaultSpec("compile.bucket", transient=True),
                           FaultSpec("serve.launch")) as inj:
            with pytest.raises(CompileError, match="transient fault") as ei:
                inj.check("compile.bucket")
            assert ei.value.transient
            with pytest.raises(LaunchError, match="permanent fault") as ei:
                inj.check("serve.launch")
            assert not ei.value.transient
        assert faults.ACTIVE is None   # context manager uninstalls

    def test_chaos_injector_is_seed_deterministic(self):
        a = FaultInjector.chaos(seed=3, rate=0.5)
        b = FaultInjector.chaos(seed=3, rate=0.5)
        fires = [a.suppress("pool.alloc") for _ in range(32)]
        assert fires == [b.suppress("pool.alloc") for _ in range(32)]
        assert {s.site for s in a.specs} == set(faults.SITES)


# ------------------------------------- compile ladder (both pipelines) --

def _ew(x, y):
    return jnp.tanh(x) * y + jnp.exp(x * 0.5)


class TestCompileLadder:
    @pytest.mark.parametrize("pipeline", ["dhlo", "jit"])
    def test_transient_compile_fault_retried_invisibly(self, pipeline):
        cf = disc_compile(_ew, [ArgSpec(("B", 8)), ArgSpec(("B", 8))],
                          pipeline=pipeline)
        x = np.random.RandomState(0).randn(5, 8).astype(np.float32)
        with faults.inject(FaultSpec("compile.bucket", times=1,
                                     transient=True)):
            # jit-pipeline outputs stay bucket-padded (callers slice)
            out = np.asarray(cf(x, x))[:len(x)]
        np.testing.assert_allclose(
            out, np.asarray(_ew(jnp.asarray(x), jnp.asarray(x))),
            rtol=1e-5, atol=1e-6)
        assert cf.cache_stats()["retries"] == 1

    @pytest.mark.parametrize("pipeline", ["dhlo", "jit"])
    def test_permanent_compile_fault_raises_then_cache_recovers(
            self, pipeline):
        cf = disc_compile(_ew, [ArgSpec(("B", 8)), ArgSpec(("B", 8))],
                          pipeline=pipeline)
        x = np.random.RandomState(1).randn(4, 8).astype(np.float32)
        with faults.inject(FaultSpec("compile.bucket")):
            with pytest.raises(CompileError, match="injected permanent"):
                cf(x, x)
        # the failure never became a poisoned cache entry
        out = np.asarray(cf(x, x))[:len(x)]
        np.testing.assert_allclose(
            out, np.asarray(_ew(jnp.asarray(x), jnp.asarray(x))),
            rtol=1e-5, atol=1e-6)

    def test_failed_escalation_falls_back_to_padded_bucket(self):
        cf = disc_compile(_ew, [ArgSpec(("B", 8)), ArgSpec(("B", 8))],
                          escalation_threshold=2)
        ref = disc_compile(_ew, [ArgSpec(("B", 8)), ArgSpec(("B", 8))])
        x = np.random.RandomState(2).randn(5, 8).astype(np.float32)
        with faults.inject(FaultSpec("compile.exact")):
            outs = [np.asarray(cf(x, x)) for _ in range(5)]
        for o in outs:
            np.testing.assert_allclose(o, np.asarray(ref(x, x)),
                                       rtol=1e-5, atol=1e-6)
        st = cf.cache_stats()
        # the permanent failure pinned the exact signature: exactly one
        # attempt, zero exact compiles, every call on the bucket path
        assert st["escalation_failures"] == 1
        assert cf.compile_counts()["exact"] == 0

    def test_transient_escalation_failure_does_not_pin(self):
        cf = disc_compile(_ew, [ArgSpec(("B", 8)), ArgSpec(("B", 8))],
                          escalation_threshold=2)
        x = np.random.RandomState(3).randn(5, 8).astype(np.float32)
        # times=3 exhausts the in-cache retry budget (1 try + 2 retries)
        # on the first escalation attempt: that call falls back to the
        # bucket path but the signature is NOT pinned — a later call
        # escalates successfully once the fault clears
        with faults.inject(FaultSpec("compile.exact", times=3,
                                     transient=True)):
            for _ in range(4):
                cf(x, x)
        assert cf.cache_stats()["escalation_failures"] == 0
        assert cf.cache_stats()["retries"] == 2
        cf(x, x)
        assert cf.compile_counts()["exact"] == 1


# ------------------------------------------------- kernel demotion ladder --

def _fresh_pallas(name):
    """A pallas clone with its OWN kernel instances so strike/demotion
    state never leaks into the shared registry."""
    return register_backend(
        name, _make_aot_backend(name, "pallas clone (fault tests)",
                                codegen.pallas_cluster_kernels()),
        overwrite=True)


class TestKernelDemotion:
    def test_strikes_demote_kernel_but_outputs_stay_correct(self):
        bk = _fresh_pallas("pallas_ft_kernel")
        cf = disc_compile(_ew, [ArgSpec(("B", "D")), ArgSpec(("B", "D"))],
                          backend="pallas_ft_kernel")
        rng = np.random.RandomState(4)
        j0 = len(codegen.KERNEL_DEMOTIONS)
        with faults.inject(FaultSpec("kernel.cluster")):
            # three distinct B buckets (16/32/64) -> three trace-time
            # kernel attempts, each striking the kLoop instance; per-op
            # fallback keeps every output correct
            for b in (4, 17, 33):
                x = rng.randn(b, 8).astype(np.float32)
                np.testing.assert_allclose(
                    np.asarray(cf(x, x)),
                    np.asarray(_ew(jnp.asarray(x), jnp.asarray(x))),
                    rtol=1e-5, atol=1e-6)
        kern = bk.cluster_kernels["kLoop"]
        assert kern.strikes == 3 and kern.demoted
        journal = codegen.KERNEL_DEMOTIONS[j0:]
        assert any("kLoop" in e for e in journal)
        # demoted: the next bucket compiles WITHOUT trying the kernel
        x = rng.randn(65, 8).astype(np.float32)
        np.testing.assert_allclose(
            np.asarray(cf(x, x)),
            np.asarray(_ew(jnp.asarray(x), jnp.asarray(x))),
            rtol=1e-5, atol=1e-6)
        assert kern.strikes == 3   # no further attempts

    def test_backend_demotes_to_fallback_after_strike_budget(self):
        _fresh_pallas("pallas_ft_backend")
        cf = disc_compile(_ew, [ArgSpec(("B", "D")), ArgSpec(("B", "D"))],
                          backend="pallas_ft_backend",
                          backend_demotion_strikes=2)
        rng = np.random.RandomState(5)
        j0 = len(codegen.KERNEL_DEMOTIONS)
        with faults.inject(FaultSpec("kernel.cluster")):
            for b in (4, 17, 33):   # distinct B buckets: 16, 32, 64
                x = rng.randn(b, 8).astype(np.float32)
                cf(x, x)
        # two strikes crossed the budget: the third bucket compiled on
        # the demoted-to backend (default fallback: xla)
        assert cf._compiled.backend.name == "xla"
        assert any(e.startswith("backend:pallas_ft_backend->xla")
                   for e in codegen.KERNEL_DEMOTIONS[j0:])
        x = rng.randn(6, 8).astype(np.float32)
        np.testing.assert_allclose(
            np.asarray(cf(x, x)),
            np.asarray(_ew(jnp.asarray(x), jnp.asarray(x))),
            rtol=1e-5, atol=1e-6)


# --------------------------------------------- engine differential suite --

class TestServeDifferential:
    def test_transient_launch_fault_full_parity(self, tiny):
        cfg, model, params = tiny
        _, base = _run(model, params, _requests(cfg.vocab, LENS))
        with faults.inject(FaultSpec("serve.launch", at=[0],
                                     transient=True)):
            eng, done = _run(model, params, _requests(cfg.vocab, LENS))
        assert done == base            # bit-identical, fault invisible
        assert eng.stats["retries"] >= 1
        assert not eng.failed

    def test_permanent_decode_fault_fails_group_only(self, tiny):
        cfg, model, params = tiny
        with faults.inject(FaultSpec("serve.launch", match="decode",
                                     at=[1])):
            eng = _engine(model, params)
            eng.submit(_requests(cfg.vocab, LENS))
            done = eng.run_until_done(max_steps=400)
            # only the second decode launch's group died
            assert set(eng.failed) == set(r.rid for r in
                                          _requests(cfg.vocab, LENS))
            assert all("LaunchError(decode)" in v
                       for v in eng.failed.values())
            assert eng.stats["failed_requests"] == len(LENS)
            assert not done
            # the engine keeps serving: a fresh wave completes with
            # tokens bit-identical to a fault-free engine's
            wave2 = _requests(cfg.vocab, [7, 10], rid0=100)
            eng.submit(wave2)
            done2 = eng.run_until_done(max_steps=400)
        _, base2 = _run(model, params, _requests(cfg.vocab, [7, 10],
                                                 rid0=100))
        assert done2 == base2

    def test_permanent_prefill_fault_spares_other_group(self, tiny):
        cfg, model, params = tiny
        # 5 and 40 land in different S buckets -> two prefill groups;
        # only the first-launched group fails
        reqs = _requests(cfg.vocab, [5, 40])
        with faults.inject(FaultSpec("serve.launch", match="prefill",
                                     at=[0])):
            eng, done = _run(model, params, reqs)
        assert len(eng.failed) == 1 and len(done) == 1
        (frid,) = eng.failed
        (orid,) = done
        assert "LaunchError(prefill)" in eng.failed[frid]
        solo = [r for r in _requests(cfg.vocab, [5, 40]) if r.rid == orid]
        _, base = _run(model, params, solo)
        assert done[orid] == base[orid]   # survivor is bit-identical

    def test_compile_fault_during_serve_fails_group_not_engine(self, tiny):
        cfg, model, params = tiny
        # the artifact compiles lazily INSIDE the first launch: a
        # permanent bucket-compile failure is a launch-group failure
        with faults.inject(FaultSpec("compile.bucket", match="prefill")):
            eng, done = _run(model, params, _requests(cfg.vocab, LENS))
        assert not done
        assert set(eng.failed) and all(
            "LaunchError(prefill)" in v or "injected permanent" in v
            for v in eng.failed.values())

    def test_pool_alloc_fault_preempts_and_recovers(self, tiny):
        cfg, model, params = tiny
        paged = dict(kv_block_size=16, kv_pool_blocks=12)
        _, base = _run(model, params, _requests(cfg.vocab, LENS), **paged)
        with faults.inject(FaultSpec("pool.alloc", times=2)):
            eng, done = _run(model, params, _requests(cfg.vocab, LENS),
                             **paged)
        assert done == base            # greedy recompute is exact
        assert not eng.failed
        eng.alloc.assert_consistent()

    def test_pool_exhaustion_bounds_recompute(self, tiny):
        cfg, model, params = tiny
        with faults.inject(FaultSpec("pool.alloc")):   # every alloc denied
            eng, done = _run(model, params, _requests(cfg.vocab, LENS),
                             kv_block_size=16, kv_pool_blocks=12,
                             max_recomputes=2)
        # bounded recompute turns the livelock into PoolExhausted
        assert not done
        assert set(eng.failed) == {r.rid
                                   for r in _requests(cfg.vocab, LENS)}
        assert all("PoolExhausted" in v for v in eng.failed.values())
        assert not eng.queue and all(s is None for s in eng.slots)
        eng.alloc.assert_consistent()

    def test_deadline_expires_only_late_request(self, tiny):
        cfg, model, params = tiny
        def reqs():
            out = _requests(cfg.vocab, LENS, max_new=6)
            out[2].deadline_s = 3.0    # expires mid-run (fake clock)
            return out
        _, base = _run(model, params, _requests(cfg.vocab, LENS[:2],
                                                max_new=6))
        eng = _engine(model, params)
        t = [0.0]
        eng._clock = lambda: t[0]
        eng.submit(reqs())
        for _ in range(3):
            eng.step()
        t[0] = 5.0                     # past rid 2's absolute deadline
        done = eng.run_until_done(max_steps=400)
        assert set(eng.failed) == {2}
        assert "DeadlineExceeded" in eng.failed[2]
        assert eng.stats["deadline_expirations"] == 1
        assert {k: done[k] for k in base} == base   # survivors identical

    def test_deadline_checked_at_admission(self, tiny):
        cfg, model, params = tiny
        eng = _engine(model, params, max_batch=1)
        t = [0.0]
        eng._clock = lambda: t[0]
        r = _requests(cfg.vocab, [6], max_new=4)
        r[0].deadline_s = 1.0
        eng.submit(r)
        t[0] = 2.0                     # expired while still queued
        eng.step()
        assert eng.failed[0].endswith("before completion")
        assert eng.stats["deadline_expirations"] == 1

    def test_replica_drain_preempts_and_survivors_serve(self, tiny):
        cfg, model, params = tiny
        # monitoring never changes generated tokens: the baseline runs
        # without it (a real-clock baseline with a 5 s deadline and no
        # beats could drain spuriously under first-launch compile cost)
        _, base = _run(model, params, _requests(cfg.vocab, [6, 9]),
                       max_batch=1, replicas=2)
        eng = _engine(model, params, max_batch=1, replicas=2,
                      heartbeat_deadline_s=5.0)
        t = [1.0]
        eng._clock = lambda: t[0]
        for r in range(2):
            eng.heartbeat(r)           # beats at t=1
        eng.submit(_requests(cfg.vocab, [6, 9]))
        for _ in range(2):
            eng.step()                 # both admitted, prefill started
        t[0] = 10.0
        eng.heartbeat(0)               # only replica 0 stays live
        done = eng.run_until_done(max_steps=400)
        assert eng.stats["replica_drains"] == 1
        assert eng._replica_alive == [True, False]
        assert not eng.failed          # drained request requeued, not lost
        assert set(done) == set(base)
        # the survivor replica's own request never moved: bit-identical;
        # the drained one recomputed via prefill (prefix preserved)
        per_rep = eng.stats["per_replica"]
        assert per_rep[1]["requests_completed"] == 0
        for rid in done:
            assert done[rid][:1] == base[rid][:1]
        # recovery: a beat restores the replica and it serves again
        eng.heartbeat(1)
        eng.submit(_requests(cfg.vocab, [7], rid0=50))
        eng.run_until_done(max_steps=400)
        assert 50 in eng.done
        assert eng._replica_alive == [True, True]
        assert eng.stats["per_replica"][1]["admitted"] >= 1

    def test_injected_heartbeat_loss_drains_replica(self, tiny):
        cfg, model, params = tiny
        with faults.inject(FaultSpec("ft.heartbeat", match="replica1")):
            # replica 1's init beat is dropped -> drained at step 0;
            # traffic lands on replica 0 and completes
            eng, done = _run(model, params, _requests(cfg.vocab, [6, 9]),
                             max_batch=1, replicas=2,
                             heartbeat_deadline_s=60.0)
        _, base = _run(model, params, _requests(cfg.vocab, [6, 9]),
                       max_batch=1, replicas=1)
        assert eng.stats["replica_drains"] == 1
        assert eng._replica_alive == [True, False]
        assert done == base            # single-replica parity
        assert eng.stats["per_replica"][1]["admitted"] == 0

    def test_report_health_structure(self, tiny):
        cfg, model, params = tiny
        with faults.inject(FaultSpec("serve.launch", at=[0],
                                     transient=True)):
            eng, _ = _run(model, params, _requests(cfg.vocab, [5]),
                          heartbeat_deadline_s=60.0)
        rep = eng.report()
        h = rep["health"]
        assert h["alive_replicas"] == 1
        assert h["replicas"][0]["alive"]
        assert "last_beat_age_s" in h["replicas"][0]
        assert h["counters"]["retries"] >= 1
        assert set(h["counters"]) == {"failed_requests", "retries",
                                      "kernel_demotions",
                                      "deadline_expirations",
                                      "replica_drains"}
        assert h["failed"] == {}
        assert set(h["compile"]) == {"retries", "escalation_failures"}
        assert set(rep) == {"health", "stats", "compiles"}
        assert set(rep["stats"]) == set(STATS_KEYS)

    def test_chaos_run_completes_every_request(self, tiny):
        cfg, model, params = tiny
        reqs = _requests(cfg.vocab, [5, 9, 12, 7], max_new=4)
        inj = FaultInjector.chaos(seed=12, rate=0.04,
                                  sites=("serve.launch", "pool.alloc"))
        with faults.inject(injector=inj):
            eng, done = _run(model, params, reqs, kv_block_size=16,
                             kv_pool_blocks=16)
        # graceful degradation: every request retired done or failed,
        # never dropped, never an engine crash
        assert set(done) | set(eng.failed) == {r.rid for r in reqs}
        eng.alloc.assert_consistent()


# ----------------------------------------------------------- mesh (SPMD) --

class TestServeDifferentialMesh:
    @needs2
    def test_transient_launch_fault_parity_under_mesh(self, tiny):
        cfg, model, params = tiny
        mesh = make_mesh((2,), ("data",))
        kw = dict(max_batch=2, replicas=1, mesh=mesh,
                  sharding_profile="dp")
        _, base = _run(model, params, _requests(cfg.vocab, [6, 9]), **kw)
        with faults.inject(FaultSpec("serve.launch", at=[0],
                                     transient=True)):
            eng, done = _run(model, params, _requests(cfg.vocab, [6, 9]),
                             **kw)
        assert done == base
        assert eng.stats["retries"] >= 1 and not eng.failed

    @needs2
    def test_replica_drain_under_mesh(self, tiny):
        cfg, model, params = tiny
        mesh = make_mesh((2,), ("data",))
        kw = dict(max_batch=1, replicas=2, mesh=mesh,
                  sharding_profile="dp", heartbeat_deadline_s=5.0)
        eng = _engine(model, params, **kw)
        t = [1.0]
        eng._clock = lambda: t[0]
        for r in range(2):
            eng.heartbeat(r)
        eng.submit(_requests(cfg.vocab, [6, 9]))
        for _ in range(2):
            eng.step()
        t[0] = 10.0
        eng.heartbeat(0)
        done = eng.run_until_done(max_steps=400)
        assert eng.stats["replica_drains"] == 1
        assert not eng.failed
        assert set(done) == {0, 1}     # both completed on the survivor
