"""Paged KV-cache pool + speculative decoding tests.

Covers the block allocator's invariants under random ensure/release
sequences (property-tested via the hypothesis shim), the paged
gather/scatter primitives against a dense numpy reference, pool-pressure
preemption end to end (victims recompute, token budgets and emitted
prefixes are preserved, the allocator stays consistent), speculative
drafting (n-gram proposer, single-launch verify, greedy accept-or-fix
parity), and the model-level ``verify`` ≡ decode-replay contract the
speculative path rests on.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs import get_config
from repro.data.pipeline import Request
from repro.models.layers import paged_gather, paged_scatter
from repro.models.registry import get_model, replay_verify
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.paging import (NULL_BLOCK, BlockAllocator, blocks_for,
                                pick_victim)
from repro.serve.speculative import (DraftModelProposer, NGramProposer,
                                     get_proposer)


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tinyllama_11b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests(vocab, lens, max_new=4, prios=None, seed=7):
    rng = np.random.RandomState(seed)
    return [Request(rid=i,
                    tokens=rng.randint(0, vocab, size=ln).astype(np.int32),
                    max_new_tokens=max_new,
                    priority=0 if prios is None else prios[i])
            for i, ln in enumerate(lens)]


# -------------------------------------------------------------- allocator --

class TestBlockAllocator:
    def test_blocks_for(self):
        assert blocks_for(0, 16) == 0
        assert blocks_for(1, 16) == 1
        assert blocks_for(16, 16) == 1
        assert blocks_for(17, 16) == 2

    def test_ensure_is_all_or_nothing(self):
        a = BlockAllocator(4, 8, n_slots=2, max_blocks_per_slot=4)
        assert a.ensure(0, 24)           # 3 blocks
        assert not a.ensure(1, 16)       # needs 2, only 1 free
        assert a.owned(1) == []          # nothing half-allocated
        assert a.free_blocks == 1
        assert a.ensure(1, 8)
        a.assert_consistent()

    def test_ensure_respects_per_slot_cap(self):
        a = BlockAllocator(8, 8, n_slots=2, max_blocks_per_slot=2)
        assert not a.ensure(0, 24)       # 3 blocks > cap, despite 8 free
        assert a.owned(0) == []

    def test_release_returns_blocks_and_table_is_null_padded(self):
        a = BlockAllocator(4, 8, n_slots=2, max_blocks_per_slot=4)
        a.ensure(0, 20)
        t = a.table()
        assert t.shape == (2, 4) and t.dtype == np.int32
        assert NULL_BLOCK not in t[0, :3] and (t[0, 3:] == NULL_BLOCK).all()
        assert (t[1] == NULL_BLOCK).all()
        freed = a.release(0)
        assert freed == 3 and a.free_blocks == 4
        a.assert_consistent()

    @settings(max_examples=25, deadline=None)
    @given(ops=st.lists(st.tuples(st.integers(0, 3), st.integers(0, 40),
                                  st.booleans()),
                        min_size=1, max_size=40),
           n_blocks=st.integers(1, 12))
    def test_random_op_sequences_keep_invariants(self, ops, n_blocks):
        """No double-assignment, freed blocks return, owned+free is
        conserved — under arbitrary interleaved ensure/release."""
        a = BlockAllocator(n_blocks, 8, n_slots=4, max_blocks_per_slot=6)
        for slot, n_tokens, do_release in ops:
            if do_release:
                before = len(a.owned(slot))
                assert a.release(slot) == before
            else:
                before = a.owned(slot)
                ok = a.ensure(slot, n_tokens)
                if not ok:   # all-or-nothing
                    assert a.owned(slot) == before
                else:
                    assert len(a.owned(slot)) \
                        >= blocks_for(n_tokens, a.block_size)
            a.assert_consistent()

    def test_pick_victim_policy(self):
        # lowest priority first, then newest admission
        assert pick_victim([(0, 1, 5), (1, 0, 2), (2, 0, 9)]) == 2
        assert pick_victim([(0, 2, 1), (1, 1, 0)]) == 1
        assert pick_victim([]) is None


# --------------------------------------------------------- gather/scatter --

class TestGatherScatter:
    def _ref_gather(self, pool, tables, block_axis, seq_axis):
        p = np.moveaxis(np.asarray(pool), (block_axis, seq_axis), (0, 1))
        rows = [np.concatenate([p[b] for b in row], axis=0)
                for row in tables]
        return np.moveaxis(np.stack(rows), (0, 1), (block_axis, seq_axis))

    @pytest.mark.parametrize("block_axis,seq_axis,shape", [
        (1, 3, (2, 5, 3, 4, 2)),    # attention layout (L, NB, hkv, bs, hd)
        (1, 2, (2, 5, 4, 3)),       # MLA layout (L, NB, bs, lora)
    ])
    def test_gather_matches_dense_reference(self, block_axis, seq_axis,
                                            shape):
        rng = np.random.RandomState(0)
        pool = jnp.asarray(rng.randn(*shape).astype(np.float32))
        tables = jnp.asarray([[1, 3], [4, 2]], jnp.int32)
        out = paged_gather(pool, tables, block_axis=block_axis,
                           seq_axis=seq_axis)
        ref = self._ref_gather(pool, np.asarray(tables), block_axis,
                               seq_axis)
        np.testing.assert_array_equal(np.asarray(out), ref)

    def test_scatter_roundtrip_and_null_sink(self):
        """Kept positions land in their blocks; masked writes go to the
        null block; a gather after scatter returns the dense rows."""
        rng = np.random.RandomState(1)
        pool = jnp.asarray(rng.randn(2, 6, 3, 8, 2).astype(np.float32))
        tables = jnp.asarray([[2, 4], [1, 3]], jnp.int32)
        dense = jnp.asarray(rng.randn(2, 2, 3, 16, 2).astype(np.float32))
        keep = jnp.asarray(np.array([[True] * 10 + [False] * 6,
                                     [False] * 4 + [True] * 8
                                     + [False] * 4]))
        new = paged_scatter(pool, dense, tables, keep, block_axis=1,
                            seq_axis=3)
        back = paged_gather(new, tables, block_axis=1, seq_axis=3)
        kp = np.asarray(keep)[None, :, None, :, None]
        np.testing.assert_array_equal(
            np.where(kp, np.asarray(back), 0.0),
            np.where(kp, np.asarray(dense), 0.0))
        # a block in no table row stays bit-identical (the null block,
        # id 0, absorbs the masked writes instead)
        np.testing.assert_array_equal(np.asarray(new)[:, 5],
                                      np.asarray(pool)[:, 5])


# -------------------------------------------------------------- proposers --

class TestProposers:
    def test_ngram_proposes_historical_continuation(self):
        p = NGramProposer(max_ngram=3)
        h = np.array([5, 6, 7, 8, 9, 1, 2, 5, 6, 7], np.int32)
        np.testing.assert_array_equal(p.propose(h, 2), [8, 9])
        np.testing.assert_array_equal(p.propose(h, 5), [8, 9, 1, 2, 5])

    def test_ngram_falls_back_to_shorter_grams(self):
        p = NGramProposer(max_ngram=3)
        h = np.array([1, 2, 3, 9, 3], np.int32)   # only the 1-gram matches
        np.testing.assert_array_equal(p.propose(h, 2), [9, 3])

    def test_ngram_empty_cases(self):
        p = NGramProposer()
        assert p.propose(np.array([1, 2, 3], np.int32), 0).size == 0
        assert p.propose(np.array([7], np.int32), 4).size == 0
        # no repeat anywhere -> nothing to propose
        assert p.propose(np.array([1, 2, 3, 4], np.int32), 4).size == 0
        with pytest.raises(ValueError, match="max_ngram"):
            NGramProposer(0)

    def test_draft_model_proposer_is_a_stub(self):
        p = DraftModelProposer(model=None, params=None)
        with pytest.raises(NotImplementedError):
            p.propose(np.array([1, 2], np.int32), 2)

    def test_get_proposer_resolution(self):
        assert get_proposer(None) is None
        assert isinstance(get_proposer("ngram"), NGramProposer)
        custom = NGramProposer(2)
        assert get_proposer(custom) is custom
        with pytest.raises(ValueError, match="unknown proposer"):
            get_proposer("beam")
        with pytest.raises(ValueError, match="propose"):
            get_proposer(42)


# ------------------------------------------------------------- the engine --

class TestPagedEngine:
    def test_paged_config_validation(self, tiny):
        cfg, model, params = tiny
        with pytest.raises(ValueError, match="divide"):
            ServeEngine(model, params,
                        ServeConfig(max_batch=2, max_seq=96,
                                    kv_block_size=13))
        with pytest.raises(ValueError, match="kv_block_size"):
            ServeEngine(model, params,
                        ServeConfig(max_batch=2, max_seq=96,
                                    kv_block_size=0))

    def test_recurrent_family_has_no_paging(self):
        cfg = get_config("rwkv6_3b").reduced()
        model = get_model(cfg)
        assert model.init_block_pool is None
        params = model.init(jax.random.PRNGKey(0))
        with pytest.raises(ValueError, match="no paged-KV support"):
            ServeEngine(model, params,
                        ServeConfig(max_batch=2, max_seq=32,
                                    kv_block_size=8))

    def test_pool_pressure_preempts_and_recovers(self, tiny):
        """A pool too small for all admitted slots forces preemption;
        every request still completes with its full token budget, the
        already-emitted prefix survives the requeue bit-exactly, and all
        blocks drain back to the free list."""
        cfg, model, params = tiny
        reqs = _requests(cfg.vocab, [18, 23, 17, 21], max_new=20,
                         prios=[0, 1, 0, 1], seed=1)
        eng = ServeEngine(model, params,
                         ServeConfig(max_batch=4, max_seq=64,
                                     kv_block_size=8, kv_pool_blocks=6))
        carried = {}
        orig = eng._preempt
        def spy(i):
            s = eng.slots[i]
            carried.setdefault(s.rid, []).append(list(s.generated))
            return orig(i)
        eng._preempt = spy
        eng.submit(reqs)
        eng.run_until_done(max_steps=2000)
        assert eng.stats["kv_preemptions"] > 0
        assert eng.stats["kv_evictions"] >= eng.stats["kv_preemptions"]
        assert sorted(eng.done) == [0, 1, 2, 3]
        for r in reqs:   # exact token budget despite recompute
            assert len(eng.done[r.rid]) == r.max_new_tokens + 1
        for rid, prefixes in carried.items():   # emitted prefix preserved
            for pre in prefixes:
                assert eng.done[rid][:len(pre)] == pre
        eng.alloc.assert_consistent()
        assert eng.alloc.used_blocks == 0
        assert eng.stats["kv_peak_occupancy"] > 0.5

    def test_speculative_parity_and_stats(self, tiny):
        """Greedy accept-or-fix emits exactly the plain-decode tokens on
        both cache layouts, accepted drafts ride a single verify launch
        (fewer decode launches), and the counters move."""
        cfg, model, params = tiny
        lens = [12, 9, 15]
        plain = ServeEngine(model, params,
                            ServeConfig(max_batch=3, max_seq=64))
        plain.submit(_requests(cfg.vocab, lens, max_new=8))
        plain.run_until_done(max_steps=400)
        for kv_bs in (None, 16):
            spec = ServeEngine(model, params,
                               ServeConfig(max_batch=3, max_seq=64,
                                           kv_block_size=kv_bs,
                                           speculative="ngram"))
            spec.submit(_requests(cfg.vocab, lens, max_new=8))
            spec.run_until_done(max_steps=400)
            assert spec.done == plain.done
            assert spec.stats["spec_drafted_tokens"] > 0
            assert 0 <= spec.stats["spec_accepted_tokens"] \
                <= spec.stats["spec_drafted_tokens"]
            assert "verify" in spec.compile_counts()
            assert spec.stats["decode_steps"] <= plain.stats["decode_steps"]

    def test_speculative_k_validation(self, tiny):
        cfg, model, params = tiny
        with pytest.raises(ValueError, match="speculative_k"):
            ServeEngine(model, params,
                        ServeConfig(max_batch=2, max_seq=64,
                                    speculative="ngram", speculative_k=0))


# ------------------------------------------------------------ model level --

class TestVerifyContract:
    def test_verify_matches_decode_replay(self, tiny):
        """transformer.verify (single-pass, all-position logits) must
        agree with the sequential decode-step replay it shortcuts —
        same greedy argmax at every valid position."""
        cfg, model, params = tiny
        rng = np.random.RandomState(3)
        b, s, max_len = 2, 6, 32
        tokens = jnp.asarray(rng.randint(0, cfg.vocab, size=(b, s)),
                             jnp.int32)
        lens = jnp.asarray([6, 4], jnp.int32)
        offsets = jnp.asarray([0, 0], jnp.int32)
        cache = model.init_cache(b, max_len)
        fast, cache_f = model.verify(params, cache, tokens, lens, offsets)
        slow, cache_s = replay_verify(model.decode_step)(
            params, model.init_cache(b, max_len), tokens, lens, offsets)
        fa = np.asarray(jnp.argmax(fast, -1))
        sa = np.asarray(jnp.argmax(slow, -1))
        for r, ln in enumerate([6, 4]):
            np.testing.assert_array_equal(fa[r, :ln], sa[r, :ln])
