"""Symbolic-shape memory planning (DISC §4.2.2 / BladeDISC++).

* bucket-generic parity: outputs are bit-identical with planning on vs
  off, across multiple buckets of the same artifact;
* the ``le`` lattice verdict fires only through ``Dim(max=...)`` caps —
  without a cap the symbolic comparison stays ``unknown`` and the
  S-dim intermediates cannot reuse retired static slots;
* in-place donation (``dynamic_update_slice``) hands the dying operand's
  slot to the result, and ``plan_report`` charges the pair once;
* the interpreted VM executes the plan's free lines for real
  (measured planned peak < naive peak);
* every key surfaced by ``report()["memory"]`` is documented in
  ``docs/api.md`` (the docs-check-style contract for the memory chapter).
"""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (ArgSpec, CompileOptions, Dim, NimbleVM, bridge,
                       compile as disc_compile)
from repro.core.buffers import (DonateLine, ReuseLine, plan_buffers,
                                plan_report)

D = 32


def _chain(x):
    w = jnp.eye(D, dtype=jnp.float32) * 0.9
    h = jnp.tanh(x @ w)
    h = h + x
    s = h.sum(axis=1, keepdims=True)
    return h * s


def _capped(x):
    # static max-shaped constants interleaved with S-dim values: reuse of
    # the retired static slots needs the proof 4*S*D <= 4*128*D, which
    # only Dim("S", max=128) provides
    big = jnp.tanh(jnp.ones((128, D), jnp.float32))
    y = x * big.sum()
    z = y + 1.0
    return z * 0.5


class TestBucketParity:
    def test_outputs_bit_identical_across_buckets(self):
        spec = ((Dim("S", max=128), D),)
        on = disc_compile(_chain, spec, options=CompileOptions(name="mp_on"))
        off = disc_compile(_chain, spec, options=CompileOptions(
            name="mp_off", memory_planning=False, plan_donation=False))
        rng = np.random.default_rng(0)
        seen = set()
        for s in (10, 40, 100):  # >= 2 distinct buckets
            x = rng.standard_normal((s, D)).astype(np.float32)
            a, b = np.asarray(on(x)), np.asarray(off(x))
            assert np.array_equal(a, b), f"parity broke at S={s}"
        mem = on.report()["memory"]
        assert mem["planning"] is True
        assert len(mem["per_bucket"]) >= 2
        assert off.report()["memory"]["planning"] is False
        # planning-off degrades to one slot per value: no reuse at all
        assert sum(off.lower().buffer_plan.reuse_counts.values()) == 0

    def test_planned_slots_fewer_than_values(self):
        graph, _ = bridge(_chain, [ArgSpec(("S", D))])
        plan = plan_buffers(graph)
        assert plan.n_slots < plan.n_values
        assert sum(plan.reuse_counts.values()) >= 1


class TestCapDrivenLeReuse:
    def test_le_fires_only_via_dim_max(self):
        capped = disc_compile(
            _capped, ((Dim("S", max=128), D),),
            options=CompileOptions(name="mp_cap")).lower().buffer_plan
        uncapped = disc_compile(
            _capped, [ArgSpec(("S", D))],
            options=CompileOptions(name="mp_nocap")).lower().buffer_plan
        # with the cap, the S-dim intermediates fit retired static slots
        assert capped.reuse_counts["le"] > uncapped.reuse_counts["le"]
        # ...and the extra reuses are exactly the symbolic-size ones:
        # every le ReuseLine whose incoming size still has dim symbols
        # exists only in the capped plan
        def symbolic_le(plan):
            return [ln for ln in plan.lines
                    if isinstance(ln, ReuseLine) and ln.kind == "le"
                    and not ln.size.is_static()]
        assert len(symbolic_le(capped)) >= 1
        assert len(symbolic_le(uncapped)) == 0


class TestDonation:
    @staticmethod
    def _fn(x):
        # buf dies exactly at the DUS op and no other dead slot of its
        # size exists there — only donation can merge the pair
        buf = x + 1.0
        upd = x[:1] * 2.0
        out = jax.lax.dynamic_update_slice(buf, upd, (0, 0))
        return out * 1.0

    def test_dus_donates_dying_operand_slot(self):
        graph, _ = bridge(self._fn, [ArgSpec((8, D))])
        plan = plan_buffers(graph)
        assert plan.reuse_counts["donated"] >= 1
        assert plan.donated_from
        assert any(isinstance(ln, DonateLine) for ln in plan.lines)
        # donor and donated result share one slot
        for dst, src in plan.donated_from.items():
            assert plan.slot_of[dst] == plan.slot_of[src]

    def test_plan_report_counts_donated_pair_once(self):
        graph, _ = bridge(self._fn, [ArgSpec((8, D))])
        with_d = plan_buffers(graph, donation=True)
        without = plan_buffers(graph, donation=False)
        rd = plan_report(graph, with_d, {})
        rn = plan_report(graph, without, {})
        # the in-place pair is one buffer: peak strictly drops
        assert rd["peak_bytes"] < rn["peak_bytes"]

    def test_donation_gate_off_plans_no_donations(self):
        graph, _ = bridge(self._fn, [ArgSpec((8, D))])
        plan = plan_buffers(graph, donation=False)
        assert plan.reuse_counts["donated"] == 0
        assert not plan.donated_from
        assert plan.donatable_args == ()


class TestVMExecutesPlan:
    def test_planned_peak_below_naive(self):
        spec = ((Dim("S", max=128), D),)
        comp = disc_compile(_chain, spec, options=CompileOptions(name="mp_vm"))
        g = comp.lower().graph
        x = np.ones((64, D), np.float32)
        vm_on = NimbleVM(g, sync_per_op=False, memory_planning=True)
        vm_off = NimbleVM(g, sync_per_op=False, memory_planning=False)
        a, b = vm_on(x), vm_off(x)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert vm_on.stats.reuses >= 1
        assert vm_on.stats.planned_peak_bytes < vm_off.stats.naive_peak_bytes


class TestMemoryReportDocumented:
    """Every key of ``report()["memory"]`` must appear in docs/api.md."""

    def test_all_keys_documented(self):
        spec = ((Dim("S", max=128), D),)
        comp = disc_compile(_chain, spec, options=CompileOptions(name="mp_doc"))
        comp(np.ones((48, D), np.float32))
        mem = comp.report()["memory"]
        api_md = (pathlib.Path(__file__).resolve().parent.parent
                  / "docs" / "api.md").read_text()
        keys = set(mem) | set(mem["staging"])
        for bucket in mem["per_bucket"].values():
            keys |= set(bucket)
        missing = sorted(k for k in keys if f"`{k}`" not in api_md)
        assert not missing, f"report()['memory'] keys absent from " \
                            f"docs/api.md: {missing}"
