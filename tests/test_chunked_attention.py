"""The pure-jnp flash-style chunked SDPA must match the direct path."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import _sdpa, _sdpa_chunked


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("hkv", [4, 1])
def test_chunked_matches_direct(causal, hkv):
    rng = np.random.RandomState(0)
    b, h, s, d = 2, 4, 64, 16
    q = jnp.asarray(rng.randn(b, h, s, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, hkv, s, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, hkv, s, d), jnp.float32)
    lens = jnp.array([s, s // 3], jnp.int32)
    direct = _sdpa(q, k, v, causal=causal, lens=lens)
    chunked = _sdpa_chunked(q, k, v, causal=causal, lens=lens, q_offset=0)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(direct),
                               rtol=2e-4, atol=2e-5)


def test_chunked_with_q_offset():
    rng = np.random.RandomState(1)
    b, h, s, d = 1, 2, 32, 8
    q = jnp.asarray(rng.randn(b, h, 8, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, h, s, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, h, s, d), jnp.float32)
    direct = _sdpa(q, k, v, causal=True, lens=None, q_offset=16)
    chunked = _sdpa_chunked(q, k, v, causal=True, lens=None, q_offset=16)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(direct),
                               rtol=2e-4, atol=2e-5)
