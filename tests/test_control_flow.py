"""Differential control-flow suite: traced lax.while_loop / lax.scan /
lax.cond as first-class DHLO region ops (``d.while`` / ``d.scan`` /
``d.cond``).

The contract under test, on BOTH pipelines:

* compiled-vs-eager parity across >= 2 bucket signatures — including scans
  whose carry transform is iteration-count sensitive (padded extra trips
  would corrupt the carry without the dhlo trip-count guard);
* compile counts are O(#entry-shape buckets): data-dependent trip counts
  and iteration-varying interior shapes never multiply compile counts;
* nested regions (a while inside a scan body) round-trip;
* carry widening: a carry dim that changes across iterations unifies into
  a fresh *bounded* symbol when a ``Dim(max=...)`` cap is declarable, and
  raises :class:`ConstraintViolation` when it is not;
* unsupported higher-order primitives raise a named
  :class:`UnsupportedPrimitiveError` instead of silently mis-lowering.

The jit pipeline's documented contract is "the function is lens-aware":
inputs are zero-padded to the bucket and outputs are not re-sliced, so the
jit-side differential checks compare the valid region and use pad-neutral
bodies where trip count matters.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from repro.api import ArgSpec, CompileOptions, Dim, compile as disc_compile
from repro.core.constraints import ConstraintViolation, ShapeConstraintStore
from repro.core.propagation import carry_fixed_point
from repro.core.symshape import fresh_symdim
from repro.core.vm import NimbleVM
from repro.frontends.jaxpr_frontend import UnsupportedPrimitiveError

from _hypothesis_compat import given, settings, st

PIPELINES = ("dhlo", "jit")


def _compile(fn, spec=((Dim("S", max=64), 4),), pipeline="dhlo", **opts):
    return disc_compile(fn, spec,
                        options=CompileOptions(pipeline=pipeline, **opts))


def _x(s, d=4, seed=0):
    rng = np.random.RandomState(seed + s)
    return (rng.randn(s, d) * 0.1).astype(np.float32)


def _check(cf, fn, x, pipeline, rtol=1e-5):
    got = jax.tree.map(np.asarray, cf(x))
    want = jax.tree.map(np.asarray, fn(jnp.asarray(x)))
    flat_g, _ = jax.tree.flatten(got)
    flat_w, _ = jax.tree.flatten(want)
    for g, w in zip(flat_g, flat_w):
        if pipeline == "jit" and g.shape != w.shape:
            # jit pipeline: outputs stay bucket-padded (lens-aware contract)
            g = g[tuple(slice(0, n) for n in w.shape)]
        assert g.shape == w.shape
        np.testing.assert_allclose(g, w, rtol=rtol, atol=1e-6)


# ---------------------------------------------------------------- while --


def while_fn(x):
    """Data-dependent trip count: loop until the accumulator crosses a
    threshold derived from the input."""
    def cond(c):
        return c[0] < 7

    def body(c):
        return (c[0] + 1, c[1] * 1.25 + x.sum())

    return lax.while_loop(cond, body, (jnp.int32(0), jnp.float32(1.0)))[1]


class TestWhileDifferential:
    @pytest.mark.parametrize("pipeline", PIPELINES)
    def test_parity_across_buckets(self, pipeline):
        cf = _compile(while_fn, pipeline=pipeline)
        for s in (5, 13, 37, 61):
            _check(cf, while_fn, _x(s), pipeline)
        # 4 sizes, 3 pow2 buckets (16/64) -> compile count is O(#buckets)
        assert cf.n_compiles == len({16, 16, 64, 64})

    def test_trip_count_does_not_multiply_compiles(self):
        """Same entry bucket, wildly different iteration counts: ONE
        compile.  The while trip count is a runtime property, not a
        bucket-key component."""
        def f(x):
            def cond(c):
                return c[1] < x[0, 0]

            def body(c):
                return (c[0] + 1, c[1] * 2.0)

            return lax.while_loop(cond, body,
                                  (jnp.int32(0), jnp.float32(1.0)))[0]

        cf = _compile(f)
        counts = set()
        for thresh in (1.5, 100.0, 1e6):
            x = np.ones((9, 4), np.float32)
            x[0, 0] = thresh
            counts.add(int(cf(x)))
        assert len(counts) == 3       # genuinely different trip counts
        assert cf.n_compiles == 1     # one entry bucket -> one compile


# ----------------------------------------------------------------- scan --


def scan_carry_fn(x):
    """Iteration-count-sensitive carry (c doubles every step): padded
    extra iterations corrupt it unless the region masks the trip count."""
    def body(c, xi):
        return c * 2.0 + xi.sum(), c

    c, ys = lax.scan(body, jnp.float32(1.0), x)
    return c


def scan_ys_fn(x):
    def body(c, xi):
        return c + 1.0, xi * c

    c, ys = lax.scan(body, jnp.float32(1.0), x)
    return ys


class TestScanDifferential:
    def test_carry_exact_under_padding_dhlo(self):
        """S=13 in a 16-bucket: 3 padded trips would scale the carry by
        2**3 without the index guard.  Must be exact on the dhlo path."""
        cf = _compile(scan_carry_fn)
        for s in (5, 13, 16, 21, 37):
            _check(cf, scan_carry_fn, _x(s), "dhlo")

    def test_carry_parity_jit_pad_neutral(self):
        """The jit pipeline replays the function on zero-padded inputs, so
        its differential check uses a pad-neutral carry (c + xi.sum())."""
        def f(x):
            def body(c, xi):
                return c + xi.sum(), c

            return lax.scan(body, jnp.float32(0.0), x)[0]

        cf = _compile(f, pipeline="jit")
        for s in (5, 13, 37):
            _check(cf, f, _x(s), "jit")

    @pytest.mark.parametrize("pipeline", PIPELINES)
    def test_ys_outer_dim_recovered(self, pipeline):
        cf = _compile(scan_ys_fn, pipeline=pipeline)
        for s in (7, 13, 33):
            _check(cf, scan_ys_fn, _x(s), pipeline)

    def test_reverse_scan_parity(self):
        def f(x):
            def body(c, xi):
                return c * 2.0 + xi.sum(), c + xi[0]

            return lax.scan(body, jnp.float32(1.0), x, reverse=True)

        cf = _compile(f)
        for s in (5, 16, 29):
            _check(cf, f, _x(s), "dhlo")

    def test_compile_count_is_O_buckets(self):
        cf = _compile(scan_ys_fn)
        buckets = set()
        for s in (3, 5, 9, 13, 16, 19, 30, 31, 33, 50):
            cf(_x(s))
            buckets.add(16 if s <= 16 else (32 if s <= 32 else 64))
        assert cf.n_compiles == len(buckets)


# ----------------------------------------------------------------- cond --


def cond_fn(x):
    return lax.cond(x.sum() > 0.0,
                    lambda a: a * 2.0,
                    lambda a: a - 1.0, x)


class TestCondDifferential:
    @pytest.mark.parametrize("pipeline", PIPELINES)
    def test_both_branches_both_buckets(self, pipeline):
        cf = _compile(cond_fn, pipeline=pipeline)
        for s in (9, 40):
            pos = np.abs(_x(s)) + 0.1
            _check(cf, cond_fn, pos, pipeline)            # true branch
            _check(cf, cond_fn, -pos, pipeline)           # false branch
        assert cf.n_compiles == 2  # branch taken is never a bucket key


# --------------------------------------------------------------- nested --


def nested_fn(x):
    """A while loop inside every scan iteration."""
    def body(c, xi):
        def wcond(s):
            return s[0] < 3

        def wbody(s):
            return (s[0] + 1, s[1] + xi.sum())

        _, acc = lax.while_loop(wcond, wbody, (jnp.int32(0), c))
        return acc, acc

    c, ys = lax.scan(body, jnp.float32(0.0), x)
    return ys


class TestNestedRegions:
    @pytest.mark.parametrize("pipeline", PIPELINES)
    def test_while_inside_scan(self, pipeline):
        cf = _compile(nested_fn, pipeline=pipeline)
        for s in (6, 13, 37):
            _check(cf, nested_fn, _x(s), pipeline)
        assert cf.n_compiles == 2


# ---------------------------------------------------- execution surfaces --


class TestExecutionSurfaces:
    def test_vm_executes_region_ops(self):
        """The NimbleVM baseline interprets region ops through the same
        emit_region_op as codegen (exact shapes, no masking needed)."""
        cf = _compile(scan_carry_fn)
        x = _x(11)
        cf(x)  # force lowering
        vm = NimbleVM(cf.lower().graph, sync_per_op=False)
        (got,) = vm(x)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(scan_carry_fn(jnp.asarray(x))),
            rtol=1e-5)

    def test_dispatch_source_names_regions(self):
        """The generated dispatch advertises its region ops and the
        bucket-on-entry policy — the artifact is self-describing."""
        cf = _compile(nested_fn)
        cf(_x(5))
        src = cf.dispatch_source
        assert "region ops" in src
        assert "d.scan(body=" in src
        assert "entry shapes only" in src

    def test_region_attrs_fingerprint_is_shape_free(self):
        """Two lowerings of the same control-flow function share one
        shape-free fingerprint (bucketed artifacts are reusable)."""
        a = _compile(scan_ys_fn)
        b = _compile(scan_ys_fn)
        a(_x(5)), b(_x(20))
        assert a.lower().graph.fingerprint() == b.lower().graph.fingerprint()


# ------------------------------------------------------- carry widening --


class TestCarryWidening:
    def _store(self):
        return ShapeConstraintStore()

    def test_identity_rewrite_unifies_without_widening(self):
        """(S-1)+1 is provably S at two evaluation points: the carry dim
        unifies with the entry dim, no fresh symbol."""
        store = self._store()
        S = fresh_symdim("S", 37)
        t1 = fresh_symdim("S-1", 36)
        t2 = fresh_symdim("(S-1)+1", 37)
        de = {t1.uid: ("affine", S, 1, -1), t2.uid: ("affine", t1, 1, 1)}
        out = carry_fixed_point(store, de, (S, 4), (t2, 4))
        assert out == (S, 4)
        assert store.dims_equal(S, t2)

    def test_varying_dim_with_cap_widens_to_bounded_symbol(self):
        store = self._store()
        S = fresh_symdim("S", 41)
        g = fresh_symdim("S+1", 42)
        de = {g.uid: ("affine", S, 1, 1)}
        out = carry_fixed_point(store, de, (S, 4), (g, 4),
                                bounds={"S": 64})
        w = out[0]
        assert w.uid not in (S.uid, g.uid)   # fresh symbol
        assert store.dim_bound(w) == 64      # carries the declared cap
        # both the entry and the out dim unified into the widened symbol
        assert store.dims_equal(S, w) and store.dims_equal(g, w)

    def test_varying_dim_without_cap_raises(self):
        store = self._store()
        S = fresh_symdim("S", 43)
        g = fresh_symdim("S+1", 44)
        de = {g.uid: ("affine", S, 1, 1)}
        with pytest.raises(ConstraintViolation,
                           match="changes across loop iterations"):
            carry_fixed_point(store, de, (S, 4), (g, 4))

    def test_rank_mismatch_raises(self):
        store = self._store()
        S = fresh_symdim("S", 47)
        with pytest.raises(ConstraintViolation):
            carry_fixed_point(store, {}, (S, 4), (S,))

    def test_concrete_mismatch_raises(self):
        with pytest.raises(ConstraintViolation):
            carry_fixed_point(self._store(), {}, (8, 4), (9, 4))

    def test_note_dim_bound_tightest_wins_across_union(self):
        store = self._store()
        a = fresh_symdim("A", 37)
        b = fresh_symdim("B", 37)
        store.note_dim_bound(a, 128)
        store.note_dim_bound(b, 64)
        store.assert_dim_eq(a, b)
        assert store.dim_bound(a) == 64 and store.dim_bound(b) == 64


# ------------------------------------------- unsupported higher-order ops --


class TestUnsupportedPrimitive:
    def test_named_error_for_higher_order_primitive(self):
        def f(x):
            mv = lambda v: 2.0 * v
            return lax.custom_linear_solve(mv, x.sum(axis=0),
                                           lambda m, b: b / 2.0)

        with pytest.raises(UnsupportedPrimitiveError,
                           match="custom_linear_solve"):
            _compile(f, spec=((Dim("S", max=32), 4),))

    def test_error_is_a_not_implemented_error(self):
        # callers that previously caught NotImplementedError keep working
        assert issubclass(UnsupportedPrimitiveError, NotImplementedError)


# ----------------------------------------------------- property fuzzing --


_FUZZ_CF = {}


def _fuzz_artifact(pipeline):
    if pipeline not in _FUZZ_CF:
        _FUZZ_CF[pipeline] = _compile(scan_ys_fn, pipeline=pipeline)
    return _FUZZ_CF[pipeline]


class TestShapeFuzz:
    @settings(max_examples=12, deadline=None)
    @given(s=st.integers(min_value=1, max_value=63))
    def test_scan_parity_any_size(self, s):
        cf = _fuzz_artifact("dhlo")
        _check(cf, scan_ys_fn, _x(int(s)), "dhlo")
        # pow2 policy over 1..63 -> at most 3 buckets (16/32/64)
        assert cf.n_compiles <= 3

    @settings(max_examples=8, deadline=None)
    @given(s=st.integers(min_value=1, max_value=63))
    def test_scan_parity_any_size_jit(self, s):
        cf = _fuzz_artifact("jit")
        _check(cf, scan_ys_fn, _x(int(s)), "jit")
        assert cf.n_compiles <= 3
