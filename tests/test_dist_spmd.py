"""The SPMD subsystem: profiles, planner, mesh-aware dispatch, replicas.

Runs on a forced multi-device host platform (conftest sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` unless the
environment already pins XLA_FLAGS); tests that need >1 device skip
below that.

Covers the contracts the issue names:

* sharded-vs-unsharded numerical parity across >=2 buckets for the
  ``dp`` / ``fsdp`` / ``tp`` profiles, on both pipelines;
* mesh-divisible bucket constraint enforcement: a ``Dim`` whose contract
  cannot be tightened (``bucket="exact"``, non-divisible ``max``) raises
  at ``lower()`` time, and tightened policies produce only mesh-divisible
  buckets;
* compile-count parity under a mesh (sharding never adds compiles);
* replica routing order + replicated-vs-single generation parity.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

import disc
from repro.core.constraints import ConstraintViolation
from repro.dist import (DP_AXES, ShardingProfile, fit_spec, get_profile,
                        maybe_shard, use_mesh)
from repro.launch.mesh import make_mesh

N_DEV = len(jax.devices())

needs2 = pytest.mark.skipif(N_DEV < 2, reason="needs >=2 devices")
needs4 = pytest.mark.skipif(N_DEV < 4, reason="needs >=4 devices")


def _mesh_2d():
    """A (data, model) mesh using as many devices as the platform has."""
    if N_DEV >= 8:
        shape = (4, 2)
    elif N_DEV >= 4:
        shape = (2, 2)
    elif N_DEV >= 2:
        shape = (2, 1)
    else:
        shape = (1, 1)
    return make_mesh(shape, ("data", "model"))


def _fn(w1, w2, x):
    return jax.nn.relu(x @ w1) @ w2


def _specs(**dim_kw):
    return [(16, 32), (32, 8),
            (disc.Dim("B", max=64, **dim_kw), 16)]


def _weights(seed=0):
    rng = np.random.RandomState(seed)
    return (rng.randn(16, 32).astype(np.float32),
            rng.randn(32, 8).astype(np.float32))


GRANULE1 = disc.BucketPolicy(kind="pow2", granule=1)


# --------------------------------------------------------------- factory --

class TestMakeMesh:
    def test_general_factory(self):
        mesh = make_mesh((N_DEV,), ("data",))
        assert dict(mesh.shape) == {"data": N_DEV}

    @needs4
    def test_2d_shape(self):
        mesh = make_mesh((2, 2), ("data", "model"))
        assert dict(mesh.shape) == {"data": 2, "model": 2}

    def test_shape_axes_mismatch(self):
        with pytest.raises(ValueError, match="axis names"):
            make_mesh((2, 2), ("data",))

    def test_too_few_devices(self):
        with pytest.raises(RuntimeError, match="force"):
            make_mesh((N_DEV + 1,), ("data",))

    def test_production_preset_uses_factory(self):
        # 256-device floor still enforced by the preset, not the factory
        if N_DEV >= 256:
            pytest.skip("platform actually has a production mesh")
        with pytest.raises(RuntimeError):
            from repro.launch.mesh import make_production_mesh
            make_production_mesh()


# ----------------------------------------------------------- maybe_shard --

class TestMaybeShardRank:
    @needs2
    def test_overlong_spec_truncates_with_warning(self):
        # regression: a spec longer than the array rank used to fall into
        # the blanket except and silently skip sharding; now it truncates
        mesh = make_mesh((N_DEV,), ("data",))
        x = jnp.ones((N_DEV, 4))
        with use_mesh(mesh):
            with pytest.warns(UserWarning, match="truncating"):
                y = maybe_shard(x, P("data", None, "model"))
        assert np.allclose(np.asarray(y), np.asarray(x))
        assert "data" in str(y.sharding)

    def test_no_warning_on_matching_rank(self):
        mesh = make_mesh((1,), ("data",))
        x = jnp.ones((4, 4))
        with use_mesh(mesh):
            with warnings.catch_warnings():
                warnings.simplefilter("error")
                maybe_shard(x, P("data", None))


# -------------------------------------------------------------- profiles --

class TestProfiles:
    def test_builtins_resolve(self):
        for name in ("dp", "fsdp", "tp"):
            assert get_profile(name).name == name
        prof = get_profile("dp")
        assert get_profile(prof) is prof

    def test_unknown_profile(self):
        with pytest.raises(ValueError, match="unknown sharding profile"):
            get_profile("zz")
        with pytest.raises(ValueError, match="unknown sharding profile"):
            disc.CompileOptions(mesh=make_mesh((1,), ("data",)),
                                sharding_profile="zz")

    def test_profile_without_mesh_rejected(self):
        with pytest.raises(ValueError, match="needs a mesh"):
            disc.CompileOptions(sharding_profile="dp")

    def test_dim_axes(self):
        assert get_profile("dp").axes_for_dim("B") == DP_AXES
        assert get_profile("dp").axes_for_dim("S") is None
        custom = get_profile("dp").replace(
            name="sp", dim_axes=(("S", ("model",)),))
        assert custom.axes_for_dim("S") == ("model",)

    def test_param_layouts(self):
        shape = (16, 32)
        assert get_profile("dp").leaf_spec(shape) == P(None, None)
        assert get_profile("fsdp").leaf_spec(shape) == \
            P(None, ("pod", "data", "model"))  # folds onto the larger dim
        assert get_profile("tp").leaf_spec(shape) == P(None, "model")


# ------------------------------------------------------ sharded dispatch --

class TestShardedDispatchParity:
    @pytest.mark.parametrize("profile", ["dp", "fsdp", "tp"])
    def test_dhlo_parity_two_buckets(self, profile):
        mesh = _mesh_2d()
        w1, w2 = _weights()
        base = disc.compile(_fn, specs=_specs(),
                            options=disc.CompileOptions(policy=GRANULE1))
        sh = disc.compile(_fn, specs=_specs(),
                          options=disc.CompileOptions(
                              policy=GRANULE1, mesh=mesh,
                              sharding_profile=profile))
        for b in (5, 33):  # two distinct buckets
            x = np.random.randn(b, 16).astype(np.float32)
            np.testing.assert_allclose(
                np.asarray(base(w1, w2, x)), np.asarray(sh(w1, w2, x)),
                atol=1e-5, rtol=1e-5)

    @pytest.mark.parametrize("profile", ["dp", "fsdp", "tp"])
    def test_jit_parity_two_buckets(self, profile):
        mesh = _mesh_2d()
        w1, w2 = _weights()
        opts = dict(pipeline="jit", policy=GRANULE1)
        base = disc.compile(_fn, specs=[None, None,
                                        (disc.Dim("B", max=64), 16)],
                            options=disc.CompileOptions(**opts))
        sh = disc.compile(_fn, specs=[None, None,
                                      (disc.Dim("B", max=64), 16)],
                          options=disc.CompileOptions(
                              mesh=mesh, sharding_profile=profile, **opts))
        for b in (5, 33):
            x = np.random.randn(b, 16).astype(np.float32)
            # jit-pipeline outputs stay padded (lens-aware contract) and
            # bucket sizes may differ under the tightened policy: compare
            # the true rows
            np.testing.assert_allclose(
                np.asarray(base(jnp.asarray(w1), jnp.asarray(w2), x))[:b],
                np.asarray(sh(jnp.asarray(w1), jnp.asarray(w2), x))[:b],
                atol=1e-5, rtol=1e-5)

    @needs2
    def test_padded_buckets_actually_sharded(self):
        """The generated dispatch device_puts the padded bucket onto the
        mesh: the emitted source contains the put, the plan's sharding is
        the data-parallel one, and the result is correct."""
        mesh = make_mesh((N_DEV,), ("data",))
        fn = disc.compile(lambda x: x * 2.0,
                          specs=[(disc.Dim("B", max=64), 4)],
                          options=disc.CompileOptions(
                              pipeline="jit", policy=GRANULE1, mesh=mesh,
                              sharding_profile="dp"))
        out = fn(np.ones((3, 4), np.float32))
        np.testing.assert_allclose(np.asarray(out)[:3], 2.0)
        assert "_put0(" in fn.dispatch_source
        assert fn.lower().sharding_plan.arg_sharding(0).spec == \
            P("data", None)

    def test_report_shows_shardings_and_constraints(self):
        mesh = _mesh_2d()
        sh = disc.compile(_fn, specs=_specs(),
                          options=disc.CompileOptions(
                              policy=GRANULE1, mesh=mesh,
                              sharding_profile="dp"))
        rep = sh.report()
        assert rep["sharding"]["profile"] == "dp"
        assert rep["sharding"]["per_arg"][2] == "PartitionSpec('data', None)"
        dp = int(mesh.shape["data"])
        if dp > 1:
            [c] = rep["sharding"]["constraints"]
            assert c == {"dim": "B", "axes": ["data"], "multiple_of": dp}
            # surfaced in the dhlo constraint store too
            assert rep["constraints"]["mesh_constraints"] == 1
        assert rep["placement"]["device_target"].startswith("mesh(")

    def test_compile_count_parity_under_mesh(self):
        # with the default granule-16 policy (mesh axes divide 16) the
        # tightening is a no-op, so sharding adds ZERO compiles
        mesh = _mesh_2d()
        w1, w2 = _weights()
        calls = [3, 5, 17, 33, 40, 33]

        def run(options):
            fn = disc.compile(_fn, specs=_specs(), options=options)
            for b in calls:
                fn(w1, w2, np.random.randn(b, 16).astype(np.float32))
            return fn.compile_counts()

        base = run(disc.CompileOptions())
        shard = run(disc.CompileOptions(mesh=mesh, sharding_profile="dp"))
        assert shard == base
        assert shard["bucket"] == 3  # 16, 32, 64

    def test_tightened_granule_merges_never_splits(self):
        mesh = _mesh_2d()
        w1, w2 = _weights()
        calls = [3, 5, 9, 33, 40, 33]

        def run(options):
            fn = disc.compile(_fn, specs=_specs(), options=options)
            for b in calls:
                fn(w1, w2, np.random.randn(b, 16).astype(np.float32))
            return fn.compile_counts()

        base = run(disc.CompileOptions(policy=GRANULE1))
        shard = run(disc.CompileOptions(policy=GRANULE1, mesh=mesh,
                                        sharding_profile="dp"))
        assert shard["total"] <= base["total"]
        assert shard["bucket"] >= 1

    def test_legacy_backend_rejected_under_mesh(self):
        # a backend whose build_bucket predates the SPMD contract fails
        # loudly at bucket-compile time, not with a far-away sharding
        # mismatch at the AOT call
        from repro.api.backends import Backend, register_backend
        legacy = Backend(
            name="legacy",
            build_bucket=lambda graph, plan, syms, padded, donate: None,
            build_exact=lambda graph, plan: None)
        register_backend("legacy-spmd-test", legacy, overwrite=True)
        fn = disc.compile(_fn, specs=_specs(),
                          options=disc.CompileOptions(
                              mesh=_mesh_2d(), sharding_profile="dp",
                              backend="legacy-spmd-test"))
        w1, w2 = _weights()
        with pytest.raises(ValueError, match="arg_shardings"):
            fn(w1, w2, np.random.randn(5, 16).astype(np.float32))

    def test_mesh_artifacts_never_share_cache_entries(self):
        # same fn + same specs + one shared CompileCache, meshless vs
        # meshed: the fingerprints must differ or the shared cache would
        # serve wrongly-sharded executables
        mesh = _mesh_2d()
        base = disc.compile(_fn, specs=_specs())
        sh = disc.compile(_fn, specs=_specs(),
                          options=disc.CompileOptions(
                              mesh=mesh, sharding_profile="fsdp"))
        assert base.lower().fingerprint() != sh.lower().fingerprint()

    @needs2
    def test_same_shape_different_devices_distinct_fingerprints(self):
        # two same-SHAPE meshes over disjoint device sets compile
        # incompatible executables: device identity is in the token
        devs = jax.devices()
        mesh_a = make_mesh((1,), ("data",), devices=devs[:1])
        mesh_b = make_mesh((1,), ("data",), devices=devs[1:2])
        fps = [disc.compile(_fn, specs=_specs(),
                            options=disc.CompileOptions(
                                mesh=m, sharding_profile="dp")
                            ).lower().fingerprint()
               for m in (mesh_a, mesh_b)]
        assert fps[0] != fps[1]

    @needs2
    def test_escalation_under_mesh(self):
        mesh = make_mesh((N_DEV,), ("data",))
        w1, w2 = _weights()
        fn = disc.compile(_fn, specs=_specs(),
                          options=disc.CompileOptions(
                              policy=GRANULE1, mesh=mesh,
                              sharding_profile="dp",
                              escalation_threshold=2))
        x = np.random.randn(7, 16).astype(np.float32)  # 7 % N_DEV != 0
        ref = None
        for _ in range(3):
            out = np.asarray(fn(w1, w2, x))
            if ref is None:
                ref = out
            np.testing.assert_allclose(out, ref, atol=1e-6)
        assert fn.compile_counts()["exact"] == 1
        assert fn.cache_stats()["escalations"] == 1


# ---------------------------------------------------- bucket constraints --

class TestMeshDivisibleBuckets:
    @needs2
    def test_policy_tightened_to_axis_multiple(self):
        mesh = make_mesh((N_DEV,), ("data",))
        fn = disc.compile(_fn, specs=_specs(),
                          options=disc.CompileOptions(
                              policy=GRANULE1, mesh=mesh,
                              sharding_profile="dp"))
        low = fn.lower()
        for v in (1, 3, 5, 17, 33):
            assert low.policy.bucket("B", v) % N_DEV == 0

    @needs2
    def test_exact_bucket_raises_at_lower(self):
        mesh = make_mesh((N_DEV,), ("data",))
        with pytest.raises(ConstraintViolation, match="exact"):
            disc.compile(_fn, specs=_specs(bucket="exact"),
                         options=disc.CompileOptions(
                             mesh=mesh, sharding_profile="dp"))

    @needs2
    def test_non_divisible_max_raises_at_lower(self):
        mesh = make_mesh((N_DEV,), ("data",))
        with pytest.raises(ConstraintViolation, match="max"):
            disc.compile(
                _fn, specs=[(16, 32), (32, 8),
                            (disc.Dim("B", max=N_DEV + 1), 16)],
                options=disc.CompileOptions(mesh=mesh,
                                            sharding_profile="dp"))

    @needs2
    def test_unsharded_dim_unconstrained(self):
        # "S" is not in the dp profile's dim_axes: exact bucketing stays
        # legal and no constraint is recorded for it
        mesh = make_mesh((N_DEV,), ("data",))
        fn = disc.compile(
            lambda x: x * 2.0,
            specs=[(disc.Dim("B", max=64),
                    disc.Dim("S", bucket="exact", max=16))],
            options=disc.CompileOptions(pipeline="jit", mesh=mesh,
                                        sharding_profile="dp"))
        dims = {c["dim"] for c in
                fn.lower().sharding_plan.report()["constraints"]}
        assert dims == {"B"}

    @needs4
    def test_fit_spec_drops_non_dividing_axes(self):
        mesh = make_mesh((2, 2), ("data", "model"))
        assert fit_spec((6, 7), P("data", "model"), mesh) == \
            P("data", None)
        assert fit_spec((5,), P(("pod", "data")), mesh) == P(None)
        assert fit_spec((6,), P(("pod", "data")), mesh) == P("data")


# ---------------------------------------------------------------- serve --

def _tiny_model():
    import dataclasses as dc
    from repro.configs import get_config
    from repro.models.registry import get_model
    cfg = dc.replace(get_config("tinyllama_11b").reduced(),
                     n_layers=2, vocab=128)
    model = get_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _requests(vocab, plens, max_new=3):
    from repro.data.pipeline import Request
    rng = np.random.RandomState(7)
    return [Request(rid=i, tokens=rng.randint(
        0, vocab, size=pl).astype(np.int32), max_new_tokens=max_new)
        for i, pl in enumerate(plens)]


class TestReplicatedServe:
    def test_routing_order_least_loaded(self):
        from disc import ServeConfig, ServeEngine
        cfg, model, params = _tiny_model()
        eng = ServeEngine(model, params,
                          ServeConfig(max_batch=2, max_seq=64, replicas=2))
        eng.submit(_requests(cfg.vocab, [8, 8, 8, 8]))
        eng._admit()
        # FIFO order, least-loaded routing: r0 gets rid 0, r1 gets rid 1
        # (now equal load -> lowest index), r0 gets 2, r1 gets 3
        placed = {i: s.rid for i, s in enumerate(eng.slots)
                  if s is not None}
        assert placed == {0: 0, 1: 2, 2: 1, 3: 3}
        eng._refresh_stats()
        per = eng.stats["per_replica"]
        assert [p["admitted"] for p in per] == [2, 2]
        assert [p["occupied_slots"] for p in per] == [2, 2]

    def test_generation_parity_with_single(self):
        from disc import ServeConfig, ServeEngine
        cfg, model, params = _tiny_model()
        reqs = lambda: _requests(cfg.vocab, [9, 5, 12, 7, 6, 10])
        e1 = ServeEngine(model, params,
                         ServeConfig(max_batch=2, max_seq=64))
        e1.submit(reqs())
        e2 = ServeEngine(model, params,
                         ServeConfig(max_batch=2, max_seq=64, replicas=3))
        e2.submit(reqs())
        assert e1.run_until_done() == e2.run_until_done()
        per = e2.stats["per_replica"]
        assert sum(p["requests_completed"] for p in per) == 6
        assert sum(p["tokens_generated"] for p in per) == \
            e2.stats["tokens_generated"]

    @needs2
    def test_mesh_serve_parity(self):
        from disc import ServeConfig, ServeEngine
        cfg, model, params = _tiny_model()
        # one data shard per replica: 2 replicas x max_batch 2 = 4 slots
        # over a 2-way data axis
        mesh = make_mesh((2,), ("data",))
        reqs = lambda: _requests(cfg.vocab, [9, 5, 12, 7])
        e1 = ServeEngine(model, params,
                         ServeConfig(max_batch=2, max_seq=64, replicas=2))
        e1.submit(reqs())
        e2 = ServeEngine(model, params,
                         ServeConfig(max_batch=2, max_seq=64, replicas=2,
                                     mesh=mesh, sharding_profile="dp"))
        e2.submit(reqs())
        assert e1.run_until_done() == e2.run_until_done()
        rep = e2._prefill_fn.report()
        assert rep["sharding"]["profile"] == "dp"
        assert any(c["dim"] == "B"
                   for c in rep["sharding"]["constraints"])
        # the sharded KV cache stays partitioned along data
        leaf = jax.tree.leaves(e2.cache)[0]
        assert "data" in str(leaf.sharding.spec)

    @needs2
    def test_tp_profile_honors_model_cache_layout(self):
        # param_mode "tp": the KV cache follows model.cache_specs()
        # (heads/sequence on "model"), not the batch-only heuristic
        from disc import ServeConfig, ServeEngine
        cfg, model, params = _tiny_model()
        # a real (size>1) model axis: a trivial axis would be
        # canonicalized out of the shardings
        mesh = (make_mesh((2, 2), ("data", "model")) if N_DEV >= 4
                else make_mesh((1, 2), ("data", "model")))
        reqs = lambda: _requests(cfg.vocab, [9, 5, 12])
        e1 = ServeEngine(model, params,
                         ServeConfig(max_batch=2, max_seq=64, replicas=2))
        e1.submit(reqs())
        e2 = ServeEngine(model, params,
                         ServeConfig(max_batch=2, max_seq=64, replicas=2,
                                     mesh=mesh, sharding_profile="tp"))
        leaf_specs = [str(c.sharding.spec)
                      for c in jax.tree.leaves(e2.cache)]
        assert any("model" in s for s in leaf_specs), leaf_specs
        if N_DEV >= 4:
            assert any("data" in s for s in leaf_specs), leaf_specs
        e2.submit(reqs())
        assert e1.run_until_done() == e2.run_until_done()

    @needs2
    def test_mesh_slot_divisibility_checked(self):
        from disc import ServeConfig, ServeEngine
        cfg, model, params = _tiny_model()
        mesh = make_mesh((N_DEV,), ("data",))
        with pytest.raises(ValueError, match="divide"):
            ServeEngine(model, params,
                        ServeConfig(max_batch=1, max_seq=64,
                                    replicas=N_DEV + 1, mesh=mesh,
                                    sharding_profile="dp"))

    def test_replicas_validated(self):
        from disc import ServeConfig, ServeEngine
        cfg, model, params = _tiny_model()
        with pytest.raises(ValueError, match="replica"):
            ServeEngine(model, params, ServeConfig(replicas=0))

    def test_profile_without_mesh_rejected(self):
        # mirror CompileOptions: no silent single-device fallback
        from disc import ServeConfig, ServeEngine
        cfg, model, params = _tiny_model()
        with pytest.raises(ValueError, match="needs a mesh"):
            ServeEngine(model, params,
                        ServeConfig(sharding_profile="fsdp"))

    @needs2
    def test_custom_profile_batch_axes_drive_engine_layout(self):
        # the engine's cache layout / divisibility guard follow the
        # PROFILE's batch axes, not a hardcoded DP set
        from disc import ServeConfig, ServeEngine, get_profile
        cfg, model, params = _tiny_model()
        mesh = make_mesh((2,), ("model",))  # no data axis at all
        prof = get_profile("dp").replace(name="mp",
                                         dim_axes=(("B", ("model",)),))
        eng = ServeEngine(model, params,
                          ServeConfig(max_batch=2, max_seq=64, replicas=2,
                                      mesh=mesh, sharding_profile=prof))
        assert eng._dp_axes == ("model",)
        leaf = jax.tree.leaves(eng.cache)[0]
        assert "model" in str(leaf.sharding.spec)
        with pytest.raises(ValueError, match="divide"):
            ServeEngine(model, params,
                        ServeConfig(max_batch=1, max_seq=64, replicas=3,
                                    mesh=mesh, sharding_profile=prof))
