"""Hypothesis property tests on system invariants.

* random elementwise/reduce programs: disc.compile artifact (bucket-padded, masked)
  output == direct jax execution at arbitrary shapes;
* buffer plan safety: no two simultaneously-live values share a slot;
* constraint store: equality is a congruence (symmetric/transitive,
  refines through size classes);
* packing: mask/segment invariants under random length distributions.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.api import ArgSpec, bridge, compile as disc_compile
from repro.core.buffers import liveness, plan_buffers
from repro.core.constraints import ShapeConstraintStore
from repro.core.symshape import fresh_symdim
from repro.data.pipeline import pack_sequences

# ---- random program generator ------------------------------------------
_UNARY = [jnp.tanh, jnp.exp, lambda x: x * 0.5, jnp.abs,
          jax.nn.sigmoid, lambda x: x + 1.0]
_BINARY = [jnp.add, jnp.subtract, jnp.multiply, jnp.maximum]


def _random_program(seed: int, depth: int, with_reduce: bool):
    # the op plan is drawn ONCE here — fn must be pure (trace == run)
    rng = np.random.RandomState(seed)
    plan = []
    n_vals = 2
    for _ in range(depth):
        if rng.rand() < 0.5:
            plan.append(("u", rng.randint(len(_UNARY)), rng.randint(n_vals)))
        else:
            plan.append(("b", rng.randint(len(_BINARY)),
                         rng.randint(n_vals), rng.randint(n_vals)))
        n_vals += 1
    red = (int(rng.randint(2)), bool(rng.rand() < 0.5)) if with_reduce else None

    def fn(x, y):
        vals = [x, y]
        for step in plan:
            if step[0] == "u":
                vals.append(_UNARY[step[1]](vals[step[2]]))
            else:
                vals.append(_BINARY[step[1]](vals[step[2]], vals[step[3]]))
        out = vals[-1] + vals[-2]
        if red is not None:
            ax, use_sum = red
            return out.sum(axis=ax) if use_sum else out.max(axis=ax)
        return out

    return fn


class TestEngineEqualsReferenceOnRandomPrograms:
    @settings(max_examples=12, deadline=None)
    @given(seed=st.integers(0, 10_000),
           depth=st.integers(1, 6),
           with_reduce=st.booleans(),
           b=st.integers(1, 24), s=st.integers(1, 24),
           dseed=st.integers(0, 2**31 - 1))
    def test_random_program(self, seed, depth, with_reduce, b, s, dseed):
        fn = _random_program(seed, depth, with_reduce)
        eng = disc_compile(fn, [ArgSpec(("B", "S")), ArgSpec(("B", "S"))],
                           name=f"prop{seed}")
        rng = np.random.RandomState(dseed)
        x = rng.randn(b, s).astype(np.float32)
        y = rng.randn(b, s).astype(np.float32)
        got = eng(x, y)
        want = fn(jnp.asarray(x), jnp.asarray(y))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=5e-4, atol=5e-5)


class TestBufferPlanSafety:
    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000), depth=st.integers(2, 8))
    def test_no_live_overlap(self, seed, depth):
        fn = _random_program(seed, depth, with_reduce=True)
        graph, _ = bridge(fn, [ArgSpec(("B", "S")), ArgSpec(("B", "S"))])
        plan = plan_buffers(graph)
        spans = liveness(graph)
        by_slot = {}
        for vid, slot in plan.slot_of.items():
            by_slot.setdefault(slot, []).append(spans[vid])
        for slot, intervals in by_slot.items():
            intervals.sort()
            for (d1, l1), (d2, l2) in zip(intervals, intervals[1:]):
                # a later tenant may not be defined before the earlier died
                assert d2 > l1, f"slot {slot}: [{d1},{l1}] overlaps [{d2},{l2}]"
        assert plan.n_slots <= plan.n_values


class TestConstraintCongruence:
    @settings(max_examples=25, deadline=None)
    @given(pairs=st.lists(
        st.tuples(st.integers(0, 7), st.integers(0, 7)), max_size=12))
    def test_equality_is_equivalence(self, pairs):
        store = ShapeConstraintStore()
        dims = [fresh_symdim(f"d{i}") for i in range(8)]
        for a, b in pairs:
            store.assert_dim_eq(dims[a], dims[b])
        # reflexive, symmetric, transitive under the asserted closure
        for a, b in pairs:
            assert store.dims_equal(dims[a], dims[b])
            assert store.dims_equal(dims[b], dims[a])
        for a, b in pairs:
            for c, d in pairs:
                if b == c:
                    assert store.dims_equal(dims[a], dims[d])

    @settings(max_examples=25, deadline=None)
    @given(v=st.integers(1, 4096), g=st.sampled_from([8, 16, 64]))
    def test_refined_size_classes(self, v, g):
        store = ShapeConstraintStore()
        m, n = fresh_symdim("M"), fresh_symdim("N")
        store.note_value_size(1, (m, g))
        store.note_value_size(2, (n, g))
        store.assert_dim_eq(m, v)
        store.assert_dim_eq(n, v)
        assert store.sizes_equal(1, 2)  # both refined to v*g


class TestPackingProperties:
    @settings(max_examples=25, deadline=None)
    @given(lens=st.lists(st.integers(1, 48), min_size=1, max_size=30),
           seed=st.integers(0, 2**31 - 1))
    def test_mask_and_segments(self, lens, seed):
        rng = np.random.RandomState(seed)
        seqs = [rng.randint(1, 99, size=l).astype(np.int32) for l in lens]
        tokens, segs, mask = pack_sequences(seqs, seq_len=48)
        assert int(mask.sum()) == sum(min(l, 48) for l in lens)
        # every packed token is recoverable and non-pad where masked
        assert ((segs > 0) == (mask > 0)).all()
        # rows never exceed capacity
        assert tokens.shape[1] == 48
