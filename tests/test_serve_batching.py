"""Serve-path batching tests.

Covers the continuous-batching serve path end to end: single-pass batched
prefill parity against the sequential replay baseline (model- and
engine-level, across ≥2 (batch, seq) buckets), chunked prefill vs
unchunked, admission-policy ordering, O(#(B, S) buckets) compile counts
under varying batch composition, §4.4 escalation on the batched artifact,
and the ``TreeSpec`` pytree padding it rides on.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import disc
from repro.configs import get_config
from repro.data.pipeline import Request
from repro.models.registry import get_model, replay_prefill
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.policies import (ADMISSION_POLICIES, get_admission_policy,
                                  priority_first, shortest_prompt_first)


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tinyllama_11b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests(vocab, lens, max_new=4, prios=None):
    rng = np.random.RandomState(7)
    return [Request(rid=i,
                    tokens=rng.randint(0, vocab, size=ln).astype(np.int32),
                    max_new_tokens=max_new,
                    priority=0 if prios is None else prios[i])
            for i, ln in enumerate(lens)]


def _engine(model, params, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_seq", 96)
    return ServeEngine(model, params, ServeConfig(**kw))


# ----------------------------------------------------------------- parity --

class TestPrefillParity:
    @pytest.mark.parametrize("lens_set", [[5, 12, 16], [33, 20, 40]])
    def test_single_pass_matches_replay_model_level(self, tiny, lens_set):
        """model.prefill ≡ decode-step replay: logits and every valid
        cache position, across two different (B, S) shapes."""
        cfg, model, params = tiny
        b, smax = len(lens_set), max(lens_set)
        rng = np.random.RandomState(1)
        tokens = np.zeros((b, smax), np.int32)
        for r, ln in enumerate(lens_set):
            tokens[r, :ln] = rng.randint(0, cfg.vocab, size=ln)
        tokens = jnp.asarray(tokens)
        lens = jnp.asarray(lens_set, jnp.int32)
        offsets = jnp.zeros((b,), jnp.int32)
        cache0 = model.init_cache(b, 96)

        log_sp, cache_sp = model.prefill(params, cache0, tokens, lens,
                                         offsets)
        log_rp, cache_rp = replay_prefill(model.decode_step)(
            params, cache0, tokens, lens, offsets)

        np.testing.assert_allclose(np.asarray(log_sp), np.asarray(log_rp),
                                   atol=2e-4, rtol=2e-4)
        for leaf_sp, leaf_rp in zip(jax.tree.leaves(cache_sp),
                                    jax.tree.leaves(cache_rp)):
            a, c = np.asarray(leaf_sp), np.asarray(leaf_rp)
            for r, ln in enumerate(lens_set):  # (L, B, hkv, Lc, hd)
                np.testing.assert_allclose(a[:, r, :, :ln], c[:, r, :, :ln],
                                           atol=2e-4, rtol=2e-4)

    def test_engine_generations_match_replay(self, tiny):
        """End to end: same requests, same generated ids, while the
        batched engine launches strictly fewer prefills."""
        cfg, model, params = tiny
        lens = [5, 9, 14, 40, 33, 12]  # spans S buckets 16 and 64
        outs, calls = {}, {}
        for mode in ("batched", "replay"):
            eng = _engine(model, params, prefill_mode=mode)
            eng.submit(_requests(cfg.vocab, lens))
            outs[mode] = eng.run_until_done(max_steps=500)
            calls[mode] = eng.stats["prefill_calls"]
        assert outs["batched"] == outs["replay"]
        assert len(outs["batched"]) == len(lens)
        assert calls["batched"] < calls["replay"] == len(lens)

    def test_chunked_prefill_matches_unchunked(self, tiny):
        """Chunk-offset continuation reproduces the one-shot prefill, at
        the model level (explicit offsets) and through the engine."""
        cfg, model, params = tiny
        # model level: 24-token prompt in two 12-token chunks
        rng = np.random.RandomState(3)
        toks = rng.randint(0, cfg.vocab, size=(1, 24)).astype(np.int32)
        cache0 = model.init_cache(1, 96)
        one = jnp.asarray([12], jnp.int32)
        log_a, cache_a = model.prefill(
            params, cache0, jnp.asarray(toks), jnp.asarray([24], jnp.int32),
            jnp.zeros((1,), jnp.int32))
        _, cache_h = model.prefill(params, cache0, jnp.asarray(toks[:, :12]),
                                   one, jnp.zeros((1,), jnp.int32))
        log_b, cache_b = model.prefill(params, cache_h,
                                       jnp.asarray(toks[:, 12:]), one,
                                       jnp.asarray([12], jnp.int32))
        np.testing.assert_allclose(np.asarray(log_a), np.asarray(log_b),
                                   atol=2e-4, rtol=2e-4)
        for la, lb in zip(jax.tree.leaves(cache_a), jax.tree.leaves(cache_b)):
            np.testing.assert_allclose(np.asarray(la)[:, :, :, :24],
                                       np.asarray(lb)[:, :, :, :24],
                                       atol=2e-4, rtol=2e-4)

        # engine level: long prompts forced through 8-token chunks
        lens = [30, 22, 6, 17]
        base, chunked = {}, {}
        for chunk, sink in ((None, base), (8, chunked)):
            eng = _engine(model, params, prefill_chunk=chunk)
            eng.submit(_requests(cfg.vocab, lens))
            sink.update(eng.run_until_done(max_steps=500))
            if chunk:
                assert eng.stats["prefill_chunks"] > 0
        assert base == chunked


# -------------------------------------------------------------- admission --

class TestAdmission:
    def test_policy_orderings(self):
        reqs = _requests(64, [24, 6, 12], prios=[0, 1, 3])
        assert [r.rid for r in ADMISSION_POLICIES["fifo"](reqs)] == [0, 1, 2]
        assert [r.rid for r in shortest_prompt_first(reqs)] == [1, 2, 0]
        assert [r.rid for r in priority_first(reqs)] == [2, 1, 0]
        assert get_admission_policy(shortest_prompt_first) \
            is shortest_prompt_first
        with pytest.raises(ValueError, match="unknown admission policy"):
            get_admission_policy("nope")

    def test_overlong_prompt_rejected_at_submit(self, tiny):
        """A prompt longer than max_seq is rejected gracefully — counted
        in stats and recorded in ``eng.rejected`` — while the rest of the
        batch is admitted and completes."""
        cfg, model, params = tiny
        eng = _engine(model, params, max_seq=64, prefill_chunk=16)
        reqs = _requests(cfg.vocab, [65, 8, 70, 12], max_new=2)
        eng.submit(reqs)
        assert eng.stats["rejected_requests"] == 2
        assert eng.rejected == [reqs[0].rid, reqs[2].rid]
        assert [r.rid for r in eng.queue] == [reqs[1].rid, reqs[3].rid]
        eng.run_until_done(max_steps=200)
        assert sorted(eng.done) == [reqs[1].rid, reqs[3].rid]
        assert eng.stats["requests_completed"] == 2

    def test_duplicate_rid_rejected_auto_rid_admits(self, tiny):
        """rids are the engine's stable request identity: submitting a
        rid that is already pending raises, while auto-assigned rids
        (Request(rid=None)) are unique and both requests complete."""
        cfg, model, params = tiny
        eng = _engine(model, params, max_batch=2)
        a, b = _requests(cfg.vocab, [8, 8], max_new=2)
        b.rid = a.rid
        with pytest.raises(ValueError, match="already pending"):
            eng.submit([a, b])
        rng = np.random.RandomState(7)
        auto = [Request(tokens=rng.randint(0, cfg.vocab, size=8)
                        .astype(np.int32), max_new_tokens=2)
                for _ in range(2)]
        assert auto[0].rid != auto[1].rid
        eng.submit(auto)
        eng.run_until_done(max_steps=100)
        assert eng.stats["requests_completed"] == 2

    def test_paged_decode_parity_across_buckets(self, tiny):
        """Unconstrained-pool paged decode is bit-parity with the
        fixed-row baseline, across ≥2 (B, S) prefill buckets (short and
        long prompts, full and partial batches)."""
        cfg, model, params = tiny
        lens = [5, 12, 40, 60, 9, 33]
        fixed = _engine(model, params, max_batch=3, max_seq=96)
        fixed.submit(_requests(cfg.vocab, lens, max_new=4))
        fixed.run_until_done(max_steps=400)
        paged = _engine(model, params, max_batch=3, max_seq=96,
                        kv_block_size=16)
        paged.submit(_requests(cfg.vocab, lens, max_new=4))
        paged.run_until_done(max_steps=400)
        assert fixed.stats["prefill_bucket_pairs"] >= 2
        assert paged.done == fixed.done
        assert paged.stats["kv_preemptions"] == 0
        assert paged.stats["kv_blocks_in_use"] == 0
        paged.alloc.assert_consistent()

    @pytest.mark.parametrize("policy,expected", [
        ("fifo", [0, 1, 2]),
        ("shortest-prompt-first", [1, 2, 0]),
        ("priority", [2, 1, 0]),
    ])
    def test_engine_completion_order(self, tiny, policy, expected):
        """With one slot, completion order is exactly admission order."""
        cfg, model, params = tiny
        eng = _engine(model, params, max_batch=1, admission=policy)
        eng.submit(_requests(cfg.vocab, [24, 6, 12], max_new=2,
                             prios=[0, 1, 3]))
        done = eng.run_until_done(max_steps=300)
        assert list(done) == expected


# ---------------------------------------------------------- compile counts --

class TestCompileCounts:
    def test_o_buckets_across_batch_compositions(self, tiny):
        """A mixed trace re-using (B, S) buckets never recompiles; a new
        group size does — exactly once per new pair."""
        cfg, model, params = tiny
        eng = _engine(model, params)
        eng.submit(_requests(cfg.vocab, [9, 12, 14, 10]))   # (4, 16)
        eng.run_until_done(max_steps=300)
        first = eng.compile_counts()["prefill"]["bucket"]
        assert first == 1

        eng.submit(_requests(cfg.vocab, [13, 10, 15, 11]))  # (4, 16) again
        eng.run_until_done(max_steps=300)
        assert eng.compile_counts()["prefill"]["bucket"] == first

        eng.submit(_requests(cfg.vocab, [12, 12]))          # (2, 16): new B
        eng.run_until_done(max_steps=300)
        counts = eng.compile_counts()["prefill"]
        assert counts["bucket"] == first + 1
        assert counts["bucket"] == eng.stats["prefill_bucket_pairs"] == 2

    def test_escalation_on_hot_batched_signature(self, tiny):
        """§4.4 still works on the 2-D artifact: a hot exact (B, S)
        signature gets an unpadded specialization."""
        cfg, model, params = tiny
        eng = _engine(model, params, max_batch=2, max_seq=64,
                      escalation_threshold=2)
        for round_ in range(3):
            eng.submit(_requests(cfg.vocab, [7, 5], max_new=2))
            eng.run_until_done(max_steps=200)
        assert eng.stats["prefill_escalations"] >= 1
        assert eng.stats["requests_completed"] == 6

    def test_stats_keys_documented(self, tiny):
        from repro.serve.engine import STATS_KEYS
        cfg, model, params = tiny
        eng = _engine(model, params)
        assert set(eng.stats) == set(STATS_KEYS)


# --------------------------------------------------------------- TreeSpec --

class TestTreeSpec:
    def test_pads_pytree_leaves_to_bucket(self):
        seen = []

        def f(tree, x):
            seen.append((tree["a"].shape, x.shape))
            return tree["a"].sum() + x.sum()

        fn = disc.compile(
            f, specs=[disc.TreeSpec({0: "B"}),
                      disc.ArgSpec(("B", 2), jnp.float32)],
            options=disc.CompileOptions(pipeline="jit"))
        assert float(fn({"a": jnp.ones((3, 2))}, jnp.ones((3, 2)))) == 12.0
        assert seen[0] == ((16, 2), (16, 2))  # POW2 granule-16 bucket
        # in-bucket second call: padded shapes identical, no new compile
        assert float(fn({"a": jnp.ones((5, 2))}, jnp.ones((5, 2)))) == 20.0
        assert fn.compile_counts()["total"] == 1

    def test_tree_only_dim_is_rejected(self):
        with pytest.raises(ValueError, match="not observable"):
            disc.compile(lambda t: t, specs=[disc.TreeSpec({0: "B"})],
                         options=disc.CompileOptions(pipeline="jit"))
