"""H3 correctness: absorbed-MLA decode must equal prefill logits.

Note: the comparison requires a drop-free MoE capacity factor — with the
default factor, prefill routes all tokens jointly and may DROP a token at
capacity, while single-token decode steps never drop; that divergence is
inherent to capacity-based MoE (GShard token dropping), not an MLA bug
(verified by bisecting with layers.MLA_ABSORBED_DECODE=False).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

import repro.models.layers as L
from repro.configs import get_config
from repro.models.registry import get_model


def _rollout(cfg, absorbed: bool):
    prev = L.MLA_ABSORBED_DECODE
    L.MLA_ABSORBED_DECODE = absorbed
    try:
        model = get_model(cfg)
        rng = np.random.RandomState(3)
        params = model.init(jax.random.PRNGKey(0))
        toks = jnp.asarray(rng.randint(0, cfg.vocab, (1, 8)), jnp.int32)
        full = model.forward(params, {"tokens": toks})
        cache = model.init_cache(1, 16)
        lens = jnp.zeros((1,), jnp.int32)
        outs = []
        for t in range(8):
            logits, cache = model.decode_step(params, cache,
                                              toks[:, t:t + 1], lens)
            lens = lens + 1
            outs.append(logits[:, 0])
        return np.asarray(jnp.stack(outs, axis=1)), np.asarray(full)
    finally:
        L.MLA_ABSORBED_DECODE = prev


def test_deepseek_decode_matches_prefill():
    cfg = dataclasses.replace(get_config("deepseek_v2_236b").reduced(),
                              capacity_factor=8.0)  # drop-free routing
    dec, full = _rollout(cfg, absorbed=True)
    # atol 0.05: a handful of logits flip when a router tie resolves
    # differently under bf16-level perturbation of the residual stream —
    # inherent MoE sensitivity, not an attention error (8/2048 elements)
    np.testing.assert_allclose(dec, full, rtol=2e-2, atol=5e-2)


def test_absorbed_equals_expanded_decode():
    cfg = dataclasses.replace(get_config("deepseek_v2_236b").reduced(),
                              capacity_factor=8.0)
    dec_abs, _ = _rollout(cfg, absorbed=True)
    dec_exp, _ = _rollout(cfg, absorbed=False)
    np.testing.assert_allclose(dec_abs, dec_exp, rtol=2e-2, atol=5e-2)
