"""Substrate tests: data pipeline, optimizer, checkpointing, FT, serving."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import (latest_step, restore_checkpoint,
                                         save_checkpoint, wait_for_writers)
from repro.configs import get_config
from repro.data.pipeline import (SyntheticLMStream, VarLenRequestStream,
                                 pack_sequences)
from repro.ft.supervisor import ElasticPlan, HeartbeatMonitor, Supervisor
from repro.models.registry import get_model
from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.compress import compress_grads, decompress_grads
from repro.optim.schedule import cosine_schedule
from repro.serve.engine import ServeConfig, ServeEngine
from repro.train.step import TrainConfig, make_train_step, train_state_init


class TestData:
    def test_deterministic_resume(self):
        s1 = SyntheticLMStream(vocab=100, batch=4, seq_len=16, seed=3)
        b5 = s1.batch_at(5)
        s2 = SyntheticLMStream(vocab=100, batch=4, seq_len=16, seed=3)
        s2.load_state_dict({"step": 5, "seed": 3})
        b5b = s2.batch_at(5)
        np.testing.assert_array_equal(b5["tokens"], b5b["tokens"])

    def test_learnable_structure(self):
        s = SyntheticLMStream(vocab=50, batch=8, seq_len=64, seed=0)
        b = s.batch_at(0)
        # consecutive tokens follow an affine rule modulo noise
        diffs = (b["labels"] - b["tokens"]) % 50
        # per-row diffs concentrate on <= 3 values (a + noise)
        for row in diffs:
            assert len(np.unique(row)) <= 6

    def test_varlen_stream_shapes(self):
        st = VarLenRequestStream(vocab=100, min_len=4, max_len=64, seed=1)
        reqs = st.sample(20)
        lens = [len(r.tokens) for r in reqs]
        assert min(lens) >= 4 and max(lens) <= 64
        assert len(set(lens)) > 3  # actually varying

    def test_packing_no_overlap(self):
        rng = np.random.RandomState(0)
        seqs = [rng.randint(1, 90, size=rng.randint(3, 30)).astype(np.int32)
                for _ in range(20)]
        tokens, segs, mask = pack_sequences(seqs, seq_len=64)
        assert tokens.shape == segs.shape == mask.shape
        total = sum(len(s) for s in seqs)
        assert int(mask.sum()) == total
        # segments within a row are monotone non-decreasing then zero
        for row in segs:
            nz = row[row > 0]
            assert (np.diff(nz) >= 0).all()


class TestOptim:
    def test_adamw_decreases_quadratic(self):
        params = {"w": jnp.array([5.0, -3.0])}
        st = adamw_init(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, st = adamw_update(params, grads, st, lr=0.05,
                                      weight_decay=0.0)
        assert float(jnp.abs(params["w"]).max()) < 0.5

    def test_schedule_shape(self):
        assert float(cosine_schedule(0, peak_lr=1.0, warmup=10, total=100)) == 0.0
        assert float(cosine_schedule(10, peak_lr=1.0, warmup=10, total=100)) == pytest.approx(1.0)
        end = float(cosine_schedule(100, peak_lr=1.0, warmup=10, total=100))
        assert end == pytest.approx(0.1, abs=1e-3)

    def test_compression_error_feedback_unbiased(self):
        grads = {"w": jnp.asarray(np.random.RandomState(0).randn(256) * 1e-3,
                                  jnp.float32)}
        residual = None
        acc = jnp.zeros(256)
        for _ in range(50):
            wire, residual = compress_grads(grads, residual)
            acc = acc + decompress_grads(wire)["w"]
        # accumulated compressed gradient ~= accumulated true gradient
        np.testing.assert_allclose(acc, grads["w"] * 50, rtol=1e-2, atol=1e-5)

    def test_microbatch_accumulation_matches_full(self):
        cfg = get_config("tinyllama_11b").reduced()
        model = get_model(cfg)
        rng = np.random.RandomState(0)
        batch = {
            "tokens": jnp.asarray(rng.randint(0, cfg.vocab, (4, 16)), jnp.int32),
            "labels": jnp.asarray(rng.randint(0, cfg.vocab, (4, 16)), jnp.int32),
            "mask": jnp.ones((4, 16), jnp.float32),
        }
        t1 = TrainConfig(microbatches=1, peak_lr=1e-3, warmup=1)
        t2 = TrainConfig(microbatches=2, peak_lr=1e-3, warmup=1)
        s1 = train_state_init(model, jax.random.PRNGKey(0), t1)
        s2 = train_state_init(model, jax.random.PRNGKey(0), t2)
        _, m1 = make_train_step(model, t1)(s1, batch)
        _, m2 = make_train_step(model, t2)(s2, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=1e-4)


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        state = {"a": jnp.arange(12.0).reshape(3, 4),
                 "nested": {"b": jnp.ones((2,), jnp.int32)}}
        save_checkpoint(tmp_path, 7, state, journal={"data_step": 7})
        like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                            state)
        restored, journal = restore_checkpoint(tmp_path, like)
        np.testing.assert_array_equal(restored["a"], state["a"])
        assert journal["data_step"] == 7

    def test_latest_and_gc(self, tmp_path):
        state = {"x": jnp.zeros(3)}
        for s in (1, 2, 3, 4, 5):
            save_checkpoint(tmp_path, s, state, keep=2)
        assert latest_step(tmp_path) == 5
        kept = sorted(p.name for p in tmp_path.glob("step_*"))
        assert kept == ["step_4", "step_5"]

    def test_async_save(self, tmp_path):
        state = {"x": jnp.arange(5.0)}
        save_checkpoint(tmp_path, 1, state, blocking=False)
        wait_for_writers()
        assert latest_step(tmp_path) == 1

    def test_elastic_restore_relayout(self, tmp_path):
        # save "on 4 devices", restore with different sharding tree (mesh
        # change) — values must be identical
        state = {"w": jnp.arange(64.0).reshape(8, 8)}
        save_checkpoint(tmp_path, 3, state)
        like = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
        restored, _ = restore_checkpoint(tmp_path, like)
        np.testing.assert_array_equal(restored["w"], state["w"])


class TestFT:
    def test_heartbeat_death(self):
        m = HeartbeatMonitor(["h0", "h1"], deadline_s=10)
        m.beat("h0", t=100.0)
        m.beat("h1", t=100.0)
        assert m.dead_hosts(now=105.0) == []
        m.beat("h0", t=110.0)
        assert m.dead_hosts(now=115.0) == ["h1"]

    def test_straggler_detection(self):
        m = HeartbeatMonitor(["h0", "h1", "h2", "h3"])
        for i in range(10):
            for h in ("h0", "h1", "h2"):
                m.beat(h, step_seconds=1.0)
            m.beat("h3", step_seconds=3.5)
        assert m.stragglers() == ["h3"]

    def test_elastic_plan_keeps_model_axis(self):
        plan = ElasticPlan.plan(512 - 16, model=16, pod_size=256)
        assert plan.model == 16
        assert plan.data * plan.model * plan.pods <= 496
        assert plan.data & (plan.data - 1) == 0  # power of two

    def test_supervisor_remesh_flow(self, tmp_path):
        sup = Supervisor(tmp_path, hosts=[f"h{i}" for i in range(4)],
                         model_axis=16, deadline_s=5)
        t0 = 1000.0
        for h in ("h0", "h1", "h2", "h3"):
            sup.monitor.beat(h, t=t0)
        for h in ("h0", "h1", "h2"):
            sup.monitor.beat(h, t=t0 + 10)
        out = sup.check(chips_per_host=64, last_ckpt_step=42, now=t0 + 10)
        assert out is not None
        restore_step, plan = out
        assert restore_step == 42
        assert "h3" in plan.dropped_hosts
        assert plan.chips <= 3 * 64


class TestServeEngine:
    def test_end_to_end_generation_and_bucketing(self):
        cfg = get_config("tinyllama_11b").reduced()
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        eng = ServeEngine(model, params,
                          ServeConfig(max_batch=4, max_seq=96))
        stream = VarLenRequestStream(vocab=cfg.vocab, min_len=4, max_len=48,
                                     seed=0)
        reqs = stream.sample(6)
        for r in reqs:
            r.max_new_tokens = min(r.max_new_tokens, 8)
        eng.submit(reqs)
        done = eng.run_until_done(max_steps=400)
        assert set(done) == {r.rid for r in reqs}
        assert all(len(v) >= 1 for v in done.values())
        # DISC contract: prefill compiles bounded by the 2-D bucket grid
        # (admission-group size × prompt bucket), not by #requests
        lens = [len(r.tokens) for r in reqs]
        s_buckets = {min(eng.scfg.prefill_policy.bucket("S", l), 96)
                     for l in lens}
        b_buckets = {1, 2, 4}  # pow2 admission-group buckets ≤ max_batch
        pairs = eng.stats["prefill_bucket_pairs"]
        assert eng.compile_counts()["prefill"]["bucket"] <= pairs
        assert pairs <= len(s_buckets) * len(b_buckets)
        # batched admission actually happened: fewer launches than requests
        assert eng.stats["prefill_calls"] < len(reqs)
