"""Deterministic fallback for ``hypothesis`` when it is not installed.

The property tests only use ``@given`` with ``st.integers`` ranges and
``@settings(max_examples=..., deadline=None)``.  When the real library is
available it is used unchanged; otherwise this shim replays each property
over a fixed number of seeded-random samples (including the range
endpoints), which keeps the properties exercised — with reproducible
counterexamples — without adding a dependency.
"""
from __future__ import annotations

import functools

try:  # pragma: no cover - exercised only when hypothesis exists
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic replacement
    import numpy as np

    HAVE_HYPOTHESIS = False

    class _IntStrategy:
        def __init__(self, min_value: int, max_value: int) -> None:
            self.min_value = min_value
            self.max_value = max_value

        def sample(self, rng) -> int:
            # dtype=int64: ranges like (0, 2**31 - 1) overflow the default
            return int(rng.randint(self.min_value, int(self.max_value) + 1,
                                   dtype=np.int64))

        def endpoints(self):
            return (self.min_value, self.max_value)

    class _BoolStrategy:
        def sample(self, rng) -> bool:
            return bool(rng.randint(2))

        def endpoints(self):
            return (False, True)

    class _ListStrategy:
        def __init__(self, elem, min_size=0, max_size=None) -> None:
            self.elem = elem
            self.min_size = min_size
            self.max_size = max_size if max_size is not None else min_size + 10

        def sample(self, rng):
            n = int(rng.randint(self.min_size, self.max_size + 1))
            return [self.elem.sample(rng) for _ in range(n)]

        def endpoints(self):
            lo, hi = self.elem.endpoints()
            return ([lo] * self.min_size, [hi] * self.max_size)

    class _TupleStrategy:
        def __init__(self, *elems) -> None:
            self.elems = elems

        def sample(self, rng):
            return tuple(e.sample(rng) for e in self.elems)

        def endpoints(self):
            return (tuple(e.endpoints()[0] for e in self.elems),
                    tuple(e.endpoints()[1] for e in self.elems))

    class _SampledFrom:
        def __init__(self, choices) -> None:
            self.choices = list(choices)

        def sample(self, rng):
            return self.choices[int(rng.randint(len(self.choices)))]

        def endpoints(self):
            return (self.choices[0], self.choices[-1])

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> "_IntStrategy":
            return _IntStrategy(min_value, max_value)

        @staticmethod
        def booleans() -> "_BoolStrategy":
            return _BoolStrategy()

        @staticmethod
        def lists(elem, min_size=0, max_size=None) -> "_ListStrategy":
            return _ListStrategy(elem, min_size, max_size)

        @staticmethod
        def tuples(*elems) -> "_TupleStrategy":
            return _TupleStrategy(*elems)

        @staticmethod
        def sampled_from(choices) -> "_SampledFrom":
            return _SampledFrom(choices)

    st = _Strategies()

    def settings(max_examples: int = 20, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):

            @functools.wraps(fn)
            def run(*args):
                # read at call time: @settings is conventionally applied
                # ABOVE @given, i.e. to this wrapper, after deco ran
                n = getattr(run, "_max_examples",
                            getattr(fn, "_max_examples", 20))
                names = list(strategies)
                rng = np.random.RandomState(0xD15C)
                # corner cases first: all-min and all-max
                corner_lo = {k: s.endpoints()[0] for k, s in strategies.items()}
                corner_hi = {k: s.endpoints()[1] for k, s in strategies.items()}
                cases = [corner_lo, corner_hi]
                for _ in range(max(n - len(cases), 0)):
                    cases.append({k: strategies[k].sample(rng) for k in names})
                for case in cases:
                    try:
                        fn(*args, **case)
                    except Exception as e:
                        raise AssertionError(
                            f"property falsified with {case}: {e}") from e

            # pytest must not see the wrapped signature (it would try to
            # inject the strategy parameters as fixtures)
            del run.__wrapped__
            return run
        return deco
