"""Validate the trip-count-aware HLO cost analyzer against known programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo_cost import analyze_hlo_text


def _compiled_text(fn, *sds):
    return jax.jit(fn).lower(*sds).compile().as_text()


class TestHloCost:
    def test_scan_trip_count_multiplies_flops(self):
        def body(x, _):
            return x @ x, None

        def f(x):
            y, _ = jax.lax.scan(body, x, None, length=10)
            return y

        sds = jax.ShapeDtypeStruct((256, 256), jnp.float32)
        cost = analyze_hlo_text(_compiled_text(f, sds))
        expected = 10 * 2 * 256**3
        assert expected <= cost.flops <= expected * 1.2
        # XLA's own analysis undercounts by ~10x (the motivation)
        xla = jax.jit(f).lower(sds).compile().cost_analysis()
        if isinstance(xla, (list, tuple)):  # newer jax: one dict per program
            xla = xla[0] if xla else {}
        assert cost.flops > 5 * float(xla.get("flops", 0))

    def test_dot_flops_formula(self):
        def f(a, b):
            return a @ b

        sa = jax.ShapeDtypeStruct((64, 128), jnp.float32)
        sb = jax.ShapeDtypeStruct((128, 32), jnp.float32)
        cost = analyze_hlo_text(_compiled_text(f, sa, sb))
        expected = 2 * 64 * 32 * 128
        assert expected <= cost.flops <= expected * 1.1

    def test_nested_scans_multiply(self):
        def inner(x, _):
            return jnp.tanh(x), None

        def outer(x, _):
            y, _ = jax.lax.scan(inner, x, None, length=4)
            return y, None

        def f(x):
            y, _ = jax.lax.scan(outer, x, None, length=3)
            return y

        sds = jax.ShapeDtypeStruct((1024,), jnp.float32)
        cost = analyze_hlo_text(_compiled_text(f, sds))
        # tanh = 12 elementwise ops: at least 3*4*1024 elementwise flops
        assert cost.flops >= 3 * 4 * 1024

    def test_collectives_counted_with_loop_multiplier(self):
        import os
        if jax.device_count() < 2:
            pytest.skip("needs >1 device")

    def test_bytes_exclude_fused_internals(self):
        def f(x):
            return jnp.exp(x) * 2.0 + 1.0  # one fusion

        sds = jax.ShapeDtypeStruct((4096,), jnp.float32)
        cost = analyze_hlo_text(_compiled_text(f, sds))
        # boundary traffic ~ in + out (not 4 tensors worth)
        assert cost.bytes <= 4 * 4096 * 4
