"""Differential suite for the unified observability plane (repro.obs).

Contracts under test:

* **spans** nest with correct parenting across BOTH pipelines — a
  generated-dispatch ``dispatch`` span parents the cache's
  ``compile.bucket`` span on a miss and has no compile child on a hit;
* **one registry** — ``disc.observe()`` agrees exactly with the legacy
  accessors it absorbed (``ServeEngine.stats`` / ``report()["health"]``,
  ``Compiled.cache_stats()`` / ``cost_report()``, ``VMStats``);
* **Chrome export** — every event validates against the ``trace_event``
  schema (internal parent/depth fields stripped);
* **zero-overhead discipline** — with no tracer installed the generated
  dispatch source is byte-identical, no events are recorded, and the
  hot serve path never grows the lifecycle timeline;
* **typed reset** — ``ServeEngine.reset_stats()`` restores every stats
  key to its documented type (the old uniform ``= 0`` clobbered
  ``per_replica``'s list-of-dicts to an int);
* **one clock** — heartbeats and the obs clock are injectable and
  deterministic under a fixed source.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import disc
from repro.api import ArgSpec
from repro.configs import get_config
from repro.core.vm import NimbleVM
from repro.data.pipeline import Request
from repro.ft.supervisor import HeartbeatMonitor
from repro.models.registry import get_model
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.clock import CLOCK, Clock
from repro.serve.engine import STATS_KEYS, ServeConfig, ServeEngine


@pytest.fixture(autouse=True)
def fresh_obs():
    """Every test gets its own metrics registry (collectors registered
    by artifacts/engines built inside the test land there, isolated from
    whatever earlier tests left alive) and must not leak a tracer."""
    prev = obs_metrics.REGISTRY
    obs_metrics.REGISTRY = obs_metrics.MetricsRegistry()
    yield
    leaked = obs_trace.ACTIVE is not None
    obs_trace.clear()
    obs_metrics.REGISTRY = prev
    assert not leaked, "test left a tracer installed"


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tinyllama_11b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests(vocab, lens, max_new=3):
    rng = np.random.RandomState(7)
    return [Request(rid=i,
                    tokens=rng.randint(0, vocab, size=ln).astype(np.int32),
                    max_new_tokens=max_new)
            for i, ln in enumerate(lens)]


def _engine(model, params, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_seq", 64)
    return ServeEngine(model, params, ServeConfig(**kw))


PIPELINES = ("dhlo", "jit")


def _artifact(pipeline, name="obs_fn"):
    specs = [ArgSpec(("S", 4), jnp.float32)]
    return disc.compile(lambda x: jnp.tanh(x) * 2.0, specs,
                        options=disc.CompileOptions(pipeline=pipeline,
                                                    name=name))


# ------------------------------------------------------------- tracer ----

class TestTracer:
    def test_manual_nesting_parent_and_depth(self):
        tr = obs_trace.Tracer()
        a = tr.begin("outer")
        b = tr.begin("inner")
        tr.instant("tick")
        b.end()
        a.end(extra=1)
        outer, inner, tick = tr.events
        assert (outer["parent"], outer["depth"]) == (-1, 0)
        assert (inner["parent"], inner["depth"]) == (0, 1)
        assert (tick["parent"], tick["depth"]) == (1, 2)
        assert outer["args"] == {"extra": 1}
        assert outer["dur"] >= inner["dur"] >= 0.0

    @pytest.mark.parametrize("pipeline", PIPELINES)
    def test_dispatch_parents_compile_span(self, pipeline):
        f = _artifact(pipeline)
        with obs_trace.tracing() as tr:
            f(np.ones((3, 4), np.float32))   # miss: compile inside dispatch
            f(np.ones((3, 4), np.float32))   # hit: no compile child
        disp = tr.spans("dispatch")
        assert len(disp) == 2
        miss, hit = disp
        assert miss["args"]["cache_hit"] is False
        assert hit["args"]["cache_hit"] is True
        assert miss["args"]["bucket"] == (16,)  # pow2 floor bucket
        # pad 3 -> 16 rows of 4 f32 (16 bytes/row): 13 padded rows
        assert miss["args"]["pad_bytes"] == 13 * 16
        assert miss["args"]["entry_seconds"] > 0.0
        comp = tr.spans("compile.bucket")
        assert len(comp) == 1
        assert comp[0]["parent"] == tr.events.index(miss)
        assert comp[0]["depth"] == miss["depth"] + 1
        # the hit span parents no compile event
        hit_idx = tr.events.index(hit)
        assert not [e for e in tr.events if e.get("parent") == hit_idx
                    and e["name"].startswith("compile")]

    def test_lower_span_dhlo(self):
        with obs_trace.tracing() as tr:
            _artifact("dhlo", name="lower_me")
        low = tr.spans("lower")
        assert len(low) == 1
        assert low[0]["args"]["artifact"] == "lower_me"
        assert low[0]["cat"] == "compile"

    def test_kernel_cluster_spans_nest_in_dispatch(self):
        # cluster spans need a backend with registered cluster kernels
        f = disc.compile(lambda x, y: jnp.tanh(x) * y + 1.0,
                         [ArgSpec(("B", 8), jnp.float32),
                          ArgSpec(("B", 8), jnp.float32)],
                         options=disc.CompileOptions(backend="pallas"))
        with obs_trace.tracing() as tr:
            f(np.ones((3, 8), np.float32), np.ones((3, 8), np.float32))
        clusters = tr.spans("kernel.cluster")
        assert clusters, "dhlo entry ran no cluster spans"
        disp_idx = tr.events.index(tr.spans("dispatch")[0])
        for c in clusters:
            assert c["cat"] == "backend"
            assert c["depth"] > 0
            # every cluster span sits somewhere under the dispatch span
            p = c
            while p["parent"] != -1 and p["parent"] != disp_idx:
                p = tr.events[p["parent"]]
            assert p["parent"] == disp_idx

    def test_vm_interp_span(self):
        f = _artifact("dhlo")
        vm = NimbleVM(f.graph)
        with obs_trace.tracing() as tr:
            vm(np.ones((4, 4), np.float32))
        sp = tr.spans("vm.interp")
        assert len(sp) == 1
        assert sp[0]["args"]["op_dispatches"] == vm.stats.op_dispatches > 0

    def test_metrics_event_mirrors_to_instant(self):
        with obs_trace.tracing() as tr:
            obs_metrics.record_event("replica.drain", replica=1)
        inst = tr.find("replica.drain")
        assert len(inst) == 1 and inst[0]["ph"] == "i"
        tl = obs_metrics.REGISTRY.snapshot()["timeline"]
        assert tl[-1]["event"] == "replica.drain"
        assert tl[-1]["replica"] == 1

    def test_overflow_drops_not_grows(self):
        tr = obs_trace.Tracer(max_events=2)
        for _ in range(5):
            tr.instant("x")
        assert len(tr.events) == 2 and tr.dropped == 3
        sp = tr.begin("late")      # over budget: recorded nowhere
        sp.end()
        assert len(tr.events) == 2
        assert tr.chrome_trace()["otherData"]["dropped"] == 4


class TestServeLifecycle:
    def test_request_async_events_and_launch_spans(self, tiny):
        cfg, model, params = tiny
        eng = _engine(model, params)
        with obs_trace.tracing() as tr:
            eng.submit(_requests(cfg.vocab, [5, 9, 12]))
            eng.run_until_done(max_steps=200)
        reqs = tr.find("request")
        begins = {e["id"] for e in reqs if e["ph"] == "b"}
        ends = {e["id"] for e in reqs if e["ph"] == "e"}
        assert begins == ends == {"0", "1", "2"}
        b0 = next(e for e in reqs if e["ph"] == "b" and e["id"] == "0")
        assert b0["args"]["prompt_len"] == 5
        e0 = next(e for e in reqs if e["ph"] == "e" and e["id"] == "0")
        assert e0["args"]["tokens"] == len(eng.done[0])
        pre = tr.spans("serve.prefill")
        dec = tr.spans("serve.decode")
        assert len(pre) == eng.stats["prefill_calls"] > 0
        assert len(dec) == eng.stats["decode_steps"] > 0
        assert all(s["args"] == {"attempts": 1, "error": False}
                   for s in pre + dec)
        # artifact dispatch spans nest inside the serve launch spans
        disp = tr.spans("dispatch")
        assert disp and all(d["parent"] != -1 for d in disp)

    def test_failed_request_closes_async_span(self, tiny):
        cfg, model, params = tiny
        eng = _engine(model, params, max_batch=1)
        eng._clock = lambda: 100.0           # frozen: deadline pre-expired
        with obs_trace.tracing() as tr:
            eng.submit([Request(rid=5, tokens=np.arange(4, dtype=np.int32),
                                max_new_tokens=2, deadline_s=-200.0)])
            eng.step()
        ends = [e for e in tr.find("request") if e["ph"] == "e"]
        assert len(ends) == 1 and ends[0]["args"]["failed"] is True
        assert "DeadlineExceeded" in ends[0]["args"]["reason"]
        tl = obs_metrics.REGISTRY.snapshot()["timeline"]
        assert any(ev["event"] == "deadline.expire" and ev["rid"] == 5
                   for ev in tl)


# ----------------------------------------------------------- registry ----

class TestMetricsParity:
    def test_observe_snapshot_covers_every_domain(self, tiny):
        cfg, model, params = tiny
        eng = _engine(model, params)
        eng.submit(_requests(cfg.vocab, [5, 9]))
        eng.run_until_done(max_steps=200)
        snap = disc.observe()
        for dom in obs_metrics.DOMAINS:
            assert dom in snap, f"missing domain {dom!r}"
        assert "serve" in snap and "engine" in snap["serve"]
        assert "health" in snap and "engine" in snap["health"]
        assert "prefill" in snap["dispatch"]
        assert "prefill" in snap["memory"]
        assert any(fp.startswith("serve") for fp in snap["compile"])

    def test_engine_stats_and_health_parity(self, tiny):
        cfg, model, params = tiny
        eng = _engine(model, params)
        eng.submit(_requests(cfg.vocab, [5, 9, 12]))
        eng.run_until_done(max_steps=200)
        snap = disc.observe()
        view = snap["serve"]["engine"]
        assert set(view) == set(STATS_KEYS)
        for k, v in eng.stats.items():
            assert view[k] == v, f"stats[{k!r}] diverged"
        assert snap["health"]["engine"] == eng.report()["health"]

    def test_compiled_accessor_parity(self):
        f = _artifact("jit")
        f(np.ones((3, 4), np.float32))
        f(np.ones((5, 4), np.float32))
        snap = disc.observe()
        assert snap["dispatch"]["obs_fn"] == f.cost_report()
        fp = f.cache.fingerprint
        assert snap["compile"][fp] == dict(f.cache_stats(),
                                           entries=len(f.cache._entries))
        mem = dict(snap["memory"]["obs_fn"])
        planning = mem.pop("planning")
        assert planning is False            # jit pipeline: no buffer plan
        assert mem == f._mstats.as_dict()
        assert f.report()["dispatch_cost"] == f.cost_report()

    def test_vm_collector_parity(self):
        f = _artifact("dhlo")
        vm = NimbleVM(f.graph)
        vm(np.ones((4, 4), np.float32))
        view = disc.observe()["vm"]
        assert view["calls"] == vm.stats.calls == 1
        assert view["op_dispatches"] == vm.stats.op_dispatches
        assert view["interp_seconds"] > 0.0

    def test_latest_collector_wins_per_name(self, tiny):
        cfg, model, params = tiny
        eng1 = _engine(model, params)
        eng2 = _engine(model, params)
        eng2.submit(_requests(cfg.vocab, [5]))
        eng2.run_until_done(max_steps=100)
        view = disc.observe()["serve"]["engine"]
        assert view["requests_completed"] == 1      # eng2, not eng1
        assert eng1.stats["requests_completed"] == 0

    def test_labeled_series_and_reset(self):
        reg = obs_metrics.REGISTRY
        reg.counter("launches", kind="prefill").inc(3)
        reg.counter("launches", kind="decode").inc()
        reg.gauge("occupancy", pool="kv").set(0.5)
        h = reg.histogram("pad_waste")
        h.observe(0.25)
        h.observe(0.75)
        snap = reg.snapshot()
        assert snap["counters"]["launches{kind=prefill}"] == 3
        assert snap["counters"]["launches{kind=decode}"] == 1
        assert snap["gauges"]["occupancy{pool=kv}"] == 0.5
        assert snap["histograms"]["pad_waste"]["mean"] == 0.5
        reg.reset()
        snap = reg.snapshot()
        assert not snap["counters"] and not snap["timeline"]


# ---------------------------------------------------- cost accounting ----

class TestCostAccounting:
    @pytest.mark.parametrize("pipeline", PIPELINES)
    def test_padding_waste_and_bucket_hits(self, pipeline):
        f = _artifact(pipeline)
        f(np.ones((3, 4), np.float32))     # bucket 16, true 3
        f(np.ones((20, 4), np.float32))    # bucket 32, true 20
        f(np.ones((20, 4), np.float32))
        cost = f.cost_report()
        assert cost["calls"] == 3
        assert cost["bucket_hits"] == {"(16,)": 1, "(32,)": 2}
        # f32 rows of 4 (16 bytes): padded (16+32+32) vs true (3+20+20)
        assert cost["padded_bytes"] == 80 * 16
        assert cost["true_bytes"] == 43 * 16
        assert cost["pad_waste_ratio"] == pytest.approx(37 / 80)
        pb = cost["per_bucket"]["(32,)"]
        assert pb["calls"] == 2
        assert pb["pad_waste_ratio"] == pytest.approx(24 / 64)

    def test_dispatch_overhead_timer(self):
        f = _artifact("jit")
        for _ in range(3):
            f(np.ones((3, 4), np.float32))
        cost = f.cost_report()
        # host-side dispatch wall (key + pad plan, pre-entry) and the
        # entry call are timed separately; both must tick
        assert cost["host_dispatch_seconds"] > 0.0
        assert cost["entry_seconds"] > 0.0
        pb = cost["per_bucket"]["(16,)"]
        assert pb["host_dispatch_seconds"] > 0.0
        assert pb["entry_seconds"] > 0.0

    def test_compile_and_escalation_timeline(self):
        f = disc.compile(lambda x: x * 2.0, [ArgSpec(("S", 4), jnp.float32)],
                         options=disc.CompileOptions(
                             pipeline="jit", escalation_threshold=2))
        for _ in range(3):
            f(np.ones((5, 4), np.float32))
        tl = obs_metrics.REGISTRY.snapshot()["timeline"]
        kinds = [ev["event"] for ev in tl]
        assert "compile.bucket" in kinds
        assert "escalate" in kinds
        esc = next(ev for ev in tl if ev["event"] == "escalate")
        assert esc["key"] == "(5,)"


# ------------------------------------------------- disabled == no-op -----

class TestDisabledNoOp:
    @pytest.mark.parametrize("pipeline", PIPELINES)
    def test_dispatch_source_identical_with_tracer(self, pipeline):
        off = _artifact(pipeline)
        off(np.ones((3, 4), np.float32))
        with obs_trace.tracing():
            on = _artifact(pipeline)
            on(np.ones((3, 4), np.float32))
        assert off.dispatch_source == on.dispatch_source

    def test_no_events_recorded_when_disabled(self, tiny):
        cfg, model, params = tiny
        assert obs_trace.ACTIVE is None
        eng = _engine(model, params)
        eng.submit(_requests(cfg.vocab, [5, 9]))
        eng.run_until_done(max_steps=200)
        tr = obs_trace.install()
        try:
            assert tr.events == []
        finally:
            obs_trace.clear()

    def test_hot_path_never_grows_timeline(self, tiny):
        cfg, model, params = tiny
        eng = _engine(model, params)
        eng.submit(_requests(cfg.vocab, [5, 9]))
        eng.run_until_done(max_steps=200)       # warm: compiles journaled
        n0 = len(obs_metrics.REGISTRY.snapshot()["timeline"])
        eng.submit(_requests(cfg.vocab, [5, 9]))
        eng.run_until_done(max_steps=200)       # all-hit steady state
        assert len(obs_metrics.REGISTRY.snapshot()["timeline"]) == n0


# ----------------------------------------------------- typed reset -------

class TestResetStats:
    def test_reset_preserves_types(self, tiny):
        cfg, model, params = tiny
        eng = _engine(model, params, replicas=2, max_batch=1)
        eng.submit(_requests(cfg.vocab, [5, 9]))
        eng.run_until_done(max_steps=200)
        # regression guard: even before _refresh_stats repairs anything,
        # every key must already hold its documented type
        eng._refresh_stats = lambda: None
        eng.reset_stats()
        assert isinstance(eng.stats["per_replica"], list)
        assert len(eng.stats["per_replica"]) == 2
        for rep in eng.stats["per_replica"]:
            assert rep == {"admitted": 0, "tokens_generated": 0,
                           "requests_completed": 0, "occupied_slots": 0}
        for k in ("tokens_per_sec", "max_decode_gap_s",
                  "kv_pool_occupancy", "kv_peak_occupancy"):
            assert isinstance(eng.stats[k], float)
        ints = set(STATS_KEYS) - {"per_replica", "tokens_per_sec",
                                  "max_decode_gap_s", "kv_pool_occupancy",
                                  "kv_peak_occupancy"}
        assert all(eng.stats[k] == 0 and isinstance(eng.stats[k], int)
                   for k in ints)

    def test_reset_keeps_dict_identity(self, tiny):
        cfg, model, params = tiny
        eng = _engine(model, params)
        held = eng.stats                 # benchmarks hold this reference
        eng.submit(_requests(cfg.vocab, [5]))
        eng.run_until_done(max_steps=100)
        eng.reset_stats()
        assert held is eng.stats
        assert held["requests_completed"] == 0


# ------------------------------------------------------- chrome trace ----

def _validate_trace_event(ev):
    assert set(("name", "cat", "ph", "ts", "pid", "tid", "args")) <= set(ev)
    assert isinstance(ev["ts"], (int, float)) and ev["ts"] >= 0
    assert isinstance(ev["args"], dict)
    assert "parent" not in ev and "depth" not in ev
    if ev["ph"] == "X":
        assert isinstance(ev["dur"], (int, float)) and ev["dur"] >= 0
    elif ev["ph"] == "i":
        assert ev["s"] == "t"
    elif ev["ph"] in ("b", "e"):
        assert isinstance(ev["id"], str)
    else:
        assert ev["ph"] == "C"


class TestChromeExport:
    def test_schema_and_roundtrip(self, tiny, tmp_path):
        cfg, model, params = tiny
        eng = _engine(model, params)
        disc.observe.start_trace()
        try:
            eng.submit(_requests(cfg.vocab, [5, 9]))
            eng.run_until_done(max_steps=200)
            path = tmp_path / "trace.json"
            disc.observe.export_chrome_trace(path)
        finally:
            tr = disc.observe.stop_trace()
        doc = json.loads(path.read_text())
        assert set(doc) == {"traceEvents", "displayTimeUnit", "otherData"}
        assert doc["displayTimeUnit"] == "ms"
        assert len(doc["traceEvents"]) == len(tr.events)
        phases = set()
        for ev in doc["traceEvents"]:
            _validate_trace_event(ev)
            phases.add(ev["ph"])
        assert {"X", "b", "e"} <= phases
        # spans and their async pairs must be time-ordered in µs
        ts = [ev["ts"] for ev in doc["traceEvents"]]
        assert ts == sorted(ts)

    def test_export_without_tracer_raises(self, tmp_path):
        with pytest.raises(RuntimeError, match="no active tracer"):
            disc.observe.export_chrome_trace(tmp_path / "x.json")


# ------------------------------------------------------------- clocks ----

class TestClocks:
    def test_clock_fixed_source(self):
        t = [10.0]
        with CLOCK.fixed(lambda: t[0]):
            assert CLOCK() == 10.0
            t[0] = 11.5
            assert CLOCK() == 11.5
        assert CLOCK() != 11.5      # perf_counter restored

    def test_heartbeat_monitor_injected_clock(self):
        t = [0.0]
        mon = HeartbeatMonitor(["h0", "h1"], deadline_s=5.0,
                               clock=lambda: t[0])
        mon.beat("h0")
        mon.beat("h1")
        t[0] = 4.0
        assert mon.dead_hosts() == []
        mon.beat("h1")
        t[0] = 6.0
        assert mon.dead_hosts() == ["h0"]   # h1 beat at t=4, alive

    def test_monitor_defaults_to_obs_clock(self):
        t = [100.0]
        mon = HeartbeatMonitor(["h0"], deadline_s=1.0)
        with CLOCK.fixed(lambda: t[0]):
            mon.beat("h0")
            t[0] = 102.0
            assert mon.dead_hosts() == ["h0"]

    def test_tracer_timestamps_use_injected_clock(self):
        t = [0.0]
        with CLOCK.fixed(lambda: t[0]):
            tr = obs_trace.Tracer()
            sp = tr.begin("a")
            t[0] = 0.25
            sp.end()
        ev = tr.events[0]
        assert ev["ts"] == 0.0 and ev["dur"] == 0.25
