"""Integration + property tests for the DISC runtime (engine, fusion, VM)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.api import (ArgSpec, BucketPolicy, NimbleVM, bridge, pow2_bucket,
                       compile as disc_compile)
from repro.core.fusion import plan_fusion

F32 = jnp.float32


def _mlp_block(x, w1, w2):
    h = jnp.tanh(x @ w1)
    return jax.nn.softmax(h @ w2, axis=-1)


def _attention_scores(q, k):
    s = q @ k.T / np.sqrt(q.shape[-1])
    return jax.nn.softmax(s, axis=-1)


class TestEngineCorrectness:
    def test_elementwise_exact(self):
        def f(x, y):
            return jnp.exp(x) * y + jnp.tanh(x)

        eng = disc_compile(f, [ArgSpec(("B", "D")), ArgSpec(("B", "D"))])
        for b, d in [(3, 5), (17, 9), (16, 16), (1, 1)]:
            x = np.random.randn(b, d).astype(np.float32)
            y = np.random.randn(b, d).astype(np.float32)
            got = eng(x, y)
            np.testing.assert_allclose(got, f(x, y), rtol=1e-5)

    def test_reduction_masked_exactly(self):
        # exp(pad)=1 garbage must not leak into the sum
        def f(x):
            return jnp.exp(x).sum(axis=1)

        eng = disc_compile(f, [ArgSpec(("B", "S"))])
        x = np.random.randn(5, 13).astype(np.float32)
        np.testing.assert_allclose(eng(x), f(x), rtol=1e-5)

    def test_softmax_masked(self):
        def f(x):
            return jax.nn.softmax(x, axis=-1)

        eng = disc_compile(f, [ArgSpec(("B", "S"))])
        x = np.random.randn(3, 21).astype(np.float32)
        np.testing.assert_allclose(eng(x), f(x), rtol=1e-5, atol=1e-6)

    def test_matmul_dynamic_contraction(self):
        def f(x, w):
            return jnp.exp(x) @ w  # tainted padded region feeds contraction

        eng = disc_compile(f, [ArgSpec(("B", "K")), ArgSpec(("K", 8))])
        x = np.random.randn(5, 11).astype(np.float32)
        w = np.random.randn(11, 8).astype(np.float32)
        np.testing.assert_allclose(eng(x, w), f(x, w), rtol=1e-4)

    def test_mlp_block(self):
        eng = disc_compile(_mlp_block, [ArgSpec(("B", 16)), ArgSpec((16, 32)),
                                      ArgSpec((32, 8))])
        w1 = np.random.randn(16, 32).astype(np.float32)
        w2 = np.random.randn(32, 8).astype(np.float32)
        for b in (2, 7, 33):
            x = np.random.randn(b, 16).astype(np.float32)
            np.testing.assert_allclose(eng(x, w1, w2), _mlp_block(x, w1, w2),
                                       rtol=1e-4, atol=1e-6)

    def test_attention_scores_dynamic_seq(self):
        eng = disc_compile(_attention_scores, [ArgSpec(("S", 8)), ArgSpec(("S", 8))])
        for s in (3, 10, 31):
            q = np.random.randn(s, 8).astype(np.float32)
            k = np.random.randn(s, 8).astype(np.float32)
            np.testing.assert_allclose(
                eng(q, k), _attention_scores(q, k), rtol=1e-4, atol=1e-6)

    def test_reshape_merge_then_reduce(self):
        # (B,S,D) -> (B*S, D) -> max over merged axis: Kronecker mask path
        def f(x):
            flat = x.reshape(-1, x.shape[-1])
            return jnp.exp(flat).max(axis=0)

        eng = disc_compile(f, [ArgSpec(("B", "S", 4))])
        x = np.random.randn(3, 5, 4).astype(np.float32)
        np.testing.assert_allclose(eng(x), f(x), rtol=1e-5)

    def test_dynamic_concat(self):
        def f(x, y):
            return jnp.concatenate([x, y], axis=0).sum(axis=0)

        eng = disc_compile(f, [ArgSpec(("M", 4)), ArgSpec(("N", 4))])
        x = np.random.randn(5, 4).astype(np.float32)
        y = np.random.randn(9, 4).astype(np.float32)
        np.testing.assert_allclose(eng(x, y), f(x, y), rtol=1e-5)

    def test_dynamic_concat_output_shape(self):
        def f(x, y):
            return jnp.concatenate([x, y], axis=0)

        eng = disc_compile(f, [ArgSpec(("M", 4)), ArgSpec(("N", 4))])
        x = np.random.randn(3, 4).astype(np.float32)
        y = np.random.randn(6, 4).astype(np.float32)
        out = eng(x, y)
        assert out.shape == (9, 4)
        np.testing.assert_allclose(out, f(x, y), rtol=1e-6)

    def test_multi_output(self):
        def f(x):
            return jnp.exp(x), x.sum(axis=0)

        eng = disc_compile(f, [ArgSpec(("N", 3))])
        x = np.random.randn(7, 3).astype(np.float32)
        a, b = eng(x)
        np.testing.assert_allclose(a, jnp.exp(x), rtol=1e-6)
        np.testing.assert_allclose(b, x.sum(axis=0), rtol=1e-5)


class TestCompileCount:
    def test_compiles_per_bucket_not_per_shape(self):
        def f(x):
            return jnp.tanh(x) * 2.0

        eng = disc_compile(f, [ArgSpec(("S", 8))],
                         policy=BucketPolicy(kind="pow2", granule=16))
        shapes = list(range(1, 65))
        for s in shapes:
            eng(np.zeros((s, 8), np.float32))
        buckets = {pow2_bucket(s, 16) for s in shapes}
        assert eng.n_compiles == len(buckets)  # 16,32,64 -> 3, not 64
        assert eng.cache.stats.hits == len(shapes) - len(buckets)

    def test_exact_policy_is_static_baseline(self):
        def f(x):
            return jnp.tanh(x)

        eng = disc_compile(f, [ArgSpec(("S", 4))], policy=BucketPolicy(kind="exact"))
        for s in (3, 4, 5, 6):
            eng(np.zeros((s, 4), np.float32))
        assert eng.n_compiles == 4  # one per emerging shape, like XLA

    def test_static_escalation(self):
        def f(x):
            return jnp.exp(x) + 1.0

        eng = disc_compile(f, [ArgSpec(("S", 4))], escalation_threshold=3)
        x = np.zeros((5, 4), np.float32)
        for _ in range(5):
            eng(x)
        assert eng.cache.stats.escalations == 1
        np.testing.assert_allclose(eng(x), f(x), rtol=1e-6)


class TestGeneratedDispatch:
    def test_dispatch_source_is_generated(self):
        def f(x):
            return x * 2.0

        eng = disc_compile(f, [ArgSpec(("B", 4))])
        assert "def _dispatch" in eng.dispatch_source
        assert "key" in eng.dispatch_source
        # no per-op interpretation in the dispatch path
        assert "for op" not in eng.dispatch_source


class TestFusionPlan:
    def test_elementwise_chain_single_kernel(self):
        def f(x, y):
            return jnp.exp(x) * y + jnp.tanh(x) - 1.0

        g, _ = bridge(f, [ArgSpec(("B", "D")), ArgSpec(("B", "D"))])
        plan = plan_fusion(g)
        assert plan.n_memory_kernels == 1

    def test_reduce_roots_input_fusion(self):
        def f(x):
            return (jnp.exp(x) * 2.0).sum(axis=1)

        g, _ = bridge(f, [ArgSpec(("B", "S"))])
        plan = plan_fusion(g)
        # producers fused into the reduce root: one kInput kernel
        kinds = [c.kind for c in plan.clusters if len(c.ops) > 1]
        assert kinds == ["input"]

    def test_dot_absorbs_elementwise_epilogue(self):
        # a dot_general fuses its elementwise consumer into a kDot cluster
        # (§4.3 epilogue fusion) — but never into a plain loop cluster
        def f(x, w):
            return jnp.tanh(x @ w)

        g, _ = bridge(f, [ArgSpec(("B", 8)), ArgSpec((8, 8))])
        plan = plan_fusion(g)
        (dc,) = [c for c in plan.clusters
                 if any(op.opcode == "dot_general" for op in c.ops)]
        assert dc.kind == "dot" and dc.template == "kDot"
        assert all(c.kind != "loop" or
                   not any(op.opcode == "dot_general" for op in c.ops)
                   for c in plan.clusters)

    def test_bare_dot_stays_library_call(self):
        def f(x, w):
            return x @ w

        g, _ = bridge(f, [ArgSpec(("B", 8)), ArgSpec((8, 8))])
        plan = plan_fusion(g)
        for c in plan.clusters:
            if any(op.opcode == "dot_general" for op in c.ops):
                assert len(c.ops) == 1 and c.kind == "compute"

    def test_split_hint_enables_fusion(self):
        # a*b+c over split outputs fuses only because the frontend hint
        # proves the three slices share a shape
        def f(x):
            a, b, c = jnp.split(x, 3, axis=1)
            return a * b + c

        g, _ = bridge(f, [ArgSpec(("B", 12))])
        plan = plan_fusion(g)
        assert plan.n_memory_kernels == 1

    def test_fusion_reduces_kernel_count(self):
        def f(q, k):
            return _attention_scores(q, k)

        g, _ = bridge(f, [ArgSpec(("S", 8)), ArgSpec(("S", 8))])
        plan = plan_fusion(g)
        s = plan.stats()
        assert s["kernels_after_fusion"] < s["total_ops"]


class TestNimbleVM:
    def test_vm_matches_engine(self):
        def f(x, y):
            return jax.nn.softmax(jnp.exp(x) * y, axis=-1)

        g, _ = bridge(f, [ArgSpec(("B", "S")), ArgSpec(("B", "S"))])
        vm = NimbleVM(g)
        eng = disc_compile(f, [ArgSpec(("B", "S")), ArgSpec(("B", "S"))])
        x = np.random.randn(4, 9).astype(np.float32)
        y = np.random.randn(4, 9).astype(np.float32)
        (vm_out,) = vm(x, y)
        np.testing.assert_allclose(vm_out, eng(x, y), rtol=1e-5, atol=1e-6)
        assert vm.stats.op_dispatches == len(g.ops)  # one launch per op


class TestPropertyBased:
    @settings(max_examples=30, deadline=None)
    @given(
        b=st.integers(min_value=1, max_value=40),
        s=st.integers(min_value=1, max_value=40),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_engine_equals_reference_any_shape(self, b, s, seed):
        def f(x):
            y = jnp.exp(x) * 0.5
            return jax.nn.softmax(y, axis=-1).sum(axis=0)

        if not hasattr(self, "_eng"):
            type(self)._eng = disc_compile(f, [ArgSpec(("B", "S"))])
        rng = np.random.RandomState(seed)
        x = rng.randn(b, s).astype(np.float32)
        np.testing.assert_allclose(type(self)._eng(x), f(x),
                                   rtol=1e-4, atol=1e-5)

    @settings(max_examples=30, deadline=None)
    @given(v=st.integers(min_value=1, max_value=10_000))
    def test_bucket_monotone_and_covering(self, v):
        pol = BucketPolicy(kind="pow2", granule=16)
        bkt = pol.bucket("S", v)
        assert bkt >= v
        assert bkt == pol.bucket("S", bkt)  # idempotent
        assert pol.bucket("S", v + 1) >= bkt or v + 1 <= bkt
