"""``disc`` — the public name of the DISC compiler API.

A thin alias for :mod:`repro.api`; see that module for the full surface.

    import disc
    fast = disc.compile(fn, [(disc.Dim("S", max=4096), 64), (64, 32)])
"""
import repro.api as _api
from repro.api import (  # noqa: F401
    ArgSpec,
    Backend,
    BucketPolicy,
    CacheStats,
    Compiled,
    CompiledFunction,
    CompileCache,
    CompileError,
    CompileOptions,
    DeadlineExceeded,
    Dim,
    DiscError,
    EXACT,
    FaultInjector,
    FaultSpec,
    LaunchError,
    Lowered,
    Observe,
    PoolExhausted,
    RetryPolicy,
    NimbleVM,
    POW2,
    ShardingProfile,
    Tracer,
    TreeSpec,
    UnknownBackendError,
    bridge,
    compile,
    faults,
    get_backend,
    get_mesh,
    get_profile,
    infer_specs,
    list_backends,
    list_profiles,
    make_mesh,
    observe,
    pow2_bucket,
    register_backend,
    use_mesh,
)

__all__ = list(_api.__all__)


def __getattr__(name):  # ServeEngine / ServeConfig stay lazy (model zoo)
    return getattr(_api, name)
