"""zamba2-7b [hybrid] — 81L d_model=3584 32H (GQA kv=32) d_ff=14336,
ssm_state=64 — Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; unverified]
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    ssm_state=64,
    ssm_head_dim=64,
    shared_attn_every=6,
    dtype="bf16",
    act="silu",
    norm="rmsnorm",
    remat="full",
    max_seq=1048576,
)
