"""codeqwen1.5-7b [dense] — 32L d_model=4096 32H (GQA kv=32) d_ff=13440
vocab=92416 — qwen1.5-arch.  [hf:Qwen/CodeQwen1.5-7B; hf]
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab=92416,
    dtype="bf16",
    act="silu",
    norm="rmsnorm",
    remat="full",
    max_seq=65536,
)
