"""whisper-tiny [audio] — 4L d_model=384 6H d_ff=1536 vocab=51865 —
enc-dec; conv frontend is a STUB (input_specs supplies precomputed frame
embeddings, 1500 x 384).  [arXiv:2212.04356; unverified]
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,              # decoder layers
    n_encoder_layers=4,
    encoder_len=1500,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    dtype="bf16",
    act="gelu",
    norm="layernorm",
    remat="none",
    max_seq=32768,
)
