"""Assigned-architecture configs (exact public-literature dimensions).

Selectable via ``--arch <id>`` in the launchers; ``ARCH_IDS`` lists all 10
assigned architectures plus the paper's own workload config.
"""
from importlib import import_module

ARCH_IDS = [
    "dbrx_132b",
    "deepseek_v2_236b",
    "minitron_4b",
    "codeqwen15_7b",
    "tinyllama_11b",
    "granite_20b",
    "rwkv6_3b",
    "whisper_tiny",
    "zamba2_7b",
    "llava_next_34b",
]

ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}


def get_config(arch_id: str):
    arch_id = ALIASES.get(arch_id, arch_id)
    mod = import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG
