"""rwkv6-3b [ssm] — 32L d_model=2560 (attention-free) d_ff=8960
vocab=65536 — Finch, data-dependent decay.  [arXiv:2404.05892; hf]
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,              # d_model / ssm_head_dim
    n_kv_heads=40,
    d_ff=8960,
    vocab=65536,
    ssm_state=64,
    ssm_head_dim=64,
    attn_kind="none",
    dtype="bf16",
    norm="layernorm",
    remat="full",
    max_seq=1048576,         # O(1) state: long-context capable
)
