"""llava-next-34b [vlm] — 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 — anyres tiling; the vision tower is a STUB (input_specs
supplies precomputed patch embeddings; variable image-token counts are the
canonical DISC dynamic-shape workload).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    max_image_tokens=2880,   # anyres: up to 5 tiles x 576 patches
    dtype="bf16",
    act="silu",
    norm="rmsnorm",
    remat="full",
    max_seq=32768,
)
