"""deepseek-v2-236b [moe] — 60L d_model=5120 128H (GQA kv=128) d_ff=1536
vocab=102400, MoE 160 experts top-6, MLA kv_lora=512, 2 shared experts.
[arXiv:2405.04434; hf]
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=1536,              # fine-grained expert width
    vocab=102400,
    n_experts=160,
    top_k=6,
    n_shared_experts=2,
    d_expert=1536,
    mla_kv_lora=512,
    mla_rope_dim=64,
    dtype="bf16",
    act="silu",
    norm="rmsnorm",
    remat="full",
    max_seq=32768,
)
