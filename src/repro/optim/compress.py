"""Gradient compression for the DP all-reduce (DESIGN §7).

Two composable stages, both with error feedback:
  * dtype compression: f32 -> bf16 on the wire (2x collective bytes)
  * top-k sparsification (per-tensor magnitude top-k), optional

Off by default; enabled via TrainConfig.grad_compression.  The error-
feedback residual is carried in the train state so compression is unbiased
over time (Karimireddy et al., 2019).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def compress_grads(grads, residual=None, *, topk_frac: Optional[float] = None):
    """Returns (wire_grads, new_residual)."""
    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        if topk_frac is not None and gf.size > 64:
            k = max(int(gf.size * topk_frac), 1)
            flat = gf.reshape(-1)
            thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
            kept = jnp.where(jnp.abs(flat) >= thresh, flat, 0.0)
            wire = kept.reshape(gf.shape).astype(jnp.bfloat16)
        else:
            wire = gf.astype(jnp.bfloat16)
        new_r = gf - wire.astype(jnp.float32)
        return wire, new_r

    out = jax.tree.map(one, grads, residual)
    wire = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    new_res = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple))
    return wire, new_res


def decompress_grads(wire):
    return jax.tree.map(lambda w: w.astype(jnp.float32), wire)
