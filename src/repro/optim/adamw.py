"""AdamW with f32 master state over arbitrary param pytrees (ZeRO-friendly:
optimizer state inherits the params' sharding specs plus the DP axis)."""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def adamw_init(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(step=jnp.zeros((), jnp.int32),
                    mu=jax.tree.map(zeros, params),
                    nu=jax.tree.map(zeros, params))


def adamw_update(params, grads, state: OptState, *, lr, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1,
                 grad_clip: float = 1.0) -> Tuple[Any, OptState]:
    # global-norm clip in f32
    gflat = jax.tree.leaves(jax.tree.map(
        lambda g: jnp.sum(g.astype(jnp.float32) ** 2), grads))
    gnorm = jnp.sqrt(sum(gflat))
    scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    b1c = 1 - b1 ** step.astype(jnp.float32)
    b2c = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_params, OptState(step=step, mu=new_mu, nu=new_nu)
