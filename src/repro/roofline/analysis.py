"""Roofline-term extraction from compiled dry-run artifacts (deliverable g).

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

Sources: ``compiled.cost_analysis()`` for FLOPs/bytes; ``compiled.as_text()``
parsed for all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute operand bytes (collective bytes are NOT in
cost_analysis).  Hardware constants: TPU v5e.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

__all__ = ["HW", "RooflineTerms", "analyze_compiled", "collective_bytes"]


class HW:
    PEAK_FLOPS_BF16 = 197e12      # per chip
    HBM_BW = 819e9                # bytes/s per chip
    ICI_LINK_BW = 50e9            # bytes/s per link


_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0,
}

# one HLO value definition: %name = type[dims]{layout} opcode(...)
_DEF_RE = re.compile(
    r"%?([\w\.\-]+)\s*=\s*\(?\s*([a-z0-9]+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_COLL_RE = re.compile(
    r"=\s*(.+?)\s+(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(([^)]*)\)")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes per collective kind from optimized HLO text."""
    # table of every defined value's shape
    shapes: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.search(line)
        if m:
            name, dt, dims = m.groups()
            shapes[name] = _shape_bytes(dt, dims)

    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        result_types, kind, operands = m.groups()
        if "-done" in line.split("=")[1][:60]:
            continue  # avoid double counting async pairs
        # operand bytes: resolve %names; fall back to inline shapes
        total = 0
        names = re.findall(r"%?([\w\.\-]+)", operands)
        for nm in names:
            if nm in shapes:
                total += shapes[nm]
        if total == 0:
            for dt, dims in _SHAPE_RE.findall(result_types):
                total += _shape_bytes(dt, dims)
        out[kind] += total
        out["count"] += 1
    return out


@dataclass
class RooflineTerms:
    arch: str
    cell: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_breakdown: Dict[str, int]
    model_flops: float
    bytes_per_device: float = 0.0
    peak_memory_per_device: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * HW.PEAK_FLOPS_BF16)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HW.HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.chips * HW.ICI_LINK_BW)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the binding roofline the useful work achieves:
        t_model_compute / max(all terms) — 1.0 means the dominant term is
        exactly the useful compute."""
        t_model = self.model_flops / (self.chips * HW.PEAK_FLOPS_BF16)
        bound = max(self.t_compute, self.t_memory, self.t_collective)
        return t_model / bound if bound else 0.0

    def as_dict(self) -> Dict:
        return {
            "arch": self.arch, "cell": self.cell, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes,
            "coll_breakdown": self.coll_breakdown,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "bytes_per_device": self.bytes_per_device,
            "peak_memory_per_device": self.peak_memory_per_device,
        }


def analyze_compiled(compiled, *, arch: str, cell: str, mesh_name: str,
                     chips: int, model_flops: float) -> RooflineTerms:
    """Roofline terms from the compiled artifact.

    Primary source: our trip-count-aware HLO walk (hlo_cost.py) — XLA's
    cost_analysis counts while bodies once, which under-reports scanned
    models by ~n_layers x.  The per-device totals are scaled to global by
    the chip count so the spec formulas (X / (chips·peak)) apply.
    """
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    from .hlo_cost import analyze_hlo_text
    per_dev = analyze_hlo_text(hlo) if hlo else None
    xla_cost = compiled.cost_analysis()
    if isinstance(xla_cost, list):
        xla_cost = xla_cost[0] if xla_cost else {}
    if per_dev is not None and per_dev.flops > 0:
        flops = per_dev.flops * chips
        bts = per_dev.bytes * chips
        coll = {k: v * chips for k, v in per_dev.coll.items()}
        coll["count"] = per_dev.coll_count
        total_coll = float(per_dev.coll_bytes * chips)
    else:  # fallback: XLA's own (loop-undercounting) analysis
        flops = float(xla_cost.get("flops", 0.0))
        bts = float(xla_cost.get("bytes accessed", 0.0))
        coll = collective_bytes(hlo)
        total_coll = float(sum(v for k, v in coll.items() if k != "count"))
    mem = {}
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_size": getattr(ma, "argument_size_in_bytes", 0),
            "output_size": getattr(ma, "output_size_in_bytes", 0),
            "temp_size": getattr(ma, "temp_size_in_bytes", 0),
        }
    except Exception:
        pass
    per_dev = (mem.get("argument_size", 0) + mem.get("temp_size", 0))
    return RooflineTerms(
        arch=arch, cell=cell, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=bts, coll_bytes=total_coll,
        coll_breakdown=coll, model_flops=model_flops,
        bytes_per_device=per_dev,
        peak_memory_per_device=per_dev,
    )
