"""Trip-count-aware cost analysis over optimized (post-SPMD) HLO text.

XLA's built-in ``HloCostAnalysis`` (surfaced via ``compiled.cost_analysis``)
counts a ``while`` body exactly ONCE — a scan-over-layers model therefore
under-reports FLOPs/bytes/collectives by ~n_layers x chunk-loops.  This
module re-walks the HLO call graph multiplying nested costs by the
``known_trip_count`` backend config, giving per-device totals that are
accurate for scanned programs:

* FLOPs: ``dot`` = 2·|out|·K (K = contracted extent); elementwise = |out|;
  ``reduce`` = |in|.
* Bytes: counted at *fusion boundaries* only (operands + results of
  top-level ops) — fused-internal traffic is free, approximating HBM
  traffic the way HloCostAnalysis does.
* Collectives: operand bytes per kind, multiplied through enclosing loops
  (a collective inside the layer scan runs n_layers times).

All numbers are PER DEVICE (the module is the SPMD-partitioned per-shard
program); callers multiply by chip count for global terms.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["HloCost", "analyze_hlo_text"]

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2,
    "u16": 2, "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8,
    "u64": 8, "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1,
    "f8e5m2": 1, "u2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_OPLINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")
_TRIP_RE = re.compile(r'known_trip_count[^}]*?"n"\s*:\s*"(\d+)"')
_CALLS_RE = re.compile(r"(?:calls|body|to_apply)=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_OPERANDS_RE = re.compile(r"%([\w\.\-]+)")


def _shape_info(type_str: str) -> Tuple[int, List[Tuple[str, List[int]]]]:
    """Total bytes + list of (dtype, dims) arrays in a (possibly tuple) type."""
    arrays = []
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        d = [int(x) for x in dims.split(",")] if dims.strip() else []
        n = 1
        for x in d:
            n *= x
        total += n * _DTYPE_BYTES[dt]
        arrays.append((dt, d))
    return total, arrays


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=lambda: {
        k: 0.0 for k in _COLLECTIVES})
    coll_count: float = 0.0

    def __iadd__(self, other: "HloCost"):
        self.flops += other.flops
        self.bytes += other.bytes
        for k in self.coll:
            self.coll[k] += other.coll[k]
        self.coll_count += other.coll_count
        return self

    def scaled(self, m: float) -> "HloCost":
        return HloCost(self.flops * m, self.bytes * m,
                       {k: v * m for k, v in self.coll.items()},
                       self.coll_count * m)

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())


@dataclass
class _Op:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    attrs: str


def _parse_computations(text: str) -> Dict[str, List[_Op]]:
    comps: Dict[str, List[_Op]] = {}
    cur: Optional[str] = None
    entry: Optional[str] = None
    for line in text.splitlines():
        hdr = _COMP_HDR_RE.match(line.strip())
        if hdr and ("->" in line):
            cur = hdr.group(2)
            comps[cur] = []
            if hdr.group(1):
                entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OPLINE_RE.match(line)
        if not m:
            continue
        name, type_str, opcode = m.groups()
        # operand list: first balanced paren group after "opcode("
        start = line.find(opcode + "(") + len(opcode) + 1
        depth = 1
        i = start
        while i < len(line) and depth:
            if line[i] == "(":
                depth += 1
            elif line[i] == ")":
                depth -= 1
            i += 1
        operand_str = line[start:i - 1]
        attrs = line[i:]
        operands = _OPERANDS_RE.findall(operand_str)
        comps[cur].append(_Op(name, type_str, opcode, operands, attrs))
    comps["__entry__"] = comps.get(entry, [])
    return comps


_ZERO_FLOP_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "copy", "copy-start", "copy-done", "reshape", "transpose", "broadcast",
    "slice", "dynamic-slice", "dynamic-update-slice", "concatenate", "pad",
    "iota", "reverse", "gather", "scatter", "after-all", "partition-id",
    "replica-id", "rng-bit-generator", "convert", "optimization-barrier",
    "infeed", "outfeed", "send", "recv", "domain",
}


def _contracted_extent(op: _Op, shapes: Dict[str, List[Tuple[str, List[int]]]]) -> int:
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    dims = [int(x) for x in m.group(1).split(",")] if m and m.group(1) else []
    lhs = shapes.get(op.operands[0]) if op.operands else None
    if not lhs:
        return 1
    _, lhs_dims = lhs[0]
    k = 1
    for dx in dims:
        if dx < len(lhs_dims):
            k *= lhs_dims[dx]
    return max(k, 1)


class _Analyzer:
    def __init__(self, comps: Dict[str, List[_Op]]):
        self.comps = comps
        self.memo: Dict[Tuple[str, bool], HloCost] = {}

    def comp_cost(self, name: str, boundary: bool) -> HloCost:
        key = (name, boundary)
        if key in self.memo:
            return self.memo[key]
        self.memo[key] = HloCost()  # cycle guard
        total = HloCost()
        ops = self.comps.get(name, [])
        shapes: Dict[str, List[Tuple[str, List[int]]]] = {}
        bytes_of: Dict[str, int] = {}
        for op in ops:
            b, arrs = _shape_info(op.type_str)
            shapes[op.name] = arrs
            bytes_of[op.name] = b
        for op in ops:
            total += self.op_cost(op, shapes, bytes_of, boundary)
        self.memo[key] = total
        return total

    def op_cost(self, op: _Op, shapes, bytes_of, boundary: bool) -> HloCost:
        c = HloCost()
        out_bytes = bytes_of.get(op.name, 0)
        out_elems = 0
        for dt, dims in shapes.get(op.name, []):
            n = 1
            for d in dims:
                n *= d
            out_elems += n
        opcode = op.opcode

        if opcode == "while":
            trips = 1
            m = _TRIP_RE.search(op.attrs)
            if m:
                trips = int(m.group(1))
            body = _CALLS_RE.search(op.attrs.replace("condition=", ""))
            bm = re.search(r"body=%?([\w\.\-]+)", op.attrs)
            cm = _COND_RE.search(op.attrs)
            if bm:
                c += self.comp_cost(bm.group(1), True).scaled(trips)
            if cm:
                c += self.comp_cost(cm.group(1), True).scaled(trips)
            return c

        if opcode in ("fusion",):
            m = re.search(r"calls=%?([\w\.\-]+)", op.attrs)
            if m:
                c += self.comp_cost(m.group(1), False)
            if boundary:
                c.bytes += out_bytes + sum(bytes_of.get(o, 0)
                                           for o in op.operands)
            return c

        if opcode in ("call", "conditional", "custom-call", "map",
                      "reduce-window", "sort", "async-start"):
            m = re.search(r"(?:calls|to_apply|branch_computations)=\{?%?([\w\.\-]+)",
                          op.attrs)
            if m:
                c += self.comp_cost(m.group(1), boundary)
            if boundary:
                c.bytes += out_bytes + sum(bytes_of.get(o, 0)
                                           for o in op.operands)
            if opcode == "sort":
                c.flops += out_elems  # comparator approx
            return c

        base = opcode.replace("-start", "")
        if base in _COLLECTIVES:
            if opcode.endswith("-done"):
                return c
            operand_bytes = sum(bytes_of.get(o, 0) for o in op.operands)
            if operand_bytes == 0:
                operand_bytes = out_bytes
            c.coll[base] += operand_bytes
            c.coll_count += 1
            if boundary:
                c.bytes += out_bytes + operand_bytes
            return c

        if opcode == "dot":
            k = _contracted_extent(op, shapes)
            c.flops += 2.0 * out_elems * k
            if boundary:
                c.bytes += out_bytes + sum(bytes_of.get(o, 0)
                                           for o in op.operands)
            return c

        if opcode == "convolution":
            # rough: 2 * out_elems * (kernel elems) — unused by our models
            kb = bytes_of.get(op.operands[1], 0) if len(op.operands) > 1 else 0
            c.flops += 2.0 * out_elems * max(kb // 4, 1)
            if boundary:
                c.bytes += out_bytes + sum(bytes_of.get(o, 0)
                                           for o in op.operands)
            return c

        if opcode == "reduce":
            in_bytes = sum(bytes_of.get(o, 0) for o in op.operands[:1])
            c.flops += in_bytes / 4.0
            if boundary:
                c.bytes += out_bytes + sum(bytes_of.get(o, 0)
                                           for o in op.operands)
            return c

        if opcode in _ZERO_FLOP_OPS:
            if not boundary:
                return c
            if opcode in ("dynamic-slice", "slice", "gather"):
                # reads only the sliced window, not the whole operand
                c.bytes += 2 * out_bytes
            elif opcode in ("dynamic-update-slice", "scatter"):
                upd = (bytes_of.get(op.operands[1], 0)
                       if len(op.operands) > 1 else out_bytes)
                c.bytes += 2 * upd  # read update + write region (in-place)
            elif opcode in ("copy", "concatenate", "pad", "transpose",
                            "reshape", "broadcast", "convert", "reverse"):
                c.bytes += out_bytes + sum(bytes_of.get(o, 0)
                                           for o in op.operands)
            return c

        # default: elementwise-ish
        c.flops += out_elems
        if boundary:
            c.bytes += out_bytes + sum(bytes_of.get(o, 0) for o in op.operands)
        return c


def analyze_hlo_text(text: str) -> HloCost:
    comps = _parse_computations(text)
    return _Analyzer(comps).comp_cost("__entry__", True)
