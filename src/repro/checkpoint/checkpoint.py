"""Topology-agnostic checkpointing with atomic step directories.

Design for 1000+ nodes (DESIGN §7):

* **atomicity** — a step is written to ``step_<k>.tmp`` and renamed only
  after the manifest + all leaves are durably written; a crashed writer
  never corrupts the latest checkpoint;
* **topology-agnostic** — leaves are saved as full logical arrays with
  their tree paths; restore re-lays them out onto ANY mesh via the model's
  PartitionSpec tree (elastic re-mesh: a 512-chip checkpoint restores on
  256 chips or 16);
* **journal** — ``journal.json`` records (step, data-cursor, wall time) so
  the data pipeline resumes deterministically (data/pipeline.py contract);
* async-friendly: ``save_checkpoint(..., blocking=False)`` returns after
  staging to host memory; the writer thread persists in the background
  (straggler-safe: the train loop never blocks on the filesystem).

On a real cluster each host writes only the shards it owns (via
``jax.experimental.multihost_utils``); in this single-process repo the
process owns everything, which is the degenerate case of the same layout.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step"]

_WRITERS: list = []


def _flatten_with_paths(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_checkpoint(ckpt_dir, step: int, state, *, journal: Optional[Dict] = None,
                    blocking: bool = True, keep: int = 3) -> pathlib.Path:
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    flat = _flatten_with_paths(state)  # staged to host memory NOW

    def _write():
        tmp = ckpt_dir / f"step_{step}.tmp"
        final = ckpt_dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        np.savez(tmp / "leaves.npz", **flat)
        manifest = {
            "step": step,
            "time": time.time(),
            "n_leaves": len(flat),
            "journal": journal or {},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        _gc(ckpt_dir, keep)

    if blocking:
        _write()
    else:
        th = threading.Thread(target=_write, daemon=True)
        th.start()
        _WRITERS.append(th)
    return ckpt_dir / f"step_{step}"


def _gc(ckpt_dir: pathlib.Path, keep: int) -> None:
    steps = sorted(
        (int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*")
         if not p.name.endswith(".tmp")))
    for s in steps[:-keep]:
        shutil.rmtree(ckpt_dir / f"step_{s}", ignore_errors=True)


def wait_for_writers() -> None:
    for th in list(_WRITERS):
        th.join()
        _WRITERS.remove(th)


def latest_step(ckpt_dir) -> Optional[int]:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*")
             if not p.name.endswith(".tmp")
             and (p / "manifest.json").exists()]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir, state_like, *, step: Optional[int] = None,
                       shardings=None) -> Tuple[Any, Dict]:
    """Restore into the structure of ``state_like``; optional sharding tree
    re-lays leaves onto the current mesh (elastic restore)."""
    wait_for_writers()
    ckpt_dir = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = ckpt_dir / f"step_{step}"
    manifest = json.loads((d / "manifest.json").read_text())
    with np.load(d / "leaves.npz") as z:
        flat = {k: z[k] for k in z.files}

    paths, treedef = jax.tree_util.tree_flatten_with_path(state_like)
    leaves = []
    for path, like in paths:
        key = "/".join(_path_str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key].astype(like.dtype) if hasattr(like, "dtype") else flat[key]
        leaves.append(arr)
    state = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s), state, shardings)
    return state, manifest["journal"]
