"""Backend registry — one mechanism for selecting how buckets compile.

Before this module, backend choice lived as string-ifs inside
``core/runtime.py`` (``"xla"`` vs ``"pallas"``) while the Nimble-VM
baseline was a separate class nobody could select uniformly.  Now a
:class:`Backend` bundles the two things a dispatcher needs:

* ``build_bucket``: produce the per-bucket-signature entry
  ``entry(lens_i32, *padded_arrays) -> outputs`` for one padded binding;
* ``build_exact``: produce the exact-shape executor used by §4.4 static
  escalation;
* ``cluster_kernels``: the fused-kernel registrations — a mapping from
  fusion-plan template (``"kLoop"`` / ``"kInput"`` / ``"kDot"``, see
  ``Cluster.template`` in ``core/fusion.py``) to a
  :class:`~repro.core.codegen.ClusterKernel` implementation.  Clusters
  whose template a backend registers execute through that kernel; the
  rest fall back to per-op XLA emission.  Codegen never string-checks the
  backend name.

Built-ins:

* ``"xla"``       — DHLO graph emitted through XLA, AOT-compiled per bucket
  (no cluster kernels)
* ``"pallas"``    — registers the three Pallas cluster kernels (kLoop /
  kInput / kDot); AOT-compiled per bucket
* ``"nimble_vm"`` — the interpreted baseline: the same masked executor, but
  *never jitted* — every call walks the graph op by op (Nimble's VM
  approach, kept selectable for honest §5.2 comparisons)

Third parties register their own with
``register_backend("mine", Backend(...))``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp

from ..core.codegen import (ClusterKernel, build_exact_executor,
                            build_padded_executor, pallas_cluster_kernels)
from ..core.dhlo import DGraph
from ..core.symshape import SymDim

__all__ = ["Backend", "UnknownBackendError", "register_backend",
           "get_backend", "list_backends"]


class UnknownBackendError(ValueError):
    """Raised when ``options.backend`` names no registered backend."""


@dataclass(frozen=True)
class Backend:
    """A named strategy for turning a lowered graph into executables.

    ``build_bucket(graph, plan, syms, padded, donate)`` returns the entry
    for one bucket signature — ``donate`` is ``True`` (donate every
    bucketed argument), a sequence of *parameter indices* the buffer
    plan proved dead before the graph ends (donate exactly those), or
    falsy; ``build_exact(graph, plan)`` returns the
    exact-shape executor for the static-escalation path;
    ``cluster_kernels`` maps fusion-plan templates to the
    :class:`~repro.core.codegen.ClusterKernel` objects that execute them.
    """

    name: str
    build_bucket: Callable[..., Any]
    build_exact: Callable[..., Callable]
    description: str = ""
    cluster_kernels: Mapping[str, ClusterKernel] = field(default_factory=dict)


def _padded_arg_sds(graph: DGraph, padded: Dict[int, int]):
    arg_sds = []
    for p in graph.params:
        shape = []
        for d in p.shape:
            if isinstance(d, SymDim):
                c = graph.store.canon_dim(d)
                shape.append(padded[c.uid] if isinstance(c, SymDim) else c)
            else:
                shape.append(d)
        arg_sds.append(jax.ShapeDtypeStruct(tuple(shape), p.dtype))
    return arg_sds


def _make_aot_backend(name: str, description: str,
                      cluster_kernels: Optional[Mapping[str, ClusterKernel]]
                      = None) -> Backend:
    """A backend that AOT-compiles each bucket entry through jax.jit,
    executing clusters through its registered ``cluster_kernels``."""
    kernels = dict(cluster_kernels or {})

    def build_bucket(graph: DGraph, plan, syms: Sequence[SymDim],
                     padded: Dict[int, int], donate: bool,
                     arg_shardings: Optional[Sequence[Any]] = None):
        # ``arg_shardings`` (SPMD dispatch): the (lens, *args) shardings
        # the generated host flow device_puts — the AOT entry must be
        # compiled against exactly those, so GSPMD partitions the bucket
        # executable over the mesh instead of rejecting the inputs
        executor = build_padded_executor(graph, padded, syms, plan=plan,
                                         kernels=kernels)
        lens_sds = jax.ShapeDtypeStruct((max(len(syms), 1),), jnp.int32)
        arg_sds = _padded_arg_sds(graph, padded)
        # donate: True → every bucketed arg; a sequence → the buffer
        # plan's provably-dead param indices (+1 skips the lens vector)
        if donate is True:
            donate_nums = tuple(range(1, 1 + len(arg_sds)))
        elif donate:
            donate_nums = tuple(1 + int(i) for i in donate)
        else:
            donate_nums = ()
        jit_kw = {}
        if arg_shardings is not None:
            jit_kw["in_shardings"] = tuple(arg_shardings)
        jfn = jax.jit(executor, donate_argnums=donate_nums, **jit_kw)
        return jfn.lower(lens_sds, *arg_sds).compile()

    def build_exact(graph: DGraph, plan):
        return jax.jit(build_exact_executor(graph, plan=plan,
                                            kernels=kernels))

    return Backend(name=name, build_bucket=build_bucket,
                   build_exact=build_exact, description=description,
                   cluster_kernels=kernels)


def _make_vm_backend() -> Backend:
    """The interpreted baseline: identical numerics, no AOT compile — every
    call walks the graph per op (what the paper calls the VM approach)."""

    def build_bucket(graph: DGraph, plan, syms: Sequence[SymDim],
                     padded: Dict[int, int], donate: bool):
        # NOT jitted: per-call graph walk + one dispatch per op.
        return build_padded_executor(graph, padded, syms, plan=None)

    def build_exact(graph: DGraph, plan):
        return build_exact_executor(graph)

    return Backend(
        name="nimble_vm", build_bucket=build_bucket, build_exact=build_exact,
        description="interpreted per-op baseline (Nimble-style VM)")


_REGISTRY: Dict[str, Backend] = {}


def register_backend(name: str, backend: Backend, *,
                     overwrite: bool = False) -> Backend:
    """Register ``backend`` under ``name`` (``options.backend=name``)."""
    if name in _REGISTRY and not overwrite:
        raise ValueError(
            f"backend {name!r} is already registered; pass overwrite=True "
            f"to replace it")
    _REGISTRY[name] = backend
    return backend


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise UnknownBackendError(
            f"unknown backend {name!r}; registered backends: "
            f"{sorted(_REGISTRY)}") from None


def list_backends() -> List[str]:
    return sorted(_REGISTRY)


register_backend("xla", _make_aot_backend(
    "xla", "DHLO emitted through XLA, AOT-compiled per bucket"))
register_backend("pallas", _make_aot_backend(
    "pallas",
    "kLoop/kInput/kDot clusters through fused Pallas kernels, rest XLA",
    cluster_kernels=pallas_cluster_kernels()))
register_backend("nimble_vm", _make_vm_backend())
