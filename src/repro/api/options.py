"""Public option objects for ``disc.compile`` — one place for every knob.

Historically the knobs were scattered: ``DiscEngine(...)`` kwargs, a
parallel ``ServeConfig``, and ad-hoc strings inside ``runtime.py``.
:class:`CompileOptions` consolidates them; :class:`Dim` makes symbolic
dimensions first-class values that carry their own bucketing contract
(``max``, ``multiple_of``) instead of smuggling it through a separately
constructed :class:`~repro.core.bucketing.BucketPolicy`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Optional, Sequence, Tuple, Union

import jax.numpy as jnp

from ..core.bucketing import BucketPolicy, POW2
from ..core.cache import CompileCache
from ..frontends.jaxpr_frontend import ArgSpec, TreeSpec

__all__ = ["Dim", "TreeSpec", "CompileOptions", "normalize_specs"]


@dataclass(frozen=True)
class Dim:
    """A named symbolic dimension with an optional bucketing contract.

    ``Dim("S", max=4096, multiple_of=8)`` in a spec shape means: dimension
    ``S`` is dynamic, never exceeds 4096 (buckets are clamped there, larger
    runtime values are a contract violation), and buckets are sized in
    multiples of 8.

    ``bucket`` selects the bucketing rule for this symbol:

    * ``"pow2"``     — granule·2^k buckets (log-many; the default)
    * ``"multiple"`` — multiples of ``multiple_of`` (linear-many, less
      padding waste; good when shapes cluster)
    * ``"exact"``    — no bucketing: one compile per concrete size (the
      static-compiler baseline)
    """

    name: str
    max: Optional[int] = None
    multiple_of: Optional[int] = None
    bucket: str = "pow2"

    def __post_init__(self):
        if not self.name or not isinstance(self.name, str):
            raise ValueError(f"Dim needs a non-empty string name, got {self.name!r}")
        if self.bucket not in ("pow2", "multiple", "exact"):
            raise ValueError(f"unknown bucket rule {self.bucket!r}")
        if self.max is not None and self.max < 1:
            raise ValueError(f"Dim {self.name}: max must be >= 1")
        if self.multiple_of is not None and self.multiple_of < 1:
            raise ValueError(f"Dim {self.name}: multiple_of must be >= 1")

    def policy_override(self) -> Optional[Tuple[str, Tuple[str, int]]]:
        """The per-symbol :class:`BucketPolicy` override this Dim implies."""
        if self.bucket == "exact":
            return (self.name, ("exact", 1))
        if self.multiple_of is not None:
            kind = "multiple" if self.bucket == "multiple" else "pow2"
            return (self.name, (kind, self.multiple_of))
        if self.bucket == "multiple":
            return (self.name, ("multiple", 16))
        return None


DimLike = Union[int, str, Dim]
SpecLike = Union[ArgSpec, TreeSpec, Tuple[DimLike, ...], None]


@dataclass(frozen=True)
class CompileOptions:
    """Every ``disc.compile`` knob, in one (immutable) place.

    * ``policy``               — default bucketing rule (per-``Dim``
      contracts are layered on top as overrides)
    * ``backend``              — registry name: ``"xla"``, ``"pallas"``,
      ``"nimble_vm"``, or anything registered via
      :func:`repro.api.register_backend`
    * ``escalation_threshold`` — §4.4 static/dynamic mix: exact signatures
      seen at least this many times get their own unpadded, unmasked
      specialization (``None`` disables).  Applies to *both* pipelines:
      the ``"dhlo"`` path escalates to the backend's exact executor, the
      ``"jit"`` path to a ``jax.jit`` of the raw function at the exact
      (unpadded) shapes
    * ``promote_on_change``    — spec-inference refinement: when specs
      were inferred from the first call, dims that merely coincided there
      are re-lowered as independent dims the moment a later call breaks
      the coincidence, instead of erroring or over-padding (on by
      default; only meaningful without declared specs)
    * ``max_cache_entries``    — LRU budget of the compile cache
    * ``donate``               — donate input buffers to the device
      executable (bucketed entries only)
    * ``memory_planning``      — bucket-generic symbolic buffer reuse
      (BladeDISC++): the plan built at ``lower()`` time compares live
      ranges' byte sizes *symbolically* (``eq``/``le`` proven from
      ``Dim.max``/``multiple_of`` facts) and shares slots across every
      bucket of the artifact.  Off, the planner falls back to one slot
      per value (the per-bucket baseline); outputs are bit-identical
      either way
    * ``plan_donation``        — let the plan mark dead-after-last-use
      parameters as donatable and realize in-place update ops
      (``dynamic_update_slice``/``scatter_add``) as buffer donations;
      with ``donate=True`` the jit/XLA path restricts ``donate_argnums``
      to exactly the plan's provably-dead arguments
    * ``pipeline``             — ``"dhlo"`` runs the full DISC pipeline
      (bridge → constraints → fusion → bucketed codegen → generated
      dispatch); ``"jit"`` skips the DHLO bridge and buckets a
      jax-traceable function directly (pytree-capable; used by the serving
      engine for whole-model prefill/decode)
    * ``mesh``                 — a ``jax.sharding.Mesh``: the artifact is
      lowered for SPMD execution over it.  The planner
      (:mod:`repro.dist.spmd`) emits per-argument ``NamedSharding``\\ s
      from the ``sharding_profile`` and *tightens the bucket policy* so
      every sharded dynamic dim's bucket is a multiple of the owning mesh
      axes' size (a plan-time constraint, raised at ``lower()`` when the
      ``Dim`` contract cannot satisfy it); the generated dispatch then
      ``device_put``\\ s padded buckets to their shardings and replicates
      lens vectors
    * ``sharding_profile``     — a profile name (``"dp"`` / ``"fsdp"`` /
      ``"tp"``) or a :class:`~repro.dist.profiles.ShardingProfile`;
      only meaningful with ``mesh`` (defaults to ``"dp"``)
    * ``cache``                — share a :class:`CompileCache` between
      several compiled artifacts (entries are keyed by per-artifact
      fingerprint and never collide)
    * ``fallback_backend``     — degradation ladder (robustness plane):
      the backend new compiles demote to once the configured backend's
      cluster kernels cross ``backend_demotion_strikes`` failed runs
      between them (``None`` disables demotion).  Individual failed
      kernels always fall back per-op and demote themselves after
      ``ClusterKernel.demote_after`` strikes regardless
    * ``name``                 — artifact name for diagnostics
    """

    policy: BucketPolicy = POW2
    backend: str = "xla"
    escalation_threshold: Optional[int] = None
    promote_on_change: bool = True
    max_cache_entries: int = 256
    donate: bool = False
    memory_planning: bool = True
    plan_donation: bool = True
    pipeline: str = "dhlo"
    mesh: Optional[Any] = None
    sharding_profile: Optional[Any] = None   # name or ShardingProfile
    cache: Optional[CompileCache] = None
    fallback_backend: str = "xla"
    backend_demotion_strikes: Optional[int] = 8
    name: str = "disc"

    def __post_init__(self):
        if self.pipeline not in ("dhlo", "jit"):
            raise ValueError(
                f"unknown pipeline {self.pipeline!r} (expected 'dhlo' or 'jit')")
        if self.sharding_profile is not None and self.mesh is None:
            raise ValueError(
                "CompileOptions(sharding_profile=...) needs a mesh: pass "
                "CompileOptions(mesh=..., sharding_profile=...)")
        if self.mesh is not None:
            from ..dist.profiles import get_profile
            get_profile(self.sharding_profile or "dp")  # validate early

    def replace(self, **kw) -> "CompileOptions":
        return dataclasses.replace(self, **kw)

    def policy_with_dims(self, dims: Sequence[Dim]) -> BucketPolicy:
        """Layer per-``Dim`` contracts onto the base policy."""
        overrides = list(self.policy.overrides)
        caps = list(self.policy.caps)
        for d in dims:
            ov = d.policy_override()
            if ov is not None and ov[0] not in [n for n, _ in overrides]:
                overrides.append(ov)
            if d.max is not None and d.name not in [n for n, _ in caps]:
                caps.append((d.name, d.max))
        if overrides == list(self.policy.overrides) and caps == list(self.policy.caps):
            return self.policy
        return dataclasses.replace(self.policy, overrides=tuple(overrides),
                                   caps=tuple(caps))


def normalize_specs(specs: Optional[Sequence[SpecLike]],
                    default_dtype=jnp.float32,
                    ) -> Tuple[Optional[Tuple[Optional[ArgSpec], ...]], Tuple[Dim, ...]]:
    """Normalize user-facing specs into ``ArgSpec``s + the ``Dim``s found.

    Accepts per argument: an :class:`ArgSpec`, a bare shape tuple whose
    entries are ints / symbol-name strings / :class:`Dim` objects, a
    :class:`TreeSpec` (pytree whose leaves share bucketed axes — jit
    pipeline only), or ``None`` (pass-through argument — only meaningful
    for the ``"jit"`` pipeline).  Returns ``(normalized, dims)``;
    ``normalized`` is ``None`` when ``specs`` is ``None`` (defer to
    first-call inference).
    """
    if specs is None:
        return None, ()
    dims: dict = {}
    explicit: set = set()  # names declared via a Dim object (vs bare string)

    def register(d: Union[str, Dim]) -> str:
        """Record one symbolic-dim occurrence; returns its name."""
        if isinstance(d, Dim):
            # only two *explicit* contracts can conflict — a bare string
            # occurrence of the same name just references this Dim
            if d.name in explicit and dims[d.name] != d:
                raise ValueError(
                    f"Dim {d.name!r} declared twice with different "
                    f"contracts: {dims[d.name]} vs {d}")
            dims[d.name] = d
            explicit.add(d.name)
            return d.name
        dims.setdefault(d, Dim(d))
        return d

    out = []
    for spec in specs:
        if spec is None:
            out.append(None)
            continue
        if isinstance(spec, TreeSpec):
            out.append(TreeSpec(tuple(
                (axis, register(d)) for axis, d in spec.axes)))
            continue
        if isinstance(spec, ArgSpec):
            shape, dtype, name = spec.shape, spec.dtype, spec.name
        elif isinstance(spec, tuple) and all(
                isinstance(d, (int, str, Dim)) for d in spec):
            shape, dtype, name = spec, default_dtype, ""
        elif isinstance(spec, tuple) and len(spec) in (2, 3) and isinstance(spec[0], (tuple, list)):
            shape = tuple(spec[0])
            dtype = spec[1]
            name = spec[2] if len(spec) == 3 else ""
        else:
            raise TypeError(
                f"cannot interpret spec {spec!r}: expected ArgSpec, shape "
                f"tuple, (shape, dtype[, name]) or None")
        norm_shape = []
        for d in shape:
            if isinstance(d, (Dim, str)):
                norm_shape.append(register(d))
            else:
                norm_shape.append(int(d))
        out.append(ArgSpec(tuple(norm_shape), dtype, name))
    return tuple(out), tuple(dims.values())
