"""``repro.api`` — the single public entry point to the DISC compiler.

Everything user-facing hangs off this package (aliased as the top-level
``disc`` module)::

    import disc

    @disc.compile
    def f(x, w): ...

    f2 = disc.compile(f, [(disc.Dim("S", max=4096, multiple_of=8), 64),
                          (64, 32)],
                      options=disc.CompileOptions(backend="pallas"))
    lowered  = f2.lower()        # DHLO graph + fusion/placement/buffer plans
    compiled = lowered.compile() # generated dispatcher
    compiled.dispatch_source     # the generated host flow, as text
    compiled.cache_stats()       # O(#buckets) compile contract, observable

Backends (``xla``, ``pallas``, ``nimble_vm``, or your own via
:func:`register_backend`) are selected by name through
``CompileOptions.backend``.  The serving layer (:class:`ServeEngine`) and
the baselines/benchmark helpers are re-exported here so examples and
benchmarks never reach into ``repro.core`` / ``repro.frontends``
internals.
"""
from ..core.bucketing import BucketPolicy, EXACT, POW2, pow2_bucket  # noqa: F401
from ..core.cache import CompileCache, CacheStats  # noqa: F401
from ..errors import (  # noqa: F401
    CompileError,
    DeadlineExceeded,
    DiscError,
    LaunchError,
    PoolExhausted,
    RetryPolicy,
)
from ..core.vm import NimbleVM  # noqa: F401
from ..ft import faults  # noqa: F401
from ..ft.faults import FaultInjector, FaultSpec  # noqa: F401
from ..dist import (  # noqa: F401
    ShardingProfile, get_mesh, get_profile, list_profiles, make_mesh,
    use_mesh,
)
from ..frontends.jaxpr_frontend import ArgSpec, bridge  # noqa: F401
from ..obs import Observe, Tracer, observe  # noqa: F401
from .backends import (  # noqa: F401
    Backend,
    UnknownBackendError,
    get_backend,
    list_backends,
    register_backend,
)
from .options import CompileOptions, Dim, TreeSpec  # noqa: F401
from .staged import Compiled, CompiledFunction, Lowered, compile, infer_specs  # noqa: F401

__all__ = [
    # staged pipeline
    "compile", "CompiledFunction", "Lowered", "Compiled", "infer_specs",
    # options
    "CompileOptions", "Dim", "TreeSpec", "ArgSpec",
    # backends
    "Backend", "register_backend", "get_backend", "list_backends",
    "UnknownBackendError",
    # bucketing / caching
    "BucketPolicy", "POW2", "EXACT", "pow2_bucket", "CompileCache",
    "CacheStats",
    # error taxonomy + fault injection (robustness plane)
    "DiscError", "CompileError", "LaunchError", "PoolExhausted",
    "DeadlineExceeded", "RetryPolicy", "faults", "FaultSpec",
    "FaultInjector",
    # SPMD / distribution
    "ShardingProfile", "get_profile", "list_profiles", "make_mesh",
    "use_mesh", "get_mesh",
    # observability plane
    "observe", "Observe", "Tracer",
    # baselines & serving
    "NimbleVM", "bridge", "ServeEngine", "ServeConfig",
    "ADMISSION_POLICIES",
]


def __getattr__(name):
    # serving imports models/configs; keep it lazy so `import disc` stays
    # light and the core API never depends on the model zoo
    if name in ("ServeEngine", "ServeConfig"):
        from ..serve.engine import ServeConfig, ServeEngine
        return {"ServeEngine": ServeEngine, "ServeConfig": ServeConfig}[name]
    if name == "ADMISSION_POLICIES":
        from ..serve.policies import ADMISSION_POLICIES
        return ADMISSION_POLICIES
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
