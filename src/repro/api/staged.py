"""The staged public pipeline: ``disc.compile(fn) → lower() → compile()``.

Mirrors JAX's AOT staging (``jit(f).lower(...).compile()``) for the whole
DISC compiler:

* :func:`compile` returns a :class:`CompiledFunction` — callable
  immediately (lowering/compiling happens on demand, with spec inference
  from the first call when no specs were given), and stageable explicitly;
* :class:`Lowered` holds the inspectable compile-time artifacts (DHLO
  graph, fusion / placement / buffer plans, dynamic symbols) before any
  device code exists;
* :class:`Compiled` owns the generated host dispatcher plus the per-bucket
  compile cache, and exposes ``dispatch_source`` / ``cache_stats()`` /
  ``compile_counts()`` for introspection.

Two pipelines share this surface (selected by
``CompileOptions.pipeline``):

* ``"dhlo"`` — the paper's full pipeline: jaxpr → DHLO bridge, shape
  constraints, fusion, placement, buffers, bucketed per-backend codegen,
  generated host dispatch with output recovery.
* ``"jit"``  — bucketed dispatch over a jax-traceable function *without*
  bridging it through DHLO: declared dynamic args are bucket-padded and
  one ``jax.jit`` entry is cached per bucket signature.  Pytree args pass
  through untouched (spec ``None``), so whole models (params/KV-cache
  trees) get the O(#buckets) compile contract — this is what the serving
  engine builds prefill/decode on.

Both pipelines share one host-dispatch emitter
(:func:`repro.core.dispatcher.generate_dispatch`), parameterized by a
``DispatchLens`` — so §4.4 static escalation (hot exact signatures get an
unpadded specialization) and the tie guards behind promote-on-change work
identically under either.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from ..core.bucketing import BucketPolicy
from ..core.cache import CompileCache
from ..errors import CONTROL_EXCEPTIONS, CompileError, classify_transient
from ..core.codegen import dyn_symbols
from ..core.dispatcher import dhlo_lens, generate_dispatch, jit_lens
from ..core.symshape import SymDim
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from ..frontends.jaxpr_frontend import ArgSpec, TreeSpec, bridge
from .backends import get_backend
from .options import CompileOptions, Dim, normalize_specs

__all__ = ["compile", "CompiledFunction", "Lowered", "Compiled"]


# ------------------------------------------------------------- inference --

def infer_specs(arrays: Sequence[Any]) -> List[ArgSpec]:
    """Infer ``ArgSpec``s from one call's concrete arguments.

    Every axis of size > 1 becomes a symbolic dim; axes sharing a size in
    this call share a symbol (so contractions stay well-typed when traced
    at representative sizes).  Size-1 axes stay static (broadcasting).
    The inferred profile is exact for any later call with the same
    equality structure; distinct dims that *happened* to coincide on the
    first call are tied — declare specs explicitly to untie them.
    """
    by_size: Dict[int, str] = {}
    specs: List[ArgSpec] = []
    for a in arrays:
        ashape = np.shape(a)
        dtype = getattr(a, "dtype", None)
        if dtype is None:
            dtype = np.asarray(a).dtype
        shape = []
        for size in ashape:
            if size <= 1:
                shape.append(int(size))
            else:
                shape.append(by_size.setdefault(int(size), f"d{size}"))
        specs.append(ArgSpec(tuple(shape), dtype))
    return specs


def _graph_const_token(graph) -> str:
    """Hash of a DHLO graph's literal payloads, in deterministic order.

    Recurses into region ops' nested body graphs (attrs holding a
    ``DGraph`` or a tuple of them) — a region's closure constants are as
    cache-relevant as top-level literals.
    """
    from ..core.dhlo import DGraph

    h = hashlib.sha1()
    seen = set()

    def walk(g) -> None:
        for op in g.ops:
            for v in list(op.inputs) + list(op.shape_operands):
                if v.literal is not None and v.vid not in seen:
                    seen.add(v.vid)
                    arr = np.asarray(v.literal)
                    h.update(str(arr.dtype).encode())
                    h.update(repr(arr.shape).encode())
                    h.update(arr.tobytes())
            for av in op.attrs.values():
                if isinstance(av, DGraph):
                    walk(av)
                elif isinstance(av, (tuple, list)):
                    for x in av:
                        if isinstance(x, DGraph):
                            walk(x)

    walk(graph)
    return h.hexdigest()[:16]


def _fn_token(fn: Callable) -> str:
    """An identity token for ``fn`` (code, closure, bound instance).

    Process-local: bound methods are distinguished by instance identity
    (two engines sharing one cache must never serve each other's
    closures), so tokens are not stable across processes — fine for an
    in-memory compile cache.
    """
    parts: List[str] = []
    base = getattr(fn, "__func__", fn)
    self_obj = getattr(fn, "__self__", None)
    if self_obj is not None:
        parts.append(type(self_obj).__qualname__)
        parts.append(str(id(self_obj)))
    code = getattr(base, "__code__", None)
    if code is None:
        parts.append(repr(base))
    else:
        parts.append(getattr(base, "__qualname__", ""))
        parts.append(hashlib.sha1(code.co_code).hexdigest())
        parts.append(repr(code.co_consts)[:2000])
        for cell in base.__closure__ or ():
            try:
                parts.append(repr(cell.cell_contents)[:200])
            except ValueError:  # empty cell
                parts.append("<empty>")
    return "\x00".join(parts)


# --------------------------------------------------------------- lowered --

@dataclass
class Lowered:
    """Compile-time artifacts of one function at one spec signature.

    For the ``"dhlo"`` pipeline all plan fields are populated; for the
    ``"jit"`` pipeline only ``specs`` / ``sym_names`` are (there is no hub
    IR — the function is staged directly through ``jax.jit`` per bucket).
    """

    fn: Callable
    specs: Tuple[Optional[ArgSpec], ...]
    options: CompileOptions
    policy: BucketPolicy
    pipeline: str
    graph: Any = None
    plan: Any = None              # FusionPlan
    placement: Any = None
    buffer_plan: Any = None
    syms: Tuple[SymDim, ...] = ()
    sym_names: Tuple[str, ...] = ()
    # SPMD ShardingPlan when lowered under CompileOptions(mesh=...);
    # ``policy`` is then the planner-tightened policy (sharded dynamic
    # dims' buckets are mesh-axis multiples)
    sharding_plan: Any = None

    def _spmd_token(self) -> str:
        """Distinguish same-pattern artifacts lowered for different
        meshes/profiles: their bucket entries are compiled against
        different shardings and must never share cache entries.  Device
        identity is part of the token — two same-shape meshes over
        different device sets produce incompatible executables."""
        if self.sharding_plan is None:
            return ""
        device_ids = tuple(
            d.id for d in self.sharding_plan.mesh.devices.flat)
        h = hashlib.sha1((repr(self.sharding_plan.report())
                          + repr(device_ids)).encode())
        return "+spmd:" + h.hexdigest()[:12]

    def fingerprint(self) -> str:
        if self.graph is not None:
            # DGraph.fingerprint() is deliberately shape-free AND
            # constant-free (the per-engine cache-key property).  As a
            # *shared*-cache key that is too weak: two graphs with the same
            # wiring but different literal payloads must not collide, so
            # the artifact fingerprint folds the constants in (and the
            # SPMD plan, when lowered under a mesh).
            return (self.graph.fingerprint() + "+"
                    + _graph_const_token(self.graph) + self._spmd_token())
        # jit pipeline has no shape-free graph fingerprint; identify the
        # artifact by the *function* (code + closure + bound self) plus the
        # spec signature, so distinct functions sharing one CompileCache
        # can never hit each other's entries
        def _sig(s):
            if s is None:
                return None
            if isinstance(s, TreeSpec):
                return ("tree", s.axes)
            return (s.shape, str(np.dtype(s.dtype)))

        sig = repr([_sig(s) for s in self.specs])
        h = hashlib.sha1((sig + "\x00" + _fn_token(self.fn)).encode())
        return (f"jit:{self.options.name}:{h.hexdigest()[:16]}"
                + self._spmd_token())

    def compile(self, options: Optional[CompileOptions] = None, *,
                on_tie_break: Optional[Callable] = None) -> "Compiled":
        """Build the dispatcher (device code still compiles per bucket,
        lazily, through the backend registry).

        ``options`` may override backend / cache / escalation at this
        stage; the bucketing policy is part of the lowering contract
        (``Dim`` markers were folded into it) and stays fixed.
        ``on_tie_break`` handles a call that breaks a multi-site symbol
        tie (:class:`CompiledFunction` wires promote-on-change through
        it); without a handler such a call raises a contract error.
        """
        return Compiled(self, options or self.options,
                        on_tie_break=on_tie_break)

    def as_text(self) -> str:
        """Human-readable summary of the lowering (inspectable stage)."""
        lines = [f"Lowered({self.options.name!r}, pipeline={self.pipeline!r})"]
        lines.append(f"  fingerprint: {self.fingerprint()}")
        lines.append(f"  dynamic symbols: {list(self.sym_names)}")
        if self.graph is not None:
            lines.append(f"  params: {len(self.graph.params)}  "
                         f"ops: {len(self.graph.ops)}  "
                         f"outputs: {len(self.graph.outputs)}")
            lines.append(f"  fusion: {self.plan.stats()}")
            lines.append(f"  placement: {self.placement.report()}")
            lines.append(f"  constraints: {self.graph.store.stats()}")
        else:
            lines.append("  (no DHLO graph: jit pipeline stages the "
                         "function directly per bucket)")
        return "\n".join(lines)


def _lower(fn: Callable, specs: Sequence[Optional[ArgSpec]],
           dims: Sequence[Dim], options: CompileOptions) -> Lowered:
    sp = (obs_trace.ACTIVE.begin("lower", cat="compile",
                                 artifact=options.name,
                                 pipeline=options.pipeline)
          if obs_trace.ACTIVE is not None else None)
    try:
        return _lower_impl(fn, specs, dims, options)
    finally:
        if sp is not None:
            sp.end()


def _lower_impl(fn: Callable, specs: Sequence[Optional[ArgSpec]],
                dims: Sequence[Dim], options: CompileOptions) -> Lowered:
    policy = options.policy_with_dims(dims)
    sharding_plan = None
    if options.mesh is not None:
        # SPMD planning happens at lower() time: per-arg shardings are
        # derived from the profile and the policy is tightened so every
        # sharded dynamic dim's bucket divides the mesh axes evenly
        # (ConstraintViolation here when the Dim contract cannot comply)
        from ..dist.profiles import get_profile
        from ..dist.spmd import plan_spmd
        profile = get_profile(options.sharding_profile or "dp")
        sharding_plan, policy = plan_spmd(specs, policy, options.mesh,
                                          profile)
    if options.pipeline == "jit":
        sym_names: List[str] = []
        for s in specs:
            if s is None:
                continue
            names = ([d for _, d in s.axes] if isinstance(s, TreeSpec)
                     else [d for d in s.shape if isinstance(d, str)])
            for d in names:
                if d not in sym_names:
                    sym_names.append(d)
        return Lowered(fn=fn, specs=tuple(specs), options=options,
                       policy=policy, pipeline="jit",
                       sym_names=tuple(sym_names),
                       sharding_plan=sharding_plan)

    if any(not isinstance(s, ArgSpec) for s in specs):
        raise ValueError(
            "the 'dhlo' pipeline needs an ArgSpec for every argument "
            "(None pass-through and TreeSpec pytree specs are only "
            "supported by CompileOptions(pipeline='jit'))")
    from ..core.fusion import plan_fusion
    from ..core.placer import place
    from ..core.buffers import plan_buffers

    graph, _ = bridge(fn, list(specs), name=options.name,
                      bounds={d.name: d.max for d in dims
                              if d.max is not None})
    plan = plan_fusion(graph)
    placement = place(graph, mesh=options.mesh)
    # bucket-generic symbolic memory plan, decided ONCE here — every
    # bucket entry, the VM, and donate_argnums realize the same plan
    buffer_plan = plan_buffers(graph, policy,
                               symbolic=options.memory_planning,
                               donation=options.plan_donation)
    buffer_plan.lines_text = buffer_plan.render_lines(graph)
    graph.memory_plan = buffer_plan
    syms = tuple(dyn_symbols(graph))
    if sharding_plan is not None:
        # surface the plan-time divisibility facts in the constraint
        # store (report()["constraints"]["mesh_constraints"])
        for c in sharding_plan.constraints:
            graph.store.note_mesh_divisible(c.dim, c.axes, c.multiple_of)
    return Lowered(fn=fn, specs=tuple(specs), options=options,
                   policy=policy, pipeline="dhlo", graph=graph, plan=plan,
                   placement=placement, buffer_plan=buffer_plan, syms=syms,
                   sym_names=tuple(s.name for s in syms),
                   sharding_plan=sharding_plan)


# -------------------------------------------------------------- compiled --

class Compiled:
    """The executable artifact: generated host dispatch + compile cache.

    Both pipelines flow through the one emitter in
    :mod:`repro.core.dispatcher`; all that differs is the
    :class:`~repro.core.dispatcher.DispatchLens` (how sizes are observed,
    what gets padded, whether outputs are recovered) and the per-bucket /
    exact compile callbacks (backend registry vs ``jax.jit``).  That means
    the jit pipeline gets the §4.4 static-escalation branch and the tie
    guards for free.
    """

    def __init__(self, lowered: Lowered, options: CompileOptions,
                 on_tie_break: Optional[Callable] = None) -> None:
        self.lowered = lowered
        self.options = options
        self.backend = get_backend(options.backend)
        self._fingerprint = lowered.fingerprint()
        self.cache = options.cache if options.cache is not None else \
            CompileCache(self._fingerprint,
                         max_entries=options.max_cache_entries,
                         escalation_threshold=options.escalation_threshold)
        self._bucket_compiles = 0
        self._exact_compiles = 0
        if lowered.pipeline == "dhlo":
            lens = dhlo_lens(lowered.graph, lowered.syms)
            compile_bucket = self._compile_bucket
            compile_exact = self._compile_exact
        else:
            lens = jit_lens(lowered.specs, lowered.sym_names,
                            name=options.name)
            compile_bucket = self._compile_jit_bucket
            compile_exact = self._compile_jit_exact
        self._dispatch, self.dispatch_source = generate_dispatch(
            lens, lowered.policy, self.cache, compile_bucket, compile_exact,
            fingerprint=self._fingerprint,
            escalation_threshold=options.escalation_threshold,
            on_tie_break=on_tie_break,
            sharding=lowered.sharding_plan,
            memory_plan=lowered.buffer_plan)
        self._mstats = self._dispatch._mstats
        obs_metrics.register_collector("dispatch", self._obs_dispatch,
                                       name=options.name)
        obs_metrics.register_collector("memory", self._obs_memory,
                                       name=options.name)

    def _obs_dispatch(self) -> Dict[str, Any]:
        """Pull collector: ``disc.observe()["dispatch"][name]``."""
        return self._mstats.cost_dict()

    def _obs_memory(self) -> Dict[str, Any]:
        """Pull collector: ``disc.observe()["memory"][name]`` (the light
        staging view; ``memory_report()`` has the full per-bucket plan)."""
        return dict(self._mstats.as_dict(),
                    planning=bool(self.options.memory_planning
                                  and self.lowered.pipeline == "dhlo"))

    # ------------------------------------------------------------ public --
    def __call__(self, *arrays):
        outs = self._dispatch(arrays)
        if self.lowered.pipeline == "jit":
            return outs
        return outs[0] if len(outs) == 1 else tuple(outs)

    @property
    def graph(self):
        return self.lowered.graph

    @property
    def plan(self):
        return self.lowered.plan

    @property
    def placement(self):
        return self.lowered.placement

    @property
    def buffer_plan(self):
        return self.lowered.buffer_plan

    @property
    def syms(self):
        return list(self.lowered.syms)

    @property
    def policy(self) -> BucketPolicy:
        return self.lowered.policy

    @property
    def n_compiles(self) -> int:
        return self._bucket_compiles + self._exact_compiles

    def cache_stats(self) -> Dict[str, float]:
        return self.cache.stats.as_dict()

    def cost_report(self) -> Dict[str, Any]:
        """Dynamic-shape cost accounting for this artifact: per-bucket
        hit histogram, padding-waste ratio (padded vs true bytes per
        launch), and the host-dispatch vs entry-call wall split."""
        return self._mstats.cost_dict()

    def compile_counts(self) -> Dict[str, int]:
        """Per-artifact compile counts (meaningful under shared caches)."""
        return {"bucket": self._bucket_compiles,
                "exact": self._exact_compiles,
                "total": self._bucket_compiles + self._exact_compiles}

    def report(self) -> Dict[str, Any]:
        rep: Dict[str, Any] = {
            "fingerprint": self._fingerprint,
            "backend": self.backend.name,
            "pipeline": self.lowered.pipeline,
            "cache": self.cache_stats(),
            "compiles": self.compile_counts(),
            "dynamic_symbols": list(self.lowered.sym_names),
        }
        low = self.lowered
        if low.sharding_plan is not None:
            # emitted per-arg shardings + mesh-divisibility constraints
            rep["sharding"] = low.sharding_plan.report()
        if low.graph is not None:
            templates = low.plan.template_counts()
            covered = sum(n for t, n in templates.items()
                          if t in self.backend.cluster_kernels) \
                if self.backend.cluster_kernels else 0
            rep.update({
                "fusion": low.plan.stats(),
                "placement": low.placement.report(),
                "constraints": low.graph.store.stats(),
                # clusters eligible for a fused-kernel template (plan
                # property) vs covered by THIS backend's registrations
                "pallas_eligible_clusters": sum(templates.values()),
                "cluster_templates": templates,
                "backend_covered_clusters": covered,
            })
        rep["memory"] = self.memory_report()
        rep["dispatch_cost"] = self.cost_report()
        return rep

    def memory_report(self) -> Dict[str, Any]:
        """The ``report()["memory"]`` section: the bucket-generic plan
        (symbolic peaks + reuse counts), concrete per-bucket peaks for
        every bucket this artifact has compiled, and the dispatch's
        staging-buffer accounting.  Documented in ``docs/api.md``."""
        low = self.lowered
        mem: Dict[str, Any] = {
            "planning": bool(self.options.memory_planning
                             and low.pipeline == "dhlo"),
            "staging": self._mstats.as_dict(),
        }
        plan = low.buffer_plan
        if plan is None:
            return mem
        mem.update({
            "values": plan.n_values,
            "slots": plan.n_slots,
            "reuse_counts": dict(plan.reuse_counts),
            "donatable_args": list(plan.donatable_args),
            "symbolic_peak": plan.symbolic_peak(),
            "symbolic_peak_no_reuse": plan.symbolic_peak_no_reuse(),
        })
        per_bucket: Dict[str, Any] = {}
        for k in list(self.cache._entries):
            if len(k) != 3 or k[0] != "bucket" or k[1] != self._fingerprint:
                continue
            bindings = {s.uid: int(v) for s, v in zip(low.syms, k[2])}
            peaks = plan.concrete_peaks(low.graph, bindings)
            reduction = (peaks["no_reuse_bytes"] / peaks["arena_bytes"]
                         if peaks["arena_bytes"] else 1.0)
            per_bucket[str(tuple(k[2]))] = {
                **peaks, "reduction": round(reduction, 3)}
        mem["per_bucket"] = per_bucket
        return mem

    # ------------------------------------------------- device compilation --
    def _maybe_demote_backend(self) -> None:
        """Degradation ladder, backend rung: when this backend's cluster
        kernels have accumulated ``backend_demotion_strikes`` failed runs
        between them, new bucket/exact compiles build through the
        fallback backend (``xla``) instead — already-compiled entries
        keep serving (their clusters already fell back per-op)."""
        strikes_cap = self.options.backend_demotion_strikes
        kernels = self.backend.cluster_kernels
        if (strikes_cap is None or not kernels
                or self.backend.name == self.options.fallback_backend):
            return
        total = sum(k.strikes for k in kernels.values())
        if total >= strikes_cap:
            from ..core.codegen import KERNEL_DEMOTIONS
            KERNEL_DEMOTIONS.append(
                f"backend:{self.backend.name}->"
                f"{self.options.fallback_backend} after {total} strikes")
            obs_metrics.record_event(
                "backend.demote", artifact=self.options.name,
                backend=self.backend.name,
                fallback=self.options.fallback_backend, strikes=total)
            self.backend = get_backend(self.options.fallback_backend)

    def _compile_bucket(self, key: Tuple[int, ...]):
        self._maybe_demote_backend()
        low = self.lowered
        padded = {s.uid: int(k) for s, k in zip(low.syms, key)}
        self._bucket_compiles += 1
        donate = self.options.donate
        if donate and self.options.plan_donation and low.buffer_plan is not None:
            # realize the plan: donate exactly the params it proved dead
            # before the graph ends (never an aliased output / live arg)
            donate = low.buffer_plan.donatable_args
        if low.sharding_plan is not None:
            import inspect

            # AOT entries must compile against the exact input shardings
            # the generated dispatch device_puts: (lens, *args)
            shardings = (low.sharding_plan.lens_sharding(),
                         *(low.sharding_plan.arg_sharding(i)
                           for i in range(len(low.specs))))
            params = inspect.signature(self.backend.build_bucket).parameters
            if "arg_shardings" not in params and not any(
                    p.kind is inspect.Parameter.VAR_KEYWORD
                    for p in params.values()):
                # failing loudly here beats the far-away input-sharding
                # mismatch the AOT entry would raise at first call (the
                # generated dispatch device_puts inputs onto the mesh)
                raise ValueError(
                    f"backend {self.backend.name!r} cannot compile under "
                    f"CompileOptions(mesh=...): its build_bucket accepts "
                    f"no 'arg_shardings' keyword — add the parameter "
                    f"(see repro.api.backends) or compile without a mesh")
            return self.backend.build_bucket(
                low.graph, low.plan, low.syms, padded,
                donate, arg_shardings=shardings)
        return self.backend.build_bucket(low.graph, low.plan, low.syms,
                                         padded, donate)

    def _compile_exact(self):
        # a fresh executor per escalated signature (each cache entry is
        # hit by exactly one exact shape): if the LRU evicts the entry —
        # or promote-on-change purges it — its compiled executable is
        # actually freed, instead of living on inside a shared wrapper's
        # trace cache
        self._maybe_demote_backend()
        self._exact_compiles += 1
        return self.backend.build_exact(self.lowered.graph,
                                        self.lowered.plan)

    # ----------------------------------------------------- jit pipeline --
    def _compile_jit_bucket(self, key: Tuple[int, ...]):
        """One ``jax.jit`` entry per bucket signature: the dispatch pads
        dynamic args to the bucket, so the entry traces exactly once."""
        self._bucket_compiles += 1
        return jax.jit(self.lowered.fn)

    def _compile_jit_exact(self):
        """§4.4 for the jit pipeline: the escalated path calls the
        function at *unpadded* shapes, so hot shapes get a mask/padding-
        free compile.  One fresh ``jax.jit`` wrapper per escalated
        signature: the cache's LRU budget then genuinely bounds escalated
        executables (a single shared wrapper would retain every trace in
        its own cache, immune to eviction)."""
        self._exact_compiles += 1
        return jax.jit(self.lowered.fn)


# ------------------------------------------------------ public entrypoint --

def _split_tied_specs(specs: Sequence[Optional[ArgSpec]],
                      arrays: Sequence[Any]) -> Tuple[Optional[ArgSpec], ...]:
    """Refine an inferred spec profile against one call's observed sizes.

    Symbols whose sites no longer agree are split: each subgroup of sites
    that share a size in *this* call gets its own symbol (the subgroup
    containing the extraction site keeps the original name).  Sites that
    still coincide stay tied — the profile refines monotonically, one
    broken coincidence at a time, instead of over-constraining forever.
    """
    sizes: Dict[Tuple[int, int], int] = {}
    groups: Dict[str, List[Tuple[int, int]]] = {}
    for ai, spec in enumerate(specs):
        if spec is None:
            continue
        shape = np.shape(arrays[ai])
        for ax, d in enumerate(spec.shape):
            if isinstance(d, str):
                sizes[(ai, ax)] = int(shape[ax])
                groups.setdefault(d, []).append((ai, ax))

    used = set(groups)
    renames: Dict[Tuple[int, int], str] = {}
    for name, sites in groups.items():
        by_size: Dict[int, List[Tuple[int, int]]] = {}
        for site in sites:
            by_size.setdefault(sizes[site], []).append(site)
        if len(by_size) == 1:
            continue  # this tie survived the call
        keep = sizes[sites[0]]  # extraction-site subgroup keeps the name
        for size, subsites in by_size.items():
            if size == keep:
                continue
            new = f"{name}_{size}"
            while new in used:
                new += "_"
            used.add(new)
            for site in subsites:
                renames[site] = new

    out: List[Optional[ArgSpec]] = []
    for ai, spec in enumerate(specs):
        if spec is None:
            out.append(None)
            continue
        shape = tuple(renames.get((ai, ax), d)
                      for ax, d in enumerate(spec.shape))
        out.append(ArgSpec(shape, spec.dtype, spec.name))
    return tuple(out)


class CompiledFunction:
    """What ``disc.compile`` returns: callable now, stageable explicitly.

    * with specs: lowering + dispatcher generation happen eagerly (device
      code still compiles per bucket on demand);
    * without specs: the first call infers them (:func:`infer_specs`), and
      the inferred profile *refines itself*: dims that merely coincided on
      the first call are re-lowered as independent dims the moment a later
      call breaks the coincidence (promote-on-change — disable with
      ``CompileOptions(promote_on_change=False)``).

    Attribute access falls through to the underlying :class:`Compiled`
    artifact (``plan``, ``report()``, ``n_compiles``, ...), so migrating
    from ``DiscEngine`` is a constructor swap.
    """

    def __init__(self, fn: Callable,
                 specs: Optional[Sequence[Any]] = None,
                 options: Optional[CompileOptions] = None, **kw) -> None:
        if options is None:
            options = CompileOptions(**kw)
        elif kw:
            options = options.replace(**kw)
        self.fn = fn
        self.options = options
        self._specs, self._dims = normalize_specs(specs)
        self._inferred = False
        self._lowered: Optional[Lowered] = None
        self._compiled: Optional[Compiled] = None
        if self._specs is not None:
            self._ensure()

    # ------------------------------------------------------------ staging --
    def lower(self, specs: Optional[Sequence[Any]] = None) -> Lowered:
        """Stage 1: produce the inspectable compile-time artifacts."""
        if specs is not None:
            norm, dims = normalize_specs(specs)
            return _lower(self.fn, norm, dims, self.options)
        if self._specs is None:
            raise ValueError(
                "no specs declared and none inferred yet — pass specs to "
                "lower(), declare them in disc.compile(fn, specs), or call "
                "the function once to infer them")
        if self._lowered is None:
            self._lowered = _lower(self.fn, self._specs, self._dims,
                                   self.options)
        return self._lowered

    def _ensure(self) -> Compiled:
        if self._compiled is None:
            handler = self._promote if (
                self._inferred and self.options.promote_on_change) else None
            self._compiled = self.lower().compile(on_tie_break=handler)
        return self._compiled

    def _promote(self, arrays):
        """Promote-on-change: a call broke a dim tie the first-call
        inference assumed, so split the tied symbols by the observed sizes
        and re-lower.  The compile cache carries over (stats continuity;
        the refined artifact's keys carry strictly more symbols, so they
        can never collide with the superseded artifact's — even under the
        dhlo pipeline, whose shape-free graph fingerprint is *unchanged*
        by the re-lower) and the superseded entries are purged."""
        split = _split_tied_specs(self._specs, arrays)
        if split == self._specs:
            # a stale handle to a *superseded* artifact fired its guard,
            # but the live profile already accommodates this call (its
            # tied groups all agree on these sizes) — redispatch through
            # the live artifact instead of re-lowering a third one
            return self._ensure()._dispatch(arrays)
        snapshot = (self._specs, self.options, self._lowered, self._compiled)
        prev = self._compiled
        self._specs = split
        self.options = self.options.replace(cache=prev.cache)
        self._lowered = None
        self._compiled = None
        try:
            compiled = self._ensure()
        except CONTROL_EXCEPTIONS:
            # never swallow control flow — but still roll back so the
            # pre-promotion artifact survives an interrupt mid-re-lower
            self._specs, self.options, self._lowered, self._compiled = \
                snapshot
            raise
        except Exception as e:
            # roll back: the pre-promotion artifact stays valid for calls
            # that respect the original ties.  Classify before wrapping
            # (a transient backend OOM mid-re-lower is retryable; a
            # genuine equality requirement is not) and chain the original
            # error class into the raised CompileError.
            self._specs, self.options, self._lowered, self._compiled = \
                snapshot
            raise CompileError(
                f"promote-on-change failed for {self.options.name!r}: a "
                f"call broke a dim tie inferred from the first call, but "
                f"re-lowering with independent dims "
                f"{[s.shape for s in split if s is not None]} did not "
                f"succeed — the function itself may require the equality "
                f"({type(e).__name__}: {e})",
                transient=classify_transient(e)) from e
        prev.cache.stats.promotions += 1
        obs_metrics.record_event(
            "promote", artifact=self.options.name,
            symbols=list(compiled.lowered.sym_names))
        # the superseded artifact's entries are unreachable — free the
        # executables they pin.  This must happen before the refined
        # artifact compiles its first bucket: under the dhlo pipeline the
        # two artifacts share a (shape-free) fingerprint, and the refined
        # artifact has compiled nothing yet, so everything under the old
        # fingerprint is the old artifact's.
        prev.cache.drop_fingerprint(prev._fingerprint)
        # hand the triggering call to the refined artifact's dispatch (the
        # raw dispatch-level result: the caller is the *old* artifact's
        # generated flow, whose __call__ wrapper still post-processes it)
        return compiled._dispatch(arrays)

    # ------------------------------------------------------------ calling --
    def __call__(self, *arrays):
        if self._compiled is None:
            if self._specs is None:
                if self.options.pipeline == "jit":
                    # no declared dynamic dims: every arg passes through
                    self._specs = (None,) * len(arrays)
                else:
                    self._specs = tuple(infer_specs(arrays))
                    self._inferred = True
            self._ensure()
        return self._compiled(*arrays)

    def __getattr__(self, item):
        compiled = object.__getattribute__(self, "_compiled")
        if compiled is None:
            raise AttributeError(
                f"{item!r} is unavailable before compilation — call the "
                f"function once (or pass specs) first")
        return getattr(compiled, item)


def compile(fn: Optional[Callable] = None,
            specs: Optional[Sequence[Any]] = None,
            options: Optional[CompileOptions] = None,
            **kw) -> CompiledFunction:
    """Compile ``fn`` for dynamic shapes through the DISC pipeline.

    ``specs`` declares per-argument shapes with symbolic dims (strings or
    :class:`Dim` objects); omit it to infer from the first call.  All
    remaining keywords are :class:`CompileOptions` fields::

        @disc.compile            # bare decorator, inferred specs
        def f(x, y): ...

        f2 = disc.compile(f, [("B", 64), (64, 32)], backend="pallas")
        lowered = f2.lower()      # inspect DHLO graph + plans
        art = lowered.compile()   # generated dispatcher

    Usable as a decorator (``@disc.compile`` or
    ``@disc.compile(specs=..., backend=...)``).
    """
    if fn is None:  # decorator-with-arguments form
        return lambda f: CompiledFunction(f, specs, options, **kw)
    if not callable(fn):
        raise TypeError("disc.compile: first argument must be callable")
    return CompiledFunction(fn, specs, options, **kw)
