"""The staged public pipeline: ``disc.compile(fn) → lower() → compile()``.

Mirrors JAX's AOT staging (``jit(f).lower(...).compile()``) for the whole
DISC compiler:

* :func:`compile` returns a :class:`CompiledFunction` — callable
  immediately (lowering/compiling happens on demand, with spec inference
  from the first call when no specs were given), and stageable explicitly;
* :class:`Lowered` holds the inspectable compile-time artifacts (DHLO
  graph, fusion / placement / buffer plans, dynamic symbols) before any
  device code exists;
* :class:`Compiled` owns the generated host dispatcher plus the per-bucket
  compile cache, and exposes ``dispatch_source`` / ``cache_stats()`` /
  ``compile_counts()`` for introspection.

Two pipelines share this surface (selected by
``CompileOptions.pipeline``):

* ``"dhlo"`` — the paper's full pipeline: jaxpr → DHLO bridge, shape
  constraints, fusion, placement, buffers, bucketed per-backend codegen,
  generated host dispatch with output recovery.
* ``"jit"``  — bucketed dispatch over a jax-traceable function *without*
  bridging it through DHLO: declared dynamic args are bucket-padded and
  one ``jax.jit`` entry is cached per bucket signature.  Pytree args pass
  through untouched (spec ``None``), so whole models (params/KV-cache
  trees) get the O(#buckets) compile contract — this is what the serving
  engine builds prefill/decode on.
"""
from __future__ import annotations

import builtins
import hashlib
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from ..core.bucketing import BucketPolicy
from ..core.cache import CompileCache
from ..core.codegen import dyn_symbols
from ..core.dispatcher import generate_dispatch
from ..core.symshape import SymDim
from ..frontends.jaxpr_frontend import ArgSpec, bridge
from .backends import get_backend
from .options import CompileOptions, Dim, normalize_specs

__all__ = ["compile", "CompiledFunction", "Lowered", "Compiled"]


# ------------------------------------------------------------- inference --

def infer_specs(arrays: Sequence[Any]) -> List[ArgSpec]:
    """Infer ``ArgSpec``s from one call's concrete arguments.

    Every axis of size > 1 becomes a symbolic dim; axes sharing a size in
    this call share a symbol (so contractions stay well-typed when traced
    at representative sizes).  Size-1 axes stay static (broadcasting).
    The inferred profile is exact for any later call with the same
    equality structure; distinct dims that *happened* to coincide on the
    first call are tied — declare specs explicitly to untie them.
    """
    by_size: Dict[int, str] = {}
    specs: List[ArgSpec] = []
    for a in arrays:
        ashape = np.shape(a)
        dtype = getattr(a, "dtype", None)
        if dtype is None:
            dtype = np.asarray(a).dtype
        shape = []
        for size in ashape:
            if size <= 1:
                shape.append(int(size))
            else:
                shape.append(by_size.setdefault(int(size), f"d{size}"))
        specs.append(ArgSpec(tuple(shape), dtype))
    return specs


def _graph_const_token(graph) -> str:
    """Hash of a DHLO graph's literal payloads, in deterministic order."""
    h = hashlib.sha1()
    seen = set()
    for op in graph.ops:
        for v in list(op.inputs) + list(op.shape_operands):
            if v.literal is not None and v.vid not in seen:
                seen.add(v.vid)
                arr = np.asarray(v.literal)
                h.update(str(arr.dtype).encode())
                h.update(repr(arr.shape).encode())
                h.update(arr.tobytes())
    return h.hexdigest()[:16]


def _fn_token(fn: Callable) -> str:
    """An identity token for ``fn`` (code, closure, bound instance).

    Process-local: bound methods are distinguished by instance identity
    (two engines sharing one cache must never serve each other's
    closures), so tokens are not stable across processes — fine for an
    in-memory compile cache.
    """
    parts: List[str] = []
    base = getattr(fn, "__func__", fn)
    self_obj = getattr(fn, "__self__", None)
    if self_obj is not None:
        parts.append(type(self_obj).__qualname__)
        parts.append(str(id(self_obj)))
    code = getattr(base, "__code__", None)
    if code is None:
        parts.append(repr(base))
    else:
        parts.append(getattr(base, "__qualname__", ""))
        parts.append(hashlib.sha1(code.co_code).hexdigest())
        parts.append(repr(code.co_consts)[:2000])
        for cell in base.__closure__ or ():
            try:
                parts.append(repr(cell.cell_contents)[:200])
            except ValueError:  # empty cell
                parts.append("<empty>")
    return "\x00".join(parts)


# --------------------------------------------------------------- lowered --

@dataclass
class Lowered:
    """Compile-time artifacts of one function at one spec signature.

    For the ``"dhlo"`` pipeline all plan fields are populated; for the
    ``"jit"`` pipeline only ``specs`` / ``sym_names`` are (there is no hub
    IR — the function is staged directly through ``jax.jit`` per bucket).
    """

    fn: Callable
    specs: Tuple[Optional[ArgSpec], ...]
    options: CompileOptions
    policy: BucketPolicy
    pipeline: str
    graph: Any = None
    plan: Any = None              # FusionPlan
    placement: Any = None
    buffer_plan: Any = None
    syms: Tuple[SymDim, ...] = ()
    sym_names: Tuple[str, ...] = ()

    def fingerprint(self) -> str:
        if self.graph is not None:
            # DGraph.fingerprint() is deliberately shape-free AND
            # constant-free (the per-engine cache-key property).  As a
            # *shared*-cache key that is too weak: two graphs with the same
            # wiring but different literal payloads must not collide, so
            # the artifact fingerprint folds the constants in.
            return (self.graph.fingerprint() + "+"
                    + _graph_const_token(self.graph))
        # jit pipeline has no shape-free graph fingerprint; identify the
        # artifact by the *function* (code + closure + bound self) plus the
        # spec signature, so distinct functions sharing one CompileCache
        # can never hit each other's entries
        sig = repr([(None if s is None else (s.shape, str(np.dtype(s.dtype))))
                    for s in self.specs])
        h = hashlib.sha1((sig + "\x00" + _fn_token(self.fn)).encode())
        return f"jit:{self.options.name}:{h.hexdigest()[:16]}"

    def compile(self, options: Optional[CompileOptions] = None) -> "Compiled":
        """Build the dispatcher (device code still compiles per bucket,
        lazily, through the backend registry).

        ``options`` may override backend / cache / escalation at this
        stage; the bucketing policy is part of the lowering contract
        (``Dim`` markers were folded into it) and stays fixed.
        """
        return Compiled(self, options or self.options)

    def as_text(self) -> str:
        """Human-readable summary of the lowering (inspectable stage)."""
        lines = [f"Lowered({self.options.name!r}, pipeline={self.pipeline!r})"]
        lines.append(f"  fingerprint: {self.fingerprint()}")
        lines.append(f"  dynamic symbols: {list(self.sym_names)}")
        if self.graph is not None:
            lines.append(f"  params: {len(self.graph.params)}  "
                         f"ops: {len(self.graph.ops)}  "
                         f"outputs: {len(self.graph.outputs)}")
            lines.append(f"  fusion: {self.plan.stats()}")
            lines.append(f"  placement: {self.placement.report()}")
            lines.append(f"  constraints: {self.graph.store.stats()}")
        else:
            lines.append("  (no DHLO graph: jit pipeline stages the "
                         "function directly per bucket)")
        return "\n".join(lines)


def _lower(fn: Callable, specs: Sequence[Optional[ArgSpec]],
           dims: Sequence[Dim], options: CompileOptions) -> Lowered:
    policy = options.policy_with_dims(dims)
    if options.pipeline == "jit":
        sym_names: List[str] = []
        for s in specs:
            if s is None:
                continue
            for d in s.shape:
                if isinstance(d, str) and d not in sym_names:
                    sym_names.append(d)
        return Lowered(fn=fn, specs=tuple(specs), options=options,
                       policy=policy, pipeline="jit",
                       sym_names=tuple(sym_names))

    if any(s is None for s in specs):
        raise ValueError(
            "the 'dhlo' pipeline needs an ArgSpec for every argument "
            "(None pass-through specs are only supported by "
            "CompileOptions(pipeline='jit'))")
    from ..core.fusion import plan_fusion
    from ..core.placer import place
    from ..core.buffers import plan_buffers

    graph, _ = bridge(fn, list(specs), name=options.name)
    plan = plan_fusion(graph)
    placement = place(graph)
    buffer_plan = plan_buffers(graph)
    syms = tuple(dyn_symbols(graph))
    return Lowered(fn=fn, specs=tuple(specs), options=options,
                   policy=policy, pipeline="dhlo", graph=graph, plan=plan,
                   placement=placement, buffer_plan=buffer_plan, syms=syms,
                   sym_names=tuple(s.name for s in syms))


# -------------------------------------------------------------- compiled --

class Compiled:
    """The executable artifact: generated host dispatch + compile cache."""

    def __init__(self, lowered: Lowered, options: CompileOptions) -> None:
        self.lowered = lowered
        self.options = options
        self.backend = get_backend(options.backend)
        self._fingerprint = lowered.fingerprint()
        self.cache = options.cache if options.cache is not None else \
            CompileCache(self._fingerprint,
                         max_entries=options.max_cache_entries,
                         escalation_threshold=options.escalation_threshold)
        self._bucket_compiles = 0
        self._exact_compiles = 0
        self._exact_fn = None
        if lowered.pipeline == "dhlo":
            self._dispatch, self.dispatch_source = generate_dispatch(
                lowered.graph, lowered.syms, lowered.policy, self.cache,
                self._compile_bucket, self._compile_exact,
                fingerprint=self._fingerprint,
                escalation_threshold=options.escalation_threshold)
        else:
            self._dispatch, self.dispatch_source = self._generate_jit_dispatch()

    # ------------------------------------------------------------ public --
    def __call__(self, *arrays):
        outs = self._dispatch(arrays)
        if self.lowered.pipeline == "jit":
            return outs
        return outs[0] if len(outs) == 1 else tuple(outs)

    @property
    def graph(self):
        return self.lowered.graph

    @property
    def plan(self):
        return self.lowered.plan

    @property
    def placement(self):
        return self.lowered.placement

    @property
    def buffer_plan(self):
        return self.lowered.buffer_plan

    @property
    def syms(self):
        return list(self.lowered.syms)

    @property
    def policy(self) -> BucketPolicy:
        return self.lowered.policy

    @property
    def n_compiles(self) -> int:
        return self._bucket_compiles + self._exact_compiles

    def cache_stats(self) -> Dict[str, float]:
        return self.cache.stats.as_dict()

    def compile_counts(self) -> Dict[str, int]:
        """Per-artifact compile counts (meaningful under shared caches)."""
        return {"bucket": self._bucket_compiles,
                "exact": self._exact_compiles,
                "total": self._bucket_compiles + self._exact_compiles}

    def report(self) -> Dict[str, Any]:
        rep: Dict[str, Any] = {
            "fingerprint": self._fingerprint,
            "backend": self.backend.name,
            "pipeline": self.lowered.pipeline,
            "cache": self.cache_stats(),
            "compiles": self.compile_counts(),
            "dynamic_symbols": list(self.lowered.sym_names),
        }
        low = self.lowered
        if low.graph is not None:
            from ..core.codegen import (_pallas_input_eligible,
                                        _pallas_loop_eligible)
            n_pallas = sum(
                1 for c in low.plan.clusters
                if _pallas_loop_eligible(low.graph, c)
                or _pallas_input_eligible(low.graph, c))
            rep.update({
                "fusion": low.plan.stats(),
                "placement": low.placement.report(),
                "constraints": low.graph.store.stats(),
                "pallas_eligible_clusters": n_pallas,
            })
        return rep

    # ------------------------------------------------- device compilation --
    def _compile_bucket(self, key: Tuple[int, ...]):
        low = self.lowered
        padded = {s.uid: int(k) for s, k in zip(low.syms, key)}
        self._bucket_compiles += 1
        return self.backend.build_bucket(low.graph, low.plan, low.syms,
                                         padded, self.options.donate)

    def _compile_exact(self):
        if self._exact_fn is None:
            self._exact_fn = self.backend.build_exact(self.lowered.graph,
                                                      self.lowered.plan)
        self._exact_compiles += 1
        return self._exact_fn

    # ----------------------------------------------------- jit pipeline --
    def _generate_jit_dispatch(self) -> Tuple[Callable, str]:
        """Generated host flow for the jit pipeline: extract sizes, bucket,
        zero-pad declared dynamic args, call the per-bucket jax.jit entry.
        No output recovery — jit-pipeline functions are lens-aware and
        produce shape-stable outputs themselves."""
        low = self.lowered
        sym_index = {n: i for i, n in enumerate(low.sym_names)}

        # first extraction site per symbol
        extract: Dict[str, Tuple[int, int]] = {}
        for ai, spec in enumerate(low.specs):
            if spec is None:
                continue
            for ax, d in enumerate(spec.shape):
                if isinstance(d, str) and d not in extract:
                    extract[d] = (ai, ax)

        lines = ["def _dispatch(args):"]
        w = lines.append
        for name in low.sym_names:
            ai, ax = extract[name]
            w(f"    s_{sym_index[name]} = args[{ai}].shape[{ax}]")
        if low.sym_names:
            w("    key = (" + ", ".join(
                f"_b{i}(s_{i})" for i in range(len(low.sym_names))) + ",)")
        else:
            w("    key = ()")
        w("    entry = _get(('bucket', _fp, key))")
        w("    if entry is None:")
        w("        entry = _compile(key)")

        call_args = []
        for ai, spec in enumerate(low.specs):
            var = f"a{ai}"
            if spec is None or not any(isinstance(d, str) for d in spec.shape):
                call_args.append(f"args[{ai}]")
                continue
            shape_expr = []
            dyn_axes = []
            for ax, d in enumerate(spec.shape):
                if isinstance(d, str):
                    dyn_axes.append(ax)
                    shape_expr.append(f"key[{sym_index[d]}]")
                else:
                    shape_expr.append(str(d))
            pshape = "(" + ", ".join(shape_expr) + \
                ("," if len(shape_expr) == 1 else "") + ")"
            w(f"    {var} = args[{ai}]")
            w(f"    if tuple({var}.shape) != {pshape}:")
            w(f"        _buf = _np.zeros({pshape}, _dt{ai})")
            idx = ", ".join(f":{var}.shape[{ax}]" if ax in dyn_axes else ":"
                            for ax in range(len(spec.shape)))
            w(f"        _buf[{idx}] = _np.asarray({var})")
            w(f"        {var} = _buf")
            call_args.append(var)

        w("    return entry(" + ", ".join(call_args) + ")")
        src = "\n".join(lines)

        cache = self.cache
        _entries_get = cache._entries.get
        _move_to_end = cache._entries.move_to_end
        _stats = cache.stats

        def _get(key):
            e = _entries_get(key)
            if e is not None:
                _stats.hits += 1
                _move_to_end(key)  # keep hot buckets at the LRU tail
            return e

        def _make_entry():
            self._bucket_compiles += 1
            return jax.jit(low.fn)

        def _compile(key):
            return cache.get_or_compile(key, _make_entry,
                                        fingerprint=self._fingerprint)

        ns: Dict[str, Any] = {"_np": np, "_fp": self._fingerprint,
                              "_get": _get, "_compile": _compile}
        for i, name in enumerate(low.sym_names):
            ns[f"_b{i}"] = (lambda v, _p=low.policy, _n=name:
                            _p.bucket(_n, int(v)))
        for ai, spec in enumerate(low.specs):
            if spec is not None:
                ns[f"_dt{ai}"] = np.dtype(spec.dtype)

        code = builtins.compile(
            src, f"<disc-jit-dispatch:{low.options.name}>", "exec")
        exec(code, ns)
        return ns["_dispatch"], src


# ------------------------------------------------------ public entrypoint --

class CompiledFunction:
    """What ``disc.compile`` returns: callable now, stageable explicitly.

    * with specs: lowering + dispatcher generation happen eagerly (device
      code still compiles per bucket on demand);
    * without specs: the first call infers them (:func:`infer_specs`).

    Attribute access falls through to the underlying :class:`Compiled`
    artifact (``plan``, ``report()``, ``n_compiles``, ...), so migrating
    from ``DiscEngine`` is a constructor swap.
    """

    def __init__(self, fn: Callable,
                 specs: Optional[Sequence[Any]] = None,
                 options: Optional[CompileOptions] = None, **kw) -> None:
        if options is None:
            options = CompileOptions(**kw)
        elif kw:
            options = options.replace(**kw)
        self.fn = fn
        self.options = options
        self._specs, self._dims = normalize_specs(specs)
        self._lowered: Optional[Lowered] = None
        self._compiled: Optional[Compiled] = None
        if self._specs is not None:
            self._ensure()

    # ------------------------------------------------------------ staging --
    def lower(self, specs: Optional[Sequence[Any]] = None) -> Lowered:
        """Stage 1: produce the inspectable compile-time artifacts."""
        if specs is not None:
            norm, dims = normalize_specs(specs)
            return _lower(self.fn, norm, dims, self.options)
        if self._specs is None:
            raise ValueError(
                "no specs declared and none inferred yet — pass specs to "
                "lower(), declare them in disc.compile(fn, specs), or call "
                "the function once to infer them")
        if self._lowered is None:
            self._lowered = _lower(self.fn, self._specs, self._dims,
                                   self.options)
        return self._lowered

    def _ensure(self) -> Compiled:
        if self._compiled is None:
            self._compiled = self.lower().compile()
        return self._compiled

    # ------------------------------------------------------------ calling --
    def __call__(self, *arrays):
        if self._compiled is None:
            if self._specs is None:
                if self.options.pipeline == "jit":
                    # no declared dynamic dims: every arg passes through
                    self._specs = (None,) * len(arrays)
                else:
                    self._specs = tuple(infer_specs(arrays))
            self._ensure()
        return self._compiled(*arrays)

    def __getattr__(self, item):
        compiled = object.__getattribute__(self, "_compiled")
        if compiled is None:
            raise AttributeError(
                f"{item!r} is unavailable before compilation — call the "
                f"function once (or pass specs) first")
        return getattr(compiled, item)


def compile(fn: Optional[Callable] = None,
            specs: Optional[Sequence[Any]] = None,
            options: Optional[CompileOptions] = None,
            **kw) -> CompiledFunction:
    """Compile ``fn`` for dynamic shapes through the DISC pipeline.

    ``specs`` declares per-argument shapes with symbolic dims (strings or
    :class:`Dim` objects); omit it to infer from the first call.  All
    remaining keywords are :class:`CompileOptions` fields::

        @disc.compile            # bare decorator, inferred specs
        def f(x, y): ...

        f2 = disc.compile(f, [("B", 64), (64, 32)], backend="pallas")
        lowered = f2.lower()      # inspect DHLO graph + plans
        art = lowered.compile()   # generated dispatcher

    Usable as a decorator (``@disc.compile`` or
    ``@disc.compile(specs=..., backend=...)``).
    """
    if fn is None:  # decorator-with-arguments form
        return lambda f: CompiledFunction(f, specs, options, **kw)
    if not callable(fn):
        raise TypeError("disc.compile: first argument must be callable")
    return CompiledFunction(fn, specs, options, **kw)
