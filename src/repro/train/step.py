"""Train-step factory: loss + grad + AdamW, with microbatch accumulation
and optional gradient compression on the DP reduce.

The returned ``train_step(state, batch) -> (state, metrics)`` is pure and
jit/pjit-friendly; sharding is applied by the launcher via in/out
shardings built from the model's spec tree (launch/dryrun.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.registry import Model
from ..optim.adamw import OptState, adamw_init, adamw_update
from ..optim.compress import compress_grads, decompress_grads
from ..optim.schedule import cosine_schedule


@dataclass(frozen=True)
class TrainConfig:
    peak_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    microbatches: int = 1          # grad accumulation
    grad_compression: Optional[str] = None  # None | "bf16" | "topk"
    topk_frac: float = 0.01


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    residual: Any                  # error-feedback for compression (or ())


def train_state_init(model: Model, rng, tcfg: TrainConfig) -> TrainState:
    params = model.init(rng)
    residual = ()
    if tcfg.grad_compression is not None:
        residual = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params)
    return TrainState(params=params, opt=adamw_init(params),
                      residual=residual)


def make_train_step(model: Model, tcfg: TrainConfig) -> Callable:
    def loss_fn(params, batch):
        return model.loss(params, batch)

    grad_fn = jax.value_and_grad(loss_fn)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        if tcfg.microbatches > 1:
            def micro(i, acc):
                loss_acc, grad_acc = acc
                mb = jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // tcfg.microbatches),
                        x.shape[0] // tcfg.microbatches, 0), batch)
                l, g = grad_fn(state.params, mb)
                return (loss_acc + l,
                        jax.tree.map(jnp.add, grad_acc, g))

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                state.params)
            loss, grads = jax.lax.fori_loop(
                0, tcfg.microbatches, micro, (jnp.zeros(()), zero))
            inv = 1.0 / tcfg.microbatches
            loss = loss * inv
            grads = jax.tree.map(lambda g: g * inv, grads)
        else:
            loss, grads = grad_fn(state.params, batch)

        residual = state.residual
        if tcfg.grad_compression is not None:
            topk = tcfg.topk_frac if tcfg.grad_compression == "topk" else None
            wire, residual = compress_grads(grads, residual, topk_frac=topk)
            grads = decompress_grads(wire)

        lr = cosine_schedule(state.opt.step, peak_lr=tcfg.peak_lr,
                             warmup=tcfg.warmup, total=tcfg.total_steps)
        params, opt = adamw_update(state.params, grads, state.opt, lr=lr,
                                   weight_decay=tcfg.weight_decay,
                                   grad_clip=tcfg.grad_clip)
        metrics = {"loss": loss.astype(jnp.float32), "lr": lr,
                   "step": opt.step}
        return TrainState(params=params, opt=opt, residual=residual), metrics

    return train_step
