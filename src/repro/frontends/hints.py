"""Frontend shape-constraint hints — DISC §4.2.1, constraint source #2.

    "We collect shape constraints captured by the high level ops from
     frameworks and inject such information into DHLO in computation graph
     bridging.  Take SplitOp in Tensorflow as an example ... a TF.SplitOp
     will be lowered to multiple independent DHLO.SliceOp, which actually
     have the same shapes.  However such kind of information is lost after
     being lowered to DHLO without explicit shape constraint."

``jnp.split`` lowers to multiple independent ``slice`` eqns exactly as the
paper describes for TF — the hint pass below re-detects even splits of a
common operand and injects output-shape-equality constraints.  A second pass
recognizes *stacked sibling slices* (same operand, same extents on all other
axes) and equates their shapes even when the split axis sizes are symbolic.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Tuple

from ..core.dhlo import DGraph, DOp

__all__ = ["collect_frontend_hints"]


def _split_groups(graph: DGraph) -> List[List[DOp]]:
    """Group static `slice` ops that together evenly cover one axis."""
    by_operand: Dict[int, List[DOp]] = defaultdict(list)
    for op in graph.ops:
        if op.opcode == "slice" and len(op.inputs) == 1:
            by_operand[op.inputs[0].vid].append(op)

    groups: List[List[DOp]] = []
    for ops in by_operand.values():
        if len(ops) < 2:
            continue
        # bucket by the non-split extents: a split varies exactly one axis
        by_axis: Dict[Tuple, List[DOp]] = defaultdict(list)
        for op in ops:
            starts = op.attrs.get("start_indices")
            limits = op.attrs.get("limit_indices")
            if starts is None or limits is None:
                continue
            varying = [ax for ax, s in enumerate(starts) if s != 0]
            if len(varying) > 1:
                continue
            axis = varying[0] if varying else None
            key_extent = tuple((s, l) for ax, (s, l) in enumerate(zip(starts, limits))
                               if ax != axis)
            by_axis[(axis, key_extent)].append(op)
        for (axis, _), members in by_axis.items():
            if len(members) < 2:
                continue
            if axis is None:
                continue
            # even cover check: sorted starts tile the axis with equal width
            slices = sorted(
                (op.attrs["start_indices"][axis], op.attrs["limit_indices"][axis], op)
                for op in members
            )
            widths = {l - s for s, l, _ in slices}
            contiguous = all(slices[i + 1][0] == slices[i][1]
                             for i in range(len(slices) - 1))
            if len(widths) == 1 and contiguous and slices[0][0] == 0:
                groups.append([op for _, _, op in slices])
    return groups


def collect_frontend_hints(graph: DGraph) -> int:
    """Inject high-level-op shape constraints; returns #constraints added."""
    added = 0
    for group in _split_groups(graph):
        first = group[0].outputs[0]
        for op in group[1:]:
            graph.store.assert_shape_eq(first.shape, op.outputs[0].shape)
            graph.store.assert_size_eq(first.vid, op.outputs[0].vid)
            added += 1
    return added
