"""Computation-graph bridging: jaxpr → DHLO — DISC §3 / §4.1 / §4.4.

DISC lowers TensorFlow/PyTorch graphs into its hub IR (DHLO), collecting
shape-constraint information *during* bridging.  Our host "framework" is JAX
itself: any jax-traceable function is bridged by

    graph, specs = bridge(fn, [ArgSpec(("B", "S", 512), jnp.float32), ...])

Symbolic dims are declared by naming them in :class:`ArgSpec` shapes.  The
bridge traces the function once at *representative* concrete sizes (distinct
primes per symbol), walks the jaxpr, and rebuilds symbolic output shapes per
primitive via the propagation rules — never by trusting concrete values alone
except where a rule explicitly resymbolizes (reshape/broadcast/iota), where
representative-prime matching recovers symbol structure.

DHLO fidelity notes:

* ``lax.dynamic_slice`` maps to the DHLO ``dslice`` op with its start indices
  as **shape operands** — JAX's dynamic_slice *is* the paper's Figure-2
  ``DSliceOp`` (tensor operands instead of constant attributes).
* derived dims (reshape merges, concat sums, pad affine maps) are recorded in
  ``graph.dim_exprs`` so the host-side *shape calculation* code (§4.2.1) can
  be generated at compile time (see ``core/placer.py`` / ``core/runtime.py``).
* every eqn also records its raw jax primitive + params in ``attrs`` so any
  backend can faithfully re-emit the computation (the hub-IR property that
  lets multiple backends hang off DHLO).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.extend import core as jcore

from ..core.dhlo import DGraph, DOp, DValue
from ..core.propagation import carry_fixed_point, collect_semantic_constraints
from ..core.symshape import Dim, SymDim, SymShape, dim_value, fresh_symdim

__all__ = ["ArgSpec", "TreeSpec", "UnsupportedPrimitiveError", "bridge",
           "eval_dim"]


class UnsupportedPrimitiveError(NotImplementedError):
    """A higher-order primitive the bridge cannot lower to DHLO.

    Raised (naming the op) instead of falling through to the opaque
    rebind path — a closed-over jaxpr traced at representative shapes
    would silently compute garbage at any other bucket.
    """


@dataclass(frozen=True)
class ArgSpec:
    """Shape spec with named symbolic dims, e.g. ``(("B", "S", 512), f32)``."""

    shape: Tuple[Union[int, str], ...]
    dtype: Any = jnp.float32
    name: str = ""


class TreeSpec:
    """Spec for a pytree argument whose array leaves share bucketed axes
    (``pipeline="jit"`` only).

    ``axes`` maps a leaf axis index to a symbolic dim (a name string, or a
    ``Dim`` at the public-API layer): the generated dispatch zero-pads
    every array leaf of the argument along those axes to the dim's current
    bucket.  The dim itself must also be declared on some :class:`ArgSpec`
    argument — a pytree has no single ``.shape`` to extract the symbol
    from.  The serving engine uses this to thread a gathered batch of
    KV-cache rows (a params-shaped pytree) through a ``Dim("B")``-bucketed
    prefill artifact.
    """

    def __init__(self, axes):
        items = sorted(axes.items()) if isinstance(axes, dict) else list(axes)
        self.axes: Tuple[Tuple[int, Any], ...] = tuple(
            (int(a), d) for a, d in items)

    def __repr__(self) -> str:
        return f"TreeSpec({dict(self.axes)!r})"

    def __eq__(self, other) -> bool:
        return isinstance(other, TreeSpec) and self.axes == other.axes

    def __hash__(self) -> int:
        return hash(self.axes)


# representative primes for symbols — chosen to avoid common static dims
_REPS = [37, 41, 43, 47, 53, 59, 61, 67, 71, 73, 79, 83, 89, 97, 101, 103]


# ------------------------------------------------------------------ dims --

def eval_dim(graph: DGraph, d: Dim, bindings: Dict[int, int]) -> int:
    """Evaluate a (possibly derived) dim given input-symbol bindings.

    This is the *specification* of the host-side shape calculation; the
    runtime generates straight-line code equivalent to it (§4.2 'generated
    runtime flow'), this interpreter is kept as the oracle.
    """
    if isinstance(d, int):
        return d
    if d.uid in bindings:
        return bindings[d.uid]
    expr = getattr(graph, "dim_exprs", {}).get(d.uid)
    if expr is None:
        raise KeyError(f"unbound symbolic dim {d!r}")
    tag = expr[0]
    if tag == "mul":
        v = 1
        for x in expr[1]:
            v *= eval_dim(graph, x, bindings)
        return v
    if tag == "sum":
        return sum(eval_dim(graph, x, bindings) for x in expr[1])
    if tag == "affine":  # a*d + b
        _, base, a, b = expr
        return a * eval_dim(graph, base, bindings) + b
    if tag == "div":  # exact division
        _, base, k = expr
        v = eval_dim(graph, base, bindings)
        return v // k
    raise ValueError(f"unknown dim expr {expr}")


class _Bridge:
    def __init__(self, name: str) -> None:
        self.graph = DGraph(name=name)
        self.graph.dim_exprs = {}
        self.env: Dict[Any, DValue] = {}
        self.symbols: Dict[str, SymDim] = {}
        # representative value -> SymDim, for resymbolization
        self.rep_to_dim: Dict[int, SymDim] = {}
        self._rep_iter = itertools.count()
        # symbol name -> declared Dim(max=...) cap, for carry widening
        self.bounds: Dict[str, int] = {}

    # ------------------------------------------------------------ symbols
    def symbol(self, name: str) -> SymDim:
        if name not in self.symbols:
            idx = next(self._rep_iter)
            rep = _REPS[idx % len(_REPS)] + 131 * (idx // len(_REPS))
            d = fresh_symdim(name, rep=rep)
            self.symbols[name] = d
            self.rep_to_dim[d.rep] = d
        return self.symbols[name]

    def derived(self, name: str, rep: int, expr: Tuple) -> SymDim:
        d = fresh_symdim(name, rep=rep)
        self.graph.dim_exprs[d.uid] = expr
        self.rep_to_dim.setdefault(rep, d)
        return d

    def resymbolize(self, size: int, local_dims: Sequence[Dim]) -> Dim:
        """Map a concrete traced size back to symbolic structure."""
        # 1. exact match against this op's input dims (shape propagation)
        for d in local_dims:
            if isinstance(d, SymDim) and d.rep == size:
                return d
        # 2. exact match against any known symbol
        if size in self.rep_to_dim:
            return self.rep_to_dim[size]
        # 3. product of two known local symbolic dims (reshape merge)
        syms = [d for d in local_dims if isinstance(d, SymDim)]
        for i, a in enumerate(syms):
            for b in syms[i:]:
                if a.rep * b.rep == size:
                    return self.derived(
                        f"{a.name}*{b.name}", size, ("mul", (a, b))
                    )
            # symbol * static factor (e.g. merge of (S, 128) -> S*128)
            if size % a.rep == 0:
                k = size // a.rep
                return self.derived(f"{a.name}*{k}", size, ("mul", (a, k)))
        # 4. genuinely static
        return int(size)

    # -------------------------------------------------------------- values
    def read(self, atom) -> DValue:
        if isinstance(atom, jcore.Literal):
            arr = np.asarray(atom.val)
            return self.graph.add_const(arr)
        return self.env[atom]

    def write(self, var, val: DValue) -> None:
        self.env[var] = val


# generic elementwise/unary primitive name passthroughs (jax name -> dhlo name)
_DIRECT = {
    "add": "add", "sub": "sub", "mul": "mul", "div": "div", "rem": "rem",
    "pow": "pow", "max": "max", "min": "min", "and": "and", "or": "or",
    "xor": "xor", "atan2": "atan2", "nextafter": "nextafter",
    "eq": "eq", "ne": "ne", "lt": "lt", "gt": "gt", "le": "le", "ge": "ge",
    "neg": "neg", "sign": "sign", "floor": "floor", "ceil": "ceil",
    "round": "round", "exp": "exp", "exp2": "exp2", "expm1": "expm1",
    "log": "log", "log1p": "log1p", "tanh": "tanh", "logistic": "logistic",
    "sqrt": "sqrt", "rsqrt": "rsqrt", "cbrt": "cbrt", "abs": "abs",
    "erf": "erf", "erfc": "erfc", "erf_inv": "erf_inv", "sin": "sin",
    "cos": "cos", "tan": "tan", "asin": "asin", "acos": "acos",
    "atan": "atan", "sinh": "sinh", "cosh": "cosh", "not": "not",
    "is_finite": "is_finite", "integer_pow": "integer_pow",
    "stop_gradient": "stop_gradient", "copy": "copy", "square": "square",
    "select_n": "select", "shift_left": "shift_left",
    "shift_right_logical": "shift_right_logical",
    "shift_right_arithmetic": "shift_right_arithmetic",
    "clamp": "clamp", "sort": "sort", "cumsum": "cumsum",
    "cummax": "cummax", "cumprod": "cumprod", "rev": "rev",
}

_REDUCES = {
    "reduce_sum": "reduce_sum", "reduce_max": "reduce_max",
    "reduce_min": "reduce_min", "reduce_prod": "reduce_prod",
    "reduce_and": "reduce_and", "reduce_or": "reduce_or",
    "argmax": "argmax", "argmin": "argmin",
}

_INLINE = {"pjit", "jit", "closed_call", "custom_jvp_call",
           "custom_vjp_call", "remat", "checkpoint",
           "custom_vjp_call_jaxpr", "core_call"}


def _bridge_region(b: _Bridge, closed, param_shapes, param_dtypes,
                   name: str) -> DGraph:
    """Recursively lower a closed-over jaxpr into a nested region DGraph.

    The sub-graph *shares* the parent's constraint store and derived-dim
    table — one symbolic universe — so shapes flowing through the region
    boundary keep their identity; only the value environment is scoped.
    """
    outer_graph, outer_env = b.graph, b.env
    sub = DGraph(name=name)
    sub.store = outer_graph.store
    sub.dim_exprs = outer_graph.dim_exprs
    b.graph, b.env = sub, {}
    try:
        inner = closed.jaxpr
        for var, sh, dt in zip(inner.invars, param_shapes, param_dtypes):
            b.write(var, sub.add_param(tuple(sh), dt))
        for cvar, cval in zip(inner.constvars, closed.consts):
            b.write(cvar, sub.add_const(np.asarray(cval)))
        for eqn in inner.eqns:
            _bridge_eqn(b, eqn)
        sub.set_outputs([b.read(a) for a in inner.outvars])
    finally:
        b.graph, b.env = outer_graph, outer_env
    # op-semantic constraints of the region body land in the shared store
    # now (the top-level pass does not descend into regions), so the
    # carry fixed-point that runs next sees them
    collect_semantic_constraints(sub)
    return sub


def _bridge_while(b: _Bridge, eqn, in_vals: List[DValue]) -> None:
    g = b.graph
    params = eqn.params
    cn, bn = params["cond_nconsts"], params["body_nconsts"]
    cond_args = in_vals[:cn] + in_vals[cn + bn:]
    body_args = in_vals[cn:]
    cond_graph = _bridge_region(
        b, params["cond_jaxpr"], [v.shape for v in cond_args],
        [v.dtype for v in cond_args], f"{g.name}.while.cond")
    body_graph = _bridge_region(
        b, params["body_jaxpr"], [v.shape for v in body_args],
        [v.dtype for v in body_args], f"{g.name}.while.body")
    carry = in_vals[cn + bn:]
    out_shapes = [
        carry_fixed_point(g.store, g.dim_exprs, cv.shape, ov.shape,
                          bounds=b.bounds, label=f"while carry {i}")
        for i, (cv, ov) in enumerate(zip(carry, body_graph.outputs))]
    op = g.add_op("d.while", in_vals, out_shapes,
                  [v.aval.dtype for v in eqn.outvars],
                  attrs={"cond_graph": cond_graph, "body_graph": body_graph,
                         "cond_nconsts": cn, "body_nconsts": bn})
    for var, val in zip(eqn.outvars, op.outputs):
        b.write(var, val)


def _bridge_scan(b: _Bridge, eqn, in_vals: List[DValue]) -> None:
    g = b.graph
    params = eqn.params
    nc, ncar = params["num_consts"], params["num_carry"]
    consts, carry = in_vals[:nc], in_vals[nc:nc + ncar]
    xs = in_vals[nc + ncar:]
    length_dim: Dim = xs[0].shape[0] if xs else int(params["length"])
    body_shapes = [v.shape for v in consts + carry] + \
        [tuple(v.shape[1:]) for v in xs]
    body_dtypes = [v.dtype for v in consts + carry] + [v.dtype for v in xs]
    body_graph = _bridge_region(b, params["jaxpr"], body_shapes, body_dtypes,
                                f"{g.name}.scan.body")
    out_shapes = [
        carry_fixed_point(g.store, g.dim_exprs, cv.shape, ov.shape,
                          bounds=b.bounds, label=f"scan carry {i}")
        for i, (cv, ov) in enumerate(zip(carry, body_graph.outputs[:ncar]))]
    out_shapes += [(length_dim,) + tuple(y.shape)
                   for y in body_graph.outputs[ncar:]]
    op = g.add_op("d.scan", in_vals, out_shapes,
                  [v.aval.dtype for v in eqn.outvars],
                  attrs={"body_graph": body_graph, "num_consts": nc,
                         "num_carry": ncar, "length_dim": length_dim,
                         "reverse": bool(params.get("reverse", False)),
                         "unroll": int(params.get("unroll", 1) or 1)})
    for var, val in zip(eqn.outvars, op.outputs):
        b.write(var, val)


def _bridge_cond(b: _Bridge, eqn, in_vals: List[DValue]) -> None:
    g = b.graph
    operands = in_vals[1:]  # in_vals[0] is the branch index
    branch_graphs = tuple(
        _bridge_region(b, br, [v.shape for v in operands],
                       [v.dtype for v in operands], f"{g.name}.cond.br{i}")
        for i, br in enumerate(eqn.params["branches"]))
    base = branch_graphs[0]
    for bg in branch_graphs[1:]:
        for a, o in zip(base.outputs, bg.outputs):
            g.store.assert_shape_eq(a.shape, o.shape)
    op = g.add_op("d.cond", in_vals, [v.shape for v in base.outputs],
                  [v.aval.dtype for v in eqn.outvars],
                  attrs={"branch_graphs": branch_graphs})
    for var, val in zip(eqn.outvars, op.outputs):
        b.write(var, val)


_REGION_BRIDGES = {"while": _bridge_while, "scan": _bridge_scan,
                   "cond": _bridge_cond}


def _has_subjaxpr(v: Any) -> bool:
    if isinstance(v, (jcore.Jaxpr, jcore.ClosedJaxpr)):
        return True
    if isinstance(v, (tuple, list)):
        return any(_has_subjaxpr(x) for x in v)
    return False


def _sym_out_shape_ew(b: _Bridge, in_vals: List[DValue], aval) -> SymShape:
    """Elementwise result: shape of the highest-rank symbolic operand."""
    for v in in_vals:
        if v.rank == len(aval.shape) and tuple(dim_value(d) for d in v.shape) == tuple(aval.shape):
            return v.shape
    local = [d for v in in_vals for d in v.shape]
    return tuple(b.resymbolize(s, local) for s in aval.shape)


def _bridge_eqn(b: _Bridge, eqn) -> None:
    prim = eqn.primitive
    name = prim.name
    params = dict(eqn.params)

    if name in _INLINE:
        sub = params.get("jaxpr") or params.get("call_jaxpr") or params.get("fun_jaxpr")
        if sub is not None:
            closed = sub if isinstance(sub, jcore.ClosedJaxpr) else jcore.ClosedJaxpr(sub, ())
            inner = closed.jaxpr
            for var, outer_atom in zip(inner.invars, eqn.invars):
                b.write(var, b.read(outer_atom))
            for cvar, cval in zip(inner.constvars, closed.consts):
                b.write(cvar, b.graph.add_const(np.asarray(cval)))
            for inner_eqn in inner.eqns:
                _bridge_eqn(b, inner_eqn)
            for outer_var, inner_atom in zip(eqn.outvars, inner.outvars):
                b.write(outer_var, b.read(inner_atom))
            return

    in_vals = [b.read(a) for a in eqn.invars]
    if name in _REGION_BRIDGES:
        _REGION_BRIDGES[name](b, eqn, in_vals)
        return
    g = b.graph
    attrs: Dict[str, Any] = {"_prim": prim, "_params": params}

    def emit(opcode, inputs, out_shapes, shape_operands=(), extra_attrs=None):
        a = dict(attrs)
        if extra_attrs:
            a.update(extra_attrs)
        out_dtypes = [v.aval.dtype for v in eqn.outvars]
        op = g.add_op(opcode, inputs, out_shapes, out_dtypes,
                      shape_operands=shape_operands, attrs=a)
        for var, val in zip(eqn.outvars, op.outputs):
            b.write(var, val)
        return op

    if name in _DIRECT:
        out_shapes = [_sym_out_shape_ew(b, in_vals, v.aval) for v in eqn.outvars]
        emit(_DIRECT[name], in_vals, out_shapes)
        return

    if name == "convert_element_type":
        emit("convert", in_vals, [in_vals[0].shape],
             extra_attrs={"new_dtype": params.get("new_dtype")})
        return

    if name in _REDUCES:
        axes = tuple(params.get("axes", ()))
        src = in_vals[0]
        kept = tuple(d for i, d in enumerate(src.shape) if i not in set(axes))
        emit(_REDUCES[name], in_vals, [kept], extra_attrs={"axes": axes})
        return

    if name == "broadcast_in_dim":
        shape = tuple(params["shape"])
        bdims = tuple(params["broadcast_dimensions"])
        src = in_vals[0]
        out_shape: List[Dim] = []
        for out_ax, size in enumerate(shape):
            if out_ax in bdims:
                in_ax = bdims.index(out_ax)
                d = src.shape[in_ax]
                out_shape.append(d if not (isinstance(d, int) and d == 1 and size != 1)
                                 else b.resymbolize(size, list(src.shape)))
            else:
                out_shape.append(b.resymbolize(size, list(src.shape)))
        emit("broadcast_in_dim", in_vals, [tuple(out_shape)],
             extra_attrs={"broadcast_dimensions": bdims})
        return

    if name == "reshape":
        new_sizes = tuple(params["new_sizes"])
        src = in_vals[0]
        out_shape = tuple(b.resymbolize(s, list(src.shape)) for s in new_sizes)
        emit("reshape", in_vals, [out_shape])
        return

    if name == "squeeze":
        dims = set(params.get("dimensions", ()))
        src = in_vals[0]
        out_shape = tuple(d for i, d in enumerate(src.shape) if i not in dims)
        emit("reshape", in_vals, [out_shape])
        return

    if name == "expand_dims":
        dims = sorted(params.get("dimensions", ()))
        src = in_vals[0]
        out_shape = list(src.shape)
        for ax in dims:
            out_shape.insert(ax, 1)
        emit("reshape", in_vals, [tuple(out_shape)])
        return

    if name == "transpose":
        perm = tuple(params["permutation"])
        src = in_vals[0]
        out_shape = tuple(src.shape[i] for i in perm)
        emit("transpose", in_vals, [out_shape], extra_attrs={"permutation": perm})
        return

    if name == "dot_general":
        dnums = params["dimension_numbers"]
        (lc, rc), (lb, rb) = dnums
        lhs, rhs = in_vals[0], in_vals[1]
        batch = [lhs.shape[i] for i in lb]
        lfree = [d for i, d in enumerate(lhs.shape) if i not in set(lc) | set(lb)]
        rfree = [d for i, d in enumerate(rhs.shape) if i not in set(rc) | set(rb)]
        out_shape = tuple(batch + lfree + rfree)
        emit("dot_general", in_vals, [out_shape],
             extra_attrs={"dimension_numbers": ((tuple(lc), tuple(rc)), (tuple(lb), tuple(rb)))})
        return

    if name == "dynamic_slice":
        # DHLO DSliceOp: start indices are tensor operands, not attrs (Fig. 2)
        operand = in_vals[0]
        starts = in_vals[1:]
        sizes = tuple(params["slice_sizes"])
        out_shape = tuple(b.resymbolize(s, list(operand.shape)) for s in sizes)
        emit("dslice", [operand], [out_shape], shape_operands=starts,
             extra_attrs={"slice_sizes": sizes})
        return

    if name == "dynamic_update_slice":
        operand, update = in_vals[0], in_vals[1]
        starts = in_vals[2:]
        emit("dynamic_update_slice", [operand, update], [operand.shape],
             shape_operands=starts)
        return

    if name == "slice":
        # jnp.split lowers here with numpy-int indices — coerce to python
        # ints so they never leak into DHLO shapes (isinstance(d, int)
        # checks gate every constraint/codegen path)
        starts = tuple(int(s) for s in params["start_indices"])
        limits = tuple(int(l) for l in params["limit_indices"])
        strides = tuple(int(st) for st in
                        (params["strides"] or (1,) * len(starts)))
        src = in_vals[0]
        out_shape: List[Dim] = []
        for ax, (s, l, st) in enumerate(zip(starts, limits, strides)):
            d = src.shape[ax]
            if isinstance(d, SymDim) and st == 1 and l == d.rep:
                if s == 0:
                    out_shape.append(d)
                else:
                    out_shape.append(b.derived(f"{d.name}-{s}", d.rep - s,
                                               ("affine", d, 1, -s)))
            else:
                out_shape.append(int(-(-(l - s) // st)))
        emit("slice", in_vals, [tuple(out_shape)],
             extra_attrs={"start_indices": starts, "limit_indices": limits,
                          "strides": strides})
        return

    if name == "split":
        # High-level split: lowered to multiple *independent* DHLO slice ops
        # (mirroring TF.SplitOp -> DHLO.SliceOp in the paper), with the
        # "all outputs same shape" hint injected during bridging (§4.2.1).
        axis = int(params["axis"])
        sizes = [int(s) for s in params["sizes"]]
        src = in_vals[0]
        outs: List[DValue] = []
        offset = 0
        even = len(set(sizes)) == 1
        for out_var, size in zip(eqn.outvars, sizes):
            starts = tuple(offset if ax == axis else 0 for ax in range(src.rank))
            limits = tuple(
                (offset + size) if ax == axis else dim_value(src.shape[ax])
                for ax in range(src.rank)
            )
            out_shape = tuple(
                size if ax == axis else src.shape[ax] for ax in range(src.rank)
            )
            op = g.add_op(
                "slice", [src], [out_shape], [out_var.aval.dtype],
                attrs={**attrs, "start_indices": starts,
                       "limit_indices": limits,
                       "strides": (1,) * src.rank},
            )
            b.write(out_var, op.outputs[0])
            outs.append(op.outputs[0])
            offset += size
        if even:
            for o in outs[1:]:
                g.store.assert_shape_eq(outs[0].shape, o.shape)
                g.store.assert_size_eq(outs[0].vid, o.vid)
        return

    if name == "concatenate":
        axis = int(params["dimension"])
        parts = [v.shape[axis] for v in in_vals]
        if all(isinstance(p, int) for p in parts):
            cat: Dim = sum(parts)  # type: ignore[assignment]
        else:
            rep = sum(dim_value(p) for p in parts)
            cat = b.derived("+".join(getattr(p, "name", str(p)) for p in parts),
                            rep, ("sum", tuple(parts)))
        out_shape = tuple(cat if ax == axis else in_vals[0].shape[ax]
                          for ax in range(in_vals[0].rank))
        emit("concatenate", in_vals, [out_shape], extra_attrs={"dimension": axis})
        return

    if name == "pad":
        cfg = tuple(params["padding_config"])
        src = in_vals[0]
        out_shape = []
        for d, (lo, hi, interior) in zip(src.shape, cfg):
            if isinstance(d, SymDim):
                if interior == 0:
                    out_shape.append(
                        b.derived(f"{d.name}+{lo + hi}", d.rep + lo + hi,
                                  ("affine", d, 1, lo + hi)))
                else:
                    scale = 1 + interior
                    off = lo + hi - interior
                    out_shape.append(
                        b.derived(f"{d.name}*{scale}", scale * d.rep + off,
                                  ("affine", d, scale, off)))
            else:
                out_shape.append(d + lo + hi + max(d - 1, 0) * interior)
        emit("pad", in_vals, [tuple(out_shape)], extra_attrs={"padding_config": cfg})
        return

    if name == "iota":
        shape = tuple(params["shape"])
        out_shape = tuple(b.resymbolize(s, []) for s in shape)
        emit("iota", in_vals, [out_shape],
             extra_attrs={"dimension": params.get("dimension", 0),
                          "iota_dtype": params.get("dtype")})
        return

    # ---- generic fallback: keep the primitive; resymbolize outputs ----
    # higher-order primitives must never reach here — binding a rep-traced
    # inner jaxpr at a different bucket shape would be silently wrong
    sub_keys = sorted(k for k, v in params.items() if _has_subjaxpr(v))
    if sub_keys:
        raise UnsupportedPrimitiveError(
            f"higher-order primitive {name!r} (closed-over jaxpr in params "
            f"{sub_keys}) is not supported by the DHLO bridge; supported "
            f"region ops are while/scan/cond — plain call-like primitives "
            f"belong in _INLINE in jaxpr_frontend.py")
    local = [d for v in in_vals for d in v.shape]
    out_shapes = [tuple(b.resymbolize(s, local) for s in v.aval.shape)
                  for v in eqn.outvars]
    out_dtypes = [v.aval.dtype for v in eqn.outvars]
    op = g.add_op(name, in_vals, out_shapes, out_dtypes, attrs=attrs)
    for var, val in zip(eqn.outvars, op.outputs):
        b.write(var, val)


def bridge(fn: Callable, arg_specs: Sequence[ArgSpec], *, name: str = "graph",
           collect_hints: bool = True,
           bounds: Optional[Dict[str, int]] = None,
           ) -> Tuple[DGraph, List[ArgSpec]]:
    """Lower ``fn`` to a DHLO graph, collecting shape constraints (§4.2.1).

    ``bounds`` maps symbol names to their declared ``Dim(max=...)`` caps;
    the caps are recorded in the constraint store up front so region-op
    carry widening (and the memory planner) can use them.
    """
    b = _Bridge(name)
    b.bounds = dict(bounds or {})
    sym_shapes: List[SymShape] = []
    for spec in arg_specs:
        dims: List[Dim] = []
        for s in spec.shape:
            dims.append(b.symbol(s) if isinstance(s, str) else int(s))
        sym_shapes.append(tuple(dims))
    for nm, cap in b.bounds.items():
        if nm in b.symbols and cap is not None:
            b.graph.store.note_dim_bound(b.symbols[nm], int(cap))

    concrete = [jax.ShapeDtypeStruct(tuple(dim_value(d) for d in sh), spec.dtype)
                for sh, spec in zip(sym_shapes, arg_specs)]
    closed = jax.make_jaxpr(fn)(*concrete)

    for spec, sh, var in zip(arg_specs, sym_shapes, closed.jaxpr.invars):
        v = b.graph.add_param(sh, spec.dtype, name=spec.name)
        b.write(var, v)
    for cvar, cval in zip(closed.jaxpr.constvars, closed.consts):
        b.write(cvar, b.graph.add_const(np.asarray(cval)))
    for eqn in closed.jaxpr.eqns:
        _bridge_eqn(b, eqn)
    b.graph.set_outputs([b.read(a) for a in closed.jaxpr.outvars])

    # constraint source #1: op semantics
    collect_semantic_constraints(b.graph)
    # constraint source #2: high-level-op structure hints
    if collect_hints:
        from .hints import collect_frontend_hints
        collect_frontend_hints(b.graph)
    return b.graph, list(arg_specs)
