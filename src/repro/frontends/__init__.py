from .jaxpr_frontend import ArgSpec, bridge  # noqa: F401
