"""Deterministic, seeded fault injection — the test harness for the
fault-tolerance plane.

Hot paths carry **named injection sites**; each site is a single
module-global check (``faults.ACTIVE is None`` → fall through), so the
disabled cost is one attribute load per site — nothing allocates, nothing
locks, no call is made.  Enabled, an installed :class:`FaultInjector`
decides *deterministically* (explicit call indices, or a seeded RNG)
whether each site occurrence fires.

Sites (the string is the contract; tests and the chaos bench key on it):

=====================  =====================================================
``compile.bucket``     :meth:`repro.core.cache.CompileCache.get_or_compile`
                       — compile-of-bucket-k fails
``compile.exact``      :meth:`...get_or_compile_exact` — a §4.4 exact
                       escalation compile fails
``kernel.cluster``     :func:`repro.core.codegen` cluster-kernel execution
                       — a pallas ``ClusterKernel`` raises at trace time
``serve.launch``       :class:`repro.serve.engine.ServeEngine` artifact
                       launches (prefill / decode / verify)
``pool.alloc``         :meth:`repro.serve.paging.BlockAllocator.ensure` —
                       allocation denied (simulated pool pressure)
``ft.heartbeat``       :meth:`repro.ft.supervisor.HeartbeatMonitor.beat` —
                       the beat is dropped (lost heartbeat)
=====================  =====================================================

Raising sites (``compile.*``, ``kernel.*``, ``serve.*``) go through
:meth:`FaultInjector.check`, which raises the spec's error.  Behavioral
sites (``pool.alloc``, ``ft.heartbeat``) go through
:meth:`FaultInjector.suppress`, which returns True when the operation
should be denied/dropped.  Both count every occurrence per site
(``injector.calls``) and every firing (``injector.fired``), so a
differential test can assert exactly N faults landed.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from ..errors import CompileError, LaunchError

__all__ = ["FaultSpec", "FaultInjector", "install", "clear", "inject",
           "ACTIVE", "SITES"]

#: every named site, documented above — specs naming an unknown site are
#: rejected at construction so a typo cannot silently inject nothing
SITES: Tuple[str, ...] = (
    "compile.bucket", "compile.exact", "kernel.cluster", "serve.launch",
    "pool.alloc", "ft.heartbeat",
)

_RAISING_SITES = frozenset(
    ("compile.bucket", "compile.exact", "kernel.cluster", "serve.launch"))


def _default_error(site: str, transient: bool) -> Exception:
    kind = "transient" if transient else "permanent"
    if site.startswith("compile."):
        return CompileError(f"injected {kind} fault at {site}",
                            transient=transient)
    return LaunchError(f"injected {kind} fault at {site}",
                       transient=transient)


@dataclass
class FaultSpec:
    """One injection rule.

    * ``site``      — a name from :data:`SITES`.
    * ``at``        — fire on exactly these 0-based call indices, counted
      over the calls this spec *matches* (site + ``match`` filter), so
      ``FaultSpec("serve.launch", match="decode", at=[0])`` fires on the
      first decode launch regardless of how many prefills came before;
      ``None`` = every eligible call.
    * ``times``     — stop firing after this many hits (``None`` =
      unbounded).
    * ``p``         — probability a call eligible under ``at``/``times``
      fires, drawn from the injector's seeded RNG (1.0 = always — fully
      deterministic; <1.0 = deterministic *given the seed*).
    * ``match``     — substring the site's key (artifact name, host,
      slot id) must contain; ``None`` matches any key.
    * ``transient`` — classification of the injected error (raising
      sites only).
    * ``error``     — factory for the exception to raise (raising sites);
      default builds a :class:`CompileError`/:class:`LaunchError` per the
      site and ``transient``.
    """

    site: str
    at: Optional[Sequence[int]] = None
    times: Optional[int] = None
    p: float = 1.0
    match: Optional[str] = None
    transient: bool = False
    error: Optional[Callable[[], Exception]] = None
    hits: int = field(default=0, init=False)
    seen: int = field(default=0, init=False)   # matching calls observed

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(
                f"unknown fault site {self.site!r}; known: {list(SITES)}")
        if not 0.0 <= self.p <= 1.0:
            raise ValueError(f"FaultSpec(p={self.p}): need 0 <= p <= 1")


class FaultInjector:
    """A set of :class:`FaultSpec` rules plus the per-site call counters
    that make schedules deterministic."""

    def __init__(self, specs: Sequence[FaultSpec], *, seed: int = 0):
        self.specs = list(specs)
        self.seed = seed
        self._rng = random.Random(seed)
        self.calls: Dict[str, int] = {s: 0 for s in SITES}
        self.fired: Dict[str, int] = {s: 0 for s in SITES}

    # ------------------------------------------------------------ engine --
    def _pick(self, site: str, key: str) -> Optional[FaultSpec]:
        self.calls[site] += 1
        for spec in self.specs:
            if spec.site != site:
                continue
            if spec.match is not None and spec.match not in key:
                continue
            idx = spec.seen
            spec.seen = idx + 1
            if spec.at is not None and idx not in spec.at:
                continue
            if spec.times is not None and spec.hits >= spec.times:
                continue
            if spec.p < 1.0 and self._rng.random() >= spec.p:
                continue
            spec.hits += 1
            self.fired[site] += 1
            return spec
        return None

    def check(self, site: str, key: str = "") -> None:
        """Raising sites: raise the matched spec's error, else no-op."""
        spec = self._pick(site, key)
        if spec is not None:
            err = (spec.error() if spec.error is not None
                   else _default_error(site, spec.transient))
            raise err

    def suppress(self, site: str, key: str = "") -> bool:
        """Behavioral sites: True = deny/drop the operation."""
        return self._pick(site, key) is not None

    # ------------------------------------------------------- convenience --
    def total_fired(self) -> int:
        return sum(self.fired.values())

    @staticmethod
    def chaos(*, seed: int, rate: float = 0.05,
              sites: Sequence[str] = SITES) -> "FaultInjector":
        """A random-schedule injector for chaos runs: every listed site
        fires with probability ``rate`` per call, transient and permanent
        faults alternating — deterministic for a fixed seed."""
        specs = []
        for k, s in enumerate(sites):
            specs.append(FaultSpec(site=s, p=rate, transient=(k % 2 == 0)))
        return FaultInjector(specs, seed=seed)


#: the installed injector; hot paths guard on ``ACTIVE is not None``
ACTIVE: Optional[FaultInjector] = None


def install(injector: FaultInjector) -> FaultInjector:
    global ACTIVE
    ACTIVE = injector
    return injector


def clear() -> None:
    global ACTIVE
    ACTIVE = None


class inject:
    """``with faults.inject(FaultSpec(...), seed=7) as inj:`` — install
    an injector for the block, always uninstalled on exit."""

    def __init__(self, *specs: FaultSpec, seed: int = 0,
                 injector: Optional[FaultInjector] = None):
        self.injector = injector or FaultInjector(specs, seed=seed)

    def __enter__(self) -> FaultInjector:
        return install(self.injector)

    def __exit__(self, *exc) -> None:
        clear()
