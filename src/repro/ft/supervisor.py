"""Fault tolerance: heartbeats, straggler mitigation, elastic re-mesh.

On a real multi-pod deployment these hooks attach to the cluster manager
(GKE/Borg health endpoints); here the *logic* is implemented fully and
exercised against simulated failure traces (tests/test_ft.py), while the
actual process control is a single-host no-op.  Components:

* :class:`HeartbeatMonitor` — per-host last-seen timestamps + deadline;
  hosts silent past the deadline are declared dead (node failure) and
  hosts whose step latency exceeds ``straggler_factor`` x the rolling
  median are flagged stragglers.
* :class:`ElasticPlan` — given the surviving host set, plans the largest
  valid (data, model) mesh that keeps the model axis intact (model
  parallelism cannot shrink without resharding weights), shrinking the
  data axis — checkpoints are topology-agnostic (checkpoint/), so restore
  onto the new mesh is a pure re-layout.
* :class:`Supervisor` — ties it together: journals progress, decides
  restore-step and new mesh after a failure event, applies a straggler
  policy (drop-slowest for sync training = shrink data axis; or mark for
  replacement).
"""
from __future__ import annotations

import json
import pathlib
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import faults
from ..obs.clock import CLOCK

__all__ = ["HeartbeatMonitor", "ElasticPlan", "Supervisor"]


class HeartbeatMonitor:
    """Per-host last-seen timestamps against a monotonic deadline.

    Timestamps default to the shared ``obs`` clock (``perf_counter`` —
    the old ``time.time()`` wall clock jumps under NTP adjustment, which
    could declare every host dead or resurrect one).  Pass ``clock`` (or
    explicit ``t=``/``now=`` values) to drive time deterministically in
    tests.
    """

    def __init__(self, hosts: Sequence[str], *, deadline_s: float = 60.0,
                 straggler_factor: float = 2.0,
                 clock: Optional[Callable[[], float]] = None):
        self.deadline_s = deadline_s
        self.straggler_factor = straggler_factor
        self._clock: Callable[[], float] = clock or CLOCK
        self.last_seen: Dict[str, float] = {h: 0.0 for h in hosts}
        self.step_times: Dict[str, List[float]] = {h: [] for h in hosts}

    def beat(self, host: str, *, t: Optional[float] = None,
             step_seconds: Optional[float] = None) -> None:
        if faults.ACTIVE is not None and faults.ACTIVE.suppress(
                "ft.heartbeat", key=host):
            return          # injected heartbeat loss: the beat is dropped
        self.last_seen[host] = self._clock() if t is None else t
        if step_seconds is not None:
            window = self.step_times[host]
            window.append(step_seconds)
            if len(window) > 32:
                window.pop(0)

    def dead_hosts(self, *, now: Optional[float] = None) -> List[str]:
        now = self._clock() if now is None else now
        return [h for h, seen in self.last_seen.items()
                if now - seen > self.deadline_s]

    def stragglers(self) -> List[str]:
        meds = {h: float(np.median(w)) for h, w in self.step_times.items() if w}
        if len(meds) < 2:
            return []
        global_med = float(np.median(list(meds.values())))
        return [h for h, m in meds.items()
                if m > self.straggler_factor * global_med]


@dataclass(frozen=True)
class ElasticPlan:
    """A re-mesh decision after failures: keep model axis, shrink data."""

    data: int
    model: int
    pods: int = 1
    dropped_hosts: Tuple[str, ...] = ()

    @property
    def chips(self) -> int:
        return self.pods * self.data * self.model

    @staticmethod
    def plan(n_alive_chips: int, *, model: int, pod_size: int = 256,
             dropped: Sequence[str] = ()) -> "ElasticPlan":
        """Largest data axis that fits the surviving chips, model intact.

        data is kept a power of two so global batch stays divisible and
        bucketed compile caches stay valid across re-meshes."""
        if n_alive_chips < model:
            raise RuntimeError(
                f"cannot keep model={model} with {n_alive_chips} chips")
        pods = max(n_alive_chips // pod_size, 1)
        per_pod = n_alive_chips // pods
        data = 1
        while data * 2 * model <= per_pod:
            data *= 2
        return ElasticPlan(data=data, model=model, pods=pods,
                           dropped_hosts=tuple(dropped))


class Supervisor:
    """Journals steps; on failure, emits (restore_step, ElasticPlan)."""

    def __init__(self, workdir, *, hosts: Sequence[str], model_axis: int,
                 deadline_s: float = 60.0,
                 clock: Optional[Callable[[], float]] = None):
        self.workdir = pathlib.Path(workdir)
        self.workdir.mkdir(parents=True, exist_ok=True)
        self.monitor = HeartbeatMonitor(hosts, deadline_s=deadline_s,
                                        clock=clock)
        self.model_axis = model_axis
        self.journal_path = self.workdir / "supervisor_journal.json"
        self.events: List[Dict] = []

    def record_step(self, step: int, host: str, step_seconds: float,
                    *, t: Optional[float] = None) -> None:
        self.monitor.beat(host, t=t, step_seconds=step_seconds)
        self.events.append({"kind": "step", "step": step, "host": host,
                            "seconds": step_seconds})

    def check(self, *, chips_per_host: int, last_ckpt_step: int,
              now: Optional[float] = None) -> Optional[Tuple[int, ElasticPlan]]:
        """Returns (restore_step, plan) if the mesh must change, else None."""
        dead = self.monitor.dead_hosts(now=now)
        stragglers = self.monitor.stragglers()
        to_drop = sorted(set(dead) | set(stragglers))
        if not to_drop:
            return None
        alive = [h for h in self.monitor.last_seen if h not in to_drop]
        plan = ElasticPlan.plan(len(alive) * chips_per_host,
                                model=self.model_axis, dropped=to_drop)
        self.events.append({"kind": "remesh", "dropped": to_drop,
                            "plan": {"data": plan.data, "model": plan.model,
                                     "pods": plan.pods}})
        self._flush()
        return last_ckpt_step, plan

    def _flush(self) -> None:
        self.journal_path.write_text(json.dumps(self.events, indent=2))
