from . import faults  # noqa: F401
from .faults import FaultInjector, FaultSpec  # noqa: F401
from .supervisor import Supervisor, HeartbeatMonitor, ElasticPlan  # noqa: F401
