from .supervisor import Supervisor, HeartbeatMonitor, ElasticPlan  # noqa: F401
