"""Model registry: family -> (init/specs/forward/loss/cache/decode) bundle."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from . import rwkv, transformer, whisper, zamba
from .common import ArchConfig

Params = Dict[str, Any]


@dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable            # (rng) -> params
    specs: Callable           # () -> PartitionSpec tree (congruent to params)
    forward: Callable         # (params, batch) -> logits
    loss: Callable            # (params, batch) -> scalar
    init_cache: Callable      # (batch, max_len) -> cache
    cache_specs: Callable     # () -> PartitionSpec tree
    decode_step: Callable     # (params, cache, tokens, lens, **kw) -> (logits, cache)
    prefill: Callable         # (params, cache, tokens, lens, offsets) -> (last_logits, cache)
    verify: Callable          # (params, cache, tokens, lens, offsets) -> (all_logits, cache)
    # paged-KV entry points; None for families whose cache has no
    # sequence axis to page (recurrent state)
    init_block_pool: Optional[Callable] = None  # (n_blocks, block_size) -> pool
    page_axes: Optional[Callable] = None        # () -> per-leaf seq-axis tree
    # whole decode loop as ONE traced lax.while_loop (early EOS exit);
    # (params, cache, tokens, lens, *, max_new, eos_id, **kw)
    #   -> (tokens (B, max_new), n_steps, cache)
    greedy_decode: Optional[Callable] = None


def cache_batch_axis(shape, batch: int) -> Optional[int]:
    """The batch axis of a cache leaf, or ``None`` if no axis matches.

    Cache leaves are layer-stacked ``(L, B, ...)`` in every model family
    (``init_cache`` stacks per-layer trees), so the batch axis is axis 1;
    a leaf whose axis 1 doesn't match falls back to a leading batch
    axis.  The single source of this rule — masking
    (:func:`row_keep_mask`) and SPMD cache placement (the serve engine)
    must agree on it.
    """
    nd = len(shape)
    if nd >= 2 and shape[1] == batch:
        return 1
    if nd >= 1 and shape[0] == batch:
        return 0
    return None


def row_keep_mask(keep: jax.Array, leaf: jax.Array) -> jax.Array:
    """Broadcast a per-row mask (B,) against a cache leaf (see
    :func:`cache_batch_axis` for the axis rule).  Used to gate cache
    updates so inactive rows (mid-prefill slots, padded batch rows) are
    never touched by a step they didn't take.
    """
    b = keep.shape[0]
    nd = len(leaf.shape)
    ax = cache_batch_axis(leaf.shape, b)
    if ax == 1:
        return keep.reshape((1, b) + (1,) * (nd - 2))
    if ax == 0:
        return keep.reshape((b,) + (1,) * (nd - 1))
    raise ValueError(
        f"cache leaf of shape {tuple(leaf.shape)} has no axis matching "
        f"batch={b}; cannot gate per-row updates")


def replay_verify(decode_step: Callable) -> Callable:
    """All-position logits by replaying a chunk through decode steps.

    The generic speculative-verify fallback for model families without a
    native single-pass ``verify`` (recurrent caches need sequential state
    updates anyway): ``logits[r, j]`` is the model's next-token
    distribution after consuming ``tokens[r, j]``.  Row updates are gated
    by ``j < lens`` so padded chunk positions never touch the cache:
    critical for recurrent state, which is overwritten (not positionally
    masked) by every step.
    """
    def verify(params, cache, tokens, lens, offsets):
        def step(carry, j):
            tok = jax.lax.dynamic_slice_in_dim(tokens, j, 1, axis=1)
            logits, new_cache = decode_step(params, carry, tok, offsets + j)
            keep = j < lens
            gated = jax.tree.map(
                lambda n, o: jnp.where(row_keep_mask(keep, o),
                                       n.astype(o.dtype), o),
                new_cache, carry)
            return gated, logits[:, 0]

        cache, logits = jax.lax.scan(step, cache,
                                     jnp.arange(tokens.shape[1]))
        return logits.transpose(1, 0, 2), cache

    return verify


def replay_prefill(decode_step: Callable) -> Callable:
    """Batched prefill by replaying the chunk through decode steps.

    The fallback for model families without a native single-pass
    ``prefill`` — and the serve benchmark's O(prompt_len)-launches
    baseline.  :func:`replay_verify` does the sequential work; this just
    selects each row's last valid position.
    """
    vf = replay_verify(decode_step)

    def prefill(params, cache, tokens, lens, offsets):
        b = tokens.shape[0]
        logits, cache = vf(params, cache, tokens, lens, offsets)
        idx = jnp.maximum(lens - 1, 0)[:, None, None]
        last = jnp.take_along_axis(
            logits, jnp.broadcast_to(idx, (b, 1, logits.shape[-1])), axis=1)
        return last[:, 0], cache

    return prefill


def _lm_bundle(mod, cfg: ArchConfig) -> Model:
    def fwd(params, batch):
        return mod.forward(cfg, params, batch["tokens"],
                           lens=batch.get("lens"),
                           extra_embeds=batch.get("image_embeds"))

    def decode(params, cache, tokens, lens, **kw):
        return mod.decode_step(cfg, params, cache, tokens, lens, **kw)

    if hasattr(mod, "prefill"):
        pf = lambda params, cache, tokens, lens, offsets: \
            mod.prefill(cfg, params, cache, tokens, lens, offsets)
    else:
        pf = replay_prefill(decode)
    if hasattr(mod, "verify"):
        vf = lambda params, cache, tokens, lens, offsets: \
            mod.verify(cfg, params, cache, tokens, lens, offsets)
    else:
        vf = replay_verify(decode)
    paged = hasattr(mod, "init_block_pool")

    return Model(
        cfg=cfg,
        init=lambda rng: mod.init(cfg, rng),
        specs=lambda: mod.specs(cfg),
        forward=fwd,
        loss=lambda params, batch: mod.loss_fn(cfg, params, batch),
        init_cache=lambda b, s: mod.init_cache(cfg, b, s),
        cache_specs=lambda: mod.cache_specs(cfg),
        decode_step=decode,
        prefill=pf,
        verify=vf,
        init_block_pool=(lambda n, bs: mod.init_block_pool(cfg, n, bs))
        if paged else None,
        page_axes=(lambda: mod.page_axes(cfg)) if paged else None,
        greedy_decode=(lambda params, cache, tokens, lens, **kw:
                       mod.greedy_decode(cfg, params, cache, tokens, lens,
                                         **kw))
        if hasattr(mod, "greedy_decode") else None,
    )


def _whisper_bundle(cfg: ArchConfig) -> Model:
    def fwd(params, batch):
        return whisper.forward(cfg, params, batch["tokens"],
                               frames=batch["frames"],
                               lens=batch.get("lens"))

    def decode(params, cache, tokens, lens, **kw):
        return whisper.decode_step(cfg, params, cache, tokens, lens, **kw)

    return Model(
        cfg=cfg,
        init=lambda rng: whisper.init(cfg, rng),
        specs=lambda: whisper.specs(cfg),
        forward=fwd,
        loss=lambda params, batch: whisper.loss_fn(cfg, params, batch),
        init_cache=lambda b, s: whisper.init_cache(cfg, b, s),
        cache_specs=lambda: whisper.cache_specs(cfg),
        decode_step=decode,
        # decoder-side replay only; callers must thread enc_out through
        # decode_step kwargs themselves (the serve engine is LM-only)
        prefill=replay_prefill(decode),
        verify=replay_verify(decode),
        greedy_decode=lambda params, cache, tokens, lens, **kw:
            whisper.greedy_decode(cfg, params, cache, tokens, lens, **kw),
    )


MODEL_FAMILIES = {
    "dense": lambda cfg: _lm_bundle(transformer, cfg),
    "moe": lambda cfg: _lm_bundle(transformer, cfg),
    "vlm": lambda cfg: _lm_bundle(transformer, cfg),
    "ssm": lambda cfg: _lm_bundle(rwkv, cfg),
    "hybrid": lambda cfg: _lm_bundle(zamba, cfg),
    "encdec": _whisper_bundle,
}


def get_model(cfg: ArchConfig) -> Model:
    try:
        return MODEL_FAMILIES[cfg.family](cfg)
    except KeyError:
        raise ValueError(f"unknown model family {cfg.family!r}")
