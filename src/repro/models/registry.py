"""Model registry: family -> (init/specs/forward/loss/cache/decode) bundle."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax

from . import rwkv, transformer, whisper, zamba
from .common import ArchConfig

Params = Dict[str, Any]


@dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable            # (rng) -> params
    specs: Callable           # () -> PartitionSpec tree (congruent to params)
    forward: Callable         # (params, batch) -> logits
    loss: Callable            # (params, batch) -> scalar
    init_cache: Callable      # (batch, max_len) -> cache
    cache_specs: Callable     # () -> PartitionSpec tree
    decode_step: Callable     # (params, cache, tokens, lens, **kw) -> (logits, cache)


def _lm_bundle(mod, cfg: ArchConfig) -> Model:
    def fwd(params, batch):
        return mod.forward(cfg, params, batch["tokens"],
                           lens=batch.get("lens"),
                           extra_embeds=batch.get("image_embeds"))

    return Model(
        cfg=cfg,
        init=lambda rng: mod.init(cfg, rng),
        specs=lambda: mod.specs(cfg),
        forward=fwd,
        loss=lambda params, batch: mod.loss_fn(cfg, params, batch),
        init_cache=lambda b, s: mod.init_cache(cfg, b, s),
        cache_specs=lambda: mod.cache_specs(cfg),
        decode_step=lambda params, cache, tokens, lens, **kw:
            mod.decode_step(cfg, params, cache, tokens, lens, **kw),
    )


def _whisper_bundle(cfg: ArchConfig) -> Model:
    def fwd(params, batch):
        return whisper.forward(cfg, params, batch["tokens"],
                               frames=batch["frames"],
                               lens=batch.get("lens"))

    return Model(
        cfg=cfg,
        init=lambda rng: whisper.init(cfg, rng),
        specs=lambda: whisper.specs(cfg),
        forward=fwd,
        loss=lambda params, batch: whisper.loss_fn(cfg, params, batch),
        init_cache=lambda b, s: whisper.init_cache(cfg, b, s),
        cache_specs=lambda: whisper.cache_specs(cfg),
        decode_step=lambda params, cache, tokens, lens, **kw:
            whisper.decode_step(cfg, params, cache, tokens, lens, **kw),
    )


MODEL_FAMILIES = {
    "dense": lambda cfg: _lm_bundle(transformer, cfg),
    "moe": lambda cfg: _lm_bundle(transformer, cfg),
    "vlm": lambda cfg: _lm_bundle(transformer, cfg),
    "ssm": lambda cfg: _lm_bundle(rwkv, cfg),
    "hybrid": lambda cfg: _lm_bundle(zamba, cfg),
    "encdec": _whisper_bundle,
}


def get_model(cfg: ArchConfig) -> Model:
    try:
        return MODEL_FAMILIES[cfg.family](cfg)
    except KeyError:
        raise ValueError(f"unknown model family {cfg.family!r}")
