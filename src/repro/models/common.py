"""Shared model-zoo infrastructure: configs, param trees, sharding specs.

Parameters are plain pytrees (nested dicts of arrays); every init function
has a mirrored ``*_specs`` producing an identically-structured tree of
``jax.sharding.PartitionSpec`` for the production mesh axes
``("data", "model")`` (+"pod").  Tests assert the trees stay congruent.

Logical sharding rules (DESIGN §7, MaxText-style 2-D):
  embeddings      : vocab -> "model", d_model -> "data"   (FSDP)
  attn in-proj    : d_model -> "data", heads·hd -> "model" (TP)
  attn out-proj   : heads·hd -> "model", d_model -> "data"
  mlp in / gate   : d_model -> "data", d_ff -> "model"
  mlp out         : d_ff -> "model", d_model -> "data"
  MoE experts     : experts -> "model" (EP), d_model -> "data"
  norms / biases  : replicated
  activations     : batch -> "data" (+"pod"), heads/d_ff -> "model"
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

__all__ = ["ArchConfig", "param_init", "DTYPES", "cross_entropy_loss",
           "greedy_decode"]

DTYPES = {"bf16": jnp.bfloat16, "f32": jnp.float32}


@dataclass(frozen=True)
class ArchConfig:
    """Architecture config covering all assigned families."""

    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_expert: Optional[int] = None  # fine-grained expert width (else d_ff)
    capacity_factor: float = 1.25
    # MLA (deepseek-v2)
    mla_kv_lora: int = 0
    mla_rope_dim: int = 0
    # SSM
    ssm_state: int = 0
    ssm_head_dim: int = 64
    # hybrid (zamba2): one shared attention block every k blocks
    shared_attn_every: int = 0
    # enc-dec (whisper)
    n_encoder_layers: int = 0
    encoder_len: int = 0           # static frame count (conv stub output)
    # vlm (llava)
    max_image_tokens: int = 0
    # sharding profile (§Perf H2): "tp" = 2-D TP x FSDP (default);
    # "fsdp" = pure ZeRO-3 over both mesh axes — small dense models pay TP
    # activation all-reduces without needing TP for memory, so they run
    # data-parallel on all 256 chips with fully-sharded params instead
    sharding_profile: str = "tp"
    # numerics / scale
    dtype: str = "bf16"
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    act: str = "silu"              # silu (swiglu) | gelu
    rope_theta: float = 1e4
    tie_embeddings: bool = False
    remat: str = "full"            # none | full | dots
    max_seq: int = 8192
    # attention flavor for long ctx runs
    attn_kind: str = "full"        # full | none (ssm)

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def expert_width(self) -> int:
        return self.d_expert if self.d_expert else self.d_ff

    def n_params(self) -> int:
        """Analytic parameter count (used for 6·N·D roofline baselines)."""
        d, v, L = self.d_model, self.vocab, self.n_layers
        hd, h, hkv = self.hd, self.n_heads, self.n_kv_heads
        embed = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":  # rwkv6: time-mix ~4 d^2 + channel-mix
            per_layer = 4 * d * d + 2 * d * self.d_ff + d * d
            return embed + L * per_layer
        if self.mla_kv_lora:
            attn = d * (h * hd) + d * self.mla_kv_lora + \
                self.mla_kv_lora * (h * hd * 2) + (h * hd) * d + \
                d * self.mla_rope_dim
        else:
            attn = d * (h * hd) + 2 * d * (hkv * hd) + (h * hd) * d
        if self.is_moe:
            e_w = self.expert_width
            ffn = self.n_experts * 3 * d * e_w + \
                self.n_shared_experts * 3 * d * e_w + d * self.n_experts
        else:
            ffn = 3 * d * self.d_ff if self.act == "silu" else 2 * d * self.d_ff
        per_layer = attn + ffn + 2 * d
        if self.family == "hybrid":
            # mamba2 blocks + one shared attention block
            dm_inner = 2 * d
            mamba = d * dm_inner * 2 + dm_inner * d + \
                dm_inner * (2 * self.ssm_state + 2)
            n_attn = 1
            return embed + L * (mamba + 3 * d * self.d_ff // 2) + \
                n_attn * attn
        total = embed + L * per_layer
        if self.n_encoder_layers:
            total += self.n_encoder_layers * (attn + ffn + 2 * d) + \
                self.n_encoder_layers * attn  # cross-attn in decoder counted approx
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE-aware) for MODEL_FLOPS = 6·N_act·D."""
        if not self.is_moe:
            return self.n_params()
        d, L = self.d_model, self.n_layers
        e_w = self.expert_width
        routed_all = self.n_experts * 3 * d * e_w
        routed_active = self.top_k * 3 * d * e_w
        return self.n_params() - L * routed_all + L * routed_active

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads > 1 else 1,
            head_dim=16,
            d_ff=128,
            vocab=256,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            d_expert=32 if self.d_expert else None,
            mla_kv_lora=32 if self.mla_kv_lora else 0,
            mla_rope_dim=8 if self.mla_rope_dim else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16,
            shared_attn_every=2 if self.shared_attn_every else 0,
            n_encoder_layers=min(self.n_encoder_layers, 2),
            encoder_len=32 if self.encoder_len else 0,
            max_image_tokens=16 if self.max_image_tokens else 0,
            dtype="f32",
            remat="none",
            max_seq=128,
        )


def param_init(rng: jax.Array, shape: Tuple[int, ...], dtype,
               scale: Optional[float] = None) -> jax.Array:
    if scale is None:
        fan_in = shape[0] if len(shape) > 1 else max(shape[0], 1)
        scale = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(rng, shape, jnp.float32) * scale).astype(dtype)


def greedy_decode(step_fn: Callable, cache, first_tokens, lens, *,
                  max_new: int, eos_id: int):
    """Greedy autoregressive decode as ONE traced ``lax.while_loop``.

    ``step_fn(cache, tokens, lens) -> (logits, cache)`` is a decode step
    already closed over params (and any model kwargs such as whisper's
    ``enc_out``).  The loop early-exits as soon as every row has emitted
    ``eos_id`` — the whole decode is a single region op inside one bucketed
    artifact instead of ``max_new`` separate dispatches, so the compile
    count stays keyed on *entry* shapes only.

    Rows that finish keep emitting ``eos_id`` (their buffer stays frozen);
    the cache still advances uniformly for every row, matching a batched
    Python reference loop step for step.

    Returns ``(tokens (B, max_new) int32, n_steps int32, cache)``.
    """
    b = first_tokens.shape[0]
    buf = jnp.full((b, max_new), eos_id, jnp.int32)

    def cond(c):
        i, _, _, _, _, done = c
        return jnp.logical_and(i < max_new, jnp.logical_not(jnp.all(done)))

    def body(c):
        i, buf, cur, lens, cache, done = c
        logits, cache = step_fn(cache, cur, lens)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        nxt = jnp.where(done, jnp.int32(eos_id), nxt)
        buf = jax.lax.dynamic_update_slice(buf, nxt[:, None], (0, i))
        done = jnp.logical_or(done, nxt == jnp.int32(eos_id))
        return (i + 1, buf, nxt[:, None], lens + 1, cache, done)

    init = (jnp.int32(0), buf,
            jnp.asarray(first_tokens, jnp.int32).reshape(b, 1),
            jnp.asarray(lens, jnp.int32), cache,
            jnp.zeros((b,), jnp.bool_))
    n, buf, _, _, cache, _ = jax.lax.while_loop(cond, body, init)
    return buf, n, cache


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       mask: Optional[jax.Array] = None) -> jax.Array:
    """Token-mean cross entropy in f32 with optional validity mask."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
