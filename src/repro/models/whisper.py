"""Whisper-style encoder-decoder (audio backbone; conv frontend is a STUB).

Per the assignment, ``input_specs()`` supplies *precomputed frame
embeddings* (B, encoder_len, D) — the mel-spectrogram conv stem is out of
scope.  Encoder is static-shape (1500 frames): under DISC this sub-graph
takes the §4.4 static-fallback path; the decoder is the dynamic part.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..dist.context import maybe_shard
from . import layers as L
from .common import ArchConfig, cross_entropy_loss, greedy_decode as \
    _greedy_decode, param_init

Params = Dict[str, Any]


def _enc_block_init(rng, cfg: ArchConfig) -> Params:
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    return {"ln1": L.norm_init(k1, cfg), "attn": L.attn_init(k2, cfg),
            "ln2": L.norm_init(k3, cfg), "mlp": L.mlp_init(k4, cfg)}


def _dec_block_init(rng, cfg: ArchConfig) -> Params:
    ks = jax.random.split(rng, 6)
    return {"ln1": L.norm_init(ks[0], cfg), "self": L.attn_init(ks[1], cfg),
            "ln2": L.norm_init(ks[2], cfg), "cross": L.attn_init(ks[3], cfg),
            "ln3": L.norm_init(ks[4], cfg), "mlp": L.mlp_init(ks[5], cfg)}


def _enc_block_specs(cfg):
    return {"ln1": L.norm_specs(cfg), "attn": L.attn_specs(cfg),
            "ln2": L.norm_specs(cfg), "mlp": L.mlp_specs(cfg)}


def _dec_block_specs(cfg):
    return {"ln1": L.norm_specs(cfg), "self": L.attn_specs(cfg),
            "ln2": L.norm_specs(cfg), "cross": L.attn_specs(cfg),
            "ln3": L.norm_specs(cfg), "mlp": L.mlp_specs(cfg)}


def init(cfg: ArchConfig, rng) -> Params:
    dt = jnp.bfloat16 if cfg.dtype == "bf16" else jnp.float32
    ks = jax.random.split(rng, 6)
    enc = jax.vmap(lambda k: _enc_block_init(k, cfg))(
        jax.random.split(ks[0], cfg.n_encoder_layers))
    dec = jax.vmap(lambda k: _dec_block_init(k, cfg))(
        jax.random.split(ks[1], cfg.n_layers))
    return {
        "embed": param_init(ks[2], (cfg.vocab, cfg.d_model), dt, scale=0.02),
        "enc_pos": param_init(ks[3], (cfg.encoder_len, cfg.d_model), dt,
                              scale=0.02),
        "encoder": enc, "decoder": dec,
        "ln_enc": L.norm_init(ks[4], cfg),
        "ln_f": L.norm_init(ks[5], cfg),
        "head": param_init(jax.random.fold_in(rng, 9),
                           (cfg.d_model, cfg.vocab), dt),
    }


def specs(cfg: ArchConfig) -> Params:
    stack = lambda s: jax.tree.map(lambda q: P(*((None,) + tuple(q))), s,
                                   is_leaf=lambda q: isinstance(q, P))
    return {
        "embed": P("model", "data"),
        "enc_pos": P(None, "data"),
        "encoder": stack(_enc_block_specs(cfg)),
        "decoder": stack(_dec_block_specs(cfg)),
        "ln_enc": L.norm_specs(cfg),
        "ln_f": L.norm_specs(cfg),
        "head": P("data", "model"),
    }


def encode(cfg: ArchConfig, params: Params, frames) -> jax.Array:
    """frames: precomputed conv-stub embeddings (B, encoder_len, D)."""
    x = frames + params["enc_pos"][None]
    x = maybe_shard(x, L.A_BSD)
    s = x.shape[1]
    positions = jnp.arange(s)[None, :]

    def body(h, bp):
        a, _ = L.attn_apply(cfg, bp["attn"],
                            L.norm_apply(cfg, bp["ln1"], h),
                            positions=positions, causal=False)
        h = h + a
        h = h + L.mlp_apply(cfg, bp["mlp"], L.norm_apply(cfg, bp["ln2"], h))
        return h, None

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return L.norm_apply(cfg, params["ln_enc"], x)


def _decoder_blocks(cfg, params, x, enc_out, *, positions, lens, caches=None):
    def body(h, xs):
        if caches is None:
            bp, c = xs, None
        else:
            bp, c = xs
        a, c2 = L.attn_apply(cfg, bp["self"],
                             L.norm_apply(cfg, bp["ln1"], h),
                             positions=positions, lens=lens, cache=c)
        h = h + a
        ca, _ = L.attn_apply(cfg, bp["cross"],
                             L.norm_apply(cfg, bp["ln2"], h),
                             positions=positions, kv_source=enc_out,
                             causal=False)
        h = h + ca
        h = h + L.mlp_apply(cfg, bp["mlp"], L.norm_apply(cfg, bp["ln3"], h))
        return h, c2

    if cfg.remat != "none" and caches is None:
        body = jax.checkpoint(body)
    xs = params["decoder"] if caches is None else (params["decoder"], caches)
    return jax.lax.scan(body, x, xs)


def forward(cfg: ArchConfig, params: Params, tokens, *, frames,
            lens=None) -> jax.Array:
    enc_out = encode(cfg, params, frames)
    x = jnp.take(params["embed"], tokens, axis=0)
    x = maybe_shard(x, L.A_BSD)
    positions = jnp.arange(x.shape[1])[None, :]
    x, _ = _decoder_blocks(cfg, params, x, enc_out, positions=positions,
                           lens=lens)
    x = L.norm_apply(cfg, params["ln_f"], x)
    return maybe_shard(x @ params["head"], P(("pod", "data"), None, "model"))


def loss_fn(cfg: ArchConfig, params: Params, batch):
    logits = forward(cfg, params, batch["tokens"], frames=batch["frames"])
    return cross_entropy_loss(logits, batch["labels"], batch.get("mask"))


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> Params:
    one = lambda: L.attn_cache_init(cfg, batch, max_len)
    return jax.tree.map(lambda *xs: jnp.stack(xs),
                        *[one() for _ in range(cfg.n_layers)])


def cache_specs(cfg: ArchConfig) -> Params:
    return jax.tree.map(lambda s: P(*((None,) + tuple(s))),
                        L.attn_cache_specs(cfg),
                        is_leaf=lambda s: isinstance(s, P))


def decode_step(cfg: ArchConfig, params: Params, cache: Params, tokens,
                lens, *, enc_out) -> Tuple[jax.Array, Params]:
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = lens[:, None]
    x, new_cache = _decoder_blocks(cfg, params, x, enc_out,
                                   positions=positions, lens=lens,
                                   caches=cache)
    x = L.norm_apply(cfg, params["ln_f"], x)
    return x @ params["head"], new_cache


def greedy_decode(cfg: ArchConfig, params: Params, cache: Params, tokens,
                  lens, *, enc_out, max_new: int, eos_id: int = 0):
    """Whole greedy transcription loop as one traced ``lax.while_loop``
    (early exit once every row emits ``eos_id``) — a single region op in
    the compiled artifact rather than ``max_new`` host dispatches.
    """
    step = lambda c, t, l: decode_step(cfg, params, c, t, l, enc_out=enc_out)
    return _greedy_decode(step, cache, tokens, lens,
                          max_new=max_new, eos_id=eos_id)
