"""RWKV-6 (Finch) language model — attention-free SSM family.

Block = time-mix (WKV recurrence with data-dependent decay) + channel-mix.
State is O(1) in sequence length → runs the ``long_500k`` cell
(DESIGN §4).  DISC applicability note: no attention-length bucketing
exists (no KV cache); dynamic-shape handling applies to the elementwise-
heavy time/channel mixing (DESIGN §4 Arch-applicability).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..dist.context import maybe_shard
from . import layers as L
from .common import ArchConfig, cross_entropy_loss, greedy_decode as \
    _greedy_decode, param_init

Params = Dict[str, Any]


def _chanmix_init(rng, cfg: ArchConfig) -> Params:
    dt = jnp.bfloat16 if cfg.dtype == "bf16" else jnp.float32
    k1, k2, k3 = jax.random.split(rng, 3)
    return {"w_k": param_init(k1, (cfg.d_model, cfg.d_ff), dt),
            "w_v": param_init(k2, (cfg.d_ff, cfg.d_model), dt),
            "w_r": param_init(k3, (cfg.d_model, cfg.d_model), dt),
            "mix": param_init(jax.random.fold_in(rng, 7), (2, cfg.d_model),
                              jnp.float32, scale=0.1)}


def _chanmix_specs(cfg: ArchConfig) -> Params:
    return {"w_k": P("data", "model"), "w_v": P("model", "data"),
            "w_r": P("data", "model"), "mix": P(None, None)}


def _chanmix_apply(cfg: ArchConfig, p: Params, x, x_prev=None):
    if x_prev is None:
        xp = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        xp = x_prev[:, None]
    mix = jax.nn.sigmoid(p["mix"]).astype(x.dtype)
    xk = x * mix[0] + xp * (1 - mix[0])
    xr = x * mix[1] + xp * (1 - mix[1])
    k = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    k = maybe_shard(k, L.A_BSF)
    r = jax.nn.sigmoid(xr @ p["w_r"])
    return maybe_shard(r * (k @ p["w_v"]), L.A_BSD)


def block_init(rng, cfg: ArchConfig) -> Params:
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    return {"ln1": L.norm_init(k1, cfg), "tmix": L.rwkv6_init(k2, cfg),
            "ln2": L.norm_init(k3, cfg), "cmix": _chanmix_init(k4, cfg)}


def block_specs(cfg: ArchConfig) -> Params:
    return {"ln1": L.norm_specs(cfg), "tmix": L.rwkv6_specs(cfg),
            "ln2": L.norm_specs(cfg), "cmix": _chanmix_specs(cfg)}


def init(cfg: ArchConfig, rng) -> Params:
    dt = jnp.bfloat16 if cfg.dtype == "bf16" else jnp.float32
    k_e, k_b, k_h, k_n = jax.random.split(rng, 4)
    blocks = jax.vmap(lambda k: block_init(k, cfg))(
        jax.random.split(k_b, cfg.n_layers))
    return {"embed": param_init(k_e, (cfg.vocab, cfg.d_model), dt, scale=0.02),
            "blocks": blocks,
            "ln_f": L.norm_init(k_n, cfg),
            "head": param_init(k_h, (cfg.d_model, cfg.vocab), dt)}


def specs(cfg: ArchConfig) -> Params:
    blocks = jax.tree.map(lambda s: P(*((None,) + tuple(s))),
                          block_specs(cfg), is_leaf=lambda s: isinstance(s, P))
    return {"embed": P("model", "data"), "blocks": blocks,
            "ln_f": L.norm_specs(cfg), "head": P("data", "model")}


def forward(cfg: ArchConfig, params: Params, tokens, *, lens=None,
            extra_embeds=None) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    x = maybe_shard(x, L.A_BSD)

    def body(h, bp):
        a, _ = L.rwkv6_apply(cfg, bp["tmix"],
                             L.norm_apply(cfg, bp["ln1"], h))
        h = h + a
        h = h + _chanmix_apply(cfg, bp["cmix"],
                               L.norm_apply(cfg, bp["ln2"], h))
        return h, None

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["blocks"])
    x = L.norm_apply(cfg, params["ln_f"], x)
    return maybe_shard(x @ params["head"], P(("pod", "data"), None, "model"))


def loss_fn(cfg: ArchConfig, params: Params, batch):
    logits = forward(cfg, params, batch["tokens"])
    return cross_entropy_loss(logits, batch["labels"], batch.get("mask"))


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> Params:
    one = lambda: {"tmix": L.rwkv6_cache_init(cfg, batch),
                   "cmix_x": jnp.zeros((batch, cfg.d_model),
                                       jnp.bfloat16 if cfg.dtype == "bf16"
                                       else jnp.float32)}
    return jax.tree.map(lambda *xs: jnp.stack(xs),
                        *[one() for _ in range(cfg.n_layers)])


def cache_specs(cfg: ArchConfig) -> Params:
    one = {"tmix": L.rwkv6_cache_specs(cfg),
           "cmix_x": P(("pod", "data"), None)}
    return jax.tree.map(lambda s: P(*((None,) + tuple(s))), one,
                        is_leaf=lambda s: isinstance(s, P))


def decode_step(cfg: ArchConfig, params: Params, cache: Params, tokens,
                lens) -> Tuple[jax.Array, Params]:
    x = jnp.take(params["embed"], tokens, axis=0)

    def body(h, xs):
        bp, c = xs
        a, tmix_c = L.rwkv6_apply(cfg, bp["tmix"],
                                  L.norm_apply(cfg, bp["ln1"], h),
                                  cache=c["tmix"])
        h = h + a
        h2 = L.norm_apply(cfg, bp["ln2"], h)
        h = h + _chanmix_apply(cfg, bp["cmix"], h2, x_prev=c["cmix_x"])
        return h, {"tmix": tmix_c, "cmix_x": h2[:, -1]}

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    x = L.norm_apply(cfg, params["ln_f"], x)
    return x @ params["head"], new_cache


def greedy_decode(cfg: ArchConfig, params: Params, cache: Params, tokens,
                  lens, *, max_new: int, eos_id: int = 0):
    """Greedy generation as one traced ``lax.while_loop`` (early exit when
    every row has emitted ``eos_id``) — the recurrent state threads through
    the loop carry, so the whole decode is a single region op.
    """
    step = lambda c, t, l: decode_step(cfg, params, c, t, l)
    return _greedy_decode(step, cache, tokens, lens,
                          max_new=max_new, eos_id=eos_id)
