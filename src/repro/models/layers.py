"""Composable model-zoo layers (pure JAX, mesh-aware).

Every layer family exposes ``<name>_init(rng, cfg) -> params``,
``<name>_specs(cfg) -> PartitionSpec tree`` (congruent), and a pure apply
function usable in train (full-sequence) and decode (KV/state cache) modes.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..dist.context import get_mesh, maybe_shard
from .common import ArchConfig, param_init

Params = Dict[str, Any]

# activation sharding specs (logical) — "tp" profile
A_BSD = P(("pod", "data"), None, None)      # (B, S, D)
A_BSH = P(("pod", "data"), None, "model", None)  # (B, S, H, hd)
A_BSF = P(("pod", "data"), None, "model")   # (B, S, F)

# "fsdp" profile (§Perf H2): both mesh axes are data-parallel; params are
# fully sharded and gathered per layer; no TP activation collectives
_DP_ALL = ("pod", "data", "model")


def act_bsd(cfg: ArchConfig) -> P:
    return P(_DP_ALL, None, None) if cfg.sharding_profile == "fsdp" else A_BSD


def act_bsh(cfg: ArchConfig) -> P:
    return (P(_DP_ALL, None, None, None)
            if cfg.sharding_profile == "fsdp" else A_BSH)


def act_bsf(cfg: ArchConfig) -> P:
    return P(_DP_ALL, None, None) if cfg.sharding_profile == "fsdp" else A_BSF


def wspec(cfg: ArchConfig, *entries) -> P:
    """Weight spec under the arch's profile: in "fsdp", every sharded dim
    folds onto the joint DP axis group, one dim only (ZeRO-3 layout)."""
    if cfg.sharding_profile != "fsdp":
        return P(*entries)
    out, used = [], False
    for e in entries:
        if e is None or used:
            out.append(None)
        else:
            out.append(_DP_ALL)
            used = True
    return P(*out)


# ---------------------------------------------------------------- norms --
def norm_init(rng, cfg: ArchConfig, d: Optional[int] = None) -> Params:
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def norm_specs(cfg: ArchConfig) -> Params:
    p = {"scale": P(None)}
    if cfg.norm == "layernorm":
        p["bias"] = P(None)
    return p


def norm_apply(cfg: ArchConfig, p: Params, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        xc = xf - mu
        var = (xc * xc).mean(-1, keepdims=True)
        y = xc * jax.lax.rsqrt(var + 1e-5) * p["scale"] + p["bias"]
    else:
        ms = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + 1e-6) * p["scale"]
    return y.astype(x.dtype)


# ----------------------------------------------------------------- rope --
def rope_tables(positions: jax.Array, dim: int, theta: float) -> Tuple:
    """positions (...,) -> cos/sin tables (..., dim/2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., S, H, hd); cos/sin (..., S, hd/2) broadcast over heads."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c],
                           axis=-1).astype(x.dtype)


# ------------------------------------------------------------ attention --
def attn_init(rng, cfg: ArchConfig) -> Params:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = jnp.bfloat16 if cfg.dtype == "bf16" else jnp.float32
    ks = jax.random.split(rng, 4)
    return {
        "wq": param_init(ks[0], (d, h * hd), dt),
        "wk": param_init(ks[1], (d, hkv * hd), dt),
        "wv": param_init(ks[2], (d, hkv * hd), dt),
        "wo": param_init(ks[3], (h * hd, d), dt),
    }


def attn_specs(cfg: ArchConfig) -> Params:
    return {"wq": wspec(cfg, "data", "model"),
            "wk": wspec(cfg, "data", "model"),
            "wv": wspec(cfg, "data", "model"),
            "wo": wspec(cfg, "model", "data")}


_CHUNK_THRESHOLD = 2048  # beyond this, scores are never materialized


def _pick_chunk(s: int, prefer: int = 1024) -> int:
    for c in (prefer, 512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if s % c == 0 and c <= s:
            return c
    return 1


def _q_positions(sq: int, q_offset) -> jax.Array:
    """Absolute query positions (1|B, Sq): ``q_offset`` is a scalar or a
    per-row (B,) vector of cache offsets (batched prefill)."""
    off = jnp.asarray(q_offset)
    base = jnp.arange(sq)
    if off.ndim == 0:
        return (base + off)[None, :]
    return off[:, None] + base[None, :]


def _sdpa_chunked(q, k, v, *, causal: bool, lens, q_offset,
                  scale: Optional[float] = None) -> jax.Array:
    """FlashAttention-style online-softmax in pure jnp (XLA path).

    Identical math to kernels/flash_attention, for shapes where the full
    (Sq, Sk) score matrix must never exist (32k prefill, 4k train).
    q (B,H,Sq,hd) x k,v (B,Hkv,Sk,hd) -> (B,H,Sq,hd).
    """
    b, h, sq, hd = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    dv = v.shape[-1]  # may differ from hd (MLA: qk 192, v 128)
    group = h // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qc = _pick_chunk(sq)
    kc = _pick_chunk(sk)
    nq, nk = sq // qc, sk // kc
    qf = (q.astype(jnp.float32) * scale).reshape(b, hkv, group, nq, qc, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    lens_b = None if lens is None else lens[:, None, None, None, None]

    def q_step(_, iq):
        qi = jax.lax.dynamic_index_in_dim(qf, iq, axis=3, keepdims=False)
        q_idx = (_q_positions(qc, q_offset) + iq * qc)[:, None, None, :, None]

        def k_step(carry, ik):
            # NOTE (§Perf H2 iter2, REFUTED): casting these einsum operands
            # to bf16 was hypothesized to halve score/probability traffic;
            # the dry-run measured +3.5–15% bytes instead — XLA already
            # fuses the p-matrix into the PV dot here, and the casts only
            # added convert-op boundary copies.  Reverted; on-target the
            # dtype choice lives inside the Pallas FA kernel's VMEM tiles.
            m, l, acc = carry
            ki = jax.lax.dynamic_slice_in_dim(kf, ik * kc, kc, axis=2)
            vi = jax.lax.dynamic_slice_in_dim(vf, ik * kc, kc, axis=2)
            s = jnp.einsum("bgnqd,bgkd->bgnqk", qi, ki)
            k_idx = (ik * kc + jnp.arange(kc))[None, None, None, None, :]
            neg = jnp.asarray(-1e30, s.dtype)
            if lens_b is not None:
                s = jnp.where(k_idx < lens_b, s, neg)
            if causal:
                s = jnp.where(k_idx <= q_idx, s, neg)
            m_new = jnp.maximum(m, s.max(-1, keepdims=True))
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(m - m_new)
            l_new = alpha * l + p.sum(-1, keepdims=True)
            acc_new = acc * alpha + jnp.einsum("bgnqk,bgkd->bgnqd", p, vi)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, group, qc, 1), -1e30, jnp.float32)
        l0 = jnp.zeros((b, hkv, group, qc, 1), jnp.float32)
        a0 = jnp.zeros((b, hkv, group, qc, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(k_step, (m0, l0, a0), jnp.arange(nk))
        l = jnp.where(l == 0.0, 1.0, l)
        return None, acc / l

    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq))
    # outs: (nq, b, hkv, group, qc, dv)
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(b, h, sq, dv)
    return out.astype(q.dtype)


def _sdpa(q, k, v, *, causal: bool, lens: Optional[jax.Array],
          q_offset=0) -> jax.Array:
    """q (B,H,Sq,hd) x k,v (B,Hkv,Sk,hd) -> (B,H,Sq,hd); f32 softmax."""
    b, h, sq, hd = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    if sq >= _CHUNK_THRESHOLD or sk > 4 * _CHUNK_THRESHOLD:
        return _sdpa_chunked(q, k, v, causal=causal, lens=lens,
                             q_offset=q_offset)
    group = h // hkv
    qf = q.astype(jnp.float32) / math.sqrt(hd)
    # grouped matmul without materializing repeated K/V
    qg = qf.reshape(b, hkv, group, sq, hd)
    s = jnp.einsum("bgnqd,bgkd->bgnqk", qg, k.astype(jnp.float32))
    k_idx = jnp.arange(sk)[None, None, None, None, :]
    neg = jnp.asarray(-1e30, s.dtype)
    if lens is not None:
        s = jnp.where(k_idx < lens[:, None, None, None, None], s, neg)
    if causal:
        q_idx = _q_positions(sq, q_offset)[:, None, None, :, None]
        s = jnp.where(k_idx <= q_idx, s, neg)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgnqk,bgkd->bgnqd", p, v.astype(jnp.float32))
    return o.reshape(b, h, sq, hd).astype(q.dtype)


def attn_apply(cfg: ArchConfig, p: Params, x: jax.Array, *,
               positions: jax.Array, lens: Optional[jax.Array] = None,
               cache: Optional[Params] = None, causal: bool = True,
               kv_source: Optional[jax.Array] = None,
               offsets: Optional[jax.Array] = None):
    """Full attention; ``cache`` switches to decode mode (x is (B,1,D)).

    ``cache`` + ``offsets`` switches to *batched prefill* mode instead
    (serve path): x is a (B, S, D) chunk whose row r holds ``lens[r]``
    true tokens destined for absolute cache positions
    ``[offsets[r], offsets[r] + lens[r])``; the chunk's K/V are scattered
    into the cache in one pass and queries attend causally against the
    whole cache at absolute positions.

    ``kv_source`` enables cross-attention (whisper decoder)."""
    b, s, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    src = x if kv_source is None else kv_source
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k = (src @ p["wk"]).reshape(b, src.shape[1], hkv, hd)
    v = (src @ p["wv"]).reshape(b, src.shape[1], hkv, hd)
    q = maybe_shard(q, act_bsh(cfg))
    if kv_source is None:  # self-attention: rope
        cos, sin = rope_tables(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    q = q.transpose(0, 2, 1, 3)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    new_cache = None
    if cache is not None and offsets is not None:
        # batched prefill: scatter the chunk's K/V to absolute positions
        # [offset, offset+len) per row — padded chunk positions are never
        # written — then attend causally against the whole cache
        kc, vc = cache["k"], cache["v"]
        lc = kc.shape[2]
        j = jnp.arange(lc)[None, :] - offsets[:, None]          # (B, Lc)
        written = (j >= 0) & (j < lens[:, None])
        jc = jnp.clip(j, 0, s - 1)
        idx = jnp.broadcast_to(jc[:, None, :, None], (b, hkv, lc, hd))
        wmask = written[:, None, :, None]
        kc = jnp.where(wmask, jnp.take_along_axis(k, idx, axis=2)
                       .astype(kc.dtype), kc)
        vc = jnp.where(wmask, jnp.take_along_axis(v, idx, axis=2)
                       .astype(vc.dtype), vc)
        new_cache = {"k": kc, "v": vc}
        o = _sdpa(q, kc.astype(q.dtype), vc.astype(q.dtype), causal=True,
                  lens=None, q_offset=offsets)
    elif cache is not None:
        # decode: append to cache at position lens (per batch row)
        kc, vc = cache["k"], cache["v"]
        idx = lens[:, None, None, None]  # (B,1,1,1) write position
        pos_iota = jnp.arange(kc.shape[2])[None, None, :, None]
        write = pos_iota == idx
        kc = jnp.where(write, k.astype(kc.dtype), kc)
        vc = jnp.where(write, v.astype(vc.dtype), vc)
        new_cache = {"k": kc, "v": vc}
        o = _sdpa(q, kc.astype(q.dtype), vc.astype(q.dtype), causal=False,
                  lens=lens + 1)
    else:
        o = _sdpa(q, k, v, causal=causal and kv_source is None,
                  lens=lens, q_offset=0)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    out = o @ p["wo"]
    return maybe_shard(out, act_bsd(cfg)), new_cache


def attn_cache_init(cfg: ArchConfig, batch: int, max_len: int) -> Params:
    hkv, hd = cfg.n_kv_heads, cfg.hd
    dt = jnp.bfloat16 if cfg.dtype == "bf16" else jnp.float32
    return {"k": jnp.zeros((batch, hkv, max_len, hd), dt),
            "v": jnp.zeros((batch, hkv, max_len, hd), dt)}


def attn_cache_specs(cfg: ArchConfig) -> Params:
    # few KV heads (< model-axis size 16, e.g. MQA/GQA): shard the sequence
    # axis of the cache instead of heads so the 16-way split divides evenly
    kv_spec = (P(("pod", "data"), "model", None, None)
               if cfg.n_kv_heads >= 16 else
               P(("pod", "data"), None, "model", None))
    return {"k": kv_spec, "v": kv_spec}


# ------------------------------------------------------ MLA (deepseek) --
MLA_ABSORBED_DECODE = True  # §Perf H3 switch (tests bisect against False)


def mla_init(rng, cfg: ArchConfig) -> Params:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.hd
    lora, rdim = cfg.mla_kv_lora, cfg.mla_rope_dim
    dt = jnp.bfloat16 if cfg.dtype == "bf16" else jnp.float32
    ks = jax.random.split(rng, 6)
    return {
        "wq": param_init(ks[0], (d, h * (hd + rdim)), dt),
        "w_dkv": param_init(ks[1], (d, lora), dt),
        "w_kpe": param_init(ks[2], (d, rdim), dt),
        "w_uk": param_init(ks[3], (lora, h * hd), dt),
        "w_uv": param_init(ks[4], (lora, h * hd), dt),
        "wo": param_init(ks[5], (h * hd, d), dt),
    }


def mla_specs(cfg: ArchConfig) -> Params:
    return {"wq": P("data", "model"), "w_dkv": P("data", None),
            "w_kpe": P("data", None), "w_uk": P(None, "model"),
            "w_uv": P(None, "model"), "wo": P("model", "data")}


def mla_apply(cfg: ArchConfig, p: Params, x: jax.Array, *,
              positions: jax.Array, lens=None, cache=None,
              offsets: Optional[jax.Array] = None):
    """Multi-head latent attention: cache holds the 512-d compressed kv.

    ``cache`` + ``offsets`` is batched prefill mode (see
    :func:`attn_apply`): the chunk's compressed K/V are scattered to
    absolute cache positions and queries attend causally at absolute
    positions through the expansion path (never the absorbed-decode
    shortcut)."""
    b, s, d = x.shape
    h, hd, rdim = cfg.n_heads, cfg.hd, cfg.mla_rope_dim
    q = (x @ p["wq"]).reshape(b, s, h, hd + rdim)
    q_nope, q_pe = q[..., :hd], q[..., hd:]
    kv_c = x @ p["w_dkv"]                       # (B,S,lora)
    k_pe = (x @ p["w_kpe"]).reshape(b, s, 1, rdim)
    cos, sin = rope_tables(positions, rdim, cfg.rope_theta)
    q_pe = apply_rope(q_pe, cos, sin)
    k_pe = apply_rope(k_pe, cos, sin)
    k_pe = k_pe[..., 0, :]                      # (B,S,rdim)
    new_cache = None
    if cache is not None and offsets is not None:
        # batched prefill: scatter the chunk's compressed K/V to absolute
        # positions [offset, offset+len) per row (padded positions are
        # never written), then attend causally at absolute positions
        lc = cache["kv_c"].shape[1]
        j = jnp.arange(lc)[None, :] - offsets[:, None]          # (B, Lc)
        written = ((j >= 0) & (j < lens[:, None]))[:, :, None]
        jc = jnp.clip(j, 0, s - 1)
        kv_al = jnp.take_along_axis(
            kv_c, jnp.broadcast_to(jc[:, :, None], (b, lc, kv_c.shape[-1])),
            axis=1)
        kpe_al = jnp.take_along_axis(
            k_pe, jnp.broadcast_to(jc[:, :, None], (b, lc, rdim)), axis=1)
        kv_all = jnp.where(written, kv_al.astype(cache["kv_c"].dtype),
                           cache["kv_c"])
        kpe_all = jnp.where(written, kpe_al.astype(cache["k_pe"].dtype),
                            cache["k_pe"])
        new_cache = {"kv_c": kv_all, "k_pe": kpe_all}
        eff_lens = None
        causal = True
    elif cache is not None:
        pos = jnp.arange(cache["kv_c"].shape[1])[None, :, None]
        write = pos == lens[:, None, None]
        kv_all = jnp.where(write, kv_c.astype(cache["kv_c"].dtype),
                           cache["kv_c"])
        kpe_all = jnp.where(write, k_pe.astype(cache["k_pe"].dtype),
                            cache["k_pe"])
        new_cache = {"kv_c": kv_all, "k_pe": kpe_all}
        eff_lens = lens + 1
        causal = False
    else:
        kv_all, kpe_all = kv_c, k_pe
        eff_lens = lens
        causal = True
    if cache is not None and offsets is None and s == 1 \
            and MLA_ABSORBED_DECODE:
        # §Perf H3: ABSORBED decode — W_uk folds into the query and W_uv
        # into the output, so attention runs directly against the 512-d
        # latent cache; the (B, S, H, hd) K/V expansion never exists.
        lora = cfg.mla_kv_lora
        w_uk = p["w_uk"].reshape(lora, h, hd)
        w_uv = p["w_uv"].reshape(lora, h, hd)
        q_abs = jnp.einsum("bqhd,lhd->bqhl", q_nope.astype(jnp.float32),
                           w_uk.astype(jnp.float32))      # (B,1,H,lora)
        kvf = kv_all.astype(jnp.bfloat16)
        # bf16 outputs + explicit f32 upcast (XLA:CPU lacks the mixed
        # BF16xBF16=F32 dot thunk; TPU MXU accumulates f32 regardless)
        s_nope = jnp.einsum("bqhl,bsl->bhqs",
                            q_abs.astype(jnp.bfloat16),
                            kvf).astype(jnp.float32)
        s_pe = jnp.einsum("bqhd,bsd->bhqs", q_pe.astype(jnp.float32),
                          kpe_all.astype(jnp.float32))
        sc = (s_nope + s_pe) * (1.0 / math.sqrt(hd + rdim))
        k_idx = jnp.arange(kv_all.shape[1])[None, None, None, :]
        sc = jnp.where(k_idx < eff_lens[:, None, None, None], sc, -1e30)
        prob = jax.nn.softmax(sc, axis=-1)
        o_lat = jnp.einsum("bhqs,bsl->bqhl",
                           prob.astype(jnp.bfloat16),
                           kvf).astype(jnp.float32)  # (B,1,H,lora)
        o = jnp.einsum("bqhl,lhd->bqhd", o_lat,
                       w_uv.astype(jnp.float32))
        out = o.reshape(b, s, h * hd).astype(x.dtype) @ p["wo"]
        return maybe_shard(out, A_BSD), new_cache

    # prefill/train: expand per-head keys/values from the compressed cache,
    # then fold the rope component into the head dim: scores =
    # [q_nope|q_pe]·[k_nope|k_pe] so the chunked SDPA path applies unchanged
    sk = kv_all.shape[1]
    k_nope = (kv_all @ p["w_uk"]).reshape(b, sk, h, hd)
    v = (kv_all @ p["w_uv"]).reshape(b, sk, h, hd)
    q_eff = jnp.concatenate([q_nope, q_pe], axis=-1)      # (B,S,H,hd+r)
    k_pe_b = jnp.broadcast_to(kpe_all[:, :, None, :], (b, sk, h, rdim))
    k_eff = jnp.concatenate([k_nope, k_pe_b.astype(k_nope.dtype)], axis=-1)
    q_eff = q_eff.transpose(0, 2, 1, 3)
    k_eff = k_eff.transpose(0, 2, 1, 3)
    v_t = v.transpose(0, 2, 1, 3)
    scale = 1.0 / math.sqrt(hd + rdim)
    q_off = 0 if offsets is None else offsets
    if s >= _CHUNK_THRESHOLD or sk > 4 * _CHUNK_THRESHOLD:
        o = _sdpa_chunked(q_eff, k_eff, v_t, causal=causal, lens=eff_lens,
                          q_offset=q_off, scale=scale)
    else:
        sc = jnp.einsum("bhqd,bhkd->bhqk", q_eff.astype(jnp.float32),
                        k_eff.astype(jnp.float32)) * scale
        k_idx = jnp.arange(sk)[None, None, None, :]
        neg = jnp.asarray(-1e30, sc.dtype)
        if eff_lens is not None:
            sc = jnp.where(k_idx < eff_lens[:, None, None, None], sc, neg)
        if causal:
            q_idx = _q_positions(s, q_off)[:, None, :, None]
            sc = jnp.where(k_idx <= q_idx, sc, neg)
        prob = jax.nn.softmax(sc, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", prob,
                       v_t.astype(jnp.float32)).astype(x.dtype)
    out = o.transpose(0, 2, 1, 3).reshape(b, s, h * hd).astype(x.dtype) @ p["wo"]
    return maybe_shard(out, A_BSD), new_cache


def mla_cache_init(cfg: ArchConfig, batch: int, max_len: int) -> Params:
    dt = jnp.bfloat16 if cfg.dtype == "bf16" else jnp.float32
    return {"kv_c": jnp.zeros((batch, max_len, cfg.mla_kv_lora), dt),
            "k_pe": jnp.zeros((batch, max_len, cfg.mla_rope_dim), dt)}


def mla_cache_specs(cfg: ArchConfig) -> Params:
    return {"kv_c": P(("pod", "data"), "model", None),
            "k_pe": P(("pod", "data"), "model", None)}


# ----------------------------------------------------- paged KV blocks --
def paged_gather(pool: jax.Array, tables: jax.Array, *, block_axis: int,
                 seq_axis: int) -> jax.Array:
    """Gather per-row cache rows out of a physical block pool.

    ``pool`` holds the blocks: ``block_axis`` is the block-id axis (size
    ``n_blocks + 1``, id 0 = the null block), ``seq_axis`` the
    within-block token axis (size ``block_size``).  ``tables`` (B, M)
    maps each row's logical block ``j`` to a physical id (null-padded
    with 0).  The result is a dense per-row leaf — block axis replaced by
    the row axis B, seq axis widened to ``M * block_size`` — which is
    exactly the fixed-row layout :func:`attn_apply` / :func:`mla_apply`
    consume, so the attention kernels run unchanged on paged caches.
    """
    bs = pool.shape[seq_axis]
    b, m = tables.shape
    x = jnp.moveaxis(pool, (block_axis, seq_axis), (0, 1))
    flat = x.reshape((x.shape[0] * bs,) + x.shape[2:])
    pos = jnp.arange(m * bs)
    idx = tables[:, pos // bs] * bs + (pos % bs)[None, :]      # (B, M*bs)
    return jnp.moveaxis(flat[idx], (0, 1), (block_axis, seq_axis))


def paged_scatter(pool: jax.Array, dense: jax.Array, tables: jax.Array,
                  keep: jax.Array, *, block_axis: int,
                  seq_axis: int) -> jax.Array:
    """Scatter dense per-row cache leaves back into the block pool.

    Inverse of :func:`paged_gather` restricted to the token positions
    selected by ``keep`` (B, M*block_size) — only freshly written
    positions persist.  Positions with ``keep`` False, and any position
    whose (bucket- or null-) padded table entry is 0, are routed into the
    null block, which absorbs them the way masked writes do on the fixed
    path.
    """
    bs = pool.shape[seq_axis]
    b, m = tables.shape
    x = jnp.moveaxis(pool, (block_axis, seq_axis), (0, 1))
    nb = x.shape[0]
    flat = x.reshape((nb * bs,) + x.shape[2:])
    d = jnp.moveaxis(dense, (block_axis, seq_axis), (0, 1))
    s = m * bs
    pos = jnp.arange(s)
    idx = tables[:, pos // bs] * bs + (pos % bs)[None, :]
    idx = jnp.where(keep, idx, (pos % bs)[None, :])    # null-block sink
    flat = flat.at[idx.reshape(-1)].set(
        d.reshape((b * s,) + d.shape[2:]).astype(flat.dtype))
    out = flat.reshape((nb, bs) + flat.shape[1:])
    return jnp.moveaxis(out, (0, 1), (block_axis, seq_axis))


# ------------------------------------------------------------------ mlp --
def mlp_init(rng, cfg: ArchConfig, d_ff: Optional[int] = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = jnp.bfloat16 if cfg.dtype == "bf16" else jnp.float32
    ks = jax.random.split(rng, 3)
    p = {"w_in": param_init(ks[0], (d, f), dt),
         "w_out": param_init(ks[1], (f, d), dt)}
    if cfg.act == "silu":
        p["w_gate"] = param_init(ks[2], (d, f), dt)
    return p


def mlp_specs(cfg: ArchConfig) -> Params:
    p = {"w_in": wspec(cfg, "data", "model"),
         "w_out": wspec(cfg, "model", "data")}
    if cfg.act == "silu":
        p["w_gate"] = wspec(cfg, "data", "model")
    return p


def mlp_apply(cfg: ArchConfig, p: Params, x: jax.Array) -> jax.Array:
    h = x @ p["w_in"]
    if cfg.act == "silu":
        h = jax.nn.silu(x @ p["w_gate"]) * h
    else:
        h = jax.nn.gelu(h)
    h = maybe_shard(h, act_bsf(cfg))
    return maybe_shard(h @ p["w_out"], act_bsd(cfg))


# ------------------------------------------------------------------ moe --
def moe_init(rng, cfg: ArchConfig) -> Params:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.expert_width
    dt = jnp.bfloat16 if cfg.dtype == "bf16" else jnp.float32
    ks = jax.random.split(rng, 5)
    p = {
        "router": param_init(ks[0], (d, e), jnp.float32),
        "w_in": param_init(ks[1], (e, d, f), dt),
        "w_gate": param_init(ks[2], (e, d, f), dt),
        "w_out": param_init(ks[3], (e, f, d), dt),
    }
    if cfg.n_shared_experts:
        sub = jax.random.split(ks[4], 3)
        fs = f * cfg.n_shared_experts
        p["shared"] = {"w_in": param_init(sub[0], (d, fs), dt),
                       "w_gate": param_init(sub[1], (d, fs), dt),
                       "w_out": param_init(sub[2], (fs, d), dt)}
    return p


def moe_specs(cfg: ArchConfig) -> Params:
    p = {"router": P(None, None),
         "w_in": P("model", "data", None),
         "w_gate": P("model", "data", None),
         "w_out": P("model", None, "data")}
    if cfg.n_shared_experts:
        p["shared"] = {"w_in": P("data", "model"),
                       "w_gate": P("data", "model"),
                       "w_out": P("model", "data")}
    return p


def _moe_experts_local(cfg: ArchConfig, w_in, w_gate, w_out, x_tokens,
                       gates, ids, capacity: int):
    """Sort-based capacity dispatch over a *local* expert slice.

    x_tokens (T, D); gates/ids (T, k); experts (E_loc, D, F).  Tokens routed
    to expert e get slots [0, capacity); overflow drops (standard GShard
    token dropping).  No one-hot dispatch einsum — scatter/gather keeps
    compiled FLOPs equal to useful FLOPs (DESIGN §9 beyond-paper note).
    """
    t, dmod = x_tokens.shape
    e_loc = w_in.shape[0]
    k = ids.shape[1]
    flat_e = ids.reshape(-1)                       # (T*k,) expert ids (local)
    flat_g = gates.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), k)
    valid = (flat_e >= 0) & (flat_e < e_loc)
    key = jnp.where(valid, flat_e, e_loc)          # invalid sorts last
    order = jnp.argsort(key)                       # stable
    se, st, sg = key[order], flat_tok[order], flat_g[order]
    # rank within expert: position - start offset of that expert
    counts = jnp.bincount(se, length=e_loc + 1)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(se.shape[0]) - starts[se]
    keep = (se < e_loc) & (pos_in_e < capacity)
    slot = jnp.where(keep, se * capacity + pos_in_e, e_loc * capacity)
    # gather tokens into padded expert buffers (E_loc*C, D)
    buf = jnp.zeros((e_loc * capacity + 1, dmod), x_tokens.dtype)
    buf = buf.at[slot].add(jnp.where(keep[:, None], x_tokens[st], 0))
    buf = buf[:-1].reshape(e_loc, capacity, dmod)
    h = jnp.einsum("ecd,edf->ecf", buf, w_in)
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    h = jax.nn.silu(g) * h
    out = jnp.einsum("ecf,efd->ecd", h, w_out)     # (E_loc, C, D)
    out_flat = out.reshape(e_loc * capacity, dmod)
    # combine back: weighted scatter-add into tokens
    contrib = jnp.where(keep[:, None],
                        out_flat[jnp.minimum(slot, e_loc * capacity - 1)]
                        * sg[:, None].astype(out_flat.dtype), 0)
    y = jnp.zeros_like(x_tokens).at[st].add(contrib)
    return y


def moe_apply(cfg: ArchConfig, p: Params, x: jax.Array) -> jax.Array:
    """Top-k routed MoE with optional shared experts (dbrx / deepseek-v2).

    Distributed mode (mesh active): expert-parallel over the "model" axis
    via shard_map — tokens are replicated across EP ranks (they already are
    under the activation sharding), each rank runs its expert slice at
    local capacity, partial outputs psum over "model".
    """
    b, s, d = x.shape
    tokens = x.reshape(-1, d)
    t = tokens.shape[0]
    logits = (tokens.astype(jnp.float32) @ p["router"])  # (T, E)
    gates, ids = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), cfg.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    gates = gates.astype(x.dtype)

    mesh = get_mesh()
    e = cfg.n_experts
    if mesh is not None and "model" in mesh.axis_names:
        ep = mesh.shape["model"]
        e_loc = e // ep
        # capacity is per DATA-shard token count — each EP rank sees only
        # its data shard's tokens (replicated across the model axis)
        dp = 1
        for ax in ("pod", "data"):
            if ax in mesh.axis_names:
                dp *= mesh.shape[ax]
        t_loc = max(t // dp, 1)
        cap = int(cfg.capacity_factor * t_loc * cfg.top_k / e)
        cap = max(8, -(-cap // 8) * 8)

        def ep_body(w_in, w_gate, w_out, toks, gat, idd):
            r = jax.lax.axis_index("model")
            local_ids = idd - r * e_loc  # out-of-slice ids become invalid
            y = _moe_experts_local(cfg, w_in, w_gate, w_out,
                                   toks, gat, local_ids, cap)
            # each token's k experts may live on different EP ranks
            return jax.lax.psum(y, "model")

        from jax.experimental.shard_map import shard_map
        dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        tok_spec = P(dp_axes if dp_axes else None, None)
        y = shard_map(
            ep_body, mesh=mesh,
            in_specs=(P("model", None, None), P("model", None, None),
                      P("model", None, None),
                      tok_spec, tok_spec, tok_spec),
            out_specs=tok_spec,
            check_rep=False,
        )(p["w_in"], p["w_gate"], p["w_out"], tokens, gates, ids)
    else:
        cap = int(cfg.capacity_factor * t * cfg.top_k / max(e, 1))
        cap = max(4, cap)
        y = _moe_experts_local(cfg, p["w_in"], p["w_gate"], p["w_out"],
                               tokens, gates, ids, cap)

    if cfg.n_shared_experts:
        sh = p["shared"]
        hs = jax.nn.silu(tokens @ sh["w_gate"]) * (tokens @ sh["w_in"])
        y = y + hs @ sh["w_out"]
    return maybe_shard(y.reshape(b, s, d), A_BSD)


# --------------------------------------------------------------- mamba2 --
def mamba2_init(rng, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    d_in = 2 * d
    n, hp = cfg.ssm_state, cfg.ssm_head_dim
    n_heads = d_in // hp
    dt = jnp.bfloat16 if cfg.dtype == "bf16" else jnp.float32
    ks = jax.random.split(rng, 6)
    return {
        "w_x": param_init(ks[0], (d, d_in), dt),
        "w_z": param_init(ks[1], (d, d_in), dt),
        "w_bc": param_init(ks[2], (d, 2 * n), dt),
        "w_dt": param_init(ks[3], (d, n_heads), dt),
        "a_log": jnp.zeros((n_heads,), jnp.float32),
        "w_out": param_init(ks[4], (d_in, d), dt),
        "skip": param_init(ks[5], (n_heads,), jnp.float32, scale=1.0),
    }


def mamba2_specs(cfg: ArchConfig) -> Params:
    return {"w_x": P("data", "model"), "w_z": P("data", "model"),
            "w_bc": P("data", None), "w_dt": P("data", "model"),
            "a_log": P("model"), "w_out": P("model", "data"),
            "skip": P("model")}


def _ssd_chunked(x, a, bmat, cmat, chunk: int):
    """jnp mirror of kernels/mamba2: chunk-parallel SSD scan.

    x (B,H,T,P); a (B,H,T,1); b,c (B,H,T,N) -> (B,H,T,P)."""
    bs, h, t, pdim = x.shape
    n = bmat.shape[-1]
    nc = t // chunk
    xs = x.reshape(bs, h, nc, chunk, pdim)
    as_ = a.reshape(bs, h, nc, chunk, 1)
    bs_ = bmat.reshape(bs, h, nc, chunk, n)
    cs_ = cmat.reshape(bs, h, nc, chunk, n)
    log_a = jnp.log(jnp.maximum(as_, 1e-37))
    cum = jnp.cumsum(log_a, axis=3)                      # (..., chunk, 1)
    g = jnp.exp(cum)
    ratio = jnp.exp(cum - cum.swapaxes(-1, -2))          # (..., chunk, chunk)
    tt = jnp.arange(chunk)
    l_mask = jnp.where(tt[:, None] >= tt[None, :], ratio, 0.0)
    scores = jnp.einsum("bhctn,bhcsn->bhcts", cs_, bs_) * l_mask
    y_intra = jnp.einsum("bhcts,bhcsp->bhctp", scores, xs)
    # inter-chunk state carried with a scan over chunks
    decay_end = jnp.exp(cum[..., -1:, :] - cum)          # (..., chunk, 1)
    b_x = jnp.einsum("bhctn,bhctp->bhcnp", bs_ * decay_end, xs)
    g_last = g[..., -1, 0]                               # (B,H,nc)

    def carry(h_prev, inp):
        bx_c, gl_c = inp
        h_new = gl_c[..., None, None] * h_prev + bx_c
        # §Perf H4 (H1-iter3 lesson transplanted): f32 carry, bf16 stack —
        # the stacked per-chunk states dominate the SSD HBM term
        return h_new, h_prev.astype(jnp.bfloat16)

    h0 = jnp.zeros((bs, h, n, pdim), jnp.float32)
    _, h_prevs = jax.lax.scan(
        carry, h0, (b_x.transpose(2, 0, 1, 3, 4), g_last.transpose(2, 0, 1)))
    h_prevs = h_prevs.transpose(1, 2, 0, 3, 4)           # (B,H,nc,N,P)
    y_inter = g * jnp.einsum("bhctn,bhcnp->bhctp", cs_,
                             h_prevs.astype(jnp.float32))
    return (y_intra + y_inter).reshape(bs, h, t, pdim)


def mamba2_apply(cfg: ArchConfig, p: Params, x: jax.Array, *,
                 cache: Optional[Params] = None):
    """Mamba-2 block; cache mode = single-token state update."""
    b, s, d = x.shape
    d_in = 2 * d
    n, hp = cfg.ssm_state, cfg.ssm_head_dim
    n_heads = d_in // hp
    xz = x @ p["w_x"]
    z = jax.nn.silu(x @ p["w_z"])
    bc = x @ p["w_bc"]
    bmat, cmat = bc[..., :n], bc[..., n:]
    dt_ = jax.nn.softplus((x @ p["w_dt"]).astype(jnp.float32))  # (B,S,H)
    a = jnp.exp(-dt_ * jnp.exp(p["a_log"]))                     # (B,S,H)
    xh = xz.reshape(b, s, n_heads, hp).transpose(0, 2, 1, 3)
    ah = a.transpose(0, 2, 1)[..., None]                        # (B,H,S,1)
    bh = jnp.broadcast_to(bmat[:, None], (b, n_heads, s, n))
    ch = jnp.broadcast_to(cmat[:, None], (b, n_heads, s, n))
    new_cache = None
    if cache is not None:
        h_prev = cache["h"]                                     # (B,H,N,P)
        xt = xh[:, :, 0].astype(jnp.float32)                    # (B,H,P)
        at = ah[:, :, 0]                                        # (B,H,1)
        bt = bh[:, :, 0].astype(jnp.float32)
        ct = ch[:, :, 0].astype(jnp.float32)
        h_new = at[..., None] * h_prev + jnp.einsum("bhn,bhp->bhnp", bt, xt)
        y = jnp.einsum("bhn,bhnp->bhp", ct, h_new)[:, :, None]  # (B,H,1,P)
        new_cache = {"h": h_new}
    else:
        # §Perf H4: chunk 64 (fewer stacked states) when the length allows
        if s % 64 == 0:
            chunk = 64
        elif s % 16 == 0:
            chunk = 16
        elif s % 8 == 0:
            chunk = 8
        else:
            chunk = s
        y = _ssd_chunked(xh.astype(jnp.float32), ah,
                         bh.astype(jnp.float32), ch.astype(jnp.float32),
                         chunk)
    y = y + p["skip"][None, :, None, None] * xh.astype(jnp.float32)
    y = y.transpose(0, 2, 1, 3).reshape(b, s, d_in).astype(x.dtype)
    out = (y * z) @ p["w_out"]
    return maybe_shard(out, A_BSD), new_cache


def mamba2_cache_init(cfg: ArchConfig, batch: int) -> Params:
    d_in = 2 * cfg.d_model
    n_heads = d_in // cfg.ssm_head_dim
    return {"h": jnp.zeros((batch, n_heads, cfg.ssm_state, cfg.ssm_head_dim),
                           jnp.float32)}


def mamba2_cache_specs(cfg: ArchConfig) -> Params:
    return {"h": P(("pod", "data"), "model", None, None)}


# ---------------------------------------------------------------- rwkv6 --
def rwkv6_init(rng, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    hp = cfg.ssm_head_dim
    n_heads = d // hp
    dt = jnp.bfloat16 if cfg.dtype == "bf16" else jnp.float32
    ks = jax.random.split(rng, 8)
    return {
        "w_r": param_init(ks[0], (d, d), dt),
        "w_k": param_init(ks[1], (d, d), dt),
        "w_v": param_init(ks[2], (d, d), dt),
        "w_g": param_init(ks[3], (d, d), dt),
        "w_w": param_init(ks[4], (d, d), dt),      # data-dependent decay proj
        "u": param_init(ks[5], (n_heads, hp), jnp.float32, scale=0.1),
        "w_out": param_init(ks[6], (d, d), dt),
        "mix": param_init(ks[7], (5, d), jnp.float32, scale=0.1),
    }


def rwkv6_specs(cfg: ArchConfig) -> Params:
    return {"w_r": P("data", "model"), "w_k": P("data", "model"),
            "w_v": P("data", "model"), "w_g": P("data", "model"),
            "w_w": P("data", "model"), "u": P("model", None),
            "w_out": P("model", "data"), "mix": P(None, None)}


def _wkv_chunked(r, k, v, w, u, chunk: int = 16, fast_dtype=jnp.bfloat16,
                 w_is_log: bool = False):
    """Chunk-parallel WKV (§Perf hillclimb H1, GLA-style).

    The per-timestep scan materializes O(T) state-sized buffers at HBM
    fusion boundaries; this form materializes O(T/chunk) and turns the
    recurrence into MXU matmuls.  All exponentials are differences of
    *causally ordered* cumulative log-decays, hence ≤ 0 → exp ≤ 1 →
    numerically safe for any data-dependent decay (no k/decay division).

    r,k,w: (B,H,T,K); v: (B,H,T,V); u: (H,K) -> (B,H,T,V)
    """
    b, h, t, dk = r.shape
    dv = v.shape[-1]
    nc = t // chunk
    rs = r.reshape(b, h, nc, chunk, dk)
    ks = k.reshape(b, h, nc, chunk, dk)
    vs = v.reshape(b, h, nc, chunk, dv)
    ws = w.reshape(b, h, nc, chunk, dk)

    # callers may pass LOG decay directly (negative values) to skip the
    # exp→log roundtrip and its (B,T,K) f32 materialization (H1 iter4)
    if w_is_log:
        log_w = ws
    else:
        log_w = jnp.log(jnp.maximum(ws, 1e-37))        # ≤ 0
    cum = jnp.cumsum(log_w, axis=3)                    # inclusive
    cum_excl = cum - log_w                             # exclusive

    # intra-chunk: scores[t,s] = Σ_k r_t k_s exp(cum_excl_t - cum_s), s<t
    d_ts = cum_excl[..., :, None, :] - cum[..., None, :, :]  # (..,C,C,K) ≤0 causal
    tt = jnp.arange(chunk)
    causal = (tt[:, None] > tt[None, :])[None, None, None, :, :, None]
    decay_ts = jnp.where(causal, jnp.exp(jnp.minimum(d_ts, 0.0)), 0.0)
    # §Perf H1 iter2: the (C,C,K) intermediate dominates HBM traffic — carry
    # it in bf16 (all entries ∈ [0,1]) with f32 accumulation in the reduce
    scores = jnp.einsum("bhntk,bhnsk,bhntsk->bhnts",
                        rs.astype(fast_dtype), ks.astype(fast_dtype),
                        decay_ts.astype(fast_dtype),
                        preferred_element_type=jnp.float32)
    diag = jnp.einsum("bhntk,hk,bhntk->bhnt", rs, u, ks)
    y_intra = jnp.einsum("bhnts,bhnsv->bhntv", scores, vs) \
        + diag[..., None] * vs

    # inter-chunk: y_t += (r_t ⊙ exp(cum_excl_t)) @ S_chunk_start
    r_tilde = rs * jnp.exp(cum_excl)                   # ≤ |r|
    # state carry: S_end = diag(exp(cum_last)) S0 + Σ_s (k_s⊙exp(cum_last-cum_s))ᵀ v_s
    k_tilde = ks * jnp.exp(cum[..., -1:, :] - cum)     # exps ≤ 1
    # iter4: per-chunk kv outer products in bf16 (f32 accumulate in carry)
    kv_chunk = jnp.einsum("bhnsk,bhnsv->bhnkv",
                          k_tilde.astype(fast_dtype), vs.astype(fast_dtype),
                          preferred_element_type=jnp.float32)
    g_last = jnp.exp(cum[..., -1, :])                  # (B,H,nc,K)

    def carry(s_prev, inp):
        kv_c, gl_c = inp                               # (B,H,K,V), (B,H,K)
        s_new = gl_c[..., None] * s_prev + kv_c
        # §Perf H1 iter3: carry stays f32; the STACKED per-chunk states
        # (the dominant HBM term) are emitted in bf16
        return s_new, s_prev.astype(fast_dtype)

    s0 = jnp.zeros((b, h, dk, dv), jnp.float32)
    _, s_prevs = jax.lax.scan(
        carry, s0, (kv_chunk.transpose(2, 0, 1, 3, 4),
                    g_last.transpose(2, 0, 1, 3)))
    s_prevs = s_prevs.transpose(1, 2, 0, 3, 4)         # (B,H,nc,K,V)
    y_inter = jnp.einsum("bhntk,bhnkv->bhntv",
                         r_tilde.astype(fast_dtype), s_prevs,
                         preferred_element_type=jnp.float32)
    return (y_intra + y_inter).reshape(b, h, t, dv)


def _wkv_scan(r, k, v, w, u):
    """jnp sequential oracle form: r,k,w (B,H,T,K); v (B,H,T,V); u (H,K)."""
    dk, dv = r.shape[-1], v.shape[-1]

    def step(s, inp):
        rt, kt, vt, wt = inp                        # (B,H,K/V)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        yt = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s = wt[..., None] * s + kv
        return s, yt

    b, h = r.shape[0], r.shape[1]
    s0 = jnp.zeros((b, h, dk, dv), jnp.float32)
    xs = (r.transpose(2, 0, 1, 3), k.transpose(2, 0, 1, 3),
          v.transpose(2, 0, 1, 3), w.transpose(2, 0, 1, 3))
    s_fin, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 2, 0, 3), s_fin


def rwkv6_apply(cfg: ArchConfig, p: Params, x: jax.Array, *,
                cache: Optional[Params] = None):
    """RWKV-6 time-mix block (token-shift simplified to previous-x mix)."""
    b, s, d = x.shape
    hp = cfg.ssm_head_dim
    n_heads = d // hp
    if cache is not None:
        x_prev = cache["x_prev"][:, None]           # (B,1,D)
    else:
        x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    # H1 iter6: token-shift mix arithmetic in the activation dtype — the
    # f32 mix params otherwise promote 5 (B,T,D) chains to f32 (dominant
    # residual HBM term after iter3)
    mix = jax.nn.sigmoid(p["mix"]).astype(x.dtype)  # (5, D)

    def mixed(i):
        return x * mix[i] + x_prev * (1 - mix[i])

    r = (mixed(0) @ p["w_r"]).reshape(b, s, n_heads, hp).transpose(0, 2, 1, 3)
    k = (mixed(1) @ p["w_k"]).reshape(b, s, n_heads, hp).transpose(0, 2, 1, 3)
    v = (mixed(2) @ p["w_v"]).reshape(b, s, n_heads, hp).transpose(0, 2, 1, 3)
    g = jax.nn.silu(mixed(3) @ p["w_g"])
    # log-decay computed directly (H1 iter4: skip exp→log roundtrip)
    log_dec = -jnp.exp((mixed(4) @ p["w_w"]).astype(jnp.float32).clip(-8, 4))
    log_dec = log_dec.reshape(b, s, n_heads, hp).transpose(0, 2, 1, 3)
    # H1 iter5: no blanket f32 casts — precision is chosen per-einsum
    # inside the chunked path; decode/scan paths cast locally
    rf, kf, vf = r, k, v
    new_cache = None
    if cache is not None:
        s_prev = cache["s"]                          # (B,H,K,V)
        kv = jnp.einsum("bhk,bhv->bhkv", kf[:, :, 0], vf[:, :, 0])
        y = jnp.einsum("bhk,bhkv->bhv", rf[:, :, 0],
                       s_prev + p["u"][None, :, :, None] * kv)[:, :, None]
        s_new = jnp.exp(log_dec[:, :, 0, :, None]) * s_prev + kv
        new_cache = {"s": s_new, "x_prev": x[:, -1]}
    elif s % 16 == 0:
        # §Perf H1: chunk-parallel WKV — O(T/chunk) state materializations;
        # iter3: chunk 64 balances state-stack vs intra-score traffic
        chunk = 64 if s % 64 == 0 else 16
        y = _wkv_chunked(rf, kf, vf, log_dec, p["u"], chunk=chunk,
                         w_is_log=True)
    else:
        y, _ = _wkv_scan(rf.astype(jnp.float32), kf.astype(jnp.float32),
                         vf.astype(jnp.float32), jnp.exp(log_dec), p["u"])
    y = y.transpose(0, 2, 1, 3).reshape(b, s, d).astype(x.dtype)
    out = (y * g) @ p["w_out"]
    return maybe_shard(out, A_BSD), new_cache


def rwkv6_cache_init(cfg: ArchConfig, batch: int) -> Params:
    hp = cfg.ssm_head_dim
    n_heads = cfg.d_model // hp
    dt = jnp.bfloat16 if cfg.dtype == "bf16" else jnp.float32
    return {"s": jnp.zeros((batch, n_heads, hp, hp), jnp.float32),
            "x_prev": jnp.zeros((batch, cfg.d_model), dt)}


def rwkv6_cache_specs(cfg: ArchConfig) -> Params:
    return {"s": P(("pod", "data"), "model", None, None),
            "x_prev": P(("pod", "data"), None)}
