"""Zamba2-style hybrid: Mamba-2 backbone + one *shared* attention block.

The single attention block's weights are reused every
``cfg.shared_attn_every`` Mamba blocks, with a small per-invocation LoRA
delta (the Zamba2 trick for cheap depth-specialization).  Mamba blocks are
scanned in groups; the shared block applications are a short Python loop
(#invocations ≈ L/6, HLO stays small).  State is O(1) in sequence length →
runs ``long_500k``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..dist.context import maybe_shard
from . import layers as L
from .common import ArchConfig, cross_entropy_loss, param_init

Params = Dict[str, Any]
_LORA_RANK = 8


def _mamba_block_init(rng, cfg: ArchConfig) -> Params:
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    return {"ln1": L.norm_init(k1, cfg), "mix": L.mamba2_init(k2, cfg),
            "ln2": L.norm_init(k3, cfg),
            "mlp": L.mlp_init(k4, cfg, d_ff=cfg.d_ff // 2)}


def _mamba_block_specs(cfg: ArchConfig) -> Params:
    return {"ln1": L.norm_specs(cfg), "mix": L.mamba2_specs(cfg),
            "ln2": L.norm_specs(cfg), "mlp": L.mlp_specs(cfg)}


def _n_invocations(cfg: ArchConfig) -> int:
    return max(cfg.n_layers // max(cfg.shared_attn_every, 1), 1)


def init(cfg: ArchConfig, rng) -> Params:
    dt = jnp.bfloat16 if cfg.dtype == "bf16" else jnp.float32
    keys = jax.random.split(rng, 8)
    blocks = jax.vmap(lambda k: _mamba_block_init(k, cfg))(
        jax.random.split(keys[0], cfg.n_layers))
    n_inv = _n_invocations(cfg)
    h_hd = cfg.n_heads * cfg.hd
    lora = {
        "a_q": param_init(keys[1], (n_inv, cfg.d_model, _LORA_RANK), dt),
        "b_q": jnp.zeros((n_inv, _LORA_RANK, h_hd), dt),
    }
    return {
        "embed": param_init(keys[2], (cfg.vocab, cfg.d_model), dt, scale=0.02),
        "blocks": blocks,
        "shared_attn": L.attn_init(keys[3], cfg),
        "shared_ln": L.norm_init(keys[4], cfg),
        "lora": lora,
        "ln_f": L.norm_init(keys[5], cfg),
        "head": param_init(keys[6], (cfg.d_model, cfg.vocab), dt),
    }


def specs(cfg: ArchConfig) -> Params:
    blocks = jax.tree.map(lambda s: P(*((None,) + tuple(s))),
                          _mamba_block_specs(cfg),
                          is_leaf=lambda s: isinstance(s, P))
    return {
        "embed": P("model", "data"),
        "blocks": blocks,
        "shared_attn": L.attn_specs(cfg),
        "shared_ln": L.norm_specs(cfg),
        "lora": {"a_q": P(None, "data", None), "b_q": P(None, None, "model")},
        "ln_f": L.norm_specs(cfg),
        "head": P("data", "model"),
    }


def _mamba_group(cfg: ArchConfig, group_params, x, caches=None):
    def body(h, xs):
        if caches is None:
            bp, c = xs, None
        else:
            bp, c = xs
        a, c2 = L.mamba2_apply(cfg, bp["mix"],
                               L.norm_apply(cfg, bp["ln1"], h), cache=c)
        h = h + a
        h = h + L.mlp_apply(cfg, bp["mlp"], L.norm_apply(cfg, bp["ln2"], h))
        return h, c2

    if cfg.remat != "none" and caches is None:
        body = jax.checkpoint(body)
    xs = group_params if caches is None else (group_params, caches)
    return jax.lax.scan(body, x, xs)


def _shared_attn(cfg: ArchConfig, params, inv: int, x, *, positions, lens,
                 cache=None):
    p = dict(params["shared_attn"])
    # per-invocation LoRA delta on the query projection
    delta = params["lora"]["a_q"][inv] @ params["lora"]["b_q"][inv]
    p["wq"] = p["wq"] + delta
    h = L.norm_apply(cfg, params["shared_ln"], x)
    a, new_cache = L.attn_apply(cfg, p, h, positions=positions, lens=lens,
                                cache=cache)
    return x + a, new_cache


def _group_sizes(cfg: ArchConfig):
    every = max(cfg.shared_attn_every, 1)
    n_inv = _n_invocations(cfg)
    sizes = []
    done = 0
    for i in range(n_inv):
        size = min(every, cfg.n_layers - done)
        sizes.append(size)
        done += size
    if done < cfg.n_layers:
        sizes[-1] += cfg.n_layers - done
    return sizes


def forward(cfg: ArchConfig, params: Params, tokens, *, lens=None,
            extra_embeds=None) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    x = maybe_shard(x, L.A_BSD)
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    off = 0
    for inv, size in enumerate(_group_sizes(cfg)):
        gp = jax.tree.map(lambda a: a[off:off + size], params["blocks"])
        x, _ = _mamba_group(cfg, gp, x)
        x, _ = _shared_attn(cfg, params, inv, x, positions=positions,
                            lens=lens)
        off += size
    x = L.norm_apply(cfg, params["ln_f"], x)
    return maybe_shard(x @ params["head"], P(("pod", "data"), None, "model"))


def loss_fn(cfg: ArchConfig, params: Params, batch):
    logits = forward(cfg, params, batch["tokens"])
    return cross_entropy_loss(logits, batch["labels"], batch.get("mask"))


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> Params:
    n_inv = _n_invocations(cfg)
    mamba = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[L.mamba2_cache_init(cfg, batch) for _ in range(cfg.n_layers)])
    attn = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[L.attn_cache_init(cfg, batch, max_len) for _ in range(n_inv)])
    return {"mamba": mamba, "attn": attn}


def cache_specs(cfg: ArchConfig) -> Params:
    mamba = jax.tree.map(lambda s: P(*((None,) + tuple(s))),
                         L.mamba2_cache_specs(cfg),
                         is_leaf=lambda s: isinstance(s, P))
    attn = jax.tree.map(lambda s: P(*((None,) + tuple(s))),
                        L.attn_cache_specs(cfg),
                        is_leaf=lambda s: isinstance(s, P))
    return {"mamba": mamba, "attn": attn}


def decode_step(cfg: ArchConfig, params: Params, cache: Params, tokens,
                lens) -> Tuple[jax.Array, Params]:
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = lens[:, None]
    new_mamba, new_attn = [], []
    off = 0
    for inv, size in enumerate(_group_sizes(cfg)):
        gp = jax.tree.map(lambda a: a[off:off + size], params["blocks"])
        gc = jax.tree.map(lambda a: a[off:off + size], cache["mamba"])
        x, c2 = _mamba_group(cfg, gp, x, caches=gc)
        new_mamba.append(c2)
        ac = jax.tree.map(lambda a: a[inv], cache["attn"])
        x, ac2 = _shared_attn(cfg, params, inv, x, positions=positions,
                              lens=lens, cache=ac)
        new_attn.append(ac2)
        off += size
    x = L.norm_apply(cfg, params["ln_f"], x)
    logits = x @ params["head"]
    new_cache = {
        "mamba": jax.tree.map(lambda *xs: jnp.concatenate(xs), *new_mamba),
        "attn": jax.tree.map(lambda *xs: jnp.stack(xs), *new_attn),
    }
    return logits, new_cache
