"""Decoder-only transformer LM covering dense / MoE / MLA / VLM families.

Layers are homogeneous and stacked: ``jax.lax.scan`` over a (L, ...) param
pytree keeps HLO size O(1) in depth (critical for 40–81-layer dry-run
compiles).  ``cfg.remat`` wraps the block in ``jax.checkpoint``.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..dist.context import maybe_shard
from . import layers as L
from .common import ArchConfig, cross_entropy_loss, param_init

Params = Dict[str, Any]


# ----------------------------------------------------------------- block --
def block_init(rng, cfg: ArchConfig) -> Params:
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    p = {"ln1": L.norm_init(k1, cfg), "ln2": L.norm_init(k2, cfg)}
    if cfg.mla_kv_lora:
        p["attn"] = L.mla_init(k3, cfg)
    else:
        p["attn"] = L.attn_init(k3, cfg)
    p["ffn"] = L.moe_init(k4, cfg) if cfg.is_moe else L.mlp_init(k4, cfg)
    return p


def block_specs(cfg: ArchConfig) -> Params:
    p = {"ln1": L.norm_specs(cfg), "ln2": L.norm_specs(cfg)}
    p["attn"] = L.mla_specs(cfg) if cfg.mla_kv_lora else L.attn_specs(cfg)
    p["ffn"] = L.moe_specs(cfg) if cfg.is_moe else L.mlp_specs(cfg)
    return p


def block_apply(cfg: ArchConfig, p: Params, x, *, positions, lens,
                cache: Optional[Params] = None, offsets=None):
    h = L.norm_apply(cfg, p["ln1"], x)
    if cfg.mla_kv_lora:
        a, new_cache = L.mla_apply(cfg, p["attn"], h, positions=positions,
                                   lens=lens, cache=cache, offsets=offsets)
    else:
        a, new_cache = L.attn_apply(cfg, p["attn"], h, positions=positions,
                                    lens=lens, cache=cache, offsets=offsets)
    x = x + a
    h = L.norm_apply(cfg, p["ln2"], x)
    f = L.moe_apply(cfg, p["ffn"], h) if cfg.is_moe \
        else L.mlp_apply(cfg, p["ffn"], h)
    return x + f, new_cache


def _maybe_remat(cfg: ArchConfig, fn):
    if cfg.remat == "none":
        return fn
    policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
              if cfg.remat == "dots" else None)
    return jax.checkpoint(fn, policy=policy)


# ------------------------------------------------------------------- LM --
def init(cfg: ArchConfig, rng) -> Params:
    dt = jnp.bfloat16 if cfg.dtype == "bf16" else jnp.float32
    k_e, k_b, k_h, k_n = jax.random.split(rng, 4)
    blocks = jax.vmap(lambda k: block_init(k, cfg))(
        jax.random.split(k_b, cfg.n_layers))
    p = {
        "embed": param_init(k_e, (cfg.vocab, cfg.d_model), dt, scale=0.02),
        "blocks": blocks,
        "ln_f": L.norm_init(k_n, cfg),
    }
    if not cfg.tie_embeddings:
        p["head"] = param_init(k_h, (cfg.d_model, cfg.vocab), dt)
    return p


def specs(cfg: ArchConfig) -> Params:
    blocks = jax.tree.map(lambda s: P(*((None,) + tuple(s))),
                          block_specs(cfg),
                          is_leaf=lambda s: isinstance(s, P))
    p = {
        "embed": L.wspec(cfg, "model", "data"),
        "blocks": blocks,
        "ln_f": L.norm_specs(cfg),
    }
    if not cfg.tie_embeddings:
        p["head"] = L.wspec(cfg, "data", "model")
    return p


def _run_blocks(cfg: ArchConfig, blocks: Params, x, *, positions, lens,
                caches: Optional[Params] = None, offsets=None):
    if caches is None:
        def body(h, bp):
            h2, _ = block_apply(cfg, bp, h, positions=positions, lens=lens)
            return h2, None

        body = _maybe_remat(cfg, body)
        x, _ = jax.lax.scan(body, x, blocks)
        return x, None

    def body(h, xs):
        bp, c = xs
        h2, c2 = block_apply(cfg, bp, h, positions=positions, lens=lens,
                             cache=c, offsets=offsets)
        return h2, c2

    x, new_caches = jax.lax.scan(body, x, (blocks, caches))
    return x, new_caches


def embed_tokens(cfg: ArchConfig, params: Params, tokens) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    return maybe_shard(x, L.act_bsd(cfg))


def logits_from_hidden(cfg: ArchConfig, params: Params, x) -> jax.Array:
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = x @ head
    spec = (P(L._DP_ALL, None, None) if cfg.sharding_profile == "fsdp"
            else P(("pod", "data"), None, "model"))
    return maybe_shard(logits, spec)


def forward(cfg: ArchConfig, params: Params, tokens, *, lens=None,
            extra_embeds=None) -> jax.Array:
    """Full-sequence forward (train / prefill).

    ``extra_embeds`` (B, S_img, D) are prefix embeddings (llava image
    tokens from the anyres-tiling stub) prepended to the token embeds."""
    x = embed_tokens(cfg, params, tokens)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    x, _ = _run_blocks(cfg, params["blocks"], x, positions=positions,
                       lens=lens)
    x = L.norm_apply(cfg, params["ln_f"], x)
    return logits_from_hidden(cfg, params, x)


def loss_fn(cfg: ArchConfig, params: Params, batch: Dict[str, jax.Array]):
    logits = forward(cfg, params, batch["tokens"], lens=batch.get("lens"),
                     extra_embeds=batch.get("image_embeds"))
    labels = batch["labels"]
    if batch.get("image_embeds") is not None:
        logits = logits[:, -labels.shape[1]:]
    return cross_entropy_loss(logits, labels, batch.get("mask"))


# -------------------------------------------------------------- prefill --
def _prefill_hidden(cfg: ArchConfig, params: Params, cache: Params, tokens,
                    lens, offsets) -> Tuple[jax.Array, Params]:
    """Shared chunk pass for :func:`prefill` and :func:`verify`: embed,
    run the blocks at absolute positions ``offset + arange(S)``, norm —
    returns the (B, S, D) hidden states plus the updated cache."""
    x = embed_tokens(cfg, params, tokens)
    s = x.shape[1]
    positions = offsets[:, None] + jnp.arange(s)[None, :]
    x, new_cache = _run_blocks(cfg, params["blocks"], x, positions=positions,
                               lens=lens, caches=cache, offsets=offsets)
    return L.norm_apply(cfg, params["ln_f"], x), new_cache


def prefill(cfg: ArchConfig, params: Params, cache: Params, tokens, lens,
            offsets) -> Tuple[jax.Array, Params]:
    """Single-pass batched prefill with cache offset (the serve path).

    ``tokens`` (B, S) right-padded prompt chunks; ``lens`` (B,) true chunk
    lengths; ``offsets`` (B,) current per-row cache fill (0 = fresh).  One
    launch computes every chunk position's K/V, writes them at absolute
    cache positions ``[offset, offset+len)``, and returns
    ``(last_logits, new_cache)`` where ``last_logits[r]`` is the logits at
    row r's final valid position — the head runs on that single hidden
    state per row, never on the full (B, S, vocab) tensor.
    """
    x, new_cache = _prefill_hidden(cfg, params, cache, tokens, lens, offsets)
    b = x.shape[0]
    idx = jnp.maximum(lens - 1, 0)[:, None, None]
    last = jnp.take_along_axis(
        x, jnp.broadcast_to(idx, (b, 1, x.shape[-1])), axis=1)
    return logits_from_hidden(cfg, params, last)[:, 0], new_cache


def verify(cfg: ArchConfig, params: Params, cache: Params, tokens, lens,
           offsets) -> Tuple[jax.Array, Params]:
    """Speculative-verify pass: :func:`prefill` semantics, but the head
    runs at EVERY chunk position — ``logits[r, j]`` is the model's
    next-token distribution after consuming ``tokens[r, j]``, so one
    widened launch scores a whole drafted chunk per row.  Rows with
    ``lens[r] == 0`` write nothing (same masks as prefill)."""
    x, new_cache = _prefill_hidden(cfg, params, cache, tokens, lens, offsets)
    return logits_from_hidden(cfg, params, x), new_cache


# --------------------------------------------------------------- decode --
def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> Params:
    if cfg.mla_kv_lora:
        one = lambda: L.mla_cache_init(cfg, batch, max_len)
    else:
        one = lambda: L.attn_cache_init(cfg, batch, max_len)
    return jax.tree.map(
        lambda *xs: jnp.stack(xs), *[one() for _ in range(cfg.n_layers)]) \
        if cfg.n_layers > 1 else jax.tree.map(lambda x: x[None], one())


def cache_specs(cfg: ArchConfig) -> Params:
    one = L.mla_cache_specs(cfg) if cfg.mla_kv_lora else L.attn_cache_specs(cfg)
    return jax.tree.map(lambda s: P(*((None,) + tuple(s))), one,
                        is_leaf=lambda s: isinstance(s, P))


def init_block_pool(cfg: ArchConfig, n_blocks: int,
                    block_size: int) -> Params:
    """Physical KV block pool for paged serving: the fixed-row cache with
    the batch axis reinterpreted as the block-id axis and the sequence
    axis cut to one block — leaves are ``(L, n_blocks, ..., block_size,
    ...)``.  Callers reserve id 0 as the null block (see
    :func:`repro.models.layers.paged_gather`)."""
    return init_cache(cfg, n_blocks, block_size)


def page_axes(cfg: ArchConfig) -> Params:
    """Per-leaf sequence-axis index of the layer-stacked cache/pool
    leaves (the block axis is always axis 1, per
    :func:`repro.models.registry.cache_batch_axis`)."""
    if cfg.mla_kv_lora:
        return {"kv_c": 2, "k_pe": 2}   # (L, B, S, lora/rdim)
    return {"k": 3, "v": 3}             # (L, B, hkv, S, hd)


def decode_step(cfg: ArchConfig, params: Params, cache: Params, tokens,
                lens) -> Tuple[jax.Array, Params]:
    """One decode step: tokens (B, 1), lens (B,) current cache fill."""
    x = embed_tokens(cfg, params, tokens)
    positions = lens[:, None]
    x, new_cache = _run_blocks(cfg, params["blocks"], x,
                               positions=positions, lens=lens,
                               caches=cache)
    x = L.norm_apply(cfg, params["ln_f"], x)
    return logits_from_hidden(cfg, params, x), new_cache
