"""Model zoo: the 10 assigned architectures as composable pure-JAX models."""
from .common import ArchConfig  # noqa: F401
from .registry import get_model, MODEL_FAMILIES  # noqa: F401
