"""Admission policies for the serve engine's continuous-batching scheduler.

A policy is a pure function ``queue -> ordered queue`` deciding which
waiting :class:`~repro.data.pipeline.Request`\\ s claim free KV-cache
slots first.  Policies never mutate the queue; the engine admits from the
front of the returned ordering.  Select one by name via
``ServeConfig(admission=...)`` or pass any callable with this signature.

Built-ins:

* ``fifo``                   — arrival order (the pre-batching behavior)
* ``shortest-prompt-first``  — fewest prompt tokens first (``sjf``): short
  prompts reach decode sooner, raising average slot utilization under
  mixed lengths
* ``priority``               — highest ``Request.priority`` first, FIFO
  within a priority class
"""
from __future__ import annotations

from typing import Callable, List, Union

from ..data.pipeline import Request

__all__ = ["AdmissionPolicy", "ADMISSION_POLICIES", "get_admission_policy",
           "fifo", "shortest_prompt_first", "priority_first"]

AdmissionPolicy = Callable[[List[Request]], List[Request]]


def fifo(queue: List[Request]) -> List[Request]:
    return list(queue)


def shortest_prompt_first(queue: List[Request]) -> List[Request]:
    return sorted(queue, key=lambda r: (len(r.tokens), r.rid))


def priority_first(queue: List[Request]) -> List[Request]:
    return sorted(queue, key=lambda r: (-r.priority, r.rid))


ADMISSION_POLICIES = {
    "fifo": fifo,
    "shortest-prompt-first": shortest_prompt_first,
    "sjf": shortest_prompt_first,
    "priority": priority_first,
}


def get_admission_policy(p: Union[str, AdmissionPolicy]) -> AdmissionPolicy:
    if callable(p):
        return p
    try:
        return ADMISSION_POLICIES[p]
    except KeyError:
        raise ValueError(
            f"unknown admission policy {p!r}; known: "
            f"{sorted(ADMISSION_POLICIES)} (or pass a callable "
            f"queue -> ordered queue)") from None
