from .engine import ServeEngine, ServeConfig  # noqa: F401
