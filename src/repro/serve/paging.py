"""Block-paged KV-cache pool for the serve engine (vLLM-style paging).

The fixed-row serve cache gives every slot a ``max_seq`` row, so memory —
not compute — caps concurrent slots.  This module replaces those rows
with a physical **block pool** sized by a memory budget
(``ServeConfig(kv_pool_blocks=...)``): each slot owns a growable list of
``block_size``-token blocks, and per-slot **block tables** thread through
the bucket-compiled prefill/decode artifacts, where
:func:`repro.models.layers.paged_gather` materializes each row's blocks
into the dense fixed-row layout the attention kernels already consume and
:func:`~repro.models.layers.paged_scatter` persists exactly the freshly
written positions.  Dynamic-shape logic thus stays inside generated
dispatch (the DISC thesis; Nimble makes the same argument for control
flow) and compile counts stay O(#buckets).

Conventions:

* physical block id **0 is the null block**: allocators hand out ids
  ``1..n_blocks``; null-padded table entries gather garbage that the
  length masks keep out of every real row, and masked scatter writes are
  routed into it.
* ``max_seq % block_size == 0`` is enforced by the engine, so a full
  table covers exactly ``max_seq`` positions and the gathered dense rows
  are shape-identical to the fixed path — with an unconstrained pool the
  paged engine is bit-parity with fixed rows.
* on pool pressure the engine preempts a victim
  (:func:`pick_victim`: lowest priority, then newest admission), releases
  its blocks, and requeues the request with prompt+generated tokens for
  greedy recompute — every already-emitted token is preserved exactly and
  the token budget is unchanged; the continuation is re-derived greedily
  (recompute runs through the prefill kernel, so an argmax near-tie may
  resolve differently than the decode kernel would have).
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence, Tuple

import jax
import numpy as np

from ..ft import faults
from ..models.layers import paged_gather, paged_scatter
from ..models.registry import Model

__all__ = ["NULL_BLOCK", "blocks_for", "BlockAllocator", "PagedKVPool",
           "pick_victim"]

#: physical id of the write-absorbing null block (never allocated)
NULL_BLOCK = 0


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Blocks needed to hold ``n_tokens`` cache positions."""
    return -(-max(int(n_tokens), 0) // block_size)


class BlockAllocator:
    """Free-list allocator mapping slots to owned physical blocks.

    Invariants (see :meth:`assert_consistent`): a block is owned by at
    most one slot, freed blocks return to the free list, and
    ``owned + free == n_blocks`` always; id 0 (the null block) is never
    handed out.
    """

    def __init__(self, n_blocks: int, block_size: int, n_slots: int,
                 max_blocks_per_slot: int):
        if n_blocks < 1:
            raise ValueError(f"need at least 1 block, got {n_blocks}")
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.n_slots = n_slots
        self.max_blocks_per_slot = max_blocks_per_slot
        # LIFO free list, ids 1..n_blocks (low ids pop first)
        self._free: List[int] = list(range(n_blocks, 0, -1))
        self._owned: List[List[int]] = [[] for _ in range(n_slots)]

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.n_blocks - len(self._free)

    def owned(self, slot: int) -> List[int]:
        return list(self._owned[slot])

    def ensure(self, slot: int, n_tokens: int) -> bool:
        """Grow ``slot``'s allocation to cover ``n_tokens`` positions.
        All-or-nothing: on failure nothing is allocated and the caller
        must free memory (preempt) or shrink the ask."""
        need = blocks_for(n_tokens, self.block_size)
        if need > self.max_blocks_per_slot:
            return False
        missing = need - len(self._owned[slot])
        if missing <= 0:
            return True
        if missing > len(self._free):
            return False
        if faults.ACTIVE is not None and faults.ACTIVE.suppress(
                "pool.alloc", key=f"slot{slot}"):
            return False    # injected pool pressure: allocation denied
        for _ in range(missing):
            self._owned[slot].append(self._free.pop())
        return True

    def release(self, slot: int) -> int:
        """Return every block ``slot`` owns to the free list; the number
        of blocks freed is the eviction count."""
        blks = self._owned[slot]
        self._free.extend(reversed(blks))
        self._owned[slot] = []
        return len(blks)

    def table(self) -> np.ndarray:
        """The (n_slots, max_blocks_per_slot) int32 block-table matrix,
        null-padded — the host-side input the paged artifacts gather
        through."""
        t = np.full((self.n_slots, self.max_blocks_per_slot), NULL_BLOCK,
                    np.int32)
        for i, blks in enumerate(self._owned):
            t[i, :len(blks)] = blks
        return t

    def assert_consistent(self) -> None:
        owned = [b for blks in self._owned for b in blks]
        assert len(set(owned)) == len(owned), "block double-assigned"
        assert set(owned).isdisjoint(self._free), "owned block on free list"
        assert len(owned) + len(self._free) == self.n_blocks
        assert NULL_BLOCK not in owned and NULL_BLOCK not in self._free
        assert all(len(blks) <= self.max_blocks_per_slot
                   for blks in self._owned)


class PagedKVPool:
    """The physical pool tree plus jit-traceable gather/scatter over it.

    ``tree`` leaves come from ``model.init_block_pool(n_blocks + 1,
    block_size)`` — the fixed-row cache with the batch axis reinterpreted
    as block ids (axis 1 of the layer-stacked leaves) and one extra
    block, id 0, as the null sink.
    """

    def __init__(self, model: Model, *, n_blocks: int, block_size: int):
        if model.init_block_pool is None:
            raise ValueError(
                f"model family {model.cfg.family!r} has no paged-KV "
                f"support (recurrent state has no sequence axis to "
                f"page); use fixed rows (ServeConfig(kv_block_size=None))")
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.seq_axes = model.page_axes()
        self.tree = model.init_block_pool(n_blocks + 1, block_size)

    def gather(self, pool: Any, tables: jax.Array) -> Any:
        """Dense per-row cache tree for ``tables`` (B, M) — traceable,
        called inside the compiled artifacts."""
        return jax.tree.map(
            lambda leaf, ax: paged_gather(leaf, tables, block_axis=1,
                                          seq_axis=ax),
            pool, self.seq_axes)

    def scatter(self, pool: Any, dense: Any, tables: jax.Array,
                keep: jax.Array) -> Any:
        """Persist the ``keep`` (B, M*block_size) positions of a dense
        row tree back into the pool — traceable."""
        return jax.tree.map(
            lambda leaf, d, ax: paged_scatter(leaf, d, tables, keep,
                                              block_axis=1, seq_axis=ax),
            pool, dense, self.seq_axes)


def pick_victim(
        candidates: Sequence[Tuple[int, int, int]]) -> Optional[int]:
    """Preemption victim among ``(slot, priority, admit_seq)`` tuples:
    lowest priority first, newest admission first within a class (the
    request that has consumed the least service is recomputed)."""
    if not candidates:
        return None
    return min(candidates, key=lambda c: (c[1], -c[2]))[0]
