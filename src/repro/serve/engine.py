"""Serving engine: continuous batching over 2-D DISC shape buckets.

The paper's serving problem — requests with varying prompt lengths force
either per-shape recompilation (XLA) or interpretation (Nimble VM) — is
solved here exactly as DISC prescribes, built entirely on the public
``disc.compile`` API:

* **prefill** is ONE single-pass batched artifact with two dynamic dims,
  ``Dim("B", max=max_batch)`` × ``Dim("S", max=max_seq)``: waiting
  requests are admitted together, grouped by prompt-chunk bucket, and one
  launch computes every prompt position's K/V plus last-position logits
  for the whole group (``model.prefill``).  Per-request true lengths ride
  the ``lens`` vector; the gathered KV-cache rows thread through a
  ``TreeSpec`` so the generated dispatch bucket-pads the batch axis of
  every leaf.  Compile count stays O(#(B, S) buckets); hot exact (B, S)
  signatures still escalate (§4.4) to unpadded specializations via
  ``ServeConfig(escalation_threshold=...)``.
* **chunked prefill**: ``ServeConfig(prefill_chunk=...)`` splits long
  prompts into fixed-size chunks interleaved with decode steps
  (``prefill_interleave`` decode steps owed between launches), so a long
  prompt no longer stalls every active decode slot.  The model layer
  supports this through prefill-with-cache-offset entry points
  (``offsets`` = current per-row cache fill).
* **admission** is pluggable (:mod:`repro.serve.policies`): ``"fifo"``,
  ``"shortest-prompt-first"``, ``"priority"`` (``Request.priority``), or
  any callable ordering the waiting queue.
* **decode** is compiled once against the fixed-capacity KV cache; a step
  serves any mix of sequence lengths via the lens vector, and an
  ``active`` row mask gates cache writes so mid-prefill and empty slots
  are never touched by a decode step.
* ``ServeConfig(prefill_mode="replay")`` keeps the previous
  O(prompt_len)-sequential-launches prefill as a benchmark baseline
  (``benchmarks/bench_serve.py`` measures the gap).
* **replicas** (``ServeConfig(replicas=N)``): data-parallel serving.
  The engine owns ``N x max_batch`` KV-cache rows; replica ``r`` owns the
  contiguous slot range ``[r*max_batch, (r+1)*max_batch)``.  Admission
  routes each request (in policy order) to the **least-loaded replica**
  with a free slot; decode is ONE launch over the whole replicated batch
  (the SPMD way: on a mesh the batch axis is partitioned over the
  ``data`` axis, so each replica's rows live on its own devices), so
  tokens per decode launch scale with the replica count.  Stats gain
  per-replica counters (``stats["per_replica"]``).
* **mesh** (``ServeConfig(mesh=..., sharding_profile=...)``): params and
  the KV cache are ``device_put`` per the
  :class:`~repro.dist.profiles.ShardingProfile` (per-replica cache rows
  sharded along ``data``), and the prefill artifact compiles under
  ``CompileOptions(mesh=...)`` so its generated dispatch emits
  ``device_put``-to-sharding on padded buckets (see
  :mod:`repro.dist.spmd`); the total slot count must divide the
  data-parallel axes evenly (checked at engine construction).
* **paged KV** (``ServeConfig(kv_block_size=..., kv_pool_blocks=...)``):
  slots draw ``block_size``-token blocks from a budget-sized physical
  pool (:mod:`repro.serve.paging`) instead of owning a fixed ``max_seq``
  row, so concurrency is bounded by actual token footprint, not
  worst-case rows.  Per-slot block tables ride the compiled artifacts —
  the prefill artifact threads them through a ``TreeSpec`` so they
  bucket-pad with the batch — and the gather into dense rows / scatter
  of fresh positions happens INSIDE the launch, keeping dispatch
  bucket-compiled.  On pool pressure the scheduler preempts a victim
  (lowest priority, newest admission), releases its blocks, and requeues
  the request with prompt+generated tokens: greedy recompute reproduces
  the exact output.  With an unconstrained pool the paged path is
  bit-parity with fixed rows (the baseline, kept as
  ``kv_block_size=None``).
* **speculative decoding** (``ServeConfig(speculative=...,
  speculative_k=...)``): a pluggable proposer
  (:mod:`repro.serve.speculative`; ``"ngram"`` prompt-lookup first,
  draft-model interface stubbed) drafts up to k tokens per slot, and ONE
  widened ``(n_slots, k+1)`` launch of ``model.verify`` (prefill
  semantics, head at every position) scores them all; each slot keeps
  the longest draft prefix matching the model's own greedy argmax plus
  the correction token, and per-slot accept counts advance the ``lens``
  vector.  Greedy accept-or-fix emits exactly the plain-decode tokens —
  only the launch count shrinks.

Both artifacts share one :class:`CompileCache` (entries keyed by
per-artifact fingerprint); compile counts come from the artifacts'
``compile_counts()`` so benchmarks and tests can verify the O(#buckets)
contract end-to-end on a real model.  Every ``stats`` key is documented
in :data:`STATS_KEYS`.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Set, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..api.options import CompileOptions, Dim, TreeSpec
from ..api.staged import compile as disc_compile
from ..core.bucketing import BucketPolicy, POW2
from ..core.cache import CompileCache
from ..core.codegen import KERNEL_DEMOTIONS
from ..data.pipeline import Request
from ..errors import (CONTROL_EXCEPTIONS, DEFAULT_RETRY, DiscError,
                      RetryPolicy, wrap_launch_error)
from ..frontends.jaxpr_frontend import ArgSpec
from ..ft import faults
from ..ft.supervisor import HeartbeatMonitor
from ..models.registry import (Model, cache_batch_axis, replay_prefill,
                               row_keep_mask)
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from ..obs.clock import CLOCK as _WALL
from .paging import BlockAllocator, PagedKVPool, blocks_for, pick_victim
from .policies import get_admission_policy
from .speculative import get_proposer

# admission groups bucket to powers of two starting at 1 (1, 2, 4, ...,
# clamped to max_batch) — log-many batch buckets
BATCH_POW2 = BucketPolicy(kind="pow2", granule=1)

#: every ``ServeEngine.stats`` key, documented in one place.  Counters
#: reset via :meth:`ServeEngine.reset_stats` except where noted.
STATS_KEYS: Dict[str, str] = {
    "prefill_calls": "prefill launches (any group size)",
    "batched_prefills": "prefill launches serving >1 request in one pass",
    "prefill_chunks": "prefill launches that touched a partially-prefilled "
                      "prompt (chunked prefill active)",
    "prefill_compiles": "prefill artifact compiles, bucket + exact "
                        "(artifact-lifetime: not reset)",
    "prefill_escalations": "§4.4 exact specializations of the prefill "
                           "artifact (artifact-lifetime: not reset)",
    "prefill_bucket_pairs": "distinct (B, S) bucket pairs launched "
                            "(artifact-lifetime: not reset)",
    "decode_steps": "decode launches (whole active batch per launch)",
    "tokens_generated": "tokens produced (incl. each prompt's first token "
                        "at prefill completion)",
    "tokens_per_sec": "tokens_generated / busy seconds inside step()",
    "max_decode_gap_s": "longest wall-clock gap between decode launches "
                        "while decode work was pending (decode stall)",
    "requests_completed": "requests retired into done",
    "rejected_requests": "requests refused at submit(): prompt longer than "
                         "max_seq, or a worst-case footprint larger than "
                         "the paged pool can ever hold (the rest of the "
                         "batch is still admitted)",
    "peak_active_slots": "max concurrently occupied slots observed (the "
                         "equal-memory concurrency headline for paged KV)",
    "kv_pool_blocks": "paged-KV pool capacity in blocks (0 = fixed rows; "
                      "not reset)",
    "kv_blocks_in_use": "paged-KV blocks currently allocated (not reset)",
    "kv_pool_occupancy": "kv_blocks_in_use / kv_pool_blocks (0.0 under "
                         "fixed rows; not reset)",
    "kv_peak_occupancy": "max pool occupancy fraction observed",
    "kv_preemptions": "slots preempted on pool pressure (request requeued "
                      "with prompt+generated for greedy recompute)",
    "kv_evictions": "blocks reclaimed by preemptions",
    "spec_drafted_tokens": "draft tokens sent to the speculative verify "
                           "launch",
    "spec_accepted_tokens": "draft tokens accepted by verification",
    "mem_launch_bytes": "staging bytes of the last prefill launch (dynamic "
                        "args padded to their (B, S) bucket; not reset)",
    "mem_peak_launch_bytes": "largest single prefill launch observed "
                             "(artifact-lifetime: not reset)",
    "mem_launch_saved_bytes": "cumulative staging bytes saved by bucketing "
                              "vs launching every call at the "
                              "max_batch×max_seq caps (artifact-lifetime: "
                              "not reset)",
    "per_replica": "one dict per replica: admitted, tokens_generated, "
                   "requests_completed, occupied_slots (slot-range "
                   "[r*max_batch, (r+1)*max_batch) counters under "
                   "least-loaded routing)",
    "failed_requests": "requests retired FAILED (permanent launch "
                       "failure, recompute budget exhausted under pool "
                       "pressure, deadline expiry) — reasons in "
                       "``engine.failed[rid]``",
    "retries": "transient launch retries (capped exponential backoff); "
               "transient *compile* retries live in the compile cache's "
               "stats",
    "kernel_demotions": "cluster-kernel / backend demotions journaled "
                        "process-wide during this engine's run (length "
                        "delta of repro.core.codegen.KERNEL_DEMOTIONS)",
    "deadline_expirations": "requests failed because Request.deadline_s "
                            "passed (checked at admission and between "
                            "steps)",
    "replica_drains": "replicas drained after missing the heartbeat "
                      "deadline (slots preempted back to the queue, "
                      "traffic continues on survivors)",
}


@dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_seq: int = 512
    # S (prompt/chunk length) buckets; B (admission group) buckets
    prefill_policy: BucketPolicy = POW2
    batch_policy: BucketPolicy = BATCH_POW2
    eos_id: int = 1
    # §4.4 static/dynamic mix on the serving path: exact (B, S) prefill
    # signatures seen at least this many times get an unpadded
    # specialization.  None disables.
    escalation_threshold: Optional[int] = None
    # "batched" = single-pass model.prefill; "replay" = the sequential
    # decode-step replay baseline (one request per launch)
    prefill_mode: str = "batched"
    # split prompts into chunks of at most this many tokens, interleaved
    # with decode steps; None prefills whole prompts in one launch
    prefill_chunk: Optional[int] = None
    # decode steps owed between prefill launches when both are pending
    prefill_interleave: int = 1
    # admission policy name (repro.serve.policies) or callable
    admission: Union[str, Callable] = "fifo"
    # data-parallel replica count: the engine serves replicas*max_batch
    # slots, one decode launch over all of them; admission routes each
    # request to the least-loaded replica's slot range
    replicas: int = 1
    # SPMD placement: a jax.sharding.Mesh + profile name/object (see
    # repro.dist.profiles).  Params/caches are device_put per the
    # profile; the prefill artifact compiles under CompileOptions(mesh=)
    mesh: Optional[Any] = None
    sharding_profile: Optional[Any] = None
    # paged KV pool (repro.serve.paging): block size in tokens, must
    # divide max_seq; None keeps the fixed max_seq-row cache (the parity
    # baseline)
    kv_block_size: Optional[int] = None
    # pool capacity in blocks — the memory budget that replaces
    # n_slots * max_seq.  None = unconstrained (n_slots * max_seq /
    # kv_block_size blocks: bit-parity with fixed rows, no preemption)
    kv_pool_blocks: Optional[int] = None
    # speculative decoding (repro.serve.speculative): proposer name
    # ("ngram") or object with .propose(history, k); None disables
    speculative: Optional[Any] = None
    # max draft tokens per slot per verify launch
    speculative_k: int = 4
    # --- fault-tolerance plane -----------------------------------------
    # bounded recompute: a request preempted (and requeued for greedy
    # recompute) more than this many times is retired FAILED with a
    # PoolExhausted reason instead of spinning in the preemption loop
    # forever (None = unbounded, the pre-taxonomy livelock behavior)
    max_recomputes: Optional[int] = 50
    # transient launch failures retry under this policy before the launch
    # group is failed (None = the shared DEFAULT_RETRY)
    launch_retry: Optional[RetryPolicy] = None
    # replica health: a replica whose last heartbeat (engine.heartbeat(r))
    # is older than this is drained — its slots preempt back to the queue
    # and admission routes around it until a beat restores it.  None
    # disables monitoring (no drain, no heartbeats required)
    heartbeat_deadline_s: Optional[float] = None


@dataclass
class _Slot:
    """One KV-cache row's scheduler state: admitted requests move
    prefill -> decode -> retired (slot freed); under paged KV a slot in
    either live state may also be PREEMPTED on pool pressure — its
    blocks are released and the request requeued (prompt+generated) for
    greedy recompute."""

    rid: int
    tokens: np.ndarray
    plen: int
    remaining: int
    pos: int = 0                  # prompt tokens prefilled so far
    state: str = "prefill"        # "prefill" | "decode"
    generated: List[int] = field(default_factory=list)
    priority: int = 0             # victim ordering on pool pressure
    aseq: int = 0                 # admission sequence (newest preempts first)
    # re-admitted after preemption: the prompt replays previously
    # generated tokens, so the prefill-completion token is NOT the free
    # first token — it consumes max_new budget
    resumed: bool = False


class ServeEngine:
    def __init__(self, model: Model, params, scfg: ServeConfig):
        if scfg.prefill_mode not in ("batched", "replay"):
            raise ValueError(
                f"unknown prefill_mode {scfg.prefill_mode!r} "
                f"(expected 'batched' or 'replay')")
        if scfg.replicas < 1:
            raise ValueError(f"ServeConfig(replicas={scfg.replicas}): "
                             f"need at least 1 replica")
        if scfg.sharding_profile is not None and scfg.mesh is None:
            # mirror CompileOptions: a profile without a mesh is a
            # misconfiguration, not a silent single-device fallback
            raise ValueError(
                "ServeConfig(sharding_profile=...) needs a mesh: pass "
                "ServeConfig(mesh=..., sharding_profile=...)")
        if scfg.kv_block_size is not None:
            if scfg.kv_block_size < 1:
                raise ValueError(
                    f"ServeConfig(kv_block_size={scfg.kv_block_size}): "
                    f"need a positive block size")
            if scfg.max_seq % scfg.kv_block_size != 0:
                raise ValueError(
                    f"ServeConfig(kv_block_size={scfg.kv_block_size}) must "
                    f"divide max_seq={scfg.max_seq}: full block tables "
                    f"cover exactly max_seq positions so the paged "
                    f"artifacts stay shape-identical to fixed rows")
            if scfg.mesh is not None:
                raise ValueError(
                    "paged KV (kv_block_size=...) does not compose with "
                    "mesh sharding yet: the block-id axis has no "
                    "data-parallel layout — drop the mesh or use fixed "
                    "rows")
        if scfg.speculative is not None and scfg.speculative_k < 1:
            raise ValueError(
                f"ServeConfig(speculative_k={scfg.speculative_k}): need "
                f"at least 1 draft token")
        self.model = model
        self.params = params
        self.scfg = scfg
        self.n_slots = scfg.replicas * scfg.max_batch
        self.paged = scfg.kv_block_size is not None
        if self.paged:
            self._mbs = scfg.max_seq // scfg.kv_block_size
            n_blocks = (scfg.kv_pool_blocks
                        if scfg.kv_pool_blocks is not None
                        else self.n_slots * self._mbs)
            self.pool = PagedKVPool(model, n_blocks=n_blocks,
                                    block_size=scfg.kv_block_size)
            self.alloc = BlockAllocator(n_blocks, scfg.kv_block_size,
                                        self.n_slots, self._mbs)
            self.cache = None       # paged state lives in self.pool.tree
        else:
            self._mbs = 0
            self.pool = None
            self.alloc = None
            self.cache = model.init_cache(self.n_slots, scfg.max_seq)
        self.lens = np.zeros((self.n_slots,), np.int32)
        self.slots: List[Optional[_Slot]] = [None] * self.n_slots
        self.queue: List[Request] = []
        self.done: Dict[int, List[int]] = {}
        self.rejected: List[int] = []   # rids refused at submit()
        # fault plane: rid -> failure reason for requests retired FAILED
        # (permanent launch error, PoolExhausted, DeadlineExceeded)
        self.failed: Dict[int, str] = {}
        self._recomputes: Dict[int, int] = {}   # rid -> preempt count
        self._deadlines: Dict[int, float] = {}  # rid -> absolute deadline
        self._clock = time.monotonic            # injectable (tests/docs)
        self._wall = _WALL      # perf timing (busy_s, decode gaps) only
        self._retry = scfg.launch_retry or DEFAULT_RETRY
        self._kdem0 = len(KERNEL_DEMOTIONS)
        self._replica_alive = [True] * scfg.replicas
        self.monitor: Optional[HeartbeatMonitor] = None
        if scfg.heartbeat_deadline_s is not None:
            self.monitor = HeartbeatMonitor(
                [f"replica{r}" for r in range(scfg.replicas)],
                deadline_s=scfg.heartbeat_deadline_s)
            now = self._clock()
            for r in range(scfg.replicas):
                self.monitor.beat(f"replica{r}", t=now)
        self._admit_order = get_admission_policy(scfg.admission)
        self._prefill_impl = (model.prefill if scfg.prefill_mode == "batched"
                              else replay_prefill(model.decode_step))
        self._proposer = get_proposer(scfg.speculative)
        self._decode_credit = 0
        self._bucket_pairs: Set[Tuple[int, int]] = set()
        self._busy_s = 0.0
        self._last_decode_t: Optional[float] = None
        self._aseq = 0                  # admission sequence counter
        self._carry: Dict[int, List[int]] = {}  # rid -> generated-so-far
        self._rep_counters = [
            {"admitted": 0, "tokens_generated": 0, "requests_completed": 0}
            for _ in range(scfg.replicas)]

        # SPMD placement: shard the persistent trees once at init (the
        # per-call argument shardings are the prefill artifact's job)
        self.mesh = scfg.mesh
        self._dp_axes: Tuple[str, ...] = ()
        self._put_args = lambda *xs: xs  # decode-input placement
        if self.mesh is not None:
            self._init_mesh(model)

        # one compile cache shared by both artifacts; entries are keyed by
        # per-artifact fingerprint so prefill/decode never collide
        self.compile_cache = CompileCache("serve", max_entries=64)
        pol = dataclasses.replace(
            scfg.prefill_policy,
            overrides=tuple(scfg.prefill_policy.overrides) + (
                ("B", (scfg.batch_policy.kind, scfg.batch_policy.granule)),))
        dim_b = Dim("B", max=self.n_slots)
        popts = CompileOptions(pipeline="jit", name="prefill",
                               policy=pol,
                               escalation_threshold=
                               scfg.escalation_threshold,
                               mesh=scfg.mesh,
                               sharding_profile=scfg.sharding_profile
                               if scfg.mesh is not None else None,
                               cache=self.compile_cache)
        if self.paged:
            # the block pool passes through untouched (None spec); the
            # per-slot block tables ride a TreeSpec so they bucket-pad on
            # B together with tokens/lens — padded rows carry all-null
            # tables and gather/write only the null block
            self._prefill_fn = disc_compile(
                self._prefill_paged,
                specs=[None,                 # params pytree
                       None,                 # block pool pytree
                       TreeSpec({0: "B"}),   # {"tables": (B, max_blocks)}
                       ArgSpec((dim_b, Dim("S", max=scfg.max_seq)),
                               jnp.int32, name="tokens"),
                       ArgSpec((dim_b,), jnp.int32, name="lens"),
                       ArgSpec((dim_b,), jnp.int32, name="offsets")],
                options=popts)
            self._decode_fn = disc_compile(
                self._decode_paged,
                options=CompileOptions(pipeline="jit", name="decode",
                                       cache=self.compile_cache))
        else:
            self._prefill_fn = disc_compile(
                self._prefill_call,
                specs=[None,                 # params pytree
                       TreeSpec({1: "B"}),   # gathered cache rows (L, B, ...)
                       ArgSpec((dim_b, Dim("S", max=scfg.max_seq)),
                               jnp.int32, name="tokens"),
                       ArgSpec((dim_b,), jnp.int32, name="lens"),
                       ArgSpec((dim_b,), jnp.int32, name="offsets")],
                options=popts)
            self._decode_fn = disc_compile(
                self._decode_step,
                options=CompileOptions(pipeline="jit", name="decode",
                                       cache=self.compile_cache))
        self._verify_fn = None
        if self._proposer is not None:
            self._verify_fn = disc_compile(
                self._verify_paged if self.paged else self._verify_call,
                options=CompileOptions(pipeline="jit", name="verify",
                                       cache=self.compile_cache))
        self.stats: Dict[str, Any] = self._zero_stats()
        self._refresh_stats()
        obs_metrics.register_collector("serve", self._obs_stats,
                                       name="engine")
        obs_metrics.register_collector("health", self._obs_health,
                                       name="engine")

    def _init_mesh(self, model: Model) -> None:
        """Shard params + KV cache onto the mesh per the profile: params
        follow the profile's weight layout, cache rows are partitioned
        along the data-parallel axes on their batch axis (axis 1 of the
        layer-stacked ``(L, B, ...)`` leaves) — each replica's rows live
        on its own slice of the ``data`` axis."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from ..dist.profiles import get_profile
        from ..dist.spmd import fit_spec

        profile = get_profile(self.scfg.sharding_profile or "dp")
        self.profile = profile
        # the axes the PROFILE shards the batch dim on (not a hardcoded
        # DP set): the cache layout, the slot-divisibility guard, and
        # the decode-input placement must all agree with what the
        # prefill artifact's planner emits for "B"
        self._dp_axes = tuple(a for a in profile.batch_axes()
                              if a in self.mesh.axis_names)
        dp = 1
        for a in self._dp_axes:
            dp *= int(self.mesh.shape[a])
        if dp > 1 and self.n_slots % dp != 0:
            raise ValueError(
                f"replicas*max_batch={self.n_slots} slots must divide the "
                f"batch-sharding mesh axes {self._dp_axes} (size {dp}) "
                f"evenly — adjust replicas/max_batch or the mesh shape")

        def put(x, spec):
            return jax.device_put(x, NamedSharding(
                self.mesh, fit_spec(tuple(x.shape), spec, self.mesh)))

        logical = model.specs() if profile.param_mode == "tp" else None
        pspecs = profile.param_specs(self.params, logical)
        self.params = jax.tree.map(
            lambda s, x: put(x, s), pspecs, self.params,
            is_leaf=lambda s: isinstance(s, P))
        if profile.param_mode == "tp":
            # honor the model's logical cache layout (already rank-
            # aligned with the layer-stacked leaves: batch along the DP
            # axes, heads/sequence along "model")
            cspecs = model.cache_specs()
            self._put_cache = lambda tree: jax.tree.map(
                lambda s, c: put(c, s), cspecs, tree,
                is_leaf=lambda s: isinstance(s, P))
        else:
            def batch_spec(leaf):
                # same batch-axis rule the masking path uses; a leaf
                # with no batch axis stays replicated
                ax = cache_batch_axis(leaf.shape, self.n_slots)
                if ax is None:
                    return P(*([None] * leaf.ndim))
                return profile.batch_leaf_spec(leaf.ndim, ax)

            self._put_cache = lambda tree: jax.tree.map(
                lambda c: put(c, batch_spec(c)), tree)
        self.cache = self._put_cache(self.cache)
        # decode inputs have fixed shapes: precompute their shardings
        # once — the decode loop is the per-token hot path
        dp_spec = self._dp_axes if self._dp_axes else None
        dec_shardings = tuple(
            NamedSharding(self.mesh,
                          fit_spec(shape, P(*((dp_spec,)
                                              + (None,) * (len(shape) - 1))),
                                   self.mesh))
            for shape in ((self.n_slots, 1), (self.n_slots,),
                          (self.n_slots,)))
        self._put_args = lambda *xs: tuple(
            jax.device_put(x, s) for x, s in zip(xs, dec_shardings))

    # ------------------------------------------------------------ device --
    def _prefill_call(self, params, rows, tokens, lens, offsets):
        """Single-pass prefill over a gathered group of cache rows.

        Fresh rows (offset 0) are zeroed first so a previous occupant's
        state can never leak into a new request — positional KV caches
        mask stale entries anyway, but recurrent state is overwritten,
        not masked."""
        fresh = offsets == 0
        rows = jax.tree.map(
            lambda c: jnp.where(row_keep_mask(fresh, c),
                                jnp.zeros_like(c), c), rows)
        logits, rows = self._prefill_impl(params, rows, tokens, lens,
                                          offsets)
        return logits, rows

    def _decode_step(self, params, cache, tokens, lens, active):
        """One decode step; cache writes gated to ``active`` rows so
        mid-prefill and empty slots keep their state untouched."""
        logits, new_cache = self.model.decode_step(params, cache, tokens,
                                                   lens)
        new_cache = jax.tree.map(
            lambda n, o: jnp.where(row_keep_mask(active, o),
                                   n.astype(o.dtype), o),
            new_cache, cache)
        return logits, new_cache

    def _prefill_paged(self, params, pool, tview, tokens, lens, offsets):
        """Paged prefill: gather each group row's blocks into the dense
        fixed-row layout the attention kernels consume, zero fresh rows,
        run the single-pass prefill, then scatter exactly the freshly
        written positions [offset, offset+len) back into the pool.
        Bucket-padded rows carry all-null tables: their gathers see only
        the null block (masked out of every real row by the length
        masks) and their writes land back in it."""
        tables = tview["tables"]
        rows = self.pool.gather(pool, tables)
        fresh = offsets == 0
        rows = jax.tree.map(
            lambda c: jnp.where(row_keep_mask(fresh, c),
                                jnp.zeros_like(c), c), rows)
        logits, rows = self._prefill_impl(params, rows, tokens, lens,
                                          offsets)
        pos = jnp.arange(self.scfg.max_seq)[None, :]
        keep = (pos >= offsets[:, None]) & (pos < (offsets + lens)[:, None])
        return logits, self.pool.scatter(pool, rows, tables, keep)

    def _decode_paged(self, params, pool, tables, tokens, lens, active):
        """Paged decode step: gather, step, scatter only each active
        row's single fresh position ``lens[r]`` (inactive rows write
        nothing, like the fixed path's active gate)."""
        rows = self.pool.gather(pool, tables)
        logits, rows = self.model.decode_step(params, rows, tokens, lens)
        pos = jnp.arange(self.scfg.max_seq)[None, :]
        keep = active[:, None] & (pos == lens[:, None])
        return logits, self.pool.scatter(pool, rows, tables, keep)

    def _verify_call(self, params, cache, tokens, dlens, fills):
        """Speculative verify (fixed rows): one widened chunk pass whose
        per-position argmax comes back to the host — ``ids[r, j]`` is
        the model's greedy token after consuming ``tokens[r, j]``.
        Rows with ``dlens[r] == 0`` write nothing (prefill masks)."""
        logits, new_cache = self.model.verify(params, cache, tokens, dlens,
                                              fills)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_cache

    def _verify_paged(self, params, pool, tables, tokens, dlens, fills):
        """Speculative verify over gathered paged rows; the drafted
        positions [fill, fill+dlen) scatter back to the pool."""
        rows = self.pool.gather(pool, tables)
        logits, rows = self.model.verify(params, rows, tokens, dlens,
                                         fills)
        pos = jnp.arange(self.scfg.max_seq)[None, :]
        keep = (pos >= fills[:, None]) & (pos < (fills + dlens)[:, None])
        ids = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return ids, self.pool.scatter(pool, rows, tables, keep)

    # -------------------------------------------------------------- host --
    def submit(self, reqs: List[Request]) -> None:
        """Queue requests for admission.

        Requests the engine can never serve are rejected gracefully —
        counted in ``stats["rejected_requests"]``, rids recorded in
        ``self.rejected`` — and the REST of the batch is still admitted:

        * prompt longer than ``max_seq`` (chunking would clamp every
          launch under the artifact's S cap and the overflow would
          scatter nowhere: the request would "complete" with garbage);
        * paged mode: worst-case footprint (prompt + max_new tokens)
          needing more blocks than the whole pool holds.

        A rid already pending (queued or in a slot) raises — atomically,
        before anything in the batch is queued: rids are the engine's
        stable identity (admission removal, preemption requeue, the
        ``done`` dict) and a duplicate would silently collapse two
        requests into one ``done`` entry.
        """
        pending = {r.rid for r in self.queue}
        pending.update(s.rid for s in self.slots if s is not None)
        accepted: List[Request] = []
        dropped: List[int] = []
        for r in reqs:
            if r.rid in pending:
                raise ValueError(
                    f"request rid={r.rid} is already pending: rids are "
                    f"the engine's stable identity — leave "
                    f"Request(rid=None) for an auto-assigned monotonic "
                    f"id")
            pending.add(r.rid)
            if len(r.tokens) > self.scfg.max_seq:
                dropped.append(r.rid)
                continue
            if self.paged:
                worst = min(len(r.tokens) + r.max_new_tokens + 1,
                            self.scfg.max_seq)
                if blocks_for(worst, self.scfg.kv_block_size) \
                        > self.alloc.n_blocks:
                    dropped.append(r.rid)
                    continue
            accepted.append(r)
            if r.deadline_s is not None and r.rid not in self._deadlines:
                # absolute deadline fixed at first submission; a
                # preemption requeue of the same rid keeps the original
                self._deadlines[r.rid] = self._clock() + r.deadline_s
        self.stats["rejected_requests"] += len(dropped)
        self.rejected.extend(dropped)
        self.queue.extend(accepted)

    def _replica_of(self, slot: int) -> int:
        return slot // self.scfg.max_batch

    def _admit(self) -> None:
        """Claim free slots for waiting requests in policy order; admitted
        requests enter the prefill state (launched by the next
        :meth:`_prefill_group` calls, grouped by chunk bucket).

        With replicas, each request (still in policy order) is routed to
        the **least-loaded replica** that has a free slot (ties break to
        the lowest replica index), so replica KV caches fill evenly.

        Under paged KV, admission also gates on pool headroom: a request
        is only admitted while the free list covers its first prefill
        chunk (in policy order, no skipping ahead — admitting a slot
        that cannot allocate would just thrash the preemption path).
        Blocks free up as slots retire, so blocked admission is
        pressure, not deadlock."""
        mb = self.scfg.max_batch
        # a drained replica offers no slots until a heartbeat restores it
        free_by_rep = [[i for i in range(r * mb, (r + 1) * mb)
                        if self.slots[i] is None]
                       if self._replica_alive[r] else []
                       for r in range(self.scfg.replicas)]
        n_free = sum(len(f) for f in free_by_rep)
        if not n_free or not self.queue:
            return
        chunk_cap = self.scfg.prefill_chunk or self.scfg.max_seq
        budget = self.alloc.free_blocks if self.paged else 0
        # removal is by rid — the stable identity submit() enforces —
        # never by Request.__eq__ (numpy token arrays make dataclass
        # equality ambiguous-truth-value prone)
        taken: Set[int] = set()
        for req in self._admit_order(self.queue):
            if len(taken) >= n_free:
                break
            if self.paged:
                need = blocks_for(min(len(req.tokens), chunk_cap),
                                  self.scfg.kv_block_size)
                if need > budget:
                    break
                budget -= need
            taken.add(req.rid)
            rep = min((r for r in range(self.scfg.replicas)
                       if free_by_rep[r]),
                      key=lambda r: (mb - len(free_by_rep[r]), r))
            i = free_by_rep[rep].pop(0)
            toks = np.asarray(req.tokens, np.int32)
            carried = self._carry.pop(req.rid, None)
            self.slots[i] = _Slot(rid=req.rid, tokens=toks,
                                  plen=int(toks.shape[0]),
                                  remaining=req.max_new_tokens,
                                  priority=req.priority,
                                  aseq=self._aseq,
                                  generated=list(carried or ()),
                                  resumed=bool(carried))
            self._aseq += 1
            self.lens[i] = 0
            self._rep_counters[rep]["admitted"] += 1
            if obs_trace.ACTIVE is not None:
                obs_trace.ACTIVE.async_begin(
                    "request", id=req.rid, replica=rep, slot=i,
                    prompt_len=int(toks.shape[0]),
                    resumed=bool(carried))
        self.queue = [r for r in self.queue if r.rid not in taken]

    # -------------------------------------------------------- fault plane --
    def _forget(self, rid: int) -> None:
        """Drop a retired rid's scheduler bookkeeping."""
        self._carry.pop(rid, None)
        self._recomputes.pop(rid, None)
        self._deadlines.pop(rid, None)

    def _fail_request(self, rid: int, reason: str) -> None:
        """Retire ``rid`` FAILED: recorded with its reason, counted, and
        every bookkeeping entry dropped — the rest of the engine keeps
        serving."""
        self.failed[rid] = reason
        self.stats["failed_requests"] += 1
        self._forget(rid)
        if obs_trace.ACTIVE is not None:
            obs_trace.ACTIVE.async_end("request", id=rid, failed=True,
                                       reason=reason)

    def _fail_slot(self, i: int, reason: str) -> None:
        """Fail the request occupying slot ``i`` and free the slot."""
        slot = self.slots[i]
        if self.paged:
            self.alloc.release(i)
        self.slots[i] = None
        self.lens[i] = 0
        self._fail_request(slot.rid, reason)

    def _launch(self, kind: str, fn: Callable, *args):
        """Run one artifact launch under the taxonomy: transient failures
        (backend RESOURCE_EXHAUSTED, injected transients) retry with
        capped exponential backoff; a permanent failure raises a
        classified :class:`~repro.errors.DiscError` for the caller to
        fail exactly the requests in the launch group."""
        sp = (obs_trace.ACTIVE.begin(f"serve.{kind}", cat="serve")
              if obs_trace.ACTIVE is not None else None)
        attempt = 0
        ok = False
        try:
            while True:
                try:
                    if faults.ACTIVE is not None:
                        faults.ACTIVE.check("serve.launch", key=kind)
                    out = fn(*args)
                    ok = True
                    return out
                except CONTROL_EXCEPTIONS:
                    raise
                except DiscError as e:   # already classified (e.g. a
                    err = e              # CompileError out of dispatch)
                except Exception as e:  # noqa: BLE001 — classified below
                    err = wrap_launch_error(e, kind)
                if not err.transient or attempt >= self._retry.max_retries:
                    raise err
                self.stats["retries"] += 1
                obs_metrics.record_event("serve.retry", kind=kind,
                                         attempt=attempt + 1)
                time.sleep(self._retry.delay(attempt))
                attempt += 1
        finally:
            if sp is not None:
                sp.end(attempts=attempt + 1, error=not ok)

    def heartbeat(self, replica: int, *, t: Optional[float] = None) -> None:
        """Record a liveness beat for ``replica`` (requires
        ``ServeConfig(heartbeat_deadline_s=...)``).  A beat from a
        drained replica restores it at the next step."""
        if self.monitor is None:
            raise ValueError(
                "ServeEngine.heartbeat() needs replica health monitoring: "
                "set ServeConfig(heartbeat_deadline_s=...)")
        self.monitor.beat(f"replica{replica}",
                          t=self._clock() if t is None else t)

    def _check_replicas(self) -> None:
        """Drain replicas silent past the heartbeat deadline — their
        slots preempt back to the queue (existing preemption machinery,
        no recompute-budget penalty) and admission routes around them —
        and restore drained replicas that have beaten again."""
        dead = set(self.monitor.dead_hosts(now=self._clock()))
        mb = self.scfg.max_batch
        for r in range(self.scfg.replicas):
            is_dead = f"replica{r}" in dead
            if is_dead and self._replica_alive[r]:
                self._replica_alive[r] = False
                self.stats["replica_drains"] += 1
                obs_metrics.record_event("replica.drain", replica=r)
                for i in range(r * mb, (r + 1) * mb):
                    if self.slots[i] is not None:
                        self._preempt(i, drain=True)
            elif not is_dead and not self._replica_alive[r]:
                self._replica_alive[r] = True   # restored on recovery
                obs_metrics.record_event("replica.restore", replica=r)

    def _check_deadlines(self) -> None:
        """Fail queued and in-slot requests whose deadline passed."""
        if not self._deadlines:
            return
        now = self._clock()
        expired = {rid for rid, d in self._deadlines.items() if now > d}
        if not expired:
            return
        for i, s in enumerate(self.slots):
            if s is not None and s.rid in expired:
                self.stats["deadline_expirations"] += 1
                obs_metrics.record_event("deadline.expire", rid=s.rid)
                self._fail_slot(i, f"DeadlineExceeded: deadline_s passed "
                                   f"after {len(s.generated)} tokens")
        still = [r for r in self.queue if r.rid in expired]
        for r in still:
            self.stats["deadline_expirations"] += 1
            obs_metrics.record_event("deadline.expire", rid=r.rid)
            self._fail_request(r.rid, "DeadlineExceeded: deadline_s "
                                      "passed before completion")
        self.queue = [r for r in self.queue if r.rid not in expired]

    def _preempt(self, i: int, *, drain: bool = False) -> None:
        """Evict slot ``i`` on pool pressure (or replica drain): release
        its blocks and requeue the request with prompt+generated as the
        new prompt.  Greedy decoding makes recompute exact — the resumed
        request continues with precisely the tokens it would have
        produced — so preemption trades recompute time for memory, never
        output.

        Pool-pressure preemptions are bounded by
        ``ServeConfig(max_recomputes=...)``: a request past its budget is
        retired FAILED (PoolExhausted) instead of spinning forever.
        Drain preemptions (replica fault, not memory pressure) don't
        consume the budget."""
        slot = self.slots[i]
        if not drain and self.scfg.max_recomputes is not None:
            n = self._recomputes.get(slot.rid, 0) + 1
            if n > self.scfg.max_recomputes:
                if self.paged:
                    self.stats["kv_evictions"] += len(self.alloc.owned(i))
                self._fail_slot(
                    i, f"PoolExhausted: preempted {n - 1} times under "
                       f"pool pressure (max_recomputes="
                       f"{self.scfg.max_recomputes})")
                return
            self._recomputes[slot.rid] = n
        if self.paged:
            freed = self.alloc.release(i)
            if not drain:
                self.stats["kv_preemptions"] += 1
                self.stats["kv_evictions"] += freed
        obs_metrics.record_event("preempt", rid=slot.rid, slot=i,
                                 drain=drain)
        toks = slot.tokens
        if slot.generated:
            toks = np.concatenate(
                [toks, np.asarray(slot.generated, np.int32)])
        self._carry[slot.rid] = list(slot.generated)
        self.queue.append(Request(rid=slot.rid, tokens=toks,
                                  max_new_tokens=slot.remaining,
                                  priority=slot.priority))
        self.slots[i] = None
        self.lens[i] = 0

    def _ensure_blocks(self, i: int, n_tokens: int,
                       protect: Set[int]) -> bool:
        """Grow slot ``i``'s allocation to cover ``n_tokens`` positions,
        preempting victims (lowest priority, then newest admission) on
        pool pressure.  ``protect`` shields slots already committed to
        the launch being assembled; returns False only when every
        remaining block owner is protected."""
        while not self.alloc.ensure(i, n_tokens):
            cands = [(j, s.priority, s.aseq)
                     for j, s in enumerate(self.slots)
                     if s is not None and j != i and j not in protect
                     and self.alloc.owned(j)]
            v = pick_victim(cands)
            if v is None:
                return False
            self._preempt(v)
        return True

    def _prefill_group(self) -> None:
        """One prefill launch: group prefill-state slots by the bucket of
        their next chunk length and launch the largest group in a single
        batched pass (replay mode launches one request at a time)."""
        chunk_cap = self.scfg.prefill_chunk or self.scfg.max_seq
        groups: Dict[int, List[Tuple[int, int]]] = {}
        for i, s in enumerate(self.slots):
            if s is None or s.state != "prefill":
                continue
            cl = min(s.plen - s.pos, chunk_cap)
            sb = min(self.scfg.prefill_policy.bucket("S", max(cl, 1)),
                     self.scfg.max_seq)
            groups.setdefault(sb, []).append((i, cl))
        if not groups:
            return
        _, members = max(groups.items(), key=lambda kv: (len(kv[1]), -kv[0]))
        if self.scfg.prefill_mode == "replay":
            members = members[:1]
        if self.paged:
            # claim blocks for every member's chunk before building the
            # launch; a member that cannot allocate even after preempting
            # every unprotected victim sheds itself back to the queue
            # (admission re-gates it on pool headroom; the bounded
            # recompute budget turns a permanently starved slot into a
            # PoolExhausted failure instead of a livelock)
            kept = []
            for i, cl in members:
                s = self.slots[i]
                if s is None or s.state != "prefill":
                    continue    # preempted while assembling this launch
                protect = {j for j, _ in kept} | {i}
                if self._ensure_blocks(i, s.pos + cl, protect):
                    kept.append((i, cl))
                else:
                    self._preempt(i)
            members = kept
            if not members:
                return
        idx = np.asarray([i for i, _ in members])
        nb = len(members)
        smax = max(cl for _, cl in members)
        tokens = np.zeros((nb, smax), np.int32)
        lens = np.zeros((nb,), np.int32)
        offsets = np.zeros((nb,), np.int32)
        for r, (i, cl) in enumerate(members):
            s = self.slots[i]
            tokens[r, :cl] = s.tokens[s.pos:s.pos + cl]
            lens[r] = cl
            offsets[r] = s.pos

        try:
            if self.paged:
                tview = {"tables": self.alloc.table()[idx]}
                logits, new_pool = self._launch(
                    "prefill", self._prefill_fn, self.params,
                    self.pool.tree, tview, tokens, lens, offsets)
            else:
                rows = jax.tree.map(
                    lambda c: c[:, idx] if c.ndim > 1 else c, self.cache)
                logits, new_rows = self._launch(
                    "prefill", self._prefill_fn, self.params, rows, tokens,
                    lens, offsets)
        except DiscError as e:
            # a failed launch fails ONLY this launch group; queued and
            # decode-state requests are untouched
            for i, _ in members:
                self._fail_slot(i, f"LaunchError(prefill): {e}")
            return
        if self.paged:
            self.pool.tree = new_pool
        else:
            self.cache = jax.tree.map(
                lambda full, row: full.at[:, idx].set(
                    row[:, :nb].astype(full.dtype))
                if full.ndim > 1 else full,
                self.cache, new_rows)
            if self.mesh is not None:
                # the eager scatter above may change leaf shardings; pin
                # the cache back to its planned layout so the decode
                # artifact's jit entries never retrace on a sharding flip
                self.cache = self._put_cache(self.cache)
        last = np.asarray(logits[:nb])

        self._bucket_pairs.add((
            min(self.scfg.batch_policy.bucket("B", nb), self.n_slots),
            min(self.scfg.prefill_policy.bucket("S", smax),
                self.scfg.max_seq)))
        self.stats["prefill_calls"] += 1
        if nb > 1:
            self.stats["batched_prefills"] += 1
        chunked = bool(np.any(offsets > 0))
        for r, (i, cl) in enumerate(members):
            s = self.slots[i]
            s.pos += cl
            self.lens[i] = s.pos
            if s.pos >= s.plen:
                s.state = "decode"
                s.generated.append(int(np.argmax(last[r])))
                if s.resumed:
                    # a resumed prompt replays previously generated
                    # tokens: its completion token is a fresh one and
                    # consumes budget (the free first token was already
                    # granted by the original prefill)
                    s.remaining -= 1
                    s.resumed = False
                self.stats["tokens_generated"] += 1
                self._rep_counters[self._replica_of(i)][
                    "tokens_generated"] += 1
                self._maybe_retire(i)
            else:
                chunked = True
        if chunked:
            self.stats["prefill_chunks"] += 1

    def _decode(self) -> None:
        """One decode launch over ALL replicas' rows — the tokens-per-
        launch scaling replicas buy; on a mesh the batch axis is
        partitioned along ``data``, so each replica computes its own
        rows.  With a proposer configured, the launch is the widened
        speculative verify instead."""
        active_idx = [i for i, s in enumerate(self.slots)
                      if s is not None and s.state == "decode"]
        if self._proposer is not None:
            self._decode_speculative(active_idx)
        else:
            self._decode_plain(active_idx)

    def _mark_decode_launch(self) -> None:
        now = self._wall()
        if self._last_decode_t is not None:
            self.stats["max_decode_gap_s"] = max(
                self.stats["max_decode_gap_s"], now - self._last_decode_t)
        self._last_decode_t = now
        self.stats["decode_steps"] += 1

    def _decode_plain(self, active_idx: List[int]) -> None:
        if self.paged:
            # every active row writes position lens[r]: claim the block
            # first, preempting on pressure; a row that cannot allocate
            # even then (all owners protected) sheds itself
            protect: Set[int] = set()
            for i in list(active_idx):
                s = self.slots[i]
                if s is None or s.state != "decode":
                    continue
                if self._ensure_blocks(i, int(self.lens[i]) + 1, protect):
                    protect.add(i)
                else:
                    self._preempt(i)
            active_idx = [i for i in active_idx
                          if self.slots[i] is not None
                          and self.slots[i].state == "decode"]
            if not active_idx:
                return
        tokens = np.zeros((self.n_slots, 1), np.int32)
        active = np.zeros((self.n_slots,), bool)
        for i in active_idx:
            tokens[i, 0] = self.slots[i].generated[-1]
            active[i] = True
        try:
            if self.paged:
                logits, self.pool.tree = self._launch(
                    "decode", self._decode_fn, self.params, self.pool.tree,
                    jnp.asarray(self.alloc.table()), jnp.asarray(tokens),
                    jnp.asarray(self.lens), jnp.asarray(active))
            else:
                t, l, a = self._put_args(jnp.asarray(tokens),
                                         jnp.asarray(self.lens),
                                         jnp.asarray(active))
                logits, self.cache = self._launch(
                    "decode", self._decode_fn, self.params, self.cache,
                    t, l, a)
        except DiscError as e:
            for i in active_idx:
                self._fail_slot(i, f"LaunchError(decode): {e}")
            return
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        self._mark_decode_launch()
        for i in active_idx:
            slot = self.slots[i]
            self.lens[i] += 1
            slot.generated.append(int(nxt[i]))
            slot.remaining -= 1
            self.stats["tokens_generated"] += 1
            self._rep_counters[self._replica_of(i)]["tokens_generated"] += 1
            self._maybe_retire(i)

    def _decode_speculative(self, active_idx: List[int]) -> None:
        """One widened (n_slots, k+1) verify launch: slot r's pending
        token plus up to k drafted tokens; the longest draft prefix
        matching the model's greedy argmax is accepted and the model's
        own token at the first divergence is the correction.  Accept
        counts advance the ``lens`` vector — cache fill moves by
        1 + accepted per launch instead of 1."""
        k = self.scfg.speculative_k
        tokens = np.zeros((self.n_slots, k + 1), np.int32)
        dlens = np.zeros((self.n_slots,), np.int32)
        drafts: Dict[int, np.ndarray] = {}
        protect: Set[int] = set()
        live: List[int] = []
        for i in list(active_idx):
            s = self.slots[i]
            if s is None or s.state != "decode":
                continue    # preempted while assembling this launch
            fill = int(self.lens[i])
            # drafted chunk must fit the row (fill + 1 + drafts <=
            # max_seq - 1) and never draft past the remaining budget
            cap = min(k, self.scfg.max_seq - fill - 2, s.remaining - 1)
            dr = np.zeros((0,), np.int32)
            if cap > 0:
                hist = np.concatenate(
                    [s.tokens, np.asarray(s.generated, np.int32)])
                dr = np.asarray(self._proposer.propose(hist, cap),
                                np.int32).reshape(-1)[:cap]
            dl = 1 + int(dr.shape[0])
            if self.paged:
                if not self._ensure_blocks(i, fill + dl, protect):
                    dr = dr[:0]     # shrink the ask to the bare step
                    dl = 1
                    if not self._ensure_blocks(i, fill + 1, protect):
                        self._preempt(i)
                        continue
                protect.add(i)
            tokens[i, 0] = s.generated[-1]
            tokens[i, 1:dl] = dr
            dlens[i] = dl
            drafts[i] = dr
            live.append(i)
        if not live:
            return
        fills = self.lens.copy()
        try:
            if self.paged:
                ids, self.pool.tree = self._launch(
                    "verify", self._verify_fn, self.params, self.pool.tree,
                    jnp.asarray(self.alloc.table()), jnp.asarray(tokens),
                    jnp.asarray(dlens), jnp.asarray(fills))
            else:
                ids, self.cache = self._launch(
                    "verify", self._verify_fn, self.params, self.cache,
                    jnp.asarray(tokens), jnp.asarray(dlens),
                    jnp.asarray(fills))
        except DiscError as e:
            for i in live:
                self._fail_slot(i, f"LaunchError(verify): {e}")
            return
        ids = np.asarray(ids)
        self._mark_decode_launch()
        for i in live:
            s = self.slots[i]
            dr = drafts[i]
            dl = int(dlens[i])
            a = 0
            while a < dl - 1 and int(ids[i, a]) == int(dr[a]):
                a += 1
            # emitted = accepted drafts + the model's correction token;
            # rejected positions beyond fill+a+1 stay stale in the cache
            # but are masked (>= fill) until overwritten
            emitted = [int(x) for x in dr[:a]] + [int(ids[i, a])]
            self.stats["spec_drafted_tokens"] += dl - 1
            self.stats["spec_accepted_tokens"] += a
            kept = 0
            for tok in emitted:
                s.generated.append(tok)
                s.remaining -= 1
                kept += 1
                self.stats["tokens_generated"] += 1
                self._rep_counters[self._replica_of(i)][
                    "tokens_generated"] += 1
                if tok == self.scfg.eos_id or s.remaining <= 0:
                    break
            self.lens[i] = int(fills[i]) + kept
            self._maybe_retire(i)

    def _maybe_retire(self, i: int) -> None:
        slot = self.slots[i]
        if (slot.remaining <= 0 or slot.generated[-1] == self.scfg.eos_id
                or self.lens[i] >= self.scfg.max_seq - 1):
            self.done[slot.rid] = slot.generated
            self.stats["requests_completed"] += 1
            self._forget(slot.rid)
            if obs_trace.ACTIVE is not None:
                obs_trace.ACTIVE.async_end("request", id=slot.rid,
                                           tokens=len(slot.generated))
            self._rep_counters[self._replica_of(i)][
                "requests_completed"] += 1
            if self.paged:
                # normal retirement, not an eviction: blocks just return
                self.alloc.release(i)
            self.slots[i] = None
            self.lens[i] = 0

    def step(self) -> None:
        """One engine iteration: admit, then either a prefill launch or a
        decode step — the ``prefill_interleave`` budget decides which when
        both kinds of work are pending."""
        t0 = self._wall()
        if self.monitor is not None:
            self._check_replicas()
        self._check_deadlines()
        self._admit()
        has_p = any(s is not None and s.state == "prefill"
                    for s in self.slots)
        has_d = any(s is not None and s.state == "decode"
                    for s in self.slots)
        if has_p and (not has_d or self._decode_credit <= 0):
            self._prefill_group()
            self._decode_credit = max(self.scfg.prefill_interleave, 0)
        elif has_d:
            self._decode()
            self._decode_credit -= 1
        if not any(s is not None and s.state == "decode"
                   for s in self.slots):
            self._last_decode_t = None  # decode idle: gaps don't count
        self._busy_s += self._wall() - t0
        self._refresh_stats()

    def run_until_done(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        steps = 0
        while (self.queue or any(s is not None for s in self.slots)):
            self.step()
            steps += 1
            if steps > max_steps:
                break
        return self.done

    # ------------------------------------------------------ introspection --
    def report(self) -> Dict[str, Any]:
        """Engine health + stats in one structured view.

        ``report()["health"]`` is the fault plane's summary: replica
        liveness (with last-beat ages under monitoring), FAILED requests
        with their reasons, the fault counters, compile-cache
        retry/escalation-failure totals, and any kernel/backend
        demotions journaled during this engine's run."""
        return {"health": self._obs_health(), "stats": dict(self.stats),
                "compiles": self.compile_counts()}

    def _obs_health(self) -> Dict[str, Any]:
        """The ``report()["health"]`` payload — also registered as the
        pull collector behind ``disc.observe()["health"]["engine"]``."""
        now = self._clock()
        replicas = []
        for r, alive in enumerate(self._replica_alive):
            entry: Dict[str, Any] = {"replica": r, "alive": bool(alive)}
            if self.monitor is not None:
                seen = self.monitor.last_seen[f"replica{r}"]
                entry["last_beat_age_s"] = round(now - seen, 3)
            replicas.append(entry)
        cs = self.compile_cache.stats
        return {
            "alive_replicas": int(sum(self._replica_alive)),
            "replicas": replicas,
            "failed": {rid: self.failed[rid]
                       for rid in sorted(self.failed)},
            "counters": {k: self.stats[k] for k in
                         ("failed_requests", "retries", "kernel_demotions",
                          "deadline_expirations", "replica_drains")},
            "compile": {"retries": cs.retries,
                        "escalation_failures": cs.escalation_failures},
            "kernel_demotions": list(KERNEL_DEMOTIONS[self._kdem0:]),
        }

    def _obs_stats(self) -> Dict[str, Any]:
        """Pull collector behind ``disc.observe()["serve"]["engine"]`` —
        the same counters as :attr:`stats`, refreshed at snapshot time."""
        self._refresh_stats()
        out = dict(self.stats)
        out["per_replica"] = [dict(c) for c in self.stats["per_replica"]]
        return out

    def compile_counts(self) -> Dict[str, Dict[str, int]]:
        """Per-artifact compile counts (``{"bucket", "exact", "total"}``
        each) — the observable O(#buckets) contract."""
        zero = {"bucket": 0, "exact": 0, "total": 0}

        def counts(fn):
            try:
                return fn.compile_counts()
            except AttributeError:  # not compiled yet (no calls)
                return dict(zero)

        out = {"prefill": counts(self._prefill_fn),
               "decode": counts(self._decode_fn)}
        if self._verify_fn is not None:
            out["verify"] = counts(self._verify_fn)
        return out

    def _zero_stats(self) -> Dict[str, Any]:
        """A typed zero value for every :data:`STATS_KEYS` entry: plain
        counters are ints, rate/occupancy keys are floats, and
        ``per_replica`` is a fresh list of per-replica counter dicts —
        never the scalar 0 a uniform ``= 0`` sweep would leave behind."""
        z: Dict[str, Any] = {k: 0 for k in STATS_KEYS}
        for k in ("tokens_per_sec", "max_decode_gap_s",
                  "kv_pool_occupancy", "kv_peak_occupancy"):
            z[k] = 0.0
        z["per_replica"] = [
            {"admitted": 0, "tokens_generated": 0,
             "requests_completed": 0, "occupied_slots": 0}
            for _ in range(self.scfg.replicas)]
        return z

    def reset_stats(self) -> None:
        """Zero the per-run counters (benchmark warmup boundary), each to
        its documented **type** via :meth:`_zero_stats` — the old uniform
        ``= 0`` sweep clobbered ``per_replica``'s list-of-dicts to an
        int.  Artifact-lifetime counters — compiles, escalations, bucket
        pairs, pool capacity/in-use — are re-derived and keep
        accumulating."""
        self.stats.update(self._zero_stats())
        self._rep_counters = [
            {"admitted": 0, "tokens_generated": 0, "requests_completed": 0}
            for _ in range(self.scfg.replicas)]
        self._busy_s = 0.0
        self._last_decode_t = None
        self._kdem0 = len(KERNEL_DEMOTIONS)   # demotion delta restarts
        self._refresh_stats()

    def _refresh_stats(self) -> None:
        pc = self.compile_counts()["prefill"]
        self.stats["prefill_compiles"] = pc["total"]
        self.stats["prefill_escalations"] = pc["exact"]
        self.stats["prefill_bucket_pairs"] = len(self._bucket_pairs)
        self.stats["kernel_demotions"] = len(KERNEL_DEMOTIONS) - self._kdem0
        occ = sum(s is not None for s in self.slots)
        self.stats["peak_active_slots"] = max(
            self.stats["peak_active_slots"], occ)
        if self.paged:
            self.stats["kv_pool_blocks"] = self.alloc.n_blocks
            self.stats["kv_blocks_in_use"] = self.alloc.used_blocks
            frac = self.alloc.used_blocks / self.alloc.n_blocks
            self.stats["kv_pool_occupancy"] = frac
            self.stats["kv_peak_occupancy"] = max(
                self.stats["kv_peak_occupancy"], frac)
        try:
            # staging accounting off the prefill dispatch (see
            # DispatchMemStats): padded launch bytes vs the cap worst case
            ms = self._prefill_fn._mstats
            self.stats["mem_launch_bytes"] = ms.last_bytes
            self.stats["mem_peak_launch_bytes"] = ms.peak_bytes
            self.stats["mem_launch_saved_bytes"] = ms.saved_bytes
        except AttributeError:  # not compiled yet (no calls)
            pass
        mb = self.scfg.max_batch
        self.stats["per_replica"] = [
            dict(c, occupied_slots=sum(
                s is not None
                for s in self.slots[r * mb:(r + 1) * mb]))
            for r, c in enumerate(self._rep_counters)]
        if self._busy_s > 0:
            self.stats["tokens_per_sec"] = \
                self.stats["tokens_generated"] / self._busy_s
