"""Serving engine: continuous batching over DISC shape buckets.

The paper's serving problem — requests with varying prompt lengths force
either per-shape recompilation (XLA) or interpretation (Nimble VM) — is
solved here exactly as DISC prescribes, built entirely on the public
``disc.compile`` API:

* **prefill** and **decode** are two ``disc.compile`` artifacts
  (``CompileOptions(pipeline="jit")`` — whole-model pytree functions)
  sharing **one** :class:`CompileCache`;
* prefill is compiled once per length-bucket: the artifact's generated
  dispatch bucket-pads the prompt, true lengths ride along as an i32
  operand (one compile serves every prompt ≤ bucket, clamped by
  ``Dim("S", max=max_seq)``); with
  ``ServeConfig(escalation_threshold=...)``, prompt lengths that stay hot
  escalate (§4.4) to unpadded prefill specializations — no replay steps
  wasted past the true prompt;
* decode is compiled once against the fixed-capacity KV cache; a step
  serves any mix of sequence lengths via the lens vector;
* slot management is host-side compiled Python (no per-op
  interpretation), mirroring the core dispatcher's generated flow.

Compile counts come from the artifacts' ``compile_counts()`` so
benchmarks can verify the O(#buckets) contract end-to-end on a real
model.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..api.options import CompileOptions, Dim
from ..api.staged import compile as disc_compile
from ..core.bucketing import BucketPolicy, POW2
from ..core.cache import CompileCache
from ..data.pipeline import Request
from ..frontends.jaxpr_frontend import ArgSpec
from ..models.registry import Model


@dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_seq: int = 512
    prefill_policy: BucketPolicy = POW2
    eos_id: int = 1
    # §4.4 static/dynamic mix on the serving path: prompt lengths seen at
    # least this many times get an unpadded prefill specialization (no
    # wasted replay steps past the prompt).  None disables.
    escalation_threshold: Optional[int] = None


@dataclass
class _Slot:
    rid: int
    length: int
    remaining: int
    generated: List[int] = field(default_factory=list)


class ServeEngine:
    def __init__(self, model: Model, params, scfg: ServeConfig):
        self.model = model
        self.params = params
        self.scfg = scfg
        self.cache = model.init_cache(scfg.max_batch, scfg.max_seq)
        self.lens = np.zeros((scfg.max_batch,), np.int32)
        self.slots: List[Optional[_Slot]] = [None] * scfg.max_batch
        self.queue: List[Request] = []
        self.done: Dict[int, List[int]] = {}

        # one compile cache shared by both artifacts; entries are keyed by
        # per-artifact fingerprint so prefill/decode never collide
        self.compile_cache = CompileCache("serve", max_entries=64)
        self._prefill_fn = disc_compile(
            self._replay_prefill,
            specs=[None,  # params pytree
                   None,  # KV cache row pytree
                   ArgSpec((1, Dim("S", max=scfg.max_seq)), jnp.int32,
                           name="tokens"),
                   None],  # lens (rides along, lens-aware fn)
            options=CompileOptions(pipeline="jit", name="prefill",
                                   policy=scfg.prefill_policy,
                                   escalation_threshold=
                                   scfg.escalation_threshold,
                                   cache=self.compile_cache))
        self._decode_fn = disc_compile(
            self._decode_step,
            options=CompileOptions(pipeline="jit", name="decode",
                                   cache=self.compile_cache))
        self.stats = {"prefill_compiles": 0, "decode_steps": 0,
                      "prefill_calls": 0, "tokens_generated": 0,
                      "prefill_escalations": 0}

    # ------------------------------------------------------------ device --
    def _prefill_step(self, params, cache, tokens, lens, slot_idx):
        """Prefill one request into cache row ``slot_idx`` (padded length)."""
        logits = self.model.forward(params, {"tokens": tokens, "lens": lens})
        # write prompt K/V by replaying through decode is wasteful; here we
        # recompute K/V inside forward and cache only via decode path for
        # clarity.  Production path: forward returns per-layer K/V too.
        last = jnp.take_along_axis(
            logits, (lens[:, None, None] - 1).astype(jnp.int32), axis=1)
        return last[:, 0]

    def _decode_step(self, params, cache, tokens, lens):
        return self.model.decode_step(params, cache, tokens, lens)

    # -------------------------------------------------------------- host --
    def submit(self, reqs: List[Request]) -> None:
        self.queue.extend(reqs)

    def _admit(self) -> None:
        for i in range(self.scfg.max_batch):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                self._prefill(req, i)

    def _prefill(self, req: Request, slot: int) -> None:
        """Bucket-compiled prefill: the artifact's generated dispatch pads
        the prompt to its bucket; true length rides along in ``lens``."""
        plen = len(req.tokens)
        toks = np.asarray(req.tokens, np.int32)[None, :]
        lens = np.array([plen], np.int32)
        cache_row = jax.tree.map(lambda c: c[:, slot:slot + 1]
                                 if c.ndim > 1 else c, self.cache)
        new_row, last_logits = self._prefill_fn(self.params, cache_row,
                                                toks, lens)
        self.stats["prefill_compiles"] = \
            self._prefill_fn.compile_counts()["total"]
        self.stats["prefill_escalations"] = self.compile_cache.stats.escalations
        self.cache = jax.tree.map(
            lambda full, row: jax.lax.dynamic_update_slice_in_dim(
                full, row.astype(full.dtype), slot, axis=1)
            if full.ndim > 1 else full,
            self.cache, new_row)
        self.lens[slot] = plen
        nxt = int(jnp.argmax(last_logits[0]))
        self.slots[slot] = _Slot(rid=req.rid, length=plen,
                                 remaining=req.max_new_tokens,
                                 generated=[nxt])
        self.stats["prefill_calls"] += 1

    def _replay_prefill(self, params, cache_row, tokens, lens):
        """Prefill by replaying tokens through decode steps (lax.scan) —
        keeps one code path for cache writes on every model family."""
        def step(carry, tok):
            cache, pos = carry
            logits, cache = self.model.decode_step(
                params, cache, tok[None, None], pos)
            return (cache, pos + 1), logits[:, 0]

        (cache_row, _), logits = jax.lax.scan(
            step, (cache_row, jnp.zeros((1,), jnp.int32)),
            tokens[0])
        last = logits[lens[0] - 1]
        return cache_row, last[None]

    def step(self) -> None:
        """One engine iteration: admit, decode active slots, retire."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return
        tokens = np.zeros((self.scfg.max_batch, 1), np.int32)
        for i in active:
            tokens[i, 0] = self.slots[i].generated[-1]
        logits, self.cache = self._decode_fn(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(self.lens))
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        self.stats["decode_steps"] += 1
        for i in active:
            slot = self.slots[i]
            self.lens[i] += 1
            slot.generated.append(int(nxt[i]))
            slot.remaining -= 1
            self.stats["tokens_generated"] += 1
            if (slot.remaining <= 0 or nxt[i] == self.scfg.eos_id
                    or self.lens[i] >= self.scfg.max_seq - 1):
                self.done[slot.rid] = slot.generated
                self.slots[i] = None
                self.lens[i] = 0

    def run_until_done(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        steps = 0
        while (self.queue or any(s is not None for s in self.slots)):
            self.step()
            steps += 1
            if steps > max_steps:
                break
        return self.done
