"""Pluggable draft proposers for speculative decoding on the serve path.

A proposer drafts up to ``k`` candidate tokens per slot from the slot's
token history; the engine verifies all drafts in ONE widened batched
launch (``model.verify`` — prefill semantics with the head at every
position) and keeps the longest prefix the model's own greedy argmax
agrees with, plus the model's correction token.  Greedy accept-or-fix is
exactly equivalent to plain greedy decoding — outputs are bit-for-bit
the same, only the launch count shrinks — so the only quality metric is
the accept rate (``stats["spec_accepted_tokens"] /
stats["spec_drafted_tokens"]``).

Built-ins:

* ``"ngram"`` — :class:`NGramProposer`, prompt-lookup decoding: the
  longest recent n-gram is matched against earlier history and its
  historical continuation proposed.  Free (no model), strong on
  repetitive continuations.
* :class:`DraftModelProposer` — the draft-model interface, stubbed: wire
  a small LM by subclassing and implementing :meth:`~Proposer.propose`.
"""
from __future__ import annotations

from typing import Optional, Protocol, Union, runtime_checkable

import numpy as np

__all__ = ["Proposer", "NGramProposer", "DraftModelProposer", "PROPOSERS",
           "get_proposer"]


@runtime_checkable
class Proposer(Protocol):
    def propose(self, history: np.ndarray, k: int) -> np.ndarray:
        """Up to ``k`` drafted continuation tokens (int32, possibly
        empty) for a slot whose prompt+generated history is
        ``history``; ``history[-1]`` is the token the next decode step
        will consume."""
        ...


class NGramProposer:
    """Prompt-lookup drafting: match the last ``m``-gram (``m`` from
    ``max_ngram`` down to 1) against earlier history; on a hit, propose
    the continuation that followed the most recent prior occurrence.
    Deterministic and model-free."""

    def __init__(self, max_ngram: int = 3):
        if max_ngram < 1:
            raise ValueError(f"max_ngram must be >= 1, got {max_ngram}")
        self.max_ngram = max_ngram

    def propose(self, history: np.ndarray, k: int) -> np.ndarray:
        h = np.asarray(history).reshape(-1)
        n = int(h.shape[0])
        empty = np.zeros((0,), np.int32)
        if k <= 0 or n < 2:
            return empty
        for m in range(min(self.max_ngram, n - 1), 0, -1):
            pat = h[n - m:]
            win = np.lib.stride_tricks.sliding_window_view(h, m)
            # windows strictly before the suffix itself, with at least
            # one continuation token available
            hits = np.flatnonzero((win[:n - m] == pat).all(axis=1))
            if hits.size:
                # most recent occurrence with a FULL k-token continuation
                # (the very last occurrence of a repeating run sits at the
                # end of history and would truncate the draft)
                full = hits[hits + m + k <= n]
                j = int(full[-1]) if full.size else int(hits[-1])
                cont = h[j + m:j + m + k]
                if cont.size:
                    return cont.astype(np.int32)
        return empty


class DraftModelProposer:
    """Interface stub for model-based drafting: hold a small draft LM and
    greedily roll it forward ``k`` tokens per call.  Not wired yet —
    subclass and implement :meth:`propose` (the verify side of the engine
    is proposer-agnostic, so no engine changes are needed)."""

    def __init__(self, model, params):
        self.model = model
        self.params = params

    def propose(self, history: np.ndarray, k: int) -> np.ndarray:
        raise NotImplementedError(
            "DraftModelProposer is an interface stub: subclass it and run "
            "the draft model greedily over `history`, returning up to k "
            "tokens")


PROPOSERS = {"ngram": NGramProposer}


def get_proposer(p: Union[str, Proposer, None]) -> Optional[Proposer]:
    """Resolve ``ServeConfig(speculative=...)``: None passes through, a
    name constructs the registered proposer, any object exposing
    ``propose`` is used as-is."""
    if p is None:
        return None
    if isinstance(p, str):
        try:
            return PROPOSERS[p]()
        except KeyError:
            raise ValueError(
                f"unknown proposer {p!r}; known: {sorted(PROPOSERS)} "
                f"(or pass an object with .propose(history, k))") from None
    if hasattr(p, "propose"):
        return p
    raise ValueError(
        f"speculative proposer must be a name or expose "
        f".propose(history, k); got {type(p).__name__}")
