import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input-shape x mesh) cell on the production mesh and dump
memory/cost/roofline analysis.

The two lines above MUST stay the first statements in this file — jax
locks the device count on first init.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama_11b \
        --cell train_4k [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Results land in reports/dryrun/<mesh>/<arch>__<cell>.json plus stdout.
"""
import argparse
import json
import pathlib
import traceback
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCH_IDS, get_config
from ..obs.clock import CLOCK as _clock
from ..dist.context import use_mesh
from ..dist.spmd import fit_spec as _fit_spec
from ..models.registry import get_model
from ..roofline.analysis import analyze_compiled
from ..train.step import TrainConfig, make_train_step, train_state_init
from .mesh import make_production_mesh
from .shapes import SHAPE_CELLS, cells_for_arch, input_specs

REPORT_DIR = pathlib.Path(__file__).resolve().parents[3] / "reports" / "dryrun"


# spec fitting (drop axes that don't divide the dim) lives in
# repro.dist.spmd.fit_spec now — shared with the SPMD planner
def _shardings(tree_specs, tree_sds, mesh):
    return jax.tree.map(
        lambda s, v: NamedSharding(mesh, _fit_spec(v.shape, s, mesh)),
        tree_specs, tree_sds,
        is_leaf=lambda s: isinstance(s, P))


def _batch_shardings(batch_sds, mesh):
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    out = {}
    for k, v in batch_sds.items():
        spec = P(dp) if k == "lens" else P(*((dp,) + (None,) * (len(v.shape) - 1)))
        out[k] = NamedSharding(mesh, _fit_spec(v.shape, spec, mesh))
    return out


def _model_flops(cfg, cell) -> float:
    """MODEL_FLOPS = 6·N_active·D (train: fwd+bwd; inference: 2·N·D per tok)."""
    n_act = cfg.n_active_params()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_act * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_act * tokens
    return 2.0 * n_act * cell.global_batch  # decode: 1 token per row


def lower_cell(arch_id: str, cell_name: str, *, multi_pod: bool,
               verbose: bool = True, microbatches: int = 1):
    cfg = get_config(arch_id)
    # §Perf H2 iter3: ZeRO-3 (fsdp profile) is a TRAINING layout — serving
    # it would all-gather every weight per token.  Inference cells run TP.
    if SHAPE_CELLS[cell_name].kind != "train" and \
            cfg.sharding_profile == "fsdp":
        import dataclasses
        cfg = dataclasses.replace(cfg, sharding_profile="tp")
    model = get_model(cfg)
    cell = SHAPE_CELLS[cell_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = _clock()

    with use_mesh(mesh):
        batch_sds = input_specs(cfg, cell)
        batch_sh = _batch_shardings(batch_sds, mesh)

        if cell.kind == "train":
            tcfg = TrainConfig(microbatches=microbatches)
            train_step = make_train_step(model, tcfg)
            state_sds = jax.eval_shape(
                lambda: train_state_init(model, jax.random.PRNGKey(0), tcfg))
            pspecs = model.specs()
            psh = _shardings(pspecs, state_sds.params, mesh)
            rep = NamedSharding(mesh, P())
            state_sh = type(state_sds)(
                params=psh,
                opt=type(state_sds.opt)(step=rep, mu=psh, nu=psh),
                residual=(),
            )
            jfn = jax.jit(train_step, in_shardings=(state_sh, batch_sh),
                          donate_argnums=(0,))
            lowered = jfn.lower(state_sds, batch_sds)
        elif cell.kind == "prefill":
            def prefill(params, batch):
                return model.forward(params, batch)

            params_sds = jax.eval_shape(
                lambda: model.init(jax.random.PRNGKey(0)))
            psh = _shardings(model.specs(), params_sds, mesh)
            jfn = jax.jit(prefill, in_shardings=(psh, batch_sh))
            lowered = jfn.lower(params_sds, batch_sds)
        else:  # decode
            max_len = cell.seq_len
            b = cell.global_batch

            def serve_step(params, cache, batch):
                kw = {}
                if "enc_out" in batch:
                    kw["enc_out"] = batch["enc_out"]
                return model.decode_step(params, cache, batch["tokens"],
                                         batch["lens"], **kw)

            params_sds = jax.eval_shape(
                lambda: model.init(jax.random.PRNGKey(0)))
            cache_sds = jax.eval_shape(lambda: model.init_cache(b, max_len))
            psh = _shardings(model.specs(), params_sds, mesh)
            csh = _shardings(model.cache_specs(), cache_sds, mesh)
            jfn = jax.jit(serve_step, in_shardings=(psh, csh, batch_sh),
                          donate_argnums=(1,))
            lowered = jfn.lower(params_sds, cache_sds, batch_sds)

        t_lower = _clock() - t0
        compiled = lowered.compile()
        t_compile = _clock() - t0 - t_lower

    mem = compiled.memory_analysis()
    terms = analyze_compiled(compiled, arch=arch_id, cell=cell_name,
                             mesh_name=mesh_name, chips=chips,
                             model_flops=_model_flops(cfg, cell))
    result = terms.as_dict()
    result.update({
        "lower_seconds": round(t_lower, 2),
        "compile_seconds": round(t_compile, 2),
        "memory_analysis": {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        },
        "status": "ok",
    })
    if verbose:
        print(f"[dryrun] {arch_id} x {cell_name} on {mesh_name}: "
              f"compile={t_compile:.1f}s flops={terms.hlo_flops:.3e} "
              f"bytes={terms.hlo_bytes:.3e} coll={terms.coll_bytes:.3e} "
              f"dominant={terms.dominant} "
              f"roofline_frac={terms.roofline_fraction:.3f}")
        print(f"  memory_analysis: {result['memory_analysis']}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--cell", choices=list(SHAPE_CELLS))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    if args.all:
        cells = [(a, c) for a in ARCH_IDS for c in cells_for_arch(a)]
    else:
        assert args.arch and args.cell, "--arch/--cell or --all"
        cells = [(args.arch, args.cell)]

    failures = []
    for multi_pod in meshes:
        mesh_name = "2x16x16" if multi_pod else "16x16"
        outdir = REPORT_DIR / mesh_name
        outdir.mkdir(parents=True, exist_ok=True)
        for arch_id, cell_name in cells:
            out_path = outdir / f"{arch_id}__{cell_name}.json"
            try:
                result = lower_cell(arch_id, cell_name, multi_pod=multi_pod,
                                    microbatches=args.microbatches)
            except Exception as e:  # a failure here is a bug in the system
                traceback.print_exc()
                result = {"arch": arch_id, "cell": cell_name,
                          "mesh": mesh_name, "status": "FAIL",
                          "error": repr(e)}
                failures.append((mesh_name, arch_id, cell_name, repr(e)))
            out_path.write_text(json.dumps(result, indent=2))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nAll dry-run cells compiled successfully.")


if __name__ == "__main__":
    main()
