"""Serving launcher: DISC-bucketed continuous batching.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama_11b \
        --requests 16 --reduced
    PYTHONPATH=src python -m repro.launch.serve --arch deepseek_v2_236b \
        --dry-run        # full config decode_32k: lower+compile only
"""
import argparse
import dataclasses

import jax

from ..api import ServeConfig, ServeEngine
from ..configs import ARCH_IDS, get_config
from ..obs.clock import CLOCK as _clock
from ..data.pipeline import VarLenRequestStream
from ..models.registry import get_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--dry-run", action="store_true")
    args = ap.parse_args()

    if args.dry_run:
        from .dryrun import lower_cell
        lower_cell(args.arch, "decode_32k", multi_pod=False)
        return

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(cfg.reduced(), max_seq=args.max_seq)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params,
                         ServeConfig(max_batch=args.max_batch,
                                     max_seq=args.max_seq))
    stream = VarLenRequestStream(vocab=cfg.vocab, min_len=4,
                                 max_len=args.max_seq // 2, seed=0)
    reqs = stream.sample(args.requests)
    t0 = _clock()
    engine.submit(reqs)
    done = engine.run_until_done()
    dt = _clock() - t0
    print(f"{len(done)}/{args.requests} requests in {dt:.1f}s; "
          f"{engine.stats['tokens_generated']} tokens; "
          f"prefill compiles {engine.stats['prefill_compiles']}")


if __name__ == "__main__":
    main()
