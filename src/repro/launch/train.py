"""Distributed training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama_11b \
        --steps 50 --reduced            # CPU-runnable
    PYTHONPATH=src python -m repro.launch.train --arch dbrx_132b \
        --dry-run                       # full config: lower+compile only

On a real TPU pod this process runs per host (jax.distributed.initialize)
and the same code paths execute; on CPU the full configs are compile-only
(--dry-run) and reduced configs train for real.  Features wired in:
sharded train_step (per-arch profile), microbatching, checkpoint/resume,
supervisor heartbeats, optional gradient compression.
"""
import argparse
import dataclasses
import pathlib

import jax
import numpy as np

from ..checkpoint.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from ..configs import ARCH_IDS, get_config
from ..data.pipeline import SyntheticLMStream
from ..dist.context import use_mesh
from ..ft.supervisor import Supervisor
from ..models.registry import get_model
from ..obs.clock import CLOCK as _clock
from ..train.step import TrainConfig, make_train_step, train_state_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true",
                    help="width-reduced config (CPU-runnable)")
    ap.add_argument("--dry-run", action="store_true",
                    help="lower+compile the full config, no execution")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", choices=["bf16", "topk"],
                    default=None)
    ap.add_argument("--ckpt", type=str, default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    if args.dry_run:
        from .dryrun import lower_cell
        lower_cell(args.arch, "train_4k", multi_pod=False)
        return

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(cfg.reduced(), max_seq=args.seq)
    model = get_model(cfg)
    tcfg = TrainConfig(peak_lr=1e-3, warmup=20, total_steps=args.steps,
                       microbatches=args.microbatches,
                       grad_compression=args.grad_compression)
    stream = SyntheticLMStream(vocab=cfg.vocab, batch=args.batch,
                               seq_len=args.seq, seed=0)
    sup = Supervisor(args.ckpt or "/tmp/disc_train", hosts=["host0"],
                     model_axis=1)

    state = train_state_init(model, jax.random.PRNGKey(0), tcfg)
    start = 0
    if args.ckpt and latest_step(args.ckpt) is not None:
        like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                            state)
        state, journal = restore_checkpoint(args.ckpt, like)
        start = journal.get("data_step", 0)
        print(f"resumed from step {start}")

    step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0,))
    for step in range(start, args.steps):
        t0 = _clock()
        batch = {k: jax.numpy.asarray(v)
                 for k, v in stream.batch_at(step).items()}
        if cfg.family == "encdec":
            rng = np.random.RandomState(step)
            batch["frames"] = jax.numpy.asarray(
                rng.randn(args.batch, cfg.encoder_len, cfg.d_model),
                jax.numpy.float32)
        state, metrics = step_fn(state, batch)
        dt = _clock() - t0
        sup.record_step(step, "host0", dt)
        if step % 10 == 0:
            print(f"step {step:4d}  loss {float(metrics['loss']):.4f}  "
                  f"{dt:.2f}s/step")
        if args.ckpt and step and step % args.ckpt_every == 0:
            save_checkpoint(args.ckpt, step, state,
                            journal={"data_step": step}, blocking=False)
    print(f"final loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
