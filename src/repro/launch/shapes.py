"""Assigned input-shape cells and per-arch applicability (DESIGN §4).

Shape cells (LM transformers: seq_len x global_batch):
  train_4k    : seq 4,096   batch 256  -> train_step
  prefill_32k : seq 32,768  batch 32   -> prefill (forward)
  decode_32k  : seq 32,768  batch 128  -> serve_step (1 new token, KV=seq)
  long_500k   : seq 524,288 batch 1    -> serve_step; sub-quadratic only

``long_500k`` runs only for SSM/hybrid archs (rwkv6-3b, zamba2-7b); the
8 full-attention archs skip it (recorded skip).  whisper-tiny is enc-dec:
decode cells run against its decoder with the static 1500-frame encoder
memory.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs import ARCH_IDS, get_config
from ..models.common import ArchConfig

__all__ = ["ShapeCell", "SHAPE_CELLS", "cells_for_arch", "input_specs",
           "all_cells"]


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPE_CELLS = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

_SUBQUADRATIC = {"rwkv6_3b", "zamba2_7b"}


def cells_for_arch(arch_id: str) -> List[str]:
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if arch_id in _SUBQUADRATIC:
        cells.append("long_500k")
    return cells


def all_cells() -> List[Tuple[str, str]]:
    return [(a, c) for a in ARCH_IDS for c in cells_for_arch(a)]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: ArchConfig, cell: ShapeCell) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of one cell.

    Weak-type-correct, shardable, no device allocation.  Modality frontends
    are stubs: whisper gets precomputed frame embeddings, llava gets anyres
    patch embeddings (image tokens count toward seq_len).
    """
    b, s = cell.global_batch, cell.seq_len
    act_dt = jnp.bfloat16 if cfg.dtype == "bf16" else jnp.float32
    if cell.kind in ("train", "prefill"):
        batch = {}
        s_text = s
        if cfg.family == "vlm":
            n_img = min(cfg.max_image_tokens, s // 2)
            n_img = (n_img // 576) * 576 or 576   # whole anyres tiles
            s_text = s - n_img
            batch["image_embeds"] = _sds((b, n_img, cfg.d_model), act_dt)
        if cfg.family == "encdec":
            batch["frames"] = _sds((b, cfg.encoder_len, cfg.d_model), act_dt)
        batch["tokens"] = _sds((b, s_text), jnp.int32)
        if cell.kind == "train":
            batch["labels"] = _sds((b, s_text), jnp.int32)
            batch["mask"] = _sds((b, s_text), jnp.float32)
        return batch
    # decode: one new token against a cache filled to seq_len
    batch = {"tokens": _sds((b, 1), jnp.int32),
             "lens": _sds((b,), jnp.int32)}
    if cfg.family == "encdec":
        batch["enc_out"] = _sds((b, cfg.encoder_len, cfg.d_model), act_dt)
    return batch
