"""Production mesh construction (multi-pod dry-run contract).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "MESH_AXES"]

MESH_AXES = {"single": ("data", "model"), "multi": ("pod", "data", "model")}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh.

    ``pod`` composes with ``data`` for gradient reduction (hierarchical:
    reduce-scatter intra-pod, all-reduce inter-pod is XLA's decomposition
    given the axis ordering).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — run via "
            f"launch/dryrun.py which forces XLA_FLAGS host device count")
    import numpy as np
    return jax.sharding.Mesh(np.array(devices).reshape(shape), axes)
