"""Mesh construction — general factory + production presets.

FUNCTIONS, not module-level constants — importing this module never
touches jax device state.

:func:`make_mesh` builds a mesh of any shape over any axis names (small
forced-host meshes for tests / CI / ``launch/dryrun.py``,
``XLA_FLAGS=--xla_force_host_platform_device_count=N``);
:func:`make_production_mesh` keeps the production shapes as presets on
top of it (16×16 single-pod, 2×16×16 two-pod).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax

__all__ = ["make_mesh", "make_production_mesh", "MESH_AXES",
           "PRODUCTION_SHAPES"]

MESH_AXES = {"single": ("data", "model"), "multi": ("pod", "data", "model")}

#: preset name -> (shape, axes)
PRODUCTION_SHAPES = {
    "single": ((16, 16), MESH_AXES["single"]),
    "multi": ((2, 16, 16), MESH_AXES["multi"]),
}


def make_mesh(shape: Sequence[int], axes: Sequence[str],
              devices: Optional[Sequence] = None) -> jax.sharding.Mesh:
    """A mesh of ``shape`` over ``axes`` from the first
    ``prod(shape)`` available devices (or an explicit ``devices`` list).

    No device-count floor beyond the shape itself: ``make_mesh((2, 2),
    ("data", "model"))`` works on any 4-device platform, including CPU
    hosts forced to N devices via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """
    shape = tuple(int(s) for s in shape)
    axes = tuple(axes)
    if len(shape) != len(axes):
        raise ValueError(
            f"mesh shape {shape} has {len(shape)} dims but {len(axes)} "
            f"axis names {axes}")
    n = 1
    for s in shape:
        n *= s
    devices = list(jax.devices() if devices is None else devices)[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — force "
            f"a host device count with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} (set "
            f"before jax initializes)")
    import numpy as np
    return jax.sharding.Mesh(np.array(devices).reshape(shape), axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh.

    ``pod`` composes with ``data`` for gradient reduction (hierarchical:
    reduce-scatter intra-pod, all-reduce inter-pod is XLA's decomposition
    given the axis ordering).
    """
    shape, axes = PRODUCTION_SHAPES["multi" if multi_pod else "single"]
    return make_mesh(shape, axes)
