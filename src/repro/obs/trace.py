"""Zero-dependency structured tracer with Chrome ``trace_event`` export.

Spans are emitted at the load-bearing sites of the stack — ``lower``,
per-bucket compiles (cache hit/miss annotated), the generated dispatch
entry (bucket selected, pad bytes), per-cluster kernel runs, and the
serve request lifecycle (admission → prefill → decode → retire as async
events keyed by request id).  The layer follows the same zero-overhead
discipline as ``ft/faults.py``: a module-level :data:`ACTIVE` that is
``None`` in production, so every hot site pays exactly one attribute
load and an ``is None`` test when tracing is off::

    if trace.ACTIVE is not None:
        trace.ACTIVE.instant("serve.retry", cat="serve", kind=kind)

Recorded traces export to Chrome ``trace_event`` JSON — load the file at
``ui.perfetto.dev`` or ``chrome://tracing`` (see
``docs/observability.md``).
"""
from __future__ import annotations

import json
import threading
from contextlib import contextmanager
from typing import Any, Dict, Iterator, List, Optional

from .clock import CLOCK, Clock


class _OpenSpan:
    """Handle returned by :meth:`Tracer.begin`; call :meth:`end` once."""

    __slots__ = ("tracer", "idx")

    def __init__(self, tracer: "Tracer", idx: Optional[int]):
        self.tracer = tracer
        self.idx = idx

    def end(self, **args: Any) -> None:
        self.tracer.end(self, **args)


class Tracer:
    """Collects span / instant / async / counter events in memory.

    Events are plain dicts with internal fields (``parent`` — index of
    the enclosing span on the same thread, ``depth`` — nesting level)
    that tests assert on; :meth:`chrome_trace` strips them down to the
    Chrome ``trace_event`` schema.  The buffer is capped at
    ``max_events``; overflow increments :attr:`dropped` instead of
    growing without bound.
    """

    def __init__(self, *, max_events: int = 200_000,
                 clock: Optional[Clock] = None):
        self.clock = clock or CLOCK
        self.max_events = max_events
        self.events: List[Dict[str, Any]] = []
        self.dropped = 0
        self._lock = threading.Lock()
        self._stacks: Dict[int, List[Optional[int]]] = {}
        self._t0 = self.clock()

    # ---- recording --------------------------------------------------
    def _stack(self) -> List[Optional[int]]:
        tid = threading.get_ident()
        st = self._stacks.get(tid)
        if st is None:
            st = self._stacks[tid] = []
        return st

    def _append(self, rec: Dict[str, Any]) -> Optional[int]:
        with self._lock:
            if len(self.events) >= self.max_events:
                self.dropped += 1
                return None
            self.events.append(rec)
            return len(self.events) - 1

    def begin(self, name: str, /, cat: str = "disc",
              **args: Any) -> _OpenSpan:
        """Open a nested span; close it with ``.end(**more_args)``."""
        st = self._stack()
        parent = next((i for i in reversed(st) if i is not None), -1)
        rec = {"name": name, "cat": cat, "ph": "X",
               "ts": self.clock() - self._t0, "dur": None,
               "tid": threading.get_ident(), "args": dict(args),
               "parent": parent, "depth": len(st)}
        idx = self._append(rec)
        st.append(idx)
        return _OpenSpan(self, idx)

    def end(self, span: _OpenSpan, **args: Any) -> None:
        st = self._stack()
        if st:
            st.pop()
        if span.idx is None:
            return
        rec = self.events[span.idx]
        rec["dur"] = self.clock() - self._t0 - rec["ts"]
        if args:
            rec["args"].update(args)

    @contextmanager
    def span(self, name: str, cat: str = "disc",
             **args: Any) -> Iterator[_OpenSpan]:
        sp = self.begin(name, cat, **args)
        try:
            yield sp
        finally:
            sp.end()

    def instant(self, name: str, /, cat: str = "disc", **args: Any) -> None:
        """A point event (``ph: "i"``) — retries, drains, promotions."""
        st = self._stack()
        parent = next((i for i in reversed(st) if i is not None), -1)
        self._append({"name": name, "cat": cat, "ph": "i",
                      "ts": self.clock() - self._t0,
                      "tid": threading.get_ident(), "args": dict(args),
                      "parent": parent, "depth": len(st)})

    def async_begin(self, name: str, id: Any, cat: str = "serve",
                    **args: Any) -> None:
        """Open an async span (``ph: "b"``) keyed by ``id`` — used for
        per-request serve lifecycles that outlive any one call stack."""
        self._append({"name": name, "cat": cat, "ph": "b",
                      "ts": self.clock() - self._t0, "id": str(id),
                      "tid": threading.get_ident(), "args": dict(args),
                      "parent": -1, "depth": 0})

    def async_end(self, name: str, id: Any, cat: str = "serve",
                  **args: Any) -> None:
        self._append({"name": name, "cat": cat, "ph": "e",
                      "ts": self.clock() - self._t0, "id": str(id),
                      "tid": threading.get_ident(), "args": dict(args),
                      "parent": -1, "depth": 0})

    def counter(self, name: str, values: Dict[str, float],
                cat: str = "disc") -> None:
        """A counter sample (``ph: "C"``) — renders as a track."""
        self._append({"name": name, "cat": cat, "ph": "C",
                      "ts": self.clock() - self._t0,
                      "tid": threading.get_ident(),
                      "args": {k: float(v) for k, v in values.items()},
                      "parent": -1, "depth": 0})

    # ---- inspection -------------------------------------------------
    def spans(self, name: Optional[str] = None) -> List[Dict[str, Any]]:
        """Finished duration spans, optionally filtered by name."""
        return [e for e in self.events
                if e["ph"] == "X" and e["dur"] is not None
                and (name is None or e["name"] == name)]

    def find(self, name: str) -> List[Dict[str, Any]]:
        """All events (any phase) with the given name."""
        return [e for e in self.events if e["name"] == name]

    # ---- export -----------------------------------------------------
    def chrome_trace(self) -> Dict[str, Any]:
        """The buffer as a Chrome ``trace_event`` JSON object."""
        out = []
        for e in self.events:
            ev: Dict[str, Any] = {
                "name": e["name"], "cat": e["cat"], "ph": e["ph"],
                "ts": round(e["ts"] * 1e6, 3), "pid": 1, "tid": e["tid"],
                "args": e["args"],
            }
            if e["ph"] == "X":
                ev["dur"] = round((e["dur"] or 0.0) * 1e6, 3)
            elif e["ph"] == "i":
                ev["s"] = "t"
            elif e["ph"] in ("b", "e"):
                ev["id"] = e["id"]
            out.append(ev)
        return {"traceEvents": out, "displayTimeUnit": "ms",
                "otherData": {"dropped": self.dropped}}

    def export_chrome_trace(self, path) -> str:
        """Write the Chrome trace JSON to ``path``; returns the path."""
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f, indent=1)
            f.write("\n")
        return str(path)


#: The installed tracer, or ``None`` (production).  Hot sites guard on
#: ``trace.ACTIVE is not None`` — the whole layer is a no-op when unset.
ACTIVE: Optional[Tracer] = None


def install(tracer: Optional[Tracer] = None) -> Tracer:
    """Install (and return) a tracer as the process-wide :data:`ACTIVE`."""
    global ACTIVE
    ACTIVE = tracer if tracer is not None else Tracer()
    return ACTIVE


def clear() -> None:
    """Uninstall the active tracer; hot paths revert to no-ops."""
    global ACTIVE
    ACTIVE = None


@contextmanager
def tracing(tracer: Optional[Tracer] = None) -> Iterator[Tracer]:
    """Scoped tracing: installs a tracer, restores the previous state."""
    global ACTIVE
    prev = ACTIVE
    t = install(tracer)
    try:
        yield t
    finally:
        ACTIVE = prev
