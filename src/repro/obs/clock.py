"""One injectable monotonic clock for every timing site in the repo.

Timings used to be a mix of ``time.time()`` (wall clock — jumps on NTP
adjust) and ``time.monotonic()``/``time.perf_counter()`` sprinkled per
call site.  Everything now reads through :data:`CLOCK`, a module-level
:class:`Clock` whose source defaults to ``time.perf_counter`` and can be
swapped for a fake in tests (``CLOCK.set_source(lambda: t[0])``) or
scoped with :meth:`Clock.fixed`.

``obs`` imports nothing from the rest of ``repro`` — instrumentation
flows inward only (enforced by ``scripts/import_lint.py``).
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Callable, Optional


class Clock:
    """A callable monotonic clock with a swappable source.

    Calling the instance returns seconds from an arbitrary origin
    (``time.perf_counter`` by default), so deltas are meaningful and
    immune to wall-clock adjustments.
    """

    __slots__ = ("_source",)

    def __init__(self, source: Optional[Callable[[], float]] = None):
        self._source: Callable[[], float] = source or time.perf_counter

    def __call__(self) -> float:
        return self._source()

    def set_source(self, source: Optional[Callable[[], float]] = None) -> None:
        """Swap the time source; ``None`` restores ``time.perf_counter``."""
        self._source = source or time.perf_counter

    @contextmanager
    def fixed(self, source: Callable[[], float]):
        """Scoped source swap (tests drive time deterministically)."""
        prev = self._source
        self._source = source
        try:
            yield self
        finally:
            self._source = prev


#: The process-wide clock every instrumented site reads.
CLOCK = Clock()


def now() -> float:
    """Seconds on the shared monotonic clock (module-level shorthand)."""
    return CLOCK()
