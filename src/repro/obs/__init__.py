"""``repro.obs`` — the unified observability plane.

Three pieces, all zero-dependency and importable from anywhere in the
stack (``obs`` itself imports nothing from the rest of ``repro``):

* :mod:`repro.obs.clock` — one injectable monotonic clock (``CLOCK``).
* :mod:`repro.obs.trace` — structured nested spans with Chrome
  ``trace_event`` export; compiled to no-ops when no tracer is
  installed (``trace.ACTIVE is None``).
* :mod:`repro.obs.metrics` — one registry of counters / gauges /
  histograms plus weakly-referenced pull collectors, absorbing the
  scattered stats surfaces behind ``disc.observe()``.

The public handle is :data:`observe`::

    import disc

    snap = disc.observe()                    # one registry snapshot
    with disc.observe.trace():               # record spans...
        fast(x)
    disc.observe.export_chrome_trace("trace.json")   # ...for Perfetto
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Any, Dict, Iterator, Optional

from . import clock, metrics, trace  # noqa: F401
from .clock import CLOCK, Clock  # noqa: F401
from .metrics import MetricsRegistry  # noqa: F401
from .trace import Tracer  # noqa: F401


class Observe:
    """``disc.observe`` — callable snapshot plus trace controls."""

    def __call__(self) -> Dict[str, Any]:
        """One snapshot of the live metrics registry (all domains)."""
        return metrics.snapshot()

    # ---- tracing controls -------------------------------------------
    def start_trace(self, **kwargs: Any) -> Tracer:
        """Install (and return) a process-wide tracer."""
        return trace.install(Tracer(**kwargs))

    def stop_trace(self) -> Optional[Tracer]:
        """Uninstall the active tracer and return it (spans intact)."""
        t = trace.ACTIVE
        trace.clear()
        return t

    @contextmanager
    def trace(self, tracer: Optional[Tracer] = None) -> Iterator[Tracer]:
        """Scoped tracing — ``with disc.observe.trace() as t: ...``."""
        with trace.tracing(tracer) as t:
            yield t

    @property
    def tracer(self) -> Optional[Tracer]:
        return trace.ACTIVE

    def export_chrome_trace(self, path) -> str:
        """Export the active tracer's buffer as Chrome ``trace_event``
        JSON (loadable at ``ui.perfetto.dev``)."""
        t = trace.ACTIVE
        if t is None:
            raise RuntimeError(
                "no active tracer: call disc.observe.start_trace() (or use "
                "disc.observe.trace()) around the code to record first")
        return t.export_chrome_trace(path)


#: The public observability handle, re-exported as ``disc.observe``.
observe = Observe()

__all__ = ["observe", "Observe", "Tracer", "MetricsRegistry", "Clock",
           "CLOCK", "clock", "metrics", "trace"]
