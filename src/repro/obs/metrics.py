"""One metrics registry for the whole stack.

Before this layer, runtime numbers lived on five scattered surfaces:
``ServeEngine.stats`` / ``STATS_KEYS``, ``CompileCache.stats``, the VM's
``interp_seconds``, the dispatcher's ``mem_launch_*`` staging stats, and
per-replica health counters.  Each of those still exists as a thin view
(nothing broke), but they all also publish into this registry, so
``disc.observe()`` returns one snapshot covering compile, dispatch,
memory, serve, and health.

Two mechanisms:

* **Instruments** — :class:`Counter` / :class:`Gauge` / :class:`Histogram`
  with labeled series, for code that wants to push values directly.
* **Collectors** — pull-based providers registered per ``(domain, name)``
  (e.g. ``("compile", "serve")`` for the serve engine's compile cache).
  Collectors are held by weak reference, so instrumented objects keep
  their normal lifetime; dead collectors silently drop out of the
  snapshot.  Re-registering a key overwrites it — latest live object
  wins, which is what singleton domains (``serve``, ``health``, ``vm``)
  want.

A bounded **timeline** records lifecycle events (bucket compiles,
escalations and their failures, promotions, backend/kernel demotions,
replica drains) with timestamps from the shared ``obs`` clock.
"""
from __future__ import annotations

import weakref
from collections import deque
from typing import Any, Callable, Deque, Dict, Optional, Tuple

from . import trace
from .clock import CLOCK, Clock

#: Snapshot sections that are always present, collectors or not.
DOMAINS = ("compile", "dispatch", "memory", "serve", "health", "vm")


class Counter:
    """Monotonically increasing count."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-set value."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v


class Histogram:
    """Scalar distribution: count / total / min / max summary."""

    __slots__ = ("count", "total", "vmin", "vmax")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.vmin: Optional[float] = None
        self.vmax: Optional[float] = None

    def observe(self, v: float) -> None:
        self.count += 1
        self.total += v
        self.vmin = v if self.vmin is None else min(self.vmin, v)
        self.vmax = v if self.vmax is None else max(self.vmax, v)

    def as_dict(self) -> Dict[str, Any]:
        return {"count": self.count, "total": self.total,
                "min": self.vmin, "max": self.vmax,
                "mean": self.total / self.count if self.count else None}


def _series_key(name: str, labels: Dict[str, Any]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Instruments + weakly-referenced collectors + a lifecycle timeline."""

    def __init__(self, *, clock: Optional[Clock] = None,
                 timeline_maxlen: int = 512):
        self.clock = clock or CLOCK
        self._series: Dict[Tuple[str, str], Any] = {}
        self._collectors: Dict[Tuple[str, Optional[str]], Any] = {}
        self.timeline: Deque[Dict[str, Any]] = deque(maxlen=timeline_maxlen)

    # ---- instruments ------------------------------------------------
    def _instrument(self, kind, cls, name: str, labels: Dict[str, Any]):
        key = (kind, _series_key(name, labels))
        inst = self._series.get(key)
        if inst is None:
            inst = self._series[key] = cls()
        return inst

    def counter(self, name: str, **labels: Any) -> Counter:
        return self._instrument("counter", Counter, name, labels)

    def gauge(self, name: str, **labels: Any) -> Gauge:
        return self._instrument("gauge", Gauge, name, labels)

    def histogram(self, name: str, **labels: Any) -> Histogram:
        return self._instrument("histogram", Histogram, name, labels)

    # ---- timeline ---------------------------------------------------
    def event(self, kind: str, /, **attrs: Any) -> None:
        """Record a lifecycle event; mirrored to the active tracer as an
        instant so timelines and traces stay aligned.  The event name is
        positional-only so ``attrs`` may themselves contain ``kind``."""
        self.timeline.append({"t": self.clock(), "event": kind, **attrs})
        if trace.ACTIVE is not None:
            trace.ACTIVE.instant(kind, cat="lifecycle", **attrs)

    # ---- collectors -------------------------------------------------
    def register_collector(self, domain: str, fn: Callable[[], Dict],
                           name: Optional[str] = None) -> None:
        """Register a pull-based provider for ``snapshot()[domain]``.

        ``fn`` must be a bound method of the instrumented object — it is
        held via ``weakref.WeakMethod`` so registration never extends
        the object's lifetime.
        """
        self._collectors[(domain, name)] = weakref.WeakMethod(fn)

    # ---- snapshot ---------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {d: {} for d in DOMAINS}
        dead = []
        for (domain, name), ref in self._collectors.items():
            fn = ref()
            if fn is None:
                dead.append((domain, name))
                continue
            collected = fn()
            if name is None:
                out[domain] = collected
            else:
                out.setdefault(domain, {})[name] = collected
        for k in dead:
            del self._collectors[k]
        out["counters"] = {k: v.value for (kind, k), v in
                           sorted(self._series.items()) if kind == "counter"}
        out["gauges"] = {k: v.value for (kind, k), v in
                        sorted(self._series.items()) if kind == "gauge"}
        out["histograms"] = {k: v.as_dict() for (kind, k), v in
                             sorted(self._series.items())
                             if kind == "histogram"}
        out["timeline"] = list(self.timeline)
        tr = trace.ACTIVE
        out["trace"] = {"enabled": tr is not None,
                        "events": len(tr.events) if tr is not None else 0,
                        "dropped": tr.dropped if tr is not None else 0}
        return out

    def reset(self) -> None:
        """Drop instruments and the timeline (collectors stay)."""
        self._series.clear()
        self.timeline.clear()


#: The process-wide registry.  Instrumented code reaches it through the
#: module-level helpers below, so tests and docs captures can swap in a
#: fresh registry by rebinding ``metrics.REGISTRY``.
REGISTRY = MetricsRegistry()


def register_collector(domain: str, fn: Callable[[], Dict],
                       name: Optional[str] = None) -> None:
    REGISTRY.register_collector(domain, fn, name)


def record_event(kind: str, /, **attrs: Any) -> None:
    REGISTRY.event(kind, **attrs)


def snapshot() -> Dict[str, Any]:
    return REGISTRY.snapshot()
