from .ops import mamba2_scan  # noqa: F401
