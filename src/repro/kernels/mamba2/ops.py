"""jit wrapper for the Mamba-2 SSD scan with chunk version selection."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .mamba2 import mamba2_kernel

CHUNK_VERSIONS = (16, 64, 128)


def mamba2_scan(x, a, b, c, *, interpret: bool = True) -> jax.Array:
    t = x.shape[2]
    fits = [ck for ck in CHUNK_VERSIONS if t % ck == 0]
    if fits:
        return mamba2_kernel(x, a, b, c, chunk=max(fits), interpret=interpret)
    ck = CHUNK_VERSIONS[0]
    pad = (-t) % ck
    pads = ((0, 0), (0, 0), (0, pad), (0, 0))
    out = mamba2_kernel(
        jnp.pad(x, pads),
        jnp.pad(a, pads, constant_values=1.0),  # identity decay in padding
        jnp.pad(b, pads), jnp.pad(c, pads),
        chunk=ck, interpret=interpret)
    return out[:, :, :t]
