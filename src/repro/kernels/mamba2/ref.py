"""Pure-jnp oracle for the Mamba-2 SSD scan (sequential form)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mamba2_ref(x, a, b, c):
    """x: (B,H,T,P); a: (B,H,T,1); b,c: (B,H,T,N)."""
    n = b.shape[-1]
    p = x.shape[-1]

    def scan_head(x_h, a_h, b_h, c_h):
        def step(h, inp):
            xt, at, bt, ct = inp
            h = at * h + jnp.outer(bt, xt)
            return h, ct @ h
        h0 = jnp.zeros((n, p), jnp.float32)
        _, ys = jax.lax.scan(step, h0, (x_h.astype(jnp.float32),
                                        a_h.astype(jnp.float32),
                                        b_h.astype(jnp.float32),
                                        c_h.astype(jnp.float32)))
        return ys

    out = jax.vmap(jax.vmap(scan_head))(x, a, b, c)
    return out.astype(x.dtype)
