"""Mamba-2 SSD (state-space dual) chunked scan kernel.

Per head with state h ∈ R^{N×P} (N = ssm state dim, P = head dim):

    h_t = a_t · h_{t-1} + b_t x_tᵀ        (a_t ∈ (0,1) scalar per head)
    y_t = c_tᵀ h_t

TPU schedule mirrors the SSD paper's chunking: grid (B, H, T/chunk) with
the f32 state in VMEM scratch persisting across sequential chunks.  Inside
a chunk, the intra-chunk part is computed in *parallel* form —
``y_intra = (L ⊙ (C Bᵀ)) X`` with L the causal decay-product mask — and
the inter-chunk part flows through the carried state.  This keeps MXU
matmuls dense (chunk × chunk) instead of a length-T serial loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["mamba2_kernel"]


def _body(x_ref, a_ref, b_ref, c_ref, o_ref, h_scr, *, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, 0].astype(jnp.float32)   # (chunk, P)
    a = a_ref[0, 0].astype(jnp.float32)   # (chunk, 1) decay in (0,1)
    bmat = b_ref[0, 0].astype(jnp.float32)  # (chunk, N)
    cmat = c_ref[0, 0].astype(jnp.float32)  # (chunk, N)

    # cumulative decay products within the chunk: g_t = prod_{s<=t} a_s
    log_a = jnp.log(jnp.maximum(a, 1e-37))            # (chunk, 1)
    cum = jnp.cumsum(log_a, axis=0)                    # (chunk, 1)
    g = jnp.exp(cum)                                   # (chunk, 1)

    # inter-chunk: y_inter[t] = g_t * (c_t · h_prev)
    h_prev = h_scr[...]                                # (N, P)
    y_inter = g * (cmat @ h_prev)                      # (chunk, P)

    # intra-chunk parallel form: L[t,s] = prod_{s<r<=t} a_r for s<=t
    # L[t,s] = g_t / g_s * a_s^{-1} ... using g shifted: decay from s to t
    # exclusive of a_s (state update applies a_t before adding b_t x_t? --
    # with h_t = a_t h_{t-1} + b_t x_t, contribution of s to t is
    # (prod_{r=s+1..t} a_r) * c_t·b_s * x_s, and s=t term is c_t·b_t x_t.
    ratio = jnp.exp(cum - cum.T)                       # (chunk, chunk): g_t/g_s
    t_idx = jax.lax.broadcasted_iota(jnp.int32, ratio.shape, 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, ratio.shape, 1)
    l_mask = jnp.where(t_idx >= s_idx, ratio, 0.0)     # causal decay mask
    scores = (cmat @ bmat.T) * l_mask                  # (chunk, chunk)
    y_intra = scores @ x                               # (chunk, P)

    o_ref[0, 0] = (y_inter + y_intra).astype(o_ref.dtype)

    # state carry: h_new = (prod a) h_prev + sum_s (prod_{r>s} a_r) b_s x_sT
    decay_to_end = jnp.exp(cum[-1] - cum)              # (chunk, 1)
    h_new = g[-1] * h_prev + (bmat * decay_to_end).T @ x  # (N, P)
    h_scr[...] = h_new


def mamba2_kernel(x, a, b, c, *, chunk: int = 16,
                  interpret: bool = True) -> jax.Array:
    """x: (B,H,T,P); a: (B,H,T,1); b,c: (B,H,T,N).  Returns (B,H,T,P)."""
    bsz, h, t, p = x.shape
    n = b.shape[-1]
    assert t % chunk == 0, (t, chunk)
    grid = (bsz, h, t // chunk)
    spec_x = pl.BlockSpec((1, 1, chunk, p), lambda b_, h_, ic: (b_, h_, ic, 0))
    spec_a = pl.BlockSpec((1, 1, chunk, 1), lambda b_, h_, ic: (b_, h_, ic, 0))
    spec_bn = pl.BlockSpec((1, 1, chunk, n), lambda b_, h_, ic: (b_, h_, ic, 0))
    return pl.pallas_call(
        functools.partial(_body, chunk=chunk),
        grid=grid,
        in_specs=[spec_x, spec_a, spec_bn, spec_bn],
        out_specs=spec_x,
        out_shape=jax.ShapeDtypeStruct((bsz, h, t, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, a, b, c)
