"""jit wrapper + shape-adaptive version selection — DISC §4.3.

    "we generate different versions of kernels, and generate selection
     logic from host-side to launch a proper kernel at runtime for each
     incoming shape."

Versions differ in VMEM block size (launch dimensions / vectorization
granularity).  ``select_version`` is the generated host-side selection
logic: biggest block that divides the padded size, preferring larger
blocks for fewer grid steps while keeping ≥4 grid steps for pipelining
when the array is large.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, List, Sequence

import jax
import jax.numpy as jnp

from .fused_elementwise import fused_elementwise_kernel

# block-size versions (elements): multiples of the 8x128 f32 TPU tile
VERSIONS = (1024, 4096, 16384, 65536)


def select_version(total_padded: int) -> int:
    candidates = [b for b in VERSIONS if total_padded % b == 0]
    if not candidates:
        return 0  # no aligned version: caller pads or falls back to XLA
    # prefer the largest block that still leaves ≥4 grid steps (pipelining),
    # else the largest divisor
    pipelined = [b for b in candidates if total_padded // b >= 4]
    return max(pipelined) if pipelined else max(candidates)


def fused_elementwise(expr: Callable, inputs: Sequence[jax.Array], n_valid,
                      out_dtypes: Sequence = None, *,
                      interpret: bool = True) -> List[jax.Array]:
    """Flatten inputs, pick a kernel version, run the fused cluster."""
    shape = inputs[0].shape
    flat = [jnp.ravel(x) for x in inputs]
    total = flat[0].shape[0]
    if out_dtypes is None:
        out_dtypes = [inputs[0].dtype]
    block = select_version(total)
    if block == 0:
        # unaligned fallback: pad to the smallest version boundary
        b = VERSIONS[0]
        pad = (-total) % b
        flat = [jnp.pad(x, (0, pad)) for x in flat]
        block = select_version(total + pad)
        outs = fused_elementwise_kernel(expr, flat, n_valid, out_dtypes,
                                        block=block, interpret=interpret)
        return [o[:total].reshape(shape) for o in outs]
    outs = fused_elementwise_kernel(expr, flat, n_valid, out_dtypes,
                                    block=block, interpret=interpret)
    return [o.reshape(shape) for o in outs]
