from .ops import fused_elementwise  # noqa: F401
