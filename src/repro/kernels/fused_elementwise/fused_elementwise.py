"""Shape-adaptive fused elementwise kernel — DISC §4.3 kLoop codegen.

One Pallas kernel executes an entire kLoop fusion cluster (an arbitrary
elementwise expression DAG) over the flattened element domain:

* the *expression program* is a Python closure built from the fusion
  cluster at compile time — it is unrolled into the kernel body during
  tracing, so there is zero runtime interpretation (the paper's
  "compile-time generated" property); a multi-output closure (a cluster
  with several live-outs) stores every result ref from the same launch,
  so multi-consumer clusters never split;
* the actual element count arrives as a **scalar-prefetch operand**; the
  padded tail of the bucket is masked on store, so one compiled kernel is
  exact for every runtime size ≤ bucket;
* VMEM tiling: 1-D blocks of ``block`` elements (multiples of 1024 =
  8 sublanes × 128 lanes, the float32 TPU tile).  ``ops.py`` selects the
  block version per runtime shape — the paper's shape-adaptive fusion
  configuration (launch-dimension selection + vectorized load/store).
"""
from __future__ import annotations

from typing import Callable, List, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["fused_elementwise_kernel"]


def _kernel_body(expr: Callable, n_in: int, n_out: int):
    def body(len_ref, *refs):
        in_refs = refs[:n_in]
        out_refs = refs[n_in:n_in + n_out]
        i = pl.program_id(0)
        block = out_refs[0].shape[0]
        xs = [r[...] for r in in_refs]
        ys = expr(*xs)
        if not isinstance(ys, (tuple, list)):
            ys = (ys,)
        n_valid = len_ref[0]
        idx = jax.lax.broadcasted_iota(jnp.int32, (block,), 0) + i * block
        mask = idx < n_valid
        for r, y in zip(out_refs, ys):
            r[...] = jnp.where(mask, y, jnp.zeros_like(y))

    return body


def fused_elementwise_kernel(
    expr: Callable,
    inputs: Sequence[jax.Array],
    n_valid: jax.Array,
    out_dtypes: Sequence,
    *,
    block: int = 1024,
    interpret: bool = True,
) -> List[jax.Array]:
    """Run ``expr`` (an unrolled fusion cluster) over flattened inputs.

    All inputs must share one flattened padded length divisible by
    ``block``; ``n_valid`` (i32 scalar) marks the exact element count.
    """
    total = inputs[0].shape[0]
    assert all(x.shape == (total,) for x in inputs), "flatten + equal sizes"
    assert total % block == 0, (total, block)
    n_in, n_out = len(inputs), len(out_dtypes)
    grid = (total // block,)
    spec = pl.BlockSpec((block,), lambda i, s: (i,))
    return pl.pallas_call(
        _kernel_body(expr, n_in, n_out),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[spec] * n_in,
            out_specs=[spec] * n_out,
        ),
        out_shape=[jax.ShapeDtypeStruct((total,), dt) for dt in out_dtypes],
        interpret=interpret,
    )(jnp.asarray(n_valid, jnp.int32).reshape(1), *inputs)
