"""Pure-jnp oracle for the fused elementwise kernel."""
from __future__ import annotations

from typing import Callable, List, Sequence

import jax
import jax.numpy as jnp


def fused_elementwise_ref(expr: Callable, inputs: Sequence[jax.Array],
                          n_valid, out_dtypes: Sequence) -> List[jax.Array]:
    ys = expr(*inputs)
    if not isinstance(ys, (tuple, list)):
        ys = (ys,)
    total = inputs[0].shape[0]
    mask = jnp.arange(total) < n_valid
    return [jnp.where(mask, y, jnp.zeros_like(y)).astype(dt)
            for y, dt in zip(ys, out_dtypes)]
