"""jit wrapper for fused LayerNorm."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layernorm import layernorm_kernel

ROW_VERSIONS = (8, 64, 256)
_VMEM_BUDGET = 4 * 1024 * 1024


def layernorm(x: jax.Array, g: jax.Array, b: jax.Array, *, eps: float = 1e-5,
              interpret: bool = True) -> jax.Array:
    lead = x.shape[:-1]
    d = x.shape[-1]
    flat = x.reshape(-1, d)
    r = flat.shape[0]
    item = jnp.dtype(x.dtype).itemsize
    fits = [v for v in ROW_VERSIONS
            if r % v == 0 and v * d * item <= _VMEM_BUDGET]
    if fits:
        out = layernorm_kernel(flat, g, b, eps=eps, block_r=max(fits),
                               interpret=interpret)
    else:
        v = ROW_VERSIONS[0]
        pad = (-r) % v
        out = layernorm_kernel(jnp.pad(flat, ((0, pad), (0, 0))), g, b,
                               eps=eps, block_r=v, interpret=interpret)[:r]
    return out.reshape(*lead, d)
