"""Fused LayerNorm kernel (row-blocked, single VMEM pass, f32 accumulation)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["layernorm_kernel"]


def _body(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (block_r, D)
    g = g_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    mu = jnp.mean(x, axis=1, keepdims=True)
    xc = x - mu
    var = jnp.mean(xc * xc, axis=1, keepdims=True)
    y = xc * jax.lax.rsqrt(var + eps) * g + b
    o_ref[...] = y.astype(o_ref.dtype)


def layernorm_kernel(x: jax.Array, g: jax.Array, b: jax.Array, *,
                     eps: float = 1e-5, block_r: int = 8,
                     interpret: bool = True) -> jax.Array:
    r, d = x.shape
    assert r % block_r == 0, (r, block_r)
    return pl.pallas_call(
        functools.partial(_body, eps=eps),
        grid=(r // block_r,),
        in_specs=[
            pl.BlockSpec((block_r, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        interpret=interpret,
    )(x, g.reshape(1, d), b.reshape(1, d))
