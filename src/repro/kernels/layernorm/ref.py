"""Pure-jnp oracle for fused LayerNorm."""
import jax.numpy as jnp


def layernorm_ref(x, g, b, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    xc = xf - mu
    var = jnp.mean(xc * xc, axis=-1, keepdims=True)
    y = xc / jnp.sqrt(var + eps) * g.astype(jnp.float32) + b.astype(jnp.float32)
    return y.astype(x.dtype)
