from .ops import layernorm  # noqa: F401
