"""Pure-jnp oracle for masked softmax."""
import jax.numpy as jnp


def masked_softmax_ref(x, n_valid):
    r, c = x.shape
    mask = jnp.arange(c)[None, :] < n_valid
    xm = jnp.where(mask, x, -jnp.inf)
    m = jnp.max(xm, axis=1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    e = jnp.where(mask, jnp.exp(xm - m), 0.0)
    s = jnp.sum(e, axis=1, keepdims=True)
    s = jnp.where(s == 0, 1.0, s)
    return e / s
