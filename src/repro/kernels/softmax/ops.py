"""jit wrapper for masked softmax with row-block version selection."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .softmax import masked_softmax_kernel

ROW_VERSIONS = (8, 64, 256)
_VMEM_BUDGET = 4 * 1024 * 1024


def masked_softmax(x: jax.Array, n_valid, *, interpret: bool = True):
    """Softmax over the last axis with dynamic valid length (leading dims
    flattened into rows)."""
    lead = x.shape[:-1]
    c = x.shape[-1]
    flat = x.reshape(-1, c)
    r = flat.shape[0]
    item = jnp.dtype(x.dtype).itemsize
    fits = [b for b in ROW_VERSIONS
            if r % b == 0 and b * c * item <= _VMEM_BUDGET]
    if fits:
        out = masked_softmax_kernel(flat, n_valid, block_r=max(fits),
                                    interpret=interpret)
    else:
        b = ROW_VERSIONS[0]
        pad = (-r) % b
        out = masked_softmax_kernel(jnp.pad(flat, ((0, pad), (0, 0))),
                                    n_valid, block_r=b, interpret=interpret)
        out = out[:r]
    return out.reshape(*lead, c)
