"""Masked row-softmax kernel — the canonical memory-bound fusion pattern.

XLA emits softmax as reduce→broadcast→elementwise→reduce→broadcast→div
(5+ HBM round-trips when unfused); this kernel does one VMEM-resident pass
per row block.  The valid row length arrives via scalar prefetch so a
single bucket-compiled artifact serves every sequence length ≤ bucket —
padded columns get probability exactly 0.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["masked_softmax_kernel"]


def _body(len_ref, x_ref, o_ref):
    x = x_ref[...]  # (block_r, C)
    c = x.shape[1]
    n = len_ref[0]
    col = jax.lax.broadcasted_iota(jnp.int32, (1, c), 1)
    valid = col < n
    neg = jnp.asarray(-jnp.inf, x.dtype)
    xm = jnp.where(valid, x, neg)
    m = jnp.max(xm, axis=1, keepdims=True)
    # rows fully out of range: keep m finite to avoid nan from (-inf - -inf)
    m = jnp.where(jnp.isfinite(m), m, jnp.zeros_like(m))
    e = jnp.exp(xm - m)
    e = jnp.where(valid, e, jnp.zeros_like(e))
    s = jnp.sum(e, axis=1, keepdims=True)
    s = jnp.where(s == 0, jnp.ones_like(s), s)
    o_ref[...] = e / s


def masked_softmax_kernel(x: jax.Array, n_valid, *, block_r: int = 8,
                          interpret: bool = True) -> jax.Array:
    """Softmax over axis 1 of (R, C) with valid length ``n_valid``."""
    r, c = x.shape
    assert r % block_r == 0, (r, block_r)
    spec = pl.BlockSpec((block_r, c), lambda i, s: (i, 0))
    return pl.pallas_call(
        _body,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(r // block_r,),
            in_specs=[spec],
            out_specs=spec,
        ),
        out_shape=jax.ShapeDtypeStruct((r, c), x.dtype),
        interpret=interpret,
    )(jnp.asarray(n_valid, jnp.int32).reshape(1), x)
