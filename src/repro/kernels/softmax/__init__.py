from .ops import masked_softmax  # noqa: F401
