"""Blocked MXU matmul — the static-shape kernel library body (DISC §4.5).

    "we implement an interface to choose the best kernel from a library
     according to different runtime shapes.  The library contains both
     vendor libraries ... and pre-generated kernels that has been
     hand-tuned for each shape."

This file is the *pre-generated kernel*: a classic 3-level blocked GEMM
(grid (M/bm, N/bn, K/bk), f32 VMEM accumulator persisting across the
sequential K dimension, MXU-aligned 128-multiple blocks).  ``ops.py``
holds the library: a version table of hand-picked block shapes plus the
runtime-shape selection interface; the "vendor library" entry is XLA's
native dot (jnp.dot).

:func:`matmul_epilogue_kernel` is the kDot variant (DISC §4.3 epilogue
fusion): the same blocked GEMM, but with an *elementwise epilogue*
closure (bias add / activation / residual, unrolled from the fusion
cluster at trace time) applied to the accumulator tile at the final K
step, writing N output refs.  The actual M/N/K sizes arrive as a
scalar-prefetch operand: the K tail of each accumulation step is masked
to zero (padded-bucket garbage must not enter the contraction) and the
M/N tails are masked on store, so one compiled kernel is exact for every
runtime shape ≤ its bucket.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["matmul_kernel", "matmul_epilogue_kernel"]


def _body(a_ref, b_ref, o_ref, acc_ref):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...].astype(jnp.float32), b_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_kernel(a: jax.Array, b: jax.Array, *, block_m: int = 128,
                  block_k: int = 128, block_n: int = 128,
                  interpret: bool = True) -> jax.Array:
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    assert m % block_m == 0 and k % block_k == 0 and n % block_n == 0
    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        _body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(a, b)


def _fused_body(epilogue, n_extra: int, n_out: int, acc_dtype):
    def body(lens_ref, a_ref, b_ref, *rest):
        extra_refs = rest[:n_extra]
        out_refs = rest[n_extra:n_extra + n_out]
        acc_ref = rest[-1]
        # grid coordinates read at body top level: inside a pl.when branch
        # (a traced cond) the interpreter has no grid context for them
        im, jn, ik = pl.program_id(0), pl.program_id(1), pl.program_id(2)
        nk = pl.num_programs(2)

        @pl.when(ik == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        a = a_ref[...].astype(jnp.float32)
        bk = a.shape[1]
        kcol = jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1) + ik * bk
        a = jnp.where(kcol < lens_ref[2], a, 0.0)  # masked K tail
        acc_ref[...] += jax.lax.dot_general(
            a, b_ref[...].astype(jnp.float32),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

        @pl.when(ik == nk - 1)
        def _store():
            bm, bn = acc_ref.shape
            row = (jax.lax.broadcasted_iota(jnp.int32, (bm, 1), 0)
                   + im * bm)
            col = (jax.lax.broadcasted_iota(jnp.int32, (1, bn), 1)
                   + jn * bn)
            mask = (row < lens_ref[0]) & (col < lens_ref[1])  # M/N tails
            ys = epilogue(acc_ref[...].astype(acc_dtype),
                          *[r[...] for r in extra_refs])
            if not isinstance(ys, (tuple, list)):
                ys = (ys,)
            for r, y in zip(out_refs, ys):
                r[...] = jnp.where(mask, y, jnp.zeros_like(y)).astype(r.dtype)

    return body


def matmul_epilogue_kernel(a, b, extras, epilogue, valid_mnk, out_dtypes,
                           *, acc_dtype=jnp.float32, block_m: int = 128,
                           block_k: int = 128, block_n: int = 128,
                           interpret: bool = True):
    """Blocked GEMM with a fused elementwise epilogue and masked tails.

    ``extras`` are (M, N) operands the epilogue consumes alongside the
    accumulator (pre-broadcast residual/bias terms); ``valid_mnk`` is the
    i32 triple of actual sizes (scalar-prefetched).  Returns one (M, N)
    array per entry of ``out_dtypes`` — a multi-output epilogue stores
    every cluster live-out from one launch.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    assert m % block_m == 0 and k % block_k == 0 and n % block_n == 0
    assert all(x.shape == (m, n) for x in extras), "extras must be (M, N)"
    grid = (m // block_m, n // block_n, k // block_k)
    mn_spec = pl.BlockSpec((block_m, block_n), lambda i, j, kk, s: (i, j))
    return pl.pallas_call(
        _fused_body(epilogue, len(extras), len(out_dtypes), acc_dtype),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, block_k), lambda i, j, kk, s: (i, kk)),
                pl.BlockSpec((block_k, block_n), lambda i, j, kk, s: (kk, j)),
            ] + [mn_spec] * len(extras),
            out_specs=[mn_spec] * len(out_dtypes),
            scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        ),
        out_shape=[jax.ShapeDtypeStruct((m, n), dt) for dt in out_dtypes],
        interpret=interpret,
    )(jnp.asarray(jnp.stack([jnp.asarray(v, jnp.int32) for v in valid_mnk])),
      a, b, *extras)
