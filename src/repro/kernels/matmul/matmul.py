"""Blocked MXU matmul — the static-shape kernel library body (DISC §4.5).

    "we implement an interface to choose the best kernel from a library
     according to different runtime shapes.  The library contains both
     vendor libraries ... and pre-generated kernels that has been
     hand-tuned for each shape."

This file is the *pre-generated kernel*: a classic 3-level blocked GEMM
(grid (M/bm, N/bn, K/bk), f32 VMEM accumulator persisting across the
sequential K dimension, MXU-aligned 128-multiple blocks).  ``ops.py``
holds the library: a version table of hand-picked block shapes plus the
runtime-shape selection interface; the "vendor library" entry is XLA's
native dot (jnp.dot).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["matmul_kernel"]


def _body(a_ref, b_ref, o_ref, acc_ref):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...].astype(jnp.float32), b_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_kernel(a: jax.Array, b: jax.Array, *, block_m: int = 128,
                  block_k: int = 128, block_n: int = 128,
                  interpret: bool = True) -> jax.Array:
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    assert m % block_m == 0 and k % block_k == 0 and n % block_n == 0
    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        _body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(a, b)
