from .ops import matmul, select_gemm_version, GEMM_LIBRARY  # noqa: F401
