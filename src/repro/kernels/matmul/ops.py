"""The static-shape kernel library + runtime selection — DISC §4.5.

``GEMM_LIBRARY`` maps a named version to block shapes "hand-tuned" for a
shape regime; :func:`select_gemm_version` is the runtime-shape selection
interface.  Unaligned/small shapes route to the vendor entry (XLA dot) —
exactly the paper's vendor-library/pre-generated-kernel mix.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .matmul import matmul_kernel

# name -> (block_m, block_k, block_n): tuned per shape regime
GEMM_LIBRARY = {
    "square_big": (256, 128, 256),   # large square-ish GEMMs
    "balanced": (128, 128, 128),     # default MXU tile
    "skinny_m": (8, 128, 128),       # small-M (decode-style GEMV-ish)
    "skinny_n": (128, 128, 8),       # small-N
    "deep_k": (128, 512, 128),       # reduction-dominated
}


def select_gemm_version(m: int, k: int, n: int) -> Optional[str]:
    """Pick a library kernel for a runtime shape; None -> vendor (XLA)."""
    def fits(name):
        bm, bk, bn = GEMM_LIBRARY[name]
        return m % bm == 0 and k % bk == 0 and n % bn == 0

    if m >= 1024 and n >= 1024 and fits("square_big"):
        return "square_big"
    if m <= 32 and fits("skinny_m"):
        return "skinny_m"
    if n <= 32 and fits("skinny_n"):
        return "skinny_n"
    if k >= 4 * max(m, n) and fits("deep_k"):
        return "deep_k"
    if fits("balanced"):
        return "balanced"
    return None  # vendor library (XLA dot)


def matmul(a: jax.Array, b: jax.Array, *, version: Optional[str] = None,
           interpret: bool = True) -> jax.Array:
    m, k = a.shape
    _, n = b.shape
    if version is None:
        version = select_gemm_version(m, k, n)
    if version is None:
        return jnp.dot(a, b)  # vendor entry
    bm, bk, bn = GEMM_LIBRARY[version]
    return matmul_kernel(a, b, block_m=bm, block_k=bk, block_n=bn,
                         interpret=interpret)
