"""The static-shape kernel library + runtime selection — DISC §4.5.

``GEMM_LIBRARY`` maps a named version to block shapes "hand-tuned" for a
shape regime; :func:`select_gemm_version` is the runtime-shape selection
interface.  Unaligned/small shapes route to the vendor entry (XLA dot) —
exactly the paper's vendor-library/pre-generated-kernel mix.

:func:`matmul_fused` is the kDot entry used by the Pallas backend's
cluster codegen: it pads operands to the selected block grid, runs
:func:`~repro.kernels.matmul.matmul.matmul_epilogue_kernel` (fused
elementwise epilogue, masked M/N/K tails from the runtime lens), and
slices the block padding back off.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .matmul import matmul_epilogue_kernel, matmul_kernel

# name -> (block_m, block_k, block_n): tuned per shape regime
GEMM_LIBRARY = {
    "square_big": (256, 128, 256),   # large square-ish GEMMs
    "balanced": (128, 128, 128),     # default MXU tile
    "skinny_m": (8, 128, 128),       # small-M (decode-style GEMV-ish)
    "skinny_n": (128, 128, 8),       # small-N
    "deep_k": (128, 512, 128),       # reduction-dominated
}


def select_gemm_version(m: int, k: int, n: int) -> Optional[str]:
    """Pick a library kernel for a runtime shape; None -> vendor (XLA)."""
    def fits(name):
        bm, bk, bn = GEMM_LIBRARY[name]
        return m % bm == 0 and k % bk == 0 and n % bn == 0

    if m >= 1024 and n >= 1024 and fits("square_big"):
        return "square_big"
    if m <= 32 and fits("skinny_m"):
        return "skinny_m"
    if n <= 32 and fits("skinny_n"):
        return "skinny_n"
    if k >= 4 * max(m, n) and fits("deep_k"):
        return "deep_k"
    if fits("balanced"):
        return "balanced"
    return None  # vendor library (XLA dot)


def matmul(a: jax.Array, b: jax.Array, *, version: Optional[str] = None,
           interpret: bool = True) -> jax.Array:
    m, k = a.shape
    _, n = b.shape
    if version is None:
        version = select_gemm_version(m, k, n)
    if version is None:
        return jnp.dot(a, b)  # vendor entry
    bm, bk, bn = GEMM_LIBRARY[version]
    return matmul_kernel(a, b, block_m=bm, block_k=bk, block_n=bn,
                         interpret=interpret)


# block-size preference ladders for the fused (kDot) entry: the largest
# aligned version wins; misaligned sizes are padded up to the smallest
_FUSED_M_BLOCKS = (128, 64, 32, 16, 8)
_FUSED_N_BLOCKS = (128, 64, 32, 16, 8)
_FUSED_K_BLOCKS = (512, 256, 128, 64, 32, 16, 8)


def _pick_block(size: int, prefs: Tuple[int, ...]) -> Tuple[int, int]:
    """(block, padded_size): largest preferred block dividing ``size``, else
    the smallest block with ``size`` rounded up to its multiple."""
    for b in prefs:
        if size % b == 0:
            return b, size
    b = prefs[-1]
    return b, ((size + b - 1) // b) * b


def matmul_fused(a: jax.Array, b: jax.Array, extras: Sequence[jax.Array],
                 epilogue: Callable, *, valid_mnk, out_dtypes: Sequence,
                 acc_dtype=None, interpret: bool = True) -> List[jax.Array]:
    """(M, K) @ (K, N) with a fused elementwise epilogue (kDot).

    ``extras`` are (M, N) epilogue operands; ``valid_mnk`` the runtime
    actual sizes (ints or traced i32 scalars) masking the padded M/N/K
    tails.  Returns one (M, N) array per ``out_dtypes`` entry.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    bm, pm = _pick_block(m, _FUSED_M_BLOCKS)
    bn, pn = _pick_block(n, _FUSED_N_BLOCKS)
    bk, pk = _pick_block(k, _FUSED_K_BLOCKS)

    def pad2(x, rows, cols):
        pr, pc = rows - x.shape[0], cols - x.shape[1]
        return jnp.pad(x, ((0, pr), (0, pc))) if (pr or pc) else x

    a = pad2(a, pm, pk)
    b = pad2(b, pk, pn)
    extras = [pad2(x, pm, pn) for x in extras]
    outs = matmul_epilogue_kernel(
        a, b, extras, epilogue, valid_mnk, list(out_dtypes),
        acc_dtype=acc_dtype if acc_dtype is not None else jnp.float32,
        block_m=bm, block_k=bk, block_n=bn, interpret=interpret)
    if (pm, pn) != (m, n):
        outs = [o[:m, :n] for o in outs]
    return list(outs)
