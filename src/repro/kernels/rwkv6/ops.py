"""jit wrapper for the RWKV-6 scan with chunk-size version selection."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .rwkv6 import rwkv6_kernel

CHUNK_VERSIONS = (16, 64, 128)


def rwkv6_scan(r, k, v, w, u, *, interpret: bool = True) -> jax.Array:
    t = r.shape[2]
    fits = [c for c in CHUNK_VERSIONS if t % c == 0]
    if fits:
        return rwkv6_kernel(r, k, v, w, u, chunk=max(fits),
                            interpret=interpret)
    c = CHUNK_VERSIONS[0]
    pad = (-t) % c
    pads = ((0, 0), (0, 0), (0, pad), (0, 0))
    out = rwkv6_kernel(jnp.pad(r, pads), jnp.pad(k, pads), jnp.pad(v, pads),
                       # pad decay with 1.0 (identity) to keep state stable
                       jnp.pad(w, pads, constant_values=1.0), u,
                       chunk=c, interpret=interpret)
    return out[:, :, :t]
