"""RWKV-6 (Finch) WKV recurrence kernel — data-dependent decay scan.

Per head with state S ∈ R^{K×V}:

    y_t = r_t · (S + diag(u) k_t v_tᵀ)
    S  ← diag(w_t) S + k_t v_tᵀ

(w_t data-dependent decay in (0,1), u the "bonus" for the current token.)

TPU schedule: grid (B, H, T/chunk); the f32 state matrix lives in VMEM
scratch and persists across the sequential chunk dimension; within a chunk
a ``fori_loop`` performs the recurrence on VMEM-resident (chunk, K/V)
tiles.  O(1) state in sequence length — this is what makes the rwkv6-3b
``long_500k`` cell tractable (DESIGN §4).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rwkv6_kernel"]


def _body(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_scr, *, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0, 0].astype(jnp.float32)  # (chunk, K)
    k = k_ref[0, 0].astype(jnp.float32)  # (chunk, K)
    v = v_ref[0, 0].astype(jnp.float32)  # (chunk, V)
    w = w_ref[0, 0].astype(jnp.float32)  # (chunk, K) decay in (0,1)
    u = u_ref[...].astype(jnp.float32).reshape(-1, 1)  # (K, 1) bonus

    def step(t, carry):
        s, out = carry
        rt = jax.lax.dynamic_slice_in_dim(r, t, 1, 0)      # (1, K)
        kt = jax.lax.dynamic_slice_in_dim(k, t, 1, 0)      # (1, K)
        vt = jax.lax.dynamic_slice_in_dim(v, t, 1, 0)      # (1, V)
        wt = jax.lax.dynamic_slice_in_dim(w, t, 1, 0)      # (1, K)
        kv = kt.T @ vt                                     # (K, V)
        yt = rt @ (s + u * kv)                             # (1, V)
        s = wt.T * s + kv
        out = jax.lax.dynamic_update_slice_in_dim(out, yt, t, 0)
        return s, out

    s0 = s_scr[...]
    out0 = jnp.zeros((chunk, v.shape[1]), jnp.float32)
    s_fin, out = jax.lax.fori_loop(0, chunk, step, (s0, out0))
    s_scr[...] = s_fin
    o_ref[0, 0] = out.astype(o_ref.dtype)


def rwkv6_kernel(r, k, v, w, u, *, chunk: int = 16,
                 interpret: bool = True) -> jax.Array:
    """r,k,w: (B,H,T,K); v: (B,H,T,V); u: (H,K). Returns (B,H,T,V)."""
    b, h, t, dk = r.shape
    dv = v.shape[-1]
    assert t % chunk == 0, (t, chunk)
    grid = (b, h, t // chunk)
    spec_k = pl.BlockSpec((1, 1, chunk, dk), lambda b_, h_, c: (b_, h_, c, 0))
    spec_v = pl.BlockSpec((1, 1, chunk, dv), lambda b_, h_, c: (b_, h_, c, 0))
    spec_u = pl.BlockSpec((1, dk), lambda b_, h_, c: (h_, 0))
    return pl.pallas_call(
        functools.partial(_body, chunk=chunk),
        grid=grid,
        in_specs=[spec_k, spec_k, spec_v, spec_k, spec_u],
        out_specs=spec_v,
        out_shape=jax.ShapeDtypeStruct((b, h, t, dv), r.dtype),
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
