"""Pure-jnp oracle for the RWKV-6 WKV recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_ref(r, k, v, w, u):
    """r,k,w: (B,H,T,K); v: (B,H,T,V); u: (H,K)."""
    b, h, t, dk = r.shape
    dv = v.shape[-1]
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def scan_head(r_h, k_h, v_h, w_h, u_h):
        def step(s, inp):
            rt, kt, vt, wt = inp
            kv = jnp.outer(kt, vt)
            yt = rt @ (s + u_h[:, None] * kv)
            s = wt[:, None] * s + kv
            return s, yt
        s0 = jnp.zeros((dk, dv), jnp.float32)
        _, ys = jax.lax.scan(step, s0, (r_h, k_h, v_h, w_h))
        return ys

    out = jax.vmap(  # over B
        jax.vmap(scan_head, in_axes=(0, 0, 0, 0, 0)),  # over H
        in_axes=(0, 0, 0, 0, None),
    )(rf, kf, vf, wf, uf)
    return out.astype(r.dtype)
