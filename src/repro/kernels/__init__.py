"""Pallas TPU kernels for DISC-JAX's performance-critical fused patterns.

Each kernel directory holds:
  <name>.py — the pallas_call + BlockSpec VMEM tiling (TPU target,
              validated with interpret=True on CPU),
  ops.py    — jit'd wrapper incl. shape-adaptive version selection (§4.3),
  ref.py    — pure-jnp oracle used by the test sweeps.
"""
