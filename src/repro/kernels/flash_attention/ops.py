"""jit wrappers: prefill (varlen causal FA) and decode (one-token) paths.

Version selection (§4.3 shape-adaptive configuration): block sizes chosen
per runtime sequence length — short sequences use small K blocks so the
skip-guard granularity matches the work, long sequences use MXU-saturating
128×128 blocks.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_kernel

_BLOCK_VERSIONS = ((128, 128), (64, 128), (8, 128))


def _pick_blocks(sq: int, sk: int):
    for bq, bk in _BLOCK_VERSIONS:
        if sq % bq == 0 and sk % bk == 0:
            return bq, bk
    return 0, 0


def flash_attention(q, k, v, lens=None, *, causal=True, scale=None,
                    block_q: Optional[int] = None,
                    block_k: Optional[int] = None,
                    interpret: bool = True) -> jax.Array:
    """q (B,H,Sq,D) × kv (B,Hkv,Sk,D), per-batch valid kv lens."""
    b, h, sq, d = q.shape
    sk = k.shape[2]
    if lens is None:
        lens = jnp.full((b,), sk, jnp.int32)
    if block_q is None or block_k is None:
        bq, bk = _pick_blocks(sq, sk)
        if bq == 0:  # unaligned: pad q/k to the smallest version
            bq, bk = _BLOCK_VERSIONS[-1]
            pad_q = (-sq) % bq
            pad_k = (-sk) % bk
            qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
            kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
            vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
            out = flash_attention_kernel(qp, kp, vp, lens, causal=causal,
                                         scale=scale, block_q=bq, block_k=bk,
                                         interpret=interpret)
            return out[:, :, :sq]
        block_q, block_k = bq, bk
    return flash_attention_kernel(q, k, v, lens, causal=causal, scale=scale,
                                  block_q=block_q, block_k=block_k,
                                  interpret=interpret)


def flash_decode(q, k_cache, v_cache, lens, *, scale=None,
                 interpret: bool = True) -> jax.Array:
    """Single-token decode: q (B,H,1,D) against cache (B,Hkv,Smax,D).

    Reuses the prefill kernel at block_q=8 (first row valid) — correct for
    any cache fill level via the lens mask + block skipping.  A dedicated
    decode kernel with H-packed rows is a target-hardware optimization
    recorded in EXPERIMENTS.md §Perf.
    """
    b, h, sq, d = q.shape
    assert sq == 1
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, 7), (0, 0)))
    out = flash_attention(qp, k_cache, v_cache, lens, causal=False,
                          scale=scale, interpret=interpret)
    return out[:, :, :1]
