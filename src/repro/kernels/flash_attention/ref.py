"""Pure-jnp oracle: masked multi-head attention with per-batch kv lengths."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, lens, *, causal=True, scale=None):
    """q: (B,H,Sq,D); k,v: (B,Hkv,Sk,D); lens: (B,) valid kv lengths."""
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = h // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    k_idx = jnp.arange(sk)[None, None, None, :]
    mask = k_idx < lens[:, None, None, None]
    if causal:
        q_idx = jnp.arange(sq)[None, None, :, None]
        mask = jnp.logical_and(mask, k_idx <= q_idx)
    s = jnp.where(mask, s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.where(mask, jnp.exp(s - m), 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    l = jnp.where(l == 0.0, 1.0, l)
    out = jnp.einsum("bhqk,bhkd->bhqd", p / l, vv.astype(jnp.float32))
    return out.astype(q.dtype)
