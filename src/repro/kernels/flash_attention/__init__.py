from .ops import flash_attention, flash_decode  # noqa: F401
