"""Variable-length flash attention — beyond-paper fused kernel (DESIGN §9).

DISC predates FlashAttention; its fusion scope stops at loop/input fusion.
For the serving path the dominant memory-bound pattern *is* attention, so
we extend the paper's "one artifact, any runtime shape" contract to it:

* per-sequence KV lengths arrive via **scalar prefetch** (`lens`);
* K-blocks entirely beyond a sequence's length (or above the causal
  diagonal) are *skipped* with ``pl.when`` — padded buckets cost no MXU
  flops, which is what makes bucket-compiled attention competitive with
  exact-shape compilation (benchmarks/bench_fig4_static_gap.py);
* online-softmax accumulation in f32 scratch across the innermost K-block
  grid dimension (canonical TPU FA schedule: grid (B, H, nQ, nK), scratch
  persists across the sequential nK steps);
* GQA: the K/V BlockSpec index maps query head h -> kv head h//group, so
  grouped heads share one VMEM copy.

Blocks are MXU-aligned (block_q, block_k multiples of 128 on target;
tests use smaller interpret-mode blocks).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_kernel"]

_NEG_INF = -1e30


def _fa_body(lens_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
             *, scale: float, causal: bool, block_q: int, block_k: int):
    b = pl.program_id(0)
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = lens_ref[b]
    k_start = ik * block_k
    q_start = iq * block_q

    in_range = k_start < kv_len
    if causal:
        in_range = jnp.logical_and(in_range,
                                   k_start <= q_start + block_q - 1)

    @pl.when(in_range)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)

        k_idx = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_idx < kv_len
        if causal:
            q_idx = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            mask = jnp.logical_and(mask, k_idx <= q_idx)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_scr[...]                          # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> 0 output
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_kernel(
    q: jax.Array,       # (B, H, Sq, D)
    k: jax.Array,       # (B, Hkv, Sk, D)
    v: jax.Array,       # (B, Hkv, Sk, D)
    lens: jax.Array,    # (B,) i32 actual kv lengths
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert h % hkv == 0
    group = h // hkv
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    nq, nk = sq // block_q, sk // block_k

    body = functools.partial(_fa_body, scale=scale, causal=causal,
                             block_q=block_q, block_k=block_k)
    return pl.pallas_call(
        body,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, h, nq, nk),
            in_specs=[
                pl.BlockSpec((1, 1, block_q, d),
                             lambda b_, h_, iq, ik, s: (b_, h_, iq, 0)),
                pl.BlockSpec((1, 1, block_k, d),
                             lambda b_, h_, iq, ik, s: (b_, h_ // group, ik, 0)),
                pl.BlockSpec((1, 1, block_k, d),
                             lambda b_, h_, iq, ik, s: (b_, h_ // group, ik, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, block_q, d),
                                   lambda b_, h_, iq, ik, s: (b_, h_, iq, 0)),
            scratch_shapes=[
                pltpu.VMEM((block_q, 1), jnp.float32),
                pltpu.VMEM((block_q, 1), jnp.float32),
                pltpu.VMEM((block_q, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(jnp.asarray(lens, jnp.int32), q, k, v)
