"""jit wrapper + row-block version selection for the kInput kernel.

The Pallas kernel itself only knows one layout — rows = kept axes,
columns = reduced axis.  :func:`fused_reduce` normalizes *any single
reduce axis* onto it with a transpose of the producer inputs: the fused
producer expression is elementwise, so it commutes with the permutation,
and the kept axes preserve their relative order (the transposed result
reshapes directly to the reduce's output shape).
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from .fused_reduce import fused_reduce_kernel

ROW_VERSIONS = (8, 64, 256)
_VMEM_BUDGET = 4 * 1024 * 1024  # bytes per operand tile we allow


def select_row_block(r: int, c: int, itemsize: int = 4) -> int:
    fits = [b for b in ROW_VERSIONS
            if r % b == 0 and b * c * itemsize <= _VMEM_BUDGET]
    if not fits:
        return 0
    pipelined = [b for b in fits if r // b >= 2]
    return max(pipelined) if pipelined else max(fits)


def fused_reduce(expr: Callable, inputs: Sequence[jax.Array], n_valid_cols,
                 kind: str = "sum", *, axis: int = -1,
                 interpret: bool = True) -> jax.Array:
    """Reduce ``expr(*inputs)`` over ``axis`` with dynamic valid length.

    ``axis`` may be any single dimension; non-last axes are moved last by
    transposing the inputs (legal because ``expr`` is elementwise).
    Returns the reduced array with the kept axes in their original order.
    """
    rank = inputs[0].ndim
    axis = axis % rank
    if axis != rank - 1:
        perm = [a for a in range(rank) if a != axis] + [axis]
        inputs = [jnp.transpose(x, perm) for x in inputs]
    lead = inputs[0].shape[:-1]
    c = inputs[0].shape[-1]
    flat = [x.reshape(-1, c) for x in inputs]
    r = flat[0].shape[0]
    block_r = select_row_block(r, c, jnp.dtype(flat[0].dtype).itemsize)
    if block_r == 0:
        b = ROW_VERSIONS[0]
        pad = (-r) % b
        flat = [jnp.pad(x, ((0, pad), (0, 0))) for x in flat]
        out = fused_reduce_kernel(expr, flat, n_valid_cols, kind,
                                  block_r=b, interpret=interpret)
        return out[:r].reshape(lead)
    out = fused_reduce_kernel(expr, flat, n_valid_cols, kind,
                              block_r=block_r, interpret=interpret)
    return out.reshape(lead)
