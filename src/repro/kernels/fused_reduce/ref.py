"""Pure-jnp oracle for the fused reduce (kInput) kernel."""
from __future__ import annotations

import jax.numpy as jnp

from .fused_reduce import REDUCE_IDENTITY

_REDUCERS = {"sum": jnp.sum, "max": jnp.max, "min": jnp.min, "prod": jnp.prod}


def fused_reduce_ref(expr, inputs, n_valid_cols, kind: str):
    y = expr(*inputs)
    c = y.shape[1]
    mask = jnp.arange(c)[None, :] < n_valid_cols
    y = jnp.where(mask, y, jnp.asarray(REDUCE_IDENTITY[kind], y.dtype))
    return _REDUCERS[kind](y, axis=1)
