"""kInput fusion kernel: elementwise producers + reduce root — DISC §4.3.

    "input fusion with reduce operation as the root"

A row-blocked Pallas kernel: each grid step loads a (block_r, C) tile into
VMEM, applies the fused producer expression (unrolled at trace time),
masks the dynamic tail of the reduced axis with the reduce identity using
the **scalar-prefetched actual length**, and reduces.  One artifact serves
every column count ≤ the bucket.

Layout: rows = kept axis (any fused batch dims flattened by ops.py),
columns = reduced axis.  block_r versions are the shape-adaptive launch
configurations.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["fused_reduce_kernel", "REDUCE_IDENTITY"]

REDUCE_IDENTITY = {"sum": 0.0, "max": -jnp.inf, "min": jnp.inf, "prod": 1.0}
_REDUCERS = {"sum": jnp.sum, "max": jnp.max, "min": jnp.min, "prod": jnp.prod}


def _kernel_body(expr: Callable, kind: str, n_in: int):
    identity = REDUCE_IDENTITY[kind]
    reducer = _REDUCERS[kind]

    def body(len_ref, *refs):
        in_refs = refs[:n_in]
        out_ref = refs[n_in]
        xs = [r[...] for r in in_refs]  # (block_r, C)
        y = expr(*xs)
        c = y.shape[1]
        n_valid = len_ref[0]
        col = jax.lax.broadcasted_iota(jnp.int32, (1, c), 1)
        y = jnp.where(col < n_valid, y, jnp.asarray(identity, y.dtype))
        out_ref[...] = reducer(y, axis=1, keepdims=True)

    return body


def fused_reduce_kernel(expr: Callable, inputs, n_valid_cols, kind: str,
                        *, block_r: int = 8, interpret: bool = True):
    """Reduce ``expr(*inputs)`` over axis 1 with masked dynamic length.

    inputs: (R, C) arrays, R % block_r == 0.  Returns (R,).
    """
    r, c = inputs[0].shape
    assert r % block_r == 0, (r, block_r)
    spec = pl.BlockSpec((block_r, c), lambda i, s: (i, 0))
    out = pl.pallas_call(
        _kernel_body(expr, kind, len(inputs)),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(r // block_r,),
            in_specs=[spec] * len(inputs),
            out_specs=pl.BlockSpec((block_r, 1), lambda i, s: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((r, 1), inputs[0].dtype),
        interpret=interpret,
    )(jnp.asarray(n_valid_cols, jnp.int32).reshape(1), *inputs)
    return out[:, 0]
