from .ops import fused_reduce  # noqa: F401
