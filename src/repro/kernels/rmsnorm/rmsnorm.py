"""Fused RMSNorm kernel (row-blocked, VMEM-resident single pass).

RMSNorm (Zhang & Sennrich) over the feature axis: y = x/rms(x) * w.
The feature dim is static per model; the *row* count (batch·seq) is the
dynamic-shape axis — garbage rows in padded buckets are computed and
discarded, no cross-row mixing, so no masking is needed in-kernel.
Accumulation in f32 regardless of input dtype (bf16-safe).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["rmsnorm_kernel"]


def _body(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (block_r, D)
    w = w_ref[...].astype(jnp.float32)  # (1, D)
    ms = jnp.mean(x * x, axis=1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps) * w
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_kernel(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
                   block_r: int = 8, interpret: bool = True) -> jax.Array:
    r, d = x.shape
    assert r % block_r == 0, (r, block_r)
    import functools
    return pl.pallas_call(
        functools.partial(_body, eps=eps),
        grid=(r // block_r,),
        in_specs=[
            pl.BlockSpec((block_r, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        interpret=interpret,
    )(x, w.reshape(1, d))
