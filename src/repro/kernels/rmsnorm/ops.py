"""jit wrapper for fused RMSNorm."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .rmsnorm import rmsnorm_kernel

ROW_VERSIONS = (8, 64, 256)
_VMEM_BUDGET = 4 * 1024 * 1024


def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
            interpret: bool = True) -> jax.Array:
    lead = x.shape[:-1]
    d = x.shape[-1]
    flat = x.reshape(-1, d)
    r = flat.shape[0]
    item = jnp.dtype(x.dtype).itemsize
    fits = [b for b in ROW_VERSIONS
            if r % b == 0 and b * d * item <= _VMEM_BUDGET]
    if fits:
        out = rmsnorm_kernel(flat, w, eps=eps, block_r=max(fits),
                             interpret=interpret)
    else:
        b = ROW_VERSIONS[0]
        pad = (-r) % b
        out = rmsnorm_kernel(jnp.pad(flat, ((0, pad), (0, 0))), w, eps=eps,
                             block_r=b, interpret=interpret)[:r]
    return out.reshape(*lead, d)
