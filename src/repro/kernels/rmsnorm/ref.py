"""Pure-jnp oracle for fused RMSNorm."""
import jax.numpy as jnp


def rmsnorm_ref(x, w, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jnp.reciprocal(jnp.sqrt(ms + eps)) * w.astype(jnp.float32)
            ).astype(x.dtype)
