"""Data pipeline: deterministic synthetic token streams, variable-length
request sampling (the paper's dynamic-shape workload generator), and
sequence packing.

Determinism contract (fault tolerance): every batch is a pure function of
(seed, step) — resuming from a checkpoint at step k reproduces the exact
stream without replaying. ``state_dict``/``load_state_dict`` carry the
cursor for bookkeeping.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

__all__ = ["SyntheticLMStream", "VarLenRequestStream", "Request",
           "pack_sequences"]


class SyntheticLMStream:
    """Markov-ish synthetic LM tokens: learnable structure, not pure noise.

    Tokens follow t_{i+1} = (a·t_i + b + noise) mod vocab with per-sequence
    (a, b) — a model with capacity reduces loss well below uniform entropy,
    so training curves are meaningful in examples/tests.
    """

    def __init__(self, vocab: int, batch: int, seq_len: int, seed: int = 0):
        self.vocab = vocab
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        self.step = 0

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.RandomState((self.seed * 1_000_003 + step) % 2**31)
        b, s, v = self.batch, self.seq_len + 1, self.vocab
        a = rng.randint(1, 17, size=(b, 1))
        c = rng.randint(0, v, size=(b, 1))
        t0 = rng.randint(0, v, size=(b, 1))
        idx = np.arange(s)[None, :]
        noise = rng.randint(0, 3, size=(b, s))
        toks = (t0 + a * idx + c // 7 + noise) % v
        toks = toks.astype(np.int32)
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
            "mask": np.ones((b, s - 1), np.float32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.batch_at(self.step)
            self.step += 1

    def state_dict(self) -> Dict:
        return {"step": self.step, "seed": self.seed}

    def load_state_dict(self, st: Dict) -> None:
        self.step = st["step"]
        self.seed = st["seed"]


# process-wide monotonic request-id source: a Request's rid is its STABLE
# identity — the serve engine keys admission removal, preemption requeue,
# and the done dict on it, so it must be unique among in-flight requests
_RID_COUNTER = itertools.count()


@dataclass
class Request:
    # explicit rid (stable across requeues) or None for an auto-assigned
    # monotonic id
    rid: Optional[int] = None
    tokens: np.ndarray = None   # (prompt_len,) — required
    max_new_tokens: int = 0
    # serve-path scheduling metadata: higher priority admits first under
    # the "priority" admission policy; arrival is the request's offset (in
    # seconds) into a synthetic trace (0.0 = available immediately)
    priority: int = 0
    arrival: float = 0.0
    # completion deadline in seconds from submission (None = no deadline):
    # the serve engine checks it at admission and between steps, retiring
    # expired requests FAILED with a DeadlineExceeded reason
    deadline_s: Optional[float] = None

    def __post_init__(self) -> None:
        if self.rid is None:
            self.rid = next(_RID_COUNTER)


class VarLenRequestStream:
    """Inference requests with varying prompt lengths — the dynamic-shape
    workload of the paper's evaluation (ASR/Seq2seq/BERT serving)."""

    def __init__(self, vocab: int, *, min_len: int = 8, max_len: int = 512,
                 seed: int = 0, distribution: str = "lognormal"):
        self.vocab = vocab
        self.min_len = min_len
        self.max_len = max_len
        self.seed = seed
        self.distribution = distribution
        self._next_rid = 0

    def sample(self, n: int) -> List[Request]:
        out = []
        for _ in range(n):
            rng = np.random.RandomState(
                (self.seed * 7_777_777 + self._next_rid) % 2**31)
            if self.distribution == "lognormal":
                ln = int(np.clip(rng.lognormal(np.log(64), 0.8),
                                 self.min_len, self.max_len))
            else:
                ln = int(rng.randint(self.min_len, self.max_len + 1))
            toks = rng.randint(0, self.vocab, size=ln).astype(np.int32)
            out.append(Request(rid=self._next_rid, tokens=toks,
                               max_new_tokens=int(rng.randint(4, 64)),
                               priority=int(rng.randint(0, 4))))
            self._next_rid += 1
        return out

    def sample_trace(self, n: int, *, burst: int = 4,
                     mean_gap: float = 0.05) -> List[Request]:
        """A bursty arrival trace: requests land in bursts of ``burst``
        separated by exponential gaps with mean ``mean_gap`` seconds —
        the serve benchmark's synthetic heavy-traffic workload.
        Deterministic in (seed, cursor), like :meth:`sample`."""
        reqs = self.sample(n)
        t = 0.0
        for i, r in enumerate(reqs):
            if i and i % burst == 0:
                rng = np.random.RandomState(
                    (self.seed * 13_131_313 + r.rid) % 2**31)
                t += float(rng.exponential(mean_gap))
            r.arrival = t
        return reqs


def pack_sequences(seqs: List[np.ndarray], seq_len: int,
                   pad_id: int = 0) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Greedy first-fit packing of variable-length sequences into fixed
    rows; returns (tokens, segment_ids, mask).  segment_ids let attention
    layers prevent cross-sequence leakage (standard packed-training)."""
    rows: List[List[np.ndarray]] = []
    space: List[int] = []
    for s in seqs:
        s = s[:seq_len]
        placed = False
        for i, sp in enumerate(space):
            if len(s) <= sp:
                rows[i].append(s)
                space[i] -= len(s)
                placed = True
                break
        if not placed:
            rows.append([s])
            space.append(seq_len - len(s))
    n = len(rows)
    tokens = np.full((n, seq_len), pad_id, np.int32)
    segs = np.zeros((n, seq_len), np.int32)
    mask = np.zeros((n, seq_len), np.float32)
    for i, row in enumerate(rows):
        off = 0
        for j, s in enumerate(row):
            tokens[i, off:off + len(s)] = s
            segs[i, off:off + len(s)] = j + 1
            mask[i, off:off + len(s)] = 1.0
            off += len(s)
    return tokens, segs, mask
