from .pipeline import SyntheticLMStream, VarLenRequestStream, pack_sequences  # noqa: F401
