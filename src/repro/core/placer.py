"""Host/device placement of shape calculation vs tensor compute — DISC §4.2.1.

    "DISC separates shape computation and data processing during
     compilation ... The placer component places shape calculation logic on
     host side and tensor computation kernels on device side."

Placement rule (as in the paper / Nimble): the backward closure of values
feeding **shape operands** (dslice starts, etc.) that is cheap integer math
is *shape calculation* → host; everything else is tensor compute → device.
The generated dispatcher (``runtime.py``) executes host-placed ops with
numpy inside the compiled host flow; device ops are traced into the jitted
executable.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Set

import numpy as np

from .dhlo import DGraph, DOp
from .propagation import CostClass, op_info

__all__ = ["Placement", "place"]

_HOST_BYTES_LIMIT = 1024  # shape math is tiny by definition


@dataclass
class Placement:
    host_ops: List[DOp]
    device_ops: List[DOp]
    host_value_ids: Set[int]

    def report(self) -> Dict[str, int]:
        return {"host_ops": len(self.host_ops), "device_ops": len(self.device_ops)}


def _is_small_int(v) -> bool:
    if not np.issubdtype(np.dtype(v.dtype), np.integer):
        return False
    n = 1
    for d in v.shape:
        if not isinstance(d, int):
            return False
        n *= d
    return n * np.dtype(v.dtype).itemsize <= _HOST_BYTES_LIMIT


def place(graph: DGraph) -> Placement:
    producer: Dict[int, DOp] = {}
    for op in graph.ops:
        for o in op.outputs:
            producer[o.vid] = op

    # roots: values used as shape operands + outputs of SHAPE-cost ops
    roots: List[DOp] = []
    for op in graph.ops:
        for v in op.shape_operands:
            p = producer.get(v.vid)
            if p is not None:
                roots.append(p)
        if op_info(op.opcode).cost is CostClass.SHAPE:
            roots.append(op)

    host: Set[int] = set()
    stack = list(roots)
    while stack:
        op = stack.pop()
        if op.oid in host:
            continue
        # only small integer computations move to host
        if not all(_is_small_int(o) for o in op.outputs):
            continue
        if op_info(op.opcode).cost is CostClass.COMPUTE:
            continue
        host.add(op.oid)
        for v in op.inputs:
            p = producer.get(v.vid)
            if p is not None:
                stack.append(p)

    host_ops = [op for op in graph.ops if op.oid in host]
    device_ops = [op for op in graph.ops if op.oid not in host]
    host_vals = {o.vid for op in host_ops for o in op.outputs}
    return Placement(host_ops=host_ops, device_ops=device_ops,
                     host_value_ids=host_vals)
