"""Host/device placement of shape calculation vs tensor compute — DISC §4.2.1.

    "DISC separates shape computation and data processing during
     compilation ... The placer component places shape calculation logic on
     host side and tensor computation kernels on device side."

Placement rule (as in the paper / Nimble): the backward closure of values
feeding **shape operands** (dslice starts, etc.) that is cheap integer math
is *shape calculation* → host; everything else is tensor compute → device.
The generated dispatcher (``runtime.py``) executes host-placed ops with
numpy inside the compiled host flow; device ops are traced into the jitted
executable.

The device side of the split is **host/mesh** when the artifact compiles
under ``CompileOptions(mesh=...)``: shape calculation still runs on the
host (it is *replicated* control flow — every participant computes the
same bucket key), while tensor compute is SPMD-partitioned over the mesh
per the sharding plan.  The placement records the mesh so ``report()``
shows where device ops actually land.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Set

import numpy as np

from .dhlo import DGraph, DOp
from .propagation import CostClass, op_info

__all__ = ["Placement", "place"]

_HOST_BYTES_LIMIT = 1024  # shape math is tiny by definition


@dataclass
class Placement:
    host_ops: List[DOp]
    device_ops: List[DOp]
    host_value_ids: Set[int]
    # the SPMD mesh device ops are partitioned over (None = one device)
    mesh: Optional[Any] = None

    @property
    def device_target(self) -> str:
        """Where tensor compute lands: ``"device"`` or ``"mesh(...)"``."""
        if self.mesh is None:
            return "device"
        shape = "x".join(f"{a}={int(s)}"
                         for a, s in self.mesh.shape.items())
        return f"mesh({shape})"

    def report(self) -> Dict[str, Any]:
        rep: Dict[str, Any] = {"host_ops": len(self.host_ops),
                               "device_ops": len(self.device_ops),
                               "device_target": self.device_target}
        if self.mesh is not None:
            rep["mesh_axes"] = {a: int(s)
                                for a, s in self.mesh.shape.items()}
        return rep


def _is_small_int(v) -> bool:
    if not np.issubdtype(np.dtype(v.dtype), np.integer):
        return False
    n = 1
    for d in v.shape:
        if not isinstance(d, int):
            return False
        n *= d
    return n * np.dtype(v.dtype).itemsize <= _HOST_BYTES_LIMIT


def place(graph: DGraph, mesh: Optional[Any] = None) -> Placement:
    producer: Dict[int, DOp] = {}
    for op in graph.ops:
        for o in op.outputs:
            producer[o.vid] = op

    # roots: values used as shape operands + outputs of SHAPE-cost ops
    roots: List[DOp] = []
    for op in graph.ops:
        for v in op.shape_operands:
            p = producer.get(v.vid)
            if p is not None:
                roots.append(p)
        if op_info(op.opcode).cost is CostClass.SHAPE:
            roots.append(op)

    host: Set[int] = set()
    stack = list(roots)
    while stack:
        op = stack.pop()
        if op.oid in host:
            continue
        # only small integer computations move to host
        if not all(_is_small_int(o) for o in op.outputs):
            continue
        if op_info(op.opcode).cost is CostClass.COMPUTE:
            continue
        host.add(op.oid)
        for v in op.inputs:
            p = producer.get(v.vid)
            if p is not None:
                stack.append(p)

    host_ops = [op for op in graph.ops if op.oid in host]
    device_ops = [op for op in graph.ops if op.oid not in host]
    host_vals = {o.vid for op in host_ops for o in op.outputs}
    return Placement(host_ops=host_ops, device_ops=device_ops,
                     host_value_ids=host_vals, mesh=mesh)
