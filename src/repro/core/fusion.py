"""Shape-class fusion planner — DISC §4.3.

    "A common fusion strategy is to allow memory bound ops with the same
     number of elements to be fused together.  However, the tensor shapes to
     process are not known at compile time for dynamic shape scenarios."

The planner never looks at concrete sizes.  Fusion legality between a
producer/consumer pair of *memory-intensive* ops is decided from the two
shape hints of the paper:

* **shape propagation** — the per-op-class transfer rules
  (``propagation.OP_TABLE``) let shape equality flow through elementwise
  chains, transposes, reshapes;
* **shape constraints** — tensor-size equality / dim equality from the
  :class:`ShapeConstraintStore`, including frontend-injected hints (e.g.
  ``split`` outputs), which enlarge fusion scope beyond what local
  propagation can prove.

Cluster kinds mirror the paper's codegen templates:

* ``loop``  — classical loop fusion with an elementwise root (the paper's
  **kLoop**): every member writes/reads values of one shape class, so the
  whole cluster lowers to a single flattened loop over the element domain;
* ``input`` — input fusion with a reduce op as the root (the paper's
  **kInput**): elementwise producers are recomputed inside the reduce's
  loop nest instead of materializing an intermediate;
* ``dot``   — a ``dot_general`` plus its elementwise *epilogue*
  (bias add / activation / residual), the **kDot** extension: the
  compute-intensive root still comes from the static-shape kernel library
  (§4.5) but its elementwise consumers are folded into the GEMM's output
  tiles instead of launching a separate memory-bound kernel;
* ``compute`` / ``opaque`` — unfused ops (library calls, gathers, ...).

Eligibility for the *backend fused-kernel templates* is also decided here,
at plan time: each cluster carries ``template`` — ``"kLoop"``,
``"kInput"``, ``"kDot"``, or ``None`` — so backends (``core/codegen.py``,
``api/backends.py``) dispatch on the plan instead of re-deriving
eligibility from private predicates.
"""
from __future__ import annotations

import itertools
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from .dhlo import DGraph, DOp, DValue
from .propagation import CostClass, PropClass, op_info

__all__ = [
    "Cluster",
    "FusionPlan",
    "plan_fusion",
    "cluster_live_outs",
    "PALLAS_ELEMENTWISE_OPS",
    "REDUCE_ROOT_KINDS",
]


# opcodes whose emission is shape-oblivious on a flattened block — the
# eligibility set for the backend fused-kernel templates (§4.3).  Shared
# with ``core/codegen.py``; kept here because eligibility is a *plan*
# property, not a codegen one.
PALLAS_ELEMENTWISE_OPS = frozenset({
    "add", "sub", "mul", "div", "max", "min", "pow", "neg", "exp", "exp2",
    "expm1", "log", "log1p", "tanh", "logistic", "sqrt", "rsqrt", "abs",
    "sign", "floor", "ceil", "round", "erf", "sin", "cos", "square",
    "integer_pow", "select", "convert", "stop_gradient", "copy",
    "eq", "ne", "lt", "gt", "le", "ge", "and", "or", "not",
})

# reduce opcodes a kInput root may use, mapped to the fused-reduce kernel's
# combiner name
REDUCE_ROOT_KINDS = {"reduce_sum": "sum", "reduce_max": "max",
                     "reduce_min": "min", "reduce_prod": "prod"}


@dataclass
class Cluster:
    cid: int
    kind: str  # "loop" | "input" | "dot" | "compute" | "opaque"
    ops: List[DOp] = field(default_factory=list)
    # Fused-kernel template this cluster can execute as ("kLoop" | "kInput"
    # | "kDot"), or None when only per-op execution is possible.  Decided
    # once at plan time by ``plan_fusion``.
    template: Optional[str] = None

    @property
    def root(self) -> DOp:
        return self.ops[-1]

    def __repr__(self) -> str:  # pragma: no cover
        t = f" [{self.template}]" if self.template else ""
        return f"<Cluster {self.cid} {self.kind}{t}: {[o.opcode for o in self.ops]}>"


@dataclass
class FusionPlan:
    graph: DGraph
    clusters: List[Cluster]
    op_to_cluster: Dict[int, int]

    @property
    def n_kernels(self) -> int:
        """Number of launched kernels after fusion (paper Table 3 metric)."""
        return len(self.clusters)

    @property
    def n_memory_kernels(self) -> int:
        return sum(1 for c in self.clusters if c.kind in ("loop", "input"))

    def template_counts(self) -> Dict[str, int]:
        """How many clusters each fused-kernel template covers."""
        out: Dict[str, int] = {}
        for c in self.clusters:
            if c.template:
                out[c.template] = out.get(c.template, 0) + 1
        return out

    def stats(self) -> Dict[str, int]:
        mem_ops = sum(
            1 for op in self.graph.ops if op_info(op.opcode).cost is CostClass.MEMORY
        )
        return {
            "total_ops": len(self.graph.ops),
            "memory_ops": mem_ops,
            "kernels_after_fusion": self.n_kernels,
            "memory_kernels_after_fusion": self.n_memory_kernels,
            "largest_cluster": max((len(c.ops) for c in self.clusters), default=0),
            "fusable_clusters": sum(1 for c in self.clusters if c.template),
        }


# fusable propagation classes for loop fusion members
_LOOP_FUSABLE = {
    PropClass.ELEMENTWISE,
    PropClass.BROADCAST,
    PropClass.RESHAPE,
    PropClass.TRANSPOSE,
    PropClass.SLICE,
    PropClass.CONCAT,
    PropClass.IOTA,
    PropClass.UPDATE,
}


class _ClusterSet:
    """Union-find over op ids with per-cluster successor tracking for the
    cycle check (merging A→B is illegal if A reaches B via a third cluster)."""

    def __init__(self, graph: DGraph) -> None:
        self.graph = graph
        self.parent: Dict[int, int] = {op.oid: op.oid for op in graph.ops}
        self.members: Dict[int, List[DOp]] = {op.oid: [op] for op in graph.ops}
        # op-level edges
        self.succs: Dict[int, Set[int]] = defaultdict(set)
        producer = {}
        for op in graph.ops:
            for o in op.outputs:
                producer[o.vid] = op.oid
        for op in graph.ops:
            for v in op.all_operands():
                if v.vid in producer:
                    self.succs[producer[v.vid]].add(op.oid)

    def find(self, oid: int) -> int:
        p = self.parent[oid]
        if p != oid:
            p = self.find(p)
            self.parent[oid] = p
        return p

    def cluster_succs(self, root: int) -> Set[int]:
        out: Set[int] = set()
        for op in self.members[root]:
            for s in self.succs[op.oid]:
                rs = self.find(s)
                if rs != root:
                    out.add(rs)
        return out

    def would_cycle(self, a: int, b: int) -> bool:
        """True if merging clusters a,b creates a cycle: a path a→…→b (or
        b→…→a) through a third cluster."""
        for start, goal in ((a, b), (b, a)):
            stack = [s for s in self.cluster_succs(start) if s != goal]
            seen: Set[int] = set(stack)
            while stack:
                cur = stack.pop()
                if cur == goal:
                    return True
                for s in self.cluster_succs(cur):
                    if s not in seen and s != start:
                        seen.add(s)
                        stack.append(s)
        return False

    def merge(self, a: int, b: int) -> int:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return ra
        # keep topological order of members by op id (construction order)
        merged = sorted(self.members[ra] + self.members[rb], key=lambda o: o.oid)
        self.parent[rb] = ra
        self.members[ra] = merged
        del self.members[rb]
        return ra


def _is_tiny(graph: DGraph, v: DValue) -> bool:
    """Scalar/small constants broadcast implicitly inside a fused loop."""
    e = graph.store.size_expr(v.vid)
    return e is not None and e.is_static() and e.coeff <= 4096


def _broadcast_compatible(graph: DGraph, pshape, cshape) -> bool:
    """Producer shape feeds consumer via implicit broadcast (§4.3: "whether
    an implicit broadcast is necessary") — per-dim equal or producer dim 1."""
    if len(pshape) == 0:
        return True
    if len(pshape) != len(cshape):
        return False
    store = graph.store
    for dp, dc in zip(pshape, cshape):
        if isinstance(dp, int) and dp == 1:
            continue
        if not store.dims_equal(dp, dc):
            return False
    return True


# ------------------------------------------------------------ templates --

def cluster_live_outs(graph: DGraph, cluster: Cluster,
                      users: Optional[Dict[int, List[DOp]]] = None,
                      out_ids: Optional[Set[int]] = None) -> List[DValue]:
    """Values produced inside ``cluster`` that are observable outside it:
    graph outputs, or operands of ops in other clusters.  A fused cluster
    kernel must materialize exactly these (in this, deterministic, order)."""
    if users is None:
        users = graph.users()
    if out_ids is None:
        out_ids = {o.vid for o in graph.outputs}
    member = {op.oid for op in cluster.ops}
    live: List[DValue] = []
    for op in cluster.ops:
        for o in op.outputs:
            if o.vid in out_ids or any(
                    u.oid not in member for u in users.get(o.vid, ())):
                live.append(o)
    return live


def _same_class(store, shape, ref) -> bool:
    return len(shape) == len(ref) and store.shapes_equal(shape, ref)


def _block_operand_ok(graph: DGraph, v: DValue, ref) -> bool:
    """A value a fused-kernel body may touch as a block: scalar (closure
    captured), ref-class, or broadcastable into ref (the runner
    pre-broadcasts boundary operands, so inside the kernel everything is
    ref-shaped)."""
    if v.rank == 0:
        return True
    return (_same_class(graph.store, v.shape, ref)
            or _broadcast_compatible(graph, v.shape, ref))


def _hoistable_broadcast(op: DOp, produced: Set[int]) -> bool:
    """A ``broadcast_in_dim`` whose operands all come from outside the
    cluster: emitted outside the kernel (prologue), its output streams in
    as a boundary block."""
    return (op.opcode == "broadcast_in_dim"
            and not any(v.vid in produced for v in op.inputs))


def _plain_2d_matmul(dot: DOp) -> bool:
    dn = dot.attrs.get("dimension_numbers")
    if dn is None:
        return False
    (lc, rc), (lb, rb) = dn
    return (tuple(lc), tuple(rc), tuple(lb), tuple(rb)) == ((1,), (0,), (), ()) \
        and dot.inputs[0].rank == 2 and dot.inputs[1].rank == 2


def _classify_loop(graph: DGraph, cl: Cluster, users, out_ids) -> Optional[str]:
    """kLoop: ONE flattened masked kernel writing every live-out.  Every
    body op must be shape-oblivious elementwise over one shape class
    (scalars closure-captured, broadcast-compatible boundary operands
    pre-broadcast by the runner, boundary ``broadcast_in_dim`` ops hoisted
    to a prologue).  Multiple live-outs are fine — the kernel writes N
    output refs."""
    if len(cl.ops) < 2:
        return None
    store = graph.store
    produced = {o.vid for op in cl.ops for o in op.outputs}
    body = [op for op in cl.ops if op.opcode != "broadcast_in_dim"]
    if not body:
        return None
    # the block shape class: the maximal (non-broadcast) body output class
    ref = None
    for op in body:
        for v in op.outputs:
            if v.rank == 0:
                continue
            if ref is None or not _broadcast_compatible(graph, v.shape, ref):
                ref = v.shape
    if ref is None:
        return None
    for op in cl.ops:
        if op.opcode not in PALLAS_ELEMENTWISE_OPS:
            if not _hoistable_broadcast(op, produced):
                return None
            if not _broadcast_compatible(graph, op.outputs[0].shape, ref):
                return None
            continue
        for v in list(op.inputs) + list(op.outputs):
            if not _block_operand_ok(graph, v, ref):
                return None
    for v in cluster_live_outs(graph, cl, users, out_ids):
        p = graph.producer(v)
        if p is not None and p.opcode == "broadcast_in_dim":
            continue  # prologue value, materialized outside the kernel
        if v.rank == 0 or not _same_class(store, v.shape, ref):
            return None  # the kernel only stores full ref-class blocks
    return "kLoop"


def _classify_input(graph: DGraph, cl: Cluster, users, out_ids) -> Optional[str]:
    """kInput: shape-oblivious producers + ONE single-axis reduce root.
    Any reduce axis is allowed — the backend normalizes to a last-axis
    reduce with a symbolic transpose (elementwise producers commute with
    it).  Only the root may escape: the kernel materializes one result."""
    if len(cl.ops) < 2:
        return None
    root = cl.ops[-1]
    if root.opcode not in REDUCE_ROOT_KINDS:
        return None
    if len(tuple(root.attrs.get("axes", ()))) != 1:
        return None
    produced = {o.vid for op in cl.ops for o in op.outputs}
    ref = root.inputs[0].shape
    for op in cl.ops[:-1]:
        if op.opcode not in PALLAS_ELEMENTWISE_OPS:
            if not _hoistable_broadcast(op, produced):
                return None
            if not _broadcast_compatible(graph, op.outputs[0].shape, ref):
                return None
            continue
        for v in list(op.inputs) + list(op.outputs):
            if not _block_operand_ok(graph, v, ref):
                return None
    live = cluster_live_outs(graph, cl, users, out_ids)
    if [v.vid for v in live] != [root.outputs[0].vid]:
        return None
    return "kInput"


def _classify_dot(graph: DGraph, cl: Cluster, users, out_ids) -> Optional[str]:
    """kDot: one plain 2-D ``dot_general`` whose elementwise epilogue runs
    on the GEMM's output tiles.  Cluster members split into a *prologue*
    (ops not depending on the dot — e.g. a bias ``broadcast_in_dim`` —
    emitted outside the kernel) and the *epilogue* (everything downstream
    of the accumulator, which must be shape-oblivious elementwise over the
    dot's output class)."""
    dots = [op for op in cl.ops if op_info(op.opcode).cost is CostClass.COMPUTE]
    if len(dots) != 1 or dots[0].opcode != "dot_general":
        return None
    dot = dots[0]
    if not _plain_2d_matmul(dot):
        return None
    produced = {o.vid for op in cl.ops for o in op.outputs}
    if any(v.vid in produced for v in dot.inputs):
        return None  # dot operands must be cluster boundaries (no prologue into the MXU)
    store = graph.store
    ref = dot.outputs[0].shape
    dep = {dot.outputs[0].vid}
    for op in cl.ops:  # topological
        if op is dot:
            continue
        if any(v.vid in dep for v in op.inputs):
            # epilogue op: runs on (block_m, block_n) accumulator tiles
            # (broadcast-compatible operands are pre-broadcast to (M, N)
            # outside the kernel)
            if op.opcode not in PALLAS_ELEMENTWISE_OPS:
                return None
            for v in list(op.inputs) + list(op.outputs):
                if not _block_operand_ok(graph, v, ref):
                    return None
            dep.update(o.vid for o in op.outputs)
        else:
            # prologue op: materialized outside the kernel before launch
            if op.opcode not in PALLAS_ELEMENTWISE_OPS and \
                    op.opcode != "broadcast_in_dim":
                return None
    # kernel-stored live-outs must be full (M, N) tiles
    for v in cluster_live_outs(graph, cl, users, out_ids):
        if v.vid in dep and (v.rank == 0
                             or not _same_class(store, v.shape, ref)):
            return None
    return "kDot"


def _classify(graph: DGraph, cl: Cluster, users, out_ids) -> Optional[str]:
    if cl.kind == "loop":
        return _classify_loop(graph, cl, users, out_ids)
    if cl.kind == "input":
        return _classify_input(graph, cl, users, out_ids)
    if cl.kind == "dot":
        return _classify_dot(graph, cl, users, out_ids)
    return None


# ----------------------------------------------------------------- plan --

def plan_fusion(graph: DGraph) -> FusionPlan:
    store = graph.store
    cs = _ClusterSet(graph)
    kinds: Dict[int, str] = {}

    for op in graph.ops:
        info = op_info(op.opcode)
        if info.cost is CostClass.COMPUTE:
            kinds[op.oid] = "compute"
        elif info.cost is CostClass.SHAPE:
            kinds[op.oid] = "opaque"
        elif info.prop in _LOOP_FUSABLE:
            kinds[op.oid] = "loop"
        elif info.prop is PropClass.REDUCE:
            kinds[op.oid] = "input"
        else:
            kinds[op.oid] = "opaque"

    producer = {}
    for op in graph.ops:
        for o in op.outputs:
            producer[o.vid] = op

    def out_value(op: DOp) -> DValue:
        return op.outputs[0]

    def fusable_edge(p: DOp, c: DOp) -> bool:
        """Shape-hint legality of fusing producer p into consumer c."""
        kp, kc = kinds[cs.find(p.oid)], kinds[cs.find(c.oid)]
        if kp == "opaque" or kc == "opaque":
            return False
        if c.opcode == "dot_general" or kc == "compute":
            # nothing fuses into a dot's operands (the GEMM prologue stays
            # a cluster boundary); non-dot compute ops never fuse
            return False
        pv = out_value(p)
        if kp == "compute":
            # kDot seed: a dot_general absorbs an elementwise consumer
            # whose result shares the dot output's shape class (§4.3
            # epilogue fusion; template legality is re-checked at
            # classification time — e.g. batched dots stay per-op)
            if p.opcode != "dot_general" or kc != "loop":
                return False
            cv = out_value(c)
            return (store.sizes_equal(pv.vid, cv.vid)
                    or _broadcast_compatible(graph, pv.shape, cv.shape))
        if kp == "input":
            # a reduce is a cluster *root*: nothing fuses after it within
            # the cluster (paper: input fusion with reduce as the root)
            return False
        if "dot" in (kp, kc):
            # a dot cluster grows only by elementwise epilogue ops and
            # their loop-kind producers; reduces stay outside and two dots
            # never share a cluster
            if kp == "dot" and kc == "dot":
                return False
            if {kp, kc} - {"dot", "loop"}:
                return False
            cv = out_value(c)
            return (store.sizes_equal(pv.vid, cv.vid)
                    or _broadcast_compatible(graph, pv.shape, cv.shape)
                    or _is_tiny(graph, pv))
        if kc == "input":
            # kInput: producers fuse if they share the reduce's INPUT size
            red_in = c.inputs[0]
            return (store.sizes_equal(pv.vid, red_in.vid)
                    or _broadcast_compatible(graph, pv.shape, red_in.shape)
                    or _is_tiny(graph, pv))
        # kLoop: same element count (the paper's classic rule), proven via
        # constraints — or implicit broadcast into the consumer's shape
        cv = out_value(c)
        return (store.sizes_equal(pv.vid, cv.vid)
                or _broadcast_compatible(graph, pv.shape, cv.shape)
                or _is_tiny(graph, pv))

    for op in graph.ops:  # topological
        for v in op.inputs:
            p = producer.get(v.vid)
            if p is None:
                continue
            ra, rb = cs.find(p.oid), cs.find(op.oid)
            if ra == rb:
                continue
            if not fusable_edge(p, op):
                continue
            if cs.would_cycle(ra, rb):
                continue
            ka, kb = kinds[ra], kinds[rb]
            if "dot" in (ka, kb) or "compute" in (ka, kb):
                new_kind = "dot"
            elif "input" in (ka, kb):
                new_kind = "input"
            else:
                new_kind = "loop"
            root = cs.merge(ra, rb)
            kinds[root] = new_kind

    clusters: List[Cluster] = []
    op_to_cluster: Dict[int, int] = {}
    cid_iter = itertools.count()
    roots = sorted(cs.members.keys(), key=lambda r: cs.members[r][0].oid)
    for root in roots:
        cid = next(cid_iter)
        cl = Cluster(cid=cid, kind=kinds[root], ops=cs.members[root])
        clusters.append(cl)
        for m in cl.ops:
            op_to_cluster[m.oid] = cid
    clusters = _toposort_clusters(clusters)
    # template classification: backend fused-kernel eligibility is decided
    # here, on the plan, not inside codegen
    users = graph.users()
    out_ids = {o.vid for o in graph.outputs}
    for cl in clusters:
        cl.template = _classify(graph, cl, users, out_ids)
    return FusionPlan(graph=graph, clusters=clusters, op_to_cluster=op_to_cluster)


def _toposort_clusters(clusters: List[Cluster]) -> List[Cluster]:
    """Order clusters topologically (executors run them in list order).

    First-op order is NOT sufficient: a fused cluster executes *all* its
    ops at once, so a cluster whose earliest op traces before another
    cluster may still consume that cluster's output (e.g. an elementwise
    cluster reading a reduce it post-dominates).  The merge step's cycle
    check guarantees the cluster DAG is acyclic; ties break by cid for
    determinism."""
    import heapq

    producer_cluster: Dict[int, int] = {}
    for cl in clusters:
        for op in cl.ops:
            for o in op.outputs:
                producer_cluster[o.vid] = cl.cid
    by_cid = {cl.cid: cl for cl in clusters}
    indeg = {cl.cid: 0 for cl in clusters}
    succs: Dict[int, Set[int]] = defaultdict(set)
    for cl in clusters:
        for op in cl.ops:
            for v in op.all_operands():
                pc = producer_cluster.get(v.vid)
                if pc is not None and pc != cl.cid and cl.cid not in succs[pc]:
                    succs[pc].add(cl.cid)
                    indeg[cl.cid] += 1
    heap = [cid for cid, d in indeg.items() if d == 0]
    heapq.heapify(heap)
    ordered: List[Cluster] = []
    while heap:
        cid = heapq.heappop(heap)
        ordered.append(by_cid[cid])
        for s in sorted(succs[cid]):
            indeg[s] -= 1
            if indeg[s] == 0:
                heapq.heappush(heap, s)
    assert len(ordered) == len(clusters), "cluster DAG has a cycle"
    return ordered
