"""Shape-class fusion planner — DISC §4.3.

    "A common fusion strategy is to allow memory bound ops with the same
     number of elements to be fused together.  However, the tensor shapes to
     process are not known at compile time for dynamic shape scenarios."

The planner never looks at concrete sizes.  Fusion legality between a
producer/consumer pair of *memory-intensive* ops is decided from the two
shape hints of the paper:

* **shape propagation** — the per-op-class transfer rules
  (``propagation.OP_TABLE``) let shape equality flow through elementwise
  chains, transposes, reshapes;
* **shape constraints** — tensor-size equality / dim equality from the
  :class:`ShapeConstraintStore`, including frontend-injected hints (e.g.
  ``split`` outputs), which enlarge fusion scope beyond what local
  propagation can prove.

Cluster kinds mirror the paper's codegen templates: ``kLoop`` (classical
loop fusion, elementwise root) and ``kInput`` (input fusion with a reduce
op as the root).  Compute-intensive ops (``dot_general``/``conv``) are
never fused into loops — they go to the static-shape library (§4.5).
"""
from __future__ import annotations

import itertools
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from .dhlo import DGraph, DOp, DValue
from .propagation import CostClass, PropClass, op_info

__all__ = ["Cluster", "FusionPlan", "plan_fusion"]


@dataclass
class Cluster:
    cid: int
    kind: str  # "loop" | "input" | "compute" | "opaque"
    ops: List[DOp] = field(default_factory=list)

    @property
    def root(self) -> DOp:
        return self.ops[-1]

    def __repr__(self) -> str:  # pragma: no cover
        return f"<Cluster {self.cid} {self.kind}: {[o.opcode for o in self.ops]}>"


@dataclass
class FusionPlan:
    graph: DGraph
    clusters: List[Cluster]
    op_to_cluster: Dict[int, int]

    @property
    def n_kernels(self) -> int:
        """Number of launched kernels after fusion (paper Table 3 metric)."""
        return len(self.clusters)

    @property
    def n_memory_kernels(self) -> int:
        return sum(1 for c in self.clusters if c.kind in ("loop", "input"))

    def stats(self) -> Dict[str, int]:
        mem_ops = sum(
            1 for op in self.graph.ops if op_info(op.opcode).cost is CostClass.MEMORY
        )
        return {
            "total_ops": len(self.graph.ops),
            "memory_ops": mem_ops,
            "kernels_after_fusion": self.n_kernels,
            "memory_kernels_after_fusion": self.n_memory_kernels,
            "largest_cluster": max((len(c.ops) for c in self.clusters), default=0),
        }


# fusable propagation classes for loop fusion members
_LOOP_FUSABLE = {
    PropClass.ELEMENTWISE,
    PropClass.BROADCAST,
    PropClass.RESHAPE,
    PropClass.TRANSPOSE,
    PropClass.SLICE,
    PropClass.CONCAT,
    PropClass.IOTA,
    PropClass.UPDATE,
}


class _ClusterSet:
    """Union-find over op ids with per-cluster successor tracking for the
    cycle check (merging A→B is illegal if A reaches B via a third cluster)."""

    def __init__(self, graph: DGraph) -> None:
        self.graph = graph
        self.parent: Dict[int, int] = {op.oid: op.oid for op in graph.ops}
        self.members: Dict[int, List[DOp]] = {op.oid: [op] for op in graph.ops}
        # op-level edges
        self.succs: Dict[int, Set[int]] = defaultdict(set)
        producer = {}
        for op in graph.ops:
            for o in op.outputs:
                producer[o.vid] = op.oid
        for op in graph.ops:
            for v in op.all_operands():
                if v.vid in producer:
                    self.succs[producer[v.vid]].add(op.oid)

    def find(self, oid: int) -> int:
        p = self.parent[oid]
        if p != oid:
            p = self.find(p)
            self.parent[oid] = p
        return p

    def cluster_succs(self, root: int) -> Set[int]:
        out: Set[int] = set()
        for op in self.members[root]:
            for s in self.succs[op.oid]:
                rs = self.find(s)
                if rs != root:
                    out.add(rs)
        return out

    def would_cycle(self, a: int, b: int) -> bool:
        """True if merging clusters a,b creates a cycle: a path a→…→b (or
        b→…→a) through a third cluster."""
        for start, goal in ((a, b), (b, a)):
            stack = [s for s in self.cluster_succs(start) if s != goal]
            seen: Set[int] = set(stack)
            while stack:
                cur = stack.pop()
                if cur == goal:
                    return True
                for s in self.cluster_succs(cur):
                    if s not in seen and s != start:
                        seen.add(s)
                        stack.append(s)
        return False

    def merge(self, a: int, b: int) -> int:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return ra
        # keep topological order of members by op id (construction order)
        merged = sorted(self.members[ra] + self.members[rb], key=lambda o: o.oid)
        self.parent[rb] = ra
        self.members[ra] = merged
        del self.members[rb]
        return ra


def _is_tiny(graph: DGraph, v: DValue) -> bool:
    """Scalar/small constants broadcast implicitly inside a fused loop."""
    e = graph.store.size_expr(v.vid)
    return e is not None and e.is_static() and e.coeff <= 4096


def _broadcast_compatible(graph: DGraph, pshape, cshape) -> bool:
    """Producer shape feeds consumer via implicit broadcast (§4.3: "whether
    an implicit broadcast is necessary") — per-dim equal or producer dim 1."""
    if len(pshape) == 0:
        return True
    if len(pshape) != len(cshape):
        return False
    store = graph.store
    for dp, dc in zip(pshape, cshape):
        if isinstance(dp, int) and dp == 1:
            continue
        if not store.dims_equal(dp, dc):
            return False
    return True


def plan_fusion(graph: DGraph) -> FusionPlan:
    store = graph.store
    cs = _ClusterSet(graph)
    kinds: Dict[int, str] = {}

    for op in graph.ops:
        info = op_info(op.opcode)
        if info.cost is CostClass.COMPUTE:
            kinds[op.oid] = "compute"
        elif info.cost is CostClass.SHAPE:
            kinds[op.oid] = "opaque"
        elif info.prop in _LOOP_FUSABLE:
            kinds[op.oid] = "loop"
        elif info.prop is PropClass.REDUCE:
            kinds[op.oid] = "input"
        else:
            kinds[op.oid] = "opaque"

    producer = {}
    for op in graph.ops:
        for o in op.outputs:
            producer[o.vid] = op

    def out_value(op: DOp) -> DValue:
        return op.outputs[0]

    def fusable_edge(p: DOp, c: DOp) -> bool:
        """Shape-hint legality of fusing producer p into consumer c."""
        kp, kc = kinds[cs.find(p.oid)], kinds[cs.find(c.oid)]
        if kp in ("compute", "opaque") or kc in ("compute", "opaque"):
            return False
        if kp == "input":
            # a reduce is a cluster *root*: nothing fuses after it within
            # the cluster (paper: input fusion with reduce as the root)
            return False
        pv = out_value(p)
        if kc == "input":
            # kInput: producers fuse if they share the reduce's INPUT size
            red_in = c.inputs[0]
            return (store.sizes_equal(pv.vid, red_in.vid)
                    or _broadcast_compatible(graph, pv.shape, red_in.shape)
                    or _is_tiny(graph, pv))
        # kLoop: same element count (the paper's classic rule), proven via
        # constraints — or implicit broadcast into the consumer's shape
        cv = out_value(c)
        return (store.sizes_equal(pv.vid, cv.vid)
                or _broadcast_compatible(graph, pv.shape, cv.shape)
                or _is_tiny(graph, pv))

    for op in graph.ops:  # topological
        for v in op.inputs:
            p = producer.get(v.vid)
            if p is None:
                continue
            ra, rb = cs.find(p.oid), cs.find(op.oid)
            if ra == rb:
                continue
            if not fusable_edge(p, op):
                continue
            if cs.would_cycle(ra, rb):
                continue
            new_kind = "input" if "input" in (kinds[ra], kinds[rb]) else "loop"
            root = cs.merge(ra, rb)
            kinds[root] = new_kind

    clusters: List[Cluster] = []
    op_to_cluster: Dict[int, int] = {}
    cid_iter = itertools.count()
    roots = sorted(cs.members.keys(), key=lambda r: cs.members[r][0].oid)
    for root in roots:
        cid = next(cid_iter)
        cl = Cluster(cid=cid, kind=kinds[root], ops=cs.members[root])
        clusters.append(cl)
        for m in cl.ops:
            op_to_cluster[m.oid] = cid
    return FusionPlan(graph=graph, clusters=clusters, op_to_cluster=op_to_cluster)
