"""Per-op JAX emission rules — the device-side code generation table.

Each DHLO opcode maps to a rule ``(op, inputs, out_shapes) -> outputs`` that
re-derives any shape-bearing parameters from the op's *symbolic* output
shapes evaluated at the current concrete sizes — the DHLO property that the
computation is re-emittable at any runtime shape.  Rules are pure jnp/lax
and run either under ``jax.jit`` tracing (compiled path) or eagerly (the
NimbleVM interpreted baseline).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .dhlo import DOp

__all__ = ["emit_op", "HAS_RULE"]

_UNARY = {
    "neg": jnp.negative, "sign": jnp.sign, "floor": jnp.floor,
    "ceil": jnp.ceil, "round": jnp.round, "exp": jnp.exp, "exp2": jnp.exp2,
    "expm1": jnp.expm1, "log": jnp.log, "log1p": jnp.log1p,
    "tanh": jnp.tanh, "logistic": jax.nn.sigmoid, "sqrt": jnp.sqrt,
    "rsqrt": lax.rsqrt, "cbrt": jnp.cbrt, "abs": jnp.abs, "erf": lax.erf,
    "erfc": lax.erfc, "erf_inv": lax.erf_inv, "sin": jnp.sin,
    "cos": jnp.cos, "tan": jnp.tan, "asin": jnp.arcsin, "acos": jnp.arccos,
    "atan": jnp.arctan, "sinh": jnp.sinh, "cosh": jnp.cosh,
    "not": jnp.logical_not, "is_finite": jnp.isfinite,
    "stop_gradient": lax.stop_gradient, "copy": lambda x: x,
    "square": jnp.square,
}

_BINARY = {
    "add": jnp.add, "sub": jnp.subtract, "mul": jnp.multiply,
    "div": jnp.divide, "rem": jnp.remainder, "pow": jnp.power,
    "max": jnp.maximum, "min": jnp.minimum, "atan2": jnp.arctan2,
    "and": jnp.bitwise_and, "or": jnp.bitwise_or, "xor": jnp.bitwise_xor,
    "eq": jnp.equal, "ne": jnp.not_equal, "lt": jnp.less,
    "gt": jnp.greater, "le": jnp.less_equal, "ge": jnp.greater_equal,
    "nextafter": jnp.nextafter,
    "shift_left": jnp.left_shift, "shift_right_logical": jnp.right_shift,
    "shift_right_arithmetic": jnp.right_shift,
}

_REDUCE = {
    "reduce_sum": jnp.sum, "reduce_max": jnp.max, "reduce_min": jnp.min,
    "reduce_prod": jnp.prod, "reduce_and": jnp.all, "reduce_or": jnp.any,
}


def emit_op(op: DOp, inputs: Sequence[jnp.ndarray],
            out_shapes: Sequence[Tuple[int, ...]]) -> List[jnp.ndarray]:
    """Execute/trace one DHLO op at concrete shapes ``out_shapes``."""
    code = op.opcode
    if code in _UNARY:
        return [_UNARY[code](inputs[0])]
    if code in _BINARY:
        return [_BINARY[code](inputs[0], inputs[1])]
    if code in _REDUCE:
        axes = op.attrs.get("axes", ())
        return [_REDUCE[code](inputs[0], axis=tuple(axes))]
    if code == "integer_pow":
        y = op.attrs.get("_params", {}).get("y", 2)
        return [lax.integer_pow(inputs[0], y)]
    if code == "select":
        return [lax.select_n(*inputs)]
    if code == "clamp":
        return [lax.clamp(*inputs)]
    if code == "convert":
        return [lax.convert_element_type(inputs[0], op.attrs["new_dtype"])]
    if code == "broadcast_in_dim":
        bdims = op.attrs["broadcast_dimensions"]
        return [lax.broadcast_in_dim(inputs[0], out_shapes[0], bdims)]
    if code == "reshape":
        return [jnp.reshape(inputs[0], out_shapes[0])]
    if code == "transpose":
        return [jnp.transpose(inputs[0], op.attrs["permutation"])]
    if code == "rev":
        dims = op.attrs.get("_params", {}).get("dimensions", ())
        return [lax.rev(inputs[0], tuple(dims))]
    if code in ("argmax", "argmin"):
        axes = op.attrs.get("axes", (0,))
        fn = jnp.argmax if code == "argmax" else jnp.argmin
        out = fn(inputs[0], axis=axes[0])
        return [out.astype(op.outputs[0].dtype)]
    if code in ("cumsum", "cumprod", "cummax"):
        params = op.attrs.get("_params", {})
        prim = op.attrs.get("_prim")
        return [prim.bind(inputs[0], **params)]
    if code == "dot_general":
        params = op.attrs.get("_params", {})
        return [lax.dot_general(
            inputs[0], inputs[1], op.attrs["dimension_numbers"],
            precision=params.get("precision"),
            preferred_element_type=params.get("preferred_element_type"),
        )]
    if code == "dslice":
        starts = inputs[1:] if not op.shape_operands else None
        return [lax.dynamic_slice(inputs[0], list(inputs[1:]), out_shapes[0])]
    if code == "dynamic_update_slice":
        return [lax.dynamic_update_slice(inputs[0], inputs[1], list(inputs[2:]))]
    if code == "slice":
        starts = op.attrs["start_indices"]
        strides = op.attrs.get("strides") or (1,) * len(starts)
        limits = tuple(s + o * st for s, o, st in
                       zip(starts, out_shapes[0], strides))
        return [lax.slice(inputs[0], starts, limits, strides)]
    if code == "concatenate":
        return [lax.concatenate(list(inputs), op.attrs["dimension"])]
    if code == "pad":
        cfg = op.attrs["padding_config"]
        return [lax.pad(inputs[0], inputs[1], cfg)]
    if code == "iota":
        dt = op.outputs[0].dtype
        return [lax.broadcasted_iota(dt, out_shapes[0],
                                     op.attrs.get("dimension", 0))]
    if code == "sort":
        params = op.attrs.get("_params", {})
        dim = params.get("dimension", -1)
        return [lax.sort(inputs[0], dimension=dim)]
    # ---- opaque fallback: rebind the original primitive --------------
    if code in ("d.while", "d.scan", "d.cond"):
        raise NotImplementedError(
            f"region op {code} carries nested DGraph bodies and must be "
            f"executed via codegen.emit_region_op, not the per-op table")
    prim = op.attrs.get("_prim")
    params = op.attrs.get("_params", {})
    if prim is None:
        raise NotImplementedError(f"no emission rule for {code}")
    _check_opaque_safety(op, inputs, out_shapes)
    out = prim.bind(*inputs, **params)
    return list(out) if prim.multiple_results else [out]


# param keys that carry shape info; if present AND the traced output shape
# differs from the current one, re-binding stale params would be wrong
_SHAPEY_PARAM_KEYS = ("shape", "new_sizes", "slice_sizes", "sizes",
                      "padding_config", "limit_indices", "broadcast_sizes")


def _check_opaque_safety(op: DOp, inputs, out_shapes) -> None:
    params = op.attrs.get("_params", {})
    if any(k in params for k in _SHAPEY_PARAM_KEYS):
        traced = tuple(tuple(int(x) for x in o.concrete_shape())
                       for o in op.outputs)
        if tuple(tuple(s) for s in out_shapes) != traced:
            raise NotImplementedError(
                f"opaque op {op.opcode} has shape-bearing params and was "
                f"asked to run at a different shape; add an emission rule")


HAS_RULE = (set(_UNARY) | set(_BINARY) | set(_REDUCE) |
            {"integer_pow", "select", "clamp", "convert", "broadcast_in_dim",
             "reshape", "transpose", "rev", "argmax", "argmin", "cumsum",
             "cumprod", "cummax", "dot_general", "dslice",
             "dynamic_update_slice", "slice", "concatenate", "pad", "iota",
             "sort"})
