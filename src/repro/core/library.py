"""Static-shape kernel library interface — DISC §4.5.

    "we implement an interface to choose the best kernel from a library
     according to different runtime shapes.  The library contains both
     vendor libraries such as cuBLAS/cuDNN, and pre-generated kernels that
     has been hand-tuned for each shape."

The library itself lives with the kernels (`kernels/matmul`): a version
table of hand-tuned block shapes plus the vendor entry (XLA's native dot,
our cuBLAS analogue).  This module is the compiler-side interface: the
codegen layer asks :func:`pick` for a compute-intensive op's backend at
dispatch time, keyed on the *runtime* shape — the §4.5 balance between
dynamism (any shape works) and performance (tuned kernels where shapes
align).
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

__all__ = ["pick", "LibraryChoice"]


class LibraryChoice:
    def __init__(self, name: str, fn: Callable):
        self.name = name
        self.fn = fn

    def __call__(self, *args, **kwargs):
        return self.fn(*args, **kwargs)

    def __repr__(self) -> str:  # pragma: no cover
        return f"<LibraryChoice {self.name}>"


def pick(m: int, k: int, n: int, *, interpret: bool = True) -> LibraryChoice:
    """Choose the GEMM implementation for a runtime (m, k, n)."""
    from ..kernels.matmul.ops import matmul, select_gemm_version

    version = select_gemm_version(m, k, n)
    if version is None:
        import jax.numpy as jnp
        return LibraryChoice("vendor:xla_dot", jnp.dot)
    return LibraryChoice(
        f"library:{version}",
        lambda a, b: matmul(a, b, version=version, interpret=interpret))
