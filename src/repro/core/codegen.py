"""Code generation: DHLO graph → device executables — DISC §4.3.

Two executors are generated from one graph:

* :func:`build_exact_executor` — runs the graph at the call's exact concrete
  shapes.  Used by the static-fallback path (§4.4) and as the correctness
  oracle.
* :func:`build_padded_executor` — the dynamic-shape artifact: traced/jitted
  once per *bucket signature*, it executes at padded shapes while taking the
  **actual lengths as a runtime i32 operand** (`lens`).  Masking makes it
  exact for every shape ≤ bucket:

  - inputs are zero-padded on the host (runtime.py), so padded regions start
    clean;
  - every *position-mixing* op (reduce, dot contraction, reverse cumsum,
    sort, arg-reduce) masks dynamic axes with the op's padding identity
    (``propagation.OP_TABLE.pad_identity``) right before mixing;
  - masks are canonical per symbolic dim: prefix masks ``iota < len`` for
    input symbols, Kronecker products for reshape-merged dims (matching the
    row-major garbage pattern of reshaped padded data), prefix masks for
    concat-sum / slice-affine dims;
  - ``concatenate`` along a dynamic axis is re-emitted as dynamic-update-
    slices at *traced actual offsets*, keeping valid data prefix-contiguous.

  This is the paper's "shape-adaptive" codegen: one artifact, any runtime
  shape (≤ bucket), with launch-configuration decisions (here: mask/no-mask,
  vectorized variants in the Pallas backend) resolved from runtime shape
  scalars.

Fused-cluster execution is organized around the :class:`ClusterKernel`
protocol: the fusion plan marks each cluster with the codegen *template*
it can execute as (``"kLoop"``, ``"kInput"``, ``"kDot"`` — see
``core/fusion.py``), and a backend supplies one kernel object per
template it implements.  The built-in Pallas set
(:func:`pallas_cluster_kernels`) covers:

* **kLoop**  — one flattened masked kernel over the element domain,
  writing every live-out of the cluster (multi-output clusters do not
  split);
* **kInput** — elementwise producers recomputed inside a masked last-axis
  reduce; any single reduce axis is normalized to last-axis with a
  transpose (elementwise exprs commute with it);
* **kDot**   — the tiled MXU matmul with the cluster's elementwise
  epilogue (bias/activation/residual) applied on the accumulator tiles at
  the final K step, with masked M/N/K tails from the runtime lens.

Clusters whose template a backend does not register — or whose kernel
raises — fall back to per-op XLA emission, so widening eligibility can
never change numerics.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..errors import CONTROL_EXCEPTIONS
from ..ft import faults
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from .dhlo import DGraph, DOp, DValue
from .emit import emit_op
from .fusion import REDUCE_ROOT_KINDS, Cluster, cluster_live_outs
from .propagation import op_info
from .symshape import SymDim

__all__ = [
    "build_exact_executor",
    "build_padded_executor",
    "dyn_symbols",
    "ClusterKernel",
    "pallas_cluster_kernels",
    "REGION_OPS",
    "emit_region_op",
]

# DHLO region ops: bodies are nested DGraphs in attrs, executed by
# lowering back to lax control flow (emit_region_op) — never through the
# per-op emission table
REGION_OPS = frozenset({"d.while", "d.scan", "d.cond"})


def dyn_symbols(graph: DGraph) -> List[SymDim]:
    """Ordered list of *input* symbolic dims (canonical, deduped)."""
    seen: Dict[int, SymDim] = {}
    for p in graph.params:
        for d in p.shape:
            if isinstance(d, SymDim):
                c = graph.store.canon_dim(d)
                if isinstance(c, SymDim) and c.uid not in seen:
                    seen[c.uid] = c
    return list(seen.values())


class _ShapeEnv:
    """Evaluates symbolic dims at trace time (padded ints + traced actuals)."""

    def __init__(self, graph: DGraph, padded: Dict[int, int],
                 actual: Dict[int, Any]) -> None:
        self.graph = graph
        self.store = graph.store
        self.exprs = getattr(graph, "dim_exprs", {})
        self.padded = dict(padded)   # canonical uid -> python int
        self.actual = dict(actual)   # canonical uid -> traced i32 (or int)
        self._masks: Dict[Tuple[int, int], Any] = {}

    def _canon(self, d):
        c = self.store.canon_dim(d)
        return c

    def padded_dim(self, d) -> int:
        if isinstance(d, int):
            return d
        c = self._canon(d)
        if isinstance(c, int):
            return c
        if c.uid in self.padded:
            return self.padded[c.uid]
        expr = self.exprs.get(c.uid) or self.exprs.get(d.uid)
        if expr is None:
            # widened carry dims have no input binding and no derived
            # expr — they pad to their recorded cap
            cap = self.store.dim_bound(c)
            if cap is not None:
                return int(cap)
            raise KeyError(f"unbound dim {d!r}")
        return int(self._eval(expr, self.padded))

    def actual_dim(self, d):
        if isinstance(d, int):
            return d
        c = self._canon(d)
        if isinstance(c, int):
            return c
        if c.uid in self.actual:
            return self.actual[c.uid]
        expr = self.exprs.get(c.uid) or self.exprs.get(d.uid)
        if expr is None:
            cap = self.store.dim_bound(c)
            if cap is not None:
                return int(cap)  # conservative: full padded extent valid
            raise KeyError(f"unbound dim {d!r}")
        return self._eval(expr, self.actual)

    def _eval(self, expr, env):
        tag = expr[0]
        if tag == "mul":
            v = 1
            for x in expr[1]:
                v = v * (self._lookup(x, env))
            return v
        if tag == "sum":
            v = 0
            for x in expr[1]:
                v = v + self._lookup(x, env)
            return v
        if tag == "affine":
            _, base, a, b = expr
            return a * self._lookup(base, env) + b
        if tag == "div":
            _, base, k = expr
            return self._lookup(base, env) // k
        raise ValueError(f"bad dim expr {expr}")

    def _lookup(self, d, env):
        if isinstance(d, int):
            return d
        c = self._canon(d)
        if isinstance(c, int):
            return c
        if c.uid in env:
            return env[c.uid]
        expr = self.exprs.get(c.uid) or self.exprs.get(d.uid)
        if expr is None:
            raise KeyError(f"unbound dim {d!r}")
        return self._eval(expr, env)

    def is_dynamic(self, d) -> bool:
        if isinstance(d, int):
            return False
        c = self._canon(d)
        return isinstance(c, SymDim)

    def padded_shape(self, shape) -> Tuple[int, ...]:
        return tuple(self.padded_dim(d) for d in shape)

    # ----------------------------------------------------------- masks --
    def mask_for_dim(self, d) -> Optional[Any]:
        """Canonical validity mask (bool[padded]) for a dynamic dim."""
        if not self.is_dynamic(d):
            return None
        c = self._canon(d)
        psize = self.padded_dim(c)
        key = (c.uid, psize)
        if key in self._masks:
            return self._masks[key]
        expr = self.exprs.get(c.uid)
        if expr is not None and expr[0] == "mul":
            # reshape-merged dim: Kronecker product of factor masks matches
            # the row-major garbage pattern of reshaped padded data
            factors = expr[1]
            m = None
            for f in factors:
                fp = self.padded_dim(f) if not isinstance(f, int) else f
                fm = self.mask_for_dim(f) if not isinstance(f, int) else None
                if fm is None:
                    fm = jnp.ones((fp,), dtype=bool)
                m = fm if m is None else (m[:, None] & fm[None, :]).reshape(-1)
            mask = m
        else:
            actual = self.actual_dim(c)
            mask = lax.broadcasted_iota(jnp.int32, (psize,), 0) < actual
        self._masks[key] = mask
        return mask

    def mask_axes(self, x, shape, axes, fill) -> Any:
        """Apply canonical masks along ``axes`` of value with symbolic shape."""
        for ax in axes:
            m = self.mask_for_dim(shape[ax])
            if m is None:
                continue
            bshape = [1] * x.ndim
            bshape[ax] = m.shape[0]
            x = jnp.where(m.reshape(bshape), x, jnp.asarray(fill, x.dtype))
        return x


def _emit_masked(op: DOp, inputs, out_shapes, env: _ShapeEnv):
    """emit_op + dynamic-axis masking for position-mixing ops."""
    code = op.opcode
    info = op_info(code)

    if code.startswith("reduce_") or code in ("argmax", "argmin"):
        axes = op.attrs.get("axes", ())
        src = op.inputs[0]
        dyn_axes = [a for a in axes if env.is_dynamic(src.shape[a])]
        if dyn_axes:
            fill = info.pad_identity if info.pad_identity is not None else 0.0
            x = env.mask_axes(inputs[0], src.shape, dyn_axes, fill)
            inputs = [x] + list(inputs[1:])
        return emit_op(op, inputs, out_shapes)

    if code == "dot_general":
        (lc, rc), (lb, rb) = op.attrs["dimension_numbers"]
        lhs_v, rhs_v = op.inputs[0], op.inputs[1]
        dyn_lc = [a for a in lc if env.is_dynamic(lhs_v.shape[a])]
        if dyn_lc:
            lhs = env.mask_axes(inputs[0], lhs_v.shape, dyn_lc, 0.0)
            inputs = [lhs, inputs[1]]
        return emit_op(op, inputs, out_shapes)

    if code in ("cumsum", "cumprod", "cummax"):
        params = op.attrs.get("_params", {})
        axis = params.get("axis", 0)
        src = op.inputs[0]
        if params.get("reverse", False) and env.is_dynamic(src.shape[axis]):
            fill = {"cumsum": 0.0, "cumprod": 1.0, "cummax": -np.inf}[code]
            x = env.mask_axes(inputs[0], src.shape, [axis], fill)
            inputs = [x]
        return emit_op(op, inputs, out_shapes)

    if code == "sort":
        params = op.attrs.get("_params", {})
        dim = params.get("dimension", -1)
        src = op.inputs[0]
        d = dim if dim >= 0 else src.rank + dim
        if env.is_dynamic(src.shape[d]):
            x = env.mask_axes(inputs[0], src.shape, [d], np.inf)
            inputs = [x]
        return emit_op(op, inputs, out_shapes)

    if code == "concatenate":
        axis = op.attrs["dimension"]
        out_v = op.outputs[0]
        if env.is_dynamic(out_v.shape[axis]) and len(op.inputs) > 1:
            # dynamic-axis concat: DUS at traced actual offsets keeps valid
            # data prefix-contiguous (canonical for the sum-derived dim)
            out = jnp.zeros(out_shapes[0], dtype=out_v.dtype)
            offset = jnp.asarray(0, jnp.int32)
            for v, x in zip(op.inputs, inputs):
                starts = [offset if ax == axis else 0 for ax in range(x.ndim)]
                out = lax.dynamic_update_slice(out, x, starts)
                alen = env.actual_dim(v.shape[axis])
                offset = offset + jnp.asarray(alen, jnp.int32)
            return [out]
        return emit_op(op, inputs, out_shapes)

    if code == "pad":
        cfg = op.attrs["padding_config"]
        src = op.inputs[0]
        for ax, (lo, hi, interior) in enumerate(cfg):
            if env.is_dynamic(src.shape[ax]) and (hi > 0 or interior > 0):
                raise NotImplementedError(
                    "hi/interior pad along a dynamic axis is not "
                    "bucket-paddable; pre-pad on the host instead")
        return emit_op(op, inputs, out_shapes)

    return emit_op(op, inputs, out_shapes)


# ------------------------------------------------------- region ops --

def emit_region_op(op: DOp, ins: Sequence[Any], env: _ShapeEnv,
                   masked: bool) -> List[Any]:
    """Execute a DHLO region op by lowering it back to lax control flow.

    Region bodies execute through :func:`_run_graph` on their nested
    DGraphs, inside ``lax.while_loop``/``lax.scan``/``lax.switch`` — one
    traced artifact regardless of trip count.  Each body invocation gets
    a FRESH ``_ShapeEnv`` over the same padded/actual bindings: masks are
    cached per env, and a mask traced in one lax scope must never leak
    into another.

    Masking: loop carries keep their (entry-bucket) padded shapes, so no
    per-iteration masking is needed for ``d.while``/``d.cond``; a
    ``d.scan`` over a dynamic length runs at the padded trip count with
    an iteration index threaded in, and guards the carry so padded-tail
    iterations are identity — stacked ys tail rows are garbage the
    dispatch's output recovery slices away.
    """
    code = op.opcode
    attrs = op.attrs
    if code == "d.while":
        cn, bn = attrs["cond_nconsts"], attrs["body_nconsts"]
        cond_g, body_g = attrs["cond_graph"], attrs["body_graph"]
        cond_consts = list(ins[:cn])
        body_consts = list(ins[cn:cn + bn])
        init = tuple(ins[cn + bn:])

        def cond_fun(carry):
            sub = _ShapeEnv(cond_g, env.padded, env.actual)
            (pred,) = _run_graph(cond_g, cond_consts + list(carry), sub,
                                 masked)
            return pred

        def body_fun(carry):
            sub = _ShapeEnv(body_g, env.padded, env.actual)
            return tuple(_run_graph(body_g, body_consts + list(carry), sub,
                                    masked))

        return list(lax.while_loop(cond_fun, body_fun, init))

    if code == "d.scan":
        nc, ncar = attrs["num_consts"], attrs["num_carry"]
        body_g = attrs["body_graph"]
        length_dim = attrs["length_dim"]
        consts = list(ins[:nc])
        init = tuple(ins[nc:nc + ncar])
        xs = tuple(ins[nc + ncar:])
        padded_len = env.padded_dim(length_dim)
        dyn_len = masked and env.is_dynamic(length_dim)
        actual_len = env.actual_dim(length_dim) if dyn_len else padded_len
        idxs = lax.broadcasted_iota(jnp.int32, (padded_len,), 0)

        def f(carry, row):
            idx, xslices = row[0], list(row[1:])
            sub = _ShapeEnv(body_g, env.padded, env.actual)
            outs = _run_graph(body_g, consts + list(carry) + xslices, sub,
                              masked)
            new_carry, ys = tuple(outs[:ncar]), tuple(outs[ncar:])
            if dyn_len:
                # padded-tail iterations are identity on the carry (the
                # row index travels with the row, so this is exact for
                # reverse scans too)
                keep = idx < actual_len
                new_carry = tuple(jnp.where(keep, n, c)
                                  for n, c in zip(new_carry, carry))
            return new_carry, ys

        final, ys = lax.scan(f, init, (idxs,) + xs, length=padded_len,
                             reverse=attrs["reverse"],
                             unroll=attrs["unroll"])
        return list(final) + list(ys)

    if code == "d.cond":
        branch_graphs = attrs["branch_graphs"]
        idx = jnp.clip(jnp.asarray(ins[0], jnp.int32), 0,
                       len(branch_graphs) - 1)
        operands = list(ins[1:])

        def make(bg):
            def branch(*args):
                sub = _ShapeEnv(bg, env.padded, env.actual)
                return tuple(_run_graph(bg, list(args), sub, masked))
            return branch

        out = lax.switch(idx, [make(bg) for bg in branch_graphs], *operands)
        return list(out)

    raise NotImplementedError(f"unknown region op {code}")


# --------------------------------------------------- cluster kernels --

def _cluster_expr(ops: Sequence[DOp], input_vids: Sequence[int],
                  scalar_consts: Mapping[int, Any],
                  out_vids: Sequence[int]) -> Callable:
    """Build the unrolled expression closure a fused kernel body executes.

    ``input_vids`` name the block operands (positionally), ``out_vids``
    the values the closure returns (a tuple when several).  The per-op
    emission happens at kernel TRACE time — zero runtime interpretation,
    exactly the paper's compile-time codegen property."""

    def expr(*blocks):
        local: Dict[int, Any] = dict(zip(input_vids, blocks))
        local.update(scalar_consts)

        def rd(v):
            if v.vid in local:
                return local[v.vid]
            assert v.literal is not None, f"unbound {v!r}"
            return jnp.asarray(v.literal)

        for op in ops:
            res = emit_op(op, [rd(v) for v in op.inputs], [None])
            for o, val in zip(op.outputs, res):
                local[o.vid] = val
        outs = tuple(local[vid] for vid in out_vids)
        return outs if len(outs) != 1 else outs[0]

    return expr


def _cluster_io(ops: Sequence[DOp], read) -> Tuple[List[int], List[Any],
                                                   Dict[int, Any]]:
    """Boundary operands of a fused body: non-scalar values become kernel
    tensor inputs (including non-scalar literals — they must stream in as
    blocks, not be re-materialized at full shape inside the body); rank-0
    values are closure-captured.  Scalar *literals* are captured as raw
    numpy (they trace to in-kernel constants); a non-literal rank-0
    boundary value would be a captured tracer, which Pallas rejects — the
    kernel then raises and the cluster falls back to per-op emission."""
    produced = {o.vid for op in ops for o in op.outputs}
    tensor_ids: List[int] = []
    tensors: List[Any] = []
    scalars: Dict[int, Any] = {}
    for op in ops:
        for v in op.inputs:
            if v.vid in produced or v.vid in scalars or v.vid in tensor_ids:
                continue
            if v.rank == 0:
                scalars[v.vid] = (np.asarray(v.literal)
                                  if v.literal is not None else read(v))
            else:
                tensor_ids.append(v.vid)
                tensors.append(read(v))
    return tensor_ids, tensors, scalars


def _hoist_broadcasts(cluster: Cluster, read, env: "_ShapeEnv"):
    """Emit the cluster's boundary ``broadcast_in_dim`` ops outside the
    kernel (classification guarantees their operands are boundaries);
    returns the remaining body ops and the materialized prologue values."""
    vals: Dict[int, Any] = {}
    body: List[DOp] = []
    for op in cluster.ops:
        if op.opcode == "broadcast_in_dim":
            outs = emit_op(op, [read(v) for v in op.inputs],
                           [env.padded_shape(o.shape) for o in op.outputs])
            for o, val in zip(op.outputs, outs):
                vals[o.vid] = val
        else:
            body.append(op)
    return body, vals


def _to_blocks(tensors: Sequence[Any], padded_ref: Tuple[int, ...]):
    """Pre-broadcast boundary operands to the kernel's block class (inside
    the kernel everything is ref-shaped; size-1 dims broadcast here)."""
    return [t if tuple(t.shape) == tuple(padded_ref)
            else jnp.broadcast_to(t, padded_ref) for t in tensors]


#: process-lifetime demotion journal: one entry per kernel instance that
#: crossed its strike budget (``report()["health"]`` and the serve stats
#: read its length) — append-only, never reset
KERNEL_DEMOTIONS: List[str] = []


class ClusterKernel:
    """One fused-kernel template implementation for a backend.

    ``template`` names the fusion-plan template this kernel executes
    (``Cluster.template``); :meth:`run` executes one cluster and returns
    ``{vid: padded_array}`` for every value the cluster must materialize
    (its live-outs).  ``runs``/``fallbacks`` count *traces* through the
    kernel (one per compiled bucket signature, not per call) — they let
    tests and benchmarks prove a cluster actually executed through the
    fused path instead of silently falling back to per-op XLA.

    Degradation ladder: every failed :meth:`run` is a **strike**; after
    ``demote_after`` strikes the instance is *demoted* — clusters skip it
    and emit per-op (the always-available library path, Nimble-style)
    without re-attempting a kernel that keeps failing.  Demotions land in
    :data:`KERNEL_DEMOTIONS`.
    """

    template: str = ""
    #: strikes before the instance stops being tried (None = never demote)
    demote_after: Optional[int] = 3

    def __init__(self) -> None:
        self.runs = 0
        self.fallbacks = 0
        self.strikes = 0
        self.demoted = False

    def strike(self) -> None:
        """Record one failed run; demote at the budget."""
        self.strikes += 1
        self.fallbacks += 1
        if (not self.demoted and self.demote_after is not None
                and self.strikes >= self.demote_after):
            self.demoted = True
            KERNEL_DEMOTIONS.append(
                f"{type(self).__name__}[{self.template}] after "
                f"{self.strikes} strikes")
            obs_metrics.record_event(
                "kernel.demote", kernel=type(self).__name__,
                template=self.template, strikes=self.strikes)

    def run(self, graph: DGraph, cluster: Cluster, read, env: "_ShapeEnv",
            masked: bool) -> Dict[int, Any]:
        raise NotImplementedError


class PallasLoopKernel(ClusterKernel):
    """kLoop: one flattened masked Pallas kernel writing every live-out."""

    template = "kLoop"

    def run(self, graph, cluster, read, env, masked):
        from ..kernels.fused_elementwise.ops import fused_elementwise

        body, pvals = _hoist_broadcasts(cluster, read, env)

        def rd(v):
            return pvals[v.vid] if v.vid in pvals else read(v)

        tensor_ids, tensors, scalars = _cluster_io(body, rd)
        live = cluster_live_outs(graph, cluster)
        kernel_outs = [v for v in live if v.vid not in pvals]
        result = {v.vid: pvals[v.vid] for v in live if v.vid in pvals}
        pref = env.padded_shape(kernel_outs[0].shape)
        tensors = _to_blocks(tensors, pref)
        expr = _cluster_expr(body, tensor_ids, scalars,
                             [v.vid for v in kernel_outs])
        # pointwise garbage stays confined to the padded region (which is
        # NOT a flat prefix under multi-dim padding) — downstream mixing
        # ops apply their own canonical masks, so no in-kernel mask here
        n_valid = int(np.prod(pref, dtype=np.int64))
        outs = fused_elementwise(expr, tensors, n_valid,
                                 [v.dtype for v in kernel_outs])
        result.update({v.vid: o.reshape(pref)
                       for v, o in zip(kernel_outs, outs)})
        return result


class PallasInputKernel(ClusterKernel):
    """kInput: fused producers + masked single-axis reduce root.  Non-last
    reduce axes are normalized by transposing the (elementwise) producer
    inputs — the expr commutes — so one last-axis kernel serves any axis."""

    template = "kInput"

    def run(self, graph, cluster, read, env, masked):
        from ..kernels.fused_reduce.ops import fused_reduce

        root = cluster.ops[-1]
        (axis,) = tuple(root.attrs["axes"])
        src = root.inputs[0]
        body, pvals = _hoist_broadcasts(cluster, read, env)

        def rd(v):
            return pvals[v.vid] if v.vid in pvals else read(v)

        tensor_ids, tensors, scalars = _cluster_io(body[:-1], rd)
        # the reduce source itself may be a boundary/prologue value (no
        # producer in the body): stream it in and reduce it as-is
        src_vid = root.inputs[0].vid
        if src_vid not in {o.vid for op in body[:-1] for o in op.outputs} \
                and src_vid not in tensor_ids:
            tensor_ids.append(src_vid)
            tensors.append(rd(root.inputs[0]))
        tensors = _to_blocks(tensors, env.padded_shape(src.shape))
        expr = _cluster_expr(body[:-1], tensor_ids, scalars,
                             [root.inputs[0].vid])
        red_dim = src.shape[axis]
        if masked and env.is_dynamic(red_dim):
            n_cols = env.actual_dim(red_dim)
        else:
            n_cols = env.padded_dim(red_dim)
        out = fused_reduce(expr, tensors, n_cols,
                           REDUCE_ROOT_KINDS[root.opcode], axis=axis)
        out_v = root.outputs[0]
        return {out_v.vid: out.reshape(env.padded_shape(out_v.shape))}


class PallasDotKernel(ClusterKernel):
    """kDot: tiled MXU matmul with the elementwise epilogue fused into the
    final-K-step store, M/N/K tails masked from the runtime lens.  Prologue
    ops (values the epilogue consumes that do not depend on the dot, e.g. a
    bias ``broadcast_in_dim``) are emitted outside the kernel."""

    template = "kDot"

    def run(self, graph, cluster, read, env, masked):
        from ..kernels.matmul.ops import matmul_fused

        dot = next(op for op in cluster.ops if op.opcode == "dot_general")
        acc_v = dot.outputs[0]
        dep = {acc_v.vid}
        prologue: List[DOp] = []
        epilogue: List[DOp] = []
        for op in cluster.ops:  # topological
            if op is dot:
                continue
            if any(v.vid in dep for v in op.inputs):
                epilogue.append(op)
                dep.update(o.vid for o in op.outputs)
            else:
                prologue.append(op)

        vals: Dict[int, Any] = {}

        def rd(v):
            return vals[v.vid] if v.vid in vals else read(v)

        for op in prologue:
            outs = emit_op(op, [rd(v) for v in op.inputs],
                           [env.padded_shape(o.shape) for o in op.outputs])
            for o, val in zip(op.outputs, outs):
                vals[o.vid] = val

        lhs, rhs = rd(dot.inputs[0]), rd(dot.inputs[1])
        # epilogue boundary operands beyond the accumulator, pre-broadcast
        # to full (M, N) tiles
        extra_ids: List[int] = []
        extras: List[Any] = []
        scalars: Dict[int, Any] = {}
        for op in epilogue:
            for v in op.inputs:
                if v.vid in dep or v.vid in scalars or v.vid in extra_ids:
                    continue
                if v.rank == 0:
                    scalars[v.vid] = (np.asarray(v.literal)
                                      if v.literal is not None else rd(v))
                else:
                    extra_ids.append(v.vid)
                    extras.append(rd(v))
        extras = _to_blocks(extras, env.padded_shape(acc_v.shape))

        live = cluster_live_outs(graph, cluster)
        kernel_outs = [v for v in live if v.vid in dep]
        result = {v.vid: vals[v.vid] for v in live if v.vid not in dep}
        expr = _cluster_expr(epilogue, [acc_v.vid] + extra_ids, scalars,
                             [v.vid for v in kernel_outs])

        m_d, k_d = dot.inputs[0].shape
        n_d = dot.inputs[1].shape[1]

        def bound(d):
            if masked and env.is_dynamic(d):
                return env.actual_dim(d)
            return env.padded_dim(d)

        outs = matmul_fused(lhs, rhs, extras, expr,
                            valid_mnk=(bound(m_d), bound(n_d), bound(k_d)),
                            out_dtypes=[v.dtype for v in kernel_outs],
                            acc_dtype=acc_v.dtype)
        result.update({v.vid: o for v, o in zip(kernel_outs, outs)})
        return result


def pallas_cluster_kernels() -> Dict[str, ClusterKernel]:
    """Fresh instances of the built-in Pallas cluster kernels, keyed by the
    fusion-plan template they execute (what ``backend="pallas"`` registers)."""
    kernels = (PallasLoopKernel(), PallasInputKernel(), PallasDotKernel())
    return {k.template: k for k in kernels}


def _run_graph(graph: DGraph, arrays, env: _ShapeEnv, masked: bool,
               plan=None,
               kernels: Optional[Mapping[str, ClusterKernel]] = None):
    vals: Dict[int, Any] = {}
    for p, a in zip(graph.params, arrays):
        vals[p.vid] = a

    # the lowered buffer plan's free/donate lines, keyed by op identity:
    # after an op runs, drop the references the plan proved dead — under
    # jax async dispatch the donor of a completed op is genuinely
    # releasable, so the executor's live set tracks the planned one
    memory_plan = getattr(graph, "memory_plan", None)
    frees_by_oid: Dict[int, List[int]] = {}
    if memory_plan is not None:
        for idx, vids in memory_plan.frees_after(graph).items():
            if 0 <= idx < len(graph.ops):
                frees_by_oid[graph.ops[idx].oid] = vids

    def read(v: DValue):
        if v.vid in vals:
            return vals[v.vid]
        if v.literal is not None:
            return jnp.asarray(v.literal)
        raise KeyError(f"undefined value {v!r}")

    def run_op(op):
        ins = [read(v) for v in op.inputs] + [read(v) for v in op.shape_operands]
        if op.opcode in REGION_OPS:
            outs = emit_region_op(op, ins, env, masked)
        elif masked:
            out_shapes = [env.padded_shape(o.shape) for o in op.outputs]
            outs = _emit_masked(op, ins, out_shapes, env)
        else:
            out_shapes = [env.padded_shape(o.shape) for o in op.outputs]
            outs = emit_op(op, ins, out_shapes)
        for o, val in zip(op.outputs, outs):
            vals[o.vid] = val
        for vid in frees_by_oid.get(op.oid, ()):
            vals.pop(vid, None)

    if kernels and plan is not None:
        for cluster in plan.clusters:
            kern = kernels.get(cluster.template) if cluster.template else None
            if kern is not None and kern.demoted:
                kern = None  # struck out: straight to the per-op path
            if kern is not None:
                sp = (obs_trace.ACTIVE.begin(
                          "kernel.cluster", cat="backend",
                          template=cluster.template,
                          kernel=type(kern).__name__, ops=len(cluster.ops))
                      if obs_trace.ACTIVE is not None else None)
                try:
                    if faults.ACTIVE is not None:
                        faults.ACTIVE.check("kernel.cluster",
                                            key=cluster.template)
                    vals.update(kern.run(graph, cluster, read, env, masked))
                    kern.runs += 1
                    if sp is not None:
                        sp.end(runs=kern.runs)
                    for op in cluster.ops:
                        for vid in frees_by_oid.get(op.oid, ()):
                            vals.pop(vid, None)
                    continue
                except CONTROL_EXCEPTIONS:
                    if sp is not None:
                        sp.end(error=True)
                    raise
                except Exception:
                    if sp is not None:
                        sp.end(error=True, strikes=kern.strikes + 1)
                    kern.strike()  # conservative fallback to XLA
            for op in cluster.ops:
                run_op(op)
    else:
        for op in graph.toposorted():
            run_op(op)
    return [read(o) for o in graph.outputs]


def build_exact_executor(graph: DGraph, plan=None,
                         kernels: Optional[Mapping[str, ClusterKernel]] = None,
                         ) -> Callable:
    """Executor running at exact concrete shapes (static-fallback path)."""
    syms = dyn_symbols(graph)

    def run(*arrays):
        bindings: Dict[int, int] = {}
        for p, a in zip(graph.params, arrays):
            for d, size in zip(p.shape, a.shape):
                if isinstance(d, SymDim):
                    c = graph.store.canon_dim(d)
                    if isinstance(c, SymDim):
                        bindings[c.uid] = int(size)
        env = _ShapeEnv(graph, padded=bindings, actual=dict(bindings))
        return _run_graph(graph, arrays, env, masked=False, plan=plan,
                          kernels=kernels)

    return run


def build_padded_executor(graph: DGraph, padded_bindings: Dict[int, int],
                          sym_order: Sequence[SymDim], plan=None,
                          kernels: Optional[Mapping[str, ClusterKernel]] = None,
                          ) -> Callable:
    """Executor for one bucket signature: ``run(lens_i32, *padded_arrays)``.

    ``padded_bindings`` maps canonical symbol uid -> padded size (static for
    this artifact); ``lens_i32`` carries the actual sizes at runtime in
    ``sym_order`` — the artifact is exact for any actuals ≤ the bucket.
    ``kernels`` maps fusion-plan templates to :class:`ClusterKernel`
    implementations (the backend's registration): clusters whose template
    is covered execute through the fused kernels (§4.3 codegen), the rest
    through per-op XLA emission.
    """
    uids = [s.uid for s in sym_order]

    def run(lens, *arrays):
        actual = {uid: lens[i] for i, uid in enumerate(uids)}
        env = _ShapeEnv(graph, padded=padded_bindings, actual=actual)
        return _run_graph(graph, arrays, env, masked=True, plan=plan,
                          kernels=kernels)

    return run
