"""Code generation: DHLO graph → device executables — DISC §4.3.

Two executors are generated from one graph:

* :func:`build_exact_executor` — runs the graph at the call's exact concrete
  shapes.  Used by the static-fallback path (§4.4) and as the correctness
  oracle.
* :func:`build_padded_executor` — the dynamic-shape artifact: traced/jitted
  once per *bucket signature*, it executes at padded shapes while taking the
  **actual lengths as a runtime i32 operand** (`lens`).  Masking makes it
  exact for every shape ≤ bucket:

  - inputs are zero-padded on the host (runtime.py), so padded regions start
    clean;
  - every *position-mixing* op (reduce, dot contraction, reverse cumsum,
    sort, arg-reduce) masks dynamic axes with the op's padding identity
    (``propagation.OP_TABLE.pad_identity``) right before mixing;
  - masks are canonical per symbolic dim: prefix masks ``iota < len`` for
    input symbols, Kronecker products for reshape-merged dims (matching the
    row-major garbage pattern of reshaped padded data), prefix masks for
    concat-sum / slice-affine dims;
  - ``concatenate`` along a dynamic axis is re-emitted as dynamic-update-
    slices at *traced actual offsets*, keeping valid data prefix-contiguous.

  This is the paper's "shape-adaptive" codegen: one artifact, any runtime
  shape (≤ bucket), with launch-configuration decisions (here: mask/no-mask,
  vectorized variants in the Pallas backend) resolved from runtime shape
  scalars.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .dhlo import DGraph, DOp, DValue
from .emit import emit_op
from .propagation import op_info
from .symshape import SymDim

__all__ = ["build_exact_executor", "build_padded_executor", "dyn_symbols"]


def dyn_symbols(graph: DGraph) -> List[SymDim]:
    """Ordered list of *input* symbolic dims (canonical, deduped)."""
    seen: Dict[int, SymDim] = {}
    for p in graph.params:
        for d in p.shape:
            if isinstance(d, SymDim):
                c = graph.store.canon_dim(d)
                if isinstance(c, SymDim) and c.uid not in seen:
                    seen[c.uid] = c
    return list(seen.values())


class _ShapeEnv:
    """Evaluates symbolic dims at trace time (padded ints + traced actuals)."""

    def __init__(self, graph: DGraph, padded: Dict[int, int],
                 actual: Dict[int, Any]) -> None:
        self.graph = graph
        self.store = graph.store
        self.exprs = getattr(graph, "dim_exprs", {})
        self.padded = dict(padded)   # canonical uid -> python int
        self.actual = dict(actual)   # canonical uid -> traced i32 (or int)
        self._masks: Dict[Tuple[int, int], Any] = {}

    def _canon(self, d):
        c = self.store.canon_dim(d)
        return c

    def padded_dim(self, d) -> int:
        if isinstance(d, int):
            return d
        c = self._canon(d)
        if isinstance(c, int):
            return c
        if c.uid in self.padded:
            return self.padded[c.uid]
        expr = self.exprs.get(c.uid) or self.exprs.get(d.uid)
        if expr is None:
            raise KeyError(f"unbound dim {d!r}")
        return int(self._eval(expr, self.padded))

    def actual_dim(self, d):
        if isinstance(d, int):
            return d
        c = self._canon(d)
        if isinstance(c, int):
            return c
        if c.uid in self.actual:
            return self.actual[c.uid]
        expr = self.exprs.get(c.uid) or self.exprs.get(d.uid)
        if expr is None:
            raise KeyError(f"unbound dim {d!r}")
        return self._eval(expr, self.actual)

    def _eval(self, expr, env):
        tag = expr[0]
        if tag == "mul":
            v = 1
            for x in expr[1]:
                v = v * (self._lookup(x, env))
            return v
        if tag == "sum":
            v = 0
            for x in expr[1]:
                v = v + self._lookup(x, env)
            return v
        if tag == "affine":
            _, base, a, b = expr
            return a * self._lookup(base, env) + b
        if tag == "div":
            _, base, k = expr
            return self._lookup(base, env) // k
        raise ValueError(f"bad dim expr {expr}")

    def _lookup(self, d, env):
        if isinstance(d, int):
            return d
        c = self._canon(d)
        if isinstance(c, int):
            return c
        if c.uid in env:
            return env[c.uid]
        expr = self.exprs.get(c.uid) or self.exprs.get(d.uid)
        if expr is None:
            raise KeyError(f"unbound dim {d!r}")
        return self._eval(expr, env)

    def is_dynamic(self, d) -> bool:
        if isinstance(d, int):
            return False
        c = self._canon(d)
        return isinstance(c, SymDim)

    def padded_shape(self, shape) -> Tuple[int, ...]:
        return tuple(self.padded_dim(d) for d in shape)

    # ----------------------------------------------------------- masks --
    def mask_for_dim(self, d) -> Optional[Any]:
        """Canonical validity mask (bool[padded]) for a dynamic dim."""
        if not self.is_dynamic(d):
            return None
        c = self._canon(d)
        psize = self.padded_dim(c)
        key = (c.uid, psize)
        if key in self._masks:
            return self._masks[key]
        expr = self.exprs.get(c.uid)
        if expr is not None and expr[0] == "mul":
            # reshape-merged dim: Kronecker product of factor masks matches
            # the row-major garbage pattern of reshaped padded data
            factors = expr[1]
            m = None
            for f in factors:
                fp = self.padded_dim(f) if not isinstance(f, int) else f
                fm = self.mask_for_dim(f) if not isinstance(f, int) else None
                if fm is None:
                    fm = jnp.ones((fp,), dtype=bool)
                m = fm if m is None else (m[:, None] & fm[None, :]).reshape(-1)
            mask = m
        else:
            actual = self.actual_dim(c)
            mask = lax.broadcasted_iota(jnp.int32, (psize,), 0) < actual
        self._masks[key] = mask
        return mask

    def mask_axes(self, x, shape, axes, fill) -> Any:
        """Apply canonical masks along ``axes`` of value with symbolic shape."""
        for ax in axes:
            m = self.mask_for_dim(shape[ax])
            if m is None:
                continue
            bshape = [1] * x.ndim
            bshape[ax] = m.shape[0]
            x = jnp.where(m.reshape(bshape), x, jnp.asarray(fill, x.dtype))
        return x


def _emit_masked(op: DOp, inputs, out_shapes, env: _ShapeEnv):
    """emit_op + dynamic-axis masking for position-mixing ops."""
    code = op.opcode
    info = op_info(code)

    if code.startswith("reduce_") or code in ("argmax", "argmin"):
        axes = op.attrs.get("axes", ())
        src = op.inputs[0]
        dyn_axes = [a for a in axes if env.is_dynamic(src.shape[a])]
        if dyn_axes:
            fill = info.pad_identity if info.pad_identity is not None else 0.0
            x = env.mask_axes(inputs[0], src.shape, dyn_axes, fill)
            inputs = [x] + list(inputs[1:])
        return emit_op(op, inputs, out_shapes)

    if code == "dot_general":
        (lc, rc), (lb, rb) = op.attrs["dimension_numbers"]
        lhs_v, rhs_v = op.inputs[0], op.inputs[1]
        dyn_lc = [a for a in lc if env.is_dynamic(lhs_v.shape[a])]
        if dyn_lc:
            lhs = env.mask_axes(inputs[0], lhs_v.shape, dyn_lc, 0.0)
            inputs = [lhs, inputs[1]]
        return emit_op(op, inputs, out_shapes)

    if code in ("cumsum", "cumprod", "cummax"):
        params = op.attrs.get("_params", {})
        axis = params.get("axis", 0)
        src = op.inputs[0]
        if params.get("reverse", False) and env.is_dynamic(src.shape[axis]):
            fill = {"cumsum": 0.0, "cumprod": 1.0, "cummax": -np.inf}[code]
            x = env.mask_axes(inputs[0], src.shape, [axis], fill)
            inputs = [x]
        return emit_op(op, inputs, out_shapes)

    if code == "sort":
        params = op.attrs.get("_params", {})
        dim = params.get("dimension", -1)
        src = op.inputs[0]
        d = dim if dim >= 0 else src.rank + dim
        if env.is_dynamic(src.shape[d]):
            x = env.mask_axes(inputs[0], src.shape, [d], np.inf)
            inputs = [x]
        return emit_op(op, inputs, out_shapes)

    if code == "concatenate":
        axis = op.attrs["dimension"]
        out_v = op.outputs[0]
        if env.is_dynamic(out_v.shape[axis]) and len(op.inputs) > 1:
            # dynamic-axis concat: DUS at traced actual offsets keeps valid
            # data prefix-contiguous (canonical for the sum-derived dim)
            out = jnp.zeros(out_shapes[0], dtype=out_v.dtype)
            offset = jnp.asarray(0, jnp.int32)
            for v, x in zip(op.inputs, inputs):
                starts = [offset if ax == axis else 0 for ax in range(x.ndim)]
                out = lax.dynamic_update_slice(out, x, starts)
                alen = env.actual_dim(v.shape[axis])
                offset = offset + jnp.asarray(alen, jnp.int32)
            return [out]
        return emit_op(op, inputs, out_shapes)

    if code == "pad":
        cfg = op.attrs["padding_config"]
        src = op.inputs[0]
        for ax, (lo, hi, interior) in enumerate(cfg):
            if env.is_dynamic(src.shape[ax]) and (hi > 0 or interior > 0):
                raise NotImplementedError(
                    "hi/interior pad along a dynamic axis is not "
                    "bucket-paddable; pre-pad on the host instead")
        return emit_op(op, inputs, out_shapes)

    return emit_op(op, inputs, out_shapes)


# opcodes whose emission is shape-oblivious on a flattened block — the
# eligibility set for the Pallas fused-elementwise backend (§4.3)
_PALLAS_ELIGIBLE = {
    "add", "sub", "mul", "div", "max", "min", "pow", "neg", "exp", "exp2",
    "expm1", "log", "log1p", "tanh", "logistic", "sqrt", "rsqrt", "abs",
    "sign", "floor", "ceil", "round", "erf", "sin", "cos", "square",
    "integer_pow", "select", "convert", "stop_gradient", "copy",
    "eq", "ne", "lt", "gt", "le", "ge", "and", "or", "not",
}

_REDUCE_KINDS = {"reduce_sum": "sum", "reduce_max": "max",
                 "reduce_min": "min", "reduce_prod": "prod"}


def _no_escaping_intermediates(graph: DGraph, cluster) -> bool:
    """Only the root output may be consumed outside the cluster (a single
    fused kernel materializes exactly one result)."""
    member_ids = {op.oid for op in cluster.ops}
    root_out = cluster.ops[-1].outputs[0].vid
    users = graph.users()
    out_ids = {o.vid for o in graph.outputs}
    for op in cluster.ops:
        for o in op.outputs:
            if o.vid == root_out:
                continue
            if o.vid in out_ids:
                return False
            for user in users.get(o.vid, ()):
                if user.oid not in member_ids:
                    return False
    return True


def _pallas_loop_eligible(graph: DGraph, cluster) -> bool:
    """kLoop cluster executable as ONE flattened masked Pallas kernel:
    every op shape-oblivious elementwise, every non-scalar value the same
    shape class (scalars are closure-captured)."""
    if cluster.kind != "loop" or len(cluster.ops) < 2:
        return False
    store = graph.store
    ref = cluster.ops[-1].outputs[0].shape
    for op in cluster.ops:
        if op.opcode not in _PALLAS_ELIGIBLE:
            return False
        for v in list(op.inputs) + list(op.outputs):
            if v.rank == 0:
                continue
            if len(v.shape) != len(ref) or not store.shapes_equal(v.shape, ref):
                return False
    return _no_escaping_intermediates(graph, cluster)


def _pallas_input_eligible(graph: DGraph, cluster) -> bool:
    """kInput cluster: shape-oblivious producers + one last-axis reduce root."""
    if cluster.kind != "input" or len(cluster.ops) < 2:
        return False
    root = cluster.ops[-1]
    if root.opcode not in _REDUCE_KINDS:
        return False
    axes = root.attrs.get("axes", ())
    src = root.inputs[0]
    if tuple(axes) != (src.rank - 1,):
        return False
    store = graph.store
    ref = src.shape
    for op in cluster.ops[:-1]:
        if op.opcode not in _PALLAS_ELIGIBLE:
            return False
        for v in list(op.inputs) + list(op.outputs):
            if v.rank == 0:
                continue
            if len(v.shape) != len(ref) or not store.shapes_equal(v.shape, ref):
                return False
    return _no_escaping_intermediates(graph, cluster)


def _cluster_expr(cluster, input_vids, scalar_consts, *, skip_root=False):
    """Build the unrolled expression closure a Pallas kernel body executes.

    The per-op emission happens at kernel TRACE time — zero runtime
    interpretation, exactly the paper's compile-time codegen property."""
    ops = cluster.ops[:-1] if skip_root else cluster.ops
    last = cluster.ops[-1]

    def expr(*blocks):
        local: Dict[int, Any] = dict(zip(input_vids, blocks))
        local.update(scalar_consts)

        def rd(v):
            if v.vid in local:
                return local[v.vid]
            assert v.literal is not None, f"unbound {v!r}"
            return jnp.asarray(v.literal)

        out = None
        for op in ops:
            res = emit_op(op, [rd(v) for v in op.inputs], [None])
            for o, val in zip(op.outputs, res):
                local[o.vid] = val
            out = res[0]
        if skip_root:
            return local[last.inputs[0].vid]
        return out

    return expr


def _run_pallas_cluster(graph: DGraph, cluster, read, env: _ShapeEnv,
                        masked: bool):
    """Execute an eligible cluster through the fused Pallas kernels."""
    from ..kernels.fused_elementwise.ops import fused_elementwise
    from ..kernels.fused_reduce.ops import fused_reduce

    produced = {o.vid for op in cluster.ops for o in op.outputs}
    # boundary inputs: non-literal values consumed but not produced inside
    seen = []
    for op in cluster.ops:
        for v in op.inputs:
            if v.vid not in produced and v.literal is None and \
                    v.vid not in [s for s, _ in seen]:
                seen.append((v.vid, v))
    tensor_ids, scalar_consts = [], {}
    tensors = []
    for vid, v in seen:
        arr = read(v)
        if v.rank == 0:
            scalar_consts[vid] = arr
        else:
            tensor_ids.append(vid)
            tensors.append(arr)

    root = cluster.ops[-1]
    out_v = root.outputs[0]

    if cluster.kind == "loop":
        expr = _cluster_expr(cluster, tensor_ids, scalar_consts)
        # pointwise garbage stays confined to the padded region (which is
        # NOT a flat prefix under multi-dim padding) — downstream mixing
        # ops apply their own canonical masks, so no in-kernel mask here
        n_valid = int(np.prod(env.padded_shape(out_v.shape), dtype=np.int64))
        outs = fused_elementwise(expr, tensors, n_valid, [out_v.dtype])
        return {out_v.vid: outs[0].reshape(env.padded_shape(out_v.shape))}

    # kInput: masked last-axis reduce root
    expr = _cluster_expr(cluster, tensor_ids, scalar_consts, skip_root=True)
    src = root.inputs[0]
    last_dim = src.shape[-1]
    if masked and env.is_dynamic(last_dim):
        n_cols = env.actual_dim(last_dim)
    else:
        n_cols = env.padded_dim(last_dim)
    kind = _REDUCE_KINDS[root.opcode]
    out = fused_reduce(expr, tensors, n_cols, kind)
    return {out_v.vid: out.reshape(env.padded_shape(out_v.shape))}


def _run_graph(graph: DGraph, arrays, env: _ShapeEnv, masked: bool,
               plan=None, backend: str = "xla"):
    vals: Dict[int, Any] = {}
    for p, a in zip(graph.params, arrays):
        vals[p.vid] = a

    def read(v: DValue):
        if v.vid in vals:
            return vals[v.vid]
        if v.literal is not None:
            return jnp.asarray(v.literal)
        raise KeyError(f"undefined value {v!r}")

    def run_op(op):
        ins = [read(v) for v in op.inputs] + [read(v) for v in op.shape_operands]
        out_shapes = [env.padded_shape(o.shape) for o in op.outputs]
        if masked:
            outs = _emit_masked(op, ins, out_shapes, env)
        else:
            outs = emit_op(op, ins, out_shapes)
        for o, val in zip(op.outputs, outs):
            vals[o.vid] = val

    if backend == "pallas" and plan is not None:
        for cluster in plan.clusters:
            if _pallas_loop_eligible(graph, cluster) or \
                    _pallas_input_eligible(graph, cluster):
                try:
                    vals.update(_run_pallas_cluster(graph, cluster, read,
                                                    env, masked))
                    continue
                except Exception:
                    pass  # conservative fallback to the XLA path
            for op in cluster.ops:
                run_op(op)
    else:
        for op in graph.toposorted():
            run_op(op)
    return [read(o) for o in graph.outputs]


def build_exact_executor(graph: DGraph, plan=None,
                         backend: str = "xla") -> Callable:
    """Executor running at exact concrete shapes (static-fallback path)."""
    syms = dyn_symbols(graph)

    def run(*arrays):
        bindings: Dict[int, int] = {}
        for p, a in zip(graph.params, arrays):
            for d, size in zip(p.shape, a.shape):
                if isinstance(d, SymDim):
                    c = graph.store.canon_dim(d)
                    if isinstance(c, SymDim):
                        bindings[c.uid] = int(size)
        env = _ShapeEnv(graph, padded=bindings, actual=dict(bindings))
        return _run_graph(graph, arrays, env, masked=False, plan=plan,
                          backend=backend)

    return run


def build_padded_executor(graph: DGraph, padded_bindings: Dict[int, int],
                          sym_order: Sequence[SymDim], plan=None,
                          backend: str = "xla") -> Callable:
    """Executor for one bucket signature: ``run(lens_i32, *padded_arrays)``.

    ``padded_bindings`` maps canonical symbol uid -> padded size (static for
    this artifact); ``lens_i32`` carries the actual sizes at runtime in
    ``sym_order`` — the artifact is exact for any actuals ≤ the bucket.
    With ``backend="pallas"``, eligible fusion clusters execute through the
    fused Pallas kernels (§4.3 codegen), the rest through XLA.
    """
    uids = [s.uid for s in sym_order]

    def run(lens, *arrays):
        actual = {uid: lens[i] for i, uid in enumerate(uids)}
        env = _ShapeEnv(graph, padded=padded_bindings, actual=actual)
        return _run_graph(graph, arrays, env, masked=True, plan=plan,
                          backend=backend)

    return run
