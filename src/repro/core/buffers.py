"""Symbolic-shape memory planning — DISC §4.2.2 / BladeDISC++.

    "With emitted codes calculating shapes of each buffer at runtime, DISC
     is able to manage the buffer dynamically by emitting alloc and dealloc
     instructions ... 1) Based on shape constraint in the IR, performing
     buffer liveness analysis and optimization; 2) Lowering the alloc and
     dealloc with a cached allocator."

The planner here is *bucket-generic*: liveness intervals are expressed in
``Dim`` symbols and the reuse/donation assignment is decided once at
``lower()`` time, then holds for **every** bucket of the artifact.  Three
layers:

* :func:`liveness` + :func:`plan_buffers` — compile-time liveness analysis
  over the DHLO graph.  Reuse fires when interval byte-sizes are related
  under the symbolic comparison lattice (:func:`compare_sizes`): ``eq``
  when the canonical :class:`ByteSize` forms match, ``le`` when ``Dim.max``
  caps and ``multiple_of``/divisibility facts *prove* one size fits inside
  the other for every admissible binding, ``unknown`` otherwise.  In-place
  consumers (``dynamic_update_slice``/``scatter_add``) *donate* the dying
  operand's slot to their result.
* The plan compiles to an explicit wrapper IR —
  :class:`AllocLine`/:class:`ReuseLine`/:class:`DonateLine`/
  :class:`FreeLine` (inductor's ``MemoryPlanningLine`` shape) — which the
  dispatch emitter renders into generated source, the interpreted VM
  executes for real, and the AOT path realizes through XLA buffer
  donation (``BufferPlan.donatable_args``).
* :class:`CachedArena` — the runtime cached allocator of §4.2.2: free
  lists keyed by byte size, so alloc of a recurring size is O(1).

``plan_report`` quantifies peak memory over the program (per binding),
counting a donated output and its donor as *one* buffer — graph outputs
produced by an in-place consumer are not double-counted as live-to-end.
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .dhlo import DGraph, DValue
from .symshape import SizeExpr, SymDim

__all__ = ["liveness", "plan_buffers", "plan_report", "BufferPlan",
           "ByteSize", "DimBounds", "compare_sizes", "CachedArena",
           "MemoryPlanningLine", "AllocLine", "ReuseLine", "DonateLine",
           "FreeLine"]

# ops whose result may take over an operand's storage in place (XLA
# performs these updates in place when the operand is dead/donated)
_DONATING_OPS = {"dynamic_update_slice": 0, "scatter_add": 0}


def liveness(graph: DGraph) -> Dict[int, Tuple[int, int]]:
    """value id -> (def index, last-use index) over the topological op list."""
    spans: Dict[int, Tuple[int, int]] = {}
    for p in graph.params:
        spans[p.vid] = (-1, -1)
    for i, op in enumerate(graph.ops):
        for v in op.all_operands():
            if v.vid in spans:
                d, _ = spans[v.vid]
                spans[v.vid] = (d, i)
            else:  # constant
                spans[v.vid] = (-1, i)
        for o in op.outputs:
            spans[o.vid] = (i, i)
    n = len(graph.ops)
    for o in graph.outputs:
        if o.vid in spans:
            d, _ = spans[o.vid]
            spans[o.vid] = (d, n)  # outputs live past the end
    return spans


# ------------------------------------------------------- size lattice --

@dataclass(frozen=True)
class ByteSize:
    """Canonical symbolic byte size: ``coeff * prod(dim^power)`` bytes.

    ``dims`` is sorted by symbol *name* (stable across processes — uids
    are process-local counters) and derived product dims are expanded to
    their base symbols where the frontend recorded a ``("mul", ...)``
    expression, so ``reshape(B, S) -> (B*S,)`` compares equal.
    """

    coeff: int
    dims: Tuple[Tuple[SymDim, int], ...]

    def render(self) -> str:
        parts = ([str(self.coeff)]
                 if self.coeff != 1 or not self.dims else [])
        for d, p in self.dims:
            parts.append(d.name + (f"^{p}" if p > 1 else ""))
        return "*".join(parts) if parts else "1"

    def eval(self, bindings: Dict[int, int], graph: DGraph) -> int:
        from ..frontends.jaxpr_frontend import eval_dim
        v = self.coeff
        for d, p in self.dims:
            v *= eval_dim(graph, d, bindings) ** p
        return v

    def is_static(self) -> bool:
        return not self.dims


def _value_byte_size(graph: DGraph, v: DValue) -> ByteSize:
    """Canonical symbolic byte size of one value (dtype folded in)."""
    store = graph.store
    dim_exprs = getattr(graph, "dim_exprs", {})
    itemsize = int(np.dtype(v.dtype).itemsize) if v.dtype is not None else 4
    coeff = itemsize
    counts: Dict[SymDim, int] = {}

    def add(d, power: int) -> None:
        nonlocal coeff
        c = store.canon_dim(d) if isinstance(d, SymDim) else d
        if isinstance(c, int):
            coeff *= c ** power
            return
        expr = dim_exprs.get(c.uid)
        if expr is not None and expr[0] == "mul":
            for x in expr[1]:
                add(x, power)
            return
        counts[c] = counts.get(c, 0) + power

    for d in v.shape:
        add(d, 1)
    dims = tuple(sorted(counts.items(), key=lambda kv: (kv[0].name, kv[0].uid)))
    return ByteSize(coeff=coeff, dims=dims)


class DimBounds:
    """Provable per-dim bounds, the facts feeding the ``le`` proofs.

    * upper bounds come from ``Dim(max=...)`` caps on the bucket policy
      (runtime values beyond the cap are a contract violation, and
      buckets clamp there) and from constants the store refined;
    * lower bounds come from divisibility facts (``dim % k == 0`` with
      sizes >= 1 implies ``dim >= k``) — ``multiple_of`` contracts land
      in the store as divisors via the frontend/policy;
    * derived dims bound through their recorded ``dim_exprs``.
    """

    def __init__(self, graph: DGraph, policy: Optional[Any] = None) -> None:
        self.graph = graph
        self.store = graph.store
        self.dim_exprs = getattr(graph, "dim_exprs", {})
        # canonical uid -> cap, from every named member of the class
        self._caps: Dict[int, int] = {}
        if policy is not None:
            for d in self.store._dims.values():
                cap = policy.cap(d.name)
                if cap is None:
                    continue
                c = self.store.canon_dim(d)
                if isinstance(c, SymDim):
                    prev = self._caps.get(c.uid)
                    self._caps[c.uid] = cap if prev is None else min(prev, cap)

    def ub(self, d) -> Optional[int]:
        """Provable upper bound of a dim, or None."""
        if isinstance(d, int):
            return d
        c = self.store.canon_dim(d)
        if isinstance(c, int):
            return c
        # bounds recorded in the store (Dim.max declarations, region-op
        # carry widening) combine with policy caps: tightest wins
        cands = [x for x in (self._caps.get(c.uid), self.store.dim_bound(c))
                 if x is not None]
        if cands:
            return min(cands)
        expr = self.dim_exprs.get(c.uid)
        if expr is None:
            return None
        tag = expr[0]
        if tag == "mul":
            v = 1
            for x in expr[1]:
                u = self.ub(x)
                if u is None:
                    return None
                v *= u
            return v
        if tag == "sum":
            v = 0
            for x in expr[1]:
                u = self.ub(x)
                if u is None:
                    return None
                v += u
            return v
        if tag == "affine":  # a*base + b
            _, base, a, b = expr
            u = self.ub(base) if a > 0 else self.lb(base)
            if u is None:
                return None
            return a * u + b
        if tag == "div":
            _, base, k = expr
            u = self.ub(base)
            return None if u is None else u // k
        return None

    def lb(self, d) -> int:
        """Provable lower bound of a dim (>= 1: extents are positive)."""
        if isinstance(d, int):
            return d
        c = self.store.canon_dim(d)
        if isinstance(c, int):
            return c
        divs = self.store.known_divisors(c)
        lo = max(divs) if divs else 1
        expr = self.dim_exprs.get(c.uid)
        if expr is not None:
            tag = expr[0]
            if tag == "mul":
                v = 1
                for x in expr[1]:
                    v *= self.lb(x)
                lo = max(lo, v)
            elif tag == "sum":
                lo = max(lo, sum(self.lb(x) for x in expr[1]))
            elif tag == "affine":
                _, base, a, b = expr
                if a > 0:
                    lo = max(lo, a * self.lb(base) + b)
        return max(lo, 1)


def compare_sizes(a: ByteSize, b: ByteSize, bounds: DimBounds) -> str:
    """The symbolic size lattice: ``"eq"`` / ``"le"`` (a <= b for every
    admissible binding) / ``"unknown"``.

    ``le`` is proved by cancelling shared factors, upper-bounding ``a``'s
    surplus dims with their caps and lower-bounding ``b``'s surplus dims
    with their divisibility facts: ``a <= b`` iff
    ``a.coeff * prod(ub(d)^p_surplus_a) <= b.coeff * prod(lb(d)^p_surplus_b)``.
    """
    if a == b:
        return "eq"
    pa = {d.uid: (d, p) for d, p in a.dims}
    pb = {d.uid: (d, p) for d, p in b.dims}
    lhs, rhs = a.coeff, b.coeff
    for uid in set(pa) | set(pb):
        da, xa = pa.get(uid, (None, 0))
        db, xb = pb.get(uid, (None, 0))
        if xa > xb:  # surplus on a's side: needs a cap
            u = bounds.ub(da)
            if u is None:
                return "unknown"
            lhs *= u ** (xa - xb)
        elif xb > xa:  # surplus on b's side: its lower bound helps
            rhs *= bounds.lb(db) ** (xb - xa)
    return "le" if lhs <= rhs else "unknown"


# ----------------------------------------------------------- wrapper IR --

@dataclass(frozen=True)
class MemoryPlanningLine:
    """One step of the memory plan (inductor-wrapper-IR shape): executed
    around op ``index`` — alloc/reuse/donate before the op runs, free
    after it."""

    index: int
    vid: int
    slot: int


@dataclass(frozen=True)
class AllocLine(MemoryPlanningLine):
    size: ByteSize = None  # type: ignore[assignment]


@dataclass(frozen=True)
class ReuseLine(MemoryPlanningLine):
    kind: str = "eq"            # "eq" | "le"
    size: ByteSize = None       # type: ignore[assignment]
    slot_size: ByteSize = None  # type: ignore[assignment]


@dataclass(frozen=True)
class DonateLine(MemoryPlanningLine):
    src_vid: int = -1
    opcode: str = ""


@dataclass(frozen=True)
class FreeLine(MemoryPlanningLine):
    pass


# ----------------------------------------------------------------- plan --

@dataclass
class BufferPlan:
    """Static slot assignment: value id -> slot id (+ the wrapper IR).

    ``symbolic=True`` plans fire ``le`` reuse and donation on top of the
    exact size-class (``eq``) rule; ``symbolic=False`` reproduces the
    per-bucket baseline (each value its own slot, no reuse at all).
    """

    slot_of: Dict[int, int]
    n_slots: int
    n_values: int
    # per-slot size-class key (shape-compatibility class used for reuse)
    slot_class: Dict[int, Tuple]
    # wrapper IR, ordered by op index then kind
    lines: Tuple[MemoryPlanningLine, ...] = ()
    # symbolic byte size of every planned value / of every slot (max member)
    value_size: Dict[int, ByteSize] = field(default_factory=dict)
    slot_size: Dict[int, ByteSize] = field(default_factory=dict)
    reuse_counts: Dict[str, int] = field(default_factory=dict)
    # param indices proven dead before the graph ends (safe donate_argnums)
    donatable_args: Tuple[int, ...] = ()
    # vid -> donor vid for in-place donations
    donated_from: Dict[int, int] = field(default_factory=dict)
    spans: Dict[int, Tuple[int, int]] = field(default_factory=dict)
    symbolic: bool = True

    # ------------------------------------------------------- reporting --
    def report(self, graph: DGraph, bindings: Dict[int, int],
               itemsize: int = 4) -> Dict[str, int]:
        """Concrete total bytes with/without reuse for given dim bindings
        (sum over all values/slots — see :func:`plan_report` for the
        peak-over-time view)."""
        from ..frontends.jaxpr_frontend import eval_dim

        def nbytes(v: DValue) -> int:
            n = 1
            for d in v.shape:
                n *= eval_dim(graph, d, bindings) if not isinstance(d, int) else d
            return n * itemsize

        vals = {v.vid: v for op in graph.ops for v in op.outputs}
        no_reuse = sum(nbytes(v) for v in vals.values())
        slot_bytes: Dict[int, int] = {}
        for vid, v in vals.items():
            s = self.slot_of.get(vid)
            if s is None:
                continue
            slot_bytes[s] = max(slot_bytes.get(s, 0), nbytes(v))
        return {
            "bytes_no_reuse": no_reuse,
            "bytes_with_reuse": sum(slot_bytes.values()),
            "slots": self.n_slots,
            "values": self.n_values,
        }

    def _value_labels(self, graph: DGraph) -> Dict[int, str]:
        """Deterministic per-graph labels (vids are process-local)."""
        labels: Dict[int, str] = {}
        for i, p in enumerate(graph.params):
            labels[p.vid] = f"%p{i}"
        n = 0
        for op in graph.ops:
            for o in op.outputs:
                labels[o.vid] = f"%t{n}"
                n += 1
        return labels

    def render_lines(self, graph: DGraph) -> List[str]:
        """The plan as alloc/reuse/donate/free text — what the dispatch
        emitter embeds in generated source (deterministic: names only)."""
        lab = self._value_labels(graph)
        out: List[str] = []
        for ln in self.lines:
            v = lab.get(ln.vid, f"%{ln.vid}")
            if isinstance(ln, AllocLine):
                out.append(f"op{ln.index}: alloc  {v} -> slot{ln.slot}"
                           f"  [{ln.size.render()} B]")
            elif isinstance(ln, ReuseLine):
                proof = (f"eq {ln.size.render()}" if ln.kind == "eq" else
                         f"le {ln.size.render()} <= {ln.slot_size.render()}")
                out.append(f"op{ln.index}: reuse  {v} -> slot{ln.slot}"
                           f"  ({proof})")
            elif isinstance(ln, DonateLine):
                src = lab.get(ln.src_vid, f"%{ln.src_vid}")
                out.append(f"op{ln.index}: donate {src} -> {v}"
                           f"  (in-place {ln.opcode}, slot{ln.slot})")
            elif isinstance(ln, FreeLine):
                out.append(f"op{ln.index}: free   {v}  (slot{ln.slot})")
        return out

    def frees_after(self, graph: DGraph) -> Dict[int, List[int]]:
        """op index -> vids whose storage dies once that op ran (free +
        donate lines) — the executors drop these references for real."""
        out: Dict[int, List[int]] = defaultdict(list)
        for ln in self.lines:
            if isinstance(ln, FreeLine):
                out[ln.index].append(ln.vid)
            elif isinstance(ln, DonateLine):
                out[ln.index].append(ln.src_vid)
        return dict(out)

    # ----------------------------------------------------- peak algebra --
    def _slot_intervals(self) -> Dict[int, Tuple[int, int]]:
        """slot -> (first def, last live point) over its member values."""
        iv: Dict[int, Tuple[int, int]] = {}
        for vid, s in self.slot_of.items():
            d, l = self.spans[vid]
            if s in iv:
                d0, l0 = iv[s]
                iv[s] = (min(d0, d), max(l0, l))
            else:
                iv[s] = (d, l)
        return iv

    @staticmethod
    def _render_sum(terms: List[ByteSize]) -> str:
        """Σ of byte sizes as a canonical polynomial string (names only —
        deterministic across processes)."""
        acc: Dict[Tuple, int] = {}
        for x in terms:
            k = tuple((d.name, p) for d, p in x.dims)
            acc[k] = acc.get(k, 0) + x.coeff
        parts = []
        for k in sorted(acc, key=lambda k: (-len(k), k)):
            parts.append(ByteSize(acc[k], tuple(
                (SymDim(name=nm, uid=-1, rep=1), p) for nm, p in k)).render())
        return " + ".join(parts) if parts else "0"

    def symbolic_peak(self) -> str:
        """Arena footprint with reuse, as an exact symbolic expression:
        Σ over slots of the slot's (proven-max) byte size.  Holds for
        every bucket — this is what the slot arena keeps resident."""
        return self._render_sum(list(self.slot_size.values()))

    def symbolic_peak_no_reuse(self) -> str:
        """Baseline footprint without liveness analysis: every value its
        own allocation, held to the end (Σ over all values)."""
        return self._render_sum(list(self.value_size.values()))

    def concrete_peaks(self, graph: DGraph,
                       bindings: Dict[int, int]) -> Dict[str, int]:
        """Concrete byte numbers at one binding:

        * ``peak_bytes``     — peak over program points of live *slot*
          bytes (liveness frees applied; donation merges the in-place
          pair into one buffer);
        * ``arena_bytes``    — Σ slot maxes: the resident footprint of a
          slot arena that keeps buffers cached between calls
          (steady-state serving);
        * ``no_reuse_bytes`` — Σ all values: the per-bucket baseline with
          no liveness analysis (alloc per value, free at graph end).
        """
        n = max(len(graph.ops), 1)
        slot_iv = self._slot_intervals()
        slot_b = {s: max(self.value_size[vid].eval(bindings, graph)
                         for vid, sl in self.slot_of.items() if sl == s)
                  for s in slot_iv}
        peak = 0
        for t in range(n):
            live = sum(b for s, b in slot_b.items()
                       if slot_iv[s][0] <= t <= slot_iv[s][1])
            peak = max(peak, live)
        no_reuse = sum(self.value_size[vid].eval(bindings, graph)
                       for vid in self.slot_of)
        return {"peak_bytes": peak,
                "arena_bytes": sum(slot_b.values()),
                "no_reuse_bytes": no_reuse}


def plan_buffers(graph: DGraph, policy: Optional[Any] = None, *,
                 symbolic: bool = True, donation: bool = True) -> BufferPlan:
    """Greedy interval coloring over symbolic liveness intervals.

    Reuse fires on ``eq`` size classes, on ``le``-provable fits (caps +
    divisibility, via :func:`compare_sizes`), and through in-place
    donation — all decided once, holding for every bucket.  With
    ``symbolic=False`` the planner degrades to the per-bucket baseline:
    one slot per value, no sharing (the planning-off contrast used by
    ``benchmarks/bench_buffers.py``); ``donation=False`` additionally
    disables the in-place realization and reports no donatable params.
    """
    spans = liveness(graph)
    store = graph.store
    bounds = DimBounds(graph, policy)
    interm = [o for op in graph.ops for o in op.outputs]
    n_ops = len(graph.ops)
    out_ids = {o.vid for o in graph.outputs}

    slot_of: Dict[int, int] = {}
    slot_class: Dict[int, Tuple] = {}
    value_size: Dict[int, ByteSize] = {}
    slot_size: Dict[int, ByteSize] = {}
    lines: List[MemoryPlanningLine] = []
    reuse_counts = {"eq": 0, "le": 0, "donated": 0}
    donated_from: Dict[int, int] = {}
    free_slots: List[int] = []                     # dead, reusable
    expiry: Dict[int, List[Tuple[int, int]]] = defaultdict(list)
    next_slot = 0

    for v in interm:
        value_size[v.vid] = _value_byte_size(graph, v)

    for i, op in enumerate(graph.ops):
        for s, vid in expiry.pop(i, []):
            free_slots.append(s)
            lines.append(FreeLine(index=i - 1, vid=vid, slot=s))

        # in-place donation: the dying operand's slot becomes the result's
        donor_slot: Optional[int] = None
        donor_vid: Optional[int] = None
        if symbolic and donation and op.opcode in _DONATING_OPS and op.outputs:
            cand = op.inputs[_DONATING_OPS[op.opcode]] if op.inputs else None
            if (cand is not None and cand.vid in slot_of
                    and spans[cand.vid][1] == i
                    and cand.vid not in out_ids
                    and value_size[cand.vid] == value_size[op.outputs[0].vid]):
                donor_slot, donor_vid = slot_of[cand.vid], cand.vid
                # the donor's pending expiry would free the slot out from
                # under the result — the donation subsumes it
                expiry[i + 1] = [(s, vid) for s, vid in expiry[i + 1]
                                 if vid != donor_vid]

        for oi, o in enumerate(op.outputs):
            sz = value_size[o.vid]
            key = store.size_class_key(o.vid)
            if oi == 0 and donor_slot is not None:
                s = donor_slot
                donated_from[o.vid] = donor_vid
                reuse_counts["donated"] += 1
                lines.append(DonateLine(index=i, vid=o.vid, slot=s,
                                        src_vid=donor_vid, opcode=op.opcode))
            elif not symbolic:
                s = next_slot
                next_slot += 1
                slot_class[s] = key
                slot_size[s] = sz
                lines.append(AllocLine(index=i, vid=o.vid, slot=s, size=sz))
            else:
                s = None
                for cand in free_slots:           # first pass: exact class
                    if slot_size[cand] == sz:
                        s, kind = cand, "eq"
                        break
                if s is None:                     # second pass: provable fit
                    best_waste = None
                    for cand in free_slots:
                        if compare_sizes(sz, slot_size[cand], bounds) != "le":
                            continue
                        u = bounds.ub(slot_size[cand].dims[0][0]) \
                            if slot_size[cand].dims else None
                        waste = slot_size[cand].coeff * (u or 1)
                        if best_waste is None or waste < best_waste:
                            s, kind, best_waste = cand, "le", waste
                if s is not None:
                    free_slots.remove(s)
                    reuse_counts[kind] += 1
                    lines.append(ReuseLine(index=i, vid=o.vid, slot=s,
                                           kind=kind, size=sz,
                                           slot_size=slot_size[s]))
                    if kind == "eq":
                        slot_size[s] = sz  # identical class, keep fresh form
                else:
                    s = next_slot
                    next_slot += 1
                    slot_class[s] = key
                    slot_size[s] = sz
                    lines.append(AllocLine(index=i, vid=o.vid, slot=s,
                                           size=sz))
            slot_of[o.vid] = s
            _, last = spans[o.vid]
            if last < n_ops:
                expiry[last + 1].append((s, o.vid))

    for s, vid in expiry.pop(n_ops, []):  # died at the last op
        lines.append(FreeLine(index=n_ops - 1, vid=vid, slot=s))

    # params proven dead before the graph ends: safe XLA donation targets
    donatable = tuple(
        pi for pi, p in enumerate(graph.params)
        if -1 < spans[p.vid][1] < n_ops and p.vid not in out_ids) \
        if donation else ()

    plan = BufferPlan(slot_of=slot_of, n_slots=next_slot,
                      n_values=len(interm), slot_class=slot_class,
                      lines=tuple(lines), value_size=value_size,
                      slot_size=slot_size, reuse_counts=reuse_counts,
                      donatable_args=donatable, donated_from=donated_from,
                      spans=spans, symbolic=symbolic)
    plan._bounds = bounds  # symbolic-peak rendering reuses the fact base
    return plan


def plan_report(graph: DGraph, plan: BufferPlan,
                bindings: Dict[int, int]) -> Dict[str, Any]:
    """Peak-memory report for one concrete binding.

    Donated outputs share their donor's slot interval, so a graph output
    produced by an in-place consumer is charged **once** — the earlier
    planner double-counted the donated operand as live-to-end alongside
    its consumer, overstating reported peaks.
    """
    return {
        **plan.concrete_peaks(graph, bindings),
        "symbolic_peak": plan.symbolic_peak(),
        "symbolic_peak_no_reuse": plan.symbolic_peak_no_reuse(),
        "slots": plan.n_slots,
        "values": plan.n_values,
        "reuse_counts": dict(plan.reuse_counts),
    }


# ----------------------------------------------------------- allocator --

class CachedArena:
    """Runtime cached allocator: free lists keyed by (dtype, nbytes)."""

    def __init__(self) -> None:
        self._free: Dict[Tuple[str, int], List[np.ndarray]] = defaultdict(list)
        self.allocs = 0
        self.reuses = 0
        self.peak_bytes = 0
        self._live_bytes = 0

    def alloc(self, shape: Tuple[int, ...], dtype) -> np.ndarray:
        dt = np.dtype(dtype)
        nbytes = int(np.prod(shape, dtype=np.int64)) * dt.itemsize
        key = (dt.str, nbytes)
        pool = self._free.get(key)
        if pool:
            self.reuses += 1
            buf = pool.pop()
            return buf.reshape(shape)
        self.allocs += 1
        self._live_bytes += nbytes
        self.peak_bytes = max(self.peak_bytes, self._live_bytes)
        return np.empty(shape, dtype=dt)

    def dealloc(self, buf: np.ndarray) -> None:
        dt = buf.dtype
        key = (dt.str, buf.nbytes)
        self._free[key].append(buf.reshape(-1))
