"""Dynamic buffer management — DISC §4.2.2.

    "With emitted codes calculating shapes of each buffer at runtime, DISC
     is able to manage the buffer dynamically by emitting alloc and dealloc
     instructions ... 1) Based on shape constraint in the IR, performing
     buffer liveness analysis and optimization; 2) Lowering the alloc and
     dealloc with a cached allocator."

We reproduce both halves:

* :func:`liveness` + :func:`plan_buffers` — compile-time liveness analysis
  over the DHLO graph; values whose *tensor-size-equality class* matches a
  dead value reuse its slot (the "shape compatibility" reuse rule).  The
  result is a static slot assignment computed **without concrete shapes**.
* :class:`CachedArena` — a runtime cached allocator (the TF/PyTorch
  allocator stand-in): free lists keyed by byte size, so alloc of a
  recurring size is O(1) with no fresh allocation.

The interpreted VM executes the plan for real; the jit path realizes the
same optimization through XLA buffer donation.  ``plan_report`` quantifies
peak-memory reduction (benchmarks/bench_buffers.py).
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from .dhlo import DGraph, DValue

__all__ = ["liveness", "plan_buffers", "BufferPlan", "CachedArena"]


def liveness(graph: DGraph) -> Dict[int, Tuple[int, int]]:
    """value id -> (def index, last-use index) over the topological op list."""
    spans: Dict[int, Tuple[int, int]] = {}
    for p in graph.params:
        spans[p.vid] = (-1, -1)
    for i, op in enumerate(graph.ops):
        for v in op.all_operands():
            if v.vid in spans:
                d, _ = spans[v.vid]
                spans[v.vid] = (d, i)
            else:  # constant
                spans[v.vid] = (-1, i)
        for o in op.outputs:
            spans[o.vid] = (i, i)
    n = len(graph.ops)
    for o in graph.outputs:
        if o.vid in spans:
            d, _ = spans[o.vid]
            spans[o.vid] = (d, n)  # outputs live past the end
    return spans


@dataclass
class BufferPlan:
    """Static slot assignment: value id -> slot id (+ metadata)."""

    slot_of: Dict[int, int]
    n_slots: int
    n_values: int
    # per-slot size-class key (shape-compatibility class used for reuse)
    slot_class: Dict[int, Tuple]

    def report(self, graph: DGraph, bindings: Dict[int, int],
               itemsize: int = 4) -> Dict[str, int]:
        """Concrete peak bytes with/without reuse for given dim bindings."""
        from ..frontends.jaxpr_frontend import eval_dim

        def nbytes(v: DValue) -> int:
            n = 1
            for d in v.shape:
                n *= eval_dim(graph, d, bindings) if not isinstance(d, int) else d
            return n * itemsize

        vals = {v.vid: v for op in graph.ops for v in op.outputs}
        no_reuse = sum(nbytes(v) for v in vals.values())
        slot_bytes: Dict[int, int] = {}
        for vid, v in vals.items():
            s = self.slot_of.get(vid)
            if s is None:
                continue
            slot_bytes[s] = max(slot_bytes.get(s, 0), nbytes(v))
        return {
            "bytes_no_reuse": no_reuse,
            "bytes_with_reuse": sum(slot_bytes.values()),
            "slots": self.n_slots,
            "values": self.n_values,
        }


def plan_buffers(graph: DGraph) -> BufferPlan:
    """Greedy interval coloring with size-class-compatible slot reuse."""
    spans = liveness(graph)
    store = graph.store
    interm = [o for op in graph.ops for o in op.outputs]
    slot_of: Dict[int, int] = {}
    slot_class: Dict[int, Tuple] = {}
    # free slots per size-class key
    free: Dict[Tuple, List[int]] = defaultdict(list)
    # release events: op index -> slots freed after that op
    expiry: Dict[int, List[int]] = defaultdict(list)
    next_slot = 0

    for i, op in enumerate(graph.ops):
        # release slots whose value died strictly before op i runs
        for s in expiry.pop(i, []):
            free[slot_class[s]].append(s)
        for o in op.outputs:
            key = store.size_class_key(o.vid)
            pool = free.get(key)
            if pool:
                s = pool.pop()
            else:
                s = next_slot
                next_slot += 1
                slot_class[s] = key
            slot_of[o.vid] = s
            _, last = spans[o.vid]
            if last < len(graph.ops):
                expiry[last + 1].append(s)
    return BufferPlan(slot_of=slot_of, n_slots=next_slot,
                      n_values=len(interm), slot_class=slot_class)


class CachedArena:
    """Runtime cached allocator: free lists keyed by (dtype, nbytes)."""

    def __init__(self) -> None:
        self._free: Dict[Tuple[str, int], List[np.ndarray]] = defaultdict(list)
        self.allocs = 0
        self.reuses = 0
        self.peak_bytes = 0
        self._live_bytes = 0

    def alloc(self, shape: Tuple[int, ...], dtype) -> np.ndarray:
        dt = np.dtype(dtype)
        nbytes = int(np.prod(shape, dtype=np.int64)) * dt.itemsize
        key = (dt.str, nbytes)
        pool = self._free.get(key)
        if pool:
            self.reuses += 1
            buf = pool.pop()
            return buf.reshape(shape)
        self.allocs += 1
        self._live_bytes += nbytes
        self.peak_bytes = max(self.peak_bytes, self._live_bytes)
        return np.empty(shape, dtype=dt)

    def dealloc(self, buf: np.ndarray) -> None:
        dt = buf.dtype
        key = (dt.str, buf.nbytes)
        self._free[key].append(buf.reshape(-1))
