"""NimbleVM — the interpreted-runtime baseline (paper §5.2 comparison).

Nimble "pre-builds runtime control as a VM ... the VM approach brings
interpretation overhead".  This module is a faithful stand-in: a per-call
interpreter over the DHLO graph that

* walks the op list in Python for **every** invocation,
* re-derives every shape with the interpreted ``eval_dim`` oracle,
* dispatches each op individually and synchronizes after each dispatch
  (modeling one kernel launch per op — no fusion),
* executes the lowered buffer plan's alloc/reuse/donate/free lines for
  real: references are dropped when the plan frees them, and the byte
  trail (planned peak vs the no-liveness baseline) lands in
  :class:`VMStats` — the measurement behind ``BENCH_buffers.json``.
DISC's generated dispatcher (``runtime.py``) does none of this per call —
the delta between the two is exactly the paper's Table-2 "CPU time" claim,
measured in ``benchmarks/bench_table2_nimble.py``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from .buffers import CachedArena, plan_buffers
from .codegen import REGION_OPS, _ShapeEnv, emit_region_op
from .dhlo import DGraph, DValue
from .emit import emit_op
from .symshape import SymDim

__all__ = ["NimbleVM"]


@dataclass
class VMStats:
    calls: int = 0
    op_dispatches: int = 0
    interp_seconds: float = 0.0
    # buffer-plan execution (bytes over the last call)
    planned_peak_bytes: int = 0    # peak live bytes under the plan's frees
    naive_peak_bytes: int = 0      # every value held to the end (no plan)
    reuses: int = 0                # reuse+donate lines executed


def _nbytes(x: Any) -> int:
    n = getattr(x, "nbytes", None)
    if n is not None:
        return int(n)
    size = int(np.prod(getattr(x, "shape", ()) or (1,)))
    return size * np.dtype(getattr(x, "dtype", np.float32)).itemsize


class NimbleVM:
    """Per-op interpreter over a DHLO graph (the Nimble-style baseline).

    ``memory_planning=False`` ignores the plan's free lines (every
    intermediate is held to the end of the call) — the per-bucket
    baseline that ``benchmarks/bench_buffers.py`` contrasts against.
    """

    def __init__(self, graph: DGraph, sync_per_op: bool = True,
                 memory_planning: bool = True) -> None:
        self.graph = graph
        self.sync_per_op = sync_per_op
        self.memory_planning = memory_planning
        self.buffer_plan = getattr(graph, "memory_plan", None) or \
            plan_buffers(graph, symbolic=memory_planning)
        self.arena = CachedArena()
        self.stats = VMStats()
        # plan lines → op-indexed free schedule, fixed once per VM
        self._frees = self.buffer_plan.frees_after(graph) \
            if memory_planning else {}
        self._reuse_lines = sum(self.buffer_plan.reuse_counts.values())
        obs_metrics.register_collector("vm", self._obs_collect)

    def _obs_collect(self) -> Dict[str, Any]:
        """Pull collector for ``disc.observe()["vm"]``."""
        s = self.stats
        return {"calls": s.calls, "op_dispatches": s.op_dispatches,
                "interp_seconds": round(s.interp_seconds, 6),
                "planned_peak_bytes": s.planned_peak_bytes,
                "naive_peak_bytes": s.naive_peak_bytes, "reuses": s.reuses}

    def __call__(self, *arrays):
        sp = (obs_trace.ACTIVE.begin("vm.interp", cat="vm",
                                     graph=self.graph.name)
              if obs_trace.ACTIVE is not None else None)
        try:
            return self._interp(arrays)
        finally:
            if sp is not None:
                sp.end(op_dispatches=self.stats.op_dispatches)

    def _interp(self, arrays):
        t0 = time.perf_counter()
        g = self.graph
        # interpret shape bindings
        bindings: Dict[int, int] = {}
        for p, a in zip(g.params, arrays):
            for d, size in zip(p.shape, a.shape):
                if isinstance(d, SymDim):
                    c = g.store.canon_dim(d)
                    if isinstance(c, SymDim):
                        bindings[c.uid] = int(size)
        env = _ShapeEnv(g, padded=bindings, actual=dict(bindings))

        vals: Dict[int, Any] = {p.vid: jnp.asarray(a)
                                for p, a in zip(g.params, arrays)}
        param_ids = set(vals)

        def read(v: DValue):
            if v.vid in vals:
                return vals[v.vid]
            assert v.literal is not None, f"undefined {v!r}"
            return jnp.asarray(v.literal)

        def interm_bytes():
            return sum(_nbytes(x) for vid, x in vals.items()
                       if vid not in param_ids)

        live_peak = 0
        naive_total = 0
        for i, op in enumerate(g.ops):
            ins = [read(v) for v in op.inputs]
            ins += [read(v) for v in op.shape_operands]
            if op.opcode in REGION_OPS:
                outs = emit_region_op(op, ins, env, masked=False)
            else:
                out_shapes = [env.padded_shape(o.shape) for o in op.outputs]
                outs = emit_op(op, ins, out_shapes)
            if self.sync_per_op:
                for o in outs:
                    jax.block_until_ready(o)  # one "kernel launch" per op
            self.stats.op_dispatches += 1
            for o, val in zip(op.outputs, outs):
                vals[o.vid] = val
                naive_total += _nbytes(val)
            live_peak = max(live_peak, interm_bytes())
            # execute the plan's free/donate lines for this program point
            for vid in self._frees.get(i, ()):
                vals.pop(vid, None)

        result = [read(o) for o in g.outputs]
        self.stats.calls += 1
        self.stats.planned_peak_bytes = live_peak if self.memory_planning \
            else naive_total
        self.stats.naive_peak_bytes = naive_total
        self.stats.reuses = self._reuse_lines
        self.stats.interp_seconds += time.perf_counter() - t0
        return result
