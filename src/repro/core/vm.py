"""NimbleVM — the interpreted-runtime baseline (paper §5.2 comparison).

Nimble "pre-builds runtime control as a VM ... the VM approach brings
interpretation overhead".  This module is a faithful stand-in: a per-call
interpreter over the DHLO graph that

* walks the op list in Python for **every** invocation,
* re-derives every shape with the interpreted ``eval_dim`` oracle,
* dispatches each op individually and synchronizes after each dispatch
  (modeling one kernel launch per op — no fusion),
* manages intermediate buffers through the liveness plan + cached arena.

DISC's generated dispatcher (``runtime.py``) does none of this per call —
the delta between the two is exactly the paper's Table-2 "CPU time" claim,
measured in ``benchmarks/bench_table2_nimble.py``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .buffers import CachedArena, liveness, plan_buffers
from .codegen import _ShapeEnv  # exact-shape env reuse
from .dhlo import DGraph, DValue
from .emit import emit_op
from .symshape import SymDim

__all__ = ["NimbleVM"]


@dataclass
class VMStats:
    calls: int = 0
    op_dispatches: int = 0
    interp_seconds: float = 0.0


class NimbleVM:
    """Per-op interpreter over a DHLO graph (the Nimble-style baseline)."""

    def __init__(self, graph: DGraph, sync_per_op: bool = True) -> None:
        self.graph = graph
        self.sync_per_op = sync_per_op
        self.buffer_plan = plan_buffers(graph)
        self.arena = CachedArena()
        self.stats = VMStats()

    def __call__(self, *arrays):
        t0 = time.perf_counter()
        g = self.graph
        # interpret shape bindings
        bindings: Dict[int, int] = {}
        for p, a in zip(g.params, arrays):
            for d, size in zip(p.shape, a.shape):
                if isinstance(d, SymDim):
                    c = g.store.canon_dim(d)
                    if isinstance(c, SymDim):
                        bindings[c.uid] = int(size)
        env = _ShapeEnv(g, padded=bindings, actual=dict(bindings))

        spans = liveness(g)
        vals: Dict[int, Any] = {p.vid: jnp.asarray(a)
                                for p, a in zip(g.params, arrays)}

        def read(v: DValue):
            if v.vid in vals:
                return vals[v.vid]
            assert v.literal is not None, f"undefined {v!r}"
            return jnp.asarray(v.literal)

        out_ids = {o.vid for o in g.outputs}
        for i, op in enumerate(g.ops):
            ins = [read(v) for v in op.inputs]
            ins += [read(v) for v in op.shape_operands]
            out_shapes = [env.padded_shape(o.shape) for o in op.outputs]
            outs = emit_op(op, ins, out_shapes)
            if self.sync_per_op:
                for o in outs:
                    jax.block_until_ready(o)  # one "kernel launch" per op
            self.stats.op_dispatches += 1
            for o, val in zip(op.outputs, outs):
                vals[o.vid] = val
            # interpreted dealloc: free values whose last use just passed
            dead = [vid for vid, (_, last) in spans.items()
                    if last == i and vid not in out_ids]
            for vid in dead:
                vals.pop(vid, None)

        result = [read(o) for o in g.outputs]
        self.stats.calls += 1
        self.stats.interp_seconds += time.perf_counter() - t0
        return result
