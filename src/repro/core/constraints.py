"""Shape-constraint store — DISC §4.2.1.

DISC collects two kinds of shape constraints *at compile time*, without any
concrete shape values:

* **dimension size equality** — dim ``i`` of tensor A equals dim ``j`` of
  tensor B (or another dim of A).  We keep these in a union–find over
  :class:`SymDim`; a symbol can also be *refined* to a concrete int when the
  graph proves it (e.g. equated with a static dim).
* **tensor size equality** — two tensors have the same number of elements
  (e.g. input/output of ``transpose``/``reshape``).  We keep these in a
  union–find over value ids, and additionally decide size equality
  structurally by comparing canonicalized :class:`SizeExpr` forms.

Both sources from the paper are implemented: (1) constraints implied by DHLO
op semantics (see ``propagation.py`` — e.g. ``Add`` operands/results share a
shape), and (2) constraints injected by the *frontend bridge* from high-level
framework ops whose structure is lost on lowering (e.g. ``jnp.split`` ⇒ all
output slices share a shape; see ``frontends/hints.py``).

The store also tracks **divisibility** facts (``dim % k == 0``), which the
code-generation layer uses for vectorized load/store version selection —
DISC's "more aggressive index calculation simplification".
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Set, Tuple, Union

from .symshape import Dim, SizeExpr, SymDim, SymShape, shape_key, size_of_shape

__all__ = ["ShapeConstraintStore", "ConstraintViolation"]


class ConstraintViolation(Exception):
    """Two facts contradict (e.g. a symbol equated with two distinct ints)."""


class _UnionFind:
    def __init__(self) -> None:
        self.parent: Dict[int, int] = {}
        self.rank: Dict[int, int] = {}

    def find(self, x: int) -> int:
        p = self.parent.setdefault(x, x)
        if p != x:
            p = self.find(p)
            self.parent[x] = p
        return p

    def union(self, a: int, b: int) -> int:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return ra
        if self.rank.get(ra, 0) < self.rank.get(rb, 0):
            ra, rb = rb, ra
        self.parent[rb] = ra
        if self.rank.get(ra, 0) == self.rank.get(rb, 0):
            self.rank[ra] = self.rank.get(ra, 0) + 1
        return ra


class ShapeConstraintStore:
    """Union-find backed store of dim-equality / size-equality / divisibility."""

    def __init__(self) -> None:
        self._dims: Dict[int, SymDim] = {}
        self._dim_uf = _UnionFind()
        # root uid -> concrete int, when a symbol class is refined to a constant
        self._dim_const: Dict[int, int] = {}
        # tensor-size equality over value ids (declared, not only structural)
        self._size_uf = _UnionFind()
        self._value_size: Dict[int, SizeExpr] = {}
        # divisibility facts: root uid -> lcm-ish set of known divisors
        self._divisors: Dict[int, Set[int]] = {}
        # declared/widened upper bounds: root uid -> cap.  Fed by
        # ``Dim(max=...)`` contracts at bridge time and by the region-op
        # carry-widening rule (propagation.carry_fixed_point); consumed by
        # the memory planner's ``DimBounds`` and by padded codegen for
        # widened carry dims (which have no input binding — they pad to
        # the cap).
        self._dim_bounds: Dict[int, int] = {}
        # mesh-divisibility facts (SPMD plan): dim name -> (axes, multiple).
        # A *plan-time* constraint: the bucket policy was tightened so
        # every bucket of the dim is a multiple of the owning mesh axes'
        # size product (repro.dist.spmd).
        self.mesh_divisibility: Dict[str, Tuple[Tuple[str, ...], int]] = {}
        self.n_dim_constraints = 0
        self.n_size_constraints = 0

    # ------------------------------------------------------------- dims --
    def _register(self, d: SymDim) -> None:
        self._dims.setdefault(d.uid, d)

    def canon_dim(self, d: Dim) -> Dim:
        """Canonical representative of a dim: a SymDim root or a concrete int."""
        if isinstance(d, int):
            return d
        self._register(d)
        root = self._dim_uf.find(d.uid)
        if root in self._dim_const:
            return self._dim_const[root]
        return self._dims[root]

    def assert_dim_eq(self, a: Dim, b: Dim) -> None:
        """Record ``a == b`` (dimension size equality constraint)."""
        ca, cb = self.canon_dim(a), self.canon_dim(b)
        if isinstance(ca, int) and isinstance(cb, int):
            if ca != cb:
                raise ConstraintViolation(f"dim conflict: {ca} != {cb}")
            return
        self.n_dim_constraints += 1
        if isinstance(ca, int):
            ca, cb = cb, ca  # make ca symbolic
        assert isinstance(ca, SymDim)
        root = self._dim_uf.find(ca.uid)
        if isinstance(cb, int):
            prev = self._dim_const.get(root)
            if prev is not None and prev != cb:
                raise ConstraintViolation(f"dim conflict: {prev} != {cb}")
            self._dim_const[root] = cb
            return
        assert isinstance(cb, SymDim)
        rb = self._dim_uf.find(cb.uid)
        ca_const = self._dim_const.get(root)
        cb_const = self._dim_const.get(rb)
        if ca_const is not None and cb_const is not None and ca_const != cb_const:
            raise ConstraintViolation(f"dim conflict: {ca_const} != {cb_const}")
        merged_div = self._divisors.get(root, set()) | self._divisors.get(rb, set())
        bounds = [x for x in (self._dim_bounds.get(root),
                              self._dim_bounds.get(rb)) if x is not None]
        new_root = self._dim_uf.union(root, rb)
        const = ca_const if ca_const is not None else cb_const
        if const is not None:
            self._dim_const[new_root] = const
        if merged_div:
            self._divisors[new_root] = merged_div
        if bounds:
            self._dim_bounds[new_root] = min(bounds)

    def note_dim_bound(self, d: Dim, bound: int) -> None:
        """Record an upper bound ``d <= bound``.  Tightest bound wins."""
        c = self.canon_dim(d)
        if isinstance(c, int):
            if c > bound:
                raise ConstraintViolation(
                    f"dim bound conflict: {c} > declared max {bound}")
            return
        root = self._dim_uf.find(c.uid)
        prev = self._dim_bounds.get(root)
        self._dim_bounds[root] = int(bound) if prev is None else min(prev, int(bound))

    def dim_bound(self, d: Dim) -> Optional[int]:
        """Known upper bound for ``d``, or None.  Concrete dims bound themselves."""
        c = self.canon_dim(d)
        if isinstance(c, int):
            return c
        return self._dim_bounds.get(self._dim_uf.find(c.uid))

    def dims_equal(self, a: Dim, b: Dim) -> bool:
        ca, cb = self.canon_dim(a), self.canon_dim(b)
        if isinstance(ca, int) and isinstance(cb, int):
            return ca == cb
        if isinstance(ca, SymDim) and isinstance(cb, SymDim):
            return ca.uid == cb.uid
        return False

    def assert_shape_eq(self, sa: SymShape, sb: SymShape) -> None:
        if len(sa) != len(sb):
            raise ConstraintViolation(f"rank mismatch: {sa} vs {sb}")
        for da, db in zip(sa, sb):
            self.assert_dim_eq(da, db)

    # ---------------------------------------------------------- divisors --
    def assert_divisible(self, d: Dim, k: int) -> None:
        c = self.canon_dim(d)
        if isinstance(c, int):
            if c % k != 0:
                raise ConstraintViolation(f"{c} not divisible by {k}")
            return
        self._divisors.setdefault(self._dim_uf.find(c.uid), set()).add(int(k))

    def known_divisors(self, d: Dim) -> Set[int]:
        c = self.canon_dim(d)
        if isinstance(c, int):
            return {k for k in range(1, min(c, 1025)) if c % k == 0}
        return set(self._divisors.get(self._dim_uf.find(c.uid), set())) | {1}

    def note_mesh_divisible(self, name: str,
                            axes: Tuple[str, ...], k: int) -> None:
        """Record an SPMD mesh constraint: dim ``name`` is sharded over
        mesh ``axes`` whose size product is ``k``, and every *bucket* of
        it is a multiple of ``k`` (the planner tightened the policy).

        Deliberately NOT recorded as an ``assert_divisible`` fact on the
        dim itself: the divisibility theorem holds for padded buckets,
        not for the dim's runtime values — the §4.4 escalation path
        compiles exact (possibly non-divisible) shapes, and a false
        divisor fact would mislead vectorization decisions keyed on
        ``known_divisors``."""
        self.mesh_divisibility[name] = (tuple(axes), int(k))

    def is_divisible(self, d: Dim, k: int) -> bool:
        c = self.canon_dim(d)
        if isinstance(c, int):
            return c % k == 0
        divs = self._divisors.get(self._dim_uf.find(c.uid), set())
        return any(known % k == 0 for known in divs)

    # -------------------------------------------------------------- sizes --
    def note_value_size(self, value_id: int, shape: SymShape) -> None:
        self._value_size[value_id] = size_of_shape(shape)

    def assert_size_eq(self, va: int, vb: int) -> None:
        """Record tensor-size equality between two value ids (§4.2.1)."""
        self.n_size_constraints += 1
        self._size_uf.union(va, vb)

    def size_expr(self, value_id: int) -> Optional[SizeExpr]:
        e = self._value_size.get(value_id)
        return e.canonicalize(self.canon_dim) if e is not None else None

    def sizes_equal(self, va: int, vb: int) -> bool:
        """Decide tensor-size equality: declared classes OR structural match."""
        if self._size_uf.find(va) == self._size_uf.find(vb):
            return True
        ea, eb = self.size_expr(va), self.size_expr(vb)
        return ea is not None and eb is not None and ea == eb

    def shapes_equal(self, sa: SymShape, sb: SymShape) -> bool:
        if len(sa) != len(sb):
            return False
        return all(self.dims_equal(a, b) for a, b in zip(sa, sb))

    # ---------------------------------------------------------- summaries --
    def shape_class_key(self, shape: SymShape) -> Tuple:
        """Hashable per-shape key under canonicalization — used by fusion."""
        return shape_key(shape, canon=self.canon_dim)

    def size_class_key(self, value_id: int) -> Tuple:
        root = self._size_uf.find(value_id)
        e = self.size_expr(value_id)
        if e is not None and e.is_static():
            return ("static", e.coeff)
        if e is not None:
            return ("expr", e.coeff, tuple((d.uid, p) for d, p in e.dims))
        return ("class", root)

    def stats(self) -> Dict[str, int]:
        return {
            "dim_constraints": self.n_dim_constraints,
            "size_constraints": self.n_size_constraints,
            "dim_symbols": len(self._dims),
            "mesh_constraints": len(self.mesh_divisibility),
        }
