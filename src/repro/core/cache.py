"""Compile cache — keyed on (pattern fingerprint, bucket signature).

DISC §2: "these fusion engines will compile and generate kernel for every
emerging shape, even though some of them share the same computation
pattern" — the cache key here deliberately contains **no concrete shapes**,
only the shape-free graph fingerprint and the bucket signature, so compile
count is O(#buckets), not O(#shapes).

Also implements §4.4's static/dynamic mix: signatures that stay hot are
*escalated* to exact-shape static specializations (better codegen: no
masking, no padding waste), bounded by an LRU budget.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Set, Tuple

from ..errors import (CONTROL_EXCEPTIONS, DEFAULT_RETRY, RetryPolicy,
                      wrap_compile_error)
from ..ft import faults
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace

__all__ = ["CompileCache", "CacheStats"]


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    compile_seconds: float = 0.0
    escalations: int = 0
    evictions: int = 0
    # promote-on-change re-lowerings: a call broke a dim tie inferred from
    # the first call, so the artifact was re-lowered with independent dims
    promotions: int = 0
    # fault plane: transient compile failures retried with backoff, and
    # §4.4 exact escalations whose compile failed permanently (the exact
    # sig is pinned to the padded bucket path thereafter)
    retries: int = 0
    escalation_failures: int = 0

    @property
    def compiles(self) -> int:
        return self.misses

    def as_dict(self) -> Dict[str, float]:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "compiles": self.compiles,
            "compile_seconds": round(self.compile_seconds, 4),
            "escalations": self.escalations,
            "evictions": self.evictions,
            "promotions": self.promotions,
            "retries": self.retries,
            "escalation_failures": self.escalation_failures,
        }


class CompileCache:
    def __init__(self, fingerprint: str, max_entries: int = 256,
                 escalation_threshold: Optional[int] = None) -> None:
        self.fingerprint = fingerprint
        self.max_entries = max_entries
        self.escalation_threshold = escalation_threshold
        self._entries: "OrderedDict[Tuple, Any]" = OrderedDict()
        self._exact_hits: Dict[Tuple, int] = {}
        # exact signatures whose escalation compile failed permanently:
        # should_escalate() answers False for them forever after, so the
        # dispatch keeps serving the padded bucket artifact instead of
        # re-attempting a compile that cannot succeed on every call
        self._failed_exact: Set[Tuple] = set()
        self.retry_policy: RetryPolicy = DEFAULT_RETRY
        self.stats = CacheStats()
        obs_metrics.register_collector("compile", self._obs_collect,
                                       name=fingerprint)

    def _obs_collect(self) -> Dict[str, Any]:
        """Pull collector for ``disc.observe()["compile"]``."""
        return dict(self.stats.as_dict(), entries=len(self._entries))

    def _compile_with_retry(self, compile_fn: Callable[[], Any],
                            what: str, site: str) -> Any:
        """Run ``compile_fn`` under the taxonomy: raw errors are wrapped
        into :class:`~repro.errors.CompileError` (classified transient or
        permanent), transient failures retry with capped exponential
        backoff, and the named fault site fires first when an injector is
        installed."""
        attempt = 0
        while True:
            try:
                if faults.ACTIVE is not None:
                    faults.ACTIVE.check(site, key=what)
                return compile_fn()
            except CONTROL_EXCEPTIONS:
                raise
            except Exception as e:  # noqa: BLE001 — classified below
                err = wrap_compile_error(e, what)
                if not err.transient \
                        or attempt >= self.retry_policy.max_retries:
                    raise err from e
                self.stats.retries += 1
                time.sleep(self.retry_policy.delay(attempt))
                attempt += 1

    # --------------------------------------------------------- bucketed --
    def get_or_compile(self, bucket_sig: Tuple, compile_fn: Callable[[], Any],
                       fingerprint: Optional[str] = None) -> Any:
        """Look up / build the artifact for one bucket signature.

        ``fingerprint`` overrides the cache's default graph fingerprint so a
        single cache instance can be shared by several compiled artifacts
        (e.g. a serving engine's prefill + decode functions) — entries never
        collide because the fingerprint is part of the key.
        """
        key = ("bucket", fingerprint or self.fingerprint, bucket_sig)
        entry = self._entries.get(key)
        if entry is not None:
            self.stats.hits += 1
            self._entries.move_to_end(key)
            return entry
        self.stats.misses += 1
        fp = fingerprint or self.fingerprint
        sp = (obs_trace.ACTIVE.begin("compile.bucket", cat="compile",
                                     key=str(bucket_sig), artifact=fp[:40],
                                     cache_hit=False)
              if obs_trace.ACTIVE is not None else None)
        t0 = time.perf_counter()
        try:
            # the fault-site key carries the artifact fingerprint so an
            # injector can target one artifact (match="prefill") of a
            # shared cache
            entry = self._compile_with_retry(
                compile_fn, f"{fp} bucket {bucket_sig}", "compile.bucket")
        finally:
            dt = time.perf_counter() - t0
            self.stats.compile_seconds += dt
            if sp is not None:
                sp.end()
        obs_metrics.record_event("compile.bucket", key=str(bucket_sig),
                                 artifact=fp[:40], seconds=round(dt, 4))
        self._entries[key] = entry
        self._evict()
        return entry

    # ------------------------------------------------- static escalation --
    def should_escalate(self, exact_sig: Tuple,
                        fingerprint: Optional[str] = None,
                        threshold: Optional[int] = None) -> bool:
        """§4.4: route hot exact shapes to the static compiler."""
        threshold = self.escalation_threshold if threshold is None else threshold
        if threshold is None:
            return False
        key = (fingerprint or self.fingerprint, exact_sig)
        if key in self._failed_exact:
            return False
        n = self._exact_hits.get(key, 0) + 1
        self._exact_hits[key] = n
        return n >= threshold

    def note_escalation_failure(self, exact_sig: Tuple,
                                fingerprint: Optional[str] = None) -> None:
        """Record a permanently failed §4.4 escalation compile: the exact
        signature is pinned to the padded bucket path (``should_escalate``
        answers False for it from now on)."""
        self._failed_exact.add((fingerprint or self.fingerprint, exact_sig))
        self.stats.escalation_failures += 1
        obs_metrics.record_event(
            "escalate.fail", key=str(exact_sig),
            artifact=(fingerprint or self.fingerprint)[:40])

    def get_or_compile_exact(self, exact_sig: Tuple,
                             compile_fn: Callable[[], Any],
                             fingerprint: Optional[str] = None) -> Any:
        key = ("exact", fingerprint or self.fingerprint, exact_sig)
        entry = self._entries.get(key)
        if entry is not None:
            self.stats.hits += 1
            self._entries.move_to_end(key)
            return entry
        self.stats.misses += 1
        self.stats.escalations += 1
        fp = fingerprint or self.fingerprint
        sp = (obs_trace.ACTIVE.begin("compile.exact", cat="compile",
                                     key=str(exact_sig), artifact=fp[:40],
                                     cache_hit=False)
              if obs_trace.ACTIVE is not None else None)
        t0 = time.perf_counter()
        try:
            entry = self._compile_with_retry(
                compile_fn, f"{fp} exact {exact_sig}", "compile.exact")
        finally:
            dt = time.perf_counter() - t0
            self.stats.compile_seconds += dt
            if sp is not None:
                sp.end()
        obs_metrics.record_event("escalate", key=str(exact_sig),
                                 artifact=fp[:40], seconds=round(dt, 4))
        self._entries[key] = entry
        self._evict()
        return entry

    def drop_fingerprint(self, fingerprint: str) -> int:
        """Drop every entry (and escalation counter) keyed under
        ``fingerprint``.

        Used by promote-on-change: after a re-lower the old artifact's
        entries are unreachable (its fingerprint is never asked for
        again) but would otherwise pin compiled executables in the LRU
        until enough newer entries forced them out.  Returns the number
        of entries dropped.
        """
        dead = [k for k in self._entries if k[1] == fingerprint]
        for k in dead:
            del self._entries[k]
        self._exact_hits = {k: v for k, v in self._exact_hits.items()
                            if k[0] != fingerprint}
        self._failed_exact = {k for k in self._failed_exact
                              if k[0] != fingerprint}
        return len(dead)

    def _evict(self) -> None:
        while len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)
            self.stats.evictions += 1

    def __len__(self) -> int:
        return len(self._entries)
