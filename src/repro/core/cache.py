"""Compile cache — keyed on (pattern fingerprint, bucket signature).

DISC §2: "these fusion engines will compile and generate kernel for every
emerging shape, even though some of them share the same computation
pattern" — the cache key here deliberately contains **no concrete shapes**,
only the shape-free graph fingerprint and the bucket signature, so compile
count is O(#buckets), not O(#shapes).

Also implements §4.4's static/dynamic mix: signatures that stay hot are
*escalated* to exact-shape static specializations (better codegen: no
masking, no padding waste), bounded by an LRU budget.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

__all__ = ["CompileCache", "CacheStats"]


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    compile_seconds: float = 0.0
    escalations: int = 0
    evictions: int = 0
    # promote-on-change re-lowerings: a call broke a dim tie inferred from
    # the first call, so the artifact was re-lowered with independent dims
    promotions: int = 0

    @property
    def compiles(self) -> int:
        return self.misses

    def as_dict(self) -> Dict[str, float]:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "compiles": self.compiles,
            "compile_seconds": round(self.compile_seconds, 4),
            "escalations": self.escalations,
            "evictions": self.evictions,
            "promotions": self.promotions,
        }


class CompileCache:
    def __init__(self, fingerprint: str, max_entries: int = 256,
                 escalation_threshold: Optional[int] = None) -> None:
        self.fingerprint = fingerprint
        self.max_entries = max_entries
        self.escalation_threshold = escalation_threshold
        self._entries: "OrderedDict[Tuple, Any]" = OrderedDict()
        self._exact_hits: Dict[Tuple, int] = {}
        self.stats = CacheStats()

    # --------------------------------------------------------- bucketed --
    def get_or_compile(self, bucket_sig: Tuple, compile_fn: Callable[[], Any],
                       fingerprint: Optional[str] = None) -> Any:
        """Look up / build the artifact for one bucket signature.

        ``fingerprint`` overrides the cache's default graph fingerprint so a
        single cache instance can be shared by several compiled artifacts
        (e.g. a serving engine's prefill + decode functions) — entries never
        collide because the fingerprint is part of the key.
        """
        key = ("bucket", fingerprint or self.fingerprint, bucket_sig)
        entry = self._entries.get(key)
        if entry is not None:
            self.stats.hits += 1
            self._entries.move_to_end(key)
            return entry
        self.stats.misses += 1
        t0 = time.perf_counter()
        entry = compile_fn()
        self.stats.compile_seconds += time.perf_counter() - t0
        self._entries[key] = entry
        self._evict()
        return entry

    # ------------------------------------------------- static escalation --
    def should_escalate(self, exact_sig: Tuple,
                        fingerprint: Optional[str] = None,
                        threshold: Optional[int] = None) -> bool:
        """§4.4: route hot exact shapes to the static compiler."""
        threshold = self.escalation_threshold if threshold is None else threshold
        if threshold is None:
            return False
        key = (fingerprint or self.fingerprint, exact_sig)
        n = self._exact_hits.get(key, 0) + 1
        self._exact_hits[key] = n
        return n >= threshold

    def get_or_compile_exact(self, exact_sig: Tuple,
                             compile_fn: Callable[[], Any],
                             fingerprint: Optional[str] = None) -> Any:
        key = ("exact", fingerprint or self.fingerprint, exact_sig)
        entry = self._entries.get(key)
        if entry is not None:
            self.stats.hits += 1
            self._entries.move_to_end(key)
            return entry
        self.stats.misses += 1
        self.stats.escalations += 1
        t0 = time.perf_counter()
        entry = compile_fn()
        self.stats.compile_seconds += time.perf_counter() - t0
        self._entries[key] = entry
        self._evict()
        return entry

    def drop_fingerprint(self, fingerprint: str) -> int:
        """Drop every entry (and escalation counter) keyed under
        ``fingerprint``.

        Used by promote-on-change: after a re-lower the old artifact's
        entries are unreachable (its fingerprint is never asked for
        again) but would otherwise pin compiled executables in the LRU
        until enough newer entries forced them out.  Returns the number
        of entries dropped.
        """
        dead = [k for k in self._entries if k[1] == fingerprint]
        for k in dead:
            del self._entries[k]
        self._exact_hits = {k: v for k, v in self._exact_hits.items()
                            if k[0] != fingerprint}
        return len(dead)

    def _evict(self) -> None:
        while len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)
            self.stats.evictions += 1

    def __len__(self) -> int:
        return len(self._entries)
