"""DISC core — the paper's contribution as a composable JAX module."""
from .symshape import SymDim, SymShape, fresh_symdim  # noqa: F401
from .constraints import ShapeConstraintStore, ConstraintViolation  # noqa: F401
from .dhlo import DGraph, DOp, DValue  # noqa: F401
from .propagation import (  # noqa: F401
    PropClass,
    CostClass,
    op_info,
    collect_semantic_constraints,
)
