"""Per-op shape-propagation table — DISC §4.3 "shape hints collection".

    "DISC maintains a table to indicate the propagation property of each op.
     Some ops may have the same shape propagation property, like Add and Sub.
     We classify ops according to their shape propagation properties in the
     table to avoid repeated enumeration."

Every DHLO opcode is registered once with:

* ``prop``  — its *shape propagation class* (how shapes relate between its
  operands and results).  Fusion and constraint collection dispatch on the
  class, never on individual opcodes.
* ``cost``  — compute-intensive (GEMM/conv — routed to the static-shape
  library, §4.5) vs memory-intensive (fusion targets) vs shape-calculation
  (host-placed, §4.2.1).
* ``pad_identity`` — the value with which a *padded* tail must be filled so
  bucketed execution is exact for ops that mix positions (reductions).

``collect_semantic_constraints`` is the paper's first constraint source:
walking the graph once and asserting the constraints implied by each op's
semantics into the graph's :class:`ShapeConstraintStore`.
"""
from __future__ import annotations

import enum
import math
from dataclasses import dataclass
from typing import Dict, Optional

from .dhlo import DGraph, DOp
from .symshape import SymDim

__all__ = [
    "PropClass",
    "CostClass",
    "OpInfo",
    "OP_TABLE",
    "op_info",
    "collect_semantic_constraints",
]


class PropClass(enum.Enum):
    ELEMENTWISE = "elementwise"          # all non-scalar operands/results same shape
    BROADCAST = "broadcast"              # dims map via broadcast_dimensions
    RESHAPE = "reshape"                  # tensor-size preserving, dims remixed
    TRANSPOSE = "transpose"              # size preserving + dim permutation
    REDUCE = "reduce"                    # kept dims equal input dims
    SLICE = "slice"                      # output dims from sizes (static or operand)
    CONCAT = "concat"                    # non-concat dims equal
    DOT = "dot"                          # batch/contracting equality
    GATHER = "gather"                    # indexed
    UPDATE = "update"                    # dynamic_update_slice: result == operand shape
    IOTA = "iota"
    OPAQUE = "opaque"


class CostClass(enum.Enum):
    MEMORY = "memory"      # fusion targets (paper's focus)
    COMPUTE = "compute"    # GEMM/conv: library calls, never fused into loops
    SHAPE = "shape"        # scalar/index math: host-placed


@dataclass(frozen=True)
class OpInfo:
    prop: PropClass
    cost: CostClass = CostClass.MEMORY
    pad_identity: Optional[float] = None  # fill value making padded reduce exact


_E = PropClass.ELEMENTWISE
_M = CostClass.MEMORY

OP_TABLE: Dict[str, OpInfo] = {}


def _reg(names, info: OpInfo) -> None:
    for n in names:
        OP_TABLE[n] = info


# one table row per *propagation class*, exactly as the paper describes
_reg(
    [
        "add", "sub", "mul", "div", "rem", "pow", "max", "min", "and", "or",
        "xor", "shift_left", "shift_right_logical", "shift_right_arithmetic",
        "atan2", "nextafter",
        "lt", "gt", "le", "ge", "eq", "ne",
    ],
    OpInfo(_E, _M),
)
_reg(
    [
        "neg", "exp", "expm1", "log", "log1p", "tanh", "logistic", "sqrt",
        "rsqrt", "cbrt", "abs", "sign", "floor", "ceil", "round", "erf",
        "erfc", "erf_inv", "sin", "cos", "tan", "asin", "acos", "atan",
        "sinh", "cosh", "exp2", "not", "is_finite", "integer_pow",
        "stop_gradient", "copy", "real", "imag", "square",
    ],
    OpInfo(_E, _M),
)
_reg(["select"], OpInfo(_E, _M))
_reg(["convert"], OpInfo(_E, _M))
_reg(["broadcast_in_dim"], OpInfo(PropClass.BROADCAST, _M))
_reg(["reshape"], OpInfo(PropClass.RESHAPE, _M))
_reg(["transpose"], OpInfo(PropClass.TRANSPOSE, _M))
_reg(["rev"], OpInfo(PropClass.TRANSPOSE, _M))
_reg(["reduce_sum"], OpInfo(PropClass.REDUCE, _M, pad_identity=0.0))
_reg(["reduce_max", "argmax"], OpInfo(PropClass.REDUCE, _M, pad_identity=-math.inf))
_reg(["reduce_min", "argmin"], OpInfo(PropClass.REDUCE, _M, pad_identity=math.inf))
_reg(["reduce_prod"], OpInfo(PropClass.REDUCE, _M, pad_identity=1.0))
_reg(["reduce_and"], OpInfo(PropClass.REDUCE, _M, pad_identity=1.0))
_reg(["reduce_or"], OpInfo(PropClass.REDUCE, _M, pad_identity=0.0))
_reg(["cumsum", "cummax", "cumprod"], OpInfo(PropClass.ELEMENTWISE, _M, pad_identity=0.0))
_reg(["dot_general"], OpInfo(PropClass.DOT, CostClass.COMPUTE))
_reg(["conv"], OpInfo(PropClass.OPAQUE, CostClass.COMPUTE))
_reg(["slice"], OpInfo(PropClass.SLICE, _M))
_reg(["dslice"], OpInfo(PropClass.SLICE, _M))          # DHLO dynamic slice
_reg(["dynamic_update_slice"], OpInfo(PropClass.UPDATE, _M))
_reg(["concatenate"], OpInfo(PropClass.CONCAT, _M))
_reg(["pad"], OpInfo(PropClass.SLICE, _M))
_reg(["iota"], OpInfo(PropClass.IOTA, _M))
_reg(["gather", "take"], OpInfo(PropClass.GATHER, _M))
_reg(["scatter_add"], OpInfo(PropClass.UPDATE, _M))
_reg(["sort"], OpInfo(PropClass.ELEMENTWISE, _M))
# shape-calculation ops (host-placed by the placer, §4.2.1)
_reg(["shape_of", "dim_size", "index_add", "index_mul"], OpInfo(PropClass.OPAQUE, CostClass.SHAPE))


def op_info(opcode: str) -> OpInfo:
    try:
        return OP_TABLE[opcode]
    except KeyError:
        return OpInfo(PropClass.OPAQUE, CostClass.MEMORY)


# --------------------------------------------------------------------------
# Constraint source #1: op semantics (§4.2.1 "captured by the DHLO op
# semantic" — e.g. Transpose preserves tensor size; Add operands share shape)
# --------------------------------------------------------------------------

def collect_semantic_constraints(graph: DGraph) -> None:
    store = graph.store
    for op in graph.ops:
        info = op_info(op.opcode)
        p = info.prop
        if p is PropClass.ELEMENTWISE:
            # elementwise: non-scalar operands/results share a shape, except
            # size-1 dims (jax keeps implicit rank-equal broadcast in binary
            # primitives — a broadcast dim carries no equality information)
            shapes = [v.shape for v in op.inputs if v.rank > 0]
            shapes += [v.shape for v in op.outputs if v.rank > 0]
            for a, b in zip(shapes, shapes[1:]):
                if len(a) != len(b):
                    continue
                for da, db in zip(a, b):
                    if (isinstance(da, int) and da == 1) or \
                       (isinstance(db, int) and db == 1):
                        continue
                    store.assert_dim_eq(da, db)
        elif p is PropClass.BROADCAST:
            bdims = op.attrs.get("broadcast_dimensions", ())
            (out,) = op.outputs
            src = op.inputs[0]
            for in_ax, out_ax in enumerate(bdims):
                d = src.shape[in_ax]
                if not (isinstance(d, int) and d == 1):
                    store.assert_dim_eq(d, out.shape[out_ax])
        elif p in (PropClass.RESHAPE, PropClass.TRANSPOSE):
            (out,) = op.outputs
            src = op.inputs[0]
            store.assert_size_eq(src.vid, out.vid)
            if p is PropClass.TRANSPOSE and "permutation" in op.attrs:
                perm = op.attrs["permutation"]
                for out_ax, in_ax in enumerate(perm):
                    store.assert_dim_eq(src.shape[in_ax], out.shape[out_ax])
            if op.opcode == "rev":
                store.assert_shape_eq(src.shape, out.shape)
        elif p is PropClass.REDUCE:
            (out,) = op.outputs
            src = op.inputs[0]
            axes = set(op.attrs.get("axes", ()))
            kept = [i for i in range(src.rank) if i not in axes]
            if out.rank == len(kept):  # keepdims=False form
                for o_ax, i_ax in enumerate(kept):
                    store.assert_dim_eq(src.shape[i_ax], out.shape[o_ax])
        elif p is PropClass.CONCAT:
            (out,) = op.outputs
            axis = op.attrs.get("dimension", 0)
            for src in op.inputs:
                for ax in range(src.rank):
                    if ax != axis:
                        store.assert_dim_eq(src.shape[ax], out.shape[ax])
        elif p is PropClass.UPDATE:
            (out,) = op.outputs
            store.assert_shape_eq(op.inputs[0].shape, out.shape)
        elif p is PropClass.DOT:
            (out,) = op.outputs
            lhs, rhs = op.inputs[0], op.inputs[1]
            dnums = op.attrs.get("dimension_numbers")
            if dnums is not None:
                (lc, rc), (lb, rb) = dnums
                for a, b in zip(lc, rc):
                    store.assert_dim_eq(lhs.shape[a], rhs.shape[b])
                for a, b in zip(lb, rb):
                    store.assert_dim_eq(lhs.shape[a], rhs.shape[b])
