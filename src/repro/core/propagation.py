"""Per-op shape-propagation table — DISC §4.3 "shape hints collection".

    "DISC maintains a table to indicate the propagation property of each op.
     Some ops may have the same shape propagation property, like Add and Sub.
     We classify ops according to their shape propagation properties in the
     table to avoid repeated enumeration."

Every DHLO opcode is registered once with:

* ``prop``  — its *shape propagation class* (how shapes relate between its
  operands and results).  Fusion and constraint collection dispatch on the
  class, never on individual opcodes.
* ``cost``  — compute-intensive (GEMM/conv — routed to the static-shape
  library, §4.5) vs memory-intensive (fusion targets) vs shape-calculation
  (host-placed, §4.2.1).
* ``pad_identity`` — the value with which a *padded* tail must be filled so
  bucketed execution is exact for ops that mix positions (reductions).

``collect_semantic_constraints`` is the paper's first constraint source:
walking the graph once and asserting the constraints implied by each op's
semantics into the graph's :class:`ShapeConstraintStore`.
"""
from __future__ import annotations

import enum
import math
from dataclasses import dataclass
from typing import Dict, Optional

from .constraints import ConstraintViolation, ShapeConstraintStore
from .dhlo import DGraph, DOp
from .symshape import SymDim, fresh_symdim

__all__ = [
    "PropClass",
    "CostClass",
    "OpInfo",
    "OP_TABLE",
    "op_info",
    "collect_semantic_constraints",
    "carry_fixed_point",
]


class PropClass(enum.Enum):
    ELEMENTWISE = "elementwise"          # all non-scalar operands/results same shape
    BROADCAST = "broadcast"              # dims map via broadcast_dimensions
    RESHAPE = "reshape"                  # tensor-size preserving, dims remixed
    TRANSPOSE = "transpose"              # size preserving + dim permutation
    REDUCE = "reduce"                    # kept dims equal input dims
    SLICE = "slice"                      # output dims from sizes (static or operand)
    CONCAT = "concat"                    # non-concat dims equal
    DOT = "dot"                          # batch/contracting equality
    GATHER = "gather"                    # indexed
    UPDATE = "update"                    # dynamic_update_slice: result == operand shape
    IOTA = "iota"
    OPAQUE = "opaque"


class CostClass(enum.Enum):
    MEMORY = "memory"      # fusion targets (paper's focus)
    COMPUTE = "compute"    # GEMM/conv: library calls, never fused into loops
    SHAPE = "shape"        # scalar/index math: host-placed


@dataclass(frozen=True)
class OpInfo:
    prop: PropClass
    cost: CostClass = CostClass.MEMORY
    pad_identity: Optional[float] = None  # fill value making padded reduce exact


_E = PropClass.ELEMENTWISE
_M = CostClass.MEMORY

OP_TABLE: Dict[str, OpInfo] = {}


def _reg(names, info: OpInfo) -> None:
    for n in names:
        OP_TABLE[n] = info


# one table row per *propagation class*, exactly as the paper describes
_reg(
    [
        "add", "sub", "mul", "div", "rem", "pow", "max", "min", "and", "or",
        "xor", "shift_left", "shift_right_logical", "shift_right_arithmetic",
        "atan2", "nextafter",
        "lt", "gt", "le", "ge", "eq", "ne",
    ],
    OpInfo(_E, _M),
)
_reg(
    [
        "neg", "exp", "expm1", "log", "log1p", "tanh", "logistic", "sqrt",
        "rsqrt", "cbrt", "abs", "sign", "floor", "ceil", "round", "erf",
        "erfc", "erf_inv", "sin", "cos", "tan", "asin", "acos", "atan",
        "sinh", "cosh", "exp2", "not", "is_finite", "integer_pow",
        "stop_gradient", "copy", "real", "imag", "square",
    ],
    OpInfo(_E, _M),
)
_reg(["select"], OpInfo(_E, _M))
_reg(["convert"], OpInfo(_E, _M))
_reg(["broadcast_in_dim"], OpInfo(PropClass.BROADCAST, _M))
_reg(["reshape"], OpInfo(PropClass.RESHAPE, _M))
_reg(["transpose"], OpInfo(PropClass.TRANSPOSE, _M))
_reg(["rev"], OpInfo(PropClass.TRANSPOSE, _M))
_reg(["reduce_sum"], OpInfo(PropClass.REDUCE, _M, pad_identity=0.0))
_reg(["reduce_max", "argmax"], OpInfo(PropClass.REDUCE, _M, pad_identity=-math.inf))
_reg(["reduce_min", "argmin"], OpInfo(PropClass.REDUCE, _M, pad_identity=math.inf))
_reg(["reduce_prod"], OpInfo(PropClass.REDUCE, _M, pad_identity=1.0))
_reg(["reduce_and"], OpInfo(PropClass.REDUCE, _M, pad_identity=1.0))
_reg(["reduce_or"], OpInfo(PropClass.REDUCE, _M, pad_identity=0.0))
_reg(["cumsum", "cummax", "cumprod"], OpInfo(PropClass.ELEMENTWISE, _M, pad_identity=0.0))
_reg(["dot_general"], OpInfo(PropClass.DOT, CostClass.COMPUTE))
_reg(["conv"], OpInfo(PropClass.OPAQUE, CostClass.COMPUTE))
_reg(["slice"], OpInfo(PropClass.SLICE, _M))
_reg(["dslice"], OpInfo(PropClass.SLICE, _M))          # DHLO dynamic slice
_reg(["dynamic_update_slice"], OpInfo(PropClass.UPDATE, _M))
_reg(["concatenate"], OpInfo(PropClass.CONCAT, _M))
_reg(["pad"], OpInfo(PropClass.SLICE, _M))
_reg(["iota"], OpInfo(PropClass.IOTA, _M))
_reg(["gather", "take"], OpInfo(PropClass.GATHER, _M))
_reg(["scatter_add"], OpInfo(PropClass.UPDATE, _M))
_reg(["sort"], OpInfo(PropClass.ELEMENTWISE, _M))
# region ops (d.* control flow): bodies are nested DGraphs in attrs.
# COMPUTE keeps them out of fusion clusters — a region executes as one
# opaque launch (codegen.emit_region_op lowers it back to lax control
# flow); its shape behavior is captured by the carry fixed-point rule
# below, not by a propagation class.
_reg(["d.while", "d.scan", "d.cond"],
     OpInfo(PropClass.OPAQUE, CostClass.COMPUTE))
# shape-calculation ops (host-placed by the placer, §4.2.1)
_reg(["shape_of", "dim_size", "index_add", "index_mul"], OpInfo(PropClass.OPAQUE, CostClass.SHAPE))


def op_info(opcode: str) -> OpInfo:
    try:
        return OP_TABLE[opcode]
    except KeyError:
        return OpInfo(PropClass.OPAQUE, CostClass.MEMORY)


# --------------------------------------------------------------------------
# Constraint source #1: op semantics (§4.2.1 "captured by the DHLO op
# semantic" — e.g. Transpose preserves tensor size; Add operands share shape)
# --------------------------------------------------------------------------

def collect_semantic_constraints(graph: DGraph) -> None:
    store = graph.store
    for op in graph.ops:
        info = op_info(op.opcode)
        p = info.prop
        if p is PropClass.ELEMENTWISE:
            # elementwise: non-scalar operands/results share a shape, except
            # size-1 dims (jax keeps implicit rank-equal broadcast in binary
            # primitives — a broadcast dim carries no equality information)
            shapes = [v.shape for v in op.inputs if v.rank > 0]
            shapes += [v.shape for v in op.outputs if v.rank > 0]
            for a, b in zip(shapes, shapes[1:]):
                if len(a) != len(b):
                    continue
                for da, db in zip(a, b):
                    if (isinstance(da, int) and da == 1) or \
                       (isinstance(db, int) and db == 1):
                        continue
                    store.assert_dim_eq(da, db)
        elif p is PropClass.BROADCAST:
            bdims = op.attrs.get("broadcast_dimensions", ())
            (out,) = op.outputs
            src = op.inputs[0]
            for in_ax, out_ax in enumerate(bdims):
                d = src.shape[in_ax]
                if not (isinstance(d, int) and d == 1):
                    store.assert_dim_eq(d, out.shape[out_ax])
        elif p in (PropClass.RESHAPE, PropClass.TRANSPOSE):
            (out,) = op.outputs
            src = op.inputs[0]
            store.assert_size_eq(src.vid, out.vid)
            if p is PropClass.TRANSPOSE and "permutation" in op.attrs:
                perm = op.attrs["permutation"]
                for out_ax, in_ax in enumerate(perm):
                    store.assert_dim_eq(src.shape[in_ax], out.shape[out_ax])
            if op.opcode == "rev":
                store.assert_shape_eq(src.shape, out.shape)
        elif p is PropClass.REDUCE:
            (out,) = op.outputs
            src = op.inputs[0]
            axes = set(op.attrs.get("axes", ()))
            kept = [i for i in range(src.rank) if i not in axes]
            if out.rank == len(kept):  # keepdims=False form
                for o_ax, i_ax in enumerate(kept):
                    store.assert_dim_eq(src.shape[i_ax], out.shape[o_ax])
        elif p is PropClass.CONCAT:
            (out,) = op.outputs
            axis = op.attrs.get("dimension", 0)
            for src in op.inputs:
                for ax in range(src.rank):
                    if ax != axis:
                        store.assert_dim_eq(src.shape[ax], out.shape[ax])
        elif p is PropClass.UPDATE:
            (out,) = op.outputs
            store.assert_shape_eq(op.inputs[0].shape, out.shape)
        elif p is PropClass.DOT:
            (out,) = op.outputs
            lhs, rhs = op.inputs[0], op.inputs[1]
            dnums = op.attrs.get("dimension_numbers")
            if dnums is not None:
                (lc, rc), (lb, rb) = dnums
                for a, b in zip(lc, rc):
                    store.assert_dim_eq(lhs.shape[a], rhs.shape[b])
                for a, b in zip(lb, rb):
                    store.assert_dim_eq(lhs.shape[a], rhs.shape[b])


# --------------------------------------------------------------------------
# Carry fixed-point rule for region ops (d.while / d.scan)
# --------------------------------------------------------------------------

def _expr_leaves(dim_exprs, d, acc) -> None:
    if isinstance(d, int):
        return
    expr = dim_exprs.get(d.uid)
    if expr is None:
        acc[d.uid] = d
        return
    tag = expr[0]
    if tag in ("mul", "sum"):
        for x in expr[1]:
            _expr_leaves(dim_exprs, x, acc)
    elif tag in ("affine", "div"):
        _expr_leaves(dim_exprs, expr[1], acc)


def _expr_eval(dim_exprs, d, env):
    if isinstance(d, int):
        return d
    expr = dim_exprs.get(d.uid)
    if expr is None:
        return env[d.uid]
    tag = expr[0]
    if tag == "mul":
        v = 1
        for x in expr[1]:
            v *= _expr_eval(dim_exprs, x, env)
        return v
    if tag == "sum":
        return sum(_expr_eval(dim_exprs, x, env) for x in expr[1])
    if tag == "affine":
        _, base, a, b = expr
        return a * _expr_eval(dim_exprs, base, env) + b
    if tag == "div":
        _, base, k = expr
        return _expr_eval(dim_exprs, base, env) // k
    raise ValueError(f"unknown dim expr {expr}")


def _provably_equal(store: ShapeConstraintStore, dim_exprs, da, db) -> bool:
    """Can ``da == db`` be proved for every admissible symbol binding?

    Structural canonical equality first; otherwise the derived exprs of
    both dims are evaluated at two distinct leaf assignments (the trace
    reps, then reps shifted by per-leaf offsets).  Identity-preserving
    rewrites like ``(S-1)+1`` agree at both points; genuinely varying
    dims (``S//2*2``) disagree at the shifted point.
    """
    if store.dims_equal(da, db):
        return True
    ca = store.canon_dim(da)
    cb = store.canon_dim(db)
    leaves: Dict[int, SymDim] = {}
    try:
        _expr_leaves(dim_exprs, ca, leaves)
        _expr_leaves(dim_exprs, cb, leaves)
        ordered = sorted(leaves.values(), key=lambda s: s.uid)
        p1 = {s.uid: s.rep for s in ordered}
        p2 = {s.uid: s.rep + 16 + 13 * i for i, s in enumerate(ordered)}
        return (_expr_eval(dim_exprs, ca, p1) == _expr_eval(dim_exprs, cb, p1)
                and _expr_eval(dim_exprs, ca, p2)
                == _expr_eval(dim_exprs, cb, p2))
    except (KeyError, ValueError):
        return False


def carry_fixed_point(store: ShapeConstraintStore, dim_exprs,
                      entry_shape, out_shape, *,
                      bounds: Optional[Dict[str, int]] = None,
                      label: str = "carry"):
    """Resolve a loop carry's shape across iterations (d.while / d.scan).

    JAX's trace already guarantees the *representative* sizes of a carry
    and its body output agree; this rule decides what that means
    symbolically, per dim:

    * provably equal (canonically unified, or their derived expressions
      agree at two distinct bindings) — the dims are merged in the store
      and the entry dim is kept;
    * two plain symbols that merely coincide — the function *requires*
      the equality to stay traceable, so it is asserted as a constraint;
    * a derived dim that genuinely varies across iterations — the carry
      **widens** to a fresh bounded symbol carrying the dim's declared
      ``Dim(max=...)`` cap (looked up in ``bounds`` by symbol name, or in
      the store's recorded bounds).  With no cap to widen to, the loop's
      shape behavior is unbounded and a :class:`ConstraintViolation` is
      raised naming the carry.

    Returns the resolved symbolic shape for the region op's output.
    """
    if len(entry_shape) != len(out_shape):
        raise ConstraintViolation(
            f"{label}: rank changes across iterations "
            f"({len(entry_shape)} -> {len(out_shape)})")
    bounds = bounds or {}
    resolved = []
    for i, (din, dout) in enumerate(zip(entry_shape, out_shape)):
        if isinstance(din, int) and isinstance(dout, int):
            if din != dout:
                raise ConstraintViolation(
                    f"{label}: dim {i} changes across iterations "
                    f"({din} -> {dout})")
            resolved.append(din)
            continue
        if _provably_equal(store, dim_exprs, din, dout):
            store.assert_dim_eq(din, dout)
            resolved.append(din)
            continue
        def _plain_symbol(d):
            return (isinstance(d, SymDim)
                    and dim_exprs.get(d.uid) is None
                    and isinstance(store.canon_dim(d), SymDim))

        if _plain_symbol(din) and _plain_symbol(dout):
            # two independent input symbols in a carry position: the loop
            # itself requires them equal (jax re-checks the carry aval on
            # every trace), so the tie is a real constraint, not a widen
            store.assert_dim_eq(din, dout)
            resolved.append(din)
            continue
        cap = None
        for d in (din, dout):
            if isinstance(d, SymDim):
                cap = bounds.get(d.name) if cap is None else cap
                cap = store.dim_bound(d) if cap is None else cap
        if cap is None:
            raise ConstraintViolation(
                f"{label}: dim {i} changes across loop iterations "
                f"({din!r} -> {dout!r}) with no declared bound — give the "
                f"dim a Dim(max=...) contract so it can widen to a "
                f"bounded symbol")
        base = din if isinstance(din, SymDim) else dout
        widened = fresh_symdim(f"{base.name}^", rep=base.rep)
        store.note_dim_bound(widened, int(cap))
        store.assert_dim_eq(din, widened)
        store.assert_dim_eq(dout, widened)
        resolved.append(widened)
    return tuple(resolved)
