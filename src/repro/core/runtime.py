"""DiscEngine — generated runtime flow — DISC §4.2.

    "Rather than using an interpreter, DISC compiles and generates the code
     of computations on both host and device side, and also runtime flows
     (buffer management, kernel launch, et al.)."

`DiscEngine.compile()` *generates Python source* for the host-side dispatch
of one graph — shape extraction, bucket mapping, cache lookup, padding plan,
device invocation, output recovery — and ``exec``s it once.  The per-call
path is straight-line host code specialized to the graph: no graph walking,
no per-op interpretation (contrast ``vm.NimbleVM``).  The generated source
is kept in ``engine.dispatch_source`` as an inspectable artifact.

Device-side artifacts are produced per *bucket signature* by
``codegen.build_padded_executor`` and cached in ``cache.CompileCache`` keyed
on the shape-free graph fingerprint + bucket signature; hot exact shapes
optionally escalate to static specializations (§4.4).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..frontends.jaxpr_frontend import ArgSpec, bridge, eval_dim
from .bucketing import POW2, BucketPolicy
from .cache import CompileCache
from .codegen import build_exact_executor, build_padded_executor, dyn_symbols
from .dhlo import DGraph
from .fusion import FusionPlan, plan_fusion
from .placer import Placement, place
from .buffers import BufferPlan, plan_buffers
from .symshape import SymDim

__all__ = ["DiscEngine"]


class DiscEngine:
    """End-to-end dynamic-shape execution of a jax-traceable function."""

    def __init__(
        self,
        fn: Callable,
        arg_specs: Sequence[ArgSpec],
        *,
        policy: BucketPolicy = POW2,
        name: str = "disc",
        escalation_threshold: Optional[int] = None,
        max_cache_entries: int = 256,
        donate: bool = False,
        backend: str = "xla",
    ) -> None:
        self.fn = fn
        self.specs = list(arg_specs)
        self.policy = policy
        self.donate = donate
        self.backend = backend
        self.graph, _ = bridge(fn, arg_specs, name=name)
        self.plan: FusionPlan = plan_fusion(self.graph)
        self.placement: Placement = place(self.graph)
        self.buffer_plan: BufferPlan = plan_buffers(self.graph)
        self.syms: List[SymDim] = dyn_symbols(self.graph)
        self.cache = CompileCache(
            self.graph.fingerprint(),
            max_entries=max_cache_entries,
            escalation_threshold=escalation_threshold,
        )
        self._exact_jit = None  # lazily created static-fallback executor
        self.dispatch_source: str = ""
        self._dispatch = self._generate_dispatch()

    # ------------------------------------------------------------ public --
    def __call__(self, *arrays):
        outs = self._dispatch(arrays)
        return outs[0] if len(outs) == 1 else tuple(outs)

    @property
    def n_compiles(self) -> int:
        return self.cache.stats.compiles

    def report(self) -> Dict[str, Any]:
        from .codegen import _pallas_input_eligible, _pallas_loop_eligible
        n_pallas = sum(
            1 for c in self.plan.clusters
            if _pallas_loop_eligible(self.graph, c)
            or _pallas_input_eligible(self.graph, c))
        return {
            "fingerprint": self.graph.fingerprint(),
            "fusion": self.plan.stats(),
            "placement": self.placement.report(),
            "constraints": self.graph.store.stats(),
            "cache": self.cache.stats.as_dict(),
            "dynamic_symbols": [s.name for s in self.syms],
            "backend": self.backend,
            "pallas_eligible_clusters": n_pallas,
        }

    # ------------------------------------------------- device compilation --
    def _compile_bucket(self, key: Tuple[int, ...]):
        padded = {s.uid: int(k) for s, k in zip(self.syms, key)}
        executor = build_padded_executor(self.graph, padded, self.syms,
                                         plan=self.plan,
                                         backend=self.backend)
        lens_sds = jax.ShapeDtypeStruct((max(len(self.syms), 1),), jnp.int32)
        arg_sds = []
        for p in self.graph.params:
            shape = []
            for d in p.shape:
                if isinstance(d, SymDim):
                    c = self.graph.store.canon_dim(d)
                    shape.append(padded[c.uid] if isinstance(c, SymDim) else c)
                else:
                    shape.append(d)
            arg_sds.append(jax.ShapeDtypeStruct(tuple(shape), p.dtype))
        donate = tuple(range(1, 1 + len(arg_sds))) if self.donate else ()
        jfn = jax.jit(executor, donate_argnums=donate)
        return jfn.lower(lens_sds, *arg_sds).compile()

    def _compile_exact(self):
        if self._exact_jit is None:
            self._exact_jit = jax.jit(build_exact_executor(self.graph))
        return self._exact_jit

    # ------------------------------------------------ generated host flow --
    def _generate_dispatch(self) -> Callable:
        g = self.graph
        store = g.store
        syms = self.syms
        sym_index = {s.uid: i for i, s in enumerate(syms)}

        # one extraction site per symbol: first (param, axis) where it occurs
        extract: Dict[int, Tuple[int, int]] = {}
        for pi, p in enumerate(g.params):
            for ax, d in enumerate(p.shape):
                if isinstance(d, SymDim):
                    c = store.canon_dim(d)
                    if isinstance(c, SymDim) and c.uid not in extract:
                        extract[c.uid] = (pi, ax)

        lines: List[str] = ["def _dispatch(arrays):"]
        w = lines.append
        names = []
        for s in syms:
            pi, ax = extract[s.uid]
            nm = f"s_{s.uid}"
            names.append(nm)
            w(f"    {nm} = arrays[{pi}].shape[{ax}]")
        if syms:
            w("    key = (" + ", ".join(f"_b{i}({nm})" for i, nm in enumerate(names)) + ",)")
            w("    exact = (" + ", ".join(names) + ",)")
        else:
            w("    key = ()")
            w("    exact = ()")

        # §4.4 static escalation branch
        if self.cache.escalation_threshold is not None:
            w("    if _cache.should_escalate(exact):")
            w("        fn = _cache.get_or_compile_exact(exact, _compile_exact)")
            w("        return list(fn(*arrays))")

        w("    entry = _get(('bucket', _fp, key))")
        w("    if entry is None:")
        w("        entry = _compile(key)")
        n = max(len(syms), 1)
        if syms:
            w(f"    lens = _np.array([{', '.join(names)}], _np.int32)")
        else:
            w(f"    lens = _zero_lens")

        # padding plan: unrolled per param (host-side zero-fill)
        call_args = []
        for pi, p in enumerate(g.params):
            dyn_axes = []
            shape_expr = []
            for ax, d in enumerate(p.shape):
                if isinstance(d, SymDim):
                    c = store.canon_dim(d)
                    if isinstance(c, SymDim):
                        dyn_axes.append((ax, sym_index[c.uid]))
                        shape_expr.append(f"key[{sym_index[c.uid]}]")
                    else:
                        shape_expr.append(str(c))
                else:
                    shape_expr.append(str(d))
            var = f"x{pi}"
            if not dyn_axes:
                w(f"    {var} = arrays[{pi}]")
            else:
                pshape = "(" + ", ".join(shape_expr) + ("," if len(shape_expr) == 1 else "") + ")"
                w(f"    {var} = arrays[{pi}]")
                w(f"    if tuple({var}.shape) != {pshape}:")
                w(f"        _buf = _np.zeros({pshape}, _dt{pi})")
                idx = ", ".join(
                    (f":{var}.shape[{ax}]" if any(ax == a for a, _ in dyn_axes) else ":")
                    for ax in range(p.rank)
                )
                w(f"        _buf[{idx}] = _np.asarray({var})")
                w(f"        {var} = _buf")
            call_args.append(var)

        w(f"    outs = entry(lens, {', '.join(call_args)})" if call_args
          else "    outs = entry(lens)")

        # output recovery: slice back to true shapes
        out_exprs = []
        for oi, o in enumerate(g.outputs):
            idx_parts = []
            needs_slice = False
            for ax, d in enumerate(o.shape):
                if isinstance(d, int):
                    idx_parts.append(":")
                    continue
                c = store.canon_dim(d)
                if isinstance(c, int):
                    idx_parts.append(":")
                elif c.uid in sym_index:
                    idx_parts.append(f":s_{c.uid}")
                    needs_slice = True
                else:
                    idx_parts.append(f":_od{oi}_{ax}(exact)")
                    needs_slice = True
            if needs_slice:
                out_exprs.append(f"outs[{oi}][{', '.join(idx_parts)}]")
            else:
                out_exprs.append(f"outs[{oi}]")
        w("    return [" + ", ".join(out_exprs) + "]")

        src = "\n".join(lines)
        self.dispatch_source = src

        # namespace bound once at generation time (compiled host flow)
        _entries_get = self.cache._entries.get
        _stats = self.cache.stats

        def _get(key):
            e = _entries_get(key)
            if e is not None:
                _stats.hits += 1
            return e

        ns: Dict[str, Any] = {
            "_np": np,
            "_fp": self.cache.fingerprint,
            "_get": _get,
            "_cache": self.cache,
            "_compile_exact": self._compile_exact,
            "_zero_lens": np.zeros((1,), np.int32),
        }
        for i, s in enumerate(syms):
            pol = self.policy
            nm = s.name
            ns[f"_b{i}"] = (lambda v, _p=pol, _n=nm: _p.bucket(_n, int(v)))
        for pi, p in enumerate(g.params):
            ns[f"_dt{pi}"] = np.dtype(p.dtype)

        def _compile(key):
            return self.cache.get_or_compile(key, lambda: self._compile_bucket(key))

        ns["_compile"] = _compile

        # derived-output-dim evaluators (host shape calculation, §4.2.1)
        for oi, o in enumerate(g.outputs):
            for ax, d in enumerate(o.shape):
                if isinstance(d, SymDim):
                    c = store.canon_dim(d)
                    if isinstance(c, SymDim) and c.uid not in sym_index:
                        def _mk(dim):
                            def _f(exact):
                                binds = {s.uid: v for s, v in zip(syms, exact)}
                                return eval_dim(g, dim, binds)
                            return _f
                        ns[f"_od{oi}_{ax}"] = _mk(d)

        exec(compile(src, f"<disc-dispatch:{g.name}>", "exec"), ns)
        return ns["_dispatch"]
