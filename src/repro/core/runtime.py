"""DiscEngine — deprecated shim over the public ``disc.compile`` API.

The engine that used to live here was split apart:

* host-dispatch code generation  → :mod:`repro.core.dispatcher` (one
  lens-parameterized emitter serving both the DHLO and the jit pipeline)
* backend selection              → :mod:`repro.api.backends` (registry)
* staging / caching / options    → :mod:`repro.api.staged` /
  :class:`repro.api.CompileOptions`

``DiscEngine(fn, specs, ...)`` keeps working — it forwards to
``disc.compile`` and proxies the old attribute surface — but emits a
``DeprecationWarning``.  New code should use::

    import disc
    compiled = disc.compile(fn, specs, options=disc.CompileOptions(...))

Deprecation policy: the shim stays for two release cycles after the
``repro.api`` introduction, then construction becomes an error.
"""
from __future__ import annotations

import warnings
from typing import Any, Callable, Dict, Optional, Sequence

from ..frontends.jaxpr_frontend import ArgSpec
from .bucketing import POW2, BucketPolicy

__all__ = ["DiscEngine"]


class DiscEngine:
    """Deprecated: use ``disc.compile`` (see module docstring)."""

    def __init__(
        self,
        fn: Callable,
        arg_specs: Sequence[ArgSpec],
        *,
        policy: BucketPolicy = POW2,
        name: str = "disc",
        escalation_threshold: Optional[int] = None,
        max_cache_entries: int = 256,
        donate: bool = False,
        backend: str = "xla",
    ) -> None:
        warnings.warn(
            "DiscEngine is deprecated; use disc.compile(fn, specs, "
            "options=disc.CompileOptions(...)) instead",
            DeprecationWarning, stacklevel=2)
        from ..api import CompileOptions
        from ..api.staged import compile as disc_compile

        self.fn = fn
        self.specs = list(arg_specs)
        self.policy = policy
        self.donate = donate
        self.backend = backend
        options = CompileOptions(
            policy=policy, name=name, backend=backend,
            escalation_threshold=escalation_threshold,
            max_cache_entries=max_cache_entries, donate=donate)
        self._compiled = disc_compile(fn, arg_specs, options=options)._ensure()

    # ---------------------------------------------------- old surface --
    def __call__(self, *arrays):
        return self._compiled(*arrays)

    @property
    def graph(self):
        return self._compiled.graph

    @property
    def plan(self):
        return self._compiled.plan

    @property
    def placement(self):
        return self._compiled.placement

    @property
    def buffer_plan(self):
        return self._compiled.buffer_plan

    @property
    def syms(self):
        return self._compiled.syms

    @property
    def cache(self):
        return self._compiled.cache

    @property
    def dispatch_source(self) -> str:
        return self._compiled.dispatch_source

    @property
    def n_compiles(self) -> int:
        return self._compiled.cache.stats.compiles

    def report(self) -> Dict[str, Any]:
        rep = self._compiled.report()
        rep["backend"] = self.backend
        return rep
