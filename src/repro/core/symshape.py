"""Symbolic dimensions and shapes — the substrate of the DHLO-style IR.

DISC (§4.1) keeps *rank* static and lets dimension *sizes* be dynamic.  We
model a dimension as either a concrete ``int`` or a :class:`SymDim` — an
interned symbol.  A :class:`SymDim` carries a *representative value* (the
concrete size used when tracing a representative jaxpr); representative
values are chosen to be distinct primes so that shape re-symbolization after
shape-destroying ops (``reshape``) can recover symbol structure by
factorization (see ``frontends/jaxpr_frontend.py``).

Tensor *sizes* (element counts) are represented canonically as
:class:`SizeExpr` — ``coeff * prod(dims^power)`` — so that DISC's
*tensor size equality* constraint (§4.2.1) is decidable by canonical-form
comparison after dim-equality canonicalization.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, Iterable, Optional, Tuple, Union

__all__ = [
    "SymDim",
    "Dim",
    "SymShape",
    "SizeExpr",
    "dim_value",
    "shape_value",
    "shape_is_static",
    "size_of_shape",
    "fresh_symdim",
    "shape_key",
]

_uid = itertools.count()

# Representative prime values handed out to fresh symbols (skipping tiny
# primes that collide with common static dims like 2/3 heads etc. is not
# needed — we only match *within* a trace, and the frontend assigns them).
_PRIMES = [
    3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43, 47, 53, 59, 61, 67,
    71, 73, 79, 83, 89, 97, 101, 103, 107, 109, 113, 127,
]
_prime_iter = itertools.count()


@dataclass(frozen=True)
class SymDim:
    """An interned symbolic dimension (static rank, dynamic size)."""

    name: str
    uid: int
    rep: int  # representative concrete value used during tracing

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"?{self.name}"

    def __hash__(self) -> int:
        return hash(self.uid)

    def __eq__(self, other: object) -> bool:
        return isinstance(other, SymDim) and other.uid == self.uid


Dim = Union[int, SymDim]
SymShape = Tuple[Dim, ...]


def fresh_symdim(name: str, rep: Optional[int] = None) -> SymDim:
    """Create a fresh symbolic dim with a unique representative prime."""
    if rep is None:
        idx = next(_prime_iter)
        rep = _PRIMES[idx % len(_PRIMES)]
        # keep representatives distinct even past the prime table
        rep += 131 * (idx // len(_PRIMES))
    return SymDim(name=name, uid=next(_uid), rep=int(rep))


def dim_value(d: Dim) -> int:
    """Concrete (representative) value of a dim."""
    return d.rep if isinstance(d, SymDim) else int(d)


def shape_value(shape: SymShape) -> Tuple[int, ...]:
    return tuple(dim_value(d) for d in shape)


def shape_is_static(shape: SymShape) -> bool:
    return all(isinstance(d, int) for d in shape)


@dataclass(frozen=True)
class SizeExpr:
    """Canonical element-count expression: ``coeff * prod(dim^power)``.

    ``dims`` is a sorted tuple of ``(SymDim, power)`` pairs.  Canonical under
    a dim-canonicalization function supplied by the constraint store.
    """

    coeff: int
    dims: Tuple[Tuple[SymDim, int], ...]

    @staticmethod
    def from_shape(shape: SymShape) -> "SizeExpr":
        coeff = 1
        counts: Dict[SymDim, int] = {}
        for d in shape:
            if isinstance(d, SymDim):
                counts[d] = counts.get(d, 0) + 1
            else:
                coeff *= int(d)
        dims = tuple(sorted(counts.items(), key=lambda kv: kv[0].uid))
        return SizeExpr(coeff=coeff, dims=dims)

    def canonicalize(self, canon) -> "SizeExpr":
        """Re-express under dim canonicalization ``canon: SymDim -> Dim``.

        A symbol may canonicalize to another symbol *or* be refined to a
        concrete int (when the store learned its value).
        """
        coeff = self.coeff
        counts: Dict[SymDim, int] = {}
        for d, p in self.dims:
            c = canon(d)
            if isinstance(c, int):
                coeff *= c**p
            else:
                counts[c] = counts.get(c, 0) + p
        dims = tuple(sorted(counts.items(), key=lambda kv: kv[0].uid))
        return SizeExpr(coeff=coeff, dims=dims)

    def value(self) -> int:
        v = self.coeff
        for d, p in self.dims:
            v *= d.rep**p
        return v

    def is_static(self) -> bool:
        return not self.dims

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        parts = [str(self.coeff)] if self.coeff != 1 or not self.dims else []
        for d, p in self.dims:
            parts.append(f"{d!r}" + (f"^{p}" if p > 1 else ""))
        return "*".join(parts) if parts else "1"


def size_of_shape(shape: SymShape) -> SizeExpr:
    return SizeExpr.from_shape(shape)


def shape_key(shape: SymShape, canon=None) -> Tuple:
    """Hashable structural key of a shape under optional canonicalization."""
    out = []
    for d in shape:
        if isinstance(d, SymDim):
            c = canon(d) if canon is not None else d
            out.append(("sym", c.uid) if isinstance(c, SymDim) else ("int", c))
        else:
            out.append(("int", int(d)))
    return tuple(out)
