"""Host-dispatch code generation — DISC §4.2 "generated runtime flow".

    "Rather than using an interpreter, DISC compiles and generates the code
     of computations on both host and device side, and also runtime flows
     (buffer management, kernel launch, et al.)."

:func:`generate_dispatch` *generates Python source* for the host-side
dispatch of one compiled artifact — shape extraction, bucket mapping,
cache lookup, padding plan, device invocation, output recovery — and
``exec``s it once.  The per-call path is straight-line host code
specialized to the artifact: no graph walking, no per-op interpretation
(contrast ``vm.NimbleVM``).

One emitter serves both public pipelines.  Everything pipeline-specific is
factored into a :class:`DispatchLens` — *how* dynamic sizes are observed,
*which* arguments get bucket-padded, and *whether* outputs need recovery:

* :func:`dhlo_lens` views a DHLO graph (``pipeline="dhlo"``): symbols are
  canonicalized through the constraint store, the lens vector of true
  lengths is threaded to the masked executor, and outputs are sliced back
  to their true (possibly derived, §4.2.1) shapes.
* :func:`jit_lens` views a spec signature over a jax-traceable function
  (``pipeline="jit"``): declared dynamic args are bucket-padded, pytree
  args pass through untouched, and the function's own outputs are
  returned as-is (jit-pipeline functions are lens-aware).

Both lenses flow through the same generated skeleton, including the §4.4
static-escalation branch (hot exact signatures route to an unpadded
specialization) and the tie guards that back promote-on-change: when a
symbol is observable at several argument sites, the emitter checks the
sites still agree and either re-lowers through ``on_tie_break`` (inferred
specs) or raises a contract error (declared specs).

This module is pure mechanism: *what* gets compiled per bucket (XLA,
Pallas-fused, an interpreted baseline, or a per-bucket ``jax.jit``) is
supplied by the caller via ``compile_bucket`` / ``compile_exact``
callbacks — the public API layer (``repro.api``) wires those to the
backend registry or to ``jax.jit``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import (Any, Callable, Dict, List, Optional, Sequence, Tuple,
                    Union)

import numpy as np

from ..errors import CompileError
from ..frontends.jaxpr_frontend import TreeSpec, eval_dim
from ..obs import trace as obs_trace
from ..obs.clock import CLOCK as _obs_clock
from .bucketing import BucketPolicy
from .cache import CompileCache
from .dhlo import DGraph
from .symshape import SymDim

__all__ = ["DynAxis", "ArgPlan", "DispatchLens", "DispatchMemStats",
           "dhlo_lens", "jit_lens", "generate_dispatch"]


class DispatchMemStats:
    """Host staging-buffer accounting for one artifact's dispatch.

    The padding plan zero-fills each dynamic argument into a
    bucket-shaped staging buffer; this object tracks those launch bytes
    per call.  ``cap_bytes`` is the worst case (every symbol at its
    ``Dim.max`` cap) fixed at emit time, so ``saved_bytes`` accumulates
    how much bucketing under-shot the caps — the serve engine surfaces
    these as ``mem_*`` gauges.  Staging buffers are never recycled into
    jax calls (on CPU jax may alias a NumPy input zero-copy); instead the
    generated flow drops each staging reference right after the entry
    call, and this object keeps the byte trail.

    On top of the staging-byte trail it carries the dynamic-shape cost
    accounting (always on — a handful of dict/int ops per call): a
    per-bucket hit histogram, padded vs *true* element bytes per launch
    (the padding-waste ratio), and the host-dispatch vs entry-call wall
    split (the dispatch-overhead timer).  ``as_dict()`` keeps its
    original staging-only schema (docs capture it); the cost accounting
    is exposed separately via :meth:`cost_dict`.
    """

    __slots__ = ("calls", "last_bytes", "peak_bytes", "total_bytes",
                 "cap_bytes", "saved_bytes", "true_last_bytes",
                 "true_total_bytes", "host_seconds", "entry_seconds",
                 "per_bucket")

    def __init__(self, cap_bytes: Optional[int] = None) -> None:
        self.calls = 0
        self.last_bytes = 0
        self.peak_bytes = 0
        self.total_bytes = 0
        self.cap_bytes = cap_bytes
        self.saved_bytes = 0
        self.true_last_bytes = 0
        self.true_total_bytes = 0
        self.host_seconds = 0.0
        self.entry_seconds = 0.0
        # bucket key -> [calls, padded_bytes, true_bytes,
        #               host_seconds, entry_seconds]
        self.per_bucket: Dict[Tuple, list] = {}

    def note(self, nbytes: int) -> None:
        self.calls += 1
        self.last_bytes = nbytes
        if nbytes > self.peak_bytes:
            self.peak_bytes = nbytes
        self.total_bytes += nbytes
        if self.cap_bytes is not None:
            self.saved_bytes += self.cap_bytes - nbytes

    def note_call(self, key: Tuple, nbytes: int, true_nbytes: int) -> None:
        """One bucketed launch: padded staging bytes vs true bytes."""
        self.note(nbytes)
        self.true_last_bytes = true_nbytes
        self.true_total_bytes += true_nbytes
        pb = self.per_bucket.get(key)
        if pb is None:
            pb = self.per_bucket[key] = [0, 0, 0, 0.0, 0.0]
        pb[0] += 1
        pb[1] += nbytes
        pb[2] += true_nbytes

    def note_times(self, key: Tuple, host_s: float, entry_s: float) -> None:
        """Wall split for one launch: generated host flow vs entry call."""
        self.host_seconds += host_s
        self.entry_seconds += entry_s
        pb = self.per_bucket.get(key)
        if pb is not None:
            pb[3] += host_s
            pb[4] += entry_s

    def as_dict(self) -> Dict[str, Optional[int]]:
        return {"calls": self.calls, "last_bytes": self.last_bytes,
                "peak_bytes": self.peak_bytes,
                "total_bytes": self.total_bytes,
                "cap_bytes": self.cap_bytes,
                "saved_bytes": self.saved_bytes}

    def cost_dict(self) -> Dict[str, Any]:
        """The dynamic-shape cost view: bucket-hit histogram, padding
        waste, and the host-dispatch / entry-call wall split."""
        waste = (1.0 - self.true_total_bytes / self.total_bytes) \
            if self.total_bytes else 0.0
        return {
            "calls": self.calls,
            "bucket_hits": {str(k): v[0]
                            for k, v in sorted(self.per_bucket.items())},
            "pad_waste_ratio": round(waste, 4),
            "padded_bytes": self.total_bytes,
            "true_bytes": self.true_total_bytes,
            "host_dispatch_seconds": round(self.host_seconds, 6),
            "entry_seconds": round(self.entry_seconds, 6),
            "per_bucket": {
                str(k): {"calls": v[0], "padded_bytes": v[1],
                         "true_bytes": v[2],
                         "pad_waste_ratio": round(
                             1.0 - v[2] / v[1], 4) if v[1] else 0.0,
                         "host_dispatch_seconds": round(v[3], 6),
                         "entry_seconds": round(v[4], 6)}
                for k, v in sorted(self.per_bucket.items())},
        }


# ------------------------------------------------------------------ lens --

@dataclass(frozen=True)
class DynAxis:
    """A dynamic axis inside an :class:`ArgPlan`, bound to symbol ``sym``
    (an index into :attr:`DispatchLens.sym_names`)."""

    sym: int


@dataclass(frozen=True)
class ArgPlan:
    """Pad plan for one positional argument.

    ``shape`` entries are ints (static) or :class:`DynAxis` (zero-pad to
    the symbol's bucket).  ``shape=None`` — or a shape with no dynamic
    axis — marks a pass-through argument (e.g. pytrees in the jit
    pipeline): it reaches the entry untouched, with no host copy.

    ``tree_axes`` marks a *pytree* argument instead (jit-pipeline
    :class:`~repro.frontends.jaxpr_frontend.TreeSpec`): every array leaf
    is zero-padded along each ``(axis, sym)`` pair to the symbol's
    bucket, device-side.  Such an argument contributes no extraction
    sites or tie guards — a pytree has no single shape to observe.
    """

    shape: Optional[Tuple[Union[int, DynAxis], ...]] = None
    dtype: Any = None
    tree_axes: Optional[Tuple[Tuple[int, int], ...]] = None

    @property
    def dynamic(self) -> bool:
        return self.shape is not None and any(
            isinstance(d, DynAxis) for d in self.shape)


@dataclass(frozen=True)
class DispatchLens:
    """Everything pipeline-specific the dispatch emitter consumes.

    * ``sym_names`` — dynamic symbols, in bucket-key order.
    * ``sym_sites`` — per symbol, every ``(arg, axis)`` where its value is
      observable.  The first site is the extraction site; the rest become
      tie guards (two sites of one symbol must agree at call time).
    * ``args``      — per positional argument, the :class:`ArgPlan`.
    * ``outputs``   — per output, per-axis recovery: ``None`` (keep the
      axis), an int symbol index (slice back to the true length), or a
      callable ``exact -> int`` evaluating a derived dim (§4.2.1 host
      shape calculation).  ``outputs=None`` disables recovery entirely:
      the entry's result is returned as-is.
    * ``pass_lens`` — prepend the i32 vector of true lengths to the entry
      call (DHLO masked executors take it; jit-pipeline functions carry
      lengths as ordinary arguments).
    """

    name: str
    sym_names: Tuple[str, ...]
    sym_sites: Tuple[Tuple[Tuple[int, int], ...], ...]
    args: Tuple[ArgPlan, ...]
    outputs: Optional[Tuple[Tuple[Any, ...], ...]] = None
    pass_lens: bool = True
    # one-line summaries of the artifact's region ops (d.while/d.scan/
    # d.cond), surfaced as a header in the generated dispatch source
    regions: Tuple[str, ...] = ()


def dhlo_lens(graph: DGraph, syms: Sequence[SymDim]) -> DispatchLens:
    """View a DHLO graph through the emitter's lens.

    Symbols are resolved through the constraint store's canonical map, so
    two spec dims the propagation pass proved equal share one extraction
    site + tie guard.
    """
    store = graph.store
    syms = list(syms)
    sym_index = {s.uid: i for i, s in enumerate(syms)}

    sites: List[List[Tuple[int, int]]] = [[] for _ in syms]
    args: List[ArgPlan] = []
    for pi, p in enumerate(graph.params):
        shape: List[Union[int, DynAxis]] = []
        for ax, d in enumerate(p.shape):
            c = store.canon_dim(d) if isinstance(d, SymDim) else d
            if isinstance(c, SymDim):
                sites[sym_index[c.uid]].append((pi, ax))
                shape.append(DynAxis(sym_index[c.uid]))
            else:
                shape.append(int(c))
        args.append(ArgPlan(tuple(shape), np.dtype(p.dtype)))

    for i, s in enumerate(syms):
        if not sites[i]:
            raise ValueError(
                f"dynamic symbol {s.name!r} is not observable from any "
                f"input argument; cannot generate dispatch for "
                f"{graph.name!r}")

    dim_exprs = getattr(graph, "dim_exprs", {})
    outputs: List[Tuple[Any, ...]] = []
    for o in graph.outputs:
        axes: List[Any] = []
        for d in o.shape:
            c = store.canon_dim(d) if isinstance(d, SymDim) else d
            if isinstance(c, SymDim):
                if c.uid in sym_index:
                    axes.append(sym_index[c.uid])
                elif dim_exprs.get(c.uid) is None \
                        and dim_exprs.get(d.uid) is None:
                    # widened carry dim (bounded, no derived expr): its
                    # true extent is loop-dependent — keep the padded axis
                    axes.append(None)
                else:
                    axes.append(_derived_dim_evaluator(graph, syms, d))
            else:
                axes.append(None)
        outputs.append(tuple(axes))

    regions: List[str] = []
    for op in graph.ops:
        if op.opcode == "d.while":
            regions.append(
                f"d.while(cond={len(op.attrs['cond_graph'].ops)} ops, "
                f"body={len(op.attrs['body_graph'].ops)} ops)")
        elif op.opcode == "d.scan":
            regions.append(
                f"d.scan(body={len(op.attrs['body_graph'].ops)} ops, "
                f"carries={op.attrs['num_carry']})")
        elif op.opcode == "d.cond":
            regions.append(
                f"d.cond(branches={len(op.attrs['branch_graphs'])})")

    return DispatchLens(
        name=graph.name, sym_names=tuple(s.name for s in syms),
        sym_sites=tuple(tuple(s) for s in sites), args=tuple(args),
        outputs=tuple(outputs), pass_lens=True, regions=tuple(regions))


def jit_lens(specs: Sequence[Any], sym_names: Sequence[str],
             name: str = "disc") -> DispatchLens:
    """View a spec signature (``pipeline="jit"``) through the emitter's
    lens: string dims are the symbols, ``None`` specs pass through,
    ``TreeSpec`` pytrees are leaf-padded, and outputs need no recovery
    (the function is lens-aware)."""
    sym_names = list(sym_names)
    sym_index = {n: i for i, n in enumerate(sym_names)}
    sites: List[List[Tuple[int, int]]] = [[] for _ in sym_names]
    args: List[ArgPlan] = []
    for ai, spec in enumerate(specs):
        if spec is None:
            args.append(ArgPlan())
            continue
        if isinstance(spec, TreeSpec):
            args.append(ArgPlan(tree_axes=tuple(
                (axis, sym_index[d]) for axis, d in spec.axes)))
            continue
        shape: List[Union[int, DynAxis]] = []
        for ax, d in enumerate(spec.shape):
            if isinstance(d, str):
                sites[sym_index[d]].append((ai, ax))
                shape.append(DynAxis(sym_index[d]))
            else:
                shape.append(int(d))
        if any(isinstance(d, DynAxis) for d in shape):
            args.append(ArgPlan(tuple(shape), np.dtype(spec.dtype)))
        else:
            args.append(ArgPlan())  # fully static: no host copy needed
    for i, n in enumerate(sym_names):
        if not sites[i]:
            raise ValueError(
                f"dynamic symbol {n!r} is not observable from any "
                f"argument spec; cannot generate dispatch for {name!r}")
    return DispatchLens(
        name=name, sym_names=tuple(sym_names),
        sym_sites=tuple(tuple(s) for s in sites), args=tuple(args),
        outputs=None, pass_lens=False)


def _derived_dim_evaluator(graph: DGraph, syms: Sequence[SymDim], dim):
    """Host-side shape calculation for a derived output dim (§4.2.1)."""
    syms = list(syms)

    def _eval(exact: Tuple[int, ...]) -> int:
        binds = {s.uid: v for s, v in zip(syms, exact)}
        return eval_dim(graph, dim, binds)

    return _eval


def _tie_error(name: str, site_a: Tuple[int, int], va: int,
               site_b: Tuple[int, int], vb: int):
    raise ValueError(
        f"dim {name!r} is tied across arguments (declared with one symbol, "
        f"or inferred equal from the first call), but this call breaks the "
        f"tie: arrays[{site_a[0]}].shape[{site_a[1]}] == {va} vs "
        f"arrays[{site_b[0]}].shape[{site_b[1]}] == {vb}")


def _cap_error(name: str, value: int, cap: int):
    raise ValueError(f"dim {name}={value} exceeds its declared max={cap}")


def _tree_padder(tree_axes: Tuple[Tuple[int, int], ...]) -> Callable:
    """Bucket-pad every array leaf of a pytree argument (``TreeSpec``).

    Runs device-side (``jnp.pad``): the leaves are typically resident
    device arrays (e.g. gathered KV-cache rows) and a host round-trip per
    call would dwarf the padding itself.
    """
    def pad(tree, key):
        import jax
        import jax.numpy as jnp

        def pad_leaf(x):
            shape = getattr(x, "shape", None)
            if shape is None:
                return x
            widths = None
            for axis, sym in tree_axes:
                if axis < len(shape) and shape[axis] < key[sym]:
                    if widths is None:
                        widths = [(0, 0)] * len(shape)
                    widths[axis] = (0, key[sym] - shape[axis])
            return x if widths is None else jnp.pad(x, widths)

        return jax.tree.map(pad_leaf, tree)

    return pad


# --------------------------------------------------------------- emitter --

def generate_dispatch(
    lens: DispatchLens,
    policy: BucketPolicy,
    cache: CompileCache,
    compile_bucket: Callable[[Tuple[int, ...]], Any],
    compile_exact: Optional[Callable[[], Callable]] = None,
    *,
    fingerprint: Optional[str] = None,
    escalation_threshold: Optional[int] = None,
    on_tie_break: Optional[Callable[[Sequence[Any]], Any]] = None,
    sharding: Optional[Any] = None,
    memory_plan: Optional[Any] = None,
) -> Tuple[Callable, str]:
    """Generate the per-call host flow for one artifact, seen through
    ``lens``.

    Returns ``(dispatch, source)`` where ``dispatch(arrays)`` is the
    compiled host function and ``source`` the generated Python text (kept
    as an inspectable artifact on the public ``Compiled`` object).
    ``dispatch`` returns a list of recovered outputs when the lens
    declares output plans, or the entry's raw result when it doesn't.

    ``fingerprint`` defaults to ``cache.fingerprint``; pass the artifact's
    own fingerprint when several artifacts share one cache.  The §4.4
    escalation branch is emitted when an ``escalation_threshold`` (or the
    cache's default) and ``compile_exact`` are given.  ``on_tie_break``
    handles a call that breaks a multi-site symbol tie (promote-on-change
    re-lowering); without it such a call raises a contract error.

    ``memory_plan`` is the lowered artifact's
    :class:`~repro.core.buffers.BufferPlan`: its bucket-generic
    alloc/reuse/free lines are emitted into the generated source as the
    memory-plan block (the wrapper-IR view of what every bucket entry
    and the VM execute), and the per-call staging accounting
    (``dispatch._mstats``, a :class:`DispatchMemStats`) is recorded
    against the plan's worst-case cap bytes.

    ``sharding`` is an SPMD :class:`~repro.dist.spmd.ShardingPlan`: the
    generated flow then ``device_put``\\ s every padded bucket buffer to
    its planned ``NamedSharding`` (buckets divide the mesh axes evenly by
    the plan's tightened policy), pytree arguments through the plan's
    per-leaf sharder, and the lens vector replicated; the escalation
    branch re-fits shardings to the exact shapes.
    """
    fingerprint = fingerprint or cache.fingerprint
    if escalation_threshold is None:
        escalation_threshold = cache.escalation_threshold
    if compile_exact is None:
        escalation_threshold = None
    n_syms = len(lens.sym_names)

    def _arg_put(ai: int) -> Optional[Callable]:
        if sharding is None:
            return None
        sh = sharding.arg_sharding(ai)
        if sh is None:
            return None

        def put(x, _sh=sh):
            import jax
            return jax.device_put(x, _sh)

        return put

    # --- staging-byte accounting: padded launch bytes per call ---------
    # (sum over dynamic args of itemsize * prod(bucketed/static axes);
    # worst case fixes every symbol at its policy cap, when all are
    # capped — the delta per call is what bucketing saved vs the caps)
    byte_terms: List[str] = []
    true_terms: List[str] = []
    cap_bytes: Optional[int] = 0
    for ap in lens.args:
        if not (ap.shape is not None and ap.dynamic):
            continue
        itemsize = np.dtype(ap.dtype).itemsize
        parts, true_parts, cap_prod = [], [], itemsize
        for d in ap.shape:
            if isinstance(d, DynAxis):
                parts.append(f"key[{d.sym}]")
                true_parts.append(f"s_{d.sym}")
                cap = policy.cap(lens.sym_names[d.sym])
                cap_prod = None if (cap is None or cap_prod is None) \
                    else cap_prod * cap
            else:
                parts.append(str(d))
                true_parts.append(str(d))
                if cap_prod is not None:
                    cap_prod *= d
        byte_terms.append(f"{itemsize}*" + "*".join(parts))
        true_terms.append(f"{itemsize}*" + "*".join(true_parts))
        cap_bytes = None if (cap_bytes is None or cap_prod is None) \
            else cap_bytes + cap_prod
    mstats = DispatchMemStats(cap_bytes=cap_bytes or None)
    bytes_expr = " + ".join(byte_terms) if byte_terms else "0"
    # true (unpadded) launch bytes: same terms over the exact sizes —
    # the padded/true delta per bucket is the padding-waste accounting
    true_bytes_expr = " + ".join(true_terms) if true_terms else "0"

    # --- region-op block: traced control flow inside one artifact ------
    header: List[str] = []
    if lens.regions:
        header.append("# -- region ops (control flow traced INTO the "
                      "bucketed artifact; the")
        header.append("#    bucket key below is entry shapes only — "
                      "iteration-varying shapes")
        header.append("#    never multiply compile counts) --")
        for r in lens.regions:
            header.append(f"#   {r}")

    # --- memory-plan block: the wrapper-IR view of the buffer plan -----
    if memory_plan is not None and getattr(memory_plan, "lines_text", None):
        rc = dict(memory_plan.reuse_counts)
        header.append("# -- memory plan (bucket-generic, symbolic; every "
                      "entry + the VM execute this) --")
        header.append(f"#   slots={memory_plan.n_slots} "
                      f"values={memory_plan.n_values} reuse={rc}")
        header.append(f"#   peak = {memory_plan.symbolic_peak()}  "
                      f"(no reuse: {memory_plan.symbolic_peak_no_reuse()})")
        for ln in memory_plan.lines_text:
            header.append(f"#   {ln}")

    lines: List[str] = ["def _dispatch(arrays):"]
    w = lines.append
    ns: Dict[str, Any] = {
        "_np": np,
        "_fp": fingerprint,
        "_esc": escalation_threshold,
        "_cache": cache,
        "_zero_lens": np.zeros((1,), np.int32),
        "_clk": _obs_clock,
        "_trace": obs_trace,
        "_name": lens.name,
    }

    # dispatch-overhead timer (always on): host flow vs entry call
    w("    _t0 = _clk()")

    # --- dynamic-size extraction: one site per symbol, straight-line ---
    for i in range(n_syms):
        pi, ax = lens.sym_sites[i][0]
        w(f"    s_{i} = arrays[{pi}].shape[{ax}]")

    # --- tie guards: remaining sites of a symbol must agree -----------
    any_guard = False
    for i, name in enumerate(lens.sym_names):
        first = lens.sym_sites[i][0]
        for (pi, ax) in lens.sym_sites[i][1:]:
            any_guard = True
            w(f"    if arrays[{pi}].shape[{ax}] != s_{i}:")
            if on_tie_break is not None:
                w("        return _tie_break(arrays)")
            else:
                w(f"        _tie_error({name!r}, {first!r}, s_{i}, "
                  f"{(pi, ax)!r}, arrays[{pi}].shape[{ax}])")
    if any_guard:
        if on_tie_break is not None:
            ns["_tie_break"] = on_tie_break
        else:
            ns["_tie_error"] = _tie_error

    # --- bucket key: inlined bucket math where the policy supports it --
    key_parts: List[str] = []
    for i, name in enumerate(lens.sym_names):
        expr = policy.emit_bucket_expr(name, f"s_{i}")
        cap = policy.cap(name)
        if expr is None:
            # opaque rule: fall back to a bound closure (cap included)
            ns[f"_b{i}"] = (lambda v, _p=policy, _n=name: _p.bucket(_n, int(v)))
            key_parts.append(f"_b{i}(s_{i})")
            continue
        if cap is not None:
            w(f"    if s_{i} > {cap}:")
            w(f"        _cap_error({name!r}, s_{i}, {cap})")
            ns["_cap_error"] = _cap_error
            expr = f"min({expr}, {cap})"
        key_parts.append(expr)
    if n_syms:
        w("    key = (" + ", ".join(key_parts) + ",)")
        w("    exact = (" + ", ".join(f"s_{i}" for i in range(n_syms)) + ",)")
    else:
        w("    key = ()")
        w("    exact = ()")

    # --- §4.4 static escalation: hot exact signatures go unpadded ------
    if escalation_threshold is not None:
        # degradation ladder: a failed escalation compile falls back to
        # the padded bucket artifact below — permanent failures pin the
        # exact sig (should_escalate answers False thereafter), transient
        # ones may escalate again on a later call
        w("    if _cache.should_escalate(exact, _fp, _esc):")
        w("        try:")
        w("            fn = _cache.get_or_compile_exact("
          "exact, _compile_exact, _fp)")
        w("        except _CompileError as _ce:")
        w("            fn = None")
        w("            if not _ce.transient:")
        w("                _cache.note_escalation_failure(exact, _fp)")
        # under a mesh, exact shapes need not divide the axes: re-fit
        # the planned shardings to the concrete shapes per arg
        call_arrays = "arrays" if sharding is None else "_put_exact(arrays)"
        w("        if fn is not None:")
        if lens.outputs is None:
            w(f"            return fn(*{call_arrays})")
        else:
            w(f"            return list(fn(*{call_arrays}))")
        ns["_compile_exact"] = compile_exact
        ns["_CompileError"] = CompileError
        if sharding is not None:
            ns["_put_exact"] = sharding.put_exact

    w(f"    _pb = {bytes_expr}")
    w(f"    _tb = {true_bytes_expr}")
    w("    _mstats.note_call(key, _pb, _tb)")
    ns["_mstats"] = mstats
    w("    entry = _get(('bucket', _fp, key))")
    # span hooks are emitted unconditionally (the source is identical
    # whether tracing is on or off); the runtime guard is one attribute
    # load + `is None` test, the ft/faults.py zero-overhead discipline
    w("    _tr = _trace.ACTIVE")
    w("    _sp = _tr.begin('dispatch', cat='dispatch', artifact=_name, "
      "bucket=key, pad_bytes=_pb - _tb, cache_hit=entry is not None) "
      "if _tr is not None else None")
    w("    if entry is None:")
    w("        try:")
    w("            entry = _compile(key)")
    w("        except BaseException:")
    w("            if _sp is not None:")
    w("                _sp.end(error=True)")
    w("            raise")
    if lens.pass_lens:
        if n_syms:
            w("    lens = _np.array(["
              + ", ".join(f"s_{i}" for i in range(n_syms))
              + "], _np.int32)")
        else:
            w("    lens = _zero_lens")
        if sharding is not None:
            # true lengths are replicated control state: every mesh
            # participant masks with the same lens vector
            w("    lens = _put_lens(lens)")
            lens_sh = sharding.lens_sharding()

            def _put_lens(v, _sh=lens_sh):
                import jax
                return jax.device_put(v, _sh)

            ns["_put_lens"] = _put_lens

    # --- padding plan: unrolled per argument (host-side zero-fill) -----
    call_args: List[str] = []
    for ai, ap in enumerate(lens.args):
        put = _arg_put(ai)
        if ap.tree_axes:
            # pytree argument (TreeSpec): leaf-pad to the bucket key
            w(f"    x{ai} = _padtree{ai}(arrays[{ai}], key)")
            ns[f"_padtree{ai}"] = _tree_padder(ap.tree_axes)
            sharder = sharding.tree_sharder(ai) if sharding is not None \
                else None
            if sharder is not None:
                w(f"    x{ai} = _shardtree{ai}(x{ai})")
                ns[f"_shardtree{ai}"] = sharder
            call_args.append(f"x{ai}")
            continue
        if not ap.dynamic:
            if put is not None:
                # static argument: profile layout, fitted at plan time
                w(f"    x{ai} = _put{ai}(arrays[{ai}])")
                ns[f"_put{ai}"] = put
                call_args.append(f"x{ai}")
            else:
                call_args.append(f"arrays[{ai}]")
            continue
        shape_expr = []
        for d in ap.shape:
            shape_expr.append(f"key[{d.sym}]" if isinstance(d, DynAxis)
                              else str(d))
        pshape = ("(" + ", ".join(shape_expr)
                  + ("," if len(shape_expr) == 1 else "") + ")")
        var = f"x{ai}"
        w(f"    {var} = arrays[{ai}]")
        w(f"    if tuple({var}.shape) != {pshape}:")
        w(f"        _buf = _np.zeros({pshape}, _dt{ai})")
        idx = ", ".join(
            (f":{var}.shape[{ax}]" if isinstance(d, DynAxis) else ":")
            for ax, d in enumerate(ap.shape))
        w(f"        _buf[{idx}] = _np.asarray({var})")
        w(f"        {var} = _buf")
        ns[f"_dt{ai}"] = np.dtype(ap.dtype)
        if put is not None:
            # padded bucket → its planned NamedSharding (buckets are
            # mesh-axis multiples by the tightened policy, so the split
            # is always even)
            w(f"    {var} = _put{ai}({var})")
            ns[f"_put{ai}"] = put
        call_args.append(var)

    entry_args = (["lens"] if lens.pass_lens else []) + call_args
    call = f"entry({', '.join(entry_args)})"
    # staging buffers we materialized (padded copies / padded trees):
    # drop each reference right after the entry call — the plan's free
    # discipline applied to the host side (never recycled into jax)
    staged_vars = [a for a in call_args if a != "arrays" and
                   not a.startswith("arrays[")]

    def _free_staging():
        for var in staged_vars:
            w(f"    {var} = None  # plan: free staging")

    def _timed_call():
        w("    _t1 = _clk()")
        w("    try:")
        w(f"        outs = {call}")
        w("    except BaseException:")
        w("        if _sp is not None:")
        w("            _sp.end(error=True)")
        w("        raise")
        w("    _t2 = _clk()")
        w("    _mstats.note_times(key, _t1 - _t0, _t2 - _t1)")
        w("    if _sp is not None:")
        w("        _sp.end(entry_seconds=_t2 - _t1)")

    # --- output recovery: slice back to true shapes (dhlo only) --------
    if lens.outputs is None:
        _timed_call()
        _free_staging()
        w("    return outs")
    else:
        _timed_call()
        _free_staging()
        out_exprs = []
        for oi, axes in enumerate(lens.outputs):
            idx_parts = []
            needs_slice = False
            for ax, a in enumerate(axes):
                if a is None:
                    idx_parts.append(":")
                elif isinstance(a, int):
                    idx_parts.append(f":s_{a}")
                    needs_slice = True
                else:  # derived-dim evaluator (host shape calc, §4.2.1)
                    idx_parts.append(f":_od{oi}_{ax}(exact)")
                    ns[f"_od{oi}_{ax}"] = a
                    needs_slice = True
            if needs_slice:
                out_exprs.append(f"outs[{oi}][{', '.join(idx_parts)}]")
            else:
                out_exprs.append(f"outs[{oi}]")
        w("    return [" + ", ".join(out_exprs) + "]")

    src = "\n".join(header + lines)

    # namespace bound once at generation time (compiled host flow)
    _entries_get = cache._entries.get
    _move_to_end = cache._entries.move_to_end
    _stats = cache.stats

    def _get(key):
        e = _entries_get(key)
        if e is not None:
            _stats.hits += 1
            _move_to_end(key)  # keep hot buckets at the LRU tail
        return e

    def _compile(key):
        return cache.get_or_compile(key, lambda: compile_bucket(key),
                                    fingerprint=fingerprint)

    ns["_get"] = _get
    ns["_compile"] = _compile

    exec(compile(src, f"<disc-dispatch:{lens.name}>", "exec"), ns)
    dispatch = ns["_dispatch"]
    dispatch._mstats = mstats          # staging accounting (report/serve)
    dispatch._memory_plan = memory_plan
    return dispatch, src
