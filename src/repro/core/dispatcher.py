"""Host-dispatch code generation — DISC §4.2 "generated runtime flow".

    "Rather than using an interpreter, DISC compiles and generates the code
     of computations on both host and device side, and also runtime flows
     (buffer management, kernel launch, et al.)."

:func:`generate_dispatch` *generates Python source* for the host-side
dispatch of one DHLO graph — shape extraction, bucket mapping, cache
lookup, padding plan, device invocation, output recovery — and ``exec``s
it once.  The per-call path is straight-line host code specialized to the
graph: no graph walking, no per-op interpretation (contrast
``vm.NimbleVM``).

This module is pure mechanism: *what* gets compiled per bucket (XLA,
Pallas-fused, or an interpreted baseline) is supplied by the caller via
``compile_bucket`` / ``compile_exact`` callbacks — the public API layer
(``repro.api``) wires those to the backend registry.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..frontends.jaxpr_frontend import eval_dim
from .bucketing import BucketPolicy
from .cache import CompileCache
from .dhlo import DGraph
from .symshape import SymDim

__all__ = ["generate_dispatch"]


def generate_dispatch(
    graph: DGraph,
    syms: Sequence[SymDim],
    policy: BucketPolicy,
    cache: CompileCache,
    compile_bucket: Callable[[Tuple[int, ...]], Any],
    compile_exact: Callable[[], Callable],
    *,
    fingerprint: Optional[str] = None,
    escalation_threshold: Optional[int] = None,
) -> Tuple[Callable, str]:
    """Generate the per-call host flow for ``graph``.

    Returns ``(dispatch, source)`` where ``dispatch(arrays) -> [outputs]``
    is the compiled host function and ``source`` the generated Python text
    (kept as an inspectable artifact on the public ``Compiled`` object).

    ``fingerprint`` defaults to ``cache.fingerprint``; pass the artifact's
    own fingerprint when several artifacts share one cache.
    """
    g = graph
    fingerprint = fingerprint or cache.fingerprint
    if escalation_threshold is None:
        escalation_threshold = cache.escalation_threshold
    store = g.store
    syms = list(syms)
    sym_index = {s.uid: i for i, s in enumerate(syms)}

    # one extraction site per symbol: first (param, axis) where it occurs
    extract: Dict[int, Tuple[int, int]] = {}
    for pi, p in enumerate(g.params):
        for ax, d in enumerate(p.shape):
            if isinstance(d, SymDim):
                c = store.canon_dim(d)
                if isinstance(c, SymDim) and c.uid not in extract:
                    extract[c.uid] = (pi, ax)

    lines: List[str] = ["def _dispatch(arrays):"]
    w = lines.append
    names = []
    for s in syms:
        pi, ax = extract[s.uid]
        nm = f"s_{s.uid}"
        names.append(nm)
        w(f"    {nm} = arrays[{pi}].shape[{ax}]")
    if syms:
        w("    key = (" + ", ".join(f"_b{i}({nm})" for i, nm in enumerate(names)) + ",)")
        w("    exact = (" + ", ".join(names) + ",)")
    else:
        w("    key = ()")
        w("    exact = ()")

    # §4.4 static escalation branch
    if escalation_threshold is not None:
        w("    if _cache.should_escalate(exact, _fp, _esc):")
        w("        fn = _cache.get_or_compile_exact(exact, _compile_exact, _fp)")
        w("        return list(fn(*arrays))")

    w("    entry = _get(('bucket', _fp, key))")
    w("    if entry is None:")
    w("        entry = _compile(key)")
    if syms:
        w(f"    lens = _np.array([{', '.join(names)}], _np.int32)")
    else:
        w("    lens = _zero_lens")

    # padding plan: unrolled per param (host-side zero-fill)
    call_args = []
    for pi, p in enumerate(g.params):
        dyn_axes = []
        shape_expr = []
        for ax, d in enumerate(p.shape):
            if isinstance(d, SymDim):
                c = store.canon_dim(d)
                if isinstance(c, SymDim):
                    dyn_axes.append((ax, sym_index[c.uid]))
                    shape_expr.append(f"key[{sym_index[c.uid]}]")
                else:
                    shape_expr.append(str(c))
            else:
                shape_expr.append(str(d))
        var = f"x{pi}"
        if not dyn_axes:
            w(f"    {var} = arrays[{pi}]")
        else:
            pshape = "(" + ", ".join(shape_expr) + ("," if len(shape_expr) == 1 else "") + ")"
            w(f"    {var} = arrays[{pi}]")
            w(f"    if tuple({var}.shape) != {pshape}:")
            w(f"        _buf = _np.zeros({pshape}, _dt{pi})")
            idx = ", ".join(
                (f":{var}.shape[{ax}]" if any(ax == a for a, _ in dyn_axes) else ":")
                for ax in range(p.rank)
            )
            w(f"        _buf[{idx}] = _np.asarray({var})")
            w(f"        {var} = _buf")
        call_args.append(var)

    w(f"    outs = entry(lens, {', '.join(call_args)})" if call_args
      else "    outs = entry(lens)")

    # output recovery: slice back to true shapes
    out_exprs = []
    for oi, o in enumerate(g.outputs):
        idx_parts = []
        needs_slice = False
        for ax, d in enumerate(o.shape):
            if isinstance(d, int):
                idx_parts.append(":")
                continue
            c = store.canon_dim(d)
            if isinstance(c, int):
                idx_parts.append(":")
            elif c.uid in sym_index:
                idx_parts.append(f":s_{c.uid}")
                needs_slice = True
            else:
                idx_parts.append(f":_od{oi}_{ax}(exact)")
                needs_slice = True
        if needs_slice:
            out_exprs.append(f"outs[{oi}][{', '.join(idx_parts)}]")
        else:
            out_exprs.append(f"outs[{oi}]")
    w("    return [" + ", ".join(out_exprs) + "]")

    src = "\n".join(lines)

    # namespace bound once at generation time (compiled host flow)
    _entries_get = cache._entries.get
    _move_to_end = cache._entries.move_to_end
    _stats = cache.stats

    def _get(key):
        e = _entries_get(key)
        if e is not None:
            _stats.hits += 1
            _move_to_end(key)  # keep hot buckets at the LRU tail
        return e

    ns: Dict[str, Any] = {
        "_np": np,
        "_fp": fingerprint,
        "_esc": escalation_threshold,
        "_get": _get,
        "_cache": cache,
        "_compile_exact": compile_exact,
        "_zero_lens": np.zeros((1,), np.int32),
    }
    for i, s in enumerate(syms):
        ns[f"_b{i}"] = (lambda v, _p=policy, _n=s.name: _p.bucket(_n, int(v)))
    for pi, p in enumerate(g.params):
        ns[f"_dt{pi}"] = np.dtype(p.dtype)

    def _compile(key):
        return cache.get_or_compile(key, lambda: compile_bucket(key),
                                    fingerprint=fingerprint)

    ns["_compile"] = _compile

    # derived-output-dim evaluators (host shape calculation, §4.2.1)
    for oi, o in enumerate(g.outputs):
        for ax, d in enumerate(o.shape):
            if isinstance(d, SymDim):
                c = store.canon_dim(d)
                if isinstance(c, SymDim) and c.uid not in sym_index:
                    def _mk(dim):
                        def _f(exact):
                            binds = {s.uid: v for s, v in zip(syms, exact)}
                            return eval_dim(g, dim, binds)
                        return _f
                    ns[f"_od{oi}_{ax}"] = _mk(d)

    exec(compile(src, f"<disc-dispatch:{g.name}>", "exec"), ns)
    return ns["_dispatch"], src
