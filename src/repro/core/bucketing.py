"""Bucketing policies — the TPU-native realization of "compile once per
fusion pattern" (DESIGN.md §2).

XLA's static-shape contract means truly shape-polymorphic device code does
not exist on TPU; DISC-JAX compiles **once per (pattern, bucket)** and makes
each compiled artifact *exact* for every shape ≤ bucket by threading actual
lengths as runtime scalars and masking (see ``runtime.py``).  Buckets bound
the compile count at O(log max_shape) instead of O(#distinct shapes).

Policies:

* ``pow2``      — round up to granule·2^k (default; log-many buckets)
* ``multiple``  — round up to a multiple of k (linear-many buckets, less
  padding waste; good when shapes cluster)
* ``exact``     — no bucketing: compile per concrete shape.  This *is* the
  static-shape-compiler baseline (XLA behavior the paper critiques) and is
  used as such in the benchmarks.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

__all__ = ["BucketPolicy", "pow2_bucket"]


def pow2_bucket(n: int, granule: int = 1) -> int:
    if n <= granule:
        return granule
    return granule * (1 << math.ceil(math.log2(n / granule)))


@dataclass(frozen=True)
class BucketPolicy:
    kind: str = "pow2"          # "pow2" | "multiple" | "exact"
    granule: int = 16           # pow2: smallest bucket; multiple: the multiple
    # per-symbol overrides: symbol name -> (kind, granule)
    overrides: Tuple[Tuple[str, Tuple[str, int]], ...] = ()
    # per-symbol hard caps (declared ``Dim(max=...)``): buckets are clamped
    # to the cap; a value beyond the cap is a contract violation
    caps: Tuple[Tuple[str, int], ...] = ()

    def _rule(self, symbol_name: str) -> Tuple[str, int]:
        for name, rule in self.overrides:
            if name == symbol_name:
                return rule
        return (self.kind, self.granule)

    def rule(self, symbol_name: str) -> Tuple[str, int]:
        """The effective ``(kind, granule)`` for a symbol — public so the
        SPMD planner can tighten granules to mesh-axis multiples."""
        return self._rule(symbol_name)

    def cap(self, symbol_name: str) -> Optional[int]:
        for name, c in self.caps:
            if name == symbol_name:
                return c
        return None

    def bucket(self, symbol_name: str, value: int) -> int:
        kind, g = self._rule(symbol_name)
        if kind == "exact":
            b = value
        elif kind == "multiple":
            b = g * math.ceil(value / g)
        elif kind == "pow2":
            b = pow2_bucket(value, g)
        else:
            raise ValueError(f"unknown bucket kind {kind}")
        c = self.cap(symbol_name)
        if c is not None:
            if value > c:
                raise ValueError(
                    f"dim {symbol_name}={value} exceeds its declared "
                    f"max={c}")
            b = min(b, c)
        return b

    def emit_bucket_expr(self, symbol_name: str, var: str) -> Optional[str]:
        """A Python expression computing ``self.bucket(symbol_name, v)``
        for the source variable ``var`` — *sans* cap handling, which the
        dispatch emitter layers on top.

        This is how the bucket mapping gets *compiled into* the generated
        host flow (DISC §4.2) instead of living behind a per-call closure.
        Returns ``None`` for rules that cannot be inlined (the emitter
        then falls back to a bound ``bucket`` closure).  The pow2 form is
        pure integer math — ``ceil(v/g)`` rounded up to a power of two —
        and agrees with :func:`pow2_bucket` everywhere (see the
        equivalence test in ``tests/test_dispatch_unification.py``).
        """
        kind, g = self._rule(symbol_name)
        if kind == "exact":
            return var
        if kind == "multiple":
            return f"(-(-{var} // {g}) * {g})"
        if kind == "pow2":
            return (f"({g} if {var} <= {g} else "
                    f"{g} * (1 << (-(-{var} // {g}) - 1).bit_length()))")
        return None

    def max_buckets(self, symbol_name: str, max_value: int) -> int:
        """Upper bound on #buckets a symbol can produce up to max_value."""
        kind, g = self._rule(symbol_name)
        if kind == "exact":
            return max_value
        if kind == "multiple":
            return math.ceil(max_value / g)
        return int(math.ceil(math.log2(max(max_value / g, 1)))) + 1

    def padded_fraction(self, symbol_name: str, value: int) -> float:
        """Fraction of wasted (padded) elements for a value — perf metric."""
        b = self.bucket(symbol_name, value)
        return (b - value) / b if b else 0.0


EXACT = BucketPolicy(kind="exact")
POW2 = BucketPolicy(kind="pow2", granule=16)
