"""DHLO-style graph IR — DISC §4.1.

The paper's key IR move: ops whose HLO definition bakes shape information
into *compile-time constant attributes* (slice indices, pad amounts,
broadcast sizes, reshape targets) are re-expressed with **tensor operands**
so one compiled artifact can serve any runtime shape.  We mirror that here:

* every :class:`DOp` separates ``inputs`` (data operands) from
  ``shape_operands`` (DHLO's attr-replacing tensor operands — e.g.
  ``dslice`` start indices);
* dimension sizes in :class:`DValue` shapes may be symbolic
  (:class:`~repro.core.symshape.SymDim`) — rank is always static, matching
  DISC's "dynamic shapes with static rank" scoping;
* a graph owns a :class:`~repro.core.constraints.ShapeConstraintStore`
  populated while the graph is built (op-semantic constraints) and by the
  frontend bridge (high-level-op hints).

The *pattern fingerprint* (:meth:`DGraph.fingerprint`) deliberately excludes
concrete dimension values — DISC's insight that "we do not need to consider
shape information to check whether two fusion patterns are the same for code
generation".  The compile cache keys on it plus a bucket signature.
"""
from __future__ import annotations

import hashlib
import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .constraints import ShapeConstraintStore
from .symshape import Dim, SymDim, SymShape, shape_is_static, shape_value

__all__ = ["DValue", "DOp", "DGraph"]

_val_ids = itertools.count()
_op_ids = itertools.count()


@dataclass
class DValue:
    """An SSA value (tensor) in the graph."""

    shape: SymShape
    dtype: Any
    name: str = ""
    vid: int = field(default_factory=lambda: next(_val_ids))
    # literal payload for constants (numpy array), else None
    literal: Optional[np.ndarray] = None

    @property
    def rank(self) -> int:
        return len(self.shape)

    def concrete_shape(self) -> Tuple[int, ...]:
        return shape_value(self.shape)

    def is_static(self) -> bool:
        return shape_is_static(self.shape)

    def __hash__(self) -> int:
        return hash(self.vid)

    def __eq__(self, other: object) -> bool:
        return isinstance(other, DValue) and other.vid == self.vid

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        dt = np.dtype(self.dtype).name if self.dtype is not None else "?"
        return f"%{self.vid}{':' + self.name if self.name else ''}<{list(self.shape)};{dt}>"


@dataclass
class DOp:
    """A DHLO op.  ``shape_operands`` replace HLO's constant shape attrs."""

    opcode: str
    inputs: List[DValue]
    outputs: List[DValue]
    shape_operands: List[DValue] = field(default_factory=list)
    attrs: Dict[str, Any] = field(default_factory=dict)
    oid: int = field(default_factory=lambda: next(_op_ids))

    def all_operands(self) -> List[DValue]:
        return self.inputs + self.shape_operands

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        outs = ", ".join(map(repr, self.outputs))
        ins = ", ".join(map(repr, self.inputs))
        sh = ("; shape_ops=" + ", ".join(map(repr, self.shape_operands))) if self.shape_operands else ""
        return f"{outs} = {self.opcode}({ins}{sh})"


class DGraph:
    """A DHLO computation graph (hub IR for all frontends — §4.4)."""

    def __init__(self, name: str = "graph") -> None:
        self.name = name
        self.params: List[DValue] = []
        self.ops: List[DOp] = []
        self.outputs: List[DValue] = []
        self.store = ShapeConstraintStore()
        self._producer: Dict[int, DOp] = {}

    # ------------------------------------------------------------ build --
    def add_param(self, shape: SymShape, dtype, name: str = "") -> DValue:
        v = DValue(shape=tuple(shape), dtype=dtype, name=name or f"arg{len(self.params)}")
        self.params.append(v)
        self.store.note_value_size(v.vid, v.shape)
        return v

    def add_const(self, array: np.ndarray, name: str = "") -> DValue:
        array = np.asarray(array)
        v = DValue(shape=tuple(array.shape), dtype=array.dtype, name=name, literal=array)
        self.store.note_value_size(v.vid, v.shape)
        return v

    def add_op(
        self,
        opcode: str,
        inputs: Sequence[DValue],
        out_shapes: Sequence[SymShape],
        out_dtypes: Sequence[Any],
        shape_operands: Sequence[DValue] = (),
        attrs: Optional[Dict[str, Any]] = None,
    ) -> DOp:
        outs = [DValue(shape=tuple(s), dtype=dt) for s, dt in zip(out_shapes, out_dtypes)]
        op = DOp(
            opcode=opcode,
            inputs=list(inputs),
            outputs=outs,
            shape_operands=list(shape_operands),
            attrs=dict(attrs or {}),
        )
        self.ops.append(op)
        for o in outs:
            self._producer[o.vid] = op
            self.store.note_value_size(o.vid, o.shape)
        return op

    def set_outputs(self, outs: Sequence[DValue]) -> None:
        self.outputs = list(outs)

    # ----------------------------------------------------------- queries --
    def producer(self, v: DValue) -> Optional[DOp]:
        return self._producer.get(v.vid)

    def users(self) -> Dict[int, List[DOp]]:
        table: Dict[int, List[DOp]] = {}
        for op in self.ops:
            for v in op.all_operands():
                table.setdefault(v.vid, []).append(op)
        return table

    def values(self) -> List[DValue]:
        seen: Dict[int, DValue] = {}
        for p in self.params:
            seen[p.vid] = p
        for op in self.ops:
            for v in op.all_operands():
                seen.setdefault(v.vid, v)
            for v in op.outputs:
                seen.setdefault(v.vid, v)
        return list(seen.values())

    def toposorted(self) -> List[DOp]:
        # ops are appended in construction order which is already topological
        return list(self.ops)

    # -------------------------------------------------------- fingerprint --
    def fingerprint(self) -> str:
        """Shape-free structural hash of the computation pattern.

        Two graphs with the same ops/wiring but different concrete dims have
        the same fingerprint — the DISC cache-key property.
        """
        h = hashlib.sha256()
        idx: Dict[int, int] = {}

        def vkey(v: DValue) -> Tuple:
            if v.vid not in idx:
                idx[v.vid] = len(idx)
            # rank and dtype are structure; dim values are NOT
            return (idx[v.vid], v.rank, np.dtype(v.dtype).str)

        def akey(v) -> str:
            # region ops carry nested DGraphs in attrs: fold their own
            # (shape-free) fingerprints in, never their repr — object
            # identity must not leak into the cache key
            if isinstance(v, DGraph):
                return f"<region:{v.fingerprint()}>"
            if isinstance(v, (tuple, list)) and any(
                    isinstance(x, DGraph) for x in v):
                return "(" + ",".join(akey(x) for x in v) + ")"
            return repr(v)

        for p in self.params:
            h.update(repr(("param", vkey(p))).encode())
        for op in self.ops:
            attrs = tuple(sorted((k, akey(v)) for k, v in op.attrs.items()))
            h.update(
                repr(
                    (
                        op.opcode,
                        tuple(vkey(v) for v in op.inputs),
                        tuple(vkey(v) for v in op.shape_operands),
                        tuple(vkey(v) for v in op.outputs),
                        attrs,
                    )
                ).encode()
            )
        for o in self.outputs:
            h.update(repr(("out", vkey(o))).encode())
        return h.hexdigest()[:16]

    # ------------------------------------------------------------- debug --
    def pretty(self) -> str:
        lines = [f"DGraph {self.name} ({len(self.ops)} ops)"]
        for p in self.params:
            lines.append(f"  param {p!r}")
        for op in self.ops:
            lines.append(f"  {op!r}")
        lines.append("  return " + ", ".join(map(repr, self.outputs)))
        return "\n".join(lines)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"<DGraph {self.name}: {len(self.ops)} ops, {len(self.params)} params>"
