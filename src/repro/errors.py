"""The DISC error taxonomy — every layer raises *through* these classes.

DISC compiles **during** serving: buckets, §4.4 escalations, and
promote-on-change all hit the compiler on the hot path, so a compile or
launch failure is a *runtime* event that the serving layer must survive,
not a build-time event that may abort the process.  This module gives
every layer one vocabulary for that:

* :class:`DiscError` — base class carrying ``transient`` (retry may
  succeed: backend ``RESOURCE_EXHAUSTED``, allocator pressure) vs
  permanent (retry cannot help: a :class:`~repro.core.constraints.\
ConstraintViolation`, an :class:`~repro.frontends.jaxpr_frontend.\
UnsupportedPrimitiveError`, a malformed spec).
* :class:`CompileError` — lowering/compilation of a bucket, exact
  escalation, or promote-on-change re-lower failed.  Subclasses
  ``ValueError`` as well so existing ``except ValueError`` call sites
  (and tests) keep working across the wrap.
* :class:`LaunchError` — a compiled artifact failed at call time.
* :class:`PoolExhausted` — the paged-KV pool cannot make progress
  (a request exceeded its bounded recompute budget under preemption).
* :class:`DeadlineExceeded` — a request's ``deadline_s`` passed before
  it completed.

:func:`classify_transient` is the single transient-vs-permanent decision
point; :func:`retry_call` is the capped-exponential-backoff helper the
degradation ladders share.  ``CONTROL_EXCEPTIONS`` names the exceptions
no ladder may ever swallow (``KeyboardInterrupt``/``SystemExit``/...).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

__all__ = [
    "DiscError", "CompileError", "LaunchError", "PoolExhausted",
    "DeadlineExceeded", "RetryPolicy", "classify_transient",
    "wrap_compile_error", "wrap_launch_error", "retry_call",
    "CONTROL_EXCEPTIONS",
]

#: exceptions that must always propagate — no fallback ladder, retry
#: loop, or rollback handler may swallow these
CONTROL_EXCEPTIONS: Tuple[type, ...] = (
    KeyboardInterrupt, SystemExit, GeneratorExit)

#: substrings of backend runtime-error messages that mark the failure as
#: transient (resource pressure, not a broken program) — XLA surfaces
#: allocator failures as ``RESOURCE_EXHAUSTED: ...`` / OOM text
_TRANSIENT_MARKERS: Tuple[str, ...] = (
    "RESOURCE_EXHAUSTED", "resource exhausted", "out of memory", "OOM")


class DiscError(Exception):
    """Base of the taxonomy.  ``transient`` answers the only question a
    degradation ladder asks: is retrying this exact operation allowed to
    succeed?"""

    def __init__(self, message: str, *, transient: bool = False):
        super().__init__(message)
        self.transient = transient


class CompileError(DiscError, ValueError):
    """Bucket / exact-escalation / promote-on-change compilation failed.

    Also a ``ValueError``: most permanent compile failures *are* value
    errors in the user's specs (shape contract violations, invalid
    sharding asks), and pre-taxonomy call sites catch ``ValueError``.
    """


class LaunchError(DiscError, RuntimeError):
    """A compiled artifact raised at call time (device launch failed)."""


class PoolExhausted(DiscError, RuntimeError):
    """Paged-KV pool pressure defeated a request: it hit its bounded
    recompute budget (preempted + recomputed too many times) and is
    retired FAILED instead of spinning in the preemption loop forever."""


class DeadlineExceeded(DiscError, TimeoutError):
    """A request's ``deadline_s`` passed before it completed; checked at
    admission and between engine steps."""


def classify_transient(exc: BaseException) -> bool:
    """The transient-vs-permanent decision, in one place.

    * :class:`DiscError` — trust its own flag (already classified).
    * ``ConstraintViolation`` / ``UnsupportedPrimitiveError`` /
      ``TypeError`` — permanent: the program or spec is wrong and will
      be wrong again.
    * anything whose message carries a resource-pressure marker
      (``RESOURCE_EXHAUSTED``, OOM) — transient: memory may free up.
    * everything else — permanent (the conservative default: blind
      retries of unknown failures just triple the latency of failing).
    """
    if isinstance(exc, DiscError):
        return exc.transient
    from .core.constraints import ConstraintViolation
    from .frontends.jaxpr_frontend import UnsupportedPrimitiveError
    if isinstance(exc, (ConstraintViolation, UnsupportedPrimitiveError,
                        TypeError)):
        return False
    msg = str(exc)
    return any(m in msg for m in _TRANSIENT_MARKERS)


def wrap_compile_error(exc: BaseException, what: str) -> CompileError:
    """Wrap ``exc`` (raised while compiling ``what``) into the taxonomy,
    preserving the original message and classification.  Already-wrapped
    errors pass through unchanged."""
    if isinstance(exc, CompileError):
        return exc
    err = CompileError(
        f"compile failed ({what}): {type(exc).__name__}: {exc}",
        transient=classify_transient(exc))
    err.__cause__ = exc     # chain even when the raise site omits `from`
    return err


def wrap_launch_error(exc: BaseException, what: str) -> LaunchError:
    """Wrap ``exc`` (raised while launching ``what``) into the taxonomy.
    A :class:`CompileError` escaping a launch (first call compiles inside
    dispatch) stays a CompileError — re-raise it, don't wrap."""
    if isinstance(exc, LaunchError):
        return exc
    err = LaunchError(
        f"launch failed ({what}): {type(exc).__name__}: {exc}",
        transient=classify_transient(exc))
    err.__cause__ = exc     # chain even when the raise site omits `from`
    return err


@dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff for *transient* failures.

    ``max_retries`` additional attempts after the first; sleeps
    ``backoff_s * multiplier**attempt`` between attempts, capped at
    ``cap_s``.  Permanent failures never retry.
    """

    max_retries: int = 2
    backoff_s: float = 0.01
    multiplier: float = 2.0
    cap_s: float = 0.25

    def delay(self, attempt: int) -> float:
        return min(self.backoff_s * (self.multiplier ** attempt), self.cap_s)


#: the default ladder policy shared by compile + launch retry loops
DEFAULT_RETRY = RetryPolicy()


def retry_call(fn: Callable[[], Any], *, policy: RetryPolicy = DEFAULT_RETRY,
               wrap: Callable[[BaseException], DiscError] = None,
               on_retry: Optional[Callable[[int, DiscError], None]] = None,
               sleep: Callable[[float], None] = time.sleep) -> Any:
    """Call ``fn``, retrying transient failures per ``policy``.

    ``wrap`` converts a raw exception into the taxonomy (e.g.
    ``lambda e: wrap_compile_error(e, "bucket (8, 64)")``); the wrapped
    error decides transience.  ``on_retry(attempt, err)`` is invoked
    before each sleep (counter hooks).  Control-flow exceptions always
    propagate unwrapped.
    """
    wrap = wrap or (lambda e: wrap_launch_error(e, "call"))
    attempt = 0
    while True:
        try:
            return fn()
        except CONTROL_EXCEPTIONS:
            raise
        except Exception as e:  # noqa: BLE001 — classified right below
            err = wrap(e)
            if not err.transient or attempt >= policy.max_retries:
                raise err from e
            if on_retry is not None:
                on_retry(attempt, err)
            sleep(policy.delay(attempt))
            attempt += 1
