"""SPMD planner: specs + bucket policy + mesh + profile → a sharding plan.

DISC's runtime flow (placement, buffer management, launch logic) is
*generated at compile time* (§4); this module extends that contract to
multi-device execution.  :func:`plan_spmd` runs at ``lower()`` time and
decides, once per artifact:

* **per-argument shardings** — each declared spec (``ArgSpec`` /
  ``TreeSpec`` / pass-through ``None``) gets a ``PartitionSpec`` from the
  :class:`~repro.dist.profiles.ShardingProfile`: dynamic dims the profile
  owns land on their mesh axes, fully-static arguments get the profile's
  weight layout (fitted to the mesh), pass-through arguments stay
  untouched (persistent trees are sharded once by their owner, e.g. the
  serve engine's params).
* **mesh-divisibility bucket constraints** — a sharded dynamic dim's
  buckets must divide evenly across the owning mesh axes *for every
  bucket the policy can produce*.  The planner **tightens the
  BucketPolicy** (granule ← lcm(granule, axis size)) so divisibility is a
  plan-time theorem, not a per-call check — exactly the Nimble lesson
  (shape-dependent logic stays out of the per-step path) composed with
  Relax's (symbolic shapes must compose with distribution).  Contracts
  that *cannot* be tightened — ``bucket="exact"`` dims, or a declared
  ``max`` the mesh axes do not divide — raise
  :class:`~repro.core.constraints.ConstraintViolation` at ``lower()``
  time.

The generated host dispatch consumes the plan: padded bucket buffers are
``device_put`` to their ``NamedSharding`` (guaranteed-even by the
tightened policy), lens vectors are replicated, and the §4.4 escalation
branch re-fits shardings to the exact (possibly non-divisible) shapes.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core.bucketing import BucketPolicy
from ..core.constraints import ConstraintViolation
from .profiles import ShardingProfile

__all__ = ["MeshDimConstraint", "ShardingPlan", "plan_spmd", "fit_spec",
           "replicated"]


def fit_spec(shape: Sequence[int], spec: P, mesh: Mesh) -> P:
    """Fit a logical spec to a concrete shape on a concrete mesh.

    Axis names the mesh lacks are dropped (logical specs name the full
    production axis set); axis groups that do not evenly divide the
    dimension lose their outermost axis first (GSPMD requires even
    division for explicit shardings — e.g. batch=1 cells, odd vocabs).
    """
    out = []
    for i, entry in enumerate(spec):
        if i >= len(shape) or entry is None:
            out.append(None)
            continue
        axes = list(entry) if isinstance(entry, (tuple, list)) else [entry]
        axes = [a for a in axes if a in mesh.axis_names]
        while axes:
            prod = 1
            for a in axes:
                prod *= mesh.shape[a]
            if shape[i] % prod == 0:
                break
            axes.pop(0)  # drop outermost (e.g. "pod") first
        if not axes:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(tuple(axes))
    return P(*out)


def replicated(mesh: Mesh) -> NamedSharding:
    """The fully-replicated sharding (lens vectors, scalars)."""
    return NamedSharding(mesh, P())


@dataclass(frozen=True)
class MeshDimConstraint:
    """One plan-time shape fact: every bucket of ``dim`` is a multiple of
    ``multiple_of`` (the product of the owning mesh axes' sizes)."""

    dim: str
    axes: Tuple[str, ...]
    multiple_of: int

    def as_dict(self) -> Dict[str, Any]:
        return {"dim": self.dim, "axes": list(self.axes),
                "multiple_of": self.multiple_of}


# per-argument plan entries
_ARRAY, _TREE = "array", "tree"


@dataclass
class ShardingPlan:
    """The emitted shardings for one artifact on one mesh."""

    mesh: Mesh
    profile: ShardingProfile
    # per argument: None | ("array", PartitionSpec) |
    #               ("tree", ((leaf_axis, mesh_axes | None), ...))
    arg_entries: Tuple[Optional[Tuple[str, Any]], ...]
    constraints: Tuple[MeshDimConstraint, ...] = ()
    _cache: Dict[Any, NamedSharding] = field(default_factory=dict)

    # ------------------------------------------------------------- lookup --
    def _named(self, spec: P) -> NamedSharding:
        key = tuple(spec)
        s = self._cache.get(key)
        if s is None:
            s = self._cache[key] = NamedSharding(self.mesh, spec)
        return s

    def arg_sharding(self, i: int) -> Optional[NamedSharding]:
        """The bucket-time sharding of array argument ``i`` (``None`` for
        pass-through and tree arguments)."""
        e = self.arg_entries[i]
        if e is None or e[0] != _ARRAY:
            return None
        return self._named(e[1])

    def lens_sharding(self) -> NamedSharding:
        return self._named(P())

    # -------------------------------------------------------------- trees --
    def tree_sharder(self, i: int) -> Optional[Callable[[Any], Any]]:
        """A ``tree -> tree`` callable ``device_put``-ing every array leaf
        of pytree argument ``i`` to its per-leaf sharding (``None`` when
        the argument is not a tree or shards nothing)."""
        e = self.arg_entries[i]
        if e is None or e[0] != _TREE:
            return None
        axes = [(ax, ma) for ax, ma in e[1] if ma]
        if not axes:
            return None

        by_shape: Dict[Tuple[int, ...], Any] = {}

        def put(tree):
            import jax

            def put_leaf(x):
                shape = getattr(x, "shape", None)
                if shape is None:
                    return x
                # padded bucket shapes recur across calls: cache the
                # fitted sharding per shape (cheap hot-path dispatch)
                sh = by_shape.get(tuple(shape))
                if sh is None:
                    entries: List[Any] = [None] * len(shape)
                    for ax, ma in axes:
                        if ax < len(shape):
                            entries[ax] = ma
                    sh = self._named(fit_spec(shape, P(*entries),
                                              self.mesh))
                    by_shape[tuple(shape)] = sh
                return jax.device_put(x, sh)

            return jax.tree.map(put_leaf, tree)

        return put

    # --------------------------------------------------------- escalation --
    def put_exact(self, arrays: Sequence[Any]) -> List[Any]:
        """Shard a call's *exact* (unpadded, possibly non-divisible)
        arguments for the §4.4 escalation path: each logical spec is
        re-fitted to the concrete shape, dropping axes that no longer
        divide evenly."""
        import jax

        out = []
        for i, x in enumerate(arrays):
            e = self.arg_entries[i]
            if e is None:
                out.append(x)
            elif e[0] == _ARRAY:
                shape = tuple(getattr(x, "shape", ()))
                out.append(jax.device_put(
                    x, self._named(fit_spec(shape, e[1], self.mesh))))
            else:
                sharder = self.tree_sharder(i)
                out.append(sharder(x) if sharder is not None else x)
        return out

    # ------------------------------------------------------------- report --
    def report(self) -> Dict[str, Any]:
        per_arg: List[Any] = []
        for e in self.arg_entries:
            if e is None:
                per_arg.append(None)
            elif e[0] == _ARRAY:
                per_arg.append(str(e[1]))
            else:
                per_arg.append(
                    {"tree": {ax: list(ma) if ma else None
                              for ax, ma in e[1]}})
        return {
            "mesh": {a: int(s) for a, s in self.mesh.shape.items()},
            "profile": self.profile.name,
            "per_arg": per_arg,
            "constraints": [c.as_dict() for c in self.constraints],
        }


def _tighten(policy: BucketPolicy, name: str, axes: Tuple[str, ...],
             m: int) -> BucketPolicy:
    """Tighten ``name``'s bucket rule so every bucket is a multiple of
    ``m`` — or prove it impossible (ConstraintViolation)."""
    import dataclasses

    kind, g = policy.rule(name)
    if kind == "exact":
        raise ConstraintViolation(
            f"dim {name!r} is sharded over mesh axes {axes} (size {m}) but "
            f"uses bucket='exact': exact buckets equal the runtime value "
            f"and cannot be proven divisible at plan time — use 'pow2' or "
            f"'multiple' bucketing, or a profile that does not shard "
            f"{name!r}")
    cap = policy.cap(name)
    if cap is not None and cap % m != 0:
        raise ConstraintViolation(
            f"dim {name!r} has max={cap}, not a multiple of its mesh axes "
            f"{axes} (size {m}): the cap-clamped bucket could not be "
            f"sharded evenly — declare a max divisible by {m}")
    g2 = math.lcm(g, m)
    if g2 == g:
        return policy
    replaced = False
    overrides: List[Tuple[str, Tuple[str, int]]] = []
    for n, rule in policy.overrides:
        if n == name:
            overrides.append((n, (kind, g2)))
            replaced = True
        else:
            overrides.append((n, rule))
    if not replaced:
        overrides.append((name, (kind, g2)))
    return dataclasses.replace(policy, overrides=tuple(overrides))


def plan_spmd(specs: Sequence[Any], policy: BucketPolicy, mesh: Mesh,
              profile: ShardingProfile,
              ) -> Tuple[ShardingPlan, BucketPolicy]:
    """Plan the per-argument shardings for one lowering.

    ``specs`` are the normalized per-argument specs (``ArgSpec`` /
    ``TreeSpec`` / ``None``); returns the plan plus the **tightened**
    bucket policy (sharded dynamic dims' granules are raised to the lcm
    with the owning mesh-axis sizes, so every bucket divides evenly).
    """
    from ..frontends.jaxpr_frontend import ArgSpec, TreeSpec

    mesh_axes = set(mesh.axis_names)

    # resolve each dynamic dim the profile owns to axes present on the mesh
    def present_axes(dim_name: str) -> Tuple[str, ...]:
        axes = profile.axes_for_dim(dim_name) or ()
        return tuple(a for a in axes if a in mesh_axes)

    constraints: List[MeshDimConstraint] = []
    seen: set = set()

    def note(dim_name: str) -> Tuple[str, ...]:
        nonlocal policy
        axes = present_axes(dim_name)
        if not axes:
            return ()
        m = 1
        for a in axes:
            m *= int(mesh.shape[a])
        if m > 1 and dim_name not in seen:
            seen.add(dim_name)
            policy = _tighten(policy, dim_name, axes, m)
            constraints.append(
                MeshDimConstraint(dim=dim_name, axes=axes, multiple_of=m))
        return axes

    entries: List[Optional[Tuple[str, Any]]] = []
    for spec in specs:
        if spec is None:
            entries.append(None)
            continue
        if isinstance(spec, TreeSpec):
            entries.append((_TREE, tuple(
                (ax, note(d) or None) for ax, d in spec.axes)))
            continue
        assert isinstance(spec, ArgSpec)
        if any(isinstance(d, str) for d in spec.shape):
            parts: List[Any] = []
            for d in spec.shape:
                axes = note(d) if isinstance(d, str) else ()
                if not axes:
                    parts.append(None)
                elif len(axes) == 1:
                    parts.append(axes[0])
                else:
                    parts.append(axes)
            entries.append((_ARRAY, P(*parts)))
        else:
            # fully static: weight-like — profile layout, fitted now
            # (static shapes are known at plan time)
            entries.append((_ARRAY, fit_spec(
                spec.shape, profile.leaf_spec(tuple(spec.shape)), mesh)))

    plan = ShardingPlan(mesh=mesh, profile=profile,
                        arg_entries=tuple(entries),
                        constraints=tuple(constraints))
    return plan, policy
