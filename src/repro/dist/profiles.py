"""Sharding profiles — named SPMD layouts over the logical mesh axes.

A :class:`ShardingProfile` answers the two questions any SPMD planner has
to answer for DISC artifacts:

* **which dynamic dims are sharded, and along which mesh axes** —
  ``dim_axes`` maps a symbolic-dim *name* (the strings in
  ``disc.compile`` specs, e.g. ``"B"``) to the logical axes that
  partition it.  The planner (:mod:`repro.dist.spmd`) intersects those
  with the axes the actual mesh defines, exactly like
  :func:`repro.dist.context.maybe_shard` prunes activation specs.
* **how persistent pytrees (params, KV caches) are laid out** —
  ``param_mode`` selects between replication (pure data parallel),
  ZeRO-3 full sharding (every leaf folded onto the joint data-parallel
  axis group), and tensor parallelism (honor the model-provided logical
  spec tree from ``model.specs()`` / ``model.cache_specs()``).

The three built-ins mirror the profiles the model zoo already names
(``ArchConfig.sharding_profile``), over the production axis set
``("pod", "data", "model")`` from :mod:`repro.models.layers`:

========  =========================  ======================================
profile   dynamic batch dim ``"B"``  params / caches
========  =========================  ======================================
``dp``    ``("pod", "data")``        replicated
``fsdp``  ``("pod", "data")``        every leaf ZeRO-3 sharded over the
                                     WHOLE mesh — under fsdp all axes
                                     (incl. ``"model"``) act as one
                                     data-parallel group, as in
                                     ``models/layers.py``'s ``_DP_ALL``
``tp``    ``("pod", "data")``        model-provided logical specs (TP
                                     weights on ``"model"``); generic
                                     leaves column-parallel on ``"model"``
========  =========================  ======================================

Profiles are plain frozen dataclasses: build a custom one with different
``dim_axes`` (e.g. sequence-sharded ``"S"``) and pass it anywhere a
profile name is accepted.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple, Union

from jax.sharding import PartitionSpec as P

__all__ = ["ShardingProfile", "get_profile", "list_profiles",
           "DP_AXES", "ALL_AXES", "PROFILES"]

#: the data-parallel axis group (gradient/batch partitioning)
DP_AXES: Tuple[str, ...] = ("pod", "data")
#: every logical production axis, in mesh order
ALL_AXES: Tuple[str, ...] = ("pod", "data", "model")

_PARAM_MODES = ("replicate", "fsdp", "tp")


@dataclass(frozen=True)
class ShardingProfile:
    """One named SPMD layout (see module docstring for the built-ins)."""

    name: str
    #: dynamic-dim name -> logical mesh axes sharding it
    dim_axes: Tuple[Tuple[str, Tuple[str, ...]], ...] = (
        ("B", DP_AXES),)
    #: "replicate" | "fsdp" | "tp" — persistent-pytree layout
    param_mode: str = "replicate"
    description: str = ""

    def __post_init__(self):
        if self.param_mode not in _PARAM_MODES:
            raise ValueError(
                f"unknown param_mode {self.param_mode!r} "
                f"(expected one of {_PARAM_MODES})")

    def replace(self, **kw) -> "ShardingProfile":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------ dynamic dims --
    def axes_for_dim(self, dim_name: str) -> Optional[Tuple[str, ...]]:
        """Logical mesh axes sharding ``dim_name``, or ``None``."""
        for name, axes in self.dim_axes:
            if name == dim_name:
                return tuple(axes)
        return None

    # --------------------------------------------------- persistent trees --
    def leaf_spec(self, shape: Tuple[int, ...]) -> P:
        """Logical spec for one *static* array (a weight-like leaf).

        The spec is logical — callers fit it to a concrete mesh with
        :func:`repro.dist.spmd.fit_spec`, which drops axes that do not
        divide the dimension.
        """
        nd = len(shape)
        if nd == 0 or self.param_mode == "replicate":
            return P(*([None] * nd))
        if self.param_mode == "fsdp":
            # ZeRO-3: fold EVERY mesh axis onto one dim (fsdp treats
            # the whole mesh as one data-parallel group); the largest
            # dim, so the fold is most likely to divide evenly
            target = max(range(nd), key=lambda i: shape[i])
            return P(*[ALL_AXES if i == target else None for i in range(nd)])
        # tp without a model-provided spec: column-parallel default
        # (shard the last dim on "model")
        return P(*([None] * (nd - 1) + ["model"]))

    def param_specs(self, tree: Any, logical: Any = None) -> Any:
        """A PartitionSpec tree congruent to ``tree``.

        ``logical`` is a model-provided spec tree (``model.specs()``);
        the ``tp`` profile returns it verbatim when given, the others
        derive specs per leaf from :meth:`leaf_spec`.
        """
        import jax

        if self.param_mode == "tp" and logical is not None:
            return logical
        return jax.tree.map(
            lambda x: self.leaf_spec(tuple(getattr(x, "shape", ()))), tree)

    def batch_axes(self) -> Tuple[str, ...]:
        """The mesh axes this profile shards the batch dim ``"B"`` on."""
        return self.axes_for_dim("B") or ()

    def batch_leaf_spec(self, ndim: int, batch_axis: int) -> P:
        """Spec for a batch-carrying leaf (KV-cache rows, activations):
        the batch axis is partitioned on the profile's batch axes."""
        axes = self.batch_axes()
        return P(*[(axes or None) if i == batch_axis else None
                   for i in range(ndim)])


PROFILES: Dict[str, ShardingProfile] = {
    "dp": ShardingProfile(
        name="dp", param_mode="replicate",
        description="pure data parallel: batch sharded, params replicated"),
    "fsdp": ShardingProfile(
        name="fsdp", param_mode="fsdp",
        description="ZeRO-3: batch sharded, params fully sharded over "
                    "the whole mesh (all axes one DP group), gathered "
                    "per use"),
    "tp": ShardingProfile(
        name="tp", param_mode="tp",
        description="tensor parallel: batch on DP axes, weights on "
                    "'model' per the model's logical specs"),
}


def get_profile(p: Union[str, ShardingProfile]) -> ShardingProfile:
    """Resolve a profile name (or pass a profile object through)."""
    if isinstance(p, ShardingProfile):
        return p
    try:
        return PROFILES[p]
    except KeyError:
        raise ValueError(
            f"unknown sharding profile {p!r} "
            f"(expected one of {sorted(PROFILES)} or a ShardingProfile)")


def list_profiles() -> Tuple[str, ...]:
    return tuple(sorted(PROFILES))
