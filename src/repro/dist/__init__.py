from .context import use_mesh, get_mesh, maybe_shard  # noqa: F401
