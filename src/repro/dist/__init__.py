"""``repro.dist`` — the SPMD subsystem.

* :mod:`repro.dist.context`  — ambient-mesh context (``use_mesh`` /
  ``maybe_shard`` activation hints; single-device no-op).
* :mod:`repro.dist.profiles` — named sharding layouts (``dp`` / ``fsdp``
  / ``tp``) over the logical ``("pod", "data", "model")`` axes.
* :mod:`repro.dist.spmd`     — the plan-time SPMD planner: per-argument
  shardings + mesh-divisibility bucket constraints, consumed by the
  generated dispatch (``CompileOptions(mesh=..., sharding_profile=...)``).
"""
from .context import use_mesh, get_mesh, maybe_shard  # noqa: F401
from .profiles import (  # noqa: F401
    ALL_AXES, DP_AXES, PROFILES, ShardingProfile, get_profile,
    list_profiles,
)
from .spmd import (  # noqa: F401
    MeshDimConstraint, ShardingPlan, fit_spec, plan_spmd, replicated,
)
from ..launch.mesh import make_mesh  # noqa: F401  (device-state-free import)

__all__ = [
    "use_mesh", "get_mesh", "maybe_shard",
    "ShardingProfile", "get_profile", "list_profiles", "PROFILES",
    "DP_AXES", "ALL_AXES",
    "ShardingPlan", "MeshDimConstraint", "plan_spmd", "fit_spec",
    "replicated", "make_mesh",
]
