"""Mesh context for distributed execution — single-device no-op by default.

Models and launchers are written mesh-aware (``maybe_shard`` on activation
boundaries, ``get_mesh()`` for expert-parallel branching).  On a single
device, or outside any ``use_mesh`` scope, every call here degrades to a
no-op so the same model code runs unsharded.

Multi-device behaviour: ``use_mesh`` installs a ``jax.sharding.Mesh`` for
the dynamic extent of the ``with`` block; ``maybe_shard`` then applies
``lax.with_sharding_constraint`` with the spec *pruned to the axes that
actually exist on the mesh* (layer code names the full production axis set
``("pod", "data", "model")``; smaller meshes simply ignore missing axes).
"""
from __future__ import annotations

import contextlib
import threading
import warnings
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

__all__ = ["use_mesh", "get_mesh", "maybe_shard"]

_state = threading.local()


def get_mesh() -> Optional[Mesh]:
    """The innermost active mesh, or ``None`` outside any ``use_mesh``."""
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    """Install ``mesh`` as the ambient mesh for the duration of the block."""
    prev = get_mesh()
    _state.mesh = mesh
    try:
        yield mesh
    finally:
        _state.mesh = prev


def _prune_spec(spec: PartitionSpec, mesh: Mesh) -> PartitionSpec:
    """Drop axis names the mesh does not have (logical specs name the full
    production axis set; a 1-axis test mesh keeps only what it defines)."""
    names = set(mesh.axis_names)
    pruned = []
    for entry in spec:
        if entry is None:
            pruned.append(None)
        elif isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in names)
            pruned.append(kept if kept else None)
        else:
            pruned.append(entry if entry in names else None)
    return PartitionSpec(*pruned)


def maybe_shard(x: Any, spec: Optional[PartitionSpec]) -> Any:
    """Constrain ``x`` to ``spec`` under the active mesh; identity otherwise.

    A spec *longer than the array's rank* (a layer spec written for the
    full-production tensor reaching a reduced/squeezed variant) is
    truncated to the leading ``ndim`` entries with a warning instead of
    crashing — sharding is an optimization hint, never a correctness
    requirement.
    """
    mesh = get_mesh()
    if mesh is None or spec is None:
        return x
    ndim = getattr(x, "ndim", None)
    if ndim is not None and len(spec) > ndim:
        warnings.warn(
            f"maybe_shard: spec {spec} has {len(spec)} entries but the "
            f"array has rank {ndim}; truncating the spec to the leading "
            f"{ndim} entries", stacklevel=2)
        spec = PartitionSpec(*tuple(spec)[:ndim])
    try:
        sharding = NamedSharding(mesh, _prune_spec(spec, mesh))
        return jax.lax.with_sharding_constraint(x, sharding)
    except ValueError:
        # remaining mismatches (uneven shards etc.) fall through to
        # unconstrained
        return x
