#!/usr/bin/env bash
# Repo check: public-API import lint + tier-1 tests (+ benchmark smoke).
#
#   scripts/check.sh            # lint + tests
#   scripts/check.sh --lint     # lint only (fast)
#   scripts/check.sh --smoke    # lint + tests + benchmark smoke run (CI gate)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

MODE="${1:-}"

python scripts/import_lint.py

if [[ "$MODE" != "--lint" ]]; then
    python -m pytest -q
fi

if [[ "$MODE" == "--smoke" ]]; then
    python -m benchmarks.run --smoke
fi
