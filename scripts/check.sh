#!/usr/bin/env bash
# Repo check: public-API import lint + docs check + tier-1 tests
# (+ benchmark smoke).
#
#   scripts/check.sh            # lint + docs + tests
#   scripts/check.sh --lint     # lint only (fast)
#   scripts/check.sh --docs     # docs link/anchor/stale-reference check only
#   scripts/check.sh --smoke    # lint + docs + tests + benchmark smoke (CI gate)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

MODE="${1:-}"

if [[ "$MODE" == "--docs" ]]; then
    python scripts/docs_check.py
    exit 0
fi

python scripts/import_lint.py

if [[ "$MODE" == "--lint" ]]; then
    exit 0
fi

python scripts/docs_check.py

python -m pytest -q

if [[ "$MODE" == "--smoke" ]]; then
    python -m benchmarks.run --smoke
fi
