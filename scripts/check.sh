#!/usr/bin/env bash
# Repo check: public-API import lint + docs check + tier-1 tests
# (+ benchmark smoke).
#
#   scripts/check.sh            # lint + docs + tests
#   scripts/check.sh --lint     # lint only (fast)
#   scripts/check.sh --docs     # docs link/anchor/stale-reference check only
#   scripts/check.sh --smoke    # lint + docs + tests + benchmark smoke (CI gate)
#   scripts/check.sh --dist     # SPMD tests + dist benchmark smoke; run under
#                               # XLA_FLAGS=--xla_force_host_platform_device_count=8
#                               # for a real multi-device host mesh (CI does)
#   scripts/check.sh --serve    # serve-path tests (batching, paged KV,
#                               # speculative) + serve benchmark smoke, which
#                               # asserts ≥2x concurrent slots at equal KV
#                               # memory and paged/speculative output parity
#   scripts/check.sh --ctrl     # differential control-flow suite (while/
#                               # scan/cond region ops, both pipelines) +
#                               # single-artifact decode benchmark smoke
#   scripts/check.sh --ft       # fault-tolerance: differential fault-
#                               # injection suite (taxonomy, retry ladders,
#                               # deadlines, replica drain) + a seeded
#                               # chaos pass of the serve benchmark
#   scripts/check.sh --obs      # observability: tracing/metrics/cost-
#                               # accounting suite + obs benchmark smoke,
#                               # which holds disabled-tracer serve overhead
#                               # under 2% and schema-validates the exported
#                               # Chrome trace
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

MODE="${1:-}"

if [[ "$MODE" == "--docs" ]]; then
    python scripts/docs_check.py
    exit 0
fi

if [[ "$MODE" == "--dist" ]]; then
    python -m pytest tests/test_dist_spmd.py -q
    python -m benchmarks.bench_dist --smoke
    exit 0
fi

if [[ "$MODE" == "--serve" ]]; then
    python -m pytest tests/test_serve_batching.py tests/test_serve_paging.py -q
    python -m benchmarks.bench_serve --smoke
    exit 0
fi

if [[ "$MODE" == "--ctrl" ]]; then
    python -m pytest tests/test_control_flow.py -q
    python -m benchmarks.bench_control_flow --smoke
    exit 0
fi

if [[ "$MODE" == "--ft" ]]; then
    python -m pytest tests/test_faults.py -q
    python -m benchmarks.bench_serve --smoke --chaos
    exit 0
fi

if [[ "$MODE" == "--obs" ]]; then
    python -m pytest tests/test_observability.py -q
    python -m benchmarks.bench_obs --smoke
    exit 0
fi

python scripts/import_lint.py

if [[ "$MODE" == "--lint" ]]; then
    exit 0
fi

python scripts/docs_check.py

python -m pytest -q

if [[ "$MODE" == "--smoke" ]]; then
    python -m benchmarks.run --smoke
fi
