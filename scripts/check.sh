#!/usr/bin/env bash
# Repo check: tier-1 tests + public-API import lint.
#
#   scripts/check.sh            # everything
#   scripts/check.sh --lint     # lint only (fast)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python scripts/import_lint.py

if [[ "${1:-}" != "--lint" ]]; then
    python -m pytest -q
fi
